//! Train→serve round-trip integration tests: the repo's first end-to-end
//! loop from a native Attn-QAT finetune to the sharded decode cluster.
//!
//! The load-bearing chain:
//!
//! 1. finetune a tiny `QatModel` with `TrainSession` (Adam + global
//!    grad-clip, per-layer Attn-QAT backward),
//! 2. export the quantized checkpoint, re-import it,
//! 3. serve the imported model through `DecodeCluster` at 1 and 4 shards,
//! 4. assert every completion is **bitwise identical** to a direct greedy
//!    decode of the same model (`model::greedy_decode`, which replicates
//!    the shard worker's per-sequence math independently) — placement
//!    invariance extended across the train→serve boundary.

use attn_qat::attention::AttnConfig;
use attn_qat::model::{
    greedy_decode, LmTrainTask, QatModel, QatModelConfig, TrainConfig, TrainSession,
};
use attn_qat::serve::{ClusterConfig, DecodeCluster, Request, ShardConfig};

const SEED: u64 = 0xab5e;

fn tiny_model() -> QatModel {
    QatModel::new(QatModelConfig {
        layers: 2,
        heads: 2,
        head_dim: 16,
        ff: 32,
        max_pos: 128,
        seed: SEED,
        attn: AttnConfig::attn_qat(),
    })
}

/// Finetune for a few steps and hand back the trained model.
fn finetune(steps: usize) -> QatModel {
    let task = LmTrainTask::new(tiny_model(), 24, SEED ^ 1);
    let mut session = TrainSession::new(task, TrainConfig::adam(5e-3));
    session.run(steps, 0, |_| {});
    assert!(!session.diverged(), "tiny finetune must stay finite");
    assert!(session.max_grad_norm() > 0.0, "gradients must flow");
    session.model.into_model()
}

fn trace() -> Vec<Request> {
    (0..8u64)
        .map(|i| Request {
            id: i * 5 + 3, // non-contiguous ids exercise the router hash
            prompt: format!("t{i} serve#").into_bytes(),
            max_new_tokens: 5 + (i as usize % 3),
            temperature: 0.0, // greedy: comparable to greedy_decode
            deadline_ms: None,
            trace: Default::default(),
        })
        .collect()
}

#[test]
fn finetuned_model_serves_bitwise_across_shardings_and_direct_eval() {
    let trained = finetune(6);
    let dir = std::env::temp_dir().join("attn_qat_train_serve_test");
    let ckpt = dir.join("finetuned.ckpt");
    trained.save_quantized(&ckpt).unwrap();
    let served = QatModel::load(&ckpt, AttnConfig::fp4()).unwrap();

    let reqs = trace();
    let serve_attn = AttnConfig::fp4();
    let run_cluster = |shards: usize| {
        let cfg = ClusterConfig {
            shards,
            queue_depth: 8,
            shard: ShardConfig {
                slots: 2,
                attn: serve_attn,
                seq_max: 128,
                sample_seed: SEED,
                ..ShardConfig::default()
            },
            ..ClusterConfig::default()
        };
        let model = served.clone();
        let mut cluster = DecodeCluster::spawn(cfg, move |_| Box::new(model.clone()));
        for r in &reqs {
            cluster.submit(r.clone()).expect("submit");
        }
        cluster.drain().expect("drain")
    };
    let (one, _) = run_cluster(1);
    let (four, stats) = run_cluster(4);
    assert_eq!(one.len(), reqs.len());
    assert_eq!(four.len(), reqs.len());
    assert!(
        stats.shards.iter().filter(|s| s.requests > 0).count() >= 2,
        "8 hashed ids should land on at least two of four shards"
    );

    // Placement invariance + direct-eval parity, bitwise.
    for (a, b) in one.iter().zip(&four) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.text, b.text, "req {}: 1-shard vs 4-shard", a.id);
    }
    for c in &one {
        let req = reqs.iter().find(|r| r.id == c.id).unwrap();
        let direct =
            greedy_decode(&served, serve_attn, &req.prompt, req.max_new_tokens, 128).unwrap();
        assert_eq!(c.text, direct, "req {}: cluster vs direct model eval", c.id);
        assert!(c.new_tokens >= 1);
        assert_eq!(c.text.len(), c.prompt_tokens + c.new_tokens);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn export_import_roundtrip_is_stable_for_serving() {
    // Loading an exported checkpoint and re-exporting it must produce a
    // model that decodes identically: the quantized projections are
    // already on the export lattice, embeddings/head are f32-exact.
    let trained = finetune(3);
    let dir = std::env::temp_dir().join("attn_qat_train_serve_rt");
    let (p1, p2) = (dir.join("a.ckpt"), dir.join("b.ckpt"));
    trained.save_quantized(&p1).unwrap();
    let m1 = QatModel::load(&p1, AttnConfig::fp4()).unwrap();
    m1.save_quantized(&p2).unwrap();
    let m2 = QatModel::load(&p2, AttnConfig::fp4()).unwrap();
    let out1 = greedy_decode(&m1, AttnConfig::fp4(), b"stable?", 6, 64).unwrap();
    let out2 = greedy_decode(&m2, AttnConfig::fp4(), b"stable?", 6, 64).unwrap();
    assert_eq!(out1, out2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn training_improves_over_longer_runs() {
    // The full pipeline learns: 40 Adam steps on the synthetic corpus
    // lower the CE loss (simulated margin is wide; assert improvement).
    let task = LmTrainTask::new(tiny_model(), 32, SEED ^ 2);
    let mut session = TrainSession::new(task, TrainConfig::adam(5e-3));
    session.run(40, 0, |_| {});
    assert!(!session.diverged());
    let first = session.history[0].loss;
    let tail = session.tail_loss(8);
    assert!(tail < first, "CE should drop: first {first}, tail-8 {tail}");
}

#[test]
fn f32_serving_config_also_round_trips() {
    // The same checkpoint served with the gather+f32 baseline config:
    // still placement-invariant and equal to direct eval (the A/B switch
    // is just an AttnConfig).
    let trained = finetune(3);
    let dir = std::env::temp_dir().join("attn_qat_train_serve_f32");
    let ckpt = dir.join("m.ckpt");
    trained.save_quantized(&ckpt).unwrap();
    let served = QatModel::load(&ckpt, AttnConfig::f32()).unwrap();
    let serve_attn = AttnConfig::f32();
    let req = Request {
        id: 9,
        prompt: b"base ab#".to_vec(),
        max_new_tokens: 5,
        temperature: 0.0,
        deadline_ms: None,
        trace: Default::default(),
    };
    let cfg = ClusterConfig {
        shards: 2,
        queue_depth: 4,
        shard: ShardConfig {
            slots: 2,
            attn: serve_attn,
            seq_max: 128,
            sample_seed: SEED,
            ..ShardConfig::default()
        },
        ..ClusterConfig::default()
    };
    let model = served.clone();
    let mut cluster = DecodeCluster::spawn(cfg, move |_| Box::new(model.clone()));
    cluster.submit(req.clone()).unwrap();
    let (done, _) = cluster.drain().unwrap();
    let direct = greedy_decode(&served, serve_attn, &req.prompt, 5, 128).unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].text, direct);
    std::fs::remove_dir_all(&dir).ok();
}
