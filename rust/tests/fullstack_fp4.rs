//! Full-stack FP4 training integration tests: the properties the
//! `lowp` subsystem promises across module boundaries.
//!
//! * E4M3 stochastic rounding is exact on lattice points, empirically
//!   unbiased between them, and saturating at the format edges — the
//!   three properties that make 2-byte Adam moments trustworthy.
//! * `LowPAdam` matches f32 Adam's 40-step cross-entropy improvement on
//!   a real `LmTrainTask` while holding ~2 bytes of moment state per
//!   parameter (vs Adam's 8).
//! * `TrainConfig::with_microbatch(1)` is bitwise the plain
//!   single-sequence step.
//! * v3 train checkpoints resume a low-precision finetune bitwise
//!   (E4M3 moment bytes verbatim, data stream realigned with
//!   `skip_batches`); v2 tensor checkpoints still load.
//! * The `exp fullstack` ablation grid separates the careful
//!   low-precision arms (≈ attn-only baseline) from the naive hard
//!   requantizer (stalls), and publishes the `train.lowp.*` gauges.

use attn_qat::config::Config;
use attn_qat::coordinator::checkpoint;
use attn_qat::experiments::fullstack;
use attn_qat::formats::e4m3;
use attn_qat::model::{
    LmTrainTask, ProjQuant, QatModel, QatModelConfig, TrainConfig, TrainSession, TrainableModel,
};
use attn_qat::rng::Rng;
use attn_qat::tensor::Tensor;

fn tmp_path(name: &str) -> std::path::PathBuf {
    let pid = std::process::id();
    std::env::temp_dir().join(format!("attn_qat_fullstack_{pid}_{name}"))
}

// ---------------------------------------------------------------- e4m3 SR

#[test]
fn e4m3_stochastic_roundtrip_is_exact_on_every_code() {
    // Every representable value must come back unchanged for any u:
    // lattice points have a zero-width bracket, so the draw is irrelevant.
    for byte in 0u16..=0xFF {
        let byte = byte as u8;
        if byte & 0x7F == 0x7F {
            continue; // NaN codes
        }
        let v = e4m3::decode(byte);
        for u in [0.0, 0.25, 0.5, 0.999_999] {
            let back = e4m3::decode(e4m3::encode_stochastic(v, u));
            assert_eq!(back, v, "byte {byte:#04x} (value {v}) moved under u={u}");
        }
    }
}

#[test]
fn e4m3_stochastic_rounding_is_empirically_unbiased() {
    // x = lo + 0.25 * step between 1.0 and 1.125: E[decode] must be x.
    let mut rng = Rng::new(0x5eed_e4_53);
    for x in [1.031_25f32, -1.031_25, 3.1, 0.019, 100.0] {
        let n = 20_000usize;
        let mut sum = 0.0f64;
        for _ in 0..n {
            sum += e4m3::decode(e4m3::encode_stochastic(x, rng.uniform())) as f64;
        }
        let mean = sum / n as f64;
        // sigma of the mean is at most step/(2*sqrt(n)); 4.5 sigma keeps
        // the fixed-seed draw safely inside while real bias (O(step))
        // would still blow straight through.
        let lo = e4m3::decode(e4m3::encode(x)).abs();
        let step = e4m3::decode(e4m3::encode(x).wrapping_add(1)).abs() - lo;
        let tol = 4.5 * (step.abs() as f64).max(1e-6) / (2.0 * (n as f64).sqrt());
        assert!(
            (mean - x as f64).abs() < tol.max(2e-3),
            "biased SR for {x}: mean {mean} (tol {tol})"
        );
    }
}

#[test]
fn e4m3_stochastic_saturates_at_the_edges() {
    let mut rng = Rng::new(77);
    for _ in 0..200 {
        let u = rng.uniform();
        // Above MAX: deterministic clamp to +/-448, never NaN.
        assert_eq!(e4m3::decode(e4m3::encode_stochastic(1.0e9, u)), e4m3::MAX);
        assert_eq!(e4m3::decode(e4m3::encode_stochastic(f32::INFINITY, u)), e4m3::MAX);
        assert_eq!(e4m3::decode(e4m3::encode_stochastic(-5000.0, u)), -e4m3::MAX);
        // Just under MAX: brackets to one of the two top codes.
        let near = e4m3::decode(e4m3::encode_stochastic(440.0, u));
        assert!(near == e4m3::MAX || near == 416.0, "440 -> {near}");
        // Below the smallest subnormal: rounds to zero or the subnormal,
        // never away.
        let tiny = e4m3::decode(e4m3::encode_stochastic(e4m3::MIN_SUBNORMAL * 0.3, u));
        assert!(tiny == 0.0 || tiny == e4m3::MIN_SUBNORMAL, "tiny -> {tiny}");
    }
}

// ------------------------------------------------------- optimizer parity

fn lm_session(proj: ProjQuant, cfg: TrainConfig) -> TrainSession<LmTrainTask> {
    let mut model = QatModel::new(QatModelConfig {
        ff: 32,
        max_pos: 64,
        seed: 9,
        ..QatModelConfig::default()
    });
    model.set_proj_quant(proj);
    TrainSession::new(LmTrainTask::new(model, 24, 0xda7a), cfg)
}

#[test]
fn lowp_adam_matches_f32_adam_ce_improvement_at_two_bytes_per_param() {
    let steps = 40;
    let mut a = lm_session(ProjQuant::off(), TrainConfig::adam(5e-3));
    let mut b = lm_session(ProjQuant::off(), TrainConfig::lowp_adam(5e-3, 0xfeed));
    a.run(steps, 0, |_| {});
    b.run(steps, 0, |_| {});
    let imp_a = a.history[0].loss - a.tail_loss(10);
    let imp_b = b.history[0].loss - b.tail_loss(10);
    assert!(imp_a > 0.1, "f32 Adam failed to learn: {imp_a}");
    assert!(imp_b > 0.1, "LowPAdam failed to learn: {imp_b}");
    assert!(
        (imp_a - imp_b).abs() < 0.5,
        "CE-improvement gap too large: adam {imp_a:.4} vs lowp {imp_b:.4}"
    );

    // Moment state: 2 bytes/param + one f32 scale per tensor per moment.
    let (mut n_params, mut n_tensors) = (0usize, 0usize);
    b.model.visit_params(&mut |w, _| {
        n_params += w.len();
        n_tensors += 1;
    });
    let bytes = b.optimizer_state_bytes();
    assert!(bytes >= 2 * n_params, "missing moment bytes: {bytes}");
    assert!(
        bytes <= 2 * n_params + 8 * n_tensors,
        "more than ~2 B/param: {bytes} for {n_params} params"
    );
    assert_eq!(a.optimizer_state_bytes(), 8 * n_params, "f32 Adam is 8 B/param");
}

#[test]
fn microbatch_one_is_bitwise_the_single_sequence_step() {
    let mut a = lm_session(ProjQuant::ste(), TrainConfig::lowp_adam(5e-3, 0xabc));
    let cfg_mb1 = TrainConfig::lowp_adam(5e-3, 0xabc).with_microbatch(1);
    let mut b = lm_session(ProjQuant::ste(), cfg_mb1);
    a.run(10, 0, |_| {});
    b.run(10, 0, |_| {});
    for (ma, mb) in a.history.iter().zip(b.history.iter()) {
        assert_eq!(ma.loss.to_bits(), mb.loss.to_bits(), "loss diverged at step {}", ma.step);
    }
    let (mut wa, mut wb) = (Vec::new(), Vec::new());
    a.model.visit_params(&mut |w, _| wa.extend_from_slice(w));
    b.model.visit_params(&mut |w, _| wb.extend_from_slice(w));
    assert!(wa.iter().zip(&wb).all(|(x, y)| x.to_bits() == y.to_bits()), "weights diverged");
}

// ------------------------------------------------------------ checkpoints

#[test]
fn v3_train_checkpoint_resumes_a_lowp_finetune_bitwise() {
    let path = tmp_path("resume.ckpt");
    let cfg = TrainConfig::lowp_adam(5e-3, 0x1dea);
    let mut a = lm_session(ProjQuant::ste(), cfg);
    a.run(6, 0, |_| {});
    a.save_checkpoint(&path).unwrap();
    a.run(4, 0, |_| {});

    let mut b = lm_session(ProjQuant::ste(), cfg);
    b.load_checkpoint(&path).unwrap();
    b.model.skip_batches(6); // realign the data stream with the saved step
    b.run(4, 0, |_| {});

    for i in 0..4 {
        let (la, lb) = (a.history[6 + i].loss, b.history[i].loss);
        assert_eq!(la.to_bits(), lb.to_bits(), "resumed loss diverged at +{i}");
    }
    let (mut wa, mut wb) = (Vec::new(), Vec::new());
    a.model.visit_params(&mut |w, _| wa.extend_from_slice(w));
    b.model.visit_params(&mut |w, _| wb.extend_from_slice(w));
    assert!(wa.iter().zip(&wb).all(|(x, y)| x.to_bits() == y.to_bits()), "weights diverged");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn v2_checkpoints_still_load_and_v3_files_read_as_plain_tensors() {
    let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
    let named = [("w".to_string(), &t)];

    let v2 = tmp_path("v2.ckpt");
    checkpoint::save(&v2, &named).unwrap();
    let (tensors, state) = checkpoint::load_train(&v2).unwrap();
    assert_eq!(tensors[0].1.data, t.data);
    assert!(state.is_none(), "v2 has no optimizer section");

    let v3 = tmp_path("v3.ckpt");
    checkpoint::save_train(&v3, &named, None).unwrap();
    let tensors = checkpoint::load(&v3).unwrap();
    assert_eq!(tensors[0].1.data, t.data);
    let _ = std::fs::remove_file(&v2);
    let _ = std::fs::remove_file(&v3);
}

// ---------------------------------------------------------- ablation grid

#[test]
fn fullstack_ablation_grid_separates_naive_from_ste() {
    let mut cfg = Config::default();
    cfg.set("fullstack.steps=50").unwrap();
    cfg.set("fullstack.seq=24").unwrap();
    let outcomes = fullstack::run_grid(&cfg);
    let find = |name: &str| {
        outcomes
            .iter()
            .map(|(o, ..)| o)
            .find(|o| o.name == name)
            .unwrap_or_else(|| panic!("missing arm {name}"))
    };
    let attn = find("attn_only");
    let full = find("fullstack");
    let naive = find("naive_proj");

    assert!(!attn.diverged && !full.diverged, "baseline arms must train");
    // Careful full-stack FP4 tracks the attn-only baseline.
    assert!(
        (attn.final_loss - full.final_loss).abs() < 0.8,
        "full-stack drifted: attn {:.4} vs full {:.4}",
        attn.final_loss,
        full.final_loss
    );
    // The naive hard requantizer measurably degrades (requant erases
    // Adam-scale updates) or trips the watchdog.
    assert!(
        naive.final_loss > attn.final_loss + 0.2 || naive.rollbacks > 0 || naive.diverged,
        "naive requant should stall: naive {:.4} vs attn {:.4} ({} rollbacks)",
        naive.final_loss,
        attn.final_loss,
        naive.rollbacks
    );
    // Low-precision arms publish the train.lowp.* health gauges and hold
    // ~2 B/param of moment state; f32 Adam arms hold 8.
    assert!(full.m_sat_frac.is_finite() && full.sr_bias.is_finite(), "lowp gauges missing");
    assert!(full.opt_bytes_per_param < 2.5, "lowp state too big: {}", full.opt_bytes_per_param);
    assert!((attn.opt_bytes_per_param - 8.0).abs() < 0.1, "adam state is 8 B/param");
}
