//! Integration pins for the telemetry subsystem (ISSUE PR 7).
//!
//! Five contracts, each of which downstream tooling depends on:
//!
//! 1. **Golden schema** — `Telemetry::snapshot()` (the doc behind
//!    `repro serve cluster --json` and `DecodeCluster::introspect`)
//!    keeps its versioned top-level shape and the documented metric /
//!    config / span paths.
//! 2. **Registry exactness** — counters and histograms shared across
//!    threads lose nothing under contention.
//! 3. **Span ring** — overflow evicts oldest-first; the newest records
//!    always survive.
//! 4. **Disabled fast path** — a dark `Telemetry` handle performs zero
//!    heap allocations per span guard / metric publish.
//! 5. **Facade parity** — the registry agrees field-for-field with the
//!    typed `ClusterStats` facade after a real 4-shard drain.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use attn_qat::attention::AttnConfig;
use attn_qat::experiments::cluster::{demo_trace, serve_trace_observed};
use attn_qat::json::Json;
use attn_qat::serve::{FaultPlan, SupervisorConfig};
use attn_qat::telemetry::Telemetry;

// ---------------------------------------------------------------------
// Counting allocator: per-thread allocation counter so the disabled
// fast-path test is immune to allocations on concurrently running test
// threads. `Cell<u64>` has no destructor, so the const-init
// thread-local never allocates (or runs TLS dtors) from inside `alloc`.
// ---------------------------------------------------------------------

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------
// Helpers: walk a snapshot by dotted path.
// ---------------------------------------------------------------------

fn at<'a>(doc: &'a Json, path: &str) -> &'a Json {
    path.split('.').fold(doc, |d, k| d.get(k))
}

fn num(doc: &Json, path: &str) -> f64 {
    at(doc, path).as_f64().unwrap_or_else(|| panic!("no number at {path:?} in {doc}"))
}

#[test]
fn snapshot_schema_is_stable() -> anyhow::Result<()> {
    let trace = demo_trace(12, 6, 7);
    let (_wall, stats, done, doc) = serve_trace_observed(
        2,
        AttnConfig::fp4(),
        4,
        7,
        &trace,
        FaultPlan::none(),
        SupervisorConfig::default(),
        Telemetry::new(),
    )?;
    assert_eq!(done.len(), trace.len());

    // Top-level shape is the versioned contract. Adding a key means
    // bumping SCHEMA_VERSION and updating this pin.
    let keys: Vec<&str> =
        doc.as_obj().expect("snapshot is an object").keys().map(|s| s.as_str()).collect();
    assert_eq!(keys, ["config", "enabled", "metrics", "schema_version", "spans"]);
    assert_eq!(num(&doc, "schema_version"), 1.0);
    assert!(matches!(at(&doc, "enabled"), Json::Bool(true)));

    // Config section reflects the live ClusterConfig, attn variant included.
    assert_eq!(num(&doc, "config.cluster.shards"), 2.0);
    assert_eq!(num(&doc, "config.cluster.shard.slots"), 4.0);
    assert_eq!(at(&doc, "config.cluster.shard.attn.variant").as_str(), Some("fp4"));
    assert!(num(&doc, "config.cluster.supervisor.max_restarts") >= 1.0);

    // Metrics nest by dotted name; per-shard totals reconcile with the
    // typed facade and histogram leaves expand to summary objects.
    let tokens: f64 =
        (0..2).map(|i| num(&doc, &format!("metrics.serve.shard{i}.tokens"))).sum();
    assert_eq!(tokens as usize, stats.total_tokens());
    assert_eq!(num(&doc, "metrics.serve.cluster.submitted") as usize, trace.len());
    assert_eq!(num(&doc, "metrics.serve.supervisor.restarts"), 0.0);
    assert!(num(&doc, "metrics.serve.shard0.token_ms.count") >= 1.0);
    assert!(num(&doc, "metrics.serve.shard0.kv_bytes_peak") > 0.0);
    let hit_rate = num(&doc, "metrics.serve.shard0.qcache_hit_rate");
    assert!((0.0..=1.0).contains(&hit_rate));

    // Span section: ring bookkeeping plus per-name aggregates covering
    // the serve pipeline. Since the causal-tracing PR the batch-level
    // spans are `step.*`; the per-request lifecycle contributes
    // request/route/queue/admit/prefill/decode.token/finish.
    assert!(num(&doc, "spans.recorded") > 0.0);
    assert!(num(&doc, "spans.capacity") > 0.0);
    assert_eq!(num(&doc, "spans.dropped"), 0.0, "this trace fits the default ring");
    for name in [
        "request",
        "route",
        "queue",
        "admit",
        "prefill",
        "decode.token",
        "finish",
        "step.admit",
        "step.decode",
        "drain",
    ] {
        assert!(
            num(&doc, &format!("spans.by_name.{name}.count")) >= 1.0,
            "span {name:?} missing from snapshot"
        );
    }
    Ok(())
}

#[test]
fn registry_totals_are_exact_under_contention() {
    let tele = Telemetry::new();
    let reg = tele.registry();
    const THREADS: u64 = 8;
    const PER: u64 = 10_000;
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            // Handles for one name share a single atomic cell, so each
            // thread cloning its own handle must still sum exactly.
            let ctr = reg.counter("test.contended");
            let hist = reg.histogram("test.latency");
            s.spawn(move || {
                for i in 0..PER {
                    ctr.inc();
                    hist.record((i % 7) as f64 * 0.25);
                }
            });
        }
    });
    assert_eq!(reg.counter("test.contended").get(), THREADS * PER);
    assert_eq!(reg.histogram("test.latency").count(), THREADS * PER);

    // Gauge handles alias the same cell too: a write through one handle
    // is visible through another.
    let g1 = reg.gauge("test.level");
    let g2 = reg.gauge("test.level");
    g1.set(2.5);
    assert_eq!(g2.get(), Some(2.5));
}

#[test]
fn span_ring_overflow_keeps_newest() {
    let tele = Telemetry::with_span_capacity(4);
    for i in 0..10u64 {
        let _g = attn_qat::span!(tele.spans(), "tick", idx = i);
    }
    let rec = tele.spans();
    assert_eq!(rec.recorded(), 10, "lifetime count survives eviction");
    let records = rec.records();
    assert_eq!(records.len(), 4, "ring retains exactly its capacity");
    let seqs: Vec<u64> = records.iter().map(|r| r.seq).collect();
    assert_eq!(seqs, vec![6, 7, 8, 9], "oldest spans evicted first");
    assert!(records.iter().all(|r| r.name == "tick"));
    assert_eq!(rec.dropped(), 6, "eviction count tracks the overflow");
    // The drop count is republished as a counter on every snapshot.
    let doc = tele.snapshot();
    assert_eq!(num(&doc, "metrics.telemetry.spans_dropped"), 6.0);
    assert_eq!(num(&doc, "spans.dropped"), 6.0);
}

#[test]
fn disabled_telemetry_allocates_nothing() {
    let tele = Telemetry::disabled();
    assert!(!tele.is_enabled());
    let rec = tele.spans();
    let ctr = tele.registry().counter("dark.counter");
    let gauge = tele.registry().gauge("dark.gauge");
    // One warm pass so any lazy stdlib state is paid before counting.
    {
        let _g = attn_qat::span!(rec, "warm");
        ctr.inc();
        gauge.set(1.0);
    }
    let before = thread_allocs();
    for i in 0..1_000u64 {
        let _g = attn_qat::span!(rec, "decode", shard = i);
        ctr.inc();
        gauge.set(i as f64);
    }
    let after = thread_allocs();
    assert_eq!(after - before, 0, "disabled spans / metric publishes must not allocate");
    assert_eq!(rec.recorded(), 0, "disabled guards record nothing");
}

#[test]
fn registry_agrees_with_cluster_stats_after_four_shard_drain() -> anyhow::Result<()> {
    let trace = demo_trace(16, 8, 7);
    let telemetry = Telemetry::new();
    let (_wall, stats, done, _doc) = serve_trace_observed(
        4,
        AttnConfig::fp4(),
        4,
        7,
        &trace,
        FaultPlan::none(),
        SupervisorConfig::default(),
        telemetry.clone(),
    )?;
    assert_eq!(done.len(), trace.len());
    assert_eq!(stats.shards.len(), 4);

    let reg = telemetry.registry();
    for s in &stats.shards {
        let name = |m: &str| format!("serve.shard{}.{m}", s.shard);
        assert_eq!(reg.counter(&name("requests")).get(), s.requests as u64);
        assert_eq!(reg.counter(&name("rejected")).get(), s.rejected as u64);
        assert_eq!(reg.counter(&name("steps")).get(), s.steps as u64);
        assert_eq!(reg.counter(&name("tokens")).get(), s.tokens as u64);
        // Gauges are republished from the exact drain-time ShardStats
        // values, so equality here is bitwise, not approximate.
        assert_eq!(reg.gauge(&name("tokens_per_s")).get(), Some(s.tokens_per_s));
        assert_eq!(reg.gauge(&name("p50_token_ms")).get(), Some(s.p50_token_ms));
        assert_eq!(reg.gauge(&name("p99_token_ms")).get(), Some(s.p99_token_ms));
        assert_eq!(reg.gauge(&name("ewma_token_ms")).get(), s.ewma_token_ms);
        assert_eq!(reg.gauge(&name("qcache_hits")).get(), Some(s.qcache_hits as f64));
        assert_eq!(reg.gauge(&name("qcache_misses")).get(), Some(s.qcache_misses as f64));
        assert_eq!(reg.gauge(&name("kv_bytes_peak")).get(), Some(s.kv_bytes_peak as f64));
        assert_eq!(
            reg.gauge(&name("kv_bytes_f32_equiv_peak")).get(),
            Some(s.kv_bytes_f32_equiv_peak as f64)
        );
    }
    assert_eq!(reg.counter("serve.cluster.submitted").get(), trace.len() as u64);
    assert_eq!(reg.counter("serve.cluster.shed_deadline").get(), stats.shed_deadline as u64);
    assert_eq!(reg.counter("serve.cluster.shed_capacity").get(), stats.shed_capacity as u64);
    assert_eq!(reg.counter("serve.supervisor.restarts").get(), stats.restarts as u64);
    assert_eq!(
        reg.counter("serve.supervisor.replayed_requests").get(),
        stats.replayed_requests as u64
    );
    assert_eq!(
        reg.counter("serve.supervisor.recomputed_passes").get(),
        stats.recomputed_passes as u64
    );
    Ok(())
}
