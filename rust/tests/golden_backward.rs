//! Backward golden tests: `qat::flash_backward` (and the training forward)
//! vs the JAX oracle (`rust/tests/golden/attention_bwd_golden.json`,
//! emitted by `python -m python.compile.gen_bwd_golden`).
//!
//! Each case stores inputs, the oracle's training-forward residuals
//! `(o, o_prime, lse)` and its gradients `(dq, dk, dv)` for one ablation
//! mode. The backward is fed the *stored* residuals, so parity is checked
//! independently of forward rounding; the forward is pinned separately.
//!
//! Tolerances scale with the tensor's own magnitude: the Python port of
//! this exact pipeline measured max diffs ≤ 1e-6 on unit-scale cases and
//! ≤ 9.5e-4 on the outlier case (grad magnitudes ~410), i.e. ≥ 400×
//! margin at `2e-3 · max(1, ‖·‖∞)`.

#![allow(deprecated)] // the forward shims are the pinned comparison path

use attn_qat::attention::engine::attend_fp4_train;
use attn_qat::attention::flash::attend_f32;
use attn_qat::attention::{AttnConfig, BwdSwitches};
use attn_qat::json::Json;
use attn_qat::qat::flash_backward;

fn load_golden() -> Json {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/golden/attention_bwd_golden.json"
    );
    let text = std::fs::read_to_string(path)
        .expect("backward golden vectors missing — run `python -m python.compile.gen_bwd_golden`");
    Json::parse(&text).expect("parse backward golden json")
}

/// Golden mode strings are exactly the `AttnConfig::parse` vocabulary —
/// use the canonical mapping so this test can't drift from it ("fp4" =
/// drop-in stock-FA backward; "f32" has no quantization anywhere, so the
/// same all-off switches apply and o == o_prime).
fn switches_for(mode: &str) -> BwdSwitches {
    AttnConfig::parse(mode)
        .unwrap_or_else(|e| panic!("unknown golden mode: {e}"))
        .bwd
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn max_abs(a: &[f32]) -> f32 {
    a.iter().map(|x| x.abs()).fold(0.0, f32::max)
}

fn check_case(case: &Json, name: &str) {
    let nq = case.get("nq").as_usize().unwrap();
    let nk = case.get("nk").as_usize().unwrap();
    let d = case.get("d").as_usize().unwrap();
    let causal = matches!(case.get("causal"), Json::Bool(true));
    let mode = case.get("mode").as_str().unwrap().to_string();
    let q = case.get("q").to_f32_vec().unwrap();
    let k = case.get("k").to_f32_vec().unwrap();
    let v = case.get("v").to_f32_vec().unwrap();
    let dout = case.get("do").to_f32_vec().unwrap();
    let want_o = case.get("o").to_f32_vec().unwrap();
    let want_op = case.get("o_prime").to_f32_vec().unwrap();
    let want_lse = case.get("lse").to_f32_vec().unwrap();
    let want_dq = case.get("dq").to_f32_vec().unwrap();
    let want_dk = case.get("dk").to_f32_vec().unwrap();
    let want_dv = case.get("dv").to_f32_vec().unwrap();

    // --- training forward parity (native engine vs naive_attention) ------
    let tol = |m: &[f32]| 2e-3 * max_abs(m).max(1.0);
    if mode == "f32" {
        let out = attend_f32(&q, &k, &v, nq, nk, d, causal);
        assert!(max_abs_diff(&out.o, &want_o) < tol(&want_o), "{name}: f32 o");
        assert!(max_abs_diff(&out.lse, &want_lse) < tol(&want_lse), "{name}: f32 lse");
    } else {
        let t = attend_fp4_train(&q, &k, &v, nq, nk, d, causal);
        let d_o = max_abs_diff(&t.o, &want_o);
        assert!(d_o < tol(&want_o), "{name}: o diff {d_o}");
        let d_op = max_abs_diff(&t.o_prime, &want_op);
        assert!(d_op < tol(&want_op), "{name}: o_prime diff {d_op}");
        let d_lse = max_abs_diff(&t.lse, &want_lse);
        assert!(d_lse < tol(&want_lse), "{name}: lse diff {d_lse}");
    }

    // --- backward parity on the oracle's residuals ------------------------
    let g = flash_backward(
        &q,
        &k,
        &v,
        nq,
        nk,
        d,
        causal,
        &want_o,
        &want_op,
        &want_lse,
        &dout,
        switches_for(&mode),
    );
    let d_dq = max_abs_diff(&g.dq, &want_dq);
    assert!(d_dq < tol(&want_dq), "{name}: dq diff {d_dq}");
    let d_dk = max_abs_diff(&g.dk, &want_dk);
    assert!(d_dk < tol(&want_dk), "{name}: dk diff {d_dk}");
    let d_dv = max_abs_diff(&g.dv, &want_dv);
    assert!(d_dv < tol(&want_dv), "{name}: dv diff {d_dv}");
}

#[test]
fn attn_qat_backward_matches_oracle() {
    let g = load_golden();
    for name in ["qat_full", "qat_causal", "qat_outliers", "qat_cross_causal"] {
        check_case(&g.get(name).clone(), name);
    }
}

#[test]
fn dropin_backward_matches_oracle() {
    let g = load_golden();
    for name in ["dropin_full", "dropin_causal"] {
        check_case(&g.get(name).clone(), name);
    }
}

#[test]
fn single_fix_ablations_match_oracle() {
    let g = load_golden();
    for name in ["qat_no_o_prime", "qat_no_fq_p"] {
        check_case(&g.get(name).clone(), name);
    }
}

#[test]
fn f32_backward_matches_oracle() {
    let g = load_golden();
    check_case(&g.get("f32_full").clone(), "f32_full");
}

#[test]
fn ablation_modes_actually_differ() {
    // Sanity on the golden file itself: the modes must not collapse to the
    // same gradients (i.e. the ablation switches are load-bearing).
    let g = load_golden();
    let qat_dq = g.get("qat_causal").get("dq").to_f32_vec().unwrap();
    let dropin_dq = g.get("dropin_causal").get("dq").to_f32_vec().unwrap();
    // Different modes also use different random inputs, so compare each
    // against its own recomputation with flipped switches instead.
    let case = g.get("qat_causal").clone();
    let nq = case.get("nq").as_usize().unwrap();
    let nk = case.get("nk").as_usize().unwrap();
    let d = case.get("d").as_usize().unwrap();
    let q = case.get("q").to_f32_vec().unwrap();
    let k = case.get("k").to_f32_vec().unwrap();
    let v = case.get("v").to_f32_vec().unwrap();
    let dout = case.get("do").to_f32_vec().unwrap();
    let o = case.get("o").to_f32_vec().unwrap();
    let op = case.get("o_prime").to_f32_vec().unwrap();
    let lse = case.get("lse").to_f32_vec().unwrap();
    let flipped = flash_backward(
        &q,
        &k,
        &v,
        nq,
        nk,
        d,
        true,
        &o,
        &op,
        &lse,
        &dout,
        switches_for("fp4"),
    );
    let diff = max_abs_diff(&flipped.dq, &qat_dq);
    assert!(diff > 1e-5, "drop-in switches must change the gradients: {diff}");
    assert!(!qat_dq.is_empty() && !dropin_dq.is_empty());
}
