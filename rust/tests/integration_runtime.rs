//! End-to-end integration over compiled artifacts (needs `make artifacts`).
//!
//! Exercises the whole three-layer composition on the tiny models: init →
//! train (both jnp and Pallas train steps) → eval → serve, plus the
//! fake-vs-real quant agreement that Figure 4 scales up.

use std::path::Path;

use attn_qat::coordinator::{LrSchedule, Trainer};
use attn_qat::data::corpus::Corpus;
use attn_qat::data::latents::LatentGen;
use attn_qat::rng::Rng;
use attn_qat::runtime::{Runtime, Value};
use attn_qat::serve::{DecodeServer, Request};
use attn_qat::tensor::Tensor;

/// Build the runtime, or `None` when the PJRT backend / artifacts are
/// unavailable (offline CI uses the stub `xla` crate and ships no compiled
/// HLO). Each test skips itself in that case rather than failing: these are
/// integration tests of the compiled-artifact path, not of the native code.
fn runtime() -> Option<Runtime> {
    match Runtime::new(Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping artifact integration test: {e}");
            None
        }
    }
}

macro_rules! require_runtime {
    () => {
        match runtime() {
            Some(rt) => rt,
            None => return,
        }
    };
}

#[test]
fn registry_has_core_artifacts() {
    let rt = require_runtime!();
    for name in [
        "lm_init_tiny",
        "lm_train_f32_tiny",
        "lm_train_qat_tiny",
        "lm_train_qat_pallas_tiny",
        "lm_eval_fp4_tiny",
        "diff_train_qat_tiny",
        "quant_fake_1024x64",
        "attn_fp4_pallas_s256_d64",
    ] {
        assert!(rt.meta(name).is_ok(), "missing artifact {name}");
    }
}

#[test]
fn init_is_deterministic_per_seed() {
    let rt = require_runtime!();
    let a = rt.run("lm_init_tiny", &[Value::scalar_i32(7)]).unwrap();
    let b = rt.run("lm_init_tiny", &[Value::scalar_i32(7)]).unwrap();
    let c = rt.run("lm_init_tiny", &[Value::scalar_i32(8)]).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.data, y.data);
    }
    assert!(a.iter().zip(&c).any(|(x, y)| x.data != y.data));
}

#[test]
fn input_validation_catches_shape_and_arity() {
    let rt = require_runtime!();
    // wrong arity
    assert!(rt.run("lm_init_tiny", &[]).is_err());
    // wrong dtype
    assert!(rt.run("lm_init_tiny", &[Value::scalar_f32(1.0)]).is_err());
    // unknown artifact
    assert!(rt.run("nope", &[Value::scalar_i32(0)]).is_err());
}

#[test]
fn lm_qat_training_learns_fixed_batch() {
    let rt = require_runtime!();
    let mut trainer = Trainer::new(
        &rt,
        "lm_init_tiny",
        "lm_train_qat_tiny",
        3,
        LrSchedule::Constant(3e-3),
    )
    .unwrap();
    let mut corpus = Corpus::new(11);
    let meta = rt.meta("lm_train_qat_tiny").unwrap();
    let batch = meta.usize_field("batch").unwrap();
    let seq = meta.raw.get("model").get("seq_len").as_usize().unwrap();
    let b = corpus.next_batch(batch, seq);
    let batch_vals = vec![b.token_value(), b.mask_value()];
    let mut first = None;
    for _ in 0..10 {
        let m = trainer.step(&batch_vals).unwrap();
        first.get_or_insert(m.loss);
        assert!(m.loss.is_finite() && m.grad_norm.is_finite());
    }
    let last = trainer.history.last().unwrap().loss;
    assert!(
        last < first.unwrap() - 0.3,
        "no learning: {} -> {}",
        first.unwrap(),
        last
    );
    assert!(!trainer.diverged());
}

#[test]
fn pallas_train_step_composes() {
    // The L1-kernel-backed train step must run and produce finite grads —
    // the full three-layer composition proof.
    let rt = require_runtime!();
    let mut trainer = Trainer::new(
        &rt,
        "lm_init_tiny",
        "lm_train_qat_pallas_tiny",
        3,
        LrSchedule::Constant(1e-3),
    )
    .unwrap();
    let mut corpus = Corpus::new(5);
    let meta = rt.meta("lm_train_qat_pallas_tiny").unwrap();
    let batch = meta.usize_field("batch").unwrap();
    let seq = meta.raw.get("model").get("seq_len").as_usize().unwrap();
    let b = corpus.next_batch(batch, seq);
    let m = trainer.step(&[b.token_value(), b.mask_value()]).unwrap();
    assert!(m.loss.is_finite() && m.grad_norm.is_finite());
}

#[test]
fn pallas_and_jnp_train_steps_agree() {
    // Same params, same batch: the tiled (Pallas) and fused (jnp) QAT
    // implementations must produce near-identical loss and gradients
    // (they differ only in online-softmax tiling).
    let rt = require_runtime!();
    let params = rt.run("lm_init_tiny", &[Value::scalar_i32(9)]).unwrap();
    let meta = rt.meta("lm_train_qat_tiny").unwrap();
    let batch = meta.usize_field("batch").unwrap();
    let seq = meta.raw.get("model").get("seq_len").as_usize().unwrap();
    let mut corpus = Corpus::new(13);
    let b = corpus.next_batch(batch, seq);
    let run = |artifact: &str| -> (f32, f32) {
        let mut trainer = Trainer::new(
            &rt,
            "lm_init_tiny",
            artifact,
            9,
            LrSchedule::Constant(1e-3),
        )
        .unwrap()
        .with_params(params.clone())
        .unwrap();
        let m = trainer.step(&[b.token_value(), b.mask_value()]).unwrap();
        (m.loss, m.grad_norm)
    };
    let (l_jnp, g_jnp) = run("lm_train_qat_tiny");
    let (l_pal, g_pal) = run("lm_train_qat_pallas_tiny");
    assert!((l_jnp - l_pal).abs() < 2e-2, "loss {l_jnp} vs {l_pal}");
    assert!((g_jnp - g_pal).abs() / g_jnp.max(1e-6) < 0.1, "gnorm {g_jnp} vs {g_pal}");
}

#[test]
fn diffusion_train_and_sample() {
    let rt = require_runtime!();
    let mut trainer = Trainer::new(
        &rt,
        "diff_init_tiny",
        "diff_train_qat_tiny",
        1,
        LrSchedule::Constant(3e-3),
    )
    .unwrap();
    let meta = rt.meta("diff_train_qat_tiny").unwrap();
    let batch = meta.usize_field("batch").unwrap();
    let model = meta.raw.get("model").clone();
    let frames = model.get("frames").as_usize().unwrap();
    let dl = model.get("latent_dim").as_usize().unwrap();
    let mut gen = LatentGen::new(3, frames, dl);
    for _ in 0..5 {
        let b = gen.next_batch(batch);
        let m = trainer.step(&b.values()).unwrap();
        assert!(m.loss.is_finite());
    }
    // one sampler step
    let mut inputs: Vec<Value> = trainer.state.params.iter().cloned().map(Value::F32).collect();
    inputs.push(Value::F32(
        Tensor::new(vec![batch, frames, dl], gen.noise_batch(batch)).unwrap(),
    ));
    inputs.push(Value::F32(Tensor::new(vec![batch], vec![1.0; batch]).unwrap()));
    inputs.push(Value::F32(Tensor::new(vec![batch], vec![0.25; batch]).unwrap()));
    let x = rt.run("diff_sample_fp4_tiny", &inputs).unwrap();
    assert!(x[0].data.iter().all(|v| v.is_finite()));
}

#[test]
fn eval_artifact_counts_tokens() {
    let rt = require_runtime!();
    let params = rt.run("lm_init_tiny", &[Value::scalar_i32(1)]).unwrap();
    let meta = rt.meta("lm_eval_f32_tiny").unwrap();
    let batch = meta.usize_field("batch").unwrap();
    let seq = meta.raw.get("model").get("seq_len").as_usize().unwrap();
    let mut corpus = Corpus::new(2);
    let b = corpus.next_batch(batch, seq);
    let mut inputs: Vec<Value> = params.into_iter().map(Value::F32).collect();
    inputs.push(b.token_value());
    inputs.push(b.mask_value());
    let out = rt.run("lm_eval_f32_tiny", &inputs).unwrap();
    assert_eq!(out[1].data, vec![seq as f32; batch]);
    // fresh init ≈ uniform: per-token nll ≈ ln 256
    let nll_tok = out[0].data.iter().sum::<f32>() / (batch * seq) as f32;
    assert!((nll_tok - 256f32.ln()).abs() < 0.6, "nll/tok {nll_tok}");
}

#[test]
fn fake_quant_hlo_matches_formats_lib_bitexact() {
    let rt = require_runtime!();
    let mut rng = Rng::new(99);
    let x: Vec<f32> = rng.normal_vec(1024 * 64, 0.0, 2.0);
    let t = Tensor::new(vec![1024, 64], x.clone()).unwrap();
    for artifact in ["quant_fake_1024x64", "quant_fake_pallas_1024x64"] {
        let out = rt.run(artifact, &[Value::F32(t.clone())]).unwrap();
        let mut expect = x.clone();
        for row in expect.chunks_mut(64) {
            attn_qat::formats::block::nvfp4_fake_quant_row(row);
        }
        assert_eq!(out[0].data, expect, "{artifact}");
    }
}

#[test]
fn serve_decodes_with_fp4_kv() {
    let rt = require_runtime!();
    let meta = rt.meta("lm_init_tiny").unwrap();
    let names = meta.param_names();
    let params = rt.run("lm_init_tiny", &[Value::scalar_i32(4)]).unwrap();
    let weights: Vec<(String, Tensor)> = names.into_iter().zip(params).collect();
    let mut server = DecodeServer::new(&rt, "tiny", weights).unwrap();
    for i in 0..6 {
        server.submit(Request {
            id: i + 1,
            prompt: b"C:abc#".to_vec(),
            max_new_tokens: 5,
            temperature: 0.0,
            deadline_ms: None,
            trace: Default::default(),
        });
    }
    let done = server.run().unwrap();
    assert_eq!(done.len(), 6);
    for c in &done {
        assert!(c.new_tokens >= 1 && c.new_tokens <= 5);
        assert!(c.text.len() >= c.prompt_tokens);
    }
    // 6 requests with batch 4 => at least two waves ran; KV compressed.
    let stats = server.stats;
    assert!(stats.tokens_decoded >= 6 * 6);
    assert!(stats.kv_bytes > 0);
}

#[test]
fn serve_fused_decode_matches_baseline_completions() {
    // A/B smoke test for the packed-decode rewire: the same greedy
    // requests through the fused `attend_decode` path and the legacy
    // `gather` + `attend_f32` baseline must produce identical completions.
    //
    // Sequences are kept under PAGE_SIZE (6 prompt + 8 new = 14 tokens),
    // so every page stays hot and the fused path's f32 fallback performs
    // bit-identical arithmetic to the baseline — exact equality is
    // guaranteed by construction, and any mismatch is a real plumbing bug
    // in the rewire (wrong slot/head offsets, stale scratch, ...). The
    // sealed-page (quantized) numerics are covered with tolerances by
    // `kvcache::tests::attend_decode_matches_gather_attend_f32`.
    let rt = require_runtime!();
    let meta = rt.meta("lm_init_tiny").unwrap();
    let names = meta.param_names();
    let params = rt.run("lm_init_tiny", &[Value::scalar_i32(4)]).unwrap();
    let weights: Vec<(String, Tensor)> = names.into_iter().zip(params).collect();
    let run = |cfg: attn_qat::attention::AttnConfig| -> Vec<(u64, Vec<u8>)> {
        let mut server = DecodeServer::new(&rt, "tiny", weights.clone()).unwrap();
        server.set_attention(cfg);
        for i in 0..4 {
            server.submit(Request {
                id: i + 1,
                prompt: b"C:abc#".to_vec(),
                max_new_tokens: 8,
                temperature: 0.0,
                deadline_ms: None,
                trace: Default::default(),
            });
        }
        let mut done: Vec<(u64, Vec<u8>)> = server
            .run()
            .unwrap()
            .into_iter()
            .map(|c| (c.id, c.text))
            .collect();
        done.sort();
        done
    };
    let fused = run(attn_qat::attention::AttnConfig::fp4());
    let baseline = run(attn_qat::attention::AttnConfig::f32());
    assert_eq!(fused, baseline, "fused decode changed greedy completions");
}
