//! Fault-tolerance integration tests: the supervised cluster's three
//! contracts under injected faults, end to end.
//!
//! 1. **Zero lost requests** — every submitted request either completes
//!    or is shed *at admission* with an explicit verdict; faults mid-
//!    decode never silently drop work.
//! 2. **Deterministic replay** — a respawned shard recomputes its
//!    journal from scratch, and placement invariance makes the rerun
//!    bitwise identical to a fault-free run of the same trace.
//! 3. **Bounded supervision** — stalls are detected by heartbeat age
//!    (not by waiting the stall out), and a shard that keeps dying
//!    exhausts its restart budget and surfaces an error instead of
//!    looping forever.
//!
//! Plus the training-side analogue: the divergence watchdog recovers the
//! paper's Fig-3 drop-in instability while leaving Attn-QAT untouched.

use std::time::{Duration, Instant};

use attn_qat::attention::AttnConfig;
use attn_qat::experiments::cluster::{demo_trace, serve_trace_faulty};
use attn_qat::model::{AttnRegressor, WatchdogConfig};
use attn_qat::qat::{QatVariant, TrainerConfig};
use attn_qat::serve::{
    Admission, ClusterConfig, ClusterStats, Completion, DecodeCluster, FaultPlan, Request,
    ShardConfig, SimLm, SimLmConfig, SupervisorConfig,
};

const SEED: u64 = 0xfa17;

fn run(
    plan: FaultPlan,
    sup: SupervisorConfig,
    trace: &[Request],
) -> (ClusterStats, Vec<Completion>) {
    let (_, stats, done) =
        serve_trace_faulty(4, AttnConfig::fp4(), 3, SEED, trace, plan, sup).expect("serve");
    (stats, done)
}

fn assert_bitwise(label: &str, clean: &[Completion], faulty: &[Completion]) {
    assert_eq!(clean.len(), faulty.len(), "{label}: completion counts");
    for (a, b) in clean.iter().zip(faulty) {
        assert_eq!(a.id, b.id, "{label}: ids");
        assert_eq!(a.text, b.text, "{label}: req {} tokens", a.id);
        assert_eq!(a.new_tokens, b.new_tokens, "{label}: req {}", a.id);
    }
}

/// The busiest shard of the clean run — guaranteed to execute enough
/// forward passes for a mid-stream fault to actually fire.
fn busiest_shard(stats: &ClusterStats) -> usize {
    stats.shards.iter().max_by_key(|s| s.tokens).expect("shards").shard
}

#[test]
fn mid_decode_panic_replays_bitwise_with_zero_lost_requests() {
    let trace = demo_trace(20, 12, SEED);
    let sup = SupervisorConfig::default();
    let (clean_stats, clean) = run(FaultPlan::none(), sup, &trace);
    assert_eq!(clean.len(), trace.len());
    assert_eq!(clean_stats.restarts, 0, "clean run must not restart");

    let plan = FaultPlan::panic_at(busiest_shard(&clean_stats), 6);
    let (stats, faulty) = run(plan.clone(), sup, &trace);
    assert_eq!(plan.trips(), 1, "one-shot fault must fire exactly once");
    assert!(stats.restarts >= 1, "the killed shard must be respawned");
    assert!(stats.replayed_requests >= 1, "its journal must be replayed");
    assert_eq!(faulty.len(), trace.len(), "zero lost requests");
    assert_bitwise("panic vs clean", &clean, &faulty);
}

#[test]
fn stalled_shard_is_abandoned_by_heartbeat_not_waited_out() {
    let trace = demo_trace(12, 8, SEED ^ 1);
    let sup = SupervisorConfig { stall_timeout_ms: 200.0, ..SupervisorConfig::default() };
    let (clean_stats, clean) = run(FaultPlan::none(), sup, &trace);

    // The injected stall sleeps 8 s mid-pass; heartbeat detection at
    // 200 ms must abandon + respawn the shard long before that sleep
    // ends, so the whole faulty run finishes in a fraction of it.
    let plan = FaultPlan::stall_at(busiest_shard(&clean_stats), 4, 8_000);
    let t0 = Instant::now();
    let (stats, faulty) = run(plan.clone(), sup, &trace);
    let wall = t0.elapsed();
    assert_eq!(plan.trips(), 1);
    assert!(stats.restarts >= 1, "the stalled shard must be abandoned and respawned");
    assert!(
        wall < Duration::from_secs(5),
        "supervision must not wait out the 8 s stall (took {wall:?})"
    );
    assert_bitwise("stall vs clean", &clean, &faulty);
}

#[test]
fn deadline_shedding_rejects_only_infeasible_requests() {
    let req = |id: u64, deadline_ms: Option<f64>| Request {
        id,
        prompt: b"shed me?#".to_vec(),
        max_new_tokens: 6,
        temperature: 0.0,
        deadline_ms,
        trace: Default::default(),
    };
    let cfg = ClusterConfig {
        shards: 1,
        queue_depth: 16,
        shard: ShardConfig {
            slots: 2,
            attn: AttnConfig::fp4(),
            seq_max: 128,
            sample_seed: SEED,
            ..ShardConfig::default()
        },
        ..ClusterConfig::default()
    };
    let lm = SimLmConfig::default();
    let mut cluster = DecodeCluster::spawn(cfg, move |_| Box::new(SimLm::new(lm)));

    // Deadline-less requests are never shed — they warm the latency
    // estimator instead (the cold estimator admits everything).
    for id in 1..=4 {
        assert_eq!(cluster.submit(req(id, None)).unwrap(), Admission::Accepted);
    }
    let mut warmed = false;
    for _ in 0..5_000 {
        if cluster.token_latency_ewma(0).is_some() {
            warmed = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(warmed, "serving work must warm the EWMA estimator");

    // An impossible deadline is shed at admission; a generous one is not.
    assert_eq!(cluster.submit(req(100, Some(1e-9))).unwrap(), Admission::ShedDeadline);
    assert_eq!(cluster.submit(req(101, Some(1e9))).unwrap(), Admission::Accepted);

    let (done, stats) = cluster.drain().expect("drain");
    let ids: Vec<u64> = done.iter().map(|c| c.id).collect();
    assert_eq!(ids, vec![1, 2, 3, 4, 101], "shed request must yield no completion");
    assert_eq!(stats.shed_deadline, 1);
    assert_eq!(stats.shed_capacity, 0);
    assert_eq!(stats.total_shed(), 1);
}

#[test]
fn repeated_panics_exhaust_the_restart_budget_and_surface_an_error() {
    let plan = FaultPlan::panic_every(0, 1); // every pass dies, forever
    let sup = SupervisorConfig { max_restarts: 2, ..SupervisorConfig::default() };
    let cfg = ClusterConfig {
        shards: 1,
        queue_depth: 4,
        shard: ShardConfig {
            slots: 2,
            attn: AttnConfig::fp4(),
            seq_max: 128,
            sample_seed: SEED,
            ..ShardConfig::default()
        },
        supervisor: sup,
    };
    let lm = SimLmConfig::default();
    let wrapped = plan.clone();
    let mut cluster =
        DecodeCluster::spawn(cfg, move |shard| wrapped.wrap(shard, Box::new(SimLm::new(lm))));
    let req = Request {
        id: 1,
        prompt: b"doomed#".to_vec(),
        max_new_tokens: 4,
        temperature: 0.0,
        deadline_ms: None,
        trace: Default::default(),
    };
    // Depending on timing the budget can exhaust during submit (the
    // retry loop re-checks the shard) or during drain — either way the
    // give-up must surface as an error, never as a hang or lost work.
    let err = match cluster.submit(req) {
        Err(e) => e,
        Ok(_) => cluster.drain().expect_err("a permanently dying shard cannot drain"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("gave up"), "error should name the exhausted budget: {msg}");
    assert!(plan.trips() >= 2, "each respawn re-hits the periodic fault ({})", plan.trips());
}

#[test]
fn watchdog_recovers_fig3_drop_in_and_never_touches_attn_qat() {
    // The training-side robustness contract, on the paper's Fig-3 task:
    // the same watchdog that rescues the drop-in QAT divergence must be
    // a no-op for Attn-QAT (whose grad norms stay far under the limit).
    let steps = 150;
    let wd =
        WatchdogConfig { grad_limit: 100.0, max_rollbacks: steps, ..WatchdogConfig::default() };

    let mut qat = AttnRegressor::session(TrainerConfig::default(), QatVariant::AttnQat.config());
    qat.cfg.watchdog = Some(wd);
    qat.run(steps, 0, |_| {});
    assert_eq!(qat.rollbacks(), 0, "Attn-QAT must never trip the watchdog");
    assert!(!qat.diverged());

    let mut dropin = AttnRegressor::session(TrainerConfig::default(), QatVariant::DropIn.config());
    dropin.cfg.watchdog = Some(wd);
    dropin.run(steps, 0, |_| {});
    assert!(dropin.rollbacks() >= 1, "drop-in QAT must trip the watchdog");
    assert!(dropin.lr_scale() < 1.0, "rollbacks must back the lr off");
    // Recovery, not just bookkeeping: every step the watchdog let
    // through stayed finite and inside the guard rail — the instability
    // lives only in the rolled-back (never-applied) spikes.
    for m in dropin.history.iter().filter(|m| !m.rollback) {
        assert!(m.loss.is_finite(), "applied step {} lost finiteness", m.step);
        assert!(m.grad_norm <= 100.0, "applied step {} grad {}", m.step, m.grad_norm);
    }
    assert_eq!(
        dropin.history.len(),
        steps,
        "rollbacks consume the step budget without aborting the run"
    );
}
