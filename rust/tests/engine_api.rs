//! `AttnEngine` dispatch tests: the batched multi-head session must be
//! bitwise identical to independent single-head calls through the
//! deprecated free-function shims, and the engine must reproduce the JAX
//! golden vectors through the same configs.
//!
//! (The shims themselves delegate to the same cores, so these tests pin
//! the whole migration: config → engine → core → shim all agree.)

#![allow(deprecated)] // the deprecated shims are the comparison subjects

use attn_qat::attention::engine::{attend_fp4, attend_fp4_dequant, attend_fp4_train, attend_sage3};
use attn_qat::attention::flash::attend_f32;
use attn_qat::attention::{AttnConfig, AttnEngine, AttnOutput, Backend};
use attn_qat::json::Json;
use attn_qat::kvcache::{DecodeScratch, PagedKvCache};
use attn_qat::rng::Rng;

fn rand_heads(
    h: usize,
    nq: usize,
    nk: usize,
    d: usize,
    seed: u64,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(h * nq * d, 0.0, 1.0),
        rng.normal_vec(h * nk * d, 0.0, 1.0),
        rng.normal_vec(h * nk * d, 0.0, 1.0),
    )
}

type ShimFn = fn(&[f32], &[f32], &[f32], usize, usize, usize, bool) -> AttnOutput;

#[test]
fn multi_head_forward_bitwise_matches_single_head_shims() {
    // h batched heads == h independent single-head calls, bit for bit,
    // across precisions, causal/non-causal, and nq != nk (both ways).
    let shims: [(&str, ShimFn); 3] =
        [("f32", attend_f32), ("fp4", attend_fp4), ("sage3", attend_sage3)];
    let h = 3usize;
    for (variant, shim) in shims {
        for &(nq, nk, d, seed) in
            &[(16usize, 16usize, 32usize, 80u64), (8, 19, 64, 81), (9, 5, 16, 82)]
        {
            for causal in [false, true] {
                let (q, k, v) = rand_heads(h, nq, nk, d, seed);
                let cfg = AttnConfig::parse(variant).unwrap().with_causal(causal);
                let mut engine = AttnEngine::new(cfg);
                let got = engine.forward(&q, &k, &v, h, nq, nk, d);
                for head in 0..h {
                    let want = shim(
                        &q[head * nq * d..(head + 1) * nq * d],
                        &k[head * nk * d..(head + 1) * nk * d],
                        &v[head * nk * d..(head + 1) * nk * d],
                        nq,
                        nk,
                        d,
                        causal,
                    );
                    assert_eq!(
                        got.head_o(head),
                        &want.o[..],
                        "{variant} head {head} nq={nq} nk={nk} causal={causal}"
                    );
                    assert_eq!(
                        got.head_lse(head),
                        &want.lse[..],
                        "{variant} head {head} lse nq={nq} nk={nk} causal={causal}"
                    );
                }
            }
        }
    }
}

#[test]
fn multi_head_train_forward_bitwise_matches_shim() {
    let (h, nq, nk, d) = (4usize, 8usize, 19usize, 32usize);
    for causal in [false, true] {
        let (q, k, v) = rand_heads(h, nq, nk, d, 83);
        let mut engine = AttnEngine::new(AttnConfig::attn_qat().with_causal(causal));
        let got = engine.forward_train(&q, &k, &v, h, nq, nk, d);
        for head in 0..h {
            let want = attend_fp4_train(
                &q[head * nq * d..(head + 1) * nq * d],
                &k[head * nk * d..(head + 1) * nk * d],
                &v[head * nk * d..(head + 1) * nk * d],
                nq,
                nk,
                d,
                causal,
            );
            let (lo, hi) = (head * nq * d, (head + 1) * nq * d);
            assert_eq!(&got.o[lo..hi], &want.o[..], "head {head} causal={causal}");
            assert_eq!(&got.o_prime[lo..hi], &want.o_prime[..], "head {head} o'");
            assert_eq!(&got.lse[head * nq..(head + 1) * nq], &want.lse[..], "head {head} lse");
        }
    }
}

#[test]
fn dequant_backend_matches_dequant_shim() {
    let (h, n, d) = (2usize, 12usize, 32usize);
    let (q, k, v) = rand_heads(h, n, n, d, 84);
    let mut engine = AttnEngine::new(AttnConfig::fp4().with_backend(Backend::Dequant));
    let got = engine.forward(&q, &k, &v, h, n, n, d);
    for head in 0..h {
        let want = attend_fp4_dequant(
            &q[head * n * d..(head + 1) * n * d],
            &k[head * n * d..(head + 1) * n * d],
            &v[head * n * d..(head + 1) * n * d],
            n,
            n,
            d,
            false,
        );
        assert_eq!(got.head_o(head), &want.o[..], "head {head}");
    }
}

#[test]
fn engine_scratch_reuse_is_deterministic() {
    // Re-running the same session (warm workspaces, warm query cache)
    // must reproduce the first answer bit for bit.
    let (h, n, d) = (2usize, 16usize, 32usize);
    let (q, k, v) = rand_heads(h, n, n, d, 85);
    let mut engine = AttnEngine::new(AttnConfig::sage3());
    let a = engine.forward(&q, &k, &v, h, n, n, d);
    let b = engine.forward(&q, &k, &v, h, n, n, d);
    assert_eq!(a.o, b.o);
    assert_eq!(a.lse, b.lse);
}

fn load_golden() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/attention_golden.json");
    let text =
        std::fs::read_to_string(path).expect("golden vectors missing — run `make artifacts` first");
    Json::parse(&text).expect("parse golden json")
}

#[test]
fn engine_matches_shims_and_goldens() {
    // For every golden case: the engine with the parsed config must be
    // bitwise identical to the deprecated shim, and both inside the JAX
    // oracle tolerance — the migration cannot move the pinned numerics.
    let g = load_golden();
    let cases: [(&str, &str, bool, ShimFn, f32); 5] = [
        ("f32_full", "f32", false, attend_f32, 1e-5),
        ("f32_causal", "f32", true, attend_f32, 1e-5),
        ("fp4_full", "fp4", false, attend_fp4, 5e-5),
        ("fp4_causal", "fp4", true, attend_fp4, 5e-5),
        ("sage3_full", "sage3", false, attend_sage3, 5e-5),
    ];
    for (case_name, variant, causal, shim, tol) in cases {
        let case = g.get(case_name).clone();
        let n = case.get("n").as_usize().unwrap();
        let d = case.get("d").as_usize().unwrap();
        let q = case.get("q").to_f32_vec().unwrap();
        let k = case.get("k").to_f32_vec().unwrap();
        let v = case.get("v").to_f32_vec().unwrap();
        let want_o = case.get("o").to_f32_vec().unwrap();

        let mut engine = AttnEngine::new(AttnConfig::parse(variant).unwrap().with_causal(causal));
        let got = engine.forward(&q, &k, &v, 1, n, n, d);
        let legacy = shim(&q, &k, &v, n, n, d, causal);
        assert_eq!(got.o, legacy.o, "{case_name}: engine vs shim o");
        assert_eq!(got.lse, legacy.lse, "{case_name}: engine vs shim lse");

        let max_o = got
            .o
            .iter()
            .zip(&want_o)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_o < tol, "{case_name}: golden diff {max_o}");
    }
}

#[test]
fn engine_decode_covers_both_serving_paths() {
    // One engine.decode call per layer row == per-head attend_decode /
    // gather+f32, for the fused and baseline configs respectively.
    let (heads, d, tokens) = (2usize, 32usize, 37usize);
    let mut cache = PagedKvCache::new(1, heads, d);
    cache.add_seq(7);
    let mut rng = Rng::new(86);
    for _ in 0..tokens {
        for h in 0..heads {
            let k = rng.normal_vec(d, 0.0, 1.0);
            let v = rng.normal_vec(d, 0.0, 1.0);
            cache.append(7, 0, h, &k, &v).unwrap();
        }
    }
    let q = rng.normal_vec(heads * d, 0.0, 1.0);

    // Fused path vs raw attend_decode.
    let mut fused = AttnEngine::new(AttnConfig::fp4());
    let mut out = vec![0.0f32; heads * d];
    fused.decode(&cache, 7, 0, &q, &mut out).unwrap();
    for h in 0..heads {
        let mut want = vec![0.0f32; d];
        let mut scratch = DecodeScratch::new();
        cache.attend_decode(7, 0, h, &q[h * d..(h + 1) * d], &mut want, &mut scratch).unwrap();
        assert_eq!(&out[h * d..(h + 1) * d], &want[..], "fused head {h}");
    }

    // Baseline config vs gather + f32.
    let mut baseline = AttnEngine::new(AttnConfig::f32());
    let mut out_b = vec![0.0f32; heads * d];
    baseline.decode(&cache, 7, 0, &q, &mut out_b).unwrap();
    for h in 0..heads {
        let (kc, vc) = cache.gather(7, 0, h).unwrap();
        let want = attend_f32(&q[h * d..(h + 1) * d], &kc, &vc, 1, tokens, d, false);
        assert_eq!(&out_b[h * d..(h + 1) * d], &want.o[..], "baseline head {h}");
    }
}

#[test]
fn engine_prefill_multi_head_matches_per_head_reference() {
    // Multi-head prefill vs the f32 causal reference per head (tolerance),
    // and the f32-config prefill vs the same reference bitwise.
    let (heads, d, tokens, nq) = (2usize, 32usize, 40usize, 8usize);
    let mut cache = PagedKvCache::new(1, heads, d);
    cache.add_seq(3);
    let mut rng = Rng::new(87);
    for _ in 0..tokens {
        for h in 0..heads {
            let k = rng.normal_vec(d, 0.0, 1.0);
            let v = rng.normal_vec(d, 0.0, 1.0);
            cache.append(3, 0, h, &k, &v).unwrap();
        }
    }
    let q = rng.normal_vec(heads * nq * d, 0.0, 1.0);

    let mut fused = AttnEngine::new(AttnConfig::fp4());
    let mut out = vec![0.0f32; heads * nq * d];
    let lse = fused.prefill(&cache, 3, 0, &q, nq, &mut out).unwrap();
    assert_eq!(lse.len(), heads * nq);

    let mut baseline = AttnEngine::new(AttnConfig::f32());
    let mut out_b = vec![0.0f32; heads * nq * d];
    let lse_b = baseline.prefill(&cache, 3, 0, &q, nq, &mut out_b).unwrap();

    for h in 0..heads {
        let (kc, vc) = cache.gather(3, 0, h).unwrap();
        let qh = &q[h * nq * d..(h + 1) * nq * d];
        let want = attend_f32(qh, &kc, &vc, nq, tokens, d, true);
        // f32 config: bitwise identical to the causal flash reference.
        assert_eq!(&out_b[h * nq * d..(h + 1) * nq * d], &want.o[..], "f32 head {h}");
        assert_eq!(&lse_b[h * nq..(h + 1) * nq], &want.lse[..], "f32 head {h} lse");
        // fused config: FP4 tolerance against the same reference.
        let max_diff = out[h * nq * d..(h + 1) * nq * d]
            .iter()
            .zip(&want.o)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 0.5, "fused head {h}: {max_diff}");
    }
}
