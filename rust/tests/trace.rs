//! Causal-tracing integration tests (PR 9): the span ring's
//! `trace_id`/`span_id`/`parent_id` triples must reconstruct each
//! request's lifecycle as a tree **across threads**, survive faults, and
//! export losslessly.
//!
//! Four contracts:
//!
//! 1. **Rooted lifecycles** — every per-request span a shard worker
//!    records (queue wait, admit, prefill, sampled `decode.token`,
//!    finish) resolves its parent chain back to the `request` root that
//!    `DecodeCluster::submit` opened on the client thread.
//! 2. **Replay provenance** — after an injected mid-decode panic, the
//!    respawned shard's `replay` spans re-anchor under the original
//!    request roots and carry the shard incarnation as their tag.
//! 3. **Lossless export** — [`chrome_trace`] emits valid JSON that
//!    round-trips through the crate's own parser with the causal triple
//!    intact (the `--trace-out` file format).
//! 4. **SLO accounting** — deadline-carrying requests settle into the
//!    `serve.slo.*` counters/histograms at drain and surface in
//!    [`Telemetry::snapshot`].

use std::collections::BTreeMap;

use attn_qat::attention::AttnConfig;
use attn_qat::experiments::cluster::{demo_trace, serve_trace_observed};
use attn_qat::json::Json;
use attn_qat::serve::{
    Admission, ClusterConfig, ClusterStats, DecodeCluster, FaultPlan, Request, ShardConfig, SimLm,
    SimLmConfig, SupervisorConfig,
};
use attn_qat::telemetry::{chrome_trace, SpanRecord, Telemetry};

const SEED: u64 = 0x7ace;

/// Serve `trace` on a supervised cluster, returning the drain stats and
/// the full annotated span ring (capacity far above what the run emits,
/// so nothing is evicted and every parent chain stays resolvable).
fn traced_run(
    shards: usize,
    plan: FaultPlan,
    trace: &[Request],
) -> (ClusterStats, Vec<SpanRecord>) {
    let telemetry = Telemetry::with_span_capacity(8192);
    let (_wall, stats, done, _doc) = serve_trace_observed(
        shards,
        AttnConfig::fp4(),
        3,
        SEED,
        trace,
        plan,
        SupervisorConfig::default(),
        telemetry.clone(),
    )
    .expect("serve");
    assert_eq!(done.len(), trace.len(), "zero lost requests");
    (stats, telemetry.spans().records())
}

fn by_id(records: &[SpanRecord]) -> BTreeMap<u64, &SpanRecord> {
    records.iter().map(|r| (r.span_id, r)).collect()
}

/// Walk `span`'s parent chain to its root record (panics on a broken
/// link — an evicted or never-recorded parent).
fn root_of<'a>(ids: &BTreeMap<u64, &'a SpanRecord>, span: &'a SpanRecord) -> &'a SpanRecord {
    let mut cur = span;
    for _ in 0..64 {
        if cur.parent_id == 0 {
            return cur;
        }
        cur = ids
            .get(&cur.parent_id)
            .copied()
            .unwrap_or_else(|| panic!("span {:?} has unresolvable parent {}", span, cur.parent_id));
    }
    panic!("parent chain of {span:?} exceeds 64 hops");
}

#[test]
fn request_lifecycle_spans_resolve_to_their_request_root() {
    let trace = demo_trace(12, 8, SEED);
    let (stats, records) = traced_run(3, FaultPlan::none(), &trace);
    assert_eq!(stats.restarts, 0);
    let ids = by_id(&records);

    // Exactly one root per submitted request, tagged with its id.
    let roots: Vec<&SpanRecord> = records.iter().filter(|r| r.name == "request").collect();
    assert_eq!(roots.len(), trace.len());
    let mut root_tags: Vec<u64> = roots.iter().map(|r| r.tag).collect();
    root_tags.sort_unstable();
    let mut want: Vec<u64> = trace.iter().map(|r| r.id).collect();
    want.sort_unstable();
    assert_eq!(root_tags, want, "each root carries its request id");
    assert!(roots.iter().all(|r| r.tag_key == "req" && r.trace_id != 0 && r.parent_id == 0));

    // Every per-request span walks back to a `request` root of the same
    // trace — including the ones recorded on shard worker threads.
    for name in ["route", "queue", "admit", "prefill", "decode.token", "finish"] {
        let spans: Vec<&SpanRecord> = records.iter().filter(|r| r.name == name).collect();
        assert!(!spans.is_empty(), "no {name:?} spans recorded");
        for s in spans {
            assert_ne!(s.trace_id, 0, "{name} span outside any trace");
            let root = root_of(&ids, s);
            assert_eq!(root.name, "request", "{name} chain ends at {:?}", root.name);
            assert_eq!(root.trace_id, s.trace_id, "{name} crossed traces");
        }
    }
    // Per-step batch spans stay *outside* the request traces.
    for r in records.iter().filter(|r| r.name.starts_with("step.")) {
        assert_eq!(r.trace_id, 0, "batch span {:?} leaked into a trace", r.name);
    }
    // Span ids never collide (they are process-globally allocated).
    assert_eq!(ids.len(), records.len());
}

#[test]
fn replayed_requests_reanchor_with_incarnation_tags() {
    let trace = demo_trace(20, 12, SEED ^ 1);
    let (clean_stats, _) = traced_run(4, FaultPlan::none(), &trace);
    let busiest = clean_stats.shards.iter().max_by_key(|s| s.tokens).expect("shards").shard;

    let (stats, records) = traced_run(4, FaultPlan::panic_at(busiest, 6), &trace);
    assert!(stats.restarts >= 1, "the killed shard must be respawned");
    assert!(stats.replayed_requests >= 1);

    let ids = by_id(&records);
    let replays: Vec<&SpanRecord> = records.iter().filter(|r| r.name == "replay").collect();
    // One span per journal entry fed to a fresh incarnation; an
    // interrupted replay can record fewer than the replayed count, never
    // more.
    assert!(!replays.is_empty(), "replay must leave spans");
    assert!(replays.len() <= stats.replayed_requests);
    for r in replays {
        assert_eq!(r.tag_key, "incarnation");
        assert!(r.tag >= 1, "replay runs under a respawned (incarnation >= 1) shard");
        // The replay re-anchors under the *original* submit-side root.
        let root = root_of(&ids, r);
        assert_eq!(root.name, "request");
        assert_eq!(root.trace_id, r.trace_id);
    }
}

#[test]
fn chrome_trace_export_round_trips_the_causal_triple() {
    let trace = demo_trace(8, 6, SEED ^ 2);
    let (_stats, records) = traced_run(2, FaultPlan::none(), &trace);

    // Serialize exactly as `serve cluster --trace-out` does, then
    // re-parse with the crate's own JSON parser.
    let doc = chrome_trace(&records);
    let parsed = Json::parse(&doc.to_string()).expect("exported trace must parse");
    assert_eq!(parsed.get("displayTimeUnit").as_str(), Some("ms"));
    let events = parsed.get("traceEvents").as_arr().expect("traceEvents array");
    assert_eq!(events.len(), records.len(), "lossless: one event per span");

    let arg = |ev: &Json, k: &str| ev.get("args").get(k).as_f64().unwrap();
    let find_span = |id: f64| events.iter().find(|e| arg(e, "span_id") == id);
    let mut decode_events = 0usize;
    for ev in events {
        assert_eq!(ev.get("ph").as_str(), Some("X"));
        assert!(ev.get("ts").as_f64().is_some() && ev.get("dur").as_f64().is_some());
        if ev.get("name").as_str() != Some("decode.token") {
            continue;
        }
        decode_events += 1;
        // Resolve the parent chain purely inside the exported document.
        let mut parent = arg(ev, "parent_id");
        let mut cur = ev;
        let mut hops = 0;
        while parent != 0.0 {
            cur = find_span(parent).expect("parent event present in export");
            parent = arg(cur, "parent_id");
            hops += 1;
            assert!(hops <= 64, "unbounded parent chain");
        }
        assert_eq!(cur.get("name").as_str(), Some("request"));
        assert_eq!(arg(cur, "trace_id"), arg(ev, "trace_id"));
    }
    assert!(decode_events >= trace.len(), "first token of every request is sampled");
}

#[test]
fn slo_accounting_surfaces_in_the_snapshot() {
    let telemetry = Telemetry::new();
    let cfg = ClusterConfig {
        shards: 1,
        queue_depth: 16,
        shard: ShardConfig {
            slots: 2,
            attn: AttnConfig::fp4(),
            seq_max: 128,
            sample_seed: SEED,
            ..ShardConfig::default()
        },
        ..ClusterConfig::default()
    };
    let lm = SimLmConfig::default();
    let mut cluster =
        DecodeCluster::spawn_observed(cfg, telemetry.clone(), move |_| Box::new(SimLm::new(lm)));
    for id in 1..=5u64 {
        let req = Request {
            id,
            prompt: b"slo check#".to_vec(),
            max_new_tokens: 4,
            temperature: 0.0,
            deadline_ms: Some(1e9), // generous: must settle as met
            trace: Default::default(),
        };
        assert_eq!(cluster.submit(req).unwrap(), Admission::Accepted);
    }
    let (done, stats) = cluster.drain().expect("drain");
    assert_eq!(done.len(), 5);
    assert_eq!(stats.shed_deadline, 0);

    let doc = telemetry.snapshot();
    let num = |path: &str| {
        path.split('.')
            .fold(&doc, |d, k| d.get(k))
            .as_f64()
            .unwrap_or_else(|| panic!("no number at {path:?} in {doc}"))
    };
    assert_eq!(num("metrics.serve.slo.deadlines_met"), 5.0);
    assert_eq!(num("metrics.serve.slo.slack_ms.count"), 5.0);
    assert!(num("metrics.serve.slo.slack_ms.p50_ms") > 0.0, "1e9 ms deadlines leave real slack");
    assert_eq!(num("metrics.serve.slo.false_admit"), 0.0);
    assert_eq!(num("metrics.serve.slo.false_shed"), 0.0);
    assert_eq!(num("metrics.serve.slo.overrun_ms.count"), 0.0);
}
