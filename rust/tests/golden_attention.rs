//! Golden tests: the native Rust attention engines vs the JAX oracle
//! (`rust/tests/golden/attention_golden.json`, emitted by `make artifacts`).
//!
//! These pin the Figure-4 "real quant" comparator to the exact semantics
//! of `ref.naive_attention` per variant.

#![allow(deprecated)] // the deprecated shims are exactly what these pin

use attn_qat::attention::flash::attend_f32;
use attn_qat::attention::{attend_fp4, attend_sage3};
use attn_qat::json::Json;

fn load_golden() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/attention_golden.json");
    let text = std::fs::read_to_string(path)
        .expect("golden vectors missing — run `make artifacts` first");
    Json::parse(&text).expect("parse golden json")
}

fn check_case(
    case: &Json,
    f: impl Fn(&[f32], &[f32], &[f32], usize, usize) -> (Vec<f32>, Vec<f32>),
    tol: f32,
) {
    let n = case.get("n").as_usize().unwrap();
    let d = case.get("d").as_usize().unwrap();
    let q = case.get("q").to_f32_vec().unwrap();
    let k = case.get("k").to_f32_vec().unwrap();
    let v = case.get("v").to_f32_vec().unwrap();
    let want_o = case.get("o").to_f32_vec().unwrap();
    let want_lse = case.get("lse").to_f32_vec().unwrap();
    let (o, lse) = f(&q, &k, &v, n, d);
    let mut max_o = 0.0f32;
    for (a, b) in o.iter().zip(&want_o) {
        max_o = max_o.max((a - b).abs());
    }
    let mut max_l = 0.0f32;
    for (a, b) in lse.iter().zip(&want_lse) {
        max_l = max_l.max((a - b).abs());
    }
    assert!(max_o < tol, "o diff {max_o}");
    assert!(max_l < tol * 10.0, "lse diff {max_l}");
}

#[test]
fn f32_engine_matches_jax_full() {
    let g = load_golden();
    check_case(
        &g.get("f32_full").clone(),
        |q, k, v, n, d| {
            let out = attend_f32(q, k, v, n, n, d, false);
            (out.o, out.lse)
        },
        1e-5,
    );
}

#[test]
fn f32_engine_matches_jax_causal() {
    let g = load_golden();
    check_case(
        &g.get("f32_causal").clone(),
        |q, k, v, n, d| {
            let out = attend_f32(q, k, v, n, n, d, true);
            (out.o, out.lse)
        },
        1e-5,
    );
}

#[test]
fn fp4_engine_matches_jax_full() {
    // Real-quant vs fake-quant: same lattice arithmetic, only f32
    // accumulation order differs.
    let g = load_golden();
    check_case(
        &g.get("fp4_full").clone(),
        |q, k, v, n, d| {
            let out = attend_fp4(q, k, v, n, n, d, false);
            (out.o, out.lse)
        },
        5e-5,
    );
}

#[test]
fn fp4_engine_matches_jax_causal() {
    let g = load_golden();
    check_case(
        &g.get("fp4_causal").clone(),
        |q, k, v, n, d| {
            let out = attend_fp4(q, k, v, n, n, d, true);
            (out.o, out.lse)
        },
        5e-5,
    );
}

#[test]
fn sage3_engine_matches_jax_full() {
    let g = load_golden();
    check_case(
        &g.get("sage3_full").clone(),
        |q, k, v, n, d| {
            let out = attend_sage3(q, k, v, n, n, d, false);
            (out.o, out.lse)
        },
        5e-5,
    );
}
