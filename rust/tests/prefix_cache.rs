//! Shared-prefix KV page pool integration tests.
//!
//! The load-bearing contract: prefix sharing is a **memory/latency
//! optimization with zero numeric surface**. Sealed NVFP4 pages are
//! immutable and quantization is deterministic, so a prompt that attaches
//! an already-sealed prefix run (refcounted, no byte copy) must decode
//! bitwise identically to one that prefilled every row itself — across
//! shard counts, copy-on-write divergence at any offset, disk spill, and
//! supervised crash-replay. On top of that: refcounts must drain to zero
//! (no leaked pool pages after churn), and the accounting the bench
//! headlines (fresh KV bytes per admitted sequence) must actually drop.

use std::collections::VecDeque;

use attn_qat::attention::AttnConfig;
use attn_qat::experiments::cluster::{serve_trace_prefix, shared_prefix_trace};
use attn_qat::kvcache::{PagedKvCache, SpillConfig, PAGE_SIZE};
use attn_qat::serve::{
    Completion, FaultPlan, PrefixIndex, Request, ShardConfig, ShardWorker, SimLm, SimLmConfig,
    SupervisorConfig,
};

fn assert_same(label: &str, a: &[Completion], b: &[Completion]) {
    assert_eq!(a.len(), b.len(), "{label}: completion counts");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{label}: ids");
        assert_eq!(x.text, y.text, "{label}: req {} tokens", x.id);
        assert_eq!(x.new_tokens, y.new_tokens, "{label}: req {}", x.id);
    }
}

#[test]
fn shared_prefix_cluster_is_bitwise_and_halves_kv_admission() {
    // 24 requests behind one 64-byte system prompt (4 sealed pages each),
    // unique 4-byte suffixes: the workload the sharing tier exists for.
    // (Suffixes stay short so the f32 hot tail — identical on and off —
    // does not drown the sealed-page saving the assertion measures.)
    let trace = shared_prefix_trace(24, 64, 4, 6, 11);
    let run = |shards: usize, share: bool| {
        serve_trace_prefix(
            shards,
            AttnConfig::fp4(),
            3,
            11,
            &trace,
            share,
            None,
            FaultPlan::none(),
            SupervisorConfig::default(),
        )
        .expect("serve")
    };
    let (_, s_off, off) = run(2, false);
    let (_, s_on, on) = run(2, true);
    let (_, _, on_one) = run(1, true);

    // Sharing must be bitwise invisible, and stay placement-invariant.
    assert_same("sharing on vs off", &on, &off);
    assert_same("sharing cluster(1) vs cluster(2)", &on_one, &on);

    let (hits, pages, bytes, _) = s_on.prefix_totals();
    assert!(hits >= 2, "repeat prompts must hit the index ({hits})");
    assert!(pages > 0 && bytes > 0, "hits must attach real pages");
    assert_eq!(s_off.prefix_totals().0, 0, "sharing off must never match");

    // The headline: fresh KV bytes per admitted sequence collapse — only
    // the first request per shard seals the system prompt, everyone else
    // attaches it by refcount.
    let kv_on = s_on.kv_admit_bytes_per_seq().expect("served requests");
    let kv_off = s_off.kv_admit_bytes_per_seq().expect("served requests");
    assert!(
        kv_on < kv_off / 2.0,
        "sharing must at least halve fresh KV bytes/seq ({kv_on:.0} vs {kv_off:.0})"
    );
}

#[test]
fn cow_divergence_at_every_offset_class_is_bitwise() {
    // A registered 80-byte prompt, then variants diverging at every
    // offset class: inside page 0 (no shared pages), exactly at the first
    // page boundary, mid-trie, in the last matchable page, and past the
    // match cap. Each must attach the longest shared run, open its own
    // private pages from the divergence point, and decode bitwise equal
    // to the unshared run.
    let base: Vec<u8> = (0..80u8).map(|j| b'a' + (j % 17)).collect();
    let mut prompts = vec![base.clone()];
    for &off in &[3usize, 16, 40, 63, 79] {
        let mut p = base.clone();
        p[off] = b'Z';
        prompts.push(p);
    }
    let reqs: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request {
            id: i as u64 + 1,
            prompt: p.clone(),
            max_new_tokens: 4,
            temperature: 0.0,
            deadline_ms: None,
            trace: Default::default(),
        })
        .collect();
    let run = |share: bool| {
        let cfg = ShardConfig { prefix_share: share, ..ShardConfig::default() };
        let mut w = ShardWorker::new(Box::new(SimLm::new(SimLmConfig::default())), cfg);
        for r in &reqs {
            w.submit(r.clone());
        }
        let mut done = w.run().expect("worker run");
        done.sort_by_key(|c| c.id);
        (w.stats(0), done)
    };
    let (s_on, on) = run(true);
    let (s_off, off) = run(false);
    assert_same("cow on vs off", &on, &off);
    // Variants diverging at 16/40/63/79 all share at least one page; the
    // ones diverging inside the sealed region (3/16/40/63) are COW splits.
    assert!(s_on.prefix_hits >= 3, "boundary/mid-trie variants must hit ({})", s_on.prefix_hits);
    assert!(s_on.prefix_cow_splits >= 3, "divergence must split ({})", s_on.prefix_cow_splits);
    assert_eq!(s_off.prefix_cow_splits, 0);
    assert!(
        s_on.tokens < s_off.tokens,
        "attached prefixes must skip prefill rows ({} vs {})",
        s_on.tokens,
        s_off.tokens
    );
}

#[test]
fn refcount_churn_drains_the_pool_to_zero() {
    // 2000 sequences cycled through 8 live slots across 4 prompt
    // families, attach + register + drop each round: after the last drop
    // the index holds the only references, and releasing it leaves the
    // pool empty — no leaked or double-freed pages anywhere in the cycle.
    const LAYERS: usize = 2;
    const HEADS: usize = 2;
    const HD: usize = 8;
    const PREFIX_PAGES: usize = 3;
    let row = |tag: usize, t: usize, layer: usize, head: usize, which: usize| -> Vec<f32> {
        (0..HD)
            .map(|j| ((tag * 31 + t * 7 + layer * 13 + head * 3 + which * 5 + j) % 23) as f32
                * 0.05
                - 0.5)
            .collect()
    };
    let mut cache = PagedKvCache::new(LAYERS, HEADS, HD);
    cache.set_dedup(true);
    let mut idx = PrefixIndex::with_capacity(256);
    let mut live: VecDeque<u64> = VecDeque::new();
    for i in 0..2000u64 {
        if live.len() == 8 {
            cache.drop_seq(live.pop_front().unwrap()).unwrap();
        }
        let fam = (i % 4) as usize;
        let prompt = vec![b'a' + fam as u8; PREFIX_PAGES * PAGE_SIZE];
        let slot = cache.add_seq(i + 1);
        let m = idx.lookup(&prompt, PREFIX_PAGES);
        if !m.pages.is_empty() {
            cache.attach_prefix_at(slot, &m.pages).unwrap();
        }
        // Fill whatever the attach did not cover, then a private hot tail
        // (salted per sequence so it never seals or dedups).
        for t in m.pages.len() * PAGE_SIZE..PREFIX_PAGES * PAGE_SIZE {
            for layer in 0..LAYERS {
                for head in 0..HEADS {
                    let k = row(fam, t, layer, head, 0);
                    let v = row(fam, t, layer, head, 1);
                    cache.append_at(slot, layer, head, &k, &v).unwrap();
                }
            }
        }
        for t in 0..5 {
            for layer in 0..LAYERS {
                for head in 0..HEADS {
                    let k = row(i as usize + 9000, t, layer, head, 0);
                    let v = row(i as usize + 9000, t, layer, head, 1);
                    cache.append_at(slot, layer, head, &k, &v).unwrap();
                }
            }
        }
        let runs = cache.sealed_prefix_refs_at(slot, PREFIX_PAGES).unwrap();
        idx.register(&prompt, &runs, cache.pool_mut());
        live.push_back(i + 1);
    }
    for id in live {
        cache.drop_seq(id).unwrap();
    }
    let held = cache.pool().live_pages();
    assert!(held > 0, "the index must still hold the registered runs");
    assert!(
        held <= 4 * PREFIX_PAGES * LAYERS * HEADS,
        "at most one pooled page per (family, page, layer, head), got {held}"
    );
    assert!(cache.pool().stats().dedup_hits > 0, "family reruns must dedup");
    idx.release_all(cache.pool_mut());
    assert_eq!(cache.pool().live_pages(), 0, "released pool must drain to zero");
}

#[test]
fn mid_decode_panic_replay_reconstructs_sharing_bitwise() {
    // Supervised crash-replay with sharing on: the respawned shard
    // recomputes its journal from scratch, rebuilding its prefix index
    // and page pool along the way — completions must stay bitwise equal
    // to the clean shared run.
    let trace = shared_prefix_trace(20, 64, 8, 8, 13);
    let sup = SupervisorConfig::default();
    let run = |plan: FaultPlan| {
        serve_trace_prefix(4, AttnConfig::fp4(), 3, 13, &trace, true, None, plan, sup)
            .expect("serve")
    };
    let (_, clean_stats, clean) = run(FaultPlan::none());
    assert_eq!(clean_stats.restarts, 0, "clean run must not restart");
    let busiest =
        clean_stats.shards.iter().max_by_key(|s| s.tokens).expect("shards").shard;
    let (_, stats, faulty) = run(FaultPlan::panic_at(busiest, 6));
    assert!(stats.restarts >= 1, "the killed shard must be respawned");
    assert_eq!(faulty.len(), trace.len(), "zero lost requests");
    assert_same("replayed sharing vs clean", &clean, &faulty);
    let (hits, pages, _, _) = stats.prefix_totals();
    assert!(hits >= 1 && pages > 0, "replay must reconstruct sharing ({hits} hits)");
}

#[test]
fn disk_spill_round_trips_bitwise_and_cleans_up() {
    // A spill budget far below the working set forces cold sealed pages
    // to disk at every admission; attends reload them transparently, so
    // completions stay bitwise equal — and the pool removes every spill
    // file when it drops.
    let trace = shared_prefix_trace(12, 64, 8, 6, 17);
    let dir = std::env::temp_dir().join("attn_qat_prefix_spill_it");
    std::fs::create_dir_all(&dir).unwrap();
    let run = |spill: Option<SpillConfig>| {
        serve_trace_prefix(
            1,
            AttnConfig::fp4(),
            2,
            17,
            &trace,
            true,
            spill,
            FaultPlan::none(),
            SupervisorConfig::default(),
        )
        .expect("serve")
    };
    let (_, _, resident) = run(None);
    let (_, stats, spilled) =
        run(Some(SpillConfig { dir: dir.clone(), budget_bytes: 2048 }));
    assert_same("spill vs resident", &resident, &spilled);
    assert!(stats.spilled_pages() > 0, "a 2 KiB budget must force spills");
    let reloaded: u64 = stats.shards.iter().map(|s| s.reloaded_pages).sum();
    assert!(reloaded > 0, "decode must transparently reload spilled pages");
    let leftovers = std::fs::read_dir(&dir).unwrap().count();
    assert_eq!(leftovers, 0, "pool drop must remove its spill directory");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn drop_seq_of_unknown_id_is_a_hard_error() {
    let mut cache = PagedKvCache::new(1, 1, 8);
    assert!(cache.drop_seq(42).is_err(), "unknown id must error, not no-op");
    let _ = cache.add_seq(7);
    cache.drop_seq(7).expect("live id drops cleanly");
    assert!(cache.drop_seq(7).is_err(), "double drop must error");
}

#[test]
fn memory_json_counts_page_kinds() {
    let mut cache = PagedKvCache::new(1, 1, 8);
    cache.set_dedup(true);
    let slot = cache.add_seq(1);
    for t in 0..PAGE_SIZE + 3 {
        let k: Vec<f32> = (0..8).map(|j| (t * 8 + j) as f32 * 0.01 - 0.4).collect();
        cache.append_at(slot, 0, 0, &k, &k).unwrap();
    }
    let doc = cache.memory_json();
    assert_eq!(doc.get("pages").get("sealed").as_f64(), Some(1.0));
    assert_eq!(doc.get("pages").get("hot").as_f64(), Some(1.0));
    assert_eq!(doc.get("pages").get("shared").as_f64(), Some(0.0));
    assert_eq!(doc.get("pages").get("spilled").as_f64(), Some(0.0));
}
