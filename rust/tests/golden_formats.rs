//! Golden-vector tests: pin `formats/` bit-exactly to the JAX quantizer.
//!
//! `rust/tests/golden/nvfp4_golden.json` is emitted by
//! `python/compile/aot.py::write_golden` (runs with `make artifacts`).

use attn_qat::formats::{block, e2m1, e4m3};
use attn_qat::json::Json;

fn load_golden() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/nvfp4_golden.json");
    let text = std::fs::read_to_string(path)
        .expect("golden vectors missing — run `make artifacts` first");
    Json::parse(&text).expect("parse golden json")
}

#[test]
fn e2m1_round_matches_jax_bitexact() {
    let g = load_golden();
    let input = g.get("input").to_f32_vec().unwrap();
    let want = g.get("e2m1").to_f32_vec().unwrap();
    for (i, (&x, &w)) in input.iter().zip(&want).enumerate() {
        let got = e2m1::round(x);
        assert!(got == w || (got == 0.0 && w == 0.0), "elem {i}: x={x} got={got} want={w}");
    }
}

#[test]
fn e4m3_round_matches_jax_bitexact() {
    let g = load_golden();
    let input = g.get("input").to_f32_vec().unwrap();
    let want = g.get("e4m3").to_f32_vec().unwrap();
    for (i, (&x, &w)) in input.iter().zip(&want).enumerate() {
        let got = e4m3::round(x);
        assert!(got == w || (got == 0.0 && w == 0.0), "elem {i}: x={x} got={got} want={w}");
    }
}

#[test]
fn e4m3_encode_matches_jax_codes() {
    let g = load_golden();
    let rounded = g.get("e4m3").to_f32_vec().unwrap();
    let codes: Vec<u8> = g
        .get("e4m3_codes")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as u8)
        .collect();
    for (i, (&v, &c)) in rounded.iter().zip(&codes).enumerate() {
        // python encodes sign from the *pre-rounding* value; compare via
        // decode (value-level identity) to stay sign-of-zero agnostic.
        let got = e4m3::decode(c);
        let ours = e4m3::decode(e4m3::encode(v));
        assert!(
            (got == ours) || (got == 0.0 && ours == 0.0),
            "elem {i}: code {c} -> {got} vs ours {ours}"
        );
    }
}

#[test]
fn nvfp4_block_quant_matches_jax_bitexact() {
    let g = load_golden();
    let x = g.get("block_input").to_f32_vec().unwrap();
    let rows = g.get("block_rows").as_usize().unwrap();
    let cols = g.get("block_cols").as_usize().unwrap();
    let want_q = g.get("nvfp4_q").to_f32_vec().unwrap();
    let want_s = g.get("nvfp4_scale").to_f32_vec().unwrap();
    let want_deq = g.get("nvfp4_dequant").to_f32_vec().unwrap();

    let mut codes = Vec::new();
    let mut scales = Vec::new();
    for r in 0..rows {
        block::nvfp4_quant_row(&x[r * cols..(r + 1) * cols], &mut codes, &mut scales);
    }
    let got_q: Vec<f32> = codes.iter().map(|&c| e2m1::decode(c)).collect();
    assert_eq!(got_q.len(), want_q.len());
    for (i, (&a, &b)) in got_q.iter().zip(&want_q).enumerate() {
        assert!(a == b || (a == 0.0 && b == 0.0), "code {i}: {a} vs {b}");
    }
    let got_s: Vec<f32> = scales.iter().map(|&s| e4m3::decode(s)).collect();
    assert_eq!(got_s, want_s);
    let mut deq = Vec::new();
    block::nvfp4_dequant_row(&codes, &scales, &mut deq);
    for (i, (&a, &b)) in deq.iter().zip(&want_deq).enumerate() {
        assert!(a == b || (a == 0.0 && b == 0.0), "dequant {i}: {a} vs {b}");
    }
}

#[test]
fn mxfp4_block_quant_matches_jax_bitexact() {
    let g = load_golden();
    let x = g.get("block_input").to_f32_vec().unwrap();
    let rows = g.get("block_rows").as_usize().unwrap();
    let cols = g.get("block_cols").as_usize().unwrap();
    let want_q = g.get("mxfp4_q").to_f32_vec().unwrap();
    let want_s = g.get("mxfp4_scale").to_f32_vec().unwrap();
    let mut qi = 0;
    let mut si = 0;
    for r in 0..rows {
        for blk in x[r * cols..(r + 1) * cols].chunks(32) {
            let (codes, sb) = block::mxfp4_quant_block(blk);
            let s = attn_qat::formats::e8m0::decode(sb);
            assert_eq!(s, want_s[si], "scale {si}");
            si += 1;
            for &c in &codes {
                let v = e2m1::decode(c);
                let w = want_q[qi];
                assert!(v == w || (v == 0.0 && w == 0.0), "mx code {qi}: {v} vs {w}");
                qi += 1;
            }
        }
    }
}
