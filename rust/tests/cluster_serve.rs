//! Sharded decode cluster integration tests.
//!
//! The load-bearing property is **placement-invariance**: a sequence's
//! tokens depend only on its own cache pages, its own sampling stream,
//! and the (seed-determined) model weights — so the N-shard cluster, the
//! 1-shard cluster, and a directly-pumped single `ShardWorker` (the
//! native single-worker decode server) must produce bitwise-identical
//! completions for the same fixed-seed trace. On top of that: the
//! per-shard quantized-query caches must aggregate into `ClusterStats`
//! without cross-shard interference, and bounded-queue submission must
//! apply backpressure without losing requests.

use attn_qat::attention::AttnConfig;
use attn_qat::serve::{
    ClusterConfig, Completion, DecodeCluster, Request, ShardConfig, ShardWorker, SimLm,
    SimLmConfig,
};

const MODEL_SEED: u64 = 0xbeef;
const SAMPLE_SEED: u64 = 0x5eed;

fn lm_cfg() -> SimLmConfig {
    SimLmConfig { seed: MODEL_SEED, ..SimLmConfig::default() }
}

fn shard_cfg(attn: AttnConfig) -> ShardConfig {
    ShardConfig { slots: 3, attn, seq_max: 256, sample_seed: SAMPLE_SEED, ..ShardConfig::default() }
}

/// Fixed-seed trace: deterministic prompts, mixed budgets, a few
/// temperature-sampled requests (their draws come from per-request
/// streams, so they too must be placement-invariant).
fn fixed_trace() -> Vec<Request> {
    (0..12u64)
        .map(|i| Request {
            id: i * 7 + 1, // non-contiguous ids exercise the router hash
            prompt: format!("A q{i} x={i};#").into_bytes(),
            max_new_tokens: 4 + (i as usize % 5),
            temperature: if i % 4 == 3 { 0.7 } else { 0.0 },
            deadline_ms: None,
            trace: Default::default(),
        })
        .collect()
}

fn run_single(attn: AttnConfig, trace: &[Request]) -> Vec<Completion> {
    let mut w = ShardWorker::new(Box::new(SimLm::new(lm_cfg())), shard_cfg(attn));
    for r in trace {
        w.submit(r.clone());
    }
    let mut done = w.run().expect("single worker run");
    done.sort_by_key(|c| c.id);
    done
}

fn run_cluster(
    shards: usize,
    attn: AttnConfig,
    trace: &[Request],
) -> (Vec<Completion>, attn_qat::serve::ClusterStats) {
    let cfg =
        ClusterConfig { shards, queue_depth: 4, shard: shard_cfg(attn), ..Default::default() };
    let mut cluster = DecodeCluster::spawn(cfg, |_| Box::new(SimLm::new(lm_cfg())));
    for r in trace {
        cluster.submit(r.clone()).expect("submit");
    }
    cluster.drain().expect("drain") // completions already sorted by id
}

fn assert_same(label: &str, a: &[Completion], b: &[Completion]) {
    assert_eq!(a.len(), b.len(), "{label}: completion counts");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{label}: ids");
        assert_eq!(x.text, y.text, "{label}: req {} tokens", x.id);
        assert_eq!(x.prompt_tokens, y.prompt_tokens, "{label}: req {}", x.id);
        assert_eq!(x.new_tokens, y.new_tokens, "{label}: req {}", x.id);
    }
}

#[test]
fn sharded_cluster_matches_single_worker_bitwise() {
    let trace = fixed_trace();
    let single = run_single(AttnConfig::fp4(), &trace);
    assert_eq!(single.len(), trace.len());
    // Sanity: outputs echo their prompts and actually generated tokens.
    for c in &single {
        assert!(c.new_tokens >= 1);
        assert_eq!(c.text.len(), c.prompt_tokens + c.new_tokens);
    }
    let (one_shard, _) = run_cluster(1, AttnConfig::fp4(), &trace);
    let (four_shard, stats) = run_cluster(4, AttnConfig::fp4(), &trace);
    assert_same("cluster(1) vs single worker", &one_shard, &single);
    assert_same("cluster(4) vs single worker", &four_shard, &single);
    // The trace really was sharded, not funneled through one worker.
    assert_eq!(stats.shards.len(), 4);
    assert!(
        stats.shards.iter().filter(|s| s.requests > 0).count() >= 2,
        "12 hashed ids should occupy at least two shards"
    );
    assert_eq!(stats.total_requests(), trace.len());
}

#[test]
fn f32_baseline_cluster_is_also_placement_invariant() {
    // The gather + f32 engine config rides the same scheduling paths.
    let trace = fixed_trace();
    let single = run_single(AttnConfig::f32(), &trace);
    let (two_shard, _) = run_cluster(2, AttnConfig::f32(), &trace);
    assert_same("f32 cluster(2) vs single worker", &two_shard, &single);
}

#[test]
fn fp4_and_f32_clusters_diverge_on_long_contexts() {
    // The A/B configs run genuinely different kernels. Short caches decode
    // identically (FP4 error stays under every argmax gap — verified in
    // simulation), so this uses contexts long enough to accumulate sealed
    // pages: 24-token prompts + 12 greedy continuations flip at least one
    // token on every request in simulation; asserting "any" leaves margin.
    let trace: Vec<Request> = (0..4usize)
        .map(|i| Request {
            id: i as u64 + 1,
            prompt: (0..24)
                .map(|j| if j % 7 == 0 { b' ' } else { 65 + ((i + j) % 26) as u8 })
                .collect(),
            max_new_tokens: 12,
            temperature: 0.0,
            deadline_ms: None,
            trace: Default::default(),
        })
        .collect();
    let fp4 = run_single(AttnConfig::fp4(), &trace);
    let base = run_single(AttnConfig::f32(), &trace);
    assert!(
        fp4.iter().zip(&base).any(|(a, b)| a.text != b.text),
        "fp4 and f32 decodes should not be identical on every long request"
    );
    // Both remain well-formed.
    for (a, b) in fp4.iter().zip(&base) {
        assert_eq!(a.prompt_tokens, b.prompt_tokens);
        assert!(a.new_tokens >= 1 && b.new_tokens >= 1);
    }
}

#[test]
fn qcache_stats_aggregate_per_shard_without_cross_thrash() {
    // A tied-Q model makes every head of one attention call quantize the
    // same query row: with H=2 heads, each (token, layer) probe pair is
    // exactly one miss (head 0, new content) + one hit (head 1, served
    // from residency) — provided prompts fit the cache's 4 ways. That
    // yields the crisp invariant hits == misses > 0, and because every
    // lane engine's cache is private to its shard, the totals must be
    // identical no matter how many shards the trace spreads over — the
    // "no cross-thrash" property (sharing caches across concurrent
    // sequences would evict residents between probes and break it).
    let lm = SimLmConfig { tied_q: true, seed: MODEL_SEED, ..SimLmConfig::default() };
    let trace: Vec<Request> = (0..10u64)
        .map(|i| Request {
            id: i + 1,
            prompt: format!("p{i}#").into_bytes(), // 3 bytes < 4 cache ways
            max_new_tokens: 3 + (i as usize % 3),
            temperature: 0.0,
            deadline_ms: None,
            trace: Default::default(),
        })
        .collect();
    let run = |shards: usize| {
        let cfg = ClusterConfig {
            shards,
            queue_depth: 8,
            shard: ShardConfig {
                slots: 2,
                attn: AttnConfig::fp4(),
                seq_max: 128,
                sample_seed: SAMPLE_SEED,
                ..ShardConfig::default()
            },
            ..Default::default()
        };
        let mut cluster = DecodeCluster::spawn(cfg, move |_| Box::new(SimLm::new(lm)));
        for r in &trace {
            cluster.submit(r.clone()).expect("submit");
        }
        cluster.drain().expect("drain")
    };
    let (done1, stats1) = run(1);
    let (done3, stats3) = run(3);
    assert_same("tied-q cluster(3) vs cluster(1)", &done3, &done1);
    let (h1, m1) = stats1.qcache_totals();
    let (h3, m3) = stats3.qcache_totals();
    assert!(h1 > 0, "tied-q decode must hit the query cache");
    assert_eq!(h1, m1, "tied-q H=2: every probe pair is one miss + one hit");
    assert_eq!((h1, m1), (h3, m3), "sharding must not change cache behaviour");
    // Per-shard stats carry the counters the totals came from.
    let shard_sum: u64 = stats3.shards.iter().map(|s| s.qcache_hits).sum();
    assert_eq!(shard_sum, h3);
}

#[test]
fn bounded_queues_backpressure_without_losing_requests() {
    // queue_depth=1 forces submit() to block on busy shards; every
    // request must still complete exactly once after drain.
    let trace: Vec<Request> = (0..16u64)
        .map(|i| Request {
            id: i + 1,
            prompt: b"B hold#".to_vec(),
            max_new_tokens: 3,
            temperature: 0.0,
            deadline_ms: None,
            trace: Default::default(),
        })
        .collect();
    let cfg = ClusterConfig {
        shards: 2,
        queue_depth: 1,
        shard: shard_cfg(AttnConfig::fp4()),
        ..Default::default()
    };
    let mut cluster = DecodeCluster::spawn(cfg, |_| Box::new(SimLm::new(lm_cfg())));
    for r in &trace {
        cluster.submit(r.clone()).expect("submit blocks but succeeds");
    }
    assert_eq!(cluster.submitted(), trace.len());
    let (done, stats) = cluster.drain().expect("drain");
    let mut ids: Vec<u64> = done.iter().map(|c| c.id).collect();
    ids.dedup();
    assert_eq!(ids, (1..=16).collect::<Vec<u64>>(), "all requests, exactly once");
    assert_eq!(stats.total_requests(), 16);
    for s in &stats.shards {
        assert!(s.p50_token_ms <= s.p99_token_ms + 1e-12);
        if s.tokens > 0 {
            assert!(s.tokens_per_s > 0.0);
            assert!(s.kv_bytes_peak > 0);
        }
    }
    assert!(stats.total_tokens() >= 16 * 7, "every prompt row was processed");
}
