//! Finite-difference gradient checks for the native backward and the
//! `model` module backwards (MLP / norm / embedding), plus the optimizer
//! goldens (Adam single-step, grad-clip threshold).
//!
//! Attention-backward regimes:
//!
//! * **f32 path (exact)** — with no quantization anywhere the backward
//!   computes the true gradient of `L = Σ O ∘ W`; central differences must
//!   agree to FD truncation error. Covers causal/non-causal, the
//!   `nk < nq` empty-row edge (PR-1's forward fix), and outlier-heavy
//!   inputs.
//! * **STE path (surrogate)** — the quantized backward's STE gradients are
//!   *not* the true gradient of the quantized loss (which is zero a.e.);
//!   their defining property is approximating the full-precision gradient.
//!   Checked as high cosine similarity / bounded relative L2 against the
//!   FD gradient of the unquantized loss (simulated: cos ≥ 0.982,
//!   relL2 ≤ 0.193 — asserted at 0.9 / 0.35). The smooth-K + two-level-P̃
//!   matched recompute (`flash_backward_cfg`) is held to the same bounds
//!   (simulated: cos ≥ 0.98); a *mismatched* non-smooth recompute of the
//!   same smooth residuals drops to cos ≈ 0.3–0.44, which the
//!   discrimination test pins from above.

#![allow(deprecated)] // FD references go through the pinned forward shims

use attn_qat::attention::engine::attend_fp4_train;
use attn_qat::attention::flash::attend_f32;
use attn_qat::attention::{AttnConfig, AttnEngine};
use attn_qat::model::{Adam, Embedding, Linear, Mlp, Optimizer, Sgd};
use attn_qat::qat::{flash_backward, flash_backward_cfg, BwdSwitches};
use attn_qat::rng::Rng;

const F32_SW: BwdSwitches = BwdSwitches::STOCK;
const QAT_SW: BwdSwitches = BwdSwitches::MATCHED;

/// L = Σ O ∘ W over the f32 attention (f64 accumulation of f32 outputs).
#[allow(clippy::too_many_arguments)]
fn loss_f32(q: &[f32], k: &[f32], v: &[f32], w: &[f32], nq: usize, nk: usize, d: usize, causal: bool) -> f64 {
    let out = attend_f32(q, k, v, nq, nk, d, causal);
    out.o.iter().zip(w).map(|(&o, &g)| o as f64 * g as f64).sum()
}

/// Central-difference gradient of `loss_f32` w.r.t. every coordinate.
#[allow(clippy::too_many_arguments)]
fn fd_grads(
    q: &mut [f32],
    k: &mut [f32],
    v: &mut [f32],
    w: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let h = 1e-2f32;
    let mut grads = Vec::new();
    for which in 0..3 {
        let len = if which == 0 { nq * d } else { nk * d };
        let mut g = vec![0.0f32; len];
        for idx in 0..len {
            let mut eval = |delta: f32| {
                let t = match which {
                    0 => &mut *q,
                    1 => &mut *k,
                    _ => &mut *v,
                };
                let orig = t[idx];
                t[idx] = orig + delta;
                let l = loss_f32(q, k, v, w, nq, nk, d, causal);
                let t = match which {
                    0 => &mut *q,
                    1 => &mut *k,
                    _ => &mut *v,
                };
                t[idx] = orig;
                l
            };
            let lp = eval(h);
            let lm = eval(-h);
            g[idx] = ((lp - lm) / (2.0 * h as f64)) as f32;
        }
        grads.push(g);
    }
    let dv = grads.pop().unwrap();
    let dk = grads.pop().unwrap();
    let dq = grads.pop().unwrap();
    (dq, dk, dv)
}

fn max_abs(a: &[f32]) -> f32 {
    a.iter().map(|x| x.abs()).fold(0.0, f32::max)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn check_f32_case(nq: usize, nk: usize, d: usize, causal: bool, seed: u64, outliers: bool, tol_scale: f32) {
    let mut rng = Rng::new(seed);
    let mut q = rng.normal_vec(nq * d, 0.0, 1.0);
    let mut k = rng.normal_vec(nk * d, 0.0, 1.0);
    let mut v = rng.normal_vec(nk * d, 0.0, 1.0);
    let w = rng.normal_vec(nq * d, 0.0, 1.0);
    if outliers {
        for x in q.iter_mut().step_by(3) {
            *x *= 4.0;
        }
        for x in k.iter_mut().step_by(5) {
            *x *= 6.0;
        }
        for x in v.iter_mut().step_by(4) {
            *x *= 3.0;
        }
    }
    let out = attend_f32(&q, &k, &v, nq, nk, d, causal);
    let g = flash_backward(
        &q, &k, &v, nq, nk, d, causal, &out.o, &out.o, &out.lse, &w, F32_SW,
    );
    let (fq, fk, fv) = fd_grads(&mut q, &mut k, &mut v, &w, nq, nk, d, causal);
    for (label, analytic, fd) in [("dq", &g.dq, &fq), ("dk", &g.dk, &fk), ("dv", &g.dv, &fv)] {
        let tol = tol_scale * max_abs(fd).max(1.0);
        let diff = max_abs_diff(analytic, fd);
        assert!(
            diff < tol,
            "({nq},{nk},{d}) causal={causal} {label}: |analytic-fd| {diff} > {tol}"
        );
    }
}

#[test]
fn fd_f32_full() {
    check_f32_case(8, 8, 8, false, 7, false, 5e-3);
}

#[test]
fn fd_f32_causal() {
    check_f32_case(8, 8, 8, true, 8, false, 5e-3);
}

#[test]
fn fd_f32_causal_nk_less_than_nq() {
    // The PR-1 forward edge: leading queries see zero keys; both the FD
    // and analytic gradients for those rows must be exactly zero.
    check_f32_case(9, 5, 8, true, 9, false, 5e-3);
    let (nq, nk, d) = (9usize, 5usize, 8usize);
    let mut rng = Rng::new(9);
    let q = rng.normal_vec(nq * d, 0.0, 1.0);
    let k = rng.normal_vec(nk * d, 0.0, 1.0);
    let v = rng.normal_vec(nk * d, 0.0, 1.0);
    let w = rng.normal_vec(nq * d, 0.0, 1.0);
    let out = attend_f32(&q, &k, &v, nq, nk, d, true);
    let g = flash_backward(&q, &k, &v, nq, nk, d, true, &out.o, &out.o, &out.lse, &w, F32_SW);
    for i in 0..nq - nk {
        assert!(g.dq[i * d..(i + 1) * d].iter().all(|&x| x == 0.0), "row {i}");
    }
}

#[test]
fn fd_f32_outliers() {
    // Heavy-tailed inputs saturate the softmax; FD truncation error grows
    // with the third derivative, hence the looser scale.
    check_f32_case(8, 8, 16, false, 10, true, 2e-2);
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    dot / (na * nb).max(1e-30)
}

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt();
    let den: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    num / den.max(1e-30)
}

fn check_ste_case(causal: bool, seed: u64) {
    // STE property: the quantized-path gradients track the FD gradient of
    // the *unquantized* loss — the surrogate QAT descends on.
    let (nq, nk, d) = (16usize, 16usize, 16usize);
    let mut rng = Rng::new(seed);
    let mut q = rng.normal_vec(nq * d, 0.0, 1.0);
    let mut k = rng.normal_vec(nk * d, 0.0, 1.0);
    let mut v = rng.normal_vec(nk * d, 0.0, 1.0);
    let w = rng.normal_vec(nq * d, 0.0, 1.0);
    let t = attend_fp4_train(&q, &k, &v, nq, nk, d, causal);
    let g = flash_backward(
        &q, &k, &v, nq, nk, d, causal, &t.o, &t.o_prime, &t.lse, &w, QAT_SW,
    );
    let (fq, fk, fv) = fd_grads(&mut q, &mut k, &mut v, &w, nq, nk, d, causal);
    for (label, analytic, fd) in [("dq", &g.dq, &fq), ("dk", &g.dk, &fk), ("dv", &g.dv, &fv)] {
        let cos = cosine(analytic, fd);
        let rel = rel_l2(analytic, fd);
        assert!(cos > 0.9, "causal={causal} {label}: cosine {cos}");
        assert!(rel < 0.35, "causal={causal} {label}: relL2 {rel}");
    }
}

#[test]
fn fd_ste_full() {
    check_ste_case(false, 11);
}

#[test]
fn fd_ste_causal() {
    check_ste_case(true, 12);
}

// ---------------------------------------------------------------------------
// Smooth-K + two-level-P̃ matched recompute (flash_backward_cfg)
// ---------------------------------------------------------------------------

fn check_smooth_ste_case(causal: bool, seed: u64) {
    // A large shared K offset is the regime smoothing absorbs. The STE
    // property still holds against the *raw* f32 loss: S = q·(k − k̄) is a
    // per-row constant shift of q·k, so softmax — and its gradient — is
    // the same function of (q, k, v).
    let (nq, nk, d) = (16usize, 16usize, 16usize);
    let mut rng = Rng::new(seed);
    let mut q = rng.normal_vec(nq * d, 0.0, 1.0);
    let mut k = rng.normal_vec(nk * d, 0.0, 1.0);
    let mut v = rng.normal_vec(nk * d, 0.0, 1.0);
    for x in k.iter_mut() {
        *x += 4.0;
    }
    let w = rng.normal_vec(nq * d, 0.0, 1.0);
    let cfg = AttnConfig::attn_qat()
        .with_smooth(true)
        .with_two_level_p(true)
        .with_causal(causal);
    let mut engine = AttnEngine::new(cfg);
    let t = engine.forward_train(&q, &k, &v, 1, nq, nk, d);
    let g = flash_backward_cfg(&cfg, &q, &k, &v, nq, nk, d, &t.o, &t.o_prime, &t.lse, &w);
    let (fq, fk, fv) = fd_grads(&mut q, &mut k, &mut v, &w, nq, nk, d, causal);
    for (label, analytic, fd) in [("dq", &g.dq, &fq), ("dk", &g.dk, &fk), ("dv", &g.dv, &fv)] {
        let cos = cosine(analytic, fd);
        let rel = rel_l2(analytic, fd);
        assert!(cos > 0.9, "smooth causal={causal} {label}: cosine {cos}");
        assert!(rel < 0.35, "smooth causal={causal} {label}: relL2 {rel}");
    }
    // Discrimination: recomputing the same residuals *without* the smooth
    // terms describes a different function — its gradient quality must
    // collapse (simulated cos ≈ 0.3–0.44 vs ≥ 0.98 matched).
    let plain = AttnConfig::attn_qat().with_causal(causal);
    let bad = flash_backward_cfg(&plain, &q, &k, &v, nq, nk, d, &t.o, &t.o_prime, &t.lse, &w);
    let cos_bad = cosine(&bad.dq, &fq);
    let cos_good = cosine(&g.dq, &fq);
    assert!(
        cos_bad < 0.8 && cos_good > cos_bad,
        "mismatched recompute should collapse: matched {cos_good}, mismatched {cos_bad}"
    );
}

#[test]
fn fd_ste_smooth_two_level_full() {
    check_smooth_ste_case(false, 13);
}

#[test]
fn fd_ste_smooth_two_level_causal() {
    check_smooth_ste_case(true, 14);
}

// ---------------------------------------------------------------------------
// Module backwards: MLP, norm, embedding (the QatModel building blocks)
// ---------------------------------------------------------------------------

use attn_qat::model::modules::{rms_norm, rms_norm_bwd};

/// Central differences over a copy of `base`: `eval` gets the perturbed
/// buffer and returns the (f64) loss.
fn fd_buffer(base: &[f32], h: f32, mut eval: impl FnMut(&[f32]) -> f64) -> Vec<f32> {
    let mut buf = base.to_vec();
    let mut g = vec![0.0f32; buf.len()];
    for i in 0..buf.len() {
        let orig = buf[i];
        buf[i] = orig + h;
        let lp = eval(&buf);
        buf[i] = orig - h;
        let lm = eval(&buf);
        buf[i] = orig;
        g[i] = ((lp - lm) / (2.0 * h as f64)) as f32;
    }
    g
}

fn assert_close(label: &str, analytic: &[f32], fd: &[f32], tol_scale: f32) {
    let scale = max_abs(fd).max(1.0);
    let diff = max_abs_diff(analytic, fd);
    assert!(diff < tol_scale * scale, "{label}: |analytic-fd| {diff} > {}", tol_scale * scale);
}

#[test]
fn fd_rms_norm_backward() {
    let d = 16;
    let mut rng = Rng::new(21);
    let x = rng.normal_vec(d, 0.0, 2.0);
    let w = rng.normal_vec(d, 0.0, 1.0);
    let loss = |xb: &[f32]| -> f64 {
        let mut y = vec![0.0f32; xb.len()];
        rms_norm(xb, &mut y);
        y.iter().zip(&w).map(|(&a, &b)| a as f64 * b as f64).sum()
    };
    let mut dx = vec![0.0f32; d];
    rms_norm_bwd(&x, &w, &mut dx);
    let fd = fd_buffer(&x, 1e-2, loss);
    assert_close("rms dx", &dx, &fd, 5e-3);
}

#[test]
fn fd_mlp_backward() {
    // h ← h + tanh(rms(h)·Win)·Wout over 3 rows; L = Σ out ∘ W.
    let (n, d, ff) = (3usize, 8usize, 12usize);
    let mut rng = Rng::new(22);
    let win = Linear::new(rng.normal_vec(d * ff, 0.0, 0.35), d, ff);
    let wout = Linear::new(rng.normal_vec(ff * d, 0.0, 0.3), ff, d);
    let mut mlp = Mlp::new(win, wout);
    let h0 = rng.normal_vec(n * d, 0.0, 1.0);
    let w = rng.normal_vec(n * d, 0.0, 1.0);
    let run = |m: &Mlp, h_in: &[f32]| -> f64 {
        let mut h = h_in.to_vec();
        m.forward(&mut h, n);
        h.iter().zip(&w).map(|(&a, &b)| a as f64 * b as f64).sum()
    };
    // Analytic: forward_train + backward with dh = W.
    let mut h = h0.clone();
    let acts = mlp.forward_train(&mut h, n);
    let mut dh = w.clone();
    mlp.backward(&h0, &acts, &mut dh, n);
    let mlp = mlp; // freeze: FD below only reads
    let fd_h = fd_buffer(&h0, 1e-2, |hb| run(&mlp, hb));
    assert_close("mlp dh", &dh, &fd_h, 5e-3);
    let fd_win = fd_buffer(&mlp.win.w, 1e-2, |wb| {
        let mut m2 = mlp.clone();
        m2.win.w.copy_from_slice(wb);
        run(&m2, &h0)
    });
    assert_close("mlp dWin", &mlp.win.g, &fd_win, 5e-3);
    let fd_wout = fd_buffer(&mlp.wout.w, 1e-2, |wb| {
        let mut m2 = mlp.clone();
        m2.wout.w.copy_from_slice(wb);
        run(&m2, &h0)
    });
    assert_close("mlp dWout", &mlp.wout.g, &fd_wout, 5e-3);
}

#[test]
fn fd_embedding_backward() {
    // L = Σ h ∘ W is linear in both tables: FD is exact up to rounding,
    // and rows never touched must have zero gradient.
    let (d, max_pos) = (8usize, 6usize);
    let mut rng = Rng::new(23);
    let mut emb = Embedding::new(
        rng.normal_vec(16 * d, 0.0, 0.5),
        rng.normal_vec(max_pos * d, 0.0, 0.5),
        d,
        max_pos,
    );
    let tokens = [3u8, 7, 3, 1];
    let w = rng.normal_vec(tokens.len() * d, 0.0, 1.0);
    emb.backward(&tokens, 2, &w);
    // Token 3 appears at rows 0 and 2: its grad row is w0 + w2.
    for c in 0..d {
        let want = w[c] + w[2 * d + c];
        assert!((emb.tok_g[3 * d + c] - want).abs() < 1e-6);
        // Untouched token row stays zero.
        assert_eq!(emb.tok_g[9 * d + c], 0.0);
    }
    // Position wraps: pos0=2 with 4 tokens touches pos 2,3,4,5.
    for (i, _) in tokens.iter().enumerate() {
        let p = (2 + i) % max_pos;
        for c in 0..d {
            assert!((emb.pos_g[p * d + c] - w[i * d + c]).abs() < 1e-6, "pos {p}");
        }
    }
    // Forward/backward consistency via FD on one touched element.
    let loss = |emb: &Embedding| -> f64 {
        let mut h = vec![0.0f32; tokens.len() * d];
        emb.forward(&tokens, 2, &mut h);
        h.iter().zip(&w).map(|(&a, &b)| a as f64 * b as f64).sum()
    };
    let idx = 3 * d + 5;
    let orig = emb.tok[idx];
    emb.tok[idx] = orig + 1e-2;
    let lp = loss(&emb);
    emb.tok[idx] = orig - 1e-2;
    let lm = loss(&emb);
    emb.tok[idx] = orig;
    let fd = ((lp - lm) / 2e-2) as f32;
    assert!((emb.tok_g[idx] - fd).abs() < 5e-3, "{} vs {}", emb.tok_g[idx], fd);
}

// ---------------------------------------------------------------------------
// Optimizer goldens
// ---------------------------------------------------------------------------

#[test]
fn adam_single_step_matches_reference_golden() {
    // Reference values computed from the bias-corrected Adam recurrence
    // (f64): first step moves each weight by ≈ lr·sign(g).
    let mut opt = Adam::new();
    let mut w = vec![1.0f32, -2.0, 0.5, 3.0];
    let g = vec![0.1f32, -0.2, 0.3, -0.4];
    opt.begin_step();
    opt.update(0, &mut w, &g, 0.1);
    let want1 = [0.900000010f32, -1.900000005, 0.400000003, 3.099999997];
    for (a, b) in w.iter().zip(&want1) {
        assert!((a - b).abs() < 5e-6, "step1: {a} vs {b}");
    }
    opt.begin_step();
    opt.update(0, &mut w, &g, 0.1);
    let want2 = [0.800000020f32, -1.800000010, 0.300000007, 3.199999995];
    for (a, b) in w.iter().zip(&want2) {
        assert!((a - b).abs() < 5e-6, "step2: {a} vs {b}");
    }
}

#[test]
fn sgd_momentum_matches_native_trainer_update() {
    // v ← μv + g; w ← w − lr·v — two steps by hand.
    let mut opt = Sgd::new(0.9);
    let mut w = vec![1.0f32];
    opt.update(0, &mut w, &[0.5], 0.2);
    assert!((w[0] - (1.0 - 0.2 * 0.5)).abs() < 1e-7);
    opt.update(0, &mut w, &[0.5], 0.2);
    let v2 = 0.9 * 0.5 + 0.5;
    assert!((w[0] - (0.9 - 0.2 * v2)).abs() < 1e-6);
}

#[test]
fn session_grad_clip_threshold() {
    use attn_qat::model::{TrainConfig, TrainSession, TrainableModel};

    // Deterministic model with fixed gradients: global norm 5 (3-4-0
    // triangle over two tensors) against clip 1.0 ⇒ update scaled by 1/5;
    // recorded norm stays pre-clip.
    struct Fixed {
        w: Vec<f32>,
        g: Vec<f32>,
    }
    impl TrainableModel for Fixed {
        fn train_step(&mut self) -> f32 {
            self.g[0] += 3.0;
            self.g[1] += 4.0;
            0.0
        }
        fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
            f(&mut self.w, &mut self.g);
        }
    }
    let model = Fixed { w: vec![0.0; 2], g: vec![0.0; 2] };
    let cfg = TrainConfig::sgd(0.1, 0.0).with_grad_clip(Some(1.0));
    let mut s = TrainSession::new(model, cfg);
    let m = s.step();
    assert_eq!(m.grad_norm, 5.0, "pre-clip norm recorded");
    assert!((s.model.w[0] + 0.1 * 3.0 / 5.0).abs() < 1e-6, "{}", s.model.w[0]);
    assert!((s.model.w[1] + 0.1 * 4.0 / 5.0).abs() < 1e-6, "{}", s.model.w[1]);
    // At or below the threshold the gradient passes through unscaled.
    let model = Fixed { w: vec![0.0; 2], g: vec![0.0; 2] };
    let cfg = TrainConfig::sgd(0.1, 0.0).with_grad_clip(Some(5.0));
    let mut s = TrainSession::new(model, cfg);
    s.step();
    assert!((s.model.w[0] + 0.3).abs() < 1e-6);
    assert!((s.model.w[1] + 0.4).abs() < 1e-6);
}
