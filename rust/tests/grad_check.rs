//! Finite-difference gradient checks for the native backward.
//!
//! Two regimes:
//!
//! * **f32 path (exact)** — with no quantization anywhere the backward
//!   computes the true gradient of `L = Σ O ∘ W`; central differences must
//!   agree to FD truncation error. Covers causal/non-causal, the
//!   `nk < nq` empty-row edge (PR-1's forward fix), and outlier-heavy
//!   inputs.
//! * **STE path (surrogate)** — the quantized backward's STE gradients are
//!   *not* the true gradient of the quantized loss (which is zero a.e.);
//!   their defining property is approximating the full-precision gradient.
//!   Checked as high cosine similarity / bounded relative L2 against the
//!   FD gradient of the unquantized loss (simulated: cos ≥ 0.982,
//!   relL2 ≤ 0.193 — asserted at 0.9 / 0.35).

#![allow(deprecated)] // FD references go through the pinned forward shims

use attn_qat::attention::engine::attend_fp4_train;
use attn_qat::attention::flash::attend_f32;
use attn_qat::qat::{flash_backward, BwdSwitches};
use attn_qat::rng::Rng;

const F32_SW: BwdSwitches = BwdSwitches::STOCK;
const QAT_SW: BwdSwitches = BwdSwitches::MATCHED;

/// L = Σ O ∘ W over the f32 attention (f64 accumulation of f32 outputs).
#[allow(clippy::too_many_arguments)]
fn loss_f32(q: &[f32], k: &[f32], v: &[f32], w: &[f32], nq: usize, nk: usize, d: usize, causal: bool) -> f64 {
    let out = attend_f32(q, k, v, nq, nk, d, causal);
    out.o.iter().zip(w).map(|(&o, &g)| o as f64 * g as f64).sum()
}

/// Central-difference gradient of `loss_f32` w.r.t. every coordinate.
#[allow(clippy::too_many_arguments)]
fn fd_grads(
    q: &mut [f32],
    k: &mut [f32],
    v: &mut [f32],
    w: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let h = 1e-2f32;
    let mut grads = Vec::new();
    for which in 0..3 {
        let len = if which == 0 { nq * d } else { nk * d };
        let mut g = vec![0.0f32; len];
        for idx in 0..len {
            let mut eval = |delta: f32| {
                let t = match which {
                    0 => &mut *q,
                    1 => &mut *k,
                    _ => &mut *v,
                };
                let orig = t[idx];
                t[idx] = orig + delta;
                let l = loss_f32(q, k, v, w, nq, nk, d, causal);
                let t = match which {
                    0 => &mut *q,
                    1 => &mut *k,
                    _ => &mut *v,
                };
                t[idx] = orig;
                l
            };
            let lp = eval(h);
            let lm = eval(-h);
            g[idx] = ((lp - lm) / (2.0 * h as f64)) as f32;
        }
        grads.push(g);
    }
    let dv = grads.pop().unwrap();
    let dk = grads.pop().unwrap();
    let dq = grads.pop().unwrap();
    (dq, dk, dv)
}

fn max_abs(a: &[f32]) -> f32 {
    a.iter().map(|x| x.abs()).fold(0.0, f32::max)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn check_f32_case(nq: usize, nk: usize, d: usize, causal: bool, seed: u64, outliers: bool, tol_scale: f32) {
    let mut rng = Rng::new(seed);
    let mut q = rng.normal_vec(nq * d, 0.0, 1.0);
    let mut k = rng.normal_vec(nk * d, 0.0, 1.0);
    let mut v = rng.normal_vec(nk * d, 0.0, 1.0);
    let w = rng.normal_vec(nq * d, 0.0, 1.0);
    if outliers {
        for x in q.iter_mut().step_by(3) {
            *x *= 4.0;
        }
        for x in k.iter_mut().step_by(5) {
            *x *= 6.0;
        }
        for x in v.iter_mut().step_by(4) {
            *x *= 3.0;
        }
    }
    let out = attend_f32(&q, &k, &v, nq, nk, d, causal);
    let g = flash_backward(
        &q, &k, &v, nq, nk, d, causal, &out.o, &out.o, &out.lse, &w, F32_SW,
    );
    let (fq, fk, fv) = fd_grads(&mut q, &mut k, &mut v, &w, nq, nk, d, causal);
    for (label, analytic, fd) in [("dq", &g.dq, &fq), ("dk", &g.dk, &fk), ("dv", &g.dv, &fv)] {
        let tol = tol_scale * max_abs(fd).max(1.0);
        let diff = max_abs_diff(analytic, fd);
        assert!(
            diff < tol,
            "({nq},{nk},{d}) causal={causal} {label}: |analytic-fd| {diff} > {tol}"
        );
    }
}

#[test]
fn fd_f32_full() {
    check_f32_case(8, 8, 8, false, 7, false, 5e-3);
}

#[test]
fn fd_f32_causal() {
    check_f32_case(8, 8, 8, true, 8, false, 5e-3);
}

#[test]
fn fd_f32_causal_nk_less_than_nq() {
    // The PR-1 forward edge: leading queries see zero keys; both the FD
    // and analytic gradients for those rows must be exactly zero.
    check_f32_case(9, 5, 8, true, 9, false, 5e-3);
    let (nq, nk, d) = (9usize, 5usize, 8usize);
    let mut rng = Rng::new(9);
    let q = rng.normal_vec(nq * d, 0.0, 1.0);
    let k = rng.normal_vec(nk * d, 0.0, 1.0);
    let v = rng.normal_vec(nk * d, 0.0, 1.0);
    let w = rng.normal_vec(nq * d, 0.0, 1.0);
    let out = attend_f32(&q, &k, &v, nq, nk, d, true);
    let g = flash_backward(&q, &k, &v, nq, nk, d, true, &out.o, &out.o, &out.lse, &w, F32_SW);
    for i in 0..nq - nk {
        assert!(g.dq[i * d..(i + 1) * d].iter().all(|&x| x == 0.0), "row {i}");
    }
}

#[test]
fn fd_f32_outliers() {
    // Heavy-tailed inputs saturate the softmax; FD truncation error grows
    // with the third derivative, hence the looser scale.
    check_f32_case(8, 8, 16, false, 10, true, 2e-2);
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    dot / (na * nb).max(1e-30)
}

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt();
    let den: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    num / den.max(1e-30)
}

fn check_ste_case(causal: bool, seed: u64) {
    // STE property: the quantized-path gradients track the FD gradient of
    // the *unquantized* loss — the surrogate QAT descends on.
    let (nq, nk, d) = (16usize, 16usize, 16usize);
    let mut rng = Rng::new(seed);
    let mut q = rng.normal_vec(nq * d, 0.0, 1.0);
    let mut k = rng.normal_vec(nk * d, 0.0, 1.0);
    let mut v = rng.normal_vec(nk * d, 0.0, 1.0);
    let w = rng.normal_vec(nq * d, 0.0, 1.0);
    let t = attend_fp4_train(&q, &k, &v, nq, nk, d, causal);
    let g = flash_backward(
        &q, &k, &v, nq, nk, d, causal, &t.o, &t.o_prime, &t.lse, &w, QAT_SW,
    );
    let (fq, fk, fv) = fd_grads(&mut q, &mut k, &mut v, &w, nq, nk, d, causal);
    for (label, analytic, fd) in [("dq", &g.dq, &fq), ("dk", &g.dk, &fk), ("dv", &g.dv, &fv)] {
        let cos = cosine(analytic, fd);
        let rel = rel_l2(analytic, fd);
        assert!(cos > 0.9, "causal={causal} {label}: cosine {cos}");
        assert!(rel < 0.35, "causal={causal} {label}: relL2 {rel}");
    }
}

#[test]
fn fd_ste_full() {
    check_ste_case(false, 11);
}

#[test]
fn fd_ste_causal() {
    check_ste_case(true, 12);
}
