//! Figure 3, natively: the Attn-QAT vs drop-in training-dynamics ablation
//! with **no compiled artifacts and no XLA** — just the `qat` backward and
//! the `model` training stack.
//!
//! ```bash
//! cargo run --release --example fig3_native
//! # or, equivalently, through the experiment driver's native fallback:
//! cargo run --release -- exp fig3
//! ```
//!
//! Trains the same toy attention-regression problem under all four
//! backward ablations through `model::TrainSession` (the old
//! `qat::NativeTrainer` survives only as a deprecated shim over this) and
//! prints the grad-norm story: the matched packed-FP4 backward (Attn-QAT)
//! stays stable at a learning rate where the "drop-in" stock-FA backward
//! spikes and diverges.

use attn_qat::model::AttnRegressor;
use attn_qat::qat::{QatVariant, TrainerConfig};

fn main() {
    let steps = 150;
    println!("native Fig-3 ablation ({} steps, lr {}):\n", steps, TrainerConfig::default().lr);
    println!(
        "{:<40} {:>12} {:>14} {:>10}",
        "config", "final loss", "max grad-norm", "diverged"
    );
    for (label, variant) in [
        ("Attn-QAT", QatVariant::AttnQat),
        ("- High prec. O in BWD", QatVariant::NoHighPrecO),
        ("- Fake quant P in BWD", QatVariant::NoFqP),
        ("naive drop-in (FP4 fwd + stock bwd)", QatVariant::DropIn),
    ] {
        let mut t = AttnRegressor::session(TrainerConfig::default(), variant.config());
        t.run(steps, 0, |_| {});
        let final_loss = t.history.last().map(|m| m.loss).unwrap_or(f32::NAN);
        println!(
            "{:<40} {:>12.4} {:>14.3} {:>10}",
            label,
            final_loss,
            t.max_grad_norm(),
            t.diverged()
        );
    }
    println!("\n(the drop-in row is the paper's instability; see qat/ module docs)");
}
