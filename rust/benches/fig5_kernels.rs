//! Figure-5 bench: native packed-vs-dequant engine throughput, compiled
//! attention artifact throughput per variant and shape (the measured
//! half), plus the modeled RTX-5090 table.
//!
//! ```bash
//! cargo bench --bench fig5_kernels
//! ```

use attn_qat::attention::engine::pack_qkv_for_attention;
use attn_qat::attention::{AttnConfig, AttnEngine, Backend};
use attn_qat::bench::{bench_units, Reporter};
use attn_qat::config::Config;
use attn_qat::perfmodel::{speedup, Hw, Kernel};
use attn_qat::rng::Rng;
use attn_qat::runtime::{Runtime, Value};
use attn_qat::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let mut rep = Reporter::new("fig5_kernels");
    let mut rng = Rng::new(5);
    let quick = std::env::var("BENCH_QUICK").is_ok();

    // --- Native engines: packed-domain LUT kernels vs the legacy
    // dequantizing backend (same lattice, same outputs to fp tolerance),
    // both dispatched through `AttnEngine` — the backend is just config --
    let mut dequant_engine = AttnEngine::new(AttnConfig::fp4().with_backend(Backend::Dequant));
    let mut packed_engine = AttnEngine::new(AttnConfig::fp4());
    let native_seqs: &[usize] = if quick { &[128] } else { &[128, 256, 512] };
    for &n in native_seqs {
        let d = 64usize;
        let q = rng.normal_vec(n * d, 0.0, 1.0);
        let k = rng.normal_vec(n * d, 0.0, 1.0);
        let v = rng.normal_vec(n * d, 0.0, 1.0);
        let flops = 4.0 * (n * n * d) as f64;
        let iters = if n >= 512 { 3 } else { 5 };
        rep.push(bench_units(
            &format!("native_fp4_dequant_s{n}_d{d}"),
            1,
            iters,
            flops,
            "flop",
            || {
                let out = dequant_engine.forward(&q, &k, &v, 1, n, n, d);
                std::hint::black_box(out.o[0]);
            },
        ));
        rep.push(bench_units(
            &format!("native_fp4_packed_s{n}_d{d}"),
            1,
            iters,
            flops,
            "flop",
            || {
                let out = packed_engine.forward(&q, &k, &v, 1, n, n, d);
                std::hint::black_box(out.o[0]);
            },
        ));
        // Pure packed compute (quantization hoisted out, the engine's own
        // workspace reused): the steady-state kernel cost a resident KV
        // cache would see.
        let (qq, kq, vq) = pack_qkv_for_attention(&q, &k, &v, n, n, d);
        rep.push(bench_units(
            &format!("native_fp4_packed_prequant_s{n}_d{d}"),
            1,
            iters,
            flops,
            "flop",
            || {
                let out = packed_engine.forward_packed(&qq, &kq, &vq, n, n, d);
                std::hint::black_box(out.o[0]);
            },
        ));
    }

    // --- Compiled attention artifacts (need `make artifacts` + PJRT) ------
    match Runtime::new(&Runtime::default_dir()) {
        Ok(rt) => {
            let seqs: &[usize] = if quick { &[128, 256] } else { &[128, 256, 512, 1024] };
            for &d in &[64usize, 128] {
                for &n in seqs {
                    let (b, h) = (1usize, 4usize);
                    let numel = b * h * n * d;
                    let q = Tensor::new(vec![b, h, n, d], rng.normal_vec(numel, 0.0, 1.0))?;
                    let k = Tensor::new(vec![b, h, n, d], rng.normal_vec(numel, 0.0, 1.0))?;
                    let v = Tensor::new(vec![b, h, n, d], rng.normal_vec(numel, 0.0, 1.0))?;
                    for variant in ["f32", "fp4", "sage3"] {
                        let name = format!("attn_{variant}_s{n}_d{d}");
                        if rt.meta(&name).is_err() {
                            continue;
                        }
                        let inputs =
                            [Value::F32(q.clone()), Value::F32(k.clone()), Value::F32(v.clone())];
                        rt.run(&name, &inputs)?; // compile + warm
                        let flops = 4.0 * (b * h) as f64 * (n * n * d) as f64;
                        let iters = if n >= 1024 { 3 } else { 5 };
                        rep.push(bench_units(&name, 1, iters, flops, "flop", || {
                            rt.run(&name, &inputs).expect("run");
                        }));
                    }
                }
            }
            rep.save()?;
            // Also regenerate the results/ table via the experiment driver.
            attn_qat::experiments::kernels::fig5(&rt, &Config::default())?;
        }
        Err(e) => {
            eprintln!("skipping compiled-artifact benches: {e}");
            rep.save()?;
        }
    }

    // Modeled RTX-5090 speedup shape (the paper's headline numbers).
    let hw = Hw::default();
    println!("\nmodeled RTX-5090 speedups (batch 16, 16 heads):");
    println!("{:<18} {:>14} {:>14}", "shape", "QAT/Sage3", "QAT/FA2-BF16");
    for d in [64usize, 128] {
        for n in [1024usize, 4096, 16384] {
            println!(
                "hd={d:<4} seq={n:<6} {:>13.2}x {:>13.2}x",
                speedup(Kernel::AttnQat, Kernel::Sage3, &hw, 16, 16, n, d),
                speedup(Kernel::AttnQat, Kernel::Fa2Bf16, &hw, 16, 16, n, d)
            );
        }
    }
    Ok(())
}
