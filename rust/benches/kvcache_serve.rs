//! Serving-path bench: KV-cache append/gather hot loops and end-to-end
//! decode throughput of the FP4-KV server on the tiny model.

use attn_qat::attention::{AttnConfig, AttnEngine};
use attn_qat::bench::{bench_units, Reporter};
use attn_qat::kvcache::PagedKvCache;
use attn_qat::rng::Rng;
use attn_qat::runtime::{Runtime, Value};
use attn_qat::serve::{DecodeServer, Request};
use attn_qat::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let mut rep = Reporter::new("kvcache_serve");
    let mut rng = Rng::new(3);

    // KV cache: append (with page sealing) and gather.
    let d = 64;
    let tokens = 512;
    let kv: Vec<(Vec<f32>, Vec<f32>)> = (0..tokens)
        .map(|_| (rng.normal_vec(d, 0.0, 1.0), rng.normal_vec(d, 0.0, 1.0)))
        .collect();
    rep.push(bench_units(
        &format!("kv_append_seal_{tokens}tok_d{d}"),
        1,
        10,
        tokens as f64,
        "tok",
        || {
            let mut c = PagedKvCache::new(1, 1, d);
            c.add_seq(1);
            for (k, v) in &kv {
                c.append(1, 0, 0, k, v).unwrap();
            }
            std::hint::black_box(c.seq_len(1));
        },
    ));

    let mut cache = PagedKvCache::new(1, 1, d);
    cache.add_seq(1);
    for (k, v) in &kv {
        cache.append(1, 0, 0, k, v)?;
    }
    rep.push(bench_units(
        &format!("kv_gather_{tokens}tok_d{d}"),
        1,
        10,
        tokens as f64,
        "tok",
        || {
            let (k, _v) = cache.gather(1, 0, 0).unwrap();
            std::hint::black_box(k.len());
        },
    ));

    // Decode attention over the cache (1 query token), both paths as
    // engine configs: the materialising baseline (`AttnConfig::f32()` =
    // gather + f32) vs the fused packed-domain decode (`AttnConfig::fp4()`)
    // — the before/after record for the packed-kernel refactor.
    let q = rng.normal_vec(d, 0.0, 1.0);
    let mut baseline_engine = AttnEngine::new(AttnConfig::f32());
    let mut out_buf = vec![0.0f32; d];
    let baseline = bench_units(
        &format!("kv_decode_attend_{tokens}tok_d{d}"),
        1,
        10,
        1.0,
        "tok",
        || {
            baseline_engine.decode(&cache, 1, 0, &q, &mut out_buf).unwrap();
            std::hint::black_box(out_buf[0]);
        },
    );
    let baseline_ns = baseline.median_ns;
    rep.push(baseline);

    let mut fused_engine = AttnEngine::new(AttnConfig::fp4());
    let fused = bench_units(
        &format!("kv_decode_attend_fused_{tokens}tok_d{d}"),
        2,
        20,
        1.0,
        "tok",
        || {
            fused_engine.decode(&cache, 1, 0, &q, &mut out_buf).unwrap();
            std::hint::black_box(out_buf[0]);
        },
    );
    let fused_ns = fused.median_ns;
    rep.push(fused);
    println!(
        "fused attend_decode speedup vs gather+attend_f32 @ {tokens} tok: {:.2}x",
        baseline_ns / fused_ns
    );

    // Prompt ingestion: token-at-a-time decode (one fused `decode` per
    // arriving token) vs the batched multi-query `prefill` (append all,
    // one page-walk pass). Both closures rebuild the cache and append the
    // same ctx+prompt tokens, so the measured difference is the attention
    // path itself.
    let ctx = 192usize;
    let prompt = 64usize;
    let all_kv: Vec<(Vec<f32>, Vec<f32>)> = (0..ctx + prompt)
        .map(|_| (rng.normal_vec(d, 0.0, 1.0), rng.normal_vec(d, 0.0, 1.0)))
        .collect();
    let prompt_q = rng.normal_vec(prompt * d, 0.0, 1.0);
    let mut prefill_engine = AttnEngine::new(AttnConfig::fp4());
    let tokenwise = bench_units(
        &format!("kv_prefill_tokenwise_{prompt}q_d{d}"),
        1,
        5,
        prompt as f64,
        "tok",
        || {
            let mut c = PagedKvCache::new(1, 1, d);
            c.add_seq(1);
            for (k, v) in &all_kv[..ctx] {
                c.append(1, 0, 0, k, v).unwrap();
            }
            let mut out = vec![0.0f32; d];
            for (i, (k, v)) in all_kv[ctx..].iter().enumerate() {
                c.append(1, 0, 0, k, v).unwrap();
                prefill_engine
                    .decode(&c, 1, 0, &prompt_q[i * d..(i + 1) * d], &mut out)
                    .unwrap();
            }
            std::hint::black_box(out[0]);
        },
    );
    let tokenwise_ns = tokenwise.median_ns;
    rep.push(tokenwise);
    let batched = bench_units(
        &format!("kv_prefill_batched_{prompt}q_d{d}"),
        1,
        5,
        prompt as f64,
        "tok",
        || {
            let mut c = PagedKvCache::new(1, 1, d);
            c.add_seq(1);
            for (k, v) in &all_kv {
                c.append(1, 0, 0, k, v).unwrap();
            }
            let mut out = vec![0.0f32; prompt * d];
            let lse = prefill_engine.prefill(&c, 1, 0, &prompt_q, prompt, &mut out).unwrap();
            std::hint::black_box((out[0], lse[0]));
        },
    );
    let batched_ns = batched.median_ns;
    rep.push(batched);
    println!(
        "batched prefill speedup vs token-at-a-time decode @ {prompt} prompt tok over {ctx} ctx: {:.2}x",
        tokenwise_ns / batched_ns
    );

    // End-to-end decode server (needs core artifacts).
    if let Ok(rt) = Runtime::new(&Runtime::default_dir()) {
        if rt.meta("lm_embed_tiny").is_ok() {
            let names = rt.meta("lm_init_tiny")?.param_names();
            let params = rt.run("lm_init_tiny", &[Value::scalar_i32(1)])?;
            let weights: Vec<(String, Tensor)> = names.into_iter().zip(params).collect();
            // warmup/compile outside the measurement
            {
                let mut s = DecodeServer::new(&rt, "tiny", weights.clone())?;
                s.submit(Request {
                    id: 1,
                    prompt: b"C:ab#".to_vec(),
                    max_new_tokens: 2,
                    temperature: 0.0,
                    deadline_ms: None,
                    trace: Default::default(),
                });
                s.run()?;
            }
            let mut decoded = 0usize;
            let r = bench_units("serve_decode_8req_x16tok_tiny", 0, 3, 0.0, "", || {
                let mut s = DecodeServer::new(&rt, "tiny", weights.clone()).unwrap();
                for i in 0..8 {
                    s.submit(Request {
                        id: i + 1,
                        prompt: b"C:abcd#".to_vec(),
                        max_new_tokens: 16,
                        temperature: 0.0,
                        deadline_ms: None,
                        trace: Default::default(),
                    });
                }
                s.run().unwrap();
                decoded = s.stats.tokens_decoded;
            });
            let mut r = r;
            r.units_per_iter = decoded as f64;
            r.unit = "tok";
            rep.push(r);
        }
    }
    rep.save()?;
    Ok(())
}
