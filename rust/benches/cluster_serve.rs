//! Shard-scaling bench for the native decode cluster.
//!
//! One fixed request trace (prompts cut from the synthetic corpus, greedy
//! decoding) is served by 1 / 2 / 4 / 8 shard workers, once with the fused
//! packed-FP4 attention config and once with the `AttnConfig::f32()`
//! gather baseline — the per-shard-count A/B the cluster inherits from the
//! decode server. Rows land in `results/bench/cluster_serve.jsonl`:
//! aggregate tokens/s, worst-shard p50/p99 per-token latency, query-cache
//! hit totals, and KV memory peaks. On a multi-core host the multi-shard
//! fp4 rows should beat the single-shard row on tokens/s; the recorded
//! history is the scale-out before/after log.
//!
//! A faults scenario then serves the same trace through one injected
//! mid-decode shard panic (supervised respawn + journal replay) and
//! prices the recovery: tokens/s with 0 vs 1 panic, completions checked
//! bitwise against the clean run. A shared-prefix scenario serves 256
//! requests behind one 64-token system prompt with prefix sharing off vs
//! on and prices the sharing tier: fresh KV bytes per admitted sequence
//! and mean admission latency, completions again checked bitwise. The
//! headline numbers — scaling, tail latency, fault-recovery overhead,
//! and the prefix-sharing saving — are written to `BENCH_cluster.json`
//! at the repo root, the per-PR perf trajectory.

use std::io::Write;

use attn_qat::attention::AttnConfig;
use attn_qat::experiments::cluster::{
    demo_trace, serve_trace, serve_trace_faulty, serve_trace_observed, serve_trace_prefix,
    shared_prefix_trace,
};
use attn_qat::json::Json;
use attn_qat::serve::{FaultPlan, Request, SupervisorConfig};
use attn_qat::telemetry::{runmeta, Telemetry};

/// Headline summary path: the repo root, next to ROADMAP.md.
const HEADLINE_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_cluster.json");

struct Run {
    name: String,
    shards: usize,
    attn: &'static str,
    requests: usize,
    tokens: usize,
    wall_ms: f64,
    tok_per_s: f64,
    p50_token_ms: f64,
    p99_token_ms: f64,
    qcache_hits: u64,
    qcache_misses: u64,
    kv_bytes_peak: usize,
}

impl Run {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("shards", Json::Num(self.shards as f64)),
            ("attn", Json::Str(self.attn.to_string())),
            ("requests", Json::Num(self.requests as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("wall_ms", Json::Num(self.wall_ms)),
            ("tok_per_s", Json::Num(self.tok_per_s)),
            ("p50_token_ms", Json::Num(self.p50_token_ms)),
            ("p99_token_ms", Json::Num(self.p99_token_ms)),
            ("qcache_hits", Json::Num(self.qcache_hits as f64)),
            ("qcache_misses", Json::Num(self.qcache_misses as f64)),
            ("kv_bytes_peak", Json::Num(self.kv_bytes_peak as f64)),
        ])
    }
}

fn run_once(shards: usize, attn_name: &'static str, attn: AttnConfig, trace: &[Request]) -> Run {
    // serve_trace owns the spawn/submit/drain/verify loop (4 lanes, seed 7
    // for both weights and sampling — the same driver `exp cluster` uses).
    let (wall_s, stats) = serve_trace(shards, attn, 4, 7, trace).expect("cluster run");
    let wall_ms = wall_s * 1e3;
    let tokens = stats.total_tokens();
    let (hits, misses) = stats.qcache_totals();
    Run {
        name: format!("cluster_serve_{attn_name}_{shards}shards"),
        shards,
        attn: attn_name,
        requests: trace.len(),
        tokens,
        wall_ms,
        tok_per_s: tokens as f64 / (wall_ms * 1e-3).max(1e-9),
        p50_token_ms: stats.shards.iter().map(|s| s.p50_token_ms).fold(0.0, f64::max),
        p99_token_ms: stats.p99_token_ms(),
        qcache_hits: hits,
        qcache_misses: misses,
        kv_bytes_peak: stats.kv_bytes_peak(),
    }
}

fn main() -> anyhow::Result<()> {
    // The same deterministic trace `repro serve cluster` and `exp cluster`
    // drive (see experiments::cluster::demo_trace).
    let trace = demo_trace(48, 24, 7);
    println!("== bench group: cluster_serve ==");
    println!(
        "{:<32} {:>10} {:>12} {:>12} {:>12}",
        "name", "wall", "tok/s", "p50/tok", "p99/tok"
    );
    let mut rows = Vec::new();
    let mut fp4_single = None;
    for &shards in &[1usize, 2, 4, 8] {
        for (attn_name, attn) in [("fp4", AttnConfig::fp4()), ("f32", AttnConfig::f32())] {
            // One throwaway run warms allocators and the page pools.
            let _ = run_once(shards, attn_name, attn, &trace);
            let r = run_once(shards, attn_name, attn, &trace);
            println!(
                "{:<32} {:>8.1}ms {:>10.0}/s {:>10.3}ms {:>10.3}ms",
                r.name, r.wall_ms, r.tok_per_s, r.p50_token_ms, r.p99_token_ms
            );
            if attn_name == "fp4" {
                if shards == 1 {
                    fp4_single = Some(r.tok_per_s);
                } else if let Some(base) = fp4_single {
                    println!(
                        "  -> fp4 {shards}-shard scaling vs 1 shard: {:.2}x",
                        r.tok_per_s / base
                    );
                }
            }
            rows.push(r);
        }
    }

    // Faults scenario: the same 4-shard fp4 serve, clean vs one injected
    // mid-decode shard panic — what supervised recovery costs.
    let sup = SupervisorConfig::default();
    let (clean_s, clean_stats, clean_done) =
        serve_trace_faulty(4, AttnConfig::fp4(), 4, 7, &trace, FaultPlan::none(), sup)?;
    let target = clean_stats.shards.iter().max_by_key(|s| s.tokens).map(|s| s.shard).unwrap_or(0);
    let plan = FaultPlan::panic_at(target, 12);
    let (fault_s, fault_stats, fault_done) =
        serve_trace_faulty(4, AttnConfig::fp4(), 4, 7, &trace, plan, sup)?;
    assert!(fault_stats.restarts >= 1, "the injected panic must force a respawn");
    assert!(
        clean_done.len() == fault_done.len()
            && clean_done.iter().zip(&fault_done).all(|(a, b)| a.id == b.id && a.text == b.text),
        "faulted completions must be bitwise identical to the clean run"
    );
    let clean_tps = clean_stats.total_tokens() as f64 / clean_s.max(1e-9);
    let fault_tps = fault_stats.total_tokens() as f64 / fault_s.max(1e-9);
    println!(
        "cluster_serve_fp4_4shards faults: {:.0}/s clean vs {:.0}/s with 1 panic \
         ({:.2}x overhead, {} restart(s), {} request(s) replayed, {} passes recomputed)",
        clean_tps,
        fault_tps,
        clean_tps / fault_tps.max(1e-9),
        fault_stats.restarts,
        fault_stats.replayed_requests,
        fault_stats.recomputed_passes,
    );

    // Telemetry overhead guard: the same 4-shard fp4 serve with live
    // probes vs a disabled handle. Publishing is relaxed atomic stores off
    // the decode hot path and disabled spans are a single load, so the
    // instrumented run must stay within 3% of the dark one on tokens/s
    // (best-of-3 each, to shave scheduler noise).
    let best_tps = |make: &dyn Fn() -> Telemetry| -> anyhow::Result<f64> {
        let mut best = 0.0f64;
        for _ in 0..3 {
            let (wall_s, stats, _done, _snap) = serve_trace_observed(
                4,
                AttnConfig::fp4(),
                4,
                7,
                &trace,
                FaultPlan::none(),
                sup,
                make(),
            )?;
            best = best.max(stats.total_tokens() as f64 / wall_s.max(1e-9));
        }
        Ok(best)
    };
    let tps_tele_on = best_tps(&Telemetry::new)?;
    let tps_tele_off = best_tps(&Telemetry::disabled)?;
    let tele_overhead = tps_tele_off / tps_tele_on.max(1e-9);
    println!(
        "cluster_serve_fp4_4shards telemetry: {:.0}/s enabled vs {:.0}/s disabled \
         ({tele_overhead:.3}x overhead, guard <= 1.03x)",
        tps_tele_on, tps_tele_off,
    );

    // Shared-prefix scenario: 256 requests behind one 64-token system
    // prompt (4 sealed pages) with unique 16-token suffixes, served with
    // prefix sharing off vs on (4 shards, fp4). The headline is fresh KV
    // bytes per admitted sequence and mean admission latency; sharing is
    // only admissible if the completions stay bitwise identical.
    let ptrace = shared_prefix_trace(256, 64, 16, 8, 7);
    let run_prefix = |share: bool| {
        serve_trace_prefix(
            4,
            AttnConfig::fp4(),
            4,
            7,
            &ptrace,
            share,
            None,
            FaultPlan::none(),
            sup,
        )
    };
    let (_, prefix_off_stats, prefix_off_done) = run_prefix(false)?;
    let (_, prefix_on_stats, prefix_on_done) = run_prefix(true)?;
    assert!(
        prefix_off_done.len() == prefix_on_done.len()
            && prefix_off_done
                .iter()
                .zip(&prefix_on_done)
                .all(|(a, b)| a.id == b.id && a.text == b.text),
        "prefix sharing must be bitwise invisible"
    );
    let prefix_kv_off = prefix_off_stats.kv_admit_bytes_per_seq().unwrap_or(0.0);
    let prefix_kv_on = prefix_on_stats.kv_admit_bytes_per_seq().unwrap_or(f64::MAX);
    let prefix_admit_off = prefix_off_stats.admit_ms_mean().unwrap_or(0.0);
    let prefix_admit_on = prefix_on_stats.admit_ms_mean().unwrap_or(f64::MAX);
    let prefix_kv_saving = prefix_kv_off / prefix_kv_on.max(1e-9);
    let (prefix_hits, prefix_pages, prefix_bytes, prefix_cows) =
        prefix_on_stats.prefix_totals();
    println!(
        "cluster_serve_fp4_4shards prefix: {:.0} B/seq off vs {:.0} B/seq on \
         ({prefix_kv_saving:.2}x KV saving), admit {prefix_admit_off:.3} ms off vs \
         {prefix_admit_on:.3} ms on, {prefix_hits} hit(s), {prefix_pages} page(s) shared, \
         {prefix_bytes} B saved, {prefix_cows} COW split(s)",
        prefix_kv_off, prefix_kv_on,
    );
    assert!(
        prefix_kv_saving >= 2.0,
        "prefix sharing must at least halve fresh KV bytes/seq \
         ({prefix_kv_off:.0} off vs {prefix_kv_on:.0} on, {prefix_kv_saving:.2}x)"
    );
    assert!(
        prefix_admit_on < prefix_admit_off,
        "O(suffix) admission must beat O(prompt) \
         ({prefix_admit_on:.3} ms on vs {prefix_admit_off:.3} ms off)"
    );

    let meta = runmeta(
        "cluster_serve",
        &format!("requests={} max_new=24 seed=7 lanes=4 shards=1/2/4/8", trace.len()),
    );
    std::fs::create_dir_all("results/bench")?;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("results/bench/cluster_serve.jsonl")?;
    writeln!(f, "{meta}")?;
    for r in &rows {
        writeln!(f, "{}", r.to_json())?;
    }
    writeln!(
        f,
        "{}",
        Json::obj(vec![
            ("name", Json::Str("cluster_serve_fp4_4shards_telemetry_guard".to_string())),
            ("tok_per_s_enabled", Json::Num(tps_tele_on)),
            ("tok_per_s_disabled", Json::Num(tps_tele_off)),
            ("overhead_x", Json::Num(tele_overhead)),
            ("max_overhead_x", Json::Num(1.03)),
        ])
    )?;
    writeln!(
        f,
        "{}",
        Json::obj(vec![
            ("name", Json::Str("cluster_serve_fp4_4shards_prefix_share".to_string())),
            ("requests", Json::Num(ptrace.len() as f64)),
            ("kv_admit_bytes_per_seq_off", Json::Num(prefix_kv_off)),
            ("kv_admit_bytes_per_seq_on", Json::Num(prefix_kv_on)),
            ("kv_saving_x", Json::Num(prefix_kv_saving)),
            ("admit_ms_off", Json::Num(prefix_admit_off)),
            ("admit_ms_on", Json::Num(prefix_admit_on)),
            ("prefix_hits", Json::Num(prefix_hits as f64)),
            ("prefix_pages_shared", Json::Num(prefix_pages as f64)),
            ("prefix_bytes_saved", Json::Num(prefix_bytes as f64)),
            ("prefix_cow_splits", Json::Num(prefix_cows as f64)),
        ])
    )?;
    println!("-> results/bench/cluster_serve.jsonl ({} rows)", rows.len() + 2);
    assert!(
        tps_tele_on >= 0.97 * tps_tele_off,
        "telemetry overhead guard tripped: {tps_tele_on:.0} tok/s enabled vs \
         {tps_tele_off:.0} tok/s disabled ({tele_overhead:.3}x > 1.03x)"
    );

    // Headline summary at the repo root (overwritten each run: it is the
    // per-PR trajectory snapshot, the jsonl above is the full history).
    let find = |name: &str| rows.iter().find(|r| r.name == name);
    let tps_1 = find("cluster_serve_fp4_1shards").map_or(0.0, |r| r.tok_per_s);
    let tps_4 = find("cluster_serve_fp4_4shards").map_or(0.0, |r| r.tok_per_s);
    let p99_4 = find("cluster_serve_fp4_4shards").map_or(0.0, |r| r.p99_token_ms);
    let headline = Json::obj(vec![
        ("bench", Json::Str("cluster_serve".to_string())),
        ("runmeta", meta),
        ("requests", Json::Num(trace.len() as f64)),
        ("telemetry_tok_per_s_enabled", Json::Num(tps_tele_on)),
        ("telemetry_tok_per_s_disabled", Json::Num(tps_tele_off)),
        ("telemetry_overhead_x", Json::Num(tele_overhead)),
        ("fp4_tok_per_s_1shard", Json::Num(tps_1)),
        ("fp4_tok_per_s_4shard", Json::Num(tps_4)),
        ("fp4_scaling_4shard_x", Json::Num(tps_4 / tps_1.max(1e-9))),
        ("fp4_p99_token_ms_4shard", Json::Num(p99_4)),
        ("fault_clean_tok_per_s", Json::Num(clean_tps)),
        ("fault_1panic_tok_per_s", Json::Num(fault_tps)),
        ("fault_recovery_overhead_x", Json::Num(clean_tps / fault_tps.max(1e-9))),
        ("fault_restarts", Json::Num(fault_stats.restarts as f64)),
        ("fault_replayed_requests", Json::Num(fault_stats.replayed_requests as f64)),
        ("fault_recomputed_passes", Json::Num(fault_stats.recomputed_passes as f64)),
        ("prefix_kv_admit_bytes_per_seq_off", Json::Num(prefix_kv_off)),
        ("prefix_kv_admit_bytes_per_seq_on", Json::Num(prefix_kv_on)),
        ("prefix_kv_saving_x", Json::Num(prefix_kv_saving)),
        ("prefix_admit_ms_off", Json::Num(prefix_admit_off)),
        ("prefix_admit_ms_on", Json::Num(prefix_admit_on)),
        ("prefix_pages_shared", Json::Num(prefix_pages as f64)),
        ("prefix_bytes_saved", Json::Num(prefix_bytes as f64)),
    ]);
    std::fs::write(HEADLINE_PATH, format!("{headline}\n"))?;
    println!("-> {HEADLINE_PATH}");
    Ok(())
}
