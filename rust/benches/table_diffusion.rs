//! Diffusion pipeline bench (the Table-1/2 cost drivers): per-variant
//! train-step wall time (QAT overhead vs f32) and sampler-step time.

use attn_qat::bench::{bench_units, Reporter};
use attn_qat::coordinator::{LrSchedule, Trainer};
use attn_qat::data::latents::LatentGen;
use attn_qat::runtime::{Runtime, Value};
use attn_qat::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&Runtime::default_dir())?;
    let mut rep = Reporter::new("table_diffusion");
    let size = std::env::var("SIZE").unwrap_or_else(|_| "small".to_string());
    let variants: &[&str] = &["f32", "qat", "qat_smoothk", "qat_twolevel"];
    for variant in variants {
        let artifact = format!("diff_train_{variant}_{size}");
        if rt.meta(&artifact).is_err() {
            eprintln!("skipping {artifact} (export the exp artifact set)");
            continue;
        }
        let meta = rt.meta(&artifact)?;
        let batch = meta.usize_field("batch").unwrap();
        let model = meta.raw.get("model").clone();
        let frames = model.get("frames").as_usize().unwrap();
        let dl = model.get("latent_dim").as_usize().unwrap();
        let mut trainer = Trainer::new(
            &rt,
            &format!("diff_init_{size}"),
            &artifact,
            1,
            LrSchedule::Constant(1e-3),
        )?;
        let mut gen = LatentGen::new(1, frames, dl);
        let b = gen.next_batch(batch);
        let vals = b.values().to_vec();
        trainer.step(&vals)?; // warmup/compile
        rep.push(bench_units(
            &format!("diff_train_step_{variant}_{size}"),
            1,
            5,
            batch as f64,
            "clip",
            || {
                trainer.step(&vals).expect("step");
            },
        ));
    }

    // Sampler step per inference variant.
    for variant in ["f32", "fp4", "sage3"] {
        let artifact = format!("diff_sample_{variant}_{size}");
        if rt.meta(&artifact).is_err() {
            continue;
        }
        let meta = rt.meta(&artifact)?;
        let batch = meta.usize_field("batch").unwrap();
        let model = meta.raw.get("model").clone();
        let frames = model.get("frames").as_usize().unwrap();
        let dl = model.get("latent_dim").as_usize().unwrap();
        let params = rt.run(&format!("diff_init_{size}"), &[Value::scalar_i32(1)])?;
        let mut gen = LatentGen::new(2, frames, dl);
        let mut inputs: Vec<Value> = params.into_iter().map(Value::F32).collect();
        inputs.push(Value::F32(Tensor::new(vec![batch, frames, dl], gen.noise_batch(batch))?));
        inputs.push(Value::F32(Tensor::new(vec![batch], vec![1.0; batch])?));
        inputs.push(Value::F32(Tensor::new(vec![batch], vec![0.1; batch])?));
        rt.run(&artifact, &inputs)?;
        rep.push(bench_units(
            &format!("diff_sample_step_{variant}_{size}"),
            1,
            5,
            batch as f64,
            "clip",
            || {
                rt.run(&artifact, &inputs).expect("sample");
            },
        ));
    }
    rep.save()?;
    Ok(())
}
