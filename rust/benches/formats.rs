//! Numeric-format microbench: quantize / dequantize / fake-quant hot paths
//! (the L3-side §Perf targets — these run on the KV-cache seal path and in
//! the real-quant engine).

use attn_qat::bench::{bench_units, Reporter};
use attn_qat::formats::block::nvfp4_fake_quant_row;
use attn_qat::formats::PackedNvfp4;
use attn_qat::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rep = Reporter::new("formats");
    let mut rng = Rng::new(1);
    for &n in &[4096usize, 65536, 1 << 20] {
        let x = rng.normal_vec(n, 0.0, 2.0);
        let cols = 64;
        let rows = n / cols;

        rep.push(bench_units(
            &format!("nvfp4_quantize_pack_{n}"),
            2,
            10,
            n as f64,
            "elem",
            || {
                let p = PackedNvfp4::quantize(&x, rows, cols).unwrap();
                std::hint::black_box(p.memory_bytes());
            },
        ));

        let packed = PackedNvfp4::quantize(&x, rows, cols)?;
        rep.push(bench_units(
            &format!("nvfp4_dequantize_{n}"),
            2,
            10,
            n as f64,
            "elem",
            || {
                std::hint::black_box(packed.dequantize().len());
            },
        ));

        let mut row_buf = vec![0.0f32; cols];
        rep.push(bench_units(
            &format!("nvfp4_dequant_row_{n}"),
            2,
            10,
            n as f64,
            "elem",
            || {
                for r in 0..rows {
                    packed.dequant_row_into(r, &mut row_buf);
                }
                std::hint::black_box(row_buf[0]);
            },
        ));

        let mut y = x.clone();
        rep.push(bench_units(
            &format!("nvfp4_fake_quant_{n}"),
            2,
            10,
            n as f64,
            "elem",
            || {
                y.copy_from_slice(&x);
                for row in y.chunks_mut(16) {
                    nvfp4_fake_quant_row(row);
                }
                std::hint::black_box(y[0]);
            },
        ));
    }
    rep.save()?;
    Ok(())
}
