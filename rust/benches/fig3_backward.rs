//! Figure-3 backward bench: f32 recomputation vs packed-FP4 recomputation.
//!
//! Measures the native `qat::flash_backward` in its two recomputation
//! regimes (the drop-in stock-FA backward and the Attn-QAT matched
//! backward whose S/P rebuild runs in the packed 4-bit domain via the
//! byte-pair LUT), plus the training forward that produces the residuals,
//! plus the S-row recompute primitive both ways — per-pair
//! `lut::packed_row_dot` calls vs the batched `lut::packed_row_dots_into`
//! the backward now uses (the before/after of the ROADMAP "batch the
//! backward's per-row loops through the LUT block dots" lever).
//! Appends JSONL history to `results/bench/fig3_backward.jsonl`, same
//! format as `fig5_kernels`.
//!
//! ```bash
//! cargo bench --bench fig3_backward          # full shapes
//! BENCH_QUICK=1 cargo bench --bench fig3_backward
//! ```

use attn_qat::attention::engine::pack_qkv_for_attention;
use attn_qat::attention::{AttnConfig, AttnEngine, BwdSwitches};
use attn_qat::bench::{bench_units, Reporter};
use attn_qat::formats::lut;
use attn_qat::qat::flash_backward;
use attn_qat::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rep = Reporter::new("fig3_backward");
    let mut rng = Rng::new(3);
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let seqs: &[usize] = if quick { &[128] } else { &[128, 256] };

    const DROPIN: BwdSwitches = BwdSwitches::STOCK;
    const QAT: BwdSwitches = BwdSwitches::MATCHED;
    let mut f32_engine = AttnEngine::new(AttnConfig::f32());
    let mut qat_engine = AttnEngine::new(AttnConfig::attn_qat());

    for &n in seqs {
        let d = 64usize;
        let q = rng.normal_vec(n * d, 0.0, 1.0);
        let k = rng.normal_vec(n * d, 0.0, 1.0);
        let v = rng.normal_vec(n * d, 0.0, 1.0);
        let dout = rng.normal_vec(n * d, 0.0, 1.0);
        // Residuals once per shape; both backwards consume the same ones.
        let f32_res = f32_engine.forward(&q, &k, &v, 1, n, n, d);
        let train = qat_engine.forward_train(&q, &k, &v, 1, n, n, d);
        // 5 n×n×d matmuls in the backward (S, dV, dP, dQ, dK).
        let flops = 10.0 * (n * n * d) as f64;
        let iters = if n >= 256 { 3 } else { 5 };

        rep.push(bench_units(
            &format!("bwd_f32_recompute_s{n}_d{d}"),
            1,
            iters,
            flops,
            "flop",
            || {
                let g = flash_backward(
                    &q, &k, &v, n, n, d, false, &f32_res.o, &f32_res.o, &f32_res.lse, &dout,
                    DROPIN,
                );
                std::hint::black_box(g.dq[0]);
            },
        ));
        rep.push(bench_units(
            &format!("bwd_packed_recompute_s{n}_d{d}"),
            1,
            iters,
            flops,
            "flop",
            || {
                let g = flash_backward(
                    &q, &k, &v, n, n, d, false, &train.o, &train.o_prime, &train.lse, &dout, QAT,
                );
                std::hint::black_box(g.dq[0]);
            },
        ));
        // Training forward for context (2 n×n×d matmuls + O′).
        rep.push(bench_units(
            &format!("fwd_train_packed_s{n}_d{d}"),
            1,
            iters,
            6.0 * (n * n * d) as f64,
            "flop",
            || {
                let t = qat_engine.forward_train(&q, &k, &v, 1, n, n, d);
                std::hint::black_box(t.o[0]);
            },
        ));
        // S-row recompute primitive: per-pair row dots (the old backward
        // inner loop) vs one batched block-dot call per row (the new one).
        // Same bits out — the delta is pure setup-hoisting.
        let (q4, k4, _v4) = pack_qkv_for_attention(&q, &k, &v, n, n, d);
        let lut = lut::pair_dot();
        let mut s_row = vec![0.0f32; n];
        rep.push(bench_units(
            &format!("s_recompute_rowdot_s{n}_d{d}"),
            1,
            iters,
            2.0 * (n * n * d) as f64,
            "flop",
            || {
                for i in 0..n {
                    for (j, s) in s_row.iter_mut().enumerate() {
                        *s = lut::packed_row_dot(lut, &q4, i, &k4, j);
                    }
                    std::hint::black_box(s_row[0]);
                }
            },
        ));
        rep.push(bench_units(
            &format!("s_recompute_blockdot_s{n}_d{d}"),
            1,
            iters,
            2.0 * (n * n * d) as f64,
            "flop",
            || {
                for i in 0..n {
                    lut::packed_row_dots_into(lut, &q4, i, &k4, n, &mut s_row);
                    std::hint::black_box(s_row[0]);
                }
            },
        ));
    }
    rep.save()?;
    Ok(())
}
