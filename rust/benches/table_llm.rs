//! LLM train/eval-step bench (the Table-3/4 pipeline cost): per-variant
//! train-step and eval-step wall time on the small model, plus coordinator
//! overhead (literal round-trips vs artifact compute).

use attn_qat::bench::{bench_units, Reporter};
use attn_qat::coordinator::{LrSchedule, Trainer};
use attn_qat::data::corpus::Corpus;
use attn_qat::runtime::{Runtime, Value};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&Runtime::default_dir())?;
    let mut rep = Reporter::new("table_llm");
    let size = std::env::var("SIZE").unwrap_or_else(|_| "small".to_string());
    for variant in ["f32", "qat"] {
        let artifact = format!("lm_train_{variant}_{size}");
        if rt.meta(&artifact).is_err() {
            eprintln!("skipping {artifact} (export the exp artifact set)");
            continue;
        }
        let meta = rt.meta(&artifact)?;
        let batch = meta.usize_field("batch").unwrap();
        let seq = meta.raw.get("model").get("seq_len").as_usize().unwrap();
        let mut trainer = Trainer::new(
            &rt,
            &format!("lm_init_{size}"),
            &artifact,
            1,
            LrSchedule::Constant(1e-3),
        )?;
        let mut corpus = Corpus::new(1);
        let b = corpus.next_batch(batch, seq);
        let batch_vals = vec![b.token_value(), b.mask_value()];
        trainer.step(&batch_vals)?; // compile warmup
        let toks = (batch * seq) as f64;
        rep.push(bench_units(
            &format!("lm_train_step_{variant}_{size}"),
            1,
            5,
            toks,
            "tok",
            || {
                trainer.step(&batch_vals).expect("step");
            },
        ));

        // Eval step.
        let eval_art = format!(
            "lm_eval_{}_{size}",
            if variant == "f32" { "f32" } else { "fp4" }
        );
        let params = trainer.state.params.clone();
        let mut inputs: Vec<Value> = params.into_iter().map(Value::F32).collect();
        inputs.push(b.token_value());
        inputs.push(b.mask_value());
        rt.run(&eval_art, &inputs)?;
        rep.push(bench_units(
            &format!("lm_eval_step_{}_{size}", if variant == "f32" { "f32" } else { "fp4" }),
            1,
            5,
            toks,
            "tok",
            || {
                rt.run(&eval_art, &inputs).expect("eval");
            },
        ));
    }
    rep.save()?;
    Ok(())
}
