//! Train-step bench: native `QatModel` + `TrainSession` throughput.
//!
//! Measures whole optimizer steps (corpus batch → training forward →
//! per-layer QAT backward → Adam+clip update) in tokens/s across layer
//! counts, fp4 (Attn-QAT) vs the f32 baseline attention config, plus the
//! full-stack low-precision scenarios: microbatched steps (grad
//! accumulation amortizes the optimizer update), STE-quantized projection
//! GEMMs, and `LowPAdam` E4M3 moment state. Appends JSONL history to
//! `results/bench/train_step.jsonl` and writes the headline numbers
//! (single vs batched tokens/s, optimizer bytes/param) to
//! `BENCH_train.json` at the repo root.
//!
//! ```bash
//! cargo bench --bench train_step
//! BENCH_QUICK=1 cargo bench --bench train_step
//! ```

use attn_qat::attention::AttnConfig;
use attn_qat::bench::{bench_units, BenchResult, Reporter};
use attn_qat::json::Json;
use attn_qat::model::{
    LmTrainTask, ProjQuant, QatModel, QatModelConfig, TrainConfig, TrainSession, TrainableModel,
};

const HEADLINE_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_train.json");

fn model_cfg(layers: usize, attn: AttnConfig) -> QatModelConfig {
    QatModelConfig { layers, heads: 2, head_dim: 16, ff: 64, max_pos: 512, seed: 7, attn }
}

/// Bench one session configuration; `tokens_per_step` covers the whole
/// microbatch so tokens/s stays comparable across microbatch sizes.
fn bench_session(
    name: &str,
    mut session: TrainSession<LmTrainTask>,
    tokens_per_step: usize,
    iters: usize,
) -> (BenchResult, TrainSession<LmTrainTask>) {
    let r = bench_units(name, 1, iters, tokens_per_step as f64, "tok", || {
        let m = session.step();
        std::hint::black_box(m.loss);
    });
    (r, session)
}

fn main() -> anyhow::Result<()> {
    let mut rep = Reporter::new("train_step");
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let layer_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let seq = 48usize;
    let iters = if quick { 3 } else { 5 };

    for &layers in layer_counts {
        for (name, attn) in [("fp4", AttnConfig::attn_qat()), ("f32", AttnConfig::f32())] {
            let task = LmTrainTask::new(QatModel::new(model_cfg(layers, attn)), seq, 11);
            let session = TrainSession::new(task, TrainConfig::adam(5e-3));
            let (r, _) = bench_session(
                &format!("train_step_l{layers}_{name}_seq{seq}"),
                session,
                seq,
                iters,
            );
            rep.push(r);
        }
    }

    // Microbatching: short sequences make the per-step optimizer update a
    // visible fraction of the step, which grad accumulation amortizes.
    let mb_seq = 8usize;
    let attn = AttnConfig::attn_qat();
    let mut mb_tput = [0.0f64; 2];
    for (i, micro) in [1usize, 8].into_iter().enumerate() {
        let task = LmTrainTask::new(QatModel::new(model_cfg(2, attn)), mb_seq, 11);
        let session = TrainSession::new(task, TrainConfig::adam(5e-3).with_microbatch(micro));
        let (r, _) = bench_session(
            &format!("train_step_l2_fp4_seq{mb_seq}_mb{micro}"),
            session,
            mb_seq * micro,
            iters,
        );
        mb_tput[i] = r.throughput();
        rep.push(r);
    }

    // Full-stack low precision: STE projection quant + E4M3 moments.
    let mut opt_bytes = [0.0f64; 2]; // [adam, lowp_adam] bytes per param
    for (i, (name, lowp)) in [("adam", false), ("lowp", true)].into_iter().enumerate() {
        let mut model = QatModel::new(model_cfg(2, attn));
        if lowp {
            model.set_proj_quant(ProjQuant::ste());
        }
        let task = LmTrainTask::new(model, seq, 11);
        let tc = if lowp { TrainConfig::lowp_adam(5e-3, 0xbe7) } else { TrainConfig::adam(5e-3) };
        let session = TrainSession::new(task, tc);
        let name = format!("train_step_l2_fullstack_{name}_seq{seq}");
        let (r, mut s) = bench_session(&name, session, seq, iters);
        let mut n_params = 0usize;
        s.model.visit_params(&mut |w, _| n_params += w.len());
        opt_bytes[i] = s.optimizer_state_bytes() as f64 / n_params.max(1) as f64;
        rep.push(r);
    }

    // Headline summary for the repo root: batched-step speedup and the
    // optimizer-state footprint, the two numbers the issue tracks.
    let headline = Json::obj(vec![
        ("bench", Json::Str("train_step".into())),
        ("single_tok_per_s", Json::Num(mb_tput[0])),
        ("batched_mb8_tok_per_s", Json::Num(mb_tput[1])),
        ("batched_speedup", Json::Num(mb_tput[1] / mb_tput[0].max(1e-12))),
        ("adam_state_bytes_per_param", Json::Num(opt_bytes[0])),
        ("lowp_adam_state_bytes_per_param", Json::Num(opt_bytes[1])),
    ]);
    std::fs::write(HEADLINE_PATH, format!("{headline}\n"))?;
    println!("-> {HEADLINE_PATH}");

    rep.save()?;
    Ok(())
}
