//! Train-step bench: native `QatModel` + `TrainSession` throughput.
//!
//! Measures whole optimizer steps (corpus batch → training forward →
//! per-layer QAT backward → Adam+clip update) in tokens/s across layer
//! counts, fp4 (Attn-QAT) vs the f32 baseline attention config. Appends
//! JSONL history to `results/bench/train_step.jsonl`.
//!
//! ```bash
//! cargo bench --bench train_step
//! BENCH_QUICK=1 cargo bench --bench train_step
//! ```

use attn_qat::attention::AttnConfig;
use attn_qat::bench::{bench_units, Reporter};
use attn_qat::model::{LmTrainTask, QatModel, QatModelConfig, TrainConfig, TrainSession};

fn main() -> anyhow::Result<()> {
    let mut rep = Reporter::new("train_step");
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let layer_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let seq = 48usize;

    for &layers in layer_counts {
        for (name, attn) in [("fp4", AttnConfig::attn_qat()), ("f32", AttnConfig::f32())] {
            let cfg = QatModelConfig {
                layers,
                heads: 2,
                head_dim: 16,
                ff: 64,
                max_pos: 512,
                seed: 7,
                attn,
            };
            let task = LmTrainTask::new(QatModel::new(cfg), seq, 11);
            let mut session = TrainSession::new(task, TrainConfig::adam(5e-3));
            let iters = if quick { 3 } else { 5 };
            rep.push(bench_units(
                &format!("train_step_l{layers}_{name}_seq{seq}"),
                1,
                iters,
                seq as f64,
                "tok",
                || {
                    let m = session.step();
                    std::hint::black_box(m.loss);
                },
            ));
        }
    }
    rep.save()?;
    Ok(())
}
