//! Instruction tasks (Dolci-Instruct stand-in) and multiple-choice suites
//! (lm-eval-harness stand-ins).
//!
//! SFT task format (byte-level): `<OP>:<payload>#<answer>$` — the loss mask
//! covers `<answer>$` only, mirroring answer-only SFT. Ops:
//!
//! | op | answer                       | paper-benchmark proxy (Table 3) |
//! |----|------------------------------|---------------------------------|
//! | C  | copy payload                 | IFEval (instruction following)  |
//! | R  | reverse payload              | MATH-500 (symbol manipulation)  |
//! | U  | uppercase payload            | MMLU-Redux (rule application)   |
//! | S  | sort payload bytes           | GSM8K (algorithmic)             |
//! | Q  | value lookup in k=v list     | GPQA (retrieval + reasoning)    |
//!
//! Multiple-choice items (Table 4 proxies) are scored by ranking summed
//! continuation NLL with the compiled `lm_eval_*` artifact — the same
//! mechanism lm-eval-harness uses.

use crate::rng::Rng;

use super::LmBatch;

/// SFT task operations.
pub const SFT_OPS: [(u8, &str); 5] = [
    (b'C', "copy"),
    (b'R', "reverse"),
    (b'U', "upper"),
    (b'S', "sort"),
    (b'Q', "lookup"),
];

fn payload(rng: &mut Rng, len: usize) -> Vec<u8> {
    (0..len).map(|_| b'a' + rng.below(26) as u8).collect()
}

/// Generate one task; returns (prompt_bytes, answer_bytes).
pub fn gen_task(rng: &mut Rng, op: u8) -> (Vec<u8>, Vec<u8>) {
    match op {
        b'C' => {
            let len = 4 + rng.below(8);
            let p = payload(rng, len);
            (wrap(b'C', &p), p)
        }
        b'R' => {
            let len = 4 + rng.below(8);
            let p = payload(rng, len);
            let mut a = p.clone();
            a.reverse();
            (wrap(b'R', &p), a)
        }
        b'U' => {
            let len = 4 + rng.below(8);
            let p = payload(rng, len);
            let a = p.iter().map(|b| b.to_ascii_uppercase()).collect();
            (wrap(b'U', &p), a)
        }
        b'S' => {
            let len = 4 + rng.below(6);
            let p = payload(rng, len);
            let mut a = p.clone();
            a.sort();
            (wrap(b'S', &p), a)
        }
        b'Q' => {
            // payload: k1=v1,k2=v2,k3=v3 ; question: one of the keys.
            // Keys must be distinct or the answer is ambiguous.
            let n = 3;
            let mut keys = payload(rng, n);
            while keys[0] == keys[1] || keys[1] == keys[2] || keys[0] == keys[2] {
                keys = payload(rng, n);
            }
            let vals = payload(rng, n);
            let qi = rng.below(n);
            let mut p = Vec::new();
            for i in 0..n {
                p.push(keys[i]);
                p.push(b'=');
                p.push(vals[i]);
                p.push(b',');
            }
            p.push(b'?');
            p.push(keys[qi]);
            (wrap(b'Q', &p), vec![vals[qi]])
        }
        _ => panic!("unknown op"),
    }
}

fn wrap(op: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = vec![op, b':'];
    out.extend_from_slice(payload);
    out.push(b'#');
    out
}

/// Build an SFT batch: tasks packed into fixed windows with answer-only
/// loss masks; remainder padded with spaces (mask 0).
pub fn sft_batch(rng: &mut Rng, batch: usize, seq: usize) -> LmBatch {
    let mut tokens = Vec::with_capacity(batch * (seq + 1));
    let mut mask = Vec::with_capacity(batch * seq);
    for _ in 0..batch {
        let mut row = Vec::with_capacity(seq + 1);
        let mut row_mask = Vec::with_capacity(seq + 1);
        while row.len() < seq + 1 {
            let op = SFT_OPS[rng.below(SFT_OPS.len())].0;
            let (prompt, answer) = gen_task(rng, op);
            for &b in &prompt {
                row.push(b as i32);
                row_mask.push(0.0);
            }
            for &b in &answer {
                row.push(b as i32);
                row_mask.push(1.0);
            }
            row.push(b'$' as i32);
            row_mask.push(1.0);
        }
        row.truncate(seq + 1);
        row_mask.truncate(seq + 1);
        // Position t's mask refers to target token t+1: shift left.
        tokens.extend_from_slice(&row);
        mask.extend_from_slice(&row_mask[1..]);
    }
    LmBatch { batch, seq, tokens, mask }
}

/// One multiple-choice item: shared context, four continuations, index of
/// the correct one.
#[derive(Clone, Debug)]
pub struct McItem {
    pub context: Vec<u8>,
    pub choices: [Vec<u8>; 4],
    pub correct: usize,
}

/// The five benchmark suites (Table 4 proxies).
pub const MC_SUITES: [&str; 5] = ["topic", "markov", "copy", "sort", "lookup"];

/// Generate one item of the given suite.
pub fn gen_mc(rng: &mut Rng, suite: &str, corpus: &mut super::corpus::Corpus) -> McItem {
    match suite {
        // WinoGrande proxy: which topic byte closes the sentence?
        "topic" => {
            let mut ctx = Vec::new();
            corpus.sentence(&mut ctx);
            // ctx ends "<topic>. " — strip the closer, choices are topics.
            let topic = ctx[ctx.len() - 3];
            ctx.truncate(ctx.len() - 3);
            let mut choices = [vec![topic, b'.'], vec![], vec![], vec![]];
            for c in choices.iter_mut().skip(1) {
                loop {
                    let alt = b'A' + rng.below(26) as u8;
                    if alt != topic {
                        *c = vec![alt, b'.'];
                        break;
                    }
                }
            }
            shuffle_item(rng, ctx, choices)
        }
        // HellaSwag proxy: plausible vs shuffled Markov continuation.
        "markov" => {
            let stream = corpus.stream(48);
            let (ctx, cont) = stream.split_at(32);
            let good = cont.to_vec();
            let mut choices = [good.clone(), good.clone(), good.clone(), good];
            for c in choices.iter_mut().skip(1) {
                rng.shuffle(c);
            }
            shuffle_item(rng, ctx.to_vec(), choices)
        }
        // IFEval/ARC proxy: correct copy vs corrupted copies.
        "copy" => {
            let (prompt, answer) = gen_task(rng, b'C');
            let mut choices = [answer.clone(), answer.clone(), answer.clone(), answer];
            for c in choices.iter_mut().skip(1) {
                corrupt(rng, c);
            }
            shuffle_item(rng, prompt, choices)
        }
        // GSM8K/PIQA proxy: correctly sorted vs corrupted.
        "sort" => {
            let (prompt, answer) = gen_task(rng, b'S');
            let mut choices = [answer.clone(), answer.clone(), answer.clone(), answer];
            for c in choices.iter_mut().skip(1) {
                corrupt(rng, c);
            }
            shuffle_item(rng, prompt, choices)
        }
        // MMLU proxy: key-value lookup with distractor values.
        "lookup" => {
            let (prompt, answer) = gen_task(rng, b'Q');
            let mut choices = [answer.clone(), answer.clone(), answer.clone(), answer];
            for c in choices.iter_mut().skip(1) {
                corrupt(rng, c);
            }
            shuffle_item(rng, prompt, choices)
        }
        _ => panic!("unknown suite {suite}"),
    }
}

fn corrupt(rng: &mut Rng, bytes: &mut [u8]) {
    if bytes.is_empty() {
        return;
    }
    loop {
        let i = rng.below(bytes.len());
        let replacement = b'a' + rng.below(26) as u8;
        if bytes[i] != replacement {
            bytes[i] = replacement;
            return;
        }
    }
}

fn shuffle_item(rng: &mut Rng, context: Vec<u8>, mut choices: [Vec<u8>; 4]) -> McItem {
    let mut order = [0usize, 1, 2, 3];
    rng.shuffle(&mut order);
    let correct = order.iter().position(|&o| o == 0).unwrap();
    choices = [
        choices[order[0]].clone(),
        choices[order[1]].clone(),
        choices[order[2]].clone(),
        choices[order[3]].clone(),
    ];
    McItem { context, choices, correct }
}

/// Render an MC (context, choice) pair into an eval row: tokens padded to
/// `seq`+1, mask covering only the continuation positions.
pub fn mc_row(item: &McItem, choice: usize, seq: usize) -> (Vec<i32>, Vec<f32>) {
    let mut row: Vec<i32> = item.context.iter().map(|&b| b as i32).collect();
    let ctx_len = row.len();
    row.extend(item.choices[choice].iter().map(|&b| b as i32));
    row.truncate(seq + 1);
    let used = row.len();
    row.resize(seq + 1, b' ' as i32);
    // Mask targets: position t predicts token t+1. Continuation tokens sit
    // at [ctx_len, used); they are targets of positions [ctx_len-1, used-1).
    let mut mask = vec![0.0f32; seq];
    for t in ctx_len.saturating_sub(1)..used.saturating_sub(1).min(seq) {
        mask[t] = 1.0;
    }
    (row, mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Corpus;

    #[test]
    fn tasks_have_correct_answers() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let (p, a) = gen_task(&mut rng, b'R');
            let payload: Vec<u8> = p[2..p.len() - 1].to_vec();
            let mut rev = payload.clone();
            rev.reverse();
            assert_eq!(a, rev);
            let (_, a) = gen_task(&mut rng, b'S');
            assert!(a.windows(2).all(|w| w[0] <= w[1]));
        }
    }

    #[test]
    fn lookup_answers_match() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let (p, a) = gen_task(&mut rng, b'Q');
            // prompt: Q:k=v,k=v,k=v,?<key>#
            let s = &p[2..p.len() - 1];
            let qpos = s.iter().position(|&b| b == b'?').unwrap();
            let key = s[qpos + 1];
            let mut found = None;
            for chunk in s[..qpos].split(|&b| b == b',') {
                if chunk.len() == 3 && chunk[0] == key {
                    found = Some(chunk[2]);
                }
            }
            assert_eq!(found, Some(a[0]));
        }
    }

    #[test]
    fn sft_batch_mask_covers_answers_only() {
        let mut rng = Rng::new(3);
        let b = sft_batch(&mut rng, 2, 128);
        assert_eq!(b.tokens.len(), 2 * 129);
        assert_eq!(b.mask.len(), 2 * 128);
        let frac: f32 = b.mask.iter().sum::<f32>() / b.mask.len() as f32;
        assert!(frac > 0.15 && frac < 0.8, "answer fraction {frac}");
    }

    #[test]
    fn mc_items_unique_correct() {
        let mut rng = Rng::new(4);
        let mut corpus = Corpus::new(4);
        for suite in MC_SUITES {
            let item = gen_mc(&mut rng, suite, &mut corpus);
            let correct = &item.choices[item.correct];
            let dups = item
                .choices
                .iter()
                .enumerate()
                .filter(|(i, c)| *i != item.correct && *c == correct)
                .count();
            assert_eq!(dups, 0, "suite {suite} has duplicate correct answer");
        }
    }

    #[test]
    fn mc_row_mask_bounds() {
        let mut rng = Rng::new(5);
        let mut corpus = Corpus::new(5);
        let item = gen_mc(&mut rng, "topic", &mut corpus);
        let (row, mask) = mc_row(&item, 0, 64);
        assert_eq!(row.len(), 65);
        assert_eq!(mask.len(), 64);
        assert!(mask.iter().sum::<f32>() >= 1.0);
    }
}
