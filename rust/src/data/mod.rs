//! Synthetic data pipeline (substrate; replaces C4 / Dolci / Wan latents).
//!
//! Everything the experiments train and evaluate on is generated here, in
//! Rust, on the request path — deterministically from config seeds:
//!
//! * [`corpus`]  — a byte-level synthetic language (Markov filler + PCFG-ish
//!   sentences with **long-range topic recall**, so attention quality is
//!   measurable) standing in for C4 continued-pretraining data.
//! * [`tasks`]   — instruction tasks (copy/reverse/case/sort/lookup) with
//!   answer-masked SFT batches standing in for Dolci-Instruct, plus five
//!   multiple-choice suites standing in for the lm-eval-harness benchmarks.
//! * [`latents`] — smooth low-rank "video" latent trajectories standing in
//!   for Wan-2.1 latents, with known structure the VBench-proxy metrics in
//!   `eval::video` can measure.

pub mod corpus;
pub mod latents;
pub mod tasks;

use crate::runtime::Value;
use crate::tensor::Tensor;

/// One LM training/eval batch: `tokens (B, N+1) i32` + `loss_mask (B, N)`.
#[derive(Clone, Debug)]
pub struct LmBatch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
}

impl LmBatch {
    pub fn token_value(&self) -> Value {
        Value::I32(self.tokens.clone(), vec![self.batch, self.seq + 1])
    }

    pub fn mask_value(&self) -> Value {
        Value::F32(
            Tensor::new(vec![self.batch, self.seq], self.mask.clone()).expect("mask shape"),
        )
    }
}

/// One diffusion batch: clean latents + noise + times.
#[derive(Clone, Debug)]
pub struct DiffBatch {
    pub batch: usize,
    pub frames: usize,
    pub latent_dim: usize,
    pub x0: Vec<f32>,
    pub noise: Vec<f32>,
    pub t: Vec<f32>,
}

impl DiffBatch {
    pub fn values(&self) -> [Value; 3] {
        let shape = vec![self.batch, self.frames, self.latent_dim];
        [
            Value::F32(Tensor::new(shape.clone(), self.x0.clone()).expect("x0")),
            Value::F32(Tensor::new(shape, self.noise.clone()).expect("noise")),
            Value::F32(Tensor::new(vec![self.batch], self.t.clone()).expect("t")),
        ]
    }
}
