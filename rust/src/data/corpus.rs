//! Synthetic byte-level language (the C4 stand-in).
//!
//! Design goals (DESIGN.md §2): the language must (a) be learnable by a
//! small transformer, (b) contain **long-range dependencies routed through
//! attention** so that degrading attention precision measurably degrades
//! the model, and (c) be cheap to generate deterministically.
//!
//! A document is a stream of sentences. Each sentence:
//!
//! ```text
//! <TOPIC> <body: order-1 Markov chain over a-z, 8..24 bytes> <TOPIC> .
//! ```
//!
//! The closing byte must equal the opening topic (A–Z) — pure long-range
//! recall. Interleaved "copy clauses" `x=<payload>;y=<payload>;` add exact
//! multi-byte copying. The Markov transition matrix is itself sampled per
//! language seed, giving dense local statistics the model must also learn.

use crate::rng::Rng;

use super::LmBatch;

const TOPICS: std::ops::Range<u8> = 65..91; // 'A'..='Z'
const LOWER: std::ops::Range<u8> = 97..123; // 'a'..='z'
const N_LOWER: usize = 26;

/// Deterministic generator for one synthetic language.
pub struct Corpus {
    /// Row-stochastic order-1 transition weights over a-z.
    trans: Vec<f32>,
    rng: Rng,
}

impl Corpus {
    /// Build the language for `seed` (transition matrix is part of the
    /// language identity; the same seed always yields the same language).
    pub fn new(seed: u64) -> Corpus {
        let mut lang_rng = Rng::new(seed).split("language");
        // Sparse-ish random transition matrix: each state prefers ~4 peers.
        let mut trans = vec![0.05f32; N_LOWER * N_LOWER];
        for i in 0..N_LOWER {
            for _ in 0..4 {
                let j = lang_rng.below(N_LOWER);
                trans[i * N_LOWER + j] += 2.0 + lang_rng.uniform() * 3.0;
            }
        }
        Corpus { trans, rng: Rng::new(seed).split("stream") }
    }

    fn markov_body(&mut self, len: usize, out: &mut Vec<u8>) {
        let mut state = self.rng.below(N_LOWER);
        for _ in 0..len {
            out.push(LOWER.start + state as u8);
            let row = &self.trans[state * N_LOWER..(state + 1) * N_LOWER];
            state = self.rng.categorical(row);
        }
    }

    /// Append one sentence to `out`.
    pub fn sentence(&mut self, out: &mut Vec<u8>) {
        let topic = TOPICS.start + self.rng.below(26) as u8;
        out.push(topic);
        out.push(b' ');
        let len = 8 + self.rng.below(17);
        self.markov_body(len, out);
        out.push(b' ');
        // Occasional copy clause: exact long-range copying.
        if self.rng.uniform() < 0.3 {
            let plen = 3 + self.rng.below(5);
            let start = out.len();
            out.extend_from_slice(b"x=");
            self.markov_body(plen, out);
            let payload: Vec<u8> = out[start + 2..].to_vec();
            out.extend_from_slice(b";y=");
            out.extend_from_slice(&payload);
            out.extend_from_slice(b"; ");
        }
        out.push(topic); // long-range recall target
        out.push(b'.');
        out.push(b' ');
    }

    /// Generate a contiguous token stream of at least `n` bytes.
    pub fn stream(&mut self, n: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n + 64);
        while out.len() < n {
            self.sentence(&mut out);
        }
        out.truncate(n);
        out
    }

    /// Next LM batch of `batch` windows of `seq`+1 tokens (targets shifted).
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> LmBatch {
        let mut tokens = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let w = self.stream(seq + 1);
            tokens.extend(w.iter().map(|&b| b as i32));
        }
        LmBatch { batch, seq, tokens, mask: vec![1.0; batch * seq] }
    }
}

/// Fraction of sentences whose closing topic byte matches the opener —
/// used by tests and by the corpus-quality eval.
pub fn topic_recall_consistency(stream: &[u8]) -> f32 {
    let mut total = 0usize;
    let mut ok = 0usize;
    let mut i = 0;
    while i < stream.len() {
        if TOPICS.contains(&stream[i]) && i + 2 < stream.len() && stream[i + 1] == b' ' {
            // opener; find the ". " terminator
            let mut j = i + 2;
            while j + 1 < stream.len() && stream[j + 1] != b'.' {
                j += 1;
            }
            if j + 1 < stream.len() {
                total += 1;
                if stream[j] == stream[i] {
                    ok += 1;
                }
                i = j + 2;
                continue;
            }
        }
        i += 1;
    }
    if total == 0 {
        0.0
    } else {
        ok as f32 / total as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = Corpus::new(5).stream(512);
        let b = Corpus::new(5).stream(512);
        let c = Corpus::new(6).stream(512);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn byte_range_is_printable() {
        let s = Corpus::new(1).stream(4096);
        assert!(s.iter().all(|&b| (32..127).contains(&b)), "non-printable byte");
    }

    #[test]
    fn topics_close_consistently() {
        let mut c = Corpus::new(2);
        let mut out = Vec::new();
        for _ in 0..200 {
            c.sentence(&mut out);
        }
        let consistency = topic_recall_consistency(&out);
        assert!(consistency > 0.95, "consistency {consistency}");
    }

    #[test]
    fn batch_shapes() {
        let b = Corpus::new(3).next_batch(4, 64);
        assert_eq!(b.tokens.len(), 4 * 65);
        assert_eq!(b.mask.len(), 4 * 64);
        assert!(b.tokens.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn copy_clauses_copy() {
        let s = Corpus::new(4).stream(20_000);
        let text = String::from_utf8(s).unwrap();
        let mut found = 0;
        for (i, _) in text.match_indices("x=") {
            if let Some(semi) = text[i..].find(";y=") {
                let payload = &text[i + 2..i + semi];
                let after = &text[i + semi + 3..];
                if after.starts_with(payload) {
                    found += 1;
                }
            }
        }
        assert!(found > 10, "copy clauses found: {found}");
    }
}
