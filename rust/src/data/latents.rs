//! Synthetic "video" latents (Wan-2.1 latent stand-in).
//!
//! Each sample is a (frames × latent_dim) trajectory with known structure:
//!
//! ```text
//! x_t = background + Σ_{r<R} amp_r · u_r · sin(ω_r·t + φ_r) + σ·ε_t
//! ```
//!
//! * `background` — a static unit-norm vector ("subject/background")
//! * `u_r`        — orthogonal-ish motion directions, smooth sinusoidal
//!                  time courses ("motion")
//! * `ε_t`        — small per-frame noise ("texture")
//!
//! The generator's parameters are known, so `eval::video` can measure the
//! VBench-proxy axes directly: consistency = stability of the background
//! component, flicker = high-frequency temporal energy, dynamic degree =
//! motion amplitude, imaging quality = distance to the low-rank manifold.

use crate::rng::Rng;

use super::DiffBatch;

/// Motion components per sample.
pub const MOTION_RANK: usize = 3;
/// Per-frame texture noise level.
pub const TEXTURE_SIGMA: f32 = 0.05;
/// Leading latent dims carrying large-magnitude static content. These give
/// the model heavy-tailed activations — the regime the paper identifies as
/// what makes attention hard to quantize (§1).
pub const OUTLIER_DIMS: usize = 2;
pub const OUTLIER_SCALE: f32 = 5.0;

/// Generator over (frames × latent_dim) trajectories.
pub struct LatentGen {
    pub frames: usize,
    pub latent_dim: usize,
    rng: Rng,
}

impl LatentGen {
    pub fn new(seed: u64, frames: usize, latent_dim: usize) -> LatentGen {
        LatentGen { frames, latent_dim, rng: Rng::new(seed).split("latents") }
    }

    /// One trajectory, row-major (frames, latent_dim).
    pub fn sample(&mut self) -> Vec<f32> {
        let (t_n, d) = (self.frames, self.latent_dim);
        let mut bg = self.rng.normal_vec(d, 0.0, 1.0);
        normalize(&mut bg);
        for j in 0..OUTLIER_DIMS.min(d) {
            bg[j] *= OUTLIER_SCALE; // heavy-tailed static channels
        }
        let mut dirs = Vec::with_capacity(MOTION_RANK);
        let mut amps = Vec::with_capacity(MOTION_RANK);
        let mut omegas = Vec::with_capacity(MOTION_RANK);
        let mut phases = Vec::with_capacity(MOTION_RANK);
        for _ in 0..MOTION_RANK {
            let mut u = self.rng.normal_vec(d, 0.0, 1.0);
            normalize(&mut u);
            dirs.push(u);
            amps.push(self.rng.range_f32(0.2, 0.7));
            omegas.push(self.rng.range_f32(0.15, 0.6));
            phases.push(self.rng.range_f32(0.0, std::f32::consts::TAU));
        }
        let mut out = Vec::with_capacity(t_n * d);
        for t in 0..t_n {
            for j in 0..d {
                let mut v = bg[j];
                for r in 0..MOTION_RANK {
                    v += amps[r] * dirs[r][j] * (omegas[r] * t as f32 + phases[r]).sin();
                }
                v += TEXTURE_SIGMA * self.rng.normal();
                out.push(v);
            }
        }
        out
    }

    /// Next diffusion training batch (x0, fresh noise, uniform t).
    pub fn next_batch(&mut self, batch: usize) -> DiffBatch {
        let n = self.frames * self.latent_dim;
        let mut x0 = Vec::with_capacity(batch * n);
        for _ in 0..batch {
            x0.extend(self.sample());
        }
        let noise = self.rng.normal_vec(batch * n, 0.0, 1.0);
        let t = (0..batch).map(|_| self.rng.uniform()).collect();
        DiffBatch {
            batch,
            frames: self.frames,
            latent_dim: self.latent_dim,
            x0,
            noise,
            t,
        }
    }

    /// Pure-noise batch for sampling (x drawn from N(0,1), t unset).
    pub fn noise_batch(&mut self, batch: usize) -> Vec<f32> {
        self.rng.normal_vec(batch * self.frames * self.latent_dim, 0.0, 1.0)
    }
}

fn normalize(v: &mut [f32]) {
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
    for x in v {
        *x /= n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = LatentGen::new(7, 16, 8).sample();
        let b = LatentGen::new(7, 16, 8).sample();
        assert_eq!(a, b);
    }

    #[test]
    fn smoothness_beats_noise() {
        // Adjacent-frame distance must be far below distance of shuffled
        // frames — i.e. trajectories are temporally smooth.
        let mut g = LatentGen::new(1, 32, 16);
        let x = g.sample();
        let d = 16;
        let mut adj = 0.0f32;
        let mut far = 0.0f32;
        for t in 0..31 {
            for j in 0..d {
                adj += (x[(t + 1) * d + j] - x[t * d + j]).powi(2);
                far += (x[((t + 16) % 32) * d + j] - x[t * d + j]).powi(2);
            }
        }
        assert!(adj < far * 0.5, "adj {adj} far {far}");
    }

    #[test]
    fn batch_shapes() {
        let mut g = LatentGen::new(2, 8, 4);
        let b = g.next_batch(3);
        assert_eq!(b.x0.len(), 3 * 8 * 4);
        assert_eq!(b.noise.len(), 3 * 8 * 4);
        assert_eq!(b.t.len(), 3);
        assert!(b.t.iter().all(|&t| (0.0..1.0).contains(&t)));
    }
}
