//! PJRT runtime: load AOT artifacts (HLO text + metadata) and execute them.
//!
//! The only bridge to the build-time python world. `make artifacts` drops
//! `<name>.hlo.txt` + `<name>.meta.json` pairs in `artifacts/`; this module
//! compiles them on the PJRT CPU client (lazily, cached) and exposes a
//! typed execute API over [`crate::tensor::Tensor`].
//!
//! Interchange notes (see DESIGN.md §5): HLO **text** is required — jax ≥
//! 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids. Artifacts are lowered with
//! `return_tuple=True`, so every execution returns one tuple literal that
//! we decompose.

pub mod registry;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;
pub use registry::{ArtifactMeta, Registry, TensorSpec};

/// Lazily-compiling executor over an artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    registry: Registry,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    dir: PathBuf,
}

/// A host-side value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    pub fn scalar_f32(v: f32) -> Value {
        Value::F32(Tensor::scalar(v))
    }

    pub fn scalar_i32(v: i32) -> Value {
        Value::I32(vec![v], vec![])
    }

    pub fn tensor(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(..) => bail!("expected f32 value"),
        }
    }
}

impl Runtime {
    /// Create a runtime over `dir` (usually `artifacts/`).
    pub fn new(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let registry = Registry::load(dir)?;
        Ok(Runtime {
            client,
            registry,
            cache: RefCell::new(HashMap::new()),
            dir: dir.to_path_buf(),
        })
    }

    /// Artifact directory default: `$REPRO_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var("REPRO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.registry
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}' (run `make artifacts`?)"))
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on host values; returns one Tensor per output.
    ///
    /// Inputs are validated against the artifact metadata (count, shape,
    /// dtype) before hitting PJRT so shape bugs fail with names attached.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let meta = self.meta(name)?.clone();
        if inputs.len() != meta.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        // Upload inputs as device buffers and run through `execute_b`:
        // the literal-based `execute` entry point leaks its temporary
        // device buffers (~state-size per call — see EXPERIMENTS.md §Perf),
        // and buffer upload also skips one host copy.
        let mut buffers = Vec::with_capacity(inputs.len());
        for (v, spec) in inputs.iter().zip(&meta.inputs) {
            buffers.push(
                self.to_buffer(v, spec)
                    .with_context(|| format!("{name}:{}", spec.name))?,
            );
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple {name}: {e}"))?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "{name}: expected {} outputs, got {}",
                meta.outputs.len(),
                parts.len()
            );
        }
        parts
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, spec)| from_literal(&lit, spec))
            .collect()
    }
}

impl Runtime {
    fn to_buffer(&self, v: &Value, spec: &TensorSpec) -> Result<xla::PjRtBuffer> {
        match (v, spec.dtype.as_str()) {
            (Value::F32(t), "float32") => {
                if t.shape != spec.shape {
                    bail!("shape {:?} != expected {:?}", t.shape, spec.shape);
                }
                self.client
                    .buffer_from_host_buffer(&t.data, &spec.shape, None)
                    .map_err(|e| anyhow!("upload f32: {e}"))
            }
            (Value::I32(data, shape), "int32") => {
                if *shape != spec.shape {
                    bail!("shape {:?} != expected {:?}", shape, spec.shape);
                }
                self.client
                    .buffer_from_host_buffer(&data[..], &spec.shape, None)
                    .map_err(|e| anyhow!("upload i32: {e}"))
            }
            (v, dt) => bail!("dtype mismatch: host {:?} vs artifact {}", kind(v), dt),
        }
    }
}

fn kind(v: &Value) -> &'static str {
    match v {
        Value::F32(..) => "f32",
        Value::I32(..) => "i32",
    }
}

fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Tensor> {
    let data: Vec<f32> = match spec.dtype.as_str() {
        "float32" => lit.to_vec::<f32>().map_err(|e| anyhow!("read f32: {e}"))?,
        "int32" => lit
            .to_vec::<i32>()
            .map_err(|e| anyhow!("read i32: {e}"))?
            .into_iter()
            .map(|x| x as f32)
            .collect(),
        other => bail!("unsupported output dtype {other}"),
    };
    Tensor::new(spec.shape.clone(), data)
}
