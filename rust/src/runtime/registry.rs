//! Artifact registry: parses the `*.meta.json` files `aot.py` emits and
//! exposes typed metadata (ordered input/output specs + the free-form
//! config blob each builder attached).

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::json::Json;

/// Shape + dtype of one named artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: v
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("spec missing name"))?
                .to_string(),
            shape: v
                .get("shape")
                .as_arr()
                .ok_or_else(|| anyhow!("spec missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
            dtype: v
                .get("dtype")
                .as_str()
                .ok_or_else(|| anyhow!("spec missing dtype"))?
                .to_string(),
        })
    }
}

/// Parsed `<name>.meta.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// The whole metadata object (kind/size/variant/model config/...).
    pub raw: Json,
}

impl ArtifactMeta {
    pub fn kind(&self) -> &str {
        self.raw.get("kind").as_str().unwrap_or("")
    }

    pub fn str_field(&self, key: &str) -> Option<&str> {
        self.raw.get(key).as_str()
    }

    pub fn usize_field(&self, key: &str) -> Option<usize> {
        self.raw.get(key).as_usize()
    }

    /// Names of the model parameters, in artifact input order.
    pub fn param_names(&self) -> Vec<String> {
        self.raw
            .get("param_names")
            .as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default()
    }

    pub fn opt_names(&self) -> Vec<String> {
        self.raw
            .get("opt_names")
            .as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default()
    }

    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("artifact {} has no input '{name}'", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("artifact {} has no output '{name}'", self.name))
    }
}

/// All artifacts in a directory.
pub struct Registry {
    metas: BTreeMap<String, ArtifactMeta>,
}

impl Registry {
    pub fn load(dir: &Path) -> Result<Registry> {
        let mut metas = BTreeMap::new();
        if !dir.exists() {
            bail!(
                "artifact directory {} missing — run `make artifacts`",
                dir.display()
            );
        }
        for entry in fs::read_dir(dir).with_context(|| format!("read {}", dir.display()))? {
            let path = entry?.path();
            let fname = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
            if !fname.ends_with(".meta.json") {
                continue;
            }
            let text = fs::read_to_string(&path)?;
            let v = Json::parse(&text).with_context(|| format!("parse {}", path.display()))?;
            let meta = parse_meta(&v).with_context(|| format!("meta {}", path.display()))?;
            metas.insert(meta.name.clone(), meta);
        }
        Ok(Registry { metas })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.metas.keys().map(|s| s.as_str())
    }

    /// All artifacts whose metadata `kind` matches.
    pub fn by_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a ArtifactMeta> {
        self.metas.values().filter(move |m| m.kind() == kind)
    }

    pub fn len(&self) -> usize {
        self.metas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metas.is_empty()
    }
}

fn parse_meta(v: &Json) -> Result<ArtifactMeta> {
    let name = v
        .get("name")
        .as_str()
        .ok_or_else(|| anyhow!("meta missing name"))?
        .to_string();
    let inputs = v
        .get("inputs")
        .as_arr()
        .ok_or_else(|| anyhow!("meta missing inputs"))?
        .iter()
        .map(TensorSpec::from_json)
        .collect::<Result<_>>()?;
    let outputs = v
        .get("outputs")
        .as_arr()
        .ok_or_else(|| anyhow!("meta missing outputs"))?
        .iter()
        .map(TensorSpec::from_json)
        .collect::<Result<_>>()?;
    Ok(ArtifactMeta { name, inputs, outputs, raw: v.clone() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_meta_roundtrip() {
        let src = r#"{
            "name": "t", "kind": "lm_train",
            "inputs": [{"name": "x", "shape": [2, 3], "dtype": "float32"}],
            "outputs": [{"name": "y", "shape": [], "dtype": "float32"}],
            "param_names": ["a", "b"]
        }"#;
        let m = parse_meta(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.kind(), "lm_train");
        assert_eq!(m.inputs[0].shape, vec![2, 3]);
        assert_eq!(m.inputs[0].numel(), 6);
        assert_eq!(m.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(m.param_names(), vec!["a", "b"]);
        assert_eq!(m.input_index("x").unwrap(), 0);
        assert!(m.input_index("zz").is_err());
    }
}
