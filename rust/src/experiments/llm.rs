//! LLM experiments: Table 3 (SFT), Table 4 (continued training), Fig 3(c).
//!
//! Size mapping (DESIGN.md §2): paper's Qwen3-14B → our "small", paper's
//! Llama-3.1-70B → our "base". Evaluation = held-out perplexity + five
//! multiple-choice suites scored by likelihood ranking, mirroring
//! lm-eval-harness mechanics.

use anyhow::{anyhow, Result};

use super::common::{ensure_lm_base, f4, results_dir, write_history, write_table};
use crate::attention::AttnConfig;
use crate::config::Config;
use crate::coordinator::{LrSchedule, StepMetrics, Trainer};
use crate::data::corpus::Corpus;
use crate::data::tasks::{sft_batch, MC_SUITES};
use crate::eval::lm::{mc_accuracy, perplexity};
use crate::json::Json;
use crate::model::{
    AttnRegressor, LmTrainTask, QatModel, QatModelConfig, TrainConfig, TrainSession,
    WatchdogConfig,
};
use crate::qat::TrainerConfig;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::telemetry::Telemetry;
use crate::tensor::Tensor;

/// Eval artifact variant for a trained variant (QAT models infer in FP4).
fn eval_variant(trained: &str) -> &'static str {
    match trained {
        "f32" => "f32",
        _ => "fp4",
    }
}

/// Continue training `variant` from base params on corpus data.
fn continue_train(
    rt: &Runtime,
    size: &str,
    variant: &str,
    base: &[Tensor],
    cfg: &Config,
) -> Result<(Vec<Tensor>, Vec<StepMetrics>)> {
    let steps = cfg.usize_or("llm.ct_steps", 150);
    let lr = cfg.f32_or("llm.ct_lr", 3e-4);
    let seed = cfg.u64_or("seed", 42);
    let train_art = format!("lm_train_{variant}_{size}");
    let meta = rt.meta(&train_art)?;
    let batch = meta.usize_field("batch").ok_or_else(|| anyhow!("batch"))?;
    let seq = meta.raw.get("model").get("seq_len").as_usize().unwrap();
    println!("[llm] continued training '{variant}' on {size} for {steps} steps...");
    let mut trainer = Trainer::new(
        rt,
        &format!("lm_init_{size}"),
        &train_art,
        seed as i32,
        LrSchedule::Constant(lr),
    )?
    .with_params(base.to_vec())?;
    let mut corpus = Corpus::new(seed ^ 0xc7);
    trainer.run(
        steps,
        (steps / 5).max(1),
        |_| {
            let b = corpus.next_batch(batch, seq);
            vec![b.token_value(), b.mask_value()]
        },
        |m| println!("  [{variant}] step {:>4} loss {:.4} gnorm {:.3}", m.step, m.loss, m.grad_norm),
    )?;
    Ok((trainer.state.params.clone(), trainer.history))
}

/// SFT `variant` from base params on instruction tasks.
fn sft_train(
    rt: &Runtime,
    size: &str,
    variant: &str,
    base: &[Tensor],
    cfg: &Config,
) -> Result<(Vec<Tensor>, Vec<StepMetrics>)> {
    let steps = cfg.usize_or("llm.sft_steps", 150);
    let lr = cfg.f32_or("llm.sft_lr", 3e-4);
    let seed = cfg.u64_or("seed", 42);
    let train_art = format!("lm_train_{variant}_{size}");
    let meta = rt.meta(&train_art)?;
    let batch = meta.usize_field("batch").ok_or_else(|| anyhow!("batch"))?;
    let seq = meta.raw.get("model").get("seq_len").as_usize().unwrap();
    println!("[llm] SFT '{variant}' on {size} for {steps} steps...");
    let mut trainer = Trainer::new(
        rt,
        &format!("lm_init_{size}"),
        &train_art,
        seed as i32,
        LrSchedule::Constant(lr),
    )?
    .with_params(base.to_vec())?;
    let mut rng = Rng::new(seed ^ 0x5f7);
    trainer.run(
        steps,
        (steps / 5).max(1),
        |_| {
            let b = sft_batch(&mut rng, batch, seq);
            vec![b.token_value(), b.mask_value()]
        },
        |m| println!("  [{variant}] step {:>4} loss {:.4} gnorm {:.3}", m.step, m.loss, m.grad_norm),
    )?;
    Ok((trainer.state.params.clone(), trainer.history))
}

/// Evaluate params: perplexity + the 5 MC suites.
fn evaluate(
    rt: &Runtime,
    size: &str,
    variant: &str,
    params: &[Tensor],
    cfg: &Config,
) -> Result<(f64, Vec<f64>)> {
    let artifact = format!("lm_eval_{}_{size}", eval_variant(variant));
    let seed = cfg.u64_or("seed", 42);
    let n_items = cfg.usize_or("llm.eval_items", 40);
    let mut held_out = Corpus::new(seed ^ 0xeeee);
    let ppl = perplexity(rt, &artifact, params, &mut held_out, cfg.usize_or("llm.ppl_batches", 3))?;
    let mut accs = Vec::new();
    for suite in MC_SUITES {
        accs.push(mc_accuracy(rt, &artifact, params, suite, n_items, seed + 9)?);
    }
    Ok((ppl, accs))
}

const T4_HEADER: [&str; 8] = [
    "Exp.", "Model / Precision", "topic (WinoGrande)", "markov (HellaSwag)",
    "copy (ARC-c)", "sort (PIQA)", "lookup (MMLU)", "Held-out PPL ↓",
];

/// Table 4: continued training, sizes {small, base} × {BF16, FP4, Attn-QAT}.
pub fn table4(rt: &Runtime, cfg: &Config) -> Result<()> {
    let sizes: Vec<String> = match cfg.get("llm.sizes") {
        Some(crate::config::CfgValue::Arr(a)) => a
            .iter()
            .filter_map(|v| match v {
                crate::config::CfgValue::Str(s) => Some(s.clone()),
                _ => None,
            })
            .collect(),
        _ => vec!["small".to_string(), "base".to_string()],
    };
    let mut rows = Vec::new();
    let mut exp_no = 1;
    for size in &sizes {
        let base = ensure_lm_base(rt, size, cfg)?;
        // 1) BF16: continue in f32.
        let (p_f32, _) = continue_train(rt, size, "f32", &base, cfg)?;
        // 2) FP4: no training, base params, FP4 inference.
        // 3) Attn-QAT: continue with QAT, FP4 inference.
        let (p_qat, _) = continue_train(rt, size, "qat", &base, cfg)?;
        for (label, variant, params) in [
            ("BF16 (f32)", "f32", &p_f32),
            ("FP4", "fp4", &base),
            ("Attn-QAT", "qat", &p_qat),
        ] {
            let (ppl, accs) = evaluate(rt, size, variant, params, cfg)?;
            println!("[table4] {size}/{label}: ppl {ppl:.4} accs {accs:?}");
            let mut row = vec![exp_no.to_string(), format!("{size} / {label}")];
            row.extend(accs.iter().map(|&a| f4(a as f32)));
            row.push(format!("{ppl:.4}"));
            rows.push(row);
            exp_no += 1;
        }
    }
    write_table(
        "table4_llm",
        "Table 4 (proxy): LLM continued training — benchmark proxies + held-out perplexity",
        &T4_HEADER,
        &rows,
    )
}

const T3_HEADER: [&str; 7] = [
    "Exp.", "Model / Precision", "lookup (MMLU-Redux)", "copy (IFEval)",
    "markov (GPQA)", "sort (MATH-500)", "topic (GSM8K)",
];

/// Table 3: SFT with BF16 vs Attn-QAT; also records Fig 3(c) loss curves.
pub fn table3(rt: &Runtime, cfg: &Config) -> Result<()> {
    let sizes: Vec<String> = match cfg.get("llm.sizes") {
        Some(crate::config::CfgValue::Arr(a)) => a
            .iter()
            .filter_map(|v| match v {
                crate::config::CfgValue::Str(s) => Some(s.clone()),
                _ => None,
            })
            .collect(),
        _ => vec!["small".to_string(), "base".to_string()],
    };
    let mut rows = Vec::new();
    let mut series = Vec::new();
    let mut exp_no = 1;
    for size in &sizes {
        let base = ensure_lm_base(rt, size, cfg)?;
        for (label, variant) in [("BF16 (f32)", "f32"), ("FP4 w. Attn-QAT", "qat")] {
            let (params, hist) = sft_train(rt, size, variant, &base, cfg)?;
            // Table-3 proxies lean on the SFT task suites.
            let artifact = format!("lm_eval_{}_{size}", eval_variant(variant));
            let seed = cfg.u64_or("seed", 42);
            let n_items = cfg.usize_or("llm.eval_items", 40);
            let mut accs = Vec::new();
            for suite in ["lookup", "copy", "markov", "sort", "topic"] {
                accs.push(mc_accuracy(rt, &artifact, &params, suite, n_items, seed + 17)?);
            }
            println!("[table3] {size}/{label}: accs {accs:?}");
            let mut row = vec![exp_no.to_string(), format!("{size} / {label}")];
            row.extend(accs.iter().map(|&a| f4(a as f32)));
            rows.push(row);
            series.push((format!("{size}/{label}"), hist));
            exp_no += 1;
        }
    }
    write_history("fig3c_sft_loss", &series)?;
    write_table(
        "table3_llm",
        "Table 3 (proxy): SFT with BF16 attention vs Attn-QAT",
        &T3_HEADER,
        &rows,
    )
}

/// Figure 3(c): SFT loss curves BF16 vs Attn-QAT on the small model.
pub fn fig3c(rt: &Runtime, cfg: &Config) -> Result<()> {
    let size = cfg.str_or("llm.fig3c_size", "small");
    let base = ensure_lm_base(rt, &size, cfg)?;
    let mut series = Vec::new();
    let mut rows = Vec::new();
    for (label, variant) in [("BF16 (f32)", "f32"), ("Attn-QAT", "qat")] {
        let (_, hist) = sft_train(rt, &size, variant, &base, cfg)?;
        let final_loss = hist.last().map(|m| m.loss).unwrap_or(f32::NAN);
        let tail: Vec<f32> = hist.iter().rev().take(10).map(|m| m.loss).collect();
        let tail_mean = tail.iter().sum::<f32>() / tail.len().max(1) as f32;
        rows.push(vec![label.to_string(), f4(final_loss), f4(tail_mean)]);
        series.push((label.to_string(), hist));
    }
    write_history("fig3c_curves", &series)?;
    write_table(
        "fig3c_sft",
        "Figure 3(c) (proxy): SFT loss, BF16 vs Attn-QAT (series in results/fig3c_curves.json)",
        &["Config", "Final loss", "Tail-10 mean loss"],
        &rows,
    )
}

/// Figure 3(c) without the XLA runtime: SFT-style convergence on the
/// native `qat` trainer — the student starts away from the teacher
/// (`init_jitter`) and both the f32 baseline and Attn-QAT close the gap
/// at a normal learning rate (QAT plateaus at its quantization floor).
pub fn fig3c_native(cfg: &Config) -> Result<()> {
    let steps = cfg.usize_or("fig3c.native_steps", 150);
    let lr = cfg.f32_or("fig3c.native_lr", 0.05);
    let seed = cfg.u64_or("seed", 42);
    let mut series = Vec::new();
    let mut rows = Vec::new();
    for (label, attn) in [("BF16 (f32)", AttnConfig::f32()), ("Attn-QAT", AttnConfig::attn_qat())]
    {
        println!("[fig3c-native] training '{label}' for {steps} steps (lr {lr})...");
        let tc = TrainerConfig { lr, seed, init_jitter: 0.125, ..TrainerConfig::default() };
        let mut trainer = AttnRegressor::session(tc, attn);
        trainer.run(steps, (steps / 5).max(1), |m| {
            println!(
                "  [{label}] step {:>4} loss {:.4} gnorm {:.3}",
                m.step, m.loss, m.grad_norm
            )
        });
        let final_loss = trainer.history.last().map(|m| m.loss).unwrap_or(f32::NAN);
        let tail_mean = trainer.tail_loss(10);
        rows.push(vec![label.to_string(), f4(final_loss), f4(tail_mean)]);
        series.push((label.to_string(), trainer.history));
    }
    write_history("fig3c_curves", &series)?;
    write_table(
        "fig3c_sft",
        "Figure 3(c) (native): SFT-style loss, BF16 vs Attn-QAT on the native trainer (series in results/fig3c_curves.json)",
        &["Config", "Final loss", "Tail-10 mean loss"],
        &rows,
    )
}

/// Per-layer QAT health probes on the Fig-3 divergence setting (runs as
/// part of `repro exp fig3`, artifact-free): a two-layer [`QatModel`]
/// where layer 0 trains with the full Attn-QAT recipe and layer 1 with
/// the DropIn config (stock STE backward over plain FP4) — the
/// combination Figure 3 shows blowing up. SGD at a hot learning rate
/// with the divergence watchdog armed and telemetry sampled every step;
/// writes `results/fig3_probes.json` with the per-layer grad-norm
/// series, the first step where the DropIn layer's grad norm exceeds 4x
/// the QAT layer's (`detection_step`), and the watchdog's first rollback
/// (`first_rollback_step`). Divergence is recorded as data, never
/// asserted — the point is that the per-layer gauges localize it to the
/// DropIn layer before the global watchdog trips.
pub fn fig3_probes(cfg: &Config) -> Result<()> {
    let steps = cfg.usize_or("fig3.probe_steps", 40);
    let lr = cfg.f32_or("fig3.probe_lr", 0.8);
    let seed = cfg.u64_or("seed", 42);

    let model = QatModel::new(QatModelConfig { seed, ..QatModelConfig::default() });
    let mut task = LmTrainTask::new(model, 48, seed ^ 0xf193);
    // Layer 1 is the DropIn ablation: plain FP4 forward, STOCK backward.
    task.set_layer_attn(1, AttnConfig::fp4());
    let telemetry = Telemetry::new();
    task.attach_telemetry(&telemetry, 1);

    let train_cfg = TrainConfig::sgd(lr, 0.9).with_watchdog(WatchdogConfig::default());
    let mut session = TrainSession::new(task, train_cfg);
    session.attach_telemetry(&telemetry);

    let reg = telemetry.registry();
    let g_qat = reg.gauge("train.layer0.grad_norm");
    let g_drop = reg.gauge("train.layer1.grad_norm");

    println!("[fig3-probes] layer0 attn_qat vs layer1 DropIn, {steps} steps at lr {lr}...");
    let mut qat_series = Vec::new();
    let mut drop_series = Vec::new();
    let mut loss_series = Vec::new();
    let mut detection_step: Option<usize> = None;
    let mut first_rollback_step: Option<usize> = None;
    for step in 0..steps {
        let m = session.step();
        // The gauges hold the pre-rollback values: a diverged step's
        // gradients are sampled inside train_step, before the watchdog
        // decides to restore — exactly the early-warning view we want.
        let q = g_qat.get().unwrap_or(f64::NAN);
        let d = g_drop.get().unwrap_or(f64::NAN);
        qat_series.push(q as f32);
        drop_series.push(d as f32);
        loss_series.push(m.loss);
        if detection_step.is_none() && d.is_finite() && d > 4.0 * q.max(1e-12) {
            detection_step = Some(step);
        }
        if first_rollback_step.is_none() && session.rollbacks() > 0 {
            first_rollback_step = Some(step);
        }
    }

    let opt_step = |v: Option<usize>| v.map_or(Json::Null, |s| Json::Num(s as f64));
    let doc = Json::obj(vec![
        ("schema_version", Json::Num(1.0)),
        ("steps", Json::Num(steps as f64)),
        ("lr", Json::Num(lr as f64)),
        (
            "layer_attn",
            Json::obj(vec![
                ("layer0", Json::Str("attn_qat".to_string())),
                ("layer1", Json::Str("fp4".to_string())),
            ]),
        ),
        (
            "grad_norm",
            Json::obj(vec![
                ("layer0_attn_qat", Json::arr_f32(&qat_series)),
                ("layer1_drop_in", Json::arr_f32(&drop_series)),
            ]),
        ),
        ("loss", Json::arr_f32(&loss_series)),
        ("detection_step", opt_step(detection_step)),
        ("first_rollback_step", opt_step(first_rollback_step)),
        ("rollbacks", Json::Num(session.rollbacks() as f64)),
    ]);
    std::fs::write(results_dir().join("fig3_probes.json"), doc.to_string())?;
    println!(
        "[fig3-probes] detection_step {detection_step:?}, first_rollback {first_rollback_step:?}"
    );
    println!("-> results/fig3_probes.json");
    Ok(())
}
