//! Shared experiment machinery: markdown/JSON result writers, pretrained
//! "base model" preparation with checkpoint caching, batch providers.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::config::Config;
use crate::coordinator::{checkpoint, LrSchedule, StepMetrics, Trainer};
use crate::data::corpus::Corpus;
use crate::data::latents::LatentGen;
use crate::json::Json;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("results");
    std::fs::create_dir_all(&dir).ok();
    dir
}

/// Write a markdown table + JSON twin under `results/`.
pub fn write_table(
    name: &str,
    title: &str,
    header: &[&str],
    rows: &[Vec<String>],
) -> Result<()> {
    let dir = results_dir();
    let mut md = format!("# {title}\n\n|");
    for h in header {
        md.push_str(&format!(" {h} |"));
    }
    md.push_str("\n|");
    for _ in header {
        md.push_str("---|");
    }
    md.push('\n');
    for row in rows {
        md.push('|');
        for cell in row {
            md.push_str(&format!(" {cell} |"));
        }
        md.push('\n');
    }
    std::fs::write(dir.join(format!("{name}.md")), &md)?;
    let json = Json::obj(vec![
        ("title", Json::Str(title.to_string())),
        (
            "header",
            Json::Arr(header.iter().map(|h| Json::Str(h.to_string())).collect()),
        ),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(dir.join(format!("{name}.json")), json.to_string())?;
    println!("{md}");
    println!("-> results/{name}.md");
    Ok(())
}

/// Persist a metric history (Figure-3 style time series).
pub fn write_history(name: &str, series: &[(String, Vec<StepMetrics>)]) -> Result<()> {
    let obj = Json::Obj(
        series
            .iter()
            .map(|(label, hist)| {
                (
                    label.clone(),
                    Json::obj(vec![
                        (
                            "loss",
                            Json::arr_f32(&hist.iter().map(|m| m.loss).collect::<Vec<_>>()),
                        ),
                        (
                            "grad_norm",
                            Json::arr_f32(&hist.iter().map(|m| m.grad_norm).collect::<Vec<_>>()),
                        ),
                    ]),
                )
            })
            .collect(),
    );
    std::fs::write(results_dir().join(format!("{name}.json")), obj.to_string())?;
    Ok(())
}

fn ckpt_path(tag: &str) -> PathBuf {
    results_dir().join("ckpt").join(format!("{tag}.ckpt"))
}

/// Load cached params if present (names must match the artifact order).
pub fn load_cached(tag: &str, names: &[String]) -> Option<Vec<Tensor>> {
    let path = ckpt_path(tag);
    if !path.exists() {
        return None;
    }
    let loaded = checkpoint::load(&path).ok()?;
    if loaded.len() != names.len() || loaded.iter().zip(names).any(|((n, _), e)| n != e) {
        return None;
    }
    Some(loaded.into_iter().map(|(_, t)| t).collect())
}

pub fn save_cached(tag: &str, names: &[String], params: &[Tensor]) -> Result<()> {
    let named: Vec<(String, &Tensor)> = names
        .iter()
        .cloned()
        .zip(params.iter())
        .collect();
    checkpoint::save(&ckpt_path(tag), &named)
}

/// Train (or load cached) the f32 "pretrained base" LM for `size`.
///
/// Stands in for the released Qwen3/Llama checkpoints the paper starts
/// from: every Table-3/4 run branches off these parameters.
pub fn ensure_lm_base(rt: &Runtime, size: &str, cfg: &Config) -> Result<Vec<Tensor>> {
    let train_art = format!("lm_train_f32_{size}");
    let meta = rt.meta(&train_art)?;
    let names = meta.param_names();
    let tag = format!("lm_base_{size}");
    if !cfg.bool_or("force_retrain", false) {
        if let Some(p) = load_cached(&tag, &names) {
            println!("[base] loaded cached {tag}");
            return Ok(p);
        }
    }
    let steps = cfg.usize_or("pretrain.steps", 300);
    let lr = cfg.f32_or("pretrain.lr", 1e-3);
    let seed = cfg.u64_or("seed", 42);
    let batch = meta.usize_field("batch").ok_or_else(|| anyhow!("batch"))?;
    let seq = meta.raw.get("model").get("seq_len").as_usize().unwrap();
    println!("[base] pretraining {size} LM for {steps} steps (f32)...");
    let mut trainer = Trainer::new(
        rt,
        &format!("lm_init_{size}"),
        &train_art,
        seed as i32,
        LrSchedule::Cosine { warmup: steps / 10 + 1, peak: lr, total: steps, floor_frac: 0.1 },
    )?;
    let mut corpus = Corpus::new(seed);
    trainer.run(
        steps,
        (steps / 10).max(1),
        |_| {
            let b = corpus.next_batch(batch, seq);
            vec![b.token_value(), b.mask_value()]
        },
        |m| println!("  step {:>5} loss {:.4} gnorm {:.3}", m.step, m.loss, m.grad_norm),
    )?;
    save_cached(&tag, &names, &trainer.state.params)?;
    Ok(trainer.state.params)
}

/// Train (or load cached) the f32 "pretrained base" diffusion model.
pub fn ensure_diff_base(rt: &Runtime, size: &str, cfg: &Config) -> Result<Vec<Tensor>> {
    let train_art = format!("diff_train_f32_{size}");
    let meta = rt.meta(&train_art)?;
    let names = meta.param_names();
    let tag = format!("diff_base_{size}");
    if !cfg.bool_or("force_retrain", false) {
        if let Some(p) = load_cached(&tag, &names) {
            println!("[base] loaded cached {tag}");
            return Ok(p);
        }
    }
    let steps = cfg.usize_or("diff_pretrain.steps", 400);
    let lr = cfg.f32_or("diff_pretrain.lr", 1e-3);
    let seed = cfg.u64_or("seed", 42);
    let batch = meta.usize_field("batch").ok_or_else(|| anyhow!("batch"))?;
    let model = meta.raw.get("model").clone();
    let frames = model.get("frames").as_usize().unwrap();
    let latent_dim = model.get("latent_dim").as_usize().unwrap();
    println!("[base] pretraining {size} diffusion model for {steps} steps (f32)...");
    let mut trainer = Trainer::new(
        rt,
        &format!("diff_init_{size}"),
        &train_art,
        seed as i32,
        LrSchedule::Cosine { warmup: steps / 10 + 1, peak: lr, total: steps, floor_frac: 0.1 },
    )?;
    let mut gen = LatentGen::new(seed, frames, latent_dim);
    trainer.run(
        steps,
        (steps / 10).max(1),
        |_| gen.next_batch(batch).values().to_vec(),
        |m| println!("  step {:>5} loss {:.4} gnorm {:.3}", m.step, m.loss, m.grad_norm),
    )?;
    save_cached(&tag, &names, &trainer.state.params)?;
    Ok(trainer.state.params)
}

/// Format helper: 4-decimal metric cell.
pub fn f4(x: f32) -> String {
    if x.is_finite() {
        format!("{x:.4}")
    } else {
        "diverged".to_string()
    }
}

/// Relative path pretty-printer for logs.
pub fn rel(p: &Path) -> String {
    p.to_string_lossy().into_owned()
}
