//! `exp fullstack` — the Fig-3-style **per-component ablation grid** for
//! full-stack FP4 training (`results/fullstack_ablation.{md,json}`).
//!
//! The paper quantizes attention and keeps the rest of the training
//! stack f32; the grid here turns the remaining components low-precision
//! one at a time and together, in the spirit of the Fig-3 backward
//! switches:
//!
//! | arm | attention | projections | optimizer |
//! |-----|-----------|-------------|-----------|
//! | `f32` | f32 | f32 | Adam |
//! | `attn_only` | attn_qat | f32 | Adam |
//! | `attn_proj_ste` | attn_qat | NVFP4 STE | Adam |
//! | `attn_proj_had` | attn_qat | NVFP4 STE + Hadamard | Adam |
//! | `attn_optim` | attn_qat | f32 | LowPAdam (E4M3 moments) |
//! | `fullstack` | attn_qat | NVFP4 STE | LowPAdam |
//! | `fullstack_had` | attn_qat | NVFP4 STE + Hadamard + act | LowPAdam |
//! | `naive_proj` | attn_qat | hard requant (no STE) | Adam |
//!
//! The expected shape of the result mirrors the paper's: the *careful*
//! low-precision arms (STE scratch weights, unbiased stochastically
//! rounded moments) track the attn-only baseline within tolerance, while
//! the naive arm — hard in-place requantization every step, the obvious
//! "just quantize it" move — stalls, because the NVFP4 lattice step
//! (≈ scale/2) dwarfs an Adam-scale update and RNE erases it. The
//! divergence watchdog stays armed on every arm, so an arm can also fail
//! by burning its rollback budget — both failure modes land in the
//! table. Asserted as a smoke test by `rust/tests/fullstack_fp4.rs`.

use anyhow::Result;

use crate::attention::AttnConfig;
use crate::config::Config;
use crate::model::{
    LmTrainTask, ProjQuant, QatModel, QatModelConfig, TrainConfig, TrainSession, TrainableModel,
    WatchdogConfig,
};
use crate::telemetry::Telemetry;

use super::common::{f4, write_table};

/// One grid arm's configuration.
struct Arm {
    name: &'static str,
    attn: AttnConfig,
    attn_label: &'static str,
    proj: ProjQuant,
    lowp_optim: bool,
}

/// Everything the table (and the smoke test) reads off one arm.
pub struct ArmOutcome {
    pub name: String,
    pub first_loss: f32,
    pub final_loss: f32,
    pub max_grad_norm: f32,
    pub rollbacks: usize,
    pub diverged: bool,
    /// Optimizer moment-state bytes per parameter (8 for Adam, ~2 for
    /// LowPAdam).
    pub opt_bytes_per_param: f32,
    /// `train.lowp.m_sat_frac` after the last step (NaN for f32 Adam).
    pub m_sat_frac: f32,
    /// `train.lowp.sr_bias` after the last step (NaN for f32 Adam).
    pub sr_bias: f32,
}

fn grid() -> Vec<Arm> {
    let aq = AttnConfig::attn_qat();
    vec![
        Arm {
            name: "f32",
            attn: AttnConfig::f32(),
            attn_label: "f32",
            proj: ProjQuant::off(),
            lowp_optim: false,
        },
        Arm {
            name: "attn_only",
            attn: aq,
            attn_label: "attn_qat",
            proj: ProjQuant::off(),
            lowp_optim: false,
        },
        Arm {
            name: "attn_proj_ste",
            attn: aq,
            attn_label: "attn_qat",
            proj: ProjQuant::ste(),
            lowp_optim: false,
        },
        Arm {
            name: "attn_proj_had",
            attn: aq,
            attn_label: "attn_qat",
            proj: ProjQuant::ste().with_hadamard(true),
            lowp_optim: false,
        },
        Arm {
            name: "attn_optim",
            attn: aq,
            attn_label: "attn_qat",
            proj: ProjQuant::off(),
            lowp_optim: true,
        },
        Arm {
            name: "fullstack",
            attn: aq,
            attn_label: "attn_qat",
            proj: ProjQuant::ste(),
            lowp_optim: true,
        },
        Arm {
            name: "fullstack_had",
            attn: aq,
            attn_label: "attn_qat",
            proj: ProjQuant::ste().with_hadamard(true).with_activations(true),
            lowp_optim: true,
        },
        Arm {
            name: "naive_proj",
            attn: aq,
            attn_label: "attn_qat",
            proj: ProjQuant::naive().with_embeddings(true),
            lowp_optim: false,
        },
    ]
}

fn run_arm(arm: &Arm, cfg: &Config) -> ArmOutcome {
    let steps = cfg.usize_or("fullstack.steps", 60);
    let seq = cfg.usize_or("fullstack.seq", 32);
    let lr = cfg.f32_or("fullstack.lr", 5e-3);
    let seed = cfg.u64_or("seed", 42);

    let mut model = QatModel::new(QatModelConfig {
        ff: 32,
        max_pos: 64,
        seed,
        attn: arm.attn,
        ..QatModelConfig::default()
    });
    model.set_proj_quant(arm.proj);
    let mut task = LmTrainTask::new(model, seq, seed ^ 0xf00d);
    let telemetry = Telemetry::new();
    task.attach_telemetry(&telemetry, 4);

    let train_cfg = if arm.lowp_optim {
        TrainConfig::lowp_adam(lr, seed ^ 0x10f)
    } else {
        TrainConfig::adam(lr)
    }
    .with_watchdog(WatchdogConfig::default());
    let mut session = TrainSession::new(task, train_cfg);
    session.attach_telemetry(&telemetry);
    session.run(steps, 0, |_| {});

    let mut n_params = 0usize;
    session.model.visit_params(&mut |w, _| n_params += w.len());
    let reg = telemetry.registry();
    let gauge = |name: &str| reg.gauge(name).get().map_or(f32::NAN, |v| v as f32);
    ArmOutcome {
        name: arm.name.to_string(),
        first_loss: session.history.first().map_or(f32::NAN, |m| m.loss),
        final_loss: session.tail_loss(10),
        max_grad_norm: session.max_grad_norm(),
        rollbacks: session.rollbacks(),
        diverged: session.diverged(),
        opt_bytes_per_param: session.optimizer_state_bytes() as f32 / n_params.max(1) as f32,
        m_sat_frac: gauge("train.lowp.m_sat_frac"),
        sr_bias: gauge("train.lowp.sr_bias"),
    }
}

/// Run the whole grid (native, no PJRT) and return the outcomes in grid
/// order — the library entry the smoke test calls.
pub fn run_grid(cfg: &Config) -> Vec<(ArmOutcome, String, String, String)> {
    grid()
        .iter()
        .map(|arm| {
            println!(
                "[fullstack] arm {:<14} (attn {}, proj {})...",
                arm.name,
                arm.attn_label,
                arm.proj.label()
            );
            let out = run_arm(arm, cfg);
            let optim = if arm.lowp_optim { "lowp_adam" } else { "adam" };
            (out, arm.attn_label.to_string(), arm.proj.label(), optim.to_string())
        })
        .collect()
}

/// `exp fullstack`: run the grid and write
/// `results/fullstack_ablation.{md,json}`.
pub fn fullstack_ablation(cfg: &Config) -> Result<()> {
    let outcomes = run_grid(cfg);
    let rows: Vec<Vec<String>> = outcomes
        .iter()
        .map(|(o, attn, proj, optim)| {
            let status = if o.diverged {
                "diverged".to_string()
            } else if o.rollbacks > 0 {
                format!("{} rollbacks", o.rollbacks)
            } else {
                "ok".to_string()
            };
            vec![
                o.name.clone(),
                attn.clone(),
                proj.clone(),
                optim.clone(),
                f4(o.first_loss),
                f4(o.final_loss),
                f4(o.max_grad_norm),
                format!("{:.1}", o.opt_bytes_per_param),
                if o.m_sat_frac.is_nan() { "-".into() } else { format!("{:.4}", o.m_sat_frac) },
                if o.sr_bias.is_nan() { "-".into() } else { format!("{:+.5}", o.sr_bias) },
                status,
            ]
        })
        .collect();
    write_table(
        "fullstack_ablation",
        "Full-stack FP4 per-component ablation (final = mean of last 10 losses)",
        &[
            "config", "attn", "proj", "optimizer", "first", "final", "max gnorm", "opt B/param",
            "m_sat", "sr_bias", "status",
        ],
        &rows,
    )?;

    let find = |name: &str| outcomes.iter().find(|(o, ..)| o.name == name).map(|(o, ..)| o);
    if let (Some(attn), Some(full), Some(naive)) =
        (find("attn_only"), find("fullstack"), find("naive_proj"))
    {
        println!(
            "[fullstack] attn_only {:.4} vs fullstack {:.4} (gap {:+.4}); naive_proj {:.4} \
             ({} rollbacks)",
            attn.final_loss,
            full.final_loss,
            full.final_loss - attn.final_loss,
            naive.final_loss,
            naive.rollbacks,
        );
    }
    Ok(())
}
