//! Shard-scaling experiment for the native decode cluster (`repro exp
//! cluster`): the serving-side companion of the paper's §5 efficiency
//! claims, measured on this crate's own scale-out path.
//!
//! Serves one deterministic trace at several shard counts, fused-FP4 vs
//! the f32 gather baseline, and writes `results/cluster_scaling.{md,json}`
//! — aggregate tokens/s, parallel speedup vs one shard, worst-shard p99
//! per-token latency, and the FP4 KV-memory saving. Needs no compiled
//! artifacts and no PJRT backend (the models are native `SimLm`s), so it
//! runs in the same environments as `exp fig3`.

use anyhow::Result;

use crate::attention::AttnConfig;
use crate::config::Config;
use crate::data::corpus::Corpus;
use crate::serve::{ClusterConfig, DecodeCluster, Request, ShardConfig, SimLm, SimLmConfig};

use super::common;

/// The deterministic serving trace: prompts cut from the synthetic corpus
/// stream at varied lengths, greedy decoding. Shared by `repro serve
/// cluster`, `repro exp cluster`, and `benches/cluster_serve.rs` so all
/// three measure the same workload.
pub fn demo_trace(n_req: usize, max_new: usize, seed: u64) -> Vec<Request> {
    let mut corpus = Corpus::new(seed ^ 0xc105);
    (0..n_req)
        .map(|i| Request {
            id: i as u64 + 1,
            prompt: corpus.stream(16 + (i % 5) * 8),
            max_new_tokens: max_new,
            temperature: 0.0,
        })
        .collect()
}

/// One (shard count × attention config) serving run over `trace`:
/// spawn, submit, drain, verify nothing was lost; returns the wall time
/// (seconds) and the cluster stats. `seed` feeds both the shard models
/// and the sampling streams. Shared with `benches/cluster_serve.rs`.
pub fn serve_trace(
    shards: usize,
    attn: AttnConfig,
    lanes: usize,
    seed: u64,
    trace: &[Request],
) -> Result<(f64, crate::serve::ClusterStats)> {
    let cfg = ClusterConfig {
        shards,
        queue_depth: trace.len().max(1),
        shard: ShardConfig { slots: lanes, attn, seq_max: 512, sample_seed: seed },
    };
    let lm = SimLmConfig { seed, ..SimLmConfig::default() };
    let mut cluster = DecodeCluster::spawn(cfg, |_| Box::new(SimLm::new(lm)));
    let t0 = std::time::Instant::now();
    for r in trace {
        cluster.submit(r.clone())?;
    }
    let (done, stats) = cluster.drain()?;
    anyhow::ensure!(done.len() == trace.len(), "lost completions");
    Ok((t0.elapsed().as_secs_f64(), stats))
}

/// `repro exp cluster` — shard-scaling table.
pub fn cluster_scaling(cfg: &Config) -> Result<()> {
    let n_req = cfg.usize_or("cluster.requests", 32);
    let max_new = cfg.usize_or("cluster.max_new_tokens", 24);
    let lanes = cfg.usize_or("cluster.lanes", 4);
    let seed = cfg.u64_or("seed", 42);
    let trace = demo_trace(n_req, max_new, seed);

    let mut rows = Vec::new();
    let mut base_fp4 = None;
    for &shards in &[1usize, 2, 4] {
        for (name, attn) in [("fp4", AttnConfig::fp4()), ("f32", AttnConfig::f32())] {
            let (wall_s, stats) = serve_trace(shards, attn, lanes, seed, &trace)?;
            let tokens = stats.total_tokens();
            let tps = tokens as f64 / wall_s.max(1e-9);
            let speedup = if name == "fp4" {
                if shards == 1 {
                    base_fp4 = Some(tps);
                    1.0
                } else {
                    tps / base_fp4.unwrap_or(tps)
                }
            } else {
                f64::NAN
            };
            let (used, f32eq) = (
                stats.kv_bytes_peak(),
                stats.shards.iter().map(|s| s.kv_bytes_f32_equiv_peak).sum::<usize>(),
            );
            let speedup_cell = if speedup.is_nan() {
                "-".to_string()
            } else {
                format!("{speedup:.2}x")
            };
            rows.push(vec![
                shards.to_string(),
                name.to_string(),
                tokens.to_string(),
                format!("{tps:.0}"),
                speedup_cell,
                format!("{:.3}", stats.p99_token_ms()),
                format!("{:.1}x", f32eq as f64 / used.max(1) as f64),
            ]);
        }
    }
    common::write_table(
        "cluster_scaling",
        "Sharded decode cluster: scaling and FP4-vs-f32 serving throughput",
        &["shards", "attn", "tokens", "tok/s", "vs 1-shard fp4", "p99/tok (ms)", "KV saving"],
        &rows,
    )
}
