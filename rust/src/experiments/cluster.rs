//! Shard-scaling experiment for the native decode cluster (`repro exp
//! cluster`): the serving-side companion of the paper's §5 efficiency
//! claims, measured on this crate's own scale-out path.
//!
//! Serves one deterministic trace at several shard counts, fused-FP4 vs
//! the f32 gather baseline, and writes `results/cluster_scaling.{md,json}`
//! — aggregate tokens/s, parallel speedup vs one shard, worst-shard p99
//! per-token latency, and the FP4 KV-memory saving. Needs no compiled
//! artifacts and no PJRT backend (the models are native `SimLm`s), so it
//! runs in the same environments as `exp fig3`.

use anyhow::Result;

use crate::attention::AttnConfig;
use crate::config::Config;
use crate::data::corpus::Corpus;
use crate::json::Json;
use crate::kvcache::SpillConfig;
use crate::serve::{
    ClusterConfig, Completion, DecodeCluster, FaultPlan, Request, ShardConfig, SimLm, SimLmConfig,
    SupervisorConfig,
};
use crate::telemetry::Telemetry;

use super::common;

/// The deterministic serving trace: prompts cut from the synthetic corpus
/// stream at varied lengths, greedy decoding. Shared by `repro serve
/// cluster`, `repro exp cluster`, and `benches/cluster_serve.rs` so all
/// three measure the same workload.
pub fn demo_trace(n_req: usize, max_new: usize, seed: u64) -> Vec<Request> {
    let mut corpus = Corpus::new(seed ^ 0xc105);
    (0..n_req)
        .map(|i| Request {
            id: i as u64 + 1,
            prompt: corpus.stream(16 + (i % 5) * 8),
            max_new_tokens: max_new,
            temperature: 0.0,
            deadline_ms: None,
            trace: Default::default(),
        })
        .collect()
}

/// One (shard count × attention config) serving run over `trace`:
/// spawn, submit, drain, verify nothing was lost; returns the wall time
/// (seconds) and the cluster stats. `seed` feeds both the shard models
/// and the sampling streams. Shared with `benches/cluster_serve.rs`.
pub fn serve_trace(
    shards: usize,
    attn: AttnConfig,
    lanes: usize,
    seed: u64,
    trace: &[Request],
) -> Result<(f64, crate::serve::ClusterStats)> {
    let (wall, stats, _) = serve_trace_faulty(
        shards,
        attn,
        lanes,
        seed,
        trace,
        FaultPlan::none(),
        SupervisorConfig::default(),
    )?;
    Ok((wall, stats))
}

/// [`serve_trace`] with an injected [`FaultPlan`] and an explicit
/// supervisor policy; also returns the (id-sorted) completions so
/// callers can check faulty runs for bitwise identity against clean
/// ones. The zero-lost-requests invariant is asserted here: every
/// submitted request must come back, faults or not. Shared by `repro
/// exp faults` and `benches/cluster_serve.rs`.
pub fn serve_trace_faulty(
    shards: usize,
    attn: AttnConfig,
    lanes: usize,
    seed: u64,
    trace: &[Request],
    faults: FaultPlan,
    supervisor: SupervisorConfig,
) -> Result<(f64, crate::serve::ClusterStats, Vec<Completion>)> {
    let (wall, stats, done, _snapshot) = serve_trace_observed(
        shards,
        attn,
        lanes,
        seed,
        trace,
        faults,
        supervisor,
        Telemetry::new(),
    )?;
    Ok((wall, stats, done))
}

/// [`serve_trace_faulty`] with a caller-supplied [`Telemetry`] handle;
/// additionally returns the post-drain [`Telemetry::snapshot`] so
/// experiments can persist the registry view (live config, per-shard
/// gauges, supervisor counters) in the same document as throughput.
/// Pass [`Telemetry::disabled`] to measure the zero-instrumentation
/// path (`benches/cluster_serve.rs` uses this for its overhead guard).
#[allow(clippy::too_many_arguments)]
pub fn serve_trace_observed(
    shards: usize,
    attn: AttnConfig,
    lanes: usize,
    seed: u64,
    trace: &[Request],
    faults: FaultPlan,
    supervisor: SupervisorConfig,
    telemetry: Telemetry,
) -> Result<(f64, crate::serve::ClusterStats, Vec<Completion>, Json)> {
    let cfg = ClusterConfig {
        shards,
        queue_depth: trace.len().max(1),
        shard: ShardConfig {
            slots: lanes,
            attn,
            seq_max: 512,
            sample_seed: seed,
            ..ShardConfig::default()
        },
        supervisor,
    };
    let lm = SimLmConfig { seed, ..SimLmConfig::default() };
    let mut cluster = DecodeCluster::spawn_observed(cfg, telemetry.clone(), move |shard| {
        faults.wrap(shard, Box::new(SimLm::new(lm)))
    });
    let t0 = std::time::Instant::now();
    for r in trace {
        cluster.submit(r.clone())?;
    }
    let (done, stats) = cluster.drain()?;
    anyhow::ensure!(done.len() == trace.len(), "lost completions");
    // Snapshot after drain: shard workers republish their authoritative
    // final stats into the registry as part of the drain handshake.
    Ok((t0.elapsed().as_secs_f64(), stats, done, telemetry.snapshot()))
}

/// A shared-prefix serving trace: every request starts with the same
/// `prefix_tokens`-byte "system prompt" cut from the synthetic corpus,
/// followed by a unique per-request suffix. The workload the prefix
/// sharing tier exists for; shared by `rust/tests/prefix_cache.rs` and
/// `benches/cluster_serve.rs`.
pub fn shared_prefix_trace(
    n_req: usize,
    prefix_tokens: usize,
    suffix_tokens: usize,
    max_new: usize,
    seed: u64,
) -> Vec<Request> {
    let mut corpus = Corpus::new(seed ^ 0x9ef1);
    let system = corpus.stream(prefix_tokens);
    (0..n_req)
        .map(|i| {
            let mut prompt = system.clone();
            prompt.extend_from_slice(&corpus.stream(suffix_tokens.max(1)));
            Request {
                id: i as u64 + 1,
                prompt,
                max_new_tokens: max_new,
                temperature: 0.0,
                deadline_ms: None,
                trace: Default::default(),
            }
        })
        .collect()
}

/// [`serve_trace_faulty`] with explicit prefix-sharing / disk-spill
/// knobs on the shard config — the on/off comparison harness for the
/// shared-prefix bench and `rust/tests/prefix_cache.rs`. Returns
/// `(wall_s, stats, completions)`; completions are id-sorted so on/off
/// runs compare bitwise directly.
#[allow(clippy::too_many_arguments)]
pub fn serve_trace_prefix(
    shards: usize,
    attn: AttnConfig,
    lanes: usize,
    seed: u64,
    trace: &[Request],
    prefix_share: bool,
    kv_spill: Option<SpillConfig>,
    faults: FaultPlan,
    supervisor: SupervisorConfig,
) -> Result<(f64, crate::serve::ClusterStats, Vec<Completion>)> {
    let cfg = ClusterConfig {
        shards,
        queue_depth: trace.len().max(1),
        shard: ShardConfig {
            slots: lanes,
            attn,
            seq_max: 512,
            sample_seed: seed,
            prefix_share,
            kv_spill,
            ..ShardConfig::default()
        },
        supervisor,
    };
    let lm = SimLmConfig { seed, ..SimLmConfig::default() };
    let mut cluster = DecodeCluster::spawn_observed(cfg, Telemetry::new(), move |shard| {
        faults.wrap(shard, Box::new(SimLm::new(lm)))
    });
    let t0 = std::time::Instant::now();
    for r in trace {
        cluster.submit(r.clone())?;
    }
    let (done, stats) = cluster.drain()?;
    anyhow::ensure!(done.len() == trace.len(), "lost completions");
    Ok((t0.elapsed().as_secs_f64(), stats, done))
}

/// `repro exp cluster` — shard-scaling table.
pub fn cluster_scaling(cfg: &Config) -> Result<()> {
    let n_req = cfg.usize_or("cluster.requests", 32);
    let max_new = cfg.usize_or("cluster.max_new_tokens", 24);
    let lanes = cfg.usize_or("cluster.lanes", 4);
    let seed = cfg.u64_or("seed", 42);
    let trace = demo_trace(n_req, max_new, seed);

    let mut rows = Vec::new();
    let mut base_fp4 = None;
    for &shards in &[1usize, 2, 4] {
        for (name, attn) in [("fp4", AttnConfig::fp4()), ("f32", AttnConfig::f32())] {
            let (wall_s, stats) = serve_trace(shards, attn, lanes, seed, &trace)?;
            let tokens = stats.total_tokens();
            let tps = tokens as f64 / wall_s.max(1e-9);
            let speedup = if name == "fp4" {
                if shards == 1 {
                    base_fp4 = Some(tps);
                    1.0
                } else {
                    tps / base_fp4.unwrap_or(tps)
                }
            } else {
                f64::NAN
            };
            let (used, f32eq) = (
                stats.kv_bytes_peak(),
                stats.shards.iter().map(|s| s.kv_bytes_f32_equiv_peak).sum::<usize>(),
            );
            let speedup_cell = if speedup.is_nan() {
                "-".to_string()
            } else {
                format!("{speedup:.2}x")
            };
            rows.push(vec![
                shards.to_string(),
                name.to_string(),
                tokens.to_string(),
                format!("{tps:.0}"),
                speedup_cell,
                format!("{:.3}", stats.p99_token_ms()),
                format!("{:.1}x", f32eq as f64 / used.max(1) as f64),
            ]);
        }
    }
    common::write_table(
        "cluster_scaling",
        "Sharded decode cluster: scaling and FP4-vs-f32 serving throughput",
        &["shards", "attn", "tokens", "tok/s", "vs 1-shard fp4", "p99/tok (ms)", "KV saving"],
        &rows,
    )
}

/// `repro exp faults` — fault-tolerance table: the same trace served
/// clean, through a mid-decode shard panic, and through a shard stall,
/// each checked for zero lost requests and *bitwise identical*
/// completions against the clean run (the supervisor's deterministic-
/// replay contract). Writes `results/fault_tolerance.{md,json}`.
///
/// `-s faults.trace_out=FILE` additionally exports the causal span trees
/// of all three scenarios as one Chrome trace-event JSON file — the
/// faulted scenarios include the supervisor's `replay` spans (tagged with
/// the shard incarnation), so recovery cost is visible per request on the
/// Perfetto timeline.
pub fn fault_tolerance(cfg: &Config) -> Result<()> {
    let n_req = cfg.usize_or("faults.requests", 24);
    let max_new = cfg.usize_or("faults.max_new_tokens", 16);
    let seed = cfg.u64_or("seed", 42);
    let shards = 4usize;
    let trace = demo_trace(n_req, max_new, seed);
    let trace_out = cfg.str_or("faults.trace_out", "");
    let mut trace_records = Vec::new();

    let sup = SupervisorConfig { stall_timeout_ms: 150.0, ..SupervisorConfig::default() };
    let scenarios: [(&str, FaultPlan); 3] = [
        ("clean", FaultPlan::none()),
        ("panic shard0 @pass12", FaultPlan::panic_at(0, 12)),
        ("stall shard0 @pass8 400ms", FaultPlan::stall_at(0, 8, 400)),
    ];

    let want_json = cfg.bool_or("json", false);
    let mut baseline: Option<Vec<(u64, Vec<u8>)>> = None;
    let mut rows = Vec::new();
    let mut snapshots = Vec::new();
    for (name, plan) in scenarios {
        // A trace export wants the whole scenario retained, not the
        // default ring's newest slice.
        let telemetry = if trace_out.is_empty() {
            Telemetry::new()
        } else {
            Telemetry::with_span_capacity(8192)
        };
        let (wall_s, stats, done, snapshot) = serve_trace_observed(
            shards,
            AttnConfig::fp4(),
            4,
            seed,
            &trace,
            plan,
            sup,
            telemetry.clone(),
        )?;
        if !trace_out.is_empty() {
            trace_records.extend(telemetry.spans().records());
        }
        let texts: Vec<(u64, Vec<u8>)> = done.iter().map(|c| (c.id, c.text.clone())).collect();
        let bitwise = match &baseline {
            None => {
                baseline = Some(texts);
                "baseline".to_string()
            }
            Some(clean) => {
                anyhow::ensure!(
                    *clean == texts,
                    "scenario {name:?}: completions diverged from the clean run"
                );
                "identical".to_string()
            }
        };
        let tokens = stats.total_tokens();
        let tps = tokens as f64 / wall_s.max(1e-9);
        if want_json {
            snapshots.push(Json::obj(vec![
                ("scenario", Json::Str(name.to_string())),
                ("tokens_per_sec", Json::Num(tps)),
                ("telemetry", snapshot),
            ]));
        }
        rows.push(vec![
            name.to_string(),
            stats.restarts.to_string(),
            stats.replayed_requests.to_string(),
            stats.recomputed_passes.to_string(),
            tokens.to_string(),
            format!("{tps:.0}"),
            bitwise,
        ]);
    }
    if want_json {
        // One schema-versioned doc per scenario: supervisor restart /
        // replay / shed counters land next to throughput, so dashboards
        // consume fault runs without parsing the markdown table.
        let doc = Json::obj(vec![("scenarios", Json::Arr(snapshots))]);
        let path = common::results_dir().join("fault_tolerance_snapshot.json");
        std::fs::write(&path, doc.to_string())?;
        println!("{doc}");
        println!("-> results/fault_tolerance_snapshot.json");
    }
    if !trace_out.is_empty() {
        let doc = crate::telemetry::chrome_trace(&trace_records);
        if let Some(dir) = std::path::Path::new(&trace_out).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(&trace_out, format!("{doc}\n"))?;
        println!("chrome trace ({} span(s), all scenarios) -> {trace_out}", trace_records.len());
    }
    common::write_table(
        "fault_tolerance",
        "Supervised cluster under injected faults: zero lost requests, bitwise replay",
        &["scenario", "restarts", "replayed", "recomputed passes", "tokens", "tok/s", "vs clean"],
        &rows,
    )
}
