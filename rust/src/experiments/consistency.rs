//! Figure 4: fake-quant (compiled HLO) vs real-quant (native Rust engine)
//! agreement — the paper's train/test-mismatch check.
//!
//! Three executions of the *same* attention on identical inputs:
//!   1. `attn_<v>_s256_d64`         — fast-jnp fake-quant HLO (training fwd)
//!   2. `attn_<v>_pallas_s256_d64`  — Pallas-kernel fake-quant HLO
//!   3. `attention::engine`          — packed-4-bit real-quant Rust engine
//!
//! The paper's claim (Fig. 4: "visually indistinguishable") maps to small
//! max-abs error and cosine ≈ 1 between (1)/(2) and (3).

use anyhow::Result;

use super::common::write_table;
use crate::attention::{AttnConfig, AttnEngine};
use crate::config::Config;
use crate::rng::Rng;
use crate::runtime::{Runtime, Value};
use crate::tensor::Tensor;

pub fn fig4(rt: &Runtime, cfg: &Config) -> Result<()> {
    let (b, h, n, d) = (1usize, 4usize, 256usize, 64usize);
    let seed = cfg.u64_or("seed", 42);
    let mut rng = Rng::new(seed ^ 0xf14);
    let q = Tensor::new(vec![b, h, n, d], rng.normal_vec(b * h * n * d, 0.0, 1.0))?;
    let k = Tensor::new(vec![b, h, n, d], rng.normal_vec(b * h * n * d, 0.0, 1.0))?;
    let v = Tensor::new(vec![b, h, n, d], rng.normal_vec(b * h * n * d, 0.0, 1.0))?;

    let mut rows = Vec::new();
    for variant in ["f32", "fp4", "sage3"] {
        let fast = rt.run(
            &format!("attn_{variant}_s{n}_d{d}"),
            &[Value::F32(q.clone()), Value::F32(k.clone()), Value::F32(v.clone())],
        )?;
        let pallas = rt.run(
            &format!("attn_{variant}_pallas_s{n}_d{d}"),
            &[Value::F32(q.clone()), Value::F32(k.clone()), Value::F32(v.clone())],
        )?;
        // Native real-quant engine: one multi-head session per variant.
        // block_q = 64 must match the artifact's Q tile for sage3 bit
        // parity (it is inert for the unsmoothed f32/fp4 configs).
        let attn_cfg = AttnConfig::parse(variant)?.with_block_q(64);
        let mut engine = AttnEngine::new(attn_cfg);
        let out = engine.forward(&q.data, &k.data, &v.data, h, n, n, d);
        let native = Tensor::new(vec![b, h, n, d], out.o)?;
        let fast_vs_native = (
            fast[0].max_abs_diff(&native),
            fast[0].mean_abs_diff(&native),
            fast[0].cosine_sim(&native),
        );
        let pallas_vs_native = (
            pallas[0].max_abs_diff(&native),
            pallas[0].mean_abs_diff(&native),
            pallas[0].cosine_sim(&native),
        );
        let fast_vs_pallas = (
            fast[0].max_abs_diff(&pallas[0]),
            fast[0].mean_abs_diff(&pallas[0]),
            fast[0].cosine_sim(&pallas[0]),
        );
        println!(
            "[fig4] {variant}: fake(jnp)↔real max {:.2e}, fake(pallas)↔real max {:.2e}",
            fast_vs_native.0, pallas_vs_native.0
        );
        for (pair, (mx, mn, cs)) in [
            ("fake-quant HLO (jnp) vs real-quant engine", fast_vs_native),
            ("fake-quant HLO (pallas) vs real-quant engine", pallas_vs_native),
            ("fake-quant jnp vs pallas", fast_vs_pallas),
        ] {
            rows.push(vec![
                variant.to_string(),
                pair.to_string(),
                format!("{mx:.3e}"),
                format!("{mn:.3e}"),
                format!("{cs:.6}"),
            ]);
        }
    }
    write_table(
        "fig4_consistency",
        "Figure 4 (proxy): fake-quant (training) vs real-quant (inference) agreement, 256×64 heads",
        &["Variant", "Pair", "Max abs err", "Mean abs err", "Cosine"],
        &rows,
    )
}
