//! Figure 5: attention-kernel throughput.
//!
//! Two complementary views (DESIGN.md §2 substitution):
//!
//! * **Measured (CPU)** — wall time of the compiled attention artifacts per
//!   variant/shape. On CPU the FP4 variants *emulate* quantization in f32
//!   and are necessarily slower than plain f32 attention, but the paper's
//!   key ordering — Attn-QAT faster than SageAttention3 (less
//!   preprocessing) — must and does hold.
//! * **Modeled (RTX 5090)** — the `perfmodel` analytical estimates at the
//!   paper's shapes (batch 16, 16 heads, hd ∈ {64,128}), reproducing the
//!   1.1–1.5× Attn-QAT/Sage3 and FP4/BF16 speedup shapes.

use anyhow::Result;

use super::common::write_table;
use crate::attention::{AttnConfig, AttnEngine};
use crate::bench::bench_units;
use crate::config::Config;
use crate::perfmodel::{estimate, Hw, Kernel};
use crate::rng::Rng;
use crate::runtime::{Runtime, Value};
use crate::tensor::Tensor;

pub fn fig5(rt: &Runtime, cfg: &Config) -> Result<()> {
    measured(rt, cfg)?;
    modeled(cfg)
}

fn measured(rt: &Runtime, cfg: &Config) -> Result<()> {
    let iters = cfg.usize_or("fig5.iters", 5);
    let mut rows = Vec::new();
    let mut rng = Rng::new(cfg.u64_or("seed", 42));

    // Native real-quant engine rows (no artifacts needed): the same
    // variant family through one AttnEngine per config, so the table has
    // measured content even on the stub PJRT backend.
    {
        let d = 64usize;
        for n in [128usize, 256] {
            let q = rng.normal_vec(n * d, 0.0, 1.0);
            let k = rng.normal_vec(n * d, 0.0, 1.0);
            let v = rng.normal_vec(n * d, 0.0, 1.0);
            let flops = 4.0 * (n * n * d) as f64;
            let mut per_variant = Vec::new();
            for variant in ["f32", "fp4", "sage3"] {
                let mut engine = AttnEngine::new(AttnConfig::parse(variant)?);
                let r = bench_units(
                    &format!("native_{variant}_s{n}_d{d}"),
                    1,
                    iters.min(3),
                    flops,
                    "flop",
                    || {
                        let out = engine.forward(&q, &k, &v, 1, n, n, d);
                        std::hint::black_box(out.o[0]);
                    },
                );
                per_variant.push((variant, r.median_ns, r.throughput()));
            }
            let sage = per_variant.iter().find(|(v, ..)| *v == "sage3").map(|x| x.1);
            for (variant, ns, tput) in &per_variant {
                let vs_sage = sage.map(|s| format!("{:.2}x", s / ns)).unwrap_or_default();
                rows.push(vec![
                    format!("native hd={d} seq={n}"),
                    variant.to_string(),
                    format!("{:.3} ms", ns / 1e6),
                    format!("{:.3e}", tput),
                    vs_sage,
                ]);
            }
        }
    }
    for d in [64usize, 128] {
        for n in [128usize, 256, 512, 1024] {
            let (b, h) = (1usize, 4usize);
            let numel = b * h * n * d;
            let q = Tensor::new(vec![b, h, n, d], rng.normal_vec(numel, 0.0, 1.0))?;
            let k = Tensor::new(vec![b, h, n, d], rng.normal_vec(numel, 0.0, 1.0))?;
            let v = Tensor::new(vec![b, h, n, d], rng.normal_vec(numel, 0.0, 1.0))?;
            let mut per_variant = Vec::new();
            for variant in ["f32", "fp4", "sage3"] {
                let name = format!("attn_{variant}_s{n}_d{d}");
                if rt.meta(&name).is_err() {
                    continue; // bench set not exported
                }
                let inputs =
                    [Value::F32(q.clone()), Value::F32(k.clone()), Value::F32(v.clone())];
                rt.run(&name, &inputs)?; // compile warmup
                let flops = 4.0 * (b * h) as f64 * (n * n * d) as f64;
                let r = bench_units(&name, 1, iters, flops, "flop", || {
                    rt.run(&name, &inputs).expect("bench run");
                });
                per_variant.push((variant, r.median_ns, r.throughput()));
            }
            let sage = per_variant.iter().find(|(v, ..)| *v == "sage3").map(|x| x.1);
            for (variant, ns, tput) in &per_variant {
                let vs_sage = sage.map(|s| format!("{:.2}x", s / ns)).unwrap_or_default();
                rows.push(vec![
                    format!("hd={d} seq={n}"),
                    variant.to_string(),
                    format!("{:.3} ms", ns / 1e6),
                    format!("{:.3e}", tput),
                    vs_sage,
                ]);
            }
        }
    }
    write_table(
        "fig5_measured",
        "Figure 5a (CPU-measured): compiled attention artifact wall time (FP4 emulated in f32 — ordering vs Sage3 is the claim)",
        &["Shape", "Variant", "Median", "FLOP/s", "Speedup vs Sage3"],
        &rows,
    )
}

fn modeled(_cfg: &Config) -> Result<()> {
    let hw = Hw::default();
    let mut rows = Vec::new();
    for d in [64usize, 128] {
        for n in [1024usize, 2048, 4096, 8192, 16384] {
            let (b, h) = (16usize, 16usize);
            let fa2 = estimate(Kernel::Fa2Bf16, &hw, b, h, n, d);
            let sage = estimate(Kernel::Sage3, &hw, b, h, n, d);
            let qat = estimate(Kernel::AttnQat, &hw, b, h, n, d);
            let tput = |e: &crate::perfmodel::Estimate| {
                4.0 * (b * h) as f64 * (n * n * d) as f64 / e.total_s / 1e12
            };
            rows.push(vec![
                format!("hd={d} seq={n}"),
                format!("{:.1}", tput(&fa2)),
                format!("{:.1}", tput(&sage)),
                format!("{:.1}", tput(&qat)),
                format!("{:.2}x", sage.total_s / qat.total_s),
                format!("{:.2}x", fa2.total_s / qat.total_s),
                format!("{:.0}%", qat.mxu_utilization * 100.0),
            ]);
        }
    }
    write_table(
        "fig5_modeled",
        "Figure 5b (modeled, RTX 5090 profile): TFLOP/s by kernel; Attn-QAT vs Sage3 should fall in the paper's 1.1-1.5x band",
        &[
            "Shape", "FA2-BF16 TFLOP/s", "Sage3 TFLOP/s", "Attn-QAT TFLOP/s",
            "QAT/Sage3", "QAT/FA2", "QAT tensor-core util",
        ],
        &rows,
    )
}
