//! Diffusion experiments: Tables 1 & 2, Figures 1–3(a,b).
//!
//! Pipeline per variant: (pretrained f32 base) → [optional QAT finetune
//! with the variant's train artifact] → ODE-sample clips with the matching
//! *forward* variant → VBench-proxy metrics against the generator's
//! reference statistics.

use anyhow::{anyhow, Result};

use super::common::{ensure_diff_base, f4, write_history, write_table};
use crate::attention::AttnConfig;
use crate::config::Config;
use crate::coordinator::{LrSchedule, StepMetrics, Trainer};
use crate::model::AttnRegressor;
use crate::qat::TrainerConfig;
use crate::data::latents::LatentGen;
use crate::eval::judge::judge_pairwise;
use crate::eval::video::{reference_stats, video_metrics, VideoMetrics, VideoRefStats};
use crate::runtime::{Runtime, Value};
use crate::tensor::Tensor;

/// Sampling-forward artifact for each trained variant.
fn sample_variant(trained: &str) -> &'static str {
    match trained {
        "f32" => "f32",
        "sage3" => "sage3",
        "qat_smoothk" => "qat_smoothk",
        "qat_twolevel" => "qat_twolevel",
        // qat / ablations / raw fp4 all *infer* with the plain FP4 forward
        _ => "fp4",
    }
}

struct DiffCtx {
    size: String,
    frames: usize,
    latent_dim: usize,
    batch: usize,
    sample_steps: usize,
    seed: u64,
}

impl DiffCtx {
    fn new(rt: &Runtime, size: &str, cfg: &Config) -> Result<DiffCtx> {
        let meta = rt.meta(&format!("diff_train_f32_{size}"))?;
        let model = meta.raw.get("model").clone();
        Ok(DiffCtx {
            size: size.to_string(),
            frames: model.get("frames").as_usize().ok_or_else(|| anyhow!("frames"))?,
            latent_dim: model.get("latent_dim").as_usize().ok_or_else(|| anyhow!("latent_dim"))?,
            batch: meta.usize_field("batch").ok_or_else(|| anyhow!("batch"))?,
            sample_steps: cfg.usize_or("diff.sample_steps", 16),
            seed: cfg.u64_or("seed", 42),
        })
    }

    /// Integrate the probability-flow ODE from noise (t=1 → 0) with Euler.
    fn sample_clips(
        &self,
        rt: &Runtime,
        variant: &str,
        params: &[Tensor],
        n_clips: usize,
        seed_offset: u64,
    ) -> Result<Vec<f32>> {
        let artifact = format!("diff_sample_{}_{}", sample_variant(variant), self.size);
        let mut gen = LatentGen::new(self.seed + 1000 + seed_offset, self.frames, self.latent_dim);
        let mut out = Vec::with_capacity(n_clips * self.frames * self.latent_dim);
        let mut produced = 0;
        while produced < n_clips {
            let mut x = Tensor::new(
                vec![self.batch, self.frames, self.latent_dim],
                gen.noise_batch(self.batch),
            )?;
            let dt = 1.0 / self.sample_steps as f32;
            for s in 0..self.sample_steps {
                let t = 1.0 - s as f32 * dt;
                let mut inputs: Vec<Value> = params.iter().cloned().map(Value::F32).collect();
                inputs.push(Value::F32(x));
                inputs.push(Value::F32(Tensor::new(vec![self.batch], vec![t; self.batch])?));
                inputs.push(Value::F32(Tensor::new(vec![self.batch], vec![dt; self.batch])?));
                x = rt.run(&artifact, &inputs)?.remove(0);
            }
            let take = (n_clips - produced).min(self.batch);
            out.extend_from_slice(&x.data[..take * self.frames * self.latent_dim]);
            produced += take;
        }
        Ok(out)
    }

    fn reference(&self, n_clips: usize) -> (Vec<f32>, VideoRefStats) {
        let mut gen = LatentGen::new(self.seed + 77, self.frames, self.latent_dim);
        let mut data = Vec::new();
        for _ in 0..n_clips {
            data.extend(gen.sample());
        }
        let stats = reference_stats(&data, n_clips, self.frames, self.latent_dim);
        (data, stats)
    }

    fn metrics(&self, clips: &[f32], n: usize, r: &VideoRefStats) -> VideoMetrics {
        video_metrics(clips, n, self.frames, self.latent_dim, r)
    }
}

/// QAT-finetune `variant` from the base params; returns (params, trainer history).
fn finetune(
    rt: &Runtime,
    size: &str,
    variant: &str,
    base: &[Tensor],
    cfg: &Config,
) -> Result<(Vec<Tensor>, Vec<crate::coordinator::StepMetrics>)> {
    finetune_lr(rt, size, variant, base, cfg, cfg.f32_or("diff.qat_lr", 5e-5))
}

/// QAT finetune with an explicit learning rate (Fig. 3 uses a hotter one
/// to surface the instability the paper reports).
fn finetune_lr(
    rt: &Runtime,
    size: &str,
    variant: &str,
    base: &[Tensor],
    cfg: &Config,
    lr: f32,
) -> Result<(Vec<Tensor>, Vec<crate::coordinator::StepMetrics>)> {
    let steps = cfg.usize_or("diff.qat_steps", 150);
    let seed = cfg.u64_or("seed", 42);
    let train_art = format!("diff_train_{variant}_{size}");
    let meta = rt.meta(&train_art)?;
    let batch = meta.usize_field("batch").ok_or_else(|| anyhow!("batch"))?;
    let model = meta.raw.get("model").clone();
    let frames = model.get("frames").as_usize().unwrap();
    let latent_dim = model.get("latent_dim").as_usize().unwrap();
    println!("[qat] finetuning diffusion '{variant}' for {steps} steps...");
    let mut trainer = Trainer::new(
        rt,
        &format!("diff_init_{size}"),
        &train_art,
        seed as i32,
        LrSchedule::Constant(lr),
    )?
    .with_params(base.to_vec())?;
    let mut gen = LatentGen::new(seed ^ 0xd1ff, frames, latent_dim);
    trainer.run(
        steps,
        (steps / 5).max(1),
        |_| gen.next_batch(batch).values().to_vec(),
        |m| println!("  [{variant}] step {:>4} loss {:.4} gnorm {:.3}", m.step, m.loss, m.grad_norm),
    )?;
    Ok((trainer.state.params.clone(), trainer.history))
}

fn metric_row(label: &str, m: &VideoMetrics) -> Vec<String> {
    let mut row = vec![label.to_string()];
    row.extend(m.row().iter().map(|&x| f4(x)));
    row
}

const HEADER: [&str; 9] = [
    "Exp.",
    "Imaging Quality",
    "Aesthetic Quality",
    "Subject Consistency",
    "Background Consistency",
    "Temporal Flickering",
    "Motion Smoothness",
    "Dynamic Degree",
    "Overall",
];

/// Table 1: base-size model, rows BF16 / FP4 / SageAttention3 / Attn-QAT.
pub fn table1(rt: &Runtime, cfg: &Config) -> Result<()> {
    let size = cfg.str_or("diff.table1_size", "base");
    run_vbench_table(
        rt,
        cfg,
        &size,
        "table1_diffusion",
        &format!("Table 1 (proxy): VBench-proxy on diffusion '{size}' model"),
        &[("1 BF16 (f32)", "f32", false), ("2 FP4", "fp4", false), ("3 SageAttention3", "sage3", false), ("4 Attn-QAT", "qat", true)],
    )
}

/// Table 2: small model with the full ablation set (rows 1–8).
pub fn table2(rt: &Runtime, cfg: &Config) -> Result<()> {
    let size = cfg.str_or("diff.table2_size", "small");
    run_vbench_table(
        rt,
        cfg,
        &size,
        "table2_diffusion",
        &format!("Table 2 (proxy): VBench-proxy + ablations on diffusion '{size}' model"),
        &[
            ("1 BF16 (f32)", "f32", false),
            ("2 FP4", "fp4", false),
            ("3 SageAttention3", "sage3", false),
            ("4 Attn-QAT", "qat", true),
            ("5 + SmoothK", "qat_smoothk", true),
            ("6 + Two-level quant P", "qat_twolevel", true),
            ("7 - High prec. O in BWD", "qat_no_o_prime", true),
            ("8 - Fake quant of P in BWD", "qat_no_fq_p", true),
        ],
    )
}

fn run_vbench_table(
    rt: &Runtime,
    cfg: &Config,
    size: &str,
    out_name: &str,
    title: &str,
    rows_spec: &[(&str, &str, bool)],
) -> Result<()> {
    let ctx = DiffCtx::new(rt, size, cfg)?;
    let n_clips = cfg.usize_or("diff.eval_clips", 32);
    let (_, ref_stats) = ctx.reference(n_clips.max(64));
    let base = ensure_diff_base(rt, size, cfg)?;

    let mut rows = Vec::new();
    for &(label, variant, needs_training) in rows_spec {
        let params = if needs_training {
            finetune(rt, size, variant, &base, cfg)?.0
        } else {
            base.clone()
        };
        let clips = ctx.sample_clips(rt, variant, &params, n_clips, 0)?;
        let m = ctx.metrics(&clips, n_clips, &ref_stats);
        println!("[{out_name}] {label}: overall {:.4}", m.overall);
        rows.push(metric_row(label, &m));
    }
    write_table(out_name, title, &HEADER, &rows)
}

/// Figure 1 (proxy): dump sample clips per variant + per-clip metric table.
pub fn fig1(rt: &Runtime, cfg: &Config) -> Result<()> {
    let size = cfg.str_or("diff.table2_size", "small");
    let ctx = DiffCtx::new(rt, &size, cfg)?;
    let base = ensure_diff_base(rt, &size, cfg)?;
    let (qat_params, _) = finetune(rt, &size, "qat", &base, cfg)?;
    let n = 4;
    let (_, ref_stats) = ctx.reference(64);
    let dir = super::common::results_dir().join("fig1_samples");
    std::fs::create_dir_all(&dir)?;
    let mut rows = Vec::new();
    for (label, variant, params) in [
        ("BF16", "f32", &base),
        ("FP4", "fp4", &base),
        ("SageAttention3", "sage3", &base),
        ("Attn-QAT", "qat", &qat_params),
    ] {
        let clips = ctx.sample_clips(rt, variant, params, n, 7)?;
        // CSV dump: frames × dims per clip (the "video demo" stand-in).
        for c in 0..n {
            let mut csv = String::new();
            for t in 0..ctx.frames {
                let row: Vec<String> = (0..ctx.latent_dim)
                    .map(|j| format!("{:.5}", clips[(c * ctx.frames + t) * ctx.latent_dim + j]))
                    .collect();
                csv.push_str(&row.join(","));
                csv.push('\n');
            }
            std::fs::write(dir.join(format!("{label}_{c}.csv")), csv)?;
        }
        let m = ctx.metrics(&clips, n, &ref_stats);
        rows.push(metric_row(label, &m));
    }
    write_table(
        "fig1_samples",
        "Figure 1 (proxy): qualitative sample metrics (clips dumped to results/fig1_samples/)",
        &HEADER,
        &rows,
    )
}

/// Figure 2 (proxy): automated win/tie/lose judge over 99 seeds.
pub fn fig2(rt: &Runtime, cfg: &Config) -> Result<()> {
    let size = cfg.str_or("diff.table2_size", "small");
    let ctx = DiffCtx::new(rt, &size, cfg)?;
    let n = cfg.usize_or("fig2.prompts", 99);
    let base = ensure_diff_base(rt, &size, cfg)?;
    let (qat_params, _) = finetune(rt, &size, "qat", &base, cfg)?;
    let (_, ref_stats) = ctx.reference(64);
    let a = ctx.sample_clips(rt, "qat", &qat_params, n, 3)?;
    let b = ctx.sample_clips(rt, "f32", &base, n, 3)?;
    let eps = cfg.f32_or("fig2.tie_band", 0.01);
    let o = judge_pairwise(&a, &b, n, ctx.frames, ctx.latent_dim, &ref_stats, eps);
    write_table(
        "fig2_judge",
        "Figure 2 (proxy): Attn-QAT vs BF16, automated judge over 99 seeds",
        &["Comparison", "Win", "Tie", "Lose"],
        &[vec![
            "Attn-QAT vs BF16".to_string(),
            o.wins.to_string(),
            o.ties.to_string(),
            o.losses.to_string(),
        ]],
    )
}

/// Final-loss / max-gnorm / gnorm-std summary row for a Fig-3 curve.
fn dynamics_row(label: &str, hist: &[StepMetrics]) -> Vec<String> {
    let max_gnorm = hist.iter().map(|m| m.grad_norm).fold(0.0f32, f32::max);
    let gnorm_std = {
        let g: Vec<f32> = hist.iter().map(|m| m.grad_norm).filter(|g| g.is_finite()).collect();
        let mean = g.iter().sum::<f32>() / g.len().max(1) as f32;
        (g.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / g.len().max(1) as f32).sqrt()
    };
    let final_loss = hist.last().map(|m| m.loss).unwrap_or(f32::NAN);
    vec![label.to_string(), f4(final_loss), f4(max_gnorm), f4(gnorm_std)]
}

/// The four Figure-3 ablation curves (labels shared by both drivers).
const FIG3_CURVES: [(&str, &str); 4] = [
    ("Attn-QAT", "qat"),
    ("- High prec. O in BWD", "qat_no_o_prime"),
    ("- Fake quant P in BWD", "qat_no_fq_p"),
    ("naive drop-in (FP4 fwd + stock bwd)", "fp4"),
];

/// Figure 3 (a, b): training dynamics under the backward ablations.
pub fn fig3_dynamics(rt: &Runtime, cfg: &Config) -> Result<()> {
    let size = cfg.str_or("diff.table2_size", "small");
    let base = ensure_diff_base(rt, &size, cfg)?;
    let mut series = Vec::new();
    let mut rows = Vec::new();
    let fig3_lr = cfg.f32_or("fig3.lr", 1e-3);
    for (label, variant) in FIG3_CURVES {
        let (_, hist) = finetune_lr(rt, &size, variant, &base, cfg, fig3_lr)?;
        rows.push(dynamics_row(label, &hist));
        series.push((label.to_string(), hist));
    }
    // Distinct name for the raw series: write_table also emits a .json
    // twin, which used to clobber the history file of the same name.
    write_history("fig3_dynamics_series", &series)?;
    write_table(
        "fig3_dynamics",
        "Figure 3 (a,b) (proxy): diffusion QAT training dynamics (full series in results/fig3_dynamics_series.json)",
        &["Config", "Final loss", "Max grad-norm", "Grad-norm std"],
        &rows,
    )
}

/// Figure 3 (a, b) without the XLA runtime: the same four ablation curves
/// on the native `qat` trainer (packed-FP4 recomputed backward vs drop-in),
/// runnable from a bare `cargo run -- exp fig3`. The qualitative result —
/// drop-in spikes/diverges, Attn-QAT stays stable at the same hot lr — is
/// pinned by `qat::trainer`'s tests.
pub fn fig3_dynamics_native(cfg: &Config) -> Result<()> {
    let steps = cfg.usize_or("fig3.native_steps", 150);
    let lr = cfg.f32_or("fig3.native_lr", 0.2);
    let seed = cfg.u64_or("seed", 42);
    let mut series = Vec::new();
    let mut rows = Vec::new();
    for (label, variant) in FIG3_CURVES {
        let attn = AttnConfig::parse(variant).expect("fig3 variant");
        println!("[fig3-native] training '{label}' for {steps} steps (lr {lr})...");
        let tc = TrainerConfig { lr, seed, ..TrainerConfig::default() };
        let mut trainer = AttnRegressor::session(tc, attn);
        trainer.run(steps, (steps / 5).max(1), |m| {
            println!(
                "  [{label}] step {:>4} loss {:.4} gnorm {:.3}",
                m.step, m.loss, m.grad_norm
            )
        });
        if trainer.diverged() {
            println!("  [{label}] diverged (expected for drop-in) — recorded as data");
        }
        rows.push(dynamics_row(label, &trainer.history));
        series.push((label.to_string(), trainer.history));
    }
    write_history("fig3_dynamics_series", &series)?;
    write_table(
        "fig3_dynamics",
        "Figure 3 (a,b) (native): QAT training dynamics, native trainer (full series in results/fig3_dynamics_series.json)",
        &["Config", "Final loss", "Max grad-norm", "Grad-norm std"],
        &rows,
    )
}
