//! Experiment drivers: one per paper table/figure (DESIGN.md §1 index).
//!
//! Each driver is callable from the CLI (`repro exp <id>`) and writes both
//! a human-readable markdown table under `results/` and a JSON twin for
//! downstream tooling. Step budgets and sizes come from `config::Config`
//! (CPU-friendly defaults; scale up via `-s` overrides or a config file).

pub mod cluster;
pub mod common;
pub mod consistency;
pub mod diffusion;
pub mod fullstack;
pub mod kernels;
pub mod llm;

use anyhow::{bail, Result};

use crate::config::Config;
use crate::runtime::Runtime;

/// Run one experiment by its paper id.
pub fn run(rt: &Runtime, id: &str, cfg: &Config) -> Result<()> {
    match id {
        "table1" => diffusion::table1(rt, cfg),
        "table2" => diffusion::table2(rt, cfg),
        "table3" => llm::table3(rt, cfg),
        "table4" => llm::table4(rt, cfg),
        "fig1" => diffusion::fig1(rt, cfg),
        "fig2" => diffusion::fig2(rt, cfg),
        "fig3" => {
            diffusion::fig3_dynamics(rt, cfg)?;
            llm::fig3c(rt, cfg)?;
            llm::fig3_probes(cfg)
        }
        "fig4" => consistency::fig4(rt, cfg),
        "fig5" => kernels::fig5(rt, cfg),
        // Serving-side scale-out study; native models, no artifacts used.
        "cluster" => cluster::cluster_scaling(cfg),
        // Fault-injected serving: zero lost requests + bitwise replay.
        "faults" => cluster::fault_tolerance(cfg),
        // Full-stack FP4 training ablation grid; native models only.
        "fullstack" => fullstack::fullstack_ablation(cfg),
        "all" => {
            for id in [
                "table2", "table1", "table4", "table3", "fig1", "fig2", "fig3", "fig4", "fig5",
                "cluster", "faults", "fullstack",
            ] {
                println!("\n===== {id} =====");
                run(rt, id, cfg)?;
            }
            Ok(())
        }
        other => bail!(
            "unknown experiment '{other}' (table1-4, fig1-5, cluster, faults, fullstack, all)"
        ),
    }
}

/// Artifact-free dispatch: the subset of experiments that run on the
/// native `qat` subsystem alone. `main` falls back here when the PJRT
/// runtime is unavailable (the stub `xla` backend), so `cargo run -- exp
/// fig3` reproduces the paper's training-dynamics result out of the box.
pub fn run_native(id: &str, cfg: &Config) -> Result<()> {
    match id {
        "fig3" => {
            diffusion::fig3_dynamics_native(cfg)?;
            llm::fig3c_native(cfg)?;
            llm::fig3_probes(cfg)
        }
        "cluster" => cluster::cluster_scaling(cfg),
        "faults" => cluster::fault_tolerance(cfg),
        "fullstack" => fullstack::fullstack_ablation(cfg),
        "all" => {
            println!(
                "(native mode: only fig3, cluster, faults, and fullstack run without artifacts)"
            );
            run_native("fig3", cfg)?;
            run_native("cluster", cfg)?;
            run_native("faults", cfg)?;
            run_native("fullstack", cfg)
        }
        other => bail!(
            "experiment '{other}' needs compiled HLO artifacts and a real PJRT backend \
             (the stub xla crate is active); only 'fig3', 'cluster', 'faults', and \
             'fullstack' have native paths"
        ),
    }
}
