//! Causal trace plumbing over the span ring: cross-thread
//! [`TraceContext`], Chrome trace-event export, and a span-tree
//! self-profiler.
//!
//! PR 7's spans were a flat ring of parentless records; this module gives
//! every span a `trace_id`/`span_id`/`parent_id` triple so one request's
//! lifecycle — submit → route → queue wait → admit (incl. prefix attach
//! and COW split) → prefill → sampled per-token decode → finish, plus
//! supervisor replays tagged with the shard incarnation — reconstructs as
//! a tree across threads.
//!
//! Two parenting mechanisms compose:
//!
//! * **Implicit (same thread):** every open span installs itself as the
//!   thread's *current* context; a plain [`super::SpanRecorder::start`]
//!   (or the [`crate::span!`] macro) parents to whatever is current, so
//!   nested guards form a tree with zero call-site changes
//!   (`train.step` → `train.forward` → ...). Guards must drop in LIFO
//!   order (they are stack scoped everywhere in this crate).
//! * **Explicit (cross thread):** a [`TraceContext`] is `Copy` and rides
//!   a message — `serve::Request` carries the root context created at
//!   submit through the cluster channel into the shard worker, where
//!   [`super::SpanRecorder::start_child`] / `record_at` re-anchor spans
//!   under the request's root.
//!
//! Span ids are allocated from a process-global atomic (never 0 for a
//! recorded span); `trace_id` 0 marks spans outside any request trace
//! (e.g. per-step batch spans). On top of the annotated ring this module
//! offers [`chrome_trace`] (Perfetto-loadable trace-event JSON, one `tid`
//! row per trace), [`self_time`] (inclusive/exclusive per-name
//! aggregation), and [`flamegraph_lines`] (inferno-compatible collapsed
//! stacks).

use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::json::Json;
use crate::telemetry::span::SpanRecord;

/// Position in a trace tree: the id of the trace plus the span a child
/// should parent to. `Copy` by design — it crosses threads inside
/// `serve::Request` and the supervisor's replay journal. The default
/// (all-zero) context means "untraced"; spans parented to it become
/// roots.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceContext {
    /// Trace (request) id; 0 = not part of a request trace.
    pub trace_id: u64,
    /// Span id children should use as `parent_id`; 0 = no parent.
    pub span_id: u64,
    /// Start of the context's span, µs since the recorder epoch — lets a
    /// downstream thread measure "time since the root opened" (queue
    /// wait) without a second clock exchange.
    pub start_us: u64,
}

impl TraceContext {
    pub const NONE: TraceContext = TraceContext { trace_id: 0, span_id: 0, start_us: 0 };

    /// True when this context points at a real open/recorded span.
    pub fn is_some(&self) -> bool {
        self.span_id != 0
    }
}

static NEXT_TRACE_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);

pub(crate) fn next_trace_id() -> u64 {
    NEXT_TRACE_ID.fetch_add(1, Ordering::Relaxed)
}

pub(crate) fn next_span_id() -> u64 {
    NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed)
}

thread_local! {
    static CURRENT: Cell<TraceContext> = Cell::new(TraceContext::NONE);
}

/// The innermost open span on this thread (what a plain `start` parents
/// to); [`TraceContext::NONE`] outside any span.
pub fn current() -> TraceContext {
    CURRENT.with(|c| c.get())
}

pub(crate) fn set_current(ctx: TraceContext) {
    CURRENT.with(|c| c.set(ctx));
}

/// Render spans as Chrome trace-event JSON (the `chrome://tracing` /
/// Perfetto "JSON Array Format"): one `ph:"X"` complete event per span,
/// `ts`/`dur` in µs on the recorder's epoch clock, `tid` = `trace_id` so
/// each request trace gets its own row, and the causal triple under
/// `args` so tooling (and `rust/tests/trace.rs`) can round-trip the tree.
pub fn chrome_trace(records: &[SpanRecord]) -> Json {
    let events: Vec<Json> = records
        .iter()
        .map(|r| {
            let mut args = vec![
                ("seq", Json::Num(r.seq as f64)),
                ("trace_id", Json::Num(r.trace_id as f64)),
                ("span_id", Json::Num(r.span_id as f64)),
                ("parent_id", Json::Num(r.parent_id as f64)),
            ];
            if !r.tag_key.is_empty() {
                args.push((r.tag_key, Json::Num(r.tag as f64)));
            }
            Json::obj(vec![
                ("name", Json::Str(r.name.to_string())),
                ("cat", Json::Str(if r.trace_id != 0 { "request" } else { "span" }.to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(r.start_us as f64)),
                ("dur", Json::Num(r.dur_us as f64)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(r.trace_id as f64)),
                ("args", Json::obj(args)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// One row of the self-profiler: per span name, how often it ran, its
/// inclusive wall time, and its exclusive self time (inclusive minus the
/// summed durations of direct children — clamped at zero, since
/// cross-thread children like queue wait can overlap their parent).
#[derive(Clone, Debug)]
pub struct ProfileRow {
    pub name: &'static str,
    pub count: u64,
    /// Inclusive µs: sum of span durations.
    pub total_us: u64,
    /// Exclusive µs: inclusive minus direct children.
    pub self_us: u64,
}

/// Sum of direct-child durations keyed by parent span id.
fn child_us(records: &[SpanRecord]) -> BTreeMap<u64, u64> {
    let mut by_parent: BTreeMap<u64, u64> = BTreeMap::new();
    for r in records {
        if r.parent_id != 0 {
            *by_parent.entry(r.parent_id).or_insert(0) += r.dur_us;
        }
    }
    by_parent
}

/// Fold spans into an inclusive/exclusive self-time table, one row per
/// span name, sorted by exclusive time (descending).
pub fn self_time(records: &[SpanRecord]) -> Vec<ProfileRow> {
    let kids = child_us(records);
    let mut rows: BTreeMap<&'static str, ProfileRow> = BTreeMap::new();
    for r in records {
        let self_us = r.dur_us.saturating_sub(kids.get(&r.span_id).copied().unwrap_or(0));
        let e = rows
            .entry(r.name)
            .or_insert(ProfileRow { name: r.name, count: 0, total_us: 0, self_us: 0 });
        e.count += 1;
        e.total_us += r.dur_us;
        e.self_us += self_us;
    }
    let mut rows: Vec<ProfileRow> = rows.into_values().collect();
    rows.sort_by(|a, b| b.self_us.cmp(&a.self_us).then(a.name.cmp(b.name)));
    rows
}

/// Collapse spans into inferno-compatible flamegraph lines:
/// `root;child;leaf <self_us>`, aggregated over equal stacks. Spans whose
/// parent was evicted from the ring fold as roots of their own stacks.
pub fn flamegraph_lines(records: &[SpanRecord]) -> Vec<String> {
    let by_id: BTreeMap<u64, &SpanRecord> = records.iter().map(|r| (r.span_id, r)).collect();
    let kids = child_us(records);
    let mut agg: BTreeMap<String, u64> = BTreeMap::new();
    for r in records {
        let mut path = vec![r.name];
        let mut parent = r.parent_id;
        // Depth cap guards against id collisions corrupting the walk.
        for _ in 0..64 {
            match by_id.get(&parent) {
                Some(p) => {
                    path.push(p.name);
                    parent = p.parent_id;
                }
                None => break,
            }
            if parent == 0 {
                break;
            }
        }
        path.reverse();
        let self_us = r.dur_us.saturating_sub(kids.get(&r.span_id).copied().unwrap_or(0));
        *agg.entry(path.join(";")).or_insert(0) += self_us;
    }
    agg.into_iter().map(|(stack, us)| format!("{stack} {us}")).collect()
}

/// Render [`self_time`] rows as an aligned text table (`serve profile`).
pub fn profile_table(rows: &[ProfileRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<24} {:>8} {:>12} {:>12} {:>12}\n",
        "span", "count", "incl_ms", "excl_ms", "excl_avg_ms"
    ));
    for r in rows {
        let incl = r.total_us as f64 / 1000.0;
        let excl = r.self_us as f64 / 1000.0;
        out.push_str(&format!(
            "{:<24} {:>8} {:>12.3} {:>12.3} {:>12.4}\n",
            r.name,
            r.count,
            incl,
            excl,
            excl / r.count.max(1) as f64
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(
        name: &'static str,
        trace_id: u64,
        span_id: u64,
        parent_id: u64,
        start_us: u64,
        dur_us: u64,
    ) -> SpanRecord {
        SpanRecord {
            seq: span_id,
            name,
            tag_key: "",
            tag: 0,
            trace_id,
            span_id,
            parent_id,
            start_us,
            dur_us,
        }
    }

    /// request(100µs) -> { prefill(60µs) -> quant(20µs), decode(30µs) }
    fn tree() -> Vec<SpanRecord> {
        vec![
            rec("request", 1, 10, 0, 0, 100),
            rec("prefill", 1, 11, 10, 5, 60),
            rec("quant", 1, 12, 11, 10, 20),
            rec("decode", 1, 13, 10, 70, 30),
        ]
    }

    #[test]
    fn self_time_subtracts_direct_children() {
        let rows = self_time(&tree());
        let get = |n: &str| rows.iter().find(|r| r.name == n).unwrap().clone();
        assert_eq!(get("request").total_us, 100);
        assert_eq!(get("request").self_us, 100 - 60 - 30);
        assert_eq!(get("prefill").self_us, 60 - 20);
        assert_eq!(get("quant").self_us, 20);
        assert_eq!(get("decode").self_us, 30);
        // Sorted by exclusive time, descending.
        assert!(rows.windows(2).all(|w| w[0].self_us >= w[1].self_us));
    }

    #[test]
    fn flamegraph_lines_collapse_stacks() {
        let lines = flamegraph_lines(&tree());
        assert!(lines.contains(&"request 10".to_string()));
        assert!(lines.contains(&"request;prefill 40".to_string()));
        assert!(lines.contains(&"request;prefill;quant 20".to_string()));
        assert!(lines.contains(&"request;decode 30".to_string()));
    }

    #[test]
    fn chrome_trace_round_trips_the_tree() {
        let doc = chrome_trace(&tree());
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let events = parsed.get("traceEvents").as_arr().unwrap();
        assert_eq!(events.len(), 4);
        for ev in events {
            assert_eq!(ev.get("ph").as_str(), Some("X"));
            assert!(ev.get("ts").as_f64().is_some());
            assert!(ev.get("dur").as_f64().is_some());
        }
        // Parent chain of the deepest span resolves to the request root.
        let quant = events.iter().find(|e| e.get("name").as_str() == Some("quant")).unwrap();
        let mut parent = quant.get("args").get("parent_id").as_f64().unwrap();
        let mut hops = 0;
        while parent != 0.0 {
            let p = events
                .iter()
                .find(|e| e.get("args").get("span_id").as_f64() == Some(parent))
                .expect("parent present");
            parent = p.get("args").get("parent_id").as_f64().unwrap();
            hops += 1;
        }
        assert_eq!(hops, 2, "quant -> prefill -> request");
    }

    #[test]
    fn profile_table_lists_every_name() {
        let table = profile_table(&self_time(&tree()));
        for name in ["request", "prefill", "quant", "decode"] {
            assert!(table.contains(name), "{table}");
        }
    }
}
