//! Unified observability: metrics registry + JSON reflection + tracing
//! spans.
//!
//! One [`Telemetry`] handle (cheaply cloneable — everything inside is
//! `Arc`-shared) is threaded through a cluster or train session; every
//! component publishes into the same [`Registry`] and span ring, and
//! [`Telemetry::snapshot`] reflects registry + live config into **one
//! schema-versioned JSON document** (the rhai `export_to_json`
//! reflections idiom): what `serve cluster --json`, `serve stats`,
//! `DecodeCluster::introspect`, and the `--stats-every-ms` periodic
//! writer all emit, and what `rust/tests/telemetry.rs` pins as a golden
//! schema. The typed stat structs (`serve::ClusterStats`,
//! `coordinator::StepMetrics`) remain the bitwise facades existing tests
//! consume; the registry carries the same values under stable names.
//!
//! # Metric-name map
//!
//! | name | kind | published by |
//! |------|------|--------------|
//! | `serve.shard{i}.queue_depth` | gauge | `ShardWorker::step` (live backlog) |
//! | `serve.shard{i}.active` | gauge | `ShardWorker::step` (occupied decode lanes) |
//! | `serve.shard{i}.requests` | counter | `ShardWorker::stats` (admitted requests) |
//! | `serve.shard{i}.rejected` | counter | `ShardWorker::stats` |
//! | `serve.shard{i}.steps` | counter | `ShardWorker::stats` (decode passes) |
//! | `serve.shard{i}.tokens` | counter | `ShardWorker::step` live, finalized in `stats` |
//! | `serve.shard{i}.tokens_per_s` | gauge | `ShardWorker::stats` |
//! | `serve.shard{i}.p50_token_ms` / `.p99_token_ms` / `.ewma_token_ms` | gauge | `ShardWorker::stats` |
//! | `serve.shard{i}.token_ms` | histogram | `ShardWorker::step` (per-lane per-pass) |
//! | `serve.shard{i}.qcache_hits` / `.qcache_misses` | gauge | `ShardWorker` (summed over engine lanes) |
//! | `serve.shard{i}.qcache_hit_rate` | gauge | `ShardWorker` (hits / lookups) |
//! | `serve.shard{i}.kv_bytes` | gauge | `ShardWorker` (live KV occupancy) |
//! | `serve.shard{i}.kv_bytes_peak` / `.kv_bytes_f32_equiv_peak` | gauge | `ShardWorker::stats` |
//! | `serve.shard{i}.admit_ms_mean` | gauge | `ShardWorker::stats` (mean admission wall ms) |
//! | `serve.shard{i}.kv_admit_bytes_per_seq` | gauge | `ShardWorker::stats` (fresh KV bytes per admitted seq) |
//! | `serve.shard{i}.pool.pages` / `.pool.shared_pages` | gauge | `ShardWorker` (live / multiply-referenced sealed pages) |
//! | `serve.shard{i}.pool.spilled_pages` / `.pool.resident_bytes` | gauge | `ShardWorker` (disk-spill occupancy) |
//! | `serve.prefix.lookup_hits` | counter | `ShardWorker::admit` (prompts that attached ≥1 sealed page) |
//! | `serve.prefix.pages_shared` | counter | `ShardWorker::admit` (per-head page refs attached, not recomputed) |
//! | `serve.prefix.bytes_saved` | counter | `ShardWorker::admit` (packed bytes served by refcount instead of fresh quantization) |
//! | `serve.prefix.cow_splits` | counter | `ShardWorker::admit` (admissions diverging mid-trie: copy-on-write attach) |
//! | `serve.prefix.spilled_pages` | counter | `ShardWorker::step` (cold sealed pages written to `--kv-spill-dir`) |
//! | `serve.cluster.submitted` | counter | `DecodeCluster::submit` |
//! | `serve.cluster.shed_deadline` / `.shed_capacity` | counter | `DecodeCluster` admission |
//! | `serve.cluster.submit_retries` | counter | `DecodeCluster` backpressure loop |
//! | `serve.slo.slack_ms` | histogram | `DecodeCluster::drain` (deadline − wall, deadline met) |
//! | `serve.slo.overrun_ms` | histogram | `DecodeCluster::drain` (wall − deadline, deadline missed) |
//! | `serve.slo.deadlines_met` | counter | `DecodeCluster::drain` |
//! | `serve.slo.false_admit` | counter | `DecodeCluster::drain` (admitted as feasible, missed its deadline) |
//! | `serve.slo.false_shed` | counter | `DecodeCluster::drain` (shed as infeasible, hindsight EWMA says its own cost fit) |
//! | `telemetry.spans_dropped` | counter | `Telemetry::snapshot` (span-ring evictions — nonzero ⇒ truncated trace) |
//! | `serve.supervisor.restarts` | counter | `Supervisor::respawn_and_replay` |
//! | `serve.supervisor.replayed_requests` | counter | `Supervisor::respawn_and_replay` |
//! | `serve.supervisor.recomputed_passes` | counter | `Supervisor::respawn_and_replay` |
//! | `train.steps` | counter | `TrainSession::step` |
//! | `train.rollbacks` | counter | `TrainSession::step` (watchdog) |
//! | `train.loss` / `train.grad_norm` / `train.lr` | gauge | `TrainSession::step` |
//! | `train.step_ms` | histogram | `TrainSession::step` |
//! | `train.layer{l}.grad_norm` | gauge | `LmTrainTask` probe (every K steps) |
//! | `train.layer{l}.q_sat_frac` / `.k_sat_frac` / `.v_sat_frac` | gauge | `LmTrainTask` probe ([`probes::e2m1_health`]) |
//! | `train.layer{l}.scale_range` | gauge | `LmTrainTask` probe (per-block scale spread) |
//!
//! # Trace schema
//!
//! Every span carries a causal triple (`trace_id`, `span_id`,
//! `parent_id` — see [`trace::TraceContext`]), so the ring reconstructs
//! as a forest. Span names (ring-buffered, see [`SpanRecorder`]):
//!
//! * **Per request** (one trace per submitted request, rooted on the
//!   submit thread and continued inside the shard worker): `request`
//!   (root, tagged `req` = id) → `route`, `queue` (channel + backlog
//!   wait), `admit` (tagged `shard`; children `prefix.attach`,
//!   `prefix.cow`, `prefill`), sampled `decode.token` (first decode pass
//!   of a sequence, then every 4th), `finish`, and — after a fault —
//!   `replay` (tagged `incarnation` = the shard's restart count).
//! * **Per shard step** (untraced batch spans, `trace_id` 0):
//!   `step.admit`, `step.decode` (tagged `shard`).
//! * **Serve-side, cluster scope:** `drain`; **train-side:** `train.step`
//!   → `train.forward`, `train.backward`, `train.clip`, `train.optim`
//!   (nested implicitly — same thread-local tree).
//!
//! # Schema
//!
//! `snapshot()` returns `{schema_version, enabled, config, metrics,
//! spans}`. `config` holds reflected live configuration (cluster shape,
//! attention variant, train hyperparameters) installed via
//! [`Telemetry::set_config`]; `metrics` is the registry rendered as a
//! nested tree (dotted names split on `.`); `spans` is
//! [`SpanRecorder::to_json`]. The schema is versioned and **additive
//! only**: removing or renaming a key requires bumping
//! [`SCHEMA_VERSION`], and the golden test in `rust/tests/telemetry.rs`
//! enforces the current shape.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::json::Json;

pub mod probes;
pub mod registry;
pub mod runmeta;
pub mod span;
pub mod trace;

pub use registry::{Counter, Gauge, Histogram, Metric, Registry};
pub use runmeta::{git_rev, runmeta, summarize_bench_dir};
pub use span::{SpanGuard, SpanRecord, SpanRecorder};
pub use trace::{chrome_trace, flamegraph_lines, profile_table, self_time, ProfileRow, TraceContext};

/// Version stamped into every snapshot document. Bump on any
/// non-additive schema change.
pub const SCHEMA_VERSION: u64 = 1;

/// One observability domain: registry + span ring + reflected config.
///
/// Clone freely — clones share state. Components take a `Telemetry` (or
/// pre-registered handles derived from one) at attach time and publish
/// unconditionally; the `disabled` constructor turns the span recorder
/// off and lets sampling sites skip probe work via
/// [`Telemetry::is_enabled`], so a disabled domain costs a few relaxed
/// atomic stores per pass and allocates nothing.
#[derive(Clone)]
pub struct Telemetry {
    enabled: Arc<AtomicBool>,
    registry: Registry,
    spans: SpanRecorder,
    config: Arc<Mutex<BTreeMap<String, Json>>>,
}

impl Telemetry {
    /// Enabled telemetry with the default span-ring capacity.
    pub fn new() -> Telemetry {
        Telemetry::with_span_capacity(SpanRecorder::DEFAULT_CAPACITY)
    }

    /// Enabled telemetry retaining the newest `capacity` spans.
    pub fn with_span_capacity(capacity: usize) -> Telemetry {
        Telemetry {
            enabled: Arc::new(AtomicBool::new(true)),
            registry: Registry::new(),
            spans: SpanRecorder::new(capacity),
            config: Arc::new(Mutex::new(BTreeMap::new())),
        }
    }

    /// Disabled telemetry: spans are no-ops, [`Telemetry::is_enabled`]
    /// gates sampling work off, handle publishes stay (cheap) atomic
    /// stores.
    pub fn disabled() -> Telemetry {
        let t = Telemetry::new();
        t.set_enabled(false);
        t
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
        self.spans.set_enabled(on);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn spans(&self) -> &SpanRecorder {
        &self.spans
    }

    /// Install (or replace) a reflected-config subtree, e.g.
    /// `set_config("cluster", cfg.to_json())`. Keys surface under the
    /// snapshot's `config` object.
    pub fn set_config(&self, key: &str, value: Json) {
        self.config.lock().unwrap().insert(key.to_string(), value);
    }

    /// Reflect everything into one schema-versioned JSON document (see
    /// module docs for the shape).
    pub fn snapshot(&self) -> Json {
        // Surface span-ring evictions as a registry counter so a
        // truncated trace is visible in the same document that carries
        // the span summary.
        self.registry.counter("telemetry.spans_dropped").set(self.spans.dropped());
        let mut metrics = BTreeMap::new();
        self.registry.visit(&mut |name, metric| {
            insert_path(&mut metrics, name, metric.to_json());
        });
        Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            ("enabled", Json::Bool(self.is_enabled())),
            ("config", Json::Obj(self.config.lock().unwrap().clone())),
            ("metrics", Json::Obj(metrics)),
            ("spans", self.spans.to_json()),
        ])
    }
}

impl Default for Telemetry {
    fn default() -> Telemetry {
        Telemetry::new()
    }
}

/// Insert `leaf` at the dotted `path` inside a nested object tree,
/// creating intermediate objects (and overwriting a non-object
/// intermediate — dotted names are expected to be prefix-free).
fn insert_path(root: &mut BTreeMap<String, Json>, path: &str, leaf: Json) {
    let mut segs: Vec<&str> = path.split('.').collect();
    let last = segs.pop().unwrap_or(path);
    let mut node = root;
    for seg in segs {
        let child = node.entry(seg.to_string()).or_insert_with(|| Json::Obj(BTreeMap::new()));
        if !matches!(child, Json::Obj(_)) {
            *child = Json::Obj(BTreeMap::new());
        }
        node = match child {
            Json::Obj(obj) => obj,
            _ => unreachable!("just normalized to an object"),
        };
    }
    node.insert(last.to_string(), leaf);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_nests_dotted_names() {
        let t = Telemetry::new();
        t.registry().counter("serve.shard0.tokens").add(7);
        t.registry().gauge("serve.shard0.queue_depth").set(3.0);
        t.registry().counter("train.steps").add(2);
        t.set_config("cluster", Json::obj(vec![("shards", Json::Num(4.0))]));
        let doc = t.snapshot();
        assert_eq!(doc.get("schema_version").as_f64(), Some(1.0));
        assert_eq!(doc.get("config").get("cluster").get("shards").as_f64(), Some(4.0));
        let shard0 = doc.get("metrics").get("serve").get("shard0");
        assert_eq!(shard0.get("tokens").as_f64(), Some(7.0));
        assert_eq!(shard0.get("queue_depth").as_f64(), Some(3.0));
        assert_eq!(doc.get("metrics").get("train").get("steps").as_f64(), Some(2.0));
    }

    #[test]
    fn clones_share_state() {
        let t = Telemetry::new();
        let u = t.clone();
        u.registry().counter("n").inc();
        assert_eq!(t.registry().counter("n").get(), 1);
        u.set_enabled(false);
        assert!(!t.is_enabled());
    }

    #[test]
    fn disabled_snapshot_still_reflects() {
        let t = Telemetry::disabled();
        t.registry().counter("c").add(5);
        let doc = t.snapshot();
        assert_eq!(doc.get("enabled"), &Json::Bool(false));
        assert_eq!(doc.get("metrics").get("c").as_f64(), Some(5.0));
    }
}
