//! Lock-cheap metrics registry: named [`Counter`] / [`Gauge`] /
//! [`Histogram`] handles behind atomics.
//!
//! Registration (name → handle) takes a mutex once, at attach time; the
//! publish path — a shard worker bumping `serve.shard3.tokens` per decode
//! pass, a train session setting `train.loss` per step — is a relaxed
//! atomic op with no lock and no allocation. Handles for the same name
//! share one cell: `counter("x")` called from N threads yields N clones of
//! a single atomic, so concurrent totals are exact (pinned by
//! `rust/tests/telemetry.rs`). Names are hierarchical dotted paths
//! (`serve.shard3.queue_depth`, `train.layer2.grad_norm`); the snapshot
//! API in [`super::Telemetry::snapshot`] splits them into a nested JSON
//! tree.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::json::Json;

/// Monotone event count (requests served, tokens emitted, restarts).
///
/// `set` exists for sites that publish an externally accumulated total
/// (e.g. `ShardWorker::stats` republishing its authoritative counters at
/// drain) — the registry view then matches the typed stats facade exactly.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    /// Overwrite with an absolute total (see type docs).
    pub fn set(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// "No sample yet" sentinel: `f64::from_bits(u64::MAX)` is a NaN payload
/// no arithmetic produces, so a never-set gauge is distinguishable from a
/// gauge legitimately set to `0.0` (the supervisor's `ewma_bits` idiom
/// uses bits 0 the same way — that works there because an EWMA sample is
/// never exactly `0.0`, which a queue-depth gauge very much can be).
const GAUGE_UNSET: u64 = u64::MAX;

/// Last-write-wins scalar sample (queue depth, loss, tokens/s), stored as
/// f64 bits in one atomic.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        let mut bits = v.to_bits();
        if bits == GAUGE_UNSET {
            bits = f64::NAN.to_bits();
        }
        self.0.store(bits, Ordering::Relaxed);
    }

    /// `None` until the first [`Gauge::set`].
    pub fn get(&self) -> Option<f64> {
        let bits = self.0.load(Ordering::Relaxed);
        if bits == GAUGE_UNSET {
            None
        } else {
            Some(f64::from_bits(bits))
        }
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge(Arc::new(AtomicU64::new(GAUGE_UNSET)))
    }
}

/// Power-of-two microsecond buckets: bucket `i` counts samples whose
/// microsecond value needs `i` bits, i.e. lies in `2^(i-1) ..= 2^i - 1`
/// (bucket 0 is the sub-microsecond bin). 40 buckets reach ~6.4 days.
const HIST_BUCKETS: usize = 40;

#[derive(Debug)]
struct HistogramCells {
    count: AtomicU64,
    /// f64 bits of the running sum, updated by CAS (contention on a
    /// histogram is a handful of publishers, not a hot loop).
    sum_bits: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

/// Latency distribution in milliseconds over log2-microsecond buckets:
/// `record` is two relaxed atomic adds plus one CAS; quantiles are bucket
/// **midpoints** (within one bucket width of the exact sorted-sample
/// value: at most 1.5× / at least 0.75× — ranking, not timing precision).
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistogramCells>);

impl Histogram {
    pub fn record(&self, ms: f64) {
        let ms = if ms.is_finite() { ms.max(0.0) } else { 0.0 };
        let us = (ms * 1000.0) as u64;
        let idx = (64 - us.leading_zeros() as usize).min(HIST_BUCKETS - 1);
        let cells = &*self.0;
        cells.count.fetch_add(1, Ordering::Relaxed);
        cells.buckets[idx].fetch_add(1, Ordering::Relaxed);
        let mut cur = cells.sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + ms).to_bits();
            let cas = cells.sum_bits.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            match cas {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded samples, ms.
    pub fn sum_ms(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// Bucket-**midpoint** estimate of quantile `q` (ms); `None` when no
    /// samples have been recorded.
    ///
    /// The rank is the same `round(q * (n-1))` a sorted-sample quantile
    /// would use; the ranked sample lies somewhere in its log2 bucket
    /// `[2^(i-1), 2^i)` µs, so reporting the bucket midpoint keeps the
    /// estimate within one bucket width of the exact value — in
    /// `[0.75, 1.5]×` (pinned by
    /// `quantile_midpoint_is_within_one_bucket_of_exact`).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let count = self.count();
        if count == 0 {
            return None;
        }
        let rank = (q.clamp(0.0, 1.0) * (count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, b) in self.0.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen > rank {
                return Some(Histogram::bucket_mid_ms(i));
            }
        }
        Some(Histogram::bucket_mid_ms(HIST_BUCKETS - 1))
    }

    /// Midpoint of log2-µs bucket `i`, in ms (bucket 0 is the sub-µs
    /// bin, reported as 0).
    fn bucket_mid_ms(i: usize) -> f64 {
        if i == 0 {
            return 0.0;
        }
        let lo = 1u64 << (i - 1);
        let hi = (1u64 << i) - 1;
        (lo + hi) as f64 / 2.0 / 1000.0
    }

    fn to_json(&self) -> Json {
        let count = self.count();
        Json::obj(vec![
            ("count", Json::Num(count as f64)),
            ("sum_ms", Json::Num(self.sum_ms())),
            ("p50_ms", self.quantile(0.5).map_or(Json::Null, Json::Num)),
            ("p99_ms", self.quantile(0.99).map_or(Json::Null, Json::Num)),
        ])
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram(Arc::new(HistogramCells {
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }))
    }
}

/// One registered metric (what [`Registry::visit`] yields).
#[derive(Clone)]
pub enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

impl Metric {
    /// Snapshot value: counters and set gauges are numbers, unset gauges
    /// are `null`, histograms are `{count, sum_ms, p50_ms, p99_ms}`.
    pub fn to_json(&self) -> Json {
        match self {
            Metric::Counter(c) => Json::Num(c.get() as f64),
            Metric::Gauge(g) => g.get().map_or(Json::Null, Json::Num),
            Metric::Histogram(h) => h.to_json(),
        }
    }
}

/// Name → metric map. Cloning shares the underlying map (`Arc`), so every
/// component attached to one [`super::Telemetry`] publishes into the same
/// registry.
#[derive(Clone, Default)]
pub struct Registry(Arc<Mutex<BTreeMap<String, Metric>>>);

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create the counter `name`. Panics if `name` is already
    /// registered as a different metric kind (a programming error — the
    /// metric-name map in the module docs is the single vocabulary).
    pub fn counter(&self, name: &str) -> Counter {
        let mut map = self.0.lock().unwrap();
        let m = map.entry(name.to_string()).or_insert_with(|| Metric::Counter(Counter::default()));
        match m {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric '{name}' already registered with a different kind"),
        }
    }

    /// Get-or-create the gauge `name` (see [`Registry::counter`]).
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut map = self.0.lock().unwrap();
        let m = map.entry(name.to_string()).or_insert_with(|| Metric::Gauge(Gauge::default()));
        match m {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric '{name}' already registered with a different kind"),
        }
    }

    /// Get-or-create the histogram `name` (see [`Registry::counter`]).
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut map = self.0.lock().unwrap();
        let m = map
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Histogram::default()));
        match m {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric '{name}' already registered with a different kind"),
        }
    }

    /// Visit every registered metric in name order (holds the registry
    /// lock for the duration — snapshot-path only).
    pub fn visit(&self, f: &mut dyn FnMut(&str, &Metric)) {
        for (name, metric) in self.0.lock().unwrap().iter() {
            f(name, metric);
        }
    }

    /// Registered names, in order (test/debug convenience).
    pub fn names(&self) -> Vec<String> {
        self.0.lock().unwrap().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_handles_share_one_cell() {
        let reg = Registry::new();
        let a = reg.counter("x.y");
        let b = reg.counter("x.y");
        a.add(3);
        b.inc();
        assert_eq!(a.get(), 4);
        assert_eq!(b.get(), 4);
    }

    #[test]
    fn gauge_distinguishes_unset_from_zero() {
        let reg = Registry::new();
        let g = reg.gauge("g");
        assert_eq!(g.get(), None);
        g.set(0.0);
        assert_eq!(g.get(), Some(0.0));
        g.set(-2.5);
        assert_eq!(g.get(), Some(-2.5));
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let reg = Registry::new();
        let _c = reg.counter("m");
        let _g = reg.gauge("m");
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64); // 1..=100 ms
        }
        assert_eq!(h.count(), 100);
        assert!((h.sum_ms() - 5050.0).abs() < 1e-9);
        let p50 = h.quantile(0.5).unwrap();
        let p99 = h.quantile(0.99).unwrap();
        // Bucket midpoints: within [0.75, 1.5]x of the exact quantiles
        // (51 ms at rank 50, 99 ms at rank 98), ordered.
        assert!(p50 >= 0.75 * 51.0 && p50 <= 1.5 * 51.0, "p50 {p50}");
        assert!(p99 >= 0.75 * 99.0 && p99 <= 1.5 * 99.0, "p99 {p99}");
        assert!(p50 <= p99);
        assert_eq!(h.quantile(0.0).unwrap(), h.quantile(1e-9).unwrap());
    }

    #[test]
    fn quantile_midpoint_is_within_one_bucket_of_exact() {
        // Mixed linear / geometric / bimodal sample sets: the midpoint
        // estimator must stay within one log2 bucket of the exact
        // sorted-sample quantile at every probed q, i.e. in [0.75, 1.5]x
        // (small slack below for the ms->µs truncation at record time).
        let cases: Vec<Vec<f64>> = vec![
            (1..=16).map(|i| i as f64).collect(),
            (0..12).map(|i| 0.5 * 1.9f64.powi(i)).collect(),
            vec![0.07, 0.07, 0.07, 250.0],
        ];
        for samples in cases {
            let h = Histogram::default();
            let mut sorted = samples.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for &s in &samples {
                h.record(s);
            }
            for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                let rank = (q * (sorted.len() - 1) as f64).round() as usize;
                let exact = sorted[rank];
                let est = h.quantile(q).unwrap();
                assert!(
                    est >= 0.74 * exact && est <= 1.51 * exact,
                    "q={q} exact={exact} est={est}"
                );
            }
        }
    }

    #[test]
    fn empty_histogram_has_no_quantiles() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.to_json().get("p99_ms"), &Json::Null);
    }
}
