//! Run-provenance headers for bench artifacts.
//!
//! Every `results/bench/*.jsonl` append and `BENCH_*.json` summary stamps
//! a [`runmeta`] header — git revision, bench name, free-form config
//! string, wall-clock timestamp — so the per-PR perf trajectory stays
//! attributable at re-anchor time: a jsonl row's provenance is the
//! nearest `{"kind":"runmeta",...}` line above it. Consumers filtering
//! result rows should skip objects whose `kind` is `"runmeta"` —
//! [`summarize_bench_dir`] (the `repro bench summary` subcommand) is the
//! canonical such consumer, folding every `results/bench/*.jsonl` into
//! one repo-root trajectory document.

use std::collections::BTreeMap;
use std::path::Path;
use std::process::Command;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::json::Json;

/// Best-effort short git revision of the working tree; `"unknown"` when
/// git or the repository is unavailable (e.g. a source-tarball build).
pub fn git_rev() -> String {
    Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|out| out.status.success())
        .and_then(|out| String::from_utf8(out.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Provenance header for bench run `bench` under `config` (a free-form
/// `key=value ...` string describing the run's parameters).
pub fn runmeta(bench: &str, config: &str) -> Json {
    let unix_ms = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as f64)
        .unwrap_or(0.0);
    Json::obj(vec![
        ("kind", Json::Str("runmeta".to_string())),
        ("bench", Json::Str(bench.to_string())),
        ("config", Json::Str(config.to_string())),
        ("git_rev", Json::Str(git_rev())),
        ("unix_ms", Json::Num(unix_ms)),
    ])
}

/// Aggregate every `*.jsonl` under `dir` (typically `results/bench/`)
/// into one trajectory summary: per bench file, how many runs (runmeta
/// headers) and result rows it holds, the provenance of the newest run,
/// the best `tok_per_s` seen, and the last result row verbatim. A
/// missing or empty directory degrades to an empty summary — the
/// trajectory can start accumulating before the first full bench run.
pub fn summarize_bench_dir(dir: &Path) -> Json {
    let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("jsonl"))
                .collect()
        })
        .unwrap_or_default();
    files.sort();
    let mut benches: BTreeMap<String, Json> = BTreeMap::new();
    for path in files {
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("bench").to_string();
        let text = std::fs::read_to_string(&path).unwrap_or_default();
        let mut runs = 0u64;
        let mut last_meta = Json::Null;
        let mut rows = 0u64;
        let mut last_row = Json::Null;
        let mut max_tok_per_s: Option<f64> = None;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            // Unparsable lines (partial writes, hand edits) are skipped,
            // not fatal — the trajectory survives a corrupt row.
            let Ok(v) = Json::parse(line) else { continue };
            if v.get("kind").as_str() == Some("runmeta") {
                runs += 1;
                last_meta = v;
            } else {
                rows += 1;
                if let Some(t) = v.get("tok_per_s").as_f64() {
                    max_tok_per_s = Some(max_tok_per_s.map_or(t, |m| m.max(t)));
                }
                last_row = v;
            }
        }
        benches.insert(
            stem,
            Json::obj(vec![
                ("runs", Json::Num(runs as f64)),
                ("rows", Json::Num(rows as f64)),
                ("last_git_rev", last_meta.get("git_rev").clone()),
                ("last_unix_ms", last_meta.get("unix_ms").clone()),
                ("max_tok_per_s", max_tok_per_s.map_or(Json::Null, Json::Num)),
                ("last_row", last_row),
            ]),
        );
    }
    Json::obj(vec![
        ("kind", Json::Str("bench_summary".to_string())),
        ("git_rev", Json::Str(git_rev())),
        ("benches", Json::Obj(benches)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_summary_aggregates_jsonl_rows() {
        let dir = std::env::temp_dir().join(format!("attnqat_benchsum_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("cluster_serve.jsonl"),
            concat!(
                "{\"kind\":\"runmeta\",\"bench\":\"cluster_serve\",\"config\":\"\",",
                "\"git_rev\":\"abc1234\",\"unix_ms\":5}\n",
                "{\"name\":\"fp4_4shard\",\"tok_per_s\":123.5}\n",
                "not json\n",
                "{\"name\":\"fp4_8shard\",\"tok_per_s\":150.25}\n",
            ),
        )
        .unwrap();
        let doc = summarize_bench_dir(&dir);
        let b = doc.get("benches").get("cluster_serve");
        assert_eq!(b.get("runs").as_f64(), Some(1.0));
        assert_eq!(b.get("rows").as_f64(), Some(2.0));
        assert_eq!(b.get("last_git_rev").as_str(), Some("abc1234"));
        assert_eq!(b.get("max_tok_per_s").as_f64(), Some(150.25));
        assert_eq!(b.get("last_row").get("name").as_str(), Some("fp4_8shard"));
        std::fs::remove_dir_all(&dir).ok();
        // A missing directory degrades to an empty summary, not an error.
        let empty = summarize_bench_dir(&dir);
        assert!(empty.get("benches").as_obj().unwrap().is_empty());
    }

    #[test]
    fn runmeta_has_the_pinned_header_shape() {
        let meta = runmeta("cluster_serve", "shards=4 requests=48");
        assert_eq!(meta.get("kind").as_str(), Some("runmeta"));
        assert_eq!(meta.get("bench").as_str(), Some("cluster_serve"));
        assert_eq!(meta.get("config").as_str(), Some("shards=4 requests=48"));
        let rev = meta.get("git_rev").as_str().unwrap();
        assert!(!rev.is_empty());
        assert!(meta.get("unix_ms").as_f64().is_some());
    }
}
