//! Run-provenance headers for bench artifacts.
//!
//! Every `results/bench/*.jsonl` append and `BENCH_*.json` summary stamps
//! a [`runmeta`] header — git revision, bench name, free-form config
//! string, wall-clock timestamp — so the per-PR perf trajectory stays
//! attributable at re-anchor time: a jsonl row's provenance is the
//! nearest `{"kind":"runmeta",...}` line above it. Consumers filtering
//! result rows should skip objects whose `kind` is `"runmeta"`.

use std::process::Command;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::json::Json;

/// Best-effort short git revision of the working tree; `"unknown"` when
/// git or the repository is unavailable (e.g. a source-tarball build).
pub fn git_rev() -> String {
    Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|out| out.status.success())
        .and_then(|out| String::from_utf8(out.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Provenance header for bench run `bench` under `config` (a free-form
/// `key=value ...` string describing the run's parameters).
pub fn runmeta(bench: &str, config: &str) -> Json {
    let unix_ms = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as f64)
        .unwrap_or(0.0);
    Json::obj(vec![
        ("kind", Json::Str("runmeta".to_string())),
        ("bench", Json::Str(bench.to_string())),
        ("config", Json::Str(config.to_string())),
        ("git_rev", Json::Str(git_rev())),
        ("unix_ms", Json::Num(unix_ms)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runmeta_has_the_pinned_header_shape() {
        let meta = runmeta("cluster_serve", "shards=4 requests=48");
        assert_eq!(meta.get("kind").as_str(), Some("runmeta"));
        assert_eq!(meta.get("bench").as_str(), Some("cluster_serve"));
        assert_eq!(meta.get("config").as_str(), Some("shards=4 requests=48"));
        let rev = meta.get("git_rev").as_str().unwrap();
        assert!(!rev.is_empty());
        assert!(meta.get("unix_ms").as_f64().is_some());
    }
}
