//! Quantization-health probes: the numeric side of the per-layer QAT
//! gauges (`train.layer{l}.*` — see the module docs in
//! [`super`]).
//!
//! *Full-Stack FP4* and *FP4 All the Way* (PAPERS.md) both argue FP4
//! instability is per-component and shows up in the quantizer statistics
//! before the loss diverges. [`e2m1_health`] measures exactly that over a
//! staged activation buffer: blocks are scaled the way every quantized
//! path in this repo scales them (per-16-element absmax mapped onto
//! [`e2m1::MAX`]), and the probe reports what fraction of elements land
//! on the top E2M1 code plus the spread of per-block scales. A layer
//! whose gradients are blowing up flattens its activation distribution —
//! `sat_frac` climbs and the scale range widens steps before the
//! watchdog's global grad-norm limit trips (see `exp fig3`'s
//! `fig3_probes.json`).

use crate::formats::e2m1;

/// Quantization block length shared by every packed path in the repo.
pub const QUANT_BLOCK: usize = 16;

/// Per-block E2M1 health statistics from [`e2m1_health`].
#[derive(Clone, Copy, Debug)]
pub struct QuantHealth {
    /// Fraction of (non-zero-block) elements encoding to the top
    /// magnitude code (±6 after scaling). Healthy bell-shaped blocks sit
    /// well below 1/16; a flattening distribution pushes this up.
    pub sat_frac: f32,
    /// Smallest per-block scale (absmax / 6) over non-zero blocks.
    pub scale_min: f32,
    /// Largest per-block scale over non-zero blocks.
    pub scale_max: f32,
    /// Non-zero blocks measured.
    pub blocks: usize,
}

impl QuantHealth {
    /// `scale_max / scale_min` (1.0 = uniform; 0.0 when nothing was
    /// measured) — the "P̃ scale range" style dynamic-range gauge.
    pub fn scale_range(&self) -> f32 {
        if self.blocks == 0 || self.scale_min <= 0.0 {
            0.0
        } else {
            self.scale_max / self.scale_min
        }
    }
}

/// Measure E2M1 block-quantization health of `x` (any staged activation
/// buffer — per-layer Q/K/V in practice), per 16-element block: scale =
/// absmax / [`e2m1::MAX`], an element is *saturated* when it rounds to
/// the top magnitude. All-zero or non-finite blocks are skipped.
pub fn e2m1_health(x: &[f32]) -> QuantHealth {
    let mut saturated = 0usize;
    let mut total = 0usize;
    let mut scale_min = f32::INFINITY;
    let mut scale_max = 0.0f32;
    let mut blocks = 0usize;
    for block in x.chunks(QUANT_BLOCK) {
        let absmax = block.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        if absmax == 0.0 || !absmax.is_finite() {
            continue;
        }
        let scale = absmax / e2m1::MAX;
        blocks += 1;
        scale_min = scale_min.min(scale);
        scale_max = scale_max.max(scale);
        for v in block {
            total += 1;
            if e2m1::encode(v / scale) & 0x7 == 0x7 {
                saturated += 1;
            }
        }
    }
    QuantHealth {
        sat_frac: if total == 0 { 0.0 } else { saturated as f32 / total as f32 },
        scale_min: if blocks == 0 { 0.0 } else { scale_min },
        scale_max,
        blocks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_zero_blocks_measure_nothing() {
        let h = e2m1_health(&[]);
        assert_eq!(h.blocks, 0);
        assert_eq!(h.sat_frac, 0.0);
        assert_eq!(h.scale_range(), 0.0);
        let h = e2m1_health(&[0.0; 32]);
        assert_eq!(h.blocks, 0);
    }

    #[test]
    fn absmax_always_saturates_and_midrange_does_not() {
        // One block: absmax 6.0 → scale 1.0. The 6.0 element encodes to
        // the top code; 3.0 encodes to code 5; tiny values to low codes.
        let mut block = [0.1f32; 16];
        block[0] = 6.0;
        block[1] = 3.0;
        block[2] = -6.0;
        let h = e2m1_health(&block);
        assert_eq!(h.blocks, 1);
        assert!((h.scale_min - 1.0).abs() < 1e-6);
        assert!((h.sat_frac - 2.0 / 16.0).abs() < 1e-6, "sat_frac {}", h.sat_frac);
    }

    #[test]
    fn scale_range_tracks_block_spread() {
        // Two blocks with absmax 6 and 0.6 → scales 1.0 and 0.1.
        let mut x = [0.01f32; 32];
        x[0] = 6.0;
        x[16] = 0.6;
        let h = e2m1_health(&x);
        assert_eq!(h.blocks, 2);
        assert!((h.scale_range() - 10.0).abs() < 1e-4, "range {}", h.scale_range());
    }

    #[test]
    fn flat_distribution_saturates_fully() {
        // Every element at the block absmax → everything on the top code.
        let x = [2.5f32; 16];
        let h = e2m1_health(&x);
        assert!((h.sat_frac - 1.0).abs() < 1e-6);
        assert_eq!(h.scale_range(), 1.0);
    }
}
