//! Ring-buffer tracing spans: scoped guards with a ~zero-cost disabled
//! path, annotated with causal `trace_id`/`span_id`/`parent_id` ids.
//!
//! A span is opened with the [`crate::span!`] macro (or
//! [`SpanRecorder::start`]) and closed by dropping the returned guard; the
//! recorder keeps the newest `capacity` records in a fixed ring (overflow
//! drops the oldest and bumps [`SpanRecorder::dropped`], so a truncated
//! trace never reads as a complete one). Names and tag keys are
//! `&'static str` and the guard lives on the stack, so a **disabled**
//! recorder's `start` is one relaxed atomic load — no allocation, no
//! `Instant::now` (pinned by the counting allocator test in
//! `rust/tests/telemetry.rs`). An **enabled** span costs two `Instant`
//! reads, two relaxed id allocations, a thread-local swap, and one short
//! mutex push at drop — fine at per-pass / per-step granularity
//! (admission, prefill, decode batches, train forward/backward), not
//! intended inside per-element kernels.
//!
//! Parenting (see [`super::trace`] for the full model): a plain `start`
//! nests under the innermost open span on the same thread; `start_root`
//! opens a new trace (a request root); `start_child` re-anchors under an
//! explicit cross-thread [`TraceContext`]; `record_at` pushes a
//! self-measured interval (e.g. queue wait) directly. Guards must drop in
//! LIFO order for the implicit nesting to stay truthful — they are stack
//! scoped everywhere in this crate.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::json::Json;
use crate::telemetry::trace::{self, TraceContext};

/// One completed span.
#[derive(Clone, Copy, Debug)]
pub struct SpanRecord {
    /// Monotone completion index (global across the recorder) — the
    /// overflow tests key on it: after overflow the ring holds the
    /// records with the largest `seq` values.
    pub seq: u64,
    pub name: &'static str,
    /// Optional tag, e.g. `("shard", 2)`; `("", 0)` when untagged.
    pub tag_key: &'static str,
    pub tag: u64,
    /// Trace this span belongs to; 0 = outside any request trace.
    pub trace_id: u64,
    /// Process-globally unique id of this span (never 0 once recorded).
    pub span_id: u64,
    /// `span_id` of the parent span; 0 = root.
    pub parent_id: u64,
    /// Start offset from recorder creation, µs.
    pub start_us: u64,
    pub dur_us: u64,
}

#[derive(Debug)]
struct SpanInner {
    enabled: AtomicBool,
    /// Completed-span count (monotone; ring length is capped separately).
    seq: AtomicU64,
    /// Spans evicted from the ring (lifetime).
    dropped: AtomicU64,
    epoch: Instant,
    capacity: usize,
    ring: Mutex<VecDeque<SpanRecord>>,
}

/// Shared ring of recent spans; cloning shares the ring (`Arc`).
#[derive(Clone, Debug)]
pub struct SpanRecorder(Arc<SpanInner>);

impl SpanRecorder {
    pub const DEFAULT_CAPACITY: usize = 1024;

    /// Enabled recorder retaining the newest `capacity` spans.
    pub fn new(capacity: usize) -> SpanRecorder {
        assert!(capacity > 0, "span ring needs capacity >= 1");
        SpanRecorder(Arc::new(SpanInner {
            enabled: AtomicBool::new(true),
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            epoch: Instant::now(),
            capacity,
            ring: Mutex::new(VecDeque::with_capacity(capacity)),
        }))
    }

    /// Recorder whose `start` is a no-op (see module docs).
    pub fn disabled() -> SpanRecorder {
        let rec = SpanRecorder::new(SpanRecorder::DEFAULT_CAPACITY);
        rec.set_enabled(false);
        rec
    }

    pub fn set_enabled(&self, on: bool) {
        self.0.enabled.store(on, Ordering::Relaxed);
    }

    pub fn is_enabled(&self) -> bool {
        self.0.enabled.load(Ordering::Relaxed)
    }

    /// µs since recorder creation — the clock `SpanRecord::start_us` and
    /// [`TraceContext::start_us`] are expressed in.
    pub fn now_us(&self) -> u64 {
        self.0.epoch.elapsed().as_micros() as u64
    }

    /// Open a span nested under the innermost open span on this thread
    /// (a root when none is open); it records itself when the guard
    /// drops. Prefer the [`crate::span!`] macro at call sites.
    #[must_use = "bind the guard (`let _span = ...`) — dropping it closes the span"]
    pub fn start(&self, name: &'static str, tag_key: &'static str, tag: u64) -> SpanGuard<'_> {
        if !self.is_enabled() {
            return SpanGuard { open: None };
        }
        self.open(name, tag_key, tag, trace::current(), false)
    }

    /// Open the root span of a **new trace** (e.g. one request's
    /// lifecycle); downstream threads parent to it via
    /// [`SpanGuard::context`].
    #[must_use = "bind the guard (`let _span = ...`) — dropping it closes the span"]
    pub fn start_root(&self, name: &'static str, tag_key: &'static str, tag: u64) -> SpanGuard<'_> {
        if !self.is_enabled() {
            return SpanGuard { open: None };
        }
        self.open(name, tag_key, tag, TraceContext::NONE, true)
    }

    /// Open a span under an explicit (typically cross-thread) parent
    /// context instead of this thread's innermost span.
    #[must_use = "bind the guard (`let _span = ...`) — dropping it closes the span"]
    pub fn start_child(
        &self,
        name: &'static str,
        tag_key: &'static str,
        tag: u64,
        parent: TraceContext,
    ) -> SpanGuard<'_> {
        if !self.is_enabled() {
            return SpanGuard { open: None };
        }
        self.open(name, tag_key, tag, parent, false)
    }

    fn open(
        &self,
        name: &'static str,
        tag_key: &'static str,
        tag: u64,
        parent: TraceContext,
        root: bool,
    ) -> SpanGuard<'_> {
        let started = Instant::now();
        let start_us = started.duration_since(self.0.epoch).as_micros() as u64;
        let (trace_id, parent_id) =
            if root { (trace::next_trace_id(), 0) } else { (parent.trace_id, parent.span_id) };
        let span_id = trace::next_span_id();
        let prev = trace::current();
        trace::set_current(TraceContext { trace_id, span_id, start_us });
        SpanGuard {
            open: Some(OpenSpan {
                rec: self,
                name,
                tag_key,
                tag,
                trace_id,
                span_id,
                parent_id,
                start_us,
                started,
                prev,
            }),
        }
    }

    /// Record a completed span directly from a self-measured interval
    /// (`start_us`/`dur_us` on the [`SpanRecorder::now_us`] clock) under
    /// an explicit parent — e.g. queue wait measured at admission against
    /// the root context that rode the request across the channel. Does
    /// not touch the thread's current context. Returns the new span id (0
    /// when disabled).
    pub fn record_at(
        &self,
        name: &'static str,
        tag_key: &'static str,
        tag: u64,
        parent: TraceContext,
        start_us: u64,
        dur_us: u64,
    ) -> u64 {
        if !self.is_enabled() {
            return 0;
        }
        let span_id = trace::next_span_id();
        self.push(SpanRecord {
            seq: 0,
            name,
            tag_key,
            tag,
            trace_id: parent.trace_id,
            span_id,
            parent_id: parent.span_id,
            start_us,
            dur_us,
        });
        span_id
    }

    fn push(&self, mut record: SpanRecord) {
        record.seq = self.0.seq.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.0.ring.lock().unwrap();
        if ring.len() == self.0.capacity {
            ring.pop_front();
            self.0.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(record);
    }

    /// Spans completed over the recorder's lifetime (≥ ring length).
    pub fn recorded(&self) -> u64 {
        self.0.seq.load(Ordering::Relaxed)
    }

    /// Spans evicted from the ring over the recorder's lifetime; nonzero
    /// means [`SpanRecorder::records`] is a truncated view.
    pub fn dropped(&self) -> u64 {
        self.0.dropped.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.0.capacity
    }

    /// Copy of the retained ring, oldest first.
    pub fn records(&self) -> Vec<SpanRecord> {
        self.0.ring.lock().unwrap().iter().copied().collect()
    }

    /// Snapshot summary: lifetime counts plus per-name aggregates of the
    /// **retained** ring (`{count, total_ms, max_ms}` per span name).
    pub fn to_json(&self) -> Json {
        let mut by_name: BTreeMap<String, (u64, f64, f64)> = BTreeMap::new();
        for r in self.0.ring.lock().unwrap().iter() {
            let e = by_name.entry(r.name.to_string()).or_insert((0, 0.0, 0.0));
            e.0 += 1;
            e.1 += r.dur_us as f64 / 1000.0;
            e.2 = e.2.max(r.dur_us as f64 / 1000.0);
        }
        let by_name = Json::Obj(
            by_name
                .into_iter()
                .map(|(name, (count, total_ms, max_ms))| {
                    let v = Json::obj(vec![
                        ("count", Json::Num(count as f64)),
                        ("total_ms", Json::Num(total_ms)),
                        ("max_ms", Json::Num(max_ms)),
                    ]);
                    (name, v)
                })
                .collect(),
        );
        Json::obj(vec![
            ("recorded", Json::Num(self.recorded() as f64)),
            ("dropped", Json::Num(self.dropped() as f64)),
            ("capacity", Json::Num(self.0.capacity as f64)),
            ("retained", Json::Num(self.0.ring.lock().unwrap().len() as f64)),
            ("by_name", by_name),
        ])
    }
}

impl Default for SpanRecorder {
    fn default() -> SpanRecorder {
        SpanRecorder::new(SpanRecorder::DEFAULT_CAPACITY)
    }
}

struct OpenSpan<'a> {
    rec: &'a SpanRecorder,
    name: &'static str,
    tag_key: &'static str,
    tag: u64,
    trace_id: u64,
    span_id: u64,
    parent_id: u64,
    start_us: u64,
    started: Instant,
    /// Thread-current context to restore at drop (LIFO nesting).
    prev: TraceContext,
}

/// Scope guard returned by [`SpanRecorder::start`]; `None` inside means
/// the recorder was disabled and drop does nothing.
pub struct SpanGuard<'a> {
    open: Option<OpenSpan<'a>>,
}

impl SpanGuard<'_> {
    /// Context children should parent to — copy it into a message
    /// (`serve::Request`) to continue the trace on another thread.
    /// [`TraceContext::NONE`] when the recorder was disabled.
    pub fn context(&self) -> TraceContext {
        self.open.as_ref().map_or(TraceContext::NONE, |o| TraceContext {
            trace_id: o.trace_id,
            span_id: o.span_id,
            start_us: o.start_us,
        })
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some(o) = self.open.take() {
            trace::set_current(o.prev);
            let dur_us = o.started.elapsed().as_micros() as u64;
            o.rec.push(SpanRecord {
                seq: 0,
                name: o.name,
                tag_key: o.tag_key,
                tag: o.tag,
                trace_id: o.trace_id,
                span_id: o.span_id,
                parent_id: o.parent_id,
                start_us: o.start_us,
                dur_us,
            });
        }
    }
}

/// Scoped tracing span over a [`SpanRecorder`]:
///
/// ```
/// use attn_qat::{span, telemetry::SpanRecorder};
///
/// let rec = SpanRecorder::new(64);
/// {
///     let _span = span!(rec, "prefill", shard = 2);
///     // ... work ...
/// } // recorded here
/// assert_eq!(rec.records()[0].name, "prefill");
/// ```
#[macro_export]
macro_rules! span {
    ($rec:expr, $name:expr) => {
        $rec.start($name, "", 0)
    };
    ($rec:expr, $name:expr, $key:ident = $val:expr) => {
        $rec.start($name, stringify!($key), $val as u64)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overflow_keeps_newest() {
        let rec = SpanRecorder::new(4);
        for i in 0..10u64 {
            let _span = crate::span!(rec, "step", i = i);
        }
        assert_eq!(rec.recorded(), 10);
        assert_eq!(rec.dropped(), 6, "evictions must be counted, not silent");
        let records = rec.records();
        assert_eq!(records.len(), 4);
        let seqs: Vec<u64> = records.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "ring must retain the newest spans");
        assert_eq!(records[0].tag_key, "i");
        assert_eq!(records[0].tag, 6);
    }

    #[test]
    fn disabled_records_nothing() {
        let rec = SpanRecorder::disabled();
        {
            let _span = crate::span!(rec, "ignored");
        }
        assert_eq!(rec.recorded(), 0);
        assert!(rec.records().is_empty());
        rec.set_enabled(true);
        {
            let _span = crate::span!(rec, "seen");
        }
        assert_eq!(rec.recorded(), 1);
    }

    #[test]
    fn json_summary_aggregates_by_name() {
        let rec = SpanRecorder::new(16);
        for shard in 0..3u64 {
            let _span = crate::span!(rec, "decode", shard = shard);
        }
        {
            let _span = crate::span!(rec, "drain");
        }
        let doc = rec.to_json();
        assert_eq!(doc.get("recorded").as_f64(), Some(4.0));
        assert_eq!(doc.get("dropped").as_f64(), Some(0.0));
        assert_eq!(doc.get("by_name").get("decode").get("count").as_f64(), Some(3.0));
        assert_eq!(doc.get("by_name").get("drain").get("count").as_f64(), Some(1.0));
    }

    #[test]
    fn implicit_nesting_links_parent_ids() {
        let rec = SpanRecorder::new(16);
        {
            let root = rec.start_root("request", "req", 7);
            let ctx = root.context();
            assert!(ctx.is_some());
            {
                let _inner = crate::span!(rec, "prefill");
            }
            let _tok = rec.start_child("decode.token", "shard", 0, ctx);
        }
        let records = rec.records();
        assert_eq!(records.len(), 3);
        let root = records.iter().find(|r| r.name == "request").unwrap();
        assert_eq!(root.parent_id, 0);
        assert!(root.trace_id != 0 && root.span_id != 0);
        for r in records.iter().filter(|r| r.name != "request") {
            assert_eq!(r.parent_id, root.span_id, "{} must parent to the root", r.name);
            assert_eq!(r.trace_id, root.trace_id);
        }
        // All guards dropped: nothing is current on this thread anymore.
        assert_eq!(trace::current(), TraceContext::NONE);
    }

    #[test]
    fn record_at_anchors_under_explicit_parent() {
        let rec = SpanRecorder::new(16);
        let ctx = {
            let root = rec.start_root("request", "req", 1);
            root.context()
        };
        let id = rec.record_at("queue", "shard", 3, ctx, ctx.start_us, 42);
        assert!(id != 0);
        let q = rec.records().into_iter().find(|r| r.name == "queue").unwrap();
        assert_eq!(q.parent_id, ctx.span_id);
        assert_eq!(q.trace_id, ctx.trace_id);
        assert_eq!(q.dur_us, 42);
        // Disabled recorder: record_at is a no-op returning 0.
        rec.set_enabled(false);
        assert_eq!(rec.record_at("queue", "", 0, ctx, 0, 1), 0);
    }
}
