//! Ring-buffer tracing spans: scoped guards with a ~zero-cost disabled
//! path.
//!
//! A span is opened with the [`crate::span!`] macro (or
//! [`SpanRecorder::start`]) and closed by dropping the returned guard; the
//! recorder keeps the newest `capacity` records in a fixed ring (overflow
//! drops the oldest). Names and tag keys are `&'static str` and the guard
//! lives on the stack, so a **disabled** recorder's `start` is one relaxed
//! atomic load — no allocation, no `Instant::now` (pinned by the counting
//! allocator test in `rust/tests/telemetry.rs`). An **enabled** span costs
//! two `Instant` reads plus one short mutex push at drop — fine at
//! per-pass / per-step granularity (admission, prefill, decode batches,
//! train forward/backward), not intended inside per-element kernels.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::json::Json;

/// One completed span.
#[derive(Clone, Copy, Debug)]
pub struct SpanRecord {
    /// Monotone completion index (global across the recorder) — the
    /// overflow tests key on it: after overflow the ring holds the
    /// records with the largest `seq` values.
    pub seq: u64,
    pub name: &'static str,
    /// Optional tag, e.g. `("shard", 2)`; `("", 0)` when untagged.
    pub tag_key: &'static str,
    pub tag: u64,
    /// Start offset from recorder creation, µs.
    pub start_us: u64,
    pub dur_us: u64,
}

#[derive(Debug)]
struct SpanInner {
    enabled: AtomicBool,
    /// Completed-span count (monotone; ring length is capped separately).
    seq: AtomicU64,
    epoch: Instant,
    capacity: usize,
    ring: Mutex<VecDeque<SpanRecord>>,
}

/// Shared ring of recent spans; cloning shares the ring (`Arc`).
#[derive(Clone, Debug)]
pub struct SpanRecorder(Arc<SpanInner>);

impl SpanRecorder {
    pub const DEFAULT_CAPACITY: usize = 1024;

    /// Enabled recorder retaining the newest `capacity` spans.
    pub fn new(capacity: usize) -> SpanRecorder {
        assert!(capacity > 0, "span ring needs capacity >= 1");
        SpanRecorder(Arc::new(SpanInner {
            enabled: AtomicBool::new(true),
            seq: AtomicU64::new(0),
            epoch: Instant::now(),
            capacity,
            ring: Mutex::new(VecDeque::with_capacity(capacity)),
        }))
    }

    /// Recorder whose `start` is a no-op (see module docs).
    pub fn disabled() -> SpanRecorder {
        let rec = SpanRecorder::new(SpanRecorder::DEFAULT_CAPACITY);
        rec.set_enabled(false);
        rec
    }

    pub fn set_enabled(&self, on: bool) {
        self.0.enabled.store(on, Ordering::Relaxed);
    }

    pub fn is_enabled(&self) -> bool {
        self.0.enabled.load(Ordering::Relaxed)
    }

    /// Open a span; it records itself when the guard drops. Prefer the
    /// [`crate::span!`] macro at call sites.
    #[must_use = "bind the guard (`let _span = ...`) — dropping it closes the span"]
    pub fn start(&self, name: &'static str, tag_key: &'static str, tag: u64) -> SpanGuard<'_> {
        if !self.is_enabled() {
            return SpanGuard { open: None };
        }
        SpanGuard { open: Some((self, name, tag_key, tag, Instant::now())) }
    }

    fn push(&self, name: &'static str, tag_key: &'static str, tag: u64, started: Instant) {
        let dur_us = started.elapsed().as_micros() as u64;
        let start_us = started.duration_since(self.0.epoch).as_micros() as u64;
        let seq = self.0.seq.fetch_add(1, Ordering::Relaxed);
        let record = SpanRecord { seq, name, tag_key, tag, start_us, dur_us };
        let mut ring = self.0.ring.lock().unwrap();
        if ring.len() == self.0.capacity {
            ring.pop_front();
        }
        ring.push_back(record);
    }

    /// Spans completed over the recorder's lifetime (≥ ring length).
    pub fn recorded(&self) -> u64 {
        self.0.seq.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.0.capacity
    }

    /// Copy of the retained ring, oldest first.
    pub fn records(&self) -> Vec<SpanRecord> {
        self.0.ring.lock().unwrap().iter().copied().collect()
    }

    /// Snapshot summary: lifetime counts plus per-name aggregates of the
    /// **retained** ring (`{count, total_ms, max_ms}` per span name).
    pub fn to_json(&self) -> Json {
        let mut by_name: BTreeMap<String, (u64, f64, f64)> = BTreeMap::new();
        for r in self.0.ring.lock().unwrap().iter() {
            let e = by_name.entry(r.name.to_string()).or_insert((0, 0.0, 0.0));
            e.0 += 1;
            e.1 += r.dur_us as f64 / 1000.0;
            e.2 = e.2.max(r.dur_us as f64 / 1000.0);
        }
        let by_name = Json::Obj(
            by_name
                .into_iter()
                .map(|(name, (count, total_ms, max_ms))| {
                    let v = Json::obj(vec![
                        ("count", Json::Num(count as f64)),
                        ("total_ms", Json::Num(total_ms)),
                        ("max_ms", Json::Num(max_ms)),
                    ]);
                    (name, v)
                })
                .collect(),
        );
        Json::obj(vec![
            ("recorded", Json::Num(self.recorded() as f64)),
            ("capacity", Json::Num(self.0.capacity as f64)),
            ("retained", Json::Num(self.0.ring.lock().unwrap().len() as f64)),
            ("by_name", by_name),
        ])
    }
}

impl Default for SpanRecorder {
    fn default() -> SpanRecorder {
        SpanRecorder::new(SpanRecorder::DEFAULT_CAPACITY)
    }
}

/// Scope guard returned by [`SpanRecorder::start`]; `None` inside means
/// the recorder was disabled and drop does nothing.
pub struct SpanGuard<'a> {
    #[allow(clippy::type_complexity)]
    open: Option<(&'a SpanRecorder, &'static str, &'static str, u64, Instant)>,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some((rec, name, tag_key, tag, started)) = self.open.take() {
            rec.push(name, tag_key, tag, started);
        }
    }
}

/// Scoped tracing span over a [`SpanRecorder`]:
///
/// ```
/// use attn_qat::{span, telemetry::SpanRecorder};
///
/// let rec = SpanRecorder::new(64);
/// {
///     let _span = span!(rec, "prefill", shard = 2);
///     // ... work ...
/// } // recorded here
/// assert_eq!(rec.records()[0].name, "prefill");
/// ```
#[macro_export]
macro_rules! span {
    ($rec:expr, $name:expr) => {
        $rec.start($name, "", 0)
    };
    ($rec:expr, $name:expr, $key:ident = $val:expr) => {
        $rec.start($name, stringify!($key), $val as u64)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overflow_keeps_newest() {
        let rec = SpanRecorder::new(4);
        for i in 0..10u64 {
            let _span = crate::span!(rec, "step", i = i);
        }
        assert_eq!(rec.recorded(), 10);
        let records = rec.records();
        assert_eq!(records.len(), 4);
        let seqs: Vec<u64> = records.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "ring must retain the newest spans");
        assert_eq!(records[0].tag_key, "i");
        assert_eq!(records[0].tag, 6);
    }

    #[test]
    fn disabled_records_nothing() {
        let rec = SpanRecorder::disabled();
        {
            let _span = crate::span!(rec, "ignored");
        }
        assert_eq!(rec.recorded(), 0);
        assert!(rec.records().is_empty());
        rec.set_enabled(true);
        {
            let _span = crate::span!(rec, "seen");
        }
        assert_eq!(rec.recorded(), 1);
    }

    #[test]
    fn json_summary_aggregates_by_name() {
        let rec = SpanRecorder::new(16);
        for shard in 0..3u64 {
            let _span = crate::span!(rec, "decode", shard = shard);
        }
        {
            let _span = crate::span!(rec, "drain");
        }
        let doc = rec.to_json();
        assert_eq!(doc.get("recorded").as_f64(), Some(4.0));
        assert_eq!(doc.get("by_name").get("decode").get("count").as_f64(), Some(3.0));
        assert_eq!(doc.get("by_name").get("drain").get("count").as_f64(), Some(1.0));
    }
}
