//! Packed NVFP4 tensors: true 4-bit storage (2 codes/byte + scale bytes).
//!
//! This is what the FP4 KV cache stores and what the real-quant attention
//! engine consumes — the storage-side counterpart of the paper's inference
//! kernels (and the Fig. 4 "real quant" path). Memory per element:
//! 4 bits + 8/16 bits of scale amortised over the block = **4.5 bits**,
//! vs 32 for the f32 baseline (the paper's 2× arithmetic-intensity claim
//! comes with this ~7× storage reduction vs f32 / 3.6× vs bf16).

use anyhow::{bail, Result};

use super::{block, e2m1, e4m3};

/// A (rows × cols) matrix quantized to NVFP4 along its rows.
#[derive(Clone, Debug)]
pub struct PackedNvfp4 {
    pub rows: usize,
    pub cols: usize,
    /// Packed E2M1 codes, 2 per byte, row-major.
    pub codes: Vec<u8>,
    /// E4M3 scale bytes, one per 16-element block, row-major.
    pub scales: Vec<u8>,
}

impl PackedNvfp4 {
    /// Quantize a row-major f32 matrix. `cols` must be a multiple of 16.
    pub fn quantize(data: &[f32], rows: usize, cols: usize) -> Result<PackedNvfp4> {
        if cols % block::NVFP4_BLOCK != 0 {
            bail!("cols {} not a multiple of {}", cols, block::NVFP4_BLOCK);
        }
        if data.len() != rows * cols {
            bail!("data length {} != {}x{}", data.len(), rows, cols);
        }
        let mut codes = Vec::with_capacity(rows * cols);
        let mut scales = Vec::with_capacity(rows * cols / block::NVFP4_BLOCK);
        for r in 0..rows {
            block::nvfp4_quant_row(&data[r * cols..(r + 1) * cols], &mut codes, &mut scales);
        }
        Ok(PackedNvfp4 { rows, cols, codes: e2m1::pack(&codes), scales })
    }

    /// Dequantize the whole matrix to f32.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        let codes = e2m1::unpack(&self.codes, self.rows * self.cols);
        block::nvfp4_dequant_row(&codes, &self.scales, &mut out);
        out
    }

    /// Dequantize a single row into `out` (hot path for attention/KV reads).
    pub fn dequant_row_into(&self, r: usize, out: &mut [f32]) {
        debug_assert!(r < self.rows);
        debug_assert_eq!(out.len(), self.cols);
        let spb = self.cols / block::NVFP4_BLOCK; // scales per row
        let base_code = r * self.cols; // code index (4-bit units)
        let scales = &self.scales[r * spb..(r + 1) * spb];
        // Hot path (KV reads, real-quant engine): decode the scale once per
        // 16-block and unpack two codes per byte (cols and the row base are
        // both even, so block boundaries are byte-aligned).
        for (bi, chunk) in out.chunks_mut(block::NVFP4_BLOCK).enumerate() {
            let s = e4m3::decode(scales[bi]);
            let byte_base = (base_code + bi * block::NVFP4_BLOCK) / 2;
            for (pi, pair) in chunk.chunks_mut(2).enumerate() {
                let byte = self.codes[byte_base + pi];
                pair[0] = e2m1::decode(byte & 0xF) * s;
                pair[1] = e2m1::decode(byte >> 4) * s;
            }
        }
    }

    /// Bytes actually stored (codes + scales).
    pub fn memory_bytes(&self) -> usize {
        self.codes.len() + self.scales.len()
    }

    /// Storage ratio vs f32 (≈ 7.1× for block 16).
    pub fn compression_vs_f32(&self) -> f32 {
        (self.rows * self.cols * 4) as f32 / self.memory_bytes() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols)
            .map(|i| ((i * 2654435761usize) % 1000) as f32 / 250.0 - 2.0)
            .collect()
    }

    #[test]
    fn pack_dequant_matches_rowwise() {
        let (r, c) = (8, 32);
        let data = sample(r, c);
        let p = PackedNvfp4::quantize(&data, r, c).unwrap();
        let full = p.dequantize();
        let mut row = vec![0.0; c];
        for i in 0..r {
            p.dequant_row_into(i, &mut row);
            assert_eq!(row, full[i * c..(i + 1) * c]);
        }
    }

    #[test]
    fn quantize_is_fake_quant() {
        // dequantize(quantize(x)) == fake_quant(x) elementwise.
        let (r, c) = (4, 48);
        let data = sample(r, c);
        let p = PackedNvfp4::quantize(&data, r, c).unwrap();
        let deq = p.dequantize();
        let mut fq = data.clone();
        for row in fq.chunks_mut(c) {
            block::nvfp4_fake_quant_row(row);
        }
        assert_eq!(deq, fq);
    }

    #[test]
    fn memory_is_4p5_bits_per_elem() {
        let (r, c) = (16, 64);
        let p = PackedNvfp4::quantize(&sample(r, c), r, c).unwrap();
        let bits_per_elem = p.memory_bytes() as f32 * 8.0 / (r * c) as f32;
        assert!((bits_per_elem - 4.5).abs() < 1e-6, "{bits_per_elem}");
        assert!(p.compression_vs_f32() > 7.0);
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(PackedNvfp4::quantize(&[0.0; 10], 1, 10).is_err());
        assert!(PackedNvfp4::quantize(&[0.0; 16], 2, 16).is_err());
    }
}
