//! Packed-domain dot products: the byte-pair lookup table that lets the
//! attention engines consume NVFP4 storage *without dequantizing*.
//!
//! A packed NVFP4 byte holds two E2M1 codes. For two packed bytes `a`, `b`
//! the table stores the exact f32 dot contribution of the code pair:
//!
//! ```text
//! PAIR_DOT[a][b] = d(a & 0xF)·d(b & 0xF) + d(a >> 4)·d(b >> 4)
//! ```
//!
//! so a 16-element block dot product is **8 byte-indexed lookups** plus one
//! `s_a·s_b` scale multiply — no unpacking, no per-element dequant. This is
//! the software analogue of the FP4 tensor-core path (SageAttention3 /
//! Attn-QAT inference): arithmetic intensity comes from operating on the
//! 4-bit representation directly.
//!
//! Exactness: E2M1 magnitudes are ±{0, .5, 1, 1.5, 2, 3, 4, 6}, so every
//! pairwise product is a multiple of 0.25 bounded by 36, every block-level
//! partial sum is a multiple of 0.25 bounded by 576 — far inside f32's
//! exact-integer range — and E4M3 scales carry ≤ 4 significand bits, so
//! `block_sum · (s_a·s_b)` is computed without rounding. The LUT block dot
//! therefore equals the mathematically exact dot of the dequantized block.
//! (Across blocks the f32 sum rounds once per block, the same contract as
//! the dequantizing engines' f32 accumulation.)
//!
//! The table is 256×256 f32 = 256 KiB, built once on first use.

use std::sync::OnceLock;

use super::block::{nvfp4_block_scale, NVFP4_BLOCK};
use super::e2m1;
use super::e4m3;
use super::tensor4::PackedNvfp4;

/// Flattened 256×256 pair-dot table; index with `(a << 8) | b`.
pub const LUT_LEN: usize = 256 * 256;

static PAIR_DOT: OnceLock<Vec<f32>> = OnceLock::new();

/// The pair-dot table (built on first call, then shared).
pub fn pair_dot() -> &'static [f32] {
    PAIR_DOT.get_or_init(|| {
        let mut t = vec![0.0f32; LUT_LEN];
        for a in 0..256usize {
            let alo = e2m1::decode((a & 0xF) as u8);
            let ahi = e2m1::decode((a >> 4) as u8);
            for b in 0..256usize {
                let blo = e2m1::decode((b & 0xF) as u8);
                let bhi = e2m1::decode((b >> 4) as u8);
                t[(a << 8) | b] = alo * blo + ahi * bhi;
            }
        }
        t
    })
}

/// Unscaled dot of two packed code runs (pairs of E2M1 codes per byte).
///
/// Exact as long as the runs stay within one scale block (≤ 8 bytes); the
/// callers below apply it per 16-element block.
#[inline(always)]
pub fn bytes_dot(lut: &[f32], a: &[u8], b: &[u8]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += lut[((x as usize) << 8) | y as usize];
    }
    acc
}

/// Bytes per 16-element NVFP4 block (two codes per byte).
pub const BLOCK_BYTES: usize = NVFP4_BLOCK / 2;

/// Packed-domain dot of row `ra` of `a` with row `rb` of `b`.
///
/// Both matrices must share `cols` (a multiple of 16). Per block: 8 LUT
/// lookups + one `s_a·s_b` multiply; blocks accumulate in f32 left to
/// right. Never touches dequantized values.
#[inline]
pub fn packed_row_dot(
    lut: &[f32],
    a: &PackedNvfp4,
    ra: usize,
    b: &PackedNvfp4,
    rb: usize,
) -> f32 {
    debug_assert_eq!(a.cols, b.cols);
    debug_assert!(ra < a.rows && rb < b.rows);
    let spb = a.cols / NVFP4_BLOCK; // scale blocks per row
    let bpr = a.cols / 2; // bytes per row
    let a_codes = &a.codes[ra * bpr..(ra + 1) * bpr];
    let b_codes = &b.codes[rb * bpr..(rb + 1) * bpr];
    let a_scales = &a.scales[ra * spb..(ra + 1) * spb];
    let b_scales = &b.scales[rb * spb..(rb + 1) * spb];
    let mut acc = 0.0f32;
    for bi in 0..spb {
        let s = e4m3::decode(a_scales[bi]) * e4m3::decode(b_scales[bi]);
        let d = bytes_dot(
            lut,
            &a_codes[bi * BLOCK_BYTES..(bi + 1) * BLOCK_BYTES],
            &b_codes[bi * BLOCK_BYTES..(bi + 1) * BLOCK_BYTES],
        );
        acc += d * s;
    }
    acc
}

/// Batched row dots: `out[rb] = dot(row ra of a, row rb of b)` for
/// `rb < nb` — one full S-row recompute in a single call.
///
/// Bitwise identical to `nb` independent [`packed_row_dot`] calls (same
/// per-block products, same accumulation order); the win is hoisting the
/// `a`-side row slicing and bounds work out of the inner loop, which the
/// per-pair entry point redoes for every key. This is the backward's
/// S-recompute hot path (`qat::flash_backward` rebuilds one score row per
/// query); the `fig3_backward` bench records the per-pair vs batched
/// comparison.
pub fn packed_row_dots_into(
    lut: &[f32],
    a: &PackedNvfp4,
    ra: usize,
    b: &PackedNvfp4,
    nb: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.cols, b.cols);
    debug_assert!(ra < a.rows && nb <= b.rows);
    debug_assert!(out.len() >= nb);
    let spb = a.cols / NVFP4_BLOCK; // scale blocks per row
    let bpr = a.cols / 2; // bytes per row
    let a_codes = &a.codes[ra * bpr..(ra + 1) * bpr];
    let a_scales = &a.scales[ra * spb..(ra + 1) * spb];
    for (rb, o) in out[..nb].iter_mut().enumerate() {
        let b_codes = &b.codes[rb * bpr..(rb + 1) * bpr];
        let b_scales = &b.scales[rb * spb..(rb + 1) * spb];
        let mut acc = 0.0f32;
        for bi in 0..spb {
            let s = e4m3::decode(a_scales[bi]) * e4m3::decode(b_scales[bi]);
            let d = bytes_dot(
                lut,
                &a_codes[bi * BLOCK_BYTES..(bi + 1) * BLOCK_BYTES],
                &b_codes[bi * BLOCK_BYTES..(bi + 1) * BLOCK_BYTES],
            );
            acc += d * s;
        }
        *o = acc;
    }
}

/// Quantize one row straight into packed form (codes 2-per-byte + scale
/// bytes), reusing the caller's buffers — the allocation-free counterpart
/// of [`PackedNvfp4::quantize`] for hot paths (decode queries, P rows).
///
/// `row.len()` must be a multiple of 16. Clears and refills both vectors;
/// steady-state reuse never reallocates.
pub fn quantize_row_into(row: &[f32], codes: &mut Vec<u8>, scales: &mut Vec<u8>) {
    debug_assert_eq!(row.len() % NVFP4_BLOCK, 0);
    codes.clear();
    scales.clear();
    for block in row.chunks(NVFP4_BLOCK) {
        let s = nvfp4_block_scale(block);
        scales.push(e4m3::encode(s));
        for pair in block.chunks(2) {
            let lo = e2m1::encode(pair[0] / s);
            let hi = e2m1::encode(pair[1] / s);
            codes.push(lo | (hi << 4));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_matches_decoded_products() {
        let lut = pair_dot();
        for a in 0..256usize {
            for b in [0usize, 1, 17, 128, 136, 255, 0x93, 0x7f] {
                let want = e2m1::decode((a & 0xF) as u8) * e2m1::decode((b & 0xF) as u8)
                    + e2m1::decode((a >> 4) as u8) * e2m1::decode((b >> 4) as u8);
                assert_eq!(lut[(a << 8) | b], want, "a={a:#x} b={b:#x}");
            }
        }
    }

    #[test]
    fn packed_row_dot_matches_dequant_dot() {
        // The LUT dot must equal the exact dot of the dequantized rows
        // (per-block products are exact in f32; see module docs).
        let rows = 4;
        let cols = 64;
        let data: Vec<f32> = (0..rows * cols)
            .map(|i| ((i * 2654435761usize) % 2000) as f32 / 250.0 - 4.0)
            .collect();
        let p = PackedNvfp4::quantize(&data, rows, cols).unwrap();
        let deq = p.dequantize();
        let lut = pair_dot();
        for ra in 0..rows {
            for rb in 0..rows {
                let got = packed_row_dot(lut, &p, ra, &p, rb);
                // Exact per block; cross-block f32 sum in the same order.
                let mut want = 0.0f32;
                for bi in 0..cols / NVFP4_BLOCK {
                    let mut blk = 0.0f32;
                    for c in bi * NVFP4_BLOCK..(bi + 1) * NVFP4_BLOCK {
                        blk += deq[ra * cols + c] * deq[rb * cols + c];
                    }
                    want += blk;
                }
                assert_eq!(got, want, "rows {ra},{rb}");
            }
        }
    }

    #[test]
    fn batched_row_dots_match_per_pair_bitwise() {
        // The batched S-row recompute must be bit-identical to independent
        // per-pair dots (same block products, same accumulation order).
        let (rows, cols) = (7, 48);
        let data: Vec<f32> = (0..rows * cols)
            .map(|i| ((i * 2246822519usize) % 1777) as f32 / 200.0 - 4.4)
            .collect();
        let p = PackedNvfp4::quantize(&data, rows, cols).unwrap();
        let lut = pair_dot();
        let mut out = vec![0.0f32; rows];
        for ra in 0..rows {
            packed_row_dots_into(lut, &p, ra, &p, rows, &mut out);
            for rb in 0..rows {
                assert_eq!(out[rb], packed_row_dot(lut, &p, ra, &p, rb), "({ra},{rb})");
            }
        }
    }

    #[test]
    fn quantize_row_into_matches_packed_quantize() {
        let cols = 48;
        let row: Vec<f32> = (0..cols).map(|i| (i as f32 - 20.0) * 0.37).collect();
        let p = PackedNvfp4::quantize(&row, 1, cols).unwrap();
        let (mut codes, mut scales) = (Vec::new(), Vec::new());
        quantize_row_into(&row, &mut codes, &mut scales);
        assert_eq!(codes, p.codes);
        assert_eq!(scales, p.scales);
    }
}
