//! E8M0 — the power-of-two shared-scale format of OCP MXFP4.
//!
//! A pure 8-bit exponent (bias 127, no sign, no mantissa): representable
//! values are 2^e for e ∈ [−127, 127] plus a NaN code (0xFF). MXFP4 blocks
//! of 32 share one E8M0 scale chosen as `2^(floor(log2(amax)) − emax_elem)`
//! with `emax_elem = 2` for the E2M1 element format (OCP MX spec v1.0).

/// Element-format max exponent for E2M1 (6 = 1.5·2², so emax = 2).
pub const EMAX_ELEM: i32 = 2;

/// The MX shared scale for a block with the given amax.
///
/// Returns 1.0 for all-zero blocks (dequantization is exact either way).
#[inline]
pub fn scale_for_amax(amax: f32) -> f32 {
    if amax <= 0.0 {
        return 1.0;
    }
    let e = floor_log2(amax) - EMAX_ELEM;
    (e.clamp(-127, 127) as f32).exp2()
}

/// Encode 2^e as the biased exponent byte.
#[inline]
pub fn encode(scale: f32) -> u8 {
    debug_assert!(scale > 0.0);
    let e = floor_log2(scale);
    (e.clamp(-127, 127) + 127) as u8
}

/// Decode a biased exponent byte to 2^(byte − 127).
#[inline]
pub fn decode(byte: u8) -> f32 {
    debug_assert!(byte != 0xFF, "E8M0 NaN code");
    ((byte as i32 - 127) as f32).exp2()
}

/// floor(log2(x)) for positive normal f32 via exponent bits.
#[inline]
pub fn floor_log2(x: f32) -> i32 {
    debug_assert!(x > 0.0);
    let bits = x.to_bits();
    let exp_field = ((bits >> 23) & 0xFF) as i32;
    if exp_field == 0 {
        // subnormal: fall back (rare; only reachable with amax < 2^-126)
        x.log2().floor() as i32
    } else {
        exp_field - 127
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floor_log2_exact() {
        assert_eq!(floor_log2(1.0), 0);
        assert_eq!(floor_log2(1.5), 0);
        assert_eq!(floor_log2(2.0), 1);
        assert_eq!(floor_log2(0.5), -1);
        assert_eq!(floor_log2(0.9999), -1);
        assert_eq!(floor_log2(6.0), 2);
    }

    #[test]
    fn scale_rule() {
        // amax = 6 -> block fits e2m1 exactly with scale 2^0.
        assert_eq!(scale_for_amax(6.0), 1.0);
        // amax = 12 -> scale 2^1.
        assert_eq!(scale_for_amax(12.0), 2.0);
        assert_eq!(scale_for_amax(0.0), 1.0);
        assert_eq!(scale_for_amax(1.0), 0.25); // floor(log2 1)=0, -2 -> 2^-2
    }

    #[test]
    fn encode_decode() {
        for e in [-127i32, -10, -1, 0, 1, 10, 127] {
            let s = (e as f32).exp2();
            assert_eq!(decode(encode(s)), s);
        }
    }
}
