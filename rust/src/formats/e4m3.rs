//! E4M3 — the FP8 format NVFP4 uses for block scales (fp8e4m3fn).
//!
//! 1 sign / 4 exponent / 3 mantissa bits, bias 7, **no infinities** and a
//! single NaN code (0x7F): max finite = 448, min normal = 2⁻⁶, min
//! subnormal = 2⁻⁹. Encoding is `sign<<7 | code` with codes 0x00..=0x7E
//! monotone in value.

use super::rne_binade;

/// Largest finite magnitude.
pub const MAX: f32 = 448.0;
/// Smallest positive normal (2^-6).
pub const MIN_NORMAL: f32 = 0.015625;
/// Smallest positive subnormal (2^-9).
pub const MIN_SUBNORMAL: f32 = 0.001953125;

/// Round an f32 to the nearest finite E4M3 value (RNE, saturating).
#[inline]
pub fn round(x: f32) -> f32 {
    let mag = rne_binade(x.abs(), 3, -6, MAX);
    if x.is_sign_negative() {
        -mag
    } else {
        mag
    }
}

/// Decode magnitude from a 7-bit code (0x00..=0x7E). 0x7F is NaN.
#[inline]
pub fn decode_mag(code: u8) -> f32 {
    debug_assert!(code <= 0x7F);
    if code == 0x7F {
        return f32::NAN;
    }
    let exp = (code >> 3) as i32;
    let man = (code & 0x7) as f32;
    if exp == 0 {
        // subnormal: man/8 * 2^-6
        man / 8.0 * MIN_NORMAL
    } else {
        (1.0 + man / 8.0) * ((exp - 7) as f32).exp2()
    }
}

/// Decode a full byte (`sign<<7 | code`).
#[inline]
pub fn decode(byte: u8) -> f32 {
    let mag = decode_mag(byte & 0x7F);
    if byte & 0x80 != 0 {
        -mag
    } else {
        mag
    }
}

/// Encode an f32 to the nearest E4M3 byte (RNE, saturating).
#[inline]
pub fn encode(x: f32) -> u8 {
    let mag = rne_binade(x.abs(), 3, -6, MAX);
    let code = if mag == 0.0 {
        0u8
    } else if mag < MIN_NORMAL {
        // subnormal: round() already landed on a multiple of 2^-9
        (mag / MIN_SUBNORMAL) as u8
    } else {
        let b = mag.log2().floor() as i32; // exact: mag is on the lattice
        let exp_field = (b + 7) as u8;
        let man = ((mag / (b as f32).exp2() - 1.0) * 8.0) as u8;
        (exp_field << 3) | man
    };
    if x.is_sign_negative() && mag != 0.0 {
        code | 0x80
    } else {
        code
    }
}

/// Encode an f32 to an E4M3 byte with **stochastic rounding**.
///
/// `u` is a uniform sample in `[0, 1)` supplied by the caller (so runs
/// stay deterministic under the crate's seeded [`crate::rng::Rng`]). The
/// magnitude is bracketed between the two adjacent lattice codes and
/// rounded up with probability equal to the fractional position between
/// them, making the rounding **unbiased**: `E[decode(encode_stochastic(x,
/// U))] = x` for `|x| < MAX`. Values at or beyond `MAX` (and non-finite
/// inputs) saturate deterministically to `±MAX`; exactly-representable
/// values round-trip bitwise for every `u`.
#[inline]
pub fn encode_stochastic(x: f32, u: f32) -> u8 {
    let mag = x.abs();
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    if !mag.is_finite() || mag >= MAX {
        return sign | 0x7E;
    }
    // Binary-search the largest code whose value is <= mag (codes are
    // monotone over 0x00..=0x7E).
    let (mut lo, mut hi) = (0u8, 0x7Eu8);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if decode_mag(mid) <= mag {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    let lo_val = decode_mag(lo);
    let code = if lo_val == mag {
        lo
    } else {
        let hi_val = decode_mag(lo + 1);
        let p = (mag - lo_val) / (hi_val - lo_val);
        if u < p {
            lo + 1
        } else {
            lo
        }
    };
    // Zero is unsigned on this lattice (matches `encode`).
    if code == 0 {
        0
    } else {
        sign | code
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_known_codes() {
        assert_eq!(decode_mag(0x00), 0.0);
        assert_eq!(decode_mag(0x01), MIN_SUBNORMAL);
        assert_eq!(decode_mag(0x08), MIN_NORMAL);
        assert_eq!(decode_mag(0x7E), MAX);
        assert!(decode_mag(0x7F).is_nan());
        assert_eq!(decode(0x80 | 0x08), -MIN_NORMAL);
    }

    #[test]
    fn encode_decode_all_codes() {
        for code in 0u8..=0x7E {
            let v = decode_mag(code);
            assert_eq!(encode(v) & 0x7F, code, "code {code} value {v}");
            assert_eq!(round(v), v);
        }
    }

    #[test]
    fn saturation_and_sign() {
        assert_eq!(round(1e9), MAX);
        assert_eq!(round(-1e9), -MAX);
        assert_eq!(encode(-MAX), 0x80 | 0x7E);
    }

    #[test]
    fn rne_midpoints() {
        // 1.0 has step 1/8; midpoint 1.0625 between 1.0 (code even) and
        // 1.125 -> even mantissa wins: 1.0.
        assert_eq!(round(1.0625), 1.0);
        // midpoint between 1.125 and 1.25 -> 1.25 (even mantissa code 2).
        assert_eq!(round(1.1875), 1.25);
    }

    #[test]
    fn monotone_codes() {
        let mut prev = -1.0;
        for code in 0u8..=0x7E {
            let v = decode_mag(code);
            assert!(v > prev, "code {code}");
            prev = v;
        }
    }

    #[test]
    fn stochastic_roundtrips_exact_values_for_any_u() {
        for code in 0u8..=0x7E {
            let v = decode_mag(code);
            for u in [0.0, 0.3, 0.999] {
                assert_eq!(decode(encode_stochastic(v, u)), v, "code {code} u {u}");
                let neg = decode(encode_stochastic(-v, u));
                if v == 0.0 {
                    assert_eq!(neg, 0.0);
                } else {
                    assert_eq!(neg, -v);
                }
            }
        }
    }

    #[test]
    fn stochastic_saturates_deterministically() {
        for x in [MAX, MAX * 1.5, 1e9, f32::INFINITY] {
            for u in [0.0, 0.5, 0.999] {
                assert_eq!(decode(encode_stochastic(x, u)), MAX);
                assert_eq!(decode(encode_stochastic(-x, u)), -MAX);
            }
        }
        assert_eq!(decode(encode_stochastic(f32::NAN, 0.5)), MAX);
    }

    #[test]
    fn stochastic_brackets_to_adjacent_codes() {
        // A value strictly between two lattice points must land on one of
        // the two, low with probability 1-p, high with probability p.
        let lo = decode_mag(0x38); // 1.0
        let hi = decode_mag(0x39); // 1.125
        let x = 0.25 * lo + 0.75 * hi;
        assert_eq!(decode(encode_stochastic(x, 0.999)), lo); // u >= p=0.75
        assert_eq!(decode(encode_stochastic(x, 0.1)), hi); // u < p
    }

    #[test]
    fn round_is_nearest_dense() {
        let lattice: Vec<f32> = (0u8..=0x7E).map(decode_mag).collect();
        let mut x = 0.0f32;
        while x < 500.0 {
            let r = round(x);
            let best = lattice
                .iter()
                .copied()
                .min_by(|a, b| (a - x).abs().partial_cmp(&(b - x).abs()).unwrap())
                .unwrap();
            assert!((r - x).abs() <= (best - x).abs() + 1e-6, "x={x} r={r} best={best}");
            x = x * 1.01 + 1e-4;
        }
    }
}
