//! Quantization-error statistics — the measurement side of the format lib.
//!
//! Used by the experiment harness to report per-tensor quantization error
//! (the quantity QAT learns to compensate) and by tests to bound format
//! behaviour (e.g. NVFP4's worst-case relative error within a block).

/// Summary statistics of `q` as an approximation of `x`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorStats {
    pub mse: f64,
    pub max_abs: f32,
    /// Max relative error over elements with |x| > threshold.
    pub max_rel: f32,
    /// Signal-to-noise ratio in dB (10·log10(‖x‖² / ‖x−q‖²)).
    pub snr_db: f64,
    pub n: usize,
}

/// Compute error statistics (relative errors counted where |x| > `rel_floor`).
pub fn error_stats(x: &[f32], q: &[f32], rel_floor: f32) -> ErrorStats {
    assert_eq!(x.len(), q.len());
    let mut se = 0.0f64;
    let mut sig = 0.0f64;
    let mut max_abs = 0.0f32;
    let mut max_rel = 0.0f32;
    for (&a, &b) in x.iter().zip(q) {
        let e = a - b;
        se += (e as f64) * (e as f64);
        sig += (a as f64) * (a as f64);
        max_abs = max_abs.max(e.abs());
        if a.abs() > rel_floor {
            max_rel = max_rel.max(e.abs() / a.abs());
        }
    }
    let n = x.len().max(1);
    ErrorStats {
        mse: se / n as f64,
        max_abs,
        max_rel,
        snr_db: if se > 0.0 { 10.0 * (sig / se).log10() } else { f64::INFINITY },
        n: x.len(),
    }
}

/// Theoretical worst-case relative element error of E2M1 rounding for
/// in-range values (half the largest relative gap: between 4 and 6 the
/// midpoint 5 is 20% from 4... relative to the *input* the bound is 1/4
/// at the bottom of the subnormal range; for normal values it is 1/6).
pub const E2M1_MAX_REL_ERR_NORMAL: f32 = 1.0 / 6.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::block::nvfp4_fake_quant_row;
    use crate::rng::Rng;

    #[test]
    fn zero_error_stats() {
        let x = [1.0f32, -2.0, 3.0];
        let s = error_stats(&x, &x, 1e-6);
        assert_eq!(s.mse, 0.0);
        assert_eq!(s.max_abs, 0.0);
        assert!(s.snr_db.is_infinite());
    }

    #[test]
    fn nvfp4_snr_reasonable_for_gaussian() {
        // Gaussian data through NVFP4 keeps roughly 14-20 dB SNR — the
        // regime the paper's Q/K/V tensors live in.
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(4096, 0.0, 1.0);
        let mut q = x.clone();
        for row in q.chunks_mut(16) {
            let _ = row;
        }
        let mut q2 = x.clone();
        nvfp4_fake_quant_row(&mut q2);
        let s = error_stats(&x, &q2, 1e-3);
        assert!(s.snr_db > 10.0, "snr {}", s.snr_db);
        assert!(s.snr_db < 40.0, "suspiciously clean: {}", s.snr_db);
        // Elements much smaller than their block's amax flush to zero, so
        // the worst elementwise relative error is exactly 1.
        assert!(s.max_rel <= 1.0, "max_rel {}", s.max_rel);
    }
}
