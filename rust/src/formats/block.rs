//! Block quantization: NVFP4 (block 16, E4M3 scale) and MXFP4 (block 32,
//! E8M0 scale) — Eq. (1)/(2) of the paper, bit-exact with
//! `python/compile/kernels/nvfp4.{nvfp4_quant, mxfp4_quant}`.

use super::{e2m1, e4m3, e8m0};

/// NVFP4 micro-scaling block size.
pub const NVFP4_BLOCK: usize = 16;
/// MXFP4 (OCP MX) block size.
pub const MXFP4_BLOCK: usize = 32;

/// The NVFP4 block scale rule of Eq. (1): `s = amax/6`, E4M3-rounded,
/// with zero/underflowed blocks falling back to 1.0 (so all-zero blocks
/// dequantize exactly). Returns the *decoded* scale. Every NVFP4
/// quantizer in the crate (row quant, fake quant, the packed-domain
/// `formats::lut::quantize_row_into`) must go through this one function.
#[inline]
pub fn nvfp4_block_scale(block: &[f32]) -> f32 {
    let amax = block.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    let s = e4m3::round(amax / e2m1::MAX);
    if s <= 0.0 {
        1.0
    } else {
        s
    }
}

/// Quantize one row (blocked along its length) into E2M1 codes + E4M3
/// scale bytes. `row.len()` must be a multiple of [`NVFP4_BLOCK`].
///
/// Matches Eq. (1): scale per [`nvfp4_block_scale`], elements RNE-rounded
/// to E2M1 after division by the *decoded* scale.
pub fn nvfp4_quant_row(row: &[f32], codes: &mut Vec<u8>, scales: &mut Vec<u8>) {
    debug_assert_eq!(row.len() % NVFP4_BLOCK, 0);
    for block in row.chunks(NVFP4_BLOCK) {
        let s = nvfp4_block_scale(block);
        scales.push(e4m3::encode(s));
        for &x in block {
            codes.push(e2m1::encode(x / s));
        }
    }
}

/// Dequantize one row previously produced by [`nvfp4_quant_row`].
pub fn nvfp4_dequant_row(codes: &[u8], scales: &[u8], out: &mut Vec<f32>) {
    debug_assert_eq!(codes.len(), scales.len() * NVFP4_BLOCK);
    for (bi, block) in codes.chunks(NVFP4_BLOCK).enumerate() {
        let s = e4m3::decode(scales[bi]); // decoded once per block
        for &c in block {
            out.push(e2m1::decode(c) * s);
        }
    }
}

/// Fake-quantize a row in place: quantize + dequantize (Eq. 6's φ⁻¹∘φ).
pub fn nvfp4_fake_quant_row(row: &mut [f32]) {
    debug_assert_eq!(row.len() % NVFP4_BLOCK, 0);
    for block in row.chunks_mut(NVFP4_BLOCK) {
        let s = nvfp4_block_scale(block);
        for x in block.iter_mut() {
            *x = e2m1::round(*x / s) * s;
        }
    }
}

/// MXFP4: quantize one block of 32 with a power-of-two E8M0 scale.
/// Returns (codes, scale_byte).
pub fn mxfp4_quant_block(block: &[f32]) -> (Vec<u8>, u8) {
    debug_assert_eq!(block.len(), MXFP4_BLOCK);
    let amax = block.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    let s = e8m0::scale_for_amax(amax);
    let codes = block.iter().map(|&x| e2m1::encode(x / s)).collect();
    (codes, e8m0::encode(s))
}

/// MXFP4 dequantization of one block.
pub fn mxfp4_dequant_block(codes: &[u8], scale_byte: u8, out: &mut Vec<f32>) {
    let s = e8m0::decode(scale_byte);
    for &c in codes {
        out.push(e2m1::decode(c) * s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nvfp4_roundtrip_zero_block() {
        let row = vec![0.0f32; NVFP4_BLOCK];
        let (mut codes, mut scales) = (Vec::new(), Vec::new());
        nvfp4_quant_row(&row, &mut codes, &mut scales);
        let mut out = Vec::new();
        nvfp4_dequant_row(&codes, &scales, &mut out);
        assert_eq!(out, row);
    }

    #[test]
    fn nvfp4_block_amax_maps_to_six() {
        // amax element lands exactly on ±6·s when amax/6 is representable.
        let mut row = vec![0.1f32; NVFP4_BLOCK];
        row[3] = -12.0; // amax 12, s = 2.0 exactly representable in e4m3
        let (mut codes, mut scales) = (Vec::new(), Vec::new());
        nvfp4_quant_row(&row, &mut codes, &mut scales);
        let mut out = Vec::new();
        nvfp4_dequant_row(&codes, &scales, &mut out);
        assert_eq!(out[3], -12.0);
    }

    #[test]
    fn fake_quant_matches_quant_dequant() {
        let mut row: Vec<f32> = (0..64).map(|i| ((i * 37 % 97) as f32 - 48.0) / 7.0).collect();
        let (mut codes, mut scales) = (Vec::new(), Vec::new());
        nvfp4_quant_row(&row, &mut codes, &mut scales);
        let mut deq = Vec::new();
        nvfp4_dequant_row(&codes, &scales, &mut deq);
        nvfp4_fake_quant_row(&mut row);
        assert_eq!(row, deq);
    }

    #[test]
    fn fake_quant_idempotent() {
        let mut row: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) * 0.3).collect();
        nvfp4_fake_quant_row(&mut row);
        let once = row.clone();
        nvfp4_fake_quant_row(&mut row);
        assert_eq!(row, once);
    }

    #[test]
    fn mxfp4_roundtrip_pow2() {
        let mut block = vec![0.0f32; MXFP4_BLOCK];
        block[0] = 6.0;
        block[1] = -3.0;
        let (codes, sb) = mxfp4_quant_block(&block);
        let mut out = Vec::new();
        mxfp4_dequant_block(&codes, sb, &mut out);
        assert_eq!(out[0], 6.0);
        assert_eq!(out[1], -3.0);
    }
}
