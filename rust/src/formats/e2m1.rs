//! E2M1 — the FP4 element format (1 sign / 2 exponent / 1 mantissa bits).
//!
//! 16 codes, 15 distinct values: ±{0, 0.5, 1, 1.5, 2, 3, 4, 6} (−0 == +0).
//! Codes are `sign<<3 | mag_code` with `mag_code` indexing [`VALUES`].
//! Rounding is RNE with saturation at ±6, the semantics of Blackwell's
//! `cvt.rn.satfinite.e2m1x2.f32`.

use super::rne_binade;

/// Non-negative representable magnitudes, indexed by magnitude code 0..=7.
pub const VALUES: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Largest representable magnitude.
pub const MAX: f32 = 6.0;

/// Round an f32 to the nearest E2M1 value (RNE, saturating).
#[inline]
pub fn round(x: f32) -> f32 {
    let mag = rne_binade(x.abs(), 1, 0, MAX);
    if x.is_sign_negative() {
        -mag
    } else {
        mag
    }
}

/// Encode to a 4-bit code (`sign<<3 | mag_code`).
///
/// The magnitude code is computed directly from the rounded magnitude's
/// bit pattern (no scan over [`VALUES`]): `mag = (1 + m/2)·2^e` with
/// `e ∈ 0..=2`, `m ∈ {0, 1}` maps to code `2e + m + 2`, while 0.5 → 1 and
/// 0 → 0 fall out of the clamp/zero-mask below.
#[inline]
pub fn encode(x: f32) -> u8 {
    let mag = rne_binade(x.abs(), 1, 0, MAX);
    let bits = mag.to_bits();
    let exp = ((bits >> 23) & 0xFF) as i32 - 127;
    let top_mant = ((bits >> 22) & 1) as i32;
    let code = ((2 * exp + top_mant + 2).max(1) * (mag != 0.0) as i32) as u8;
    if x.is_sign_negative() && mag != 0.0 {
        code | 0x8
    } else {
        code
    }
}

/// All 16 code values (index = full 4-bit code, sign included).
pub const DECODE_TABLE: [f32; 16] = [
    0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0,
    -0.0, -0.5, -1.0, -1.5, -2.0, -3.0, -4.0, -6.0,
];

/// Decode a 4-bit code to f32 (branch-free table lookup).
#[inline]
pub fn decode(code: u8) -> f32 {
    DECODE_TABLE[(code & 0xF) as usize]
}

/// Pack 4-bit codes pairwise into bytes (low nibble = even index).
pub fn pack(codes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        let lo = pair[0] & 0xF;
        let hi = if pair.len() > 1 { pair[1] & 0xF } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

/// Unpack `n` 4-bit codes from packed bytes.
pub fn unpack(packed: &[u8], n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    for &b in packed {
        out.push(b & 0xF);
        if out.len() == n {
            break;
        }
        out.push(b >> 4);
        if out.len() == n {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_lattice_values_fixed() {
        for (i, v) in VALUES.iter().enumerate() {
            assert_eq!(round(*v), *v);
            assert_eq!(round(-*v), -*v);
            assert_eq!(encode(*v) & 0x7, i as u8);
        }
    }

    #[test]
    fn saturates() {
        assert_eq!(round(100.0), 6.0);
        assert_eq!(round(-100.0), -6.0);
        assert_eq!(round(6.0001), 6.0);
    }

    #[test]
    fn ties_to_even_code() {
        assert_eq!(round(0.25), 0.0);
        assert_eq!(round(0.75), 1.0);
        assert_eq!(round(1.25), 1.0);
        assert_eq!(round(1.75), 2.0);
        assert_eq!(round(2.5), 2.0);
        assert_eq!(round(3.5), 4.0);
        assert_eq!(round(5.0), 4.0);
        assert_eq!(round(-2.5), -2.0);
    }

    #[test]
    fn encode_decode_roundtrip() {
        for code in 0u8..16 {
            let v = decode(code);
            let back = encode(v);
            // -0 canonicalises to +0.
            if code == 0x8 {
                assert_eq!(back, 0);
            } else {
                assert_eq!(back, code, "code {code} -> {v}");
            }
        }
    }

    #[test]
    fn round_is_nearest() {
        // Dense sweep: result must always be a nearest lattice point.
        let mut x = -8.0f32;
        while x < 8.0 {
            let r = round(x);
            let best = VALUES
                .iter()
                .flat_map(|v| [*v, -*v])
                .min_by(|a, b| (a - x).abs().partial_cmp(&(b - x).abs()).unwrap())
                .unwrap();
            assert!(
                (r - x).abs() <= (best - x).abs() + 1e-6,
                "x={x} r={r} best={best}"
            );
            x += 0.0317;
        }
    }

    /// The pre-refactor scan encoder, kept as the equivalence oracle.
    fn encode_scan(x: f32) -> u8 {
        let mag = rne_binade(x.abs(), 1, 0, MAX);
        let mut code = 0u8;
        for (i, v) in VALUES.iter().enumerate() {
            if mag == *v {
                code = i as u8;
                break;
            }
        }
        if x.is_sign_negative() && mag != 0.0 {
            code | 0x8
        } else {
            code
        }
    }

    #[test]
    fn encode_matches_scan_exhaustively() {
        // Dense sweep across the whole useful range plus every edge the
        // codec has: lattice points, RNE midpoints, saturation, signed
        // zero, subnormals, infinities.
        let mut x = -10.0f32;
        while x < 10.0 {
            assert_eq!(encode(x), encode_scan(x), "x={x}");
            x += 0.001953125; // 2^-9: hits every midpoint exactly
        }
        let edges = [
            0.0f32, -0.0, 0.25, -0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0, 6.0, -6.0,
            6.0001, 100.0, -100.0, 1e30, -1e30, f32::INFINITY, f32::NEG_INFINITY,
            f32::MIN_POSITIVE, -f32::MIN_POSITIVE, 1e-40, -1e-40, 1e-30,
        ];
        for &e in &edges {
            assert_eq!(encode(e), encode_scan(e), "edge {e}");
        }
    }

    #[test]
    fn pack_unpack() {
        let codes: Vec<u8> = (0..16).collect();
        assert_eq!(unpack(&pack(&codes), 16), codes);
        let odd: Vec<u8> = (0..7).collect();
        assert_eq!(unpack(&pack(&odd), 7), odd);
    }
}
