//! Bit-exact software NVFP4 / MXFP4 numeric formats (the paper's §2.1).
//!
//! This is the "real quant" half of the system: while the JAX/Pallas layers
//! *emulate* FP4 via fake quantization (Eq. 6), this module implements the
//! actual storage formats —
//!
//! * [`e2m1`] — the FP4 element codec (1/2/1 bits, values ±{0,.5,..,6})
//! * [`e4m3`] — the FP8 scale codec used by NVFP4 (bias 7, max 448)
//! * [`e8m0`] — the power-of-two scale codec used by MXFP4
//! * [`block`] — NVFP4 (block 16, E4M3 scales) and MXFP4 (block 32, E8M0
//!   scales) block quantization
//! * [`tensor4`] — packed 4-bit tensors (2 codes/byte + scale bytes): the
//!   storage the FP4 KV cache and the real-quant attention engine use
//! * [`lut`] — the 256×256 byte-pair dot LUT that lets the engines consume
//!   packed storage directly (8 lookups + 1 multiply per 16-element block)
//! * [`analysis`] — quantization-error statistics
//!
//! Decoding an (E2M1 code × E4M3 scale) pair into f32 and accumulating in
//! f32 is numerically identical to what Blackwell's FP4MM hardware does, so
//! every *error-behaviour* experiment in the paper transfers exactly
//! (speed is modeled separately in `perfmodel`). Golden vectors emitted by
//! `python/compile/aot.py` pin this module to the JAX implementation.

pub mod analysis;
pub mod block;
pub mod e2m1;
pub mod e4m3;
pub mod e8m0;
pub mod lut;
pub mod tensor4;

pub use block::{mxfp4_quant_block, nvfp4_dequant_row, nvfp4_quant_row, MXFP4_BLOCK, NVFP4_BLOCK};
pub use tensor4::PackedNvfp4;

/// Round-to-nearest-even onto a mini-float magnitude lattice, closed form.
///
/// The lattice is "`mant_bits` mantissa bits, normal binades ≥ `min_binade`,
/// subnormal spacing below, saturate at `max_val`" — the exact mirror of
/// `python/compile/kernels/nvfp4._rne_binade`:
///
/// ```text
/// b    = max(floor(log2(mag)), min_binade)
/// step = 2^(b − mant_bits)
/// q    = round_ties_even(mag / step) · step, clamped to max_val
/// ```
///
/// `mag / step` is exact (power-of-two divisor), so the tie cases land
/// exactly on `.5` and `round_ties_even` reproduces IEEE RNE on the code
/// lattice (even quotient == even mantissa code).
pub fn rne_binade(mag: f32, mant_bits: i32, min_binade: i32, max_val: f32) -> f32 {
    debug_assert!(mag >= 0.0);
    if mag == 0.0 {
        return 0.0;
    }
    let bits = mag.to_bits();
    let exp_field = ((bits >> 23) & 0xFF) as i32;
    // Subnormal f32 inputs have exp_field == 0; they sit far below every
    // lattice we use, so clamping to min_binade is exact.
    let b = if exp_field == 0 { min_binade } else { (exp_field - 127).max(min_binade) };
    // 2^(b - mant_bits) constructed from bits (no libm exp2 call; the
    // exponent is always in the normal f32 range for our lattices).
    let step = f32::from_bits(((b - mant_bits + 127) as u32) << 23);
    let q = (mag / step).round_ties_even() * step;
    q.min(max_val)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rne_binade_e2m1_ties() {
        // Exact midpoints must follow the even-code convention.
        let cases = [
            (0.25, 0.0),
            (0.75, 1.0),
            (1.25, 1.0),
            (1.75, 2.0),
            (2.5, 2.0),
            (3.5, 4.0),
            (5.0, 4.0),
            (7.0, 6.0),
        ];
        for (x, want) in cases {
            assert_eq!(rne_binade(x, 1, 0, 6.0), want, "x={x}");
        }
    }

    #[test]
    fn rne_binade_zero_and_tiny() {
        assert_eq!(rne_binade(0.0, 1, 0, 6.0), 0.0);
        assert_eq!(rne_binade(1e-30, 1, 0, 6.0), 0.0);
        assert_eq!(rne_binade(f32::MIN_POSITIVE / 2.0, 3, -6, 448.0), 0.0);
    }
}
