//! `repro` — the Attn-QAT reproduction launcher.
//!
//! ```text
//! repro list                          # artifacts in the registry
//! repro train  <train_artifact>       # run a training loop
//! repro eval   <size> <variant>       # ppl + benchmark suites
//! repro sample <size> <variant>       # diffusion sampling + metrics
//! repro serve  <size>                 # batched FP4-KV decode demo
//! repro exp    <table1|...|fig5|all>  # regenerate a paper table/figure
//! ```
//!
//! Common flags: `-c <config.toml>` (preset file), `-s key=value`
//! (override), `--artifacts <dir>`.

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use attn_qat::config::Config;
use attn_qat::coordinator::{LrSchedule, Trainer};
use attn_qat::data::corpus::Corpus;
use attn_qat::data::latents::LatentGen;
use attn_qat::experiments;
use attn_qat::runtime::Runtime;
use attn_qat::serve::{DecodeServer, Request};

struct Cli {
    command: String,
    args: Vec<String>,
    cfg: Config,
    artifacts: PathBuf,
}

fn parse_cli() -> Result<Cli> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = Config::default();
    let mut artifacts = Runtime::default_dir();
    let mut rest = Vec::new();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "-c" | "--config" => {
                i += 1;
                let path = argv.get(i).ok_or_else(|| anyhow!("-c needs a path"))?;
                cfg = Config::load(std::path::Path::new(path))?;
            }
            "-s" | "--set" => {
                i += 1;
                cfg.set(argv.get(i).ok_or_else(|| anyhow!("-s needs key=value"))?)?;
            }
            "--artifacts" => {
                i += 1;
                artifacts = PathBuf::from(argv.get(i).ok_or_else(|| anyhow!("--artifacts needs a dir"))?);
            }
            other => rest.push(other.to_string()),
        }
        i += 1;
    }
    if rest.is_empty() {
        rest.push("help".to_string());
    }
    argv = rest;
    Ok(Cli { command: argv[0].clone(), args: argv[1..].to_vec(), cfg, artifacts })
}

fn main() -> Result<()> {
    let cli = parse_cli()?;
    if cli.command == "help" {
        println!("{}", HELP);
        return Ok(());
    }
    if cli.command == "serve" && cli.args.first().map(String::as_str) == Some("cluster") {
        // The sharded native cluster needs no compiled artifacts and no
        // PJRT backend — dispatch before the runtime is even attempted.
        return cmd_serve_cluster(&cli, false);
    }
    if cli.command == "serve" && cli.args.first().map(String::as_str) == Some("stats") {
        // `serve cluster` with JSON output forced on: one machine-readable
        // telemetry snapshot on stdout, nothing else.
        return cmd_serve_cluster(&cli, true);
    }
    if cli.command == "serve" && cli.args.first().map(String::as_str) == Some("profile") {
        // Self-profiler over the demo cluster: span self-time table plus
        // optional collapsed flamegraph stacks. Native, no PJRT.
        return cmd_serve_profile(&cli);
    }
    if cli.command == "bench" {
        // Bench-artifact aggregation; touches only results/bench/*.jsonl.
        return cmd_bench(&cli);
    }
    if cli.command == "train" && cli.args.first().map(String::as_str) == Some("native") {
        // Native QatModel finetune + train→serve round trip: no PJRT.
        return cmd_train_native(&cli);
    }
    let rt = match Runtime::new(&cli.artifacts) {
        Ok(rt) => rt,
        Err(e) if cli.command == "exp" => {
            // No PJRT backend (stub xla build, or artifacts missing): the
            // native qat subsystem still reproduces fig3 end to end.
            eprintln!("[repro] PJRT runtime unavailable ({e}); using the native-only path");
            let id = cli.args.first().map(String::as_str).unwrap_or("all");
            return experiments::run_native(id, &cli.cfg);
        }
        Err(e) => return Err(e),
    };
    match cli.command.as_str() {
        "list" => {
            for name in rt.registry().names() {
                let meta = rt.meta(name)?;
                println!(
                    "{name:<40} kind={:<12} inputs={} outputs={}",
                    meta.kind(),
                    meta.inputs.len(),
                    meta.outputs.len()
                );
            }
            Ok(())
        }
        "train" => cmd_train(&rt, &cli),
        "eval" => cmd_eval(&rt, &cli),
        "sample" => cmd_sample(&rt, &cli),
        "serve" => cmd_serve(&rt, &cli),
        "exp" => {
            let id = cli.args.first().map(String::as_str).unwrap_or("all");
            experiments::run(&rt, id, &cli.cfg)
        }
        other => bail!("unknown command '{other}'\n{HELP}"),
    }
}

fn cmd_train(rt: &Runtime, cli: &Cli) -> Result<()> {
    let artifact = cli
        .args
        .first()
        .ok_or_else(|| anyhow!("usage: repro train <train_artifact>"))?;
    let meta = rt.meta(artifact)?;
    let kind = meta.kind().to_string();
    let size = meta.str_field("size").unwrap_or("small").to_string();
    let steps = cli.cfg.usize_or("train.steps", 100);
    let lr = cli.cfg.f32_or("train.lr", 1e-3);
    let seed = cli.cfg.u64_or("seed", 42);
    let batch = meta.usize_field("batch").ok_or_else(|| anyhow!("batch"))?;
    let init = if kind.starts_with("lm") {
        format!("lm_init_{size}")
    } else {
        format!("diff_init_{size}")
    };
    let mut trainer = Trainer::new(
        rt,
        &init,
        artifact,
        seed as i32,
        LrSchedule::Cosine { warmup: steps / 10 + 1, peak: lr, total: steps, floor_frac: 0.1 },
    )?;
    if kind == "lm_train" {
        let seq = meta.raw.get("model").get("seq_len").as_usize().unwrap();
        let mut corpus = Corpus::new(seed);
        trainer.run(
            steps,
            cli.cfg.usize_or("train.log_every", 10),
            |_| {
                let b = corpus.next_batch(batch, seq);
                vec![b.token_value(), b.mask_value()]
            },
            |m| println!("step {:>5} loss {:.4} gnorm {:.3} lr {:.2e} {:.0}ms",
                m.step, m.loss, m.grad_norm, m.lr, m.wall_ms),
        )?;
    } else {
        let model = meta.raw.get("model").clone();
        let frames = model.get("frames").as_usize().unwrap();
        let latent_dim = model.get("latent_dim").as_usize().unwrap();
        let mut gen = LatentGen::new(seed, frames, latent_dim);
        trainer.run(
            steps,
            cli.cfg.usize_or("train.log_every", 10),
            |_| gen.next_batch(batch).values().to_vec(),
            |m| println!("step {:>5} loss {:.4} gnorm {:.3} lr {:.2e} {:.0}ms",
                m.step, m.loss, m.grad_norm, m.lr, m.wall_ms),
        )?;
    }
    println!(
        "done: {} steps, tail loss {:.4}, diverged={}",
        steps,
        trainer.tail_loss(10),
        trainer.diverged()
    );
    Ok(())
}

/// `repro train native [-s train.steps=N] [-s train.lr=X] [-s key=value ...]`
///
/// The native train→serve round trip, end to end without PJRT: finetune a
/// `model::QatModel` (Attn-QAT per-layer attention, Adam + global
/// grad-clip — the paper's recipe) on the synthetic byte corpus through
/// `model::TrainSession`, export the quantized checkpoint, re-import it,
/// and serve it from a sharded `DecodeCluster`, cross-checking the
/// cluster completions bitwise against a direct greedy decode of the same
/// model.
///
/// Config keys (override with `-s key=value`): `train.steps`, `train.lr`,
/// `train.seq`, `train.variant`, `train.grad_clip`, `train.microbatch`,
/// `train.optimizer` (`adam` | `lowp_adam`), `train.proj` (`off` | `ste` |
/// `naive`), `train.hadamard`, `model.layers`, `model.heads`,
/// `model.head_dim`, `model.ff`, `serve.shards`, `seed`.
fn cmd_train_native(cli: &Cli) -> Result<()> {
    use attn_qat::attention::AttnConfig;
    use attn_qat::model::{greedy_decode, LmTrainTask, ProjQuant, QatModel, QatModelConfig,
        TrainConfig, TrainSession};
    use attn_qat::serve::{ClusterConfig, DecodeCluster, ShardConfig};

    let cfg = &cli.cfg;
    let steps = cfg.usize_or("train.steps", 80);
    let lr = cfg.f32_or("train.lr", 5e-3);
    let seq = cfg.usize_or("train.seq", 48);
    let clip = cfg.f32_or("train.grad_clip", 1.0);
    let variant = cfg.str_or("train.variant", "attn_qat");
    let micro = cfg.usize_or("train.microbatch", 1);
    let optimizer = cfg.str_or("train.optimizer", "adam");
    let proj_mode = cfg.str_or("train.proj", "off");
    let hadamard = cfg.bool_or("train.hadamard", false);
    let seed = cfg.u64_or("seed", 42);
    let attn = AttnConfig::parse(&variant).map_err(|e| anyhow!("{e}"))?;
    let proj = match proj_mode.as_str() {
        "off" => ProjQuant::off(),
        "ste" => ProjQuant::ste(),
        "naive" => ProjQuant::naive(),
        other => bail!("unknown train.proj '{other}' (off, ste, naive)"),
    }
    .with_hadamard(hadamard);
    let model_cfg = QatModelConfig {
        layers: cfg.usize_or("model.layers", 2),
        heads: cfg.usize_or("model.heads", 2),
        head_dim: cfg.usize_or("model.head_dim", 16),
        ff: cfg.usize_or("model.ff", 64),
        max_pos: 512,
        seed,
        attn,
    };
    println!(
        "train native: {} layer(s) x {} head(s) x d{}, seq {seq}, {steps} steps, \
         lr {lr:.1e}, clip {clip}, attn={variant}, proj={}, optim={optimizer}, \
         micro={micro}, seed={seed}",
        model_cfg.layers,
        model_cfg.heads,
        model_cfg.head_dim,
        proj.label()
    );
    let mut qat_model = QatModel::new(model_cfg);
    qat_model.set_proj_quant(proj);
    let task = LmTrainTask::new(qat_model, seq, seed ^ 0x77a1);
    let train_cfg = match optimizer.as_str() {
        "adam" => TrainConfig::adam(lr),
        "lowp_adam" => TrainConfig::lowp_adam(lr, seed ^ 0x5eed),
        other => bail!("unknown train.optimizer '{other}' (adam, lowp_adam)"),
    }
    .with_grad_clip(Some(clip))
    .with_microbatch(micro);
    let mut session = TrainSession::new(task, train_cfg);
    session.run(steps, (steps / 8).max(1), |m| {
        println!(
            "  step {:>5} loss {:.4} gnorm {:.3} lr {:.2e} {:.0}ms",
            m.step, m.loss, m.grad_norm, m.lr, m.wall_ms
        )
    });
    println!(
        "trained: tail-10 loss {:.4}, max gnorm {:.3}, diverged={}, opt state {} B",
        session.tail_loss(10),
        session.max_grad_norm(),
        session.diverged(),
        session.optimizer_state_bytes()
    );

    // Export → import → serve: the round trip.
    let ckpt = std::path::Path::new("results/ckpt/qat_model_native.ckpt");
    let model = session.model.into_model();
    model.save_quantized(ckpt)?;
    println!("checkpoint (quantized projections) -> {}", ckpt.display());
    let serve_attn = if attn.quantized() { AttnConfig::fp4() } else { AttnConfig::f32() };
    let served = QatModel::load(ckpt, serve_attn)?;

    let shards = cfg.usize_or("serve.shards", 2);
    let max_new = cfg.usize_or("serve.max_new_tokens", 16);
    let trace = attn_qat::experiments::cluster::demo_trace(6, max_new, seed);
    let cluster_cfg = ClusterConfig {
        shards,
        queue_depth: 16,
        shard: ShardConfig {
            slots: 2,
            attn: serve_attn,
            seq_max: 512,
            sample_seed: seed,
            ..ShardConfig::default()
        },
        ..ClusterConfig::default()
    };
    let served_factory = served.clone();
    let mut cluster = DecodeCluster::spawn(cluster_cfg, move |_| Box::new(served_factory.clone()));
    for r in trace.iter().cloned() {
        cluster.submit(r)?;
    }
    let (done, stats) = cluster.drain()?;
    let mut mismatches = 0usize;
    for c in &done {
        let req = trace.iter().find(|r| r.id == c.id).expect("trace id");
        let direct = greedy_decode(&served, serve_attn, &req.prompt, req.max_new_tokens, 512)?;
        let ok = direct == c.text;
        mismatches += usize::from(!ok);
        println!(
            "  req {:>2}: {:>2} prompt + {:>2} new  direct-eval {}  {:?}",
            c.id,
            c.prompt_tokens,
            c.new_tokens,
            if ok { "match" } else { "MISMATCH" },
            String::from_utf8_lossy(&c.text)
        );
    }
    println!(
        "\nserved {} completions over {} shard(s), {} tokens; direct-eval mismatches: {}",
        done.len(),
        shards,
        stats.total_tokens(),
        mismatches
    );
    if mismatches > 0 {
        bail!("train->serve parity violated: {mismatches} completions differ from direct eval");
    }
    Ok(())
}

fn cmd_eval(rt: &Runtime, cli: &Cli) -> Result<()> {
    let size = cli.args.first().ok_or_else(|| anyhow!("usage: repro eval <size> [variant]"))?;
    let variant = cli.args.get(1).map(String::as_str).unwrap_or("f32");
    let params = experiments::common::ensure_lm_base(rt, size, &cli.cfg)?;
    let artifact = format!("lm_eval_{variant}_{size}");
    let seed = cli.cfg.u64_or("seed", 42);
    let mut held_out = Corpus::new(seed ^ 0xeeee);
    let ppl = attn_qat::eval::perplexity(rt, &artifact, &params, &mut held_out, 3)?;
    println!("held-out ppl ({variant}): {ppl:.4}");
    for suite in attn_qat::data::tasks::MC_SUITES {
        let acc = attn_qat::eval::mc_accuracy(rt, &artifact, &params, suite, 40, seed + 9)?;
        println!("  {suite:<8} acc {acc:.4}");
    }
    Ok(())
}

fn cmd_sample(rt: &Runtime, cli: &Cli) -> Result<()> {
    let size = cli.args.first().ok_or_else(|| anyhow!("usage: repro sample <size> [variant]"))?;
    let mut cfg = cli.cfg.clone();
    cfg.set(&format!("diff.table2_size={size}"))?;
    experiments::diffusion::fig1(rt, &cfg)
}

fn cmd_serve(rt: &Runtime, cli: &Cli) -> Result<()> {
    let size = cli.args.first().map(String::as_str).unwrap_or("tiny");
    let meta = rt.meta(&format!("lm_init_{size}"))?;
    let names = meta.param_names();
    // Weights: cached base if available, else fresh init.
    let params = experiments::common::load_cached(&format!("lm_base_{size}"), &names)
        .unwrap_or(rt.run(&format!("lm_init_{size}"), &[attn_qat::runtime::Value::scalar_i32(
            cli.cfg.u64_or("seed", 42) as i32,
        )])?);
    let weights: Vec<(String, attn_qat::tensor::Tensor)> =
        names.into_iter().zip(params).collect();
    let mut server = DecodeServer::new(rt, size, weights)?;
    let n_req = cli.cfg.usize_or("serve.requests", 8);
    let max_new = cli.cfg.usize_or("serve.max_new_tokens", 24);
    let t0 = std::time::Instant::now();
    for i in 0..n_req {
        server.submit(Request {
            id: i as u64 + 1,
            prompt: format!("C:hello{i}#").into_bytes(),
            max_new_tokens: max_new,
            temperature: 0.0,
            deadline_ms: None,
            trace: Default::default(),
        });
    }
    let done = server.run()?;
    let wall = t0.elapsed().as_secs_f64();
    for c in &done {
        println!(
            "req {:>3}: {:>3} prompt + {:>3} new tokens in {:>7.1} ms  {:?}",
            c.id,
            c.prompt_tokens,
            c.new_tokens,
            c.wall_ms,
            String::from_utf8_lossy(&c.text)
        );
    }
    let stats = server.stats;
    println!(
        "\n{} tokens in {:.2}s = {:.1} tok/s | KV mem {} B (f32-equiv {} B, {:.1}x saved)",
        stats.tokens_decoded,
        wall,
        stats.tokens_decoded as f64 / wall,
        stats.kv_bytes,
        stats.kv_bytes_f32_equiv,
        stats.kv_bytes_f32_equiv as f64 / stats.kv_bytes.max(1) as f64
    );
    Ok(())
}

/// `repro serve cluster [--shards N] [--requests R] [--max-new M]
/// [--queue-depth Q] [--lanes L] [--variant fp4|f32] [--seed S]
/// [--deadline-ms D] [--faults SPEC] [--stall-timeout-ms T]
/// [--max-restarts K] [--prefix-share] [--kv-spill-dir DIR]
/// [--kv-spill-budget-kb N] [--json] [--stats-every-ms T]
/// [--trace-out FILE]`
///
/// Native sharded decode: routes a deterministic request trace (prompts
/// drawn from the synthetic corpus) across N supervised shard workers,
/// each with its own FP4 paged KV cache and per-lane attention engines,
/// then drains and prints per-shard and aggregate throughput. Runs end to
/// end without the PJRT runtime. Flags also read from config keys
/// `serve.shards`, `serve.requests`, `serve.max_new_tokens`,
/// `serve.queue_depth`, `serve.lanes`, `serve.variant`, `seed`.
///
/// `--deadline-ms` tags every request with an SLO so the cluster sheds
/// infeasible work at admission; `--faults` injects seeded shard faults
/// (comma-separated `panic:S:P`, `stall:S:P:MS`, `every:S:K`) that the
/// supervisor must survive without losing a single request.
///
/// `--prefix-share` turns on shared-prefix admission: each shard dedups
/// sealed KV pages through its refcounted page pool and skips prefill
/// for prompt prefixes already resident (bitwise identical outputs).
/// `--kv-spill-dir DIR` additionally spills cold sealed pages to disk
/// under a `--kv-spill-budget-kb` resident budget (default 256 KiB),
/// reloading transparently on next attend.
///
/// `--json` (the whole of `repro serve stats`) replaces the human
/// summary with one schema-versioned [`attn_qat::telemetry`] snapshot on
/// stdout — live config, per-shard gauges, supervisor counters, span
/// stats. `--stats-every-ms T` additionally appends a snapshot line to
/// `results/serve_cluster_stats.jsonl` every T ms while the run drains.
///
/// `--trace-out FILE` exports the run's causal span tree as Chrome
/// trace-event JSON (Perfetto / `chrome://tracing` loadable): one track
/// per request trace, every prefill/decode span's parent chain resolving
/// to its request root. The span ring is enlarged (8192) so a demo-sized
/// run exports untruncated.
fn cmd_serve_cluster(cli: &Cli, force_json: bool) -> Result<()> {
    use attn_qat::serve::{
        Admission, ClusterConfig, DecodeCluster, FaultPlan, ShardConfig, SimLm, SimLmConfig,
        SupervisorConfig,
    };
    use attn_qat::telemetry::Telemetry;

    // `--flag value` pairs after the `cluster` subcommand override config
    // (`--json` and `--prefix-share` stand alone: they take no value).
    let mut flags = std::collections::BTreeMap::new();
    let mut json_flag = false;
    let mut prefix_share_flag = false;
    let rest = &cli.args[1..];
    let mut i = 0;
    while i < rest.len() {
        let key = rest[i]
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --flag, got '{}'", rest[i]))?;
        if key == "json" {
            json_flag = true;
            i += 1;
            continue;
        }
        if key == "prefix-share" {
            prefix_share_flag = true;
            i += 1;
            continue;
        }
        let val = rest.get(i + 1).ok_or_else(|| anyhow!("--{key} needs a value"))?;
        flags.insert(key.to_string(), val.clone());
        i += 2;
    }
    let get_usize = |name: &str, cfg_key: &str, default: usize| -> Result<usize> {
        match flags.get(name) {
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} wants an integer, got '{v}'")),
            None => Ok(cli.cfg.usize_or(cfg_key, default)),
        }
    };
    let shards = get_usize("shards", "serve.shards", 4)?;
    let n_req = get_usize("requests", "serve.requests", 32)?;
    let max_new = get_usize("max-new", "serve.max_new_tokens", 24)?;
    let queue_depth = get_usize("queue-depth", "serve.queue_depth", 64)?;
    let lanes = get_usize("lanes", "serve.lanes", 4)?;
    let seed = match flags.get("seed") {
        Some(v) => v.parse().map_err(|_| anyhow!("--seed wants an integer"))?,
        None => cli.cfg.u64_or("seed", 42),
    };
    let variant = flags
        .get("variant")
        .cloned()
        .unwrap_or_else(|| cli.cfg.str_or("serve.variant", "fp4"));
    let attn = attn_qat::attention::AttnConfig::parse(&variant).map_err(|e| anyhow!("{e}"))?;
    let deadline_ms: Option<f64> = match flags.get("deadline-ms") {
        Some(v) => Some(v.parse().map_err(|_| anyhow!("--deadline-ms wants a number"))?),
        None => None,
    };
    let faults = match flags.get("faults") {
        Some(spec) => FaultPlan::parse(spec)?,
        None => FaultPlan::none(),
    };
    let stall_timeout_ms: f64 = match flags.get("stall-timeout-ms") {
        Some(v) => v.parse().map_err(|_| anyhow!("--stall-timeout-ms wants a number"))?,
        None => cli.cfg.f32_or("serve.stall_timeout_ms", 2_000.0) as f64,
    };
    let max_restarts = get_usize("max-restarts", "serve.max_restarts", 4)?;
    let stats_every_ms = get_usize("stats-every-ms", "serve.stats_every_ms", 0)?;
    let want_json = force_json || json_flag || cli.cfg.bool_or("serve.json", false);
    let prefix_share = prefix_share_flag || cli.cfg.bool_or("serve.prefix_share", false);
    let kv_spill_budget_kb = get_usize("kv-spill-budget-kb", "serve.kv_spill_budget_kb", 256)?;
    let kv_spill = match flags.get("kv-spill-dir") {
        Some(dir) => Some(attn_qat::kvcache::SpillConfig {
            dir: PathBuf::from(dir),
            budget_bytes: kv_spill_budget_kb * 1024,
        }),
        None => None,
    };
    const KNOWN: [&str; 17] = [
        "shards",
        "requests",
        "max-new",
        "queue-depth",
        "lanes",
        "seed",
        "variant",
        "deadline-ms",
        "faults",
        "stall-timeout-ms",
        "max-restarts",
        "prefix-share",
        "kv-spill-dir",
        "kv-spill-budget-kb",
        "json",
        "stats-every-ms",
        "trace-out",
    ];
    if let Some(unknown) = flags.keys().find(|k| !KNOWN.contains(&k.as_str())) {
        bail!("unknown flag --{unknown} (expected one of: --{})", KNOWN.join(", --"));
    }
    if shards == 0 || n_req == 0 || lanes == 0 || queue_depth == 0 {
        bail!("need at least one shard, request, lane, and queue slot");
    }

    if !want_json {
        println!(
            "serve cluster: {shards} shard(s) x {lanes} lane(s), {n_req} requests, \
             max_new={max_new}, attn={variant}, queue_depth={queue_depth}, seed={seed}"
        );
    }
    let cluster_cfg = ClusterConfig {
        shards,
        queue_depth,
        shard: ShardConfig {
            slots: lanes,
            attn,
            seq_max: 512,
            sample_seed: seed,
            prefix_share,
            kv_spill,
            ..ShardConfig::default()
        },
        supervisor: SupervisorConfig {
            stall_timeout_ms,
            max_restarts,
            ..SupervisorConfig::default()
        },
    };
    let lm_cfg = SimLmConfig { seed, ..SimLmConfig::default() };
    let plan = faults.clone();
    let trace_out = flags.get("trace-out").cloned();
    // Exporting a trace wants the whole run retained, not the default
    // ring's newest slice.
    let telemetry = if trace_out.is_some() {
        Telemetry::with_span_capacity(8192)
    } else {
        Telemetry::new()
    };
    let mut cluster = DecodeCluster::spawn_observed(cluster_cfg, telemetry.clone(), move |shard| {
        plan.wrap(shard, Box::new(SimLm::new(lm_cfg)))
    });

    // Periodic snapshot writer: one JSON doc per line, readable while the
    // run is still in flight (the registry is lock-cheap to walk).
    let stop_writer = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let writer = if stats_every_ms > 0 {
        let tele = telemetry.clone();
        let stop = stop_writer.clone();
        std::fs::create_dir_all("results").ok();
        Some(std::thread::spawn(move || {
            use std::io::Write;
            let Ok(mut f) = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open("results/serve_cluster_stats.jsonl")
            else {
                return;
            };
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(stats_every_ms as u64));
                if writeln!(f, "{}", tele.snapshot()).is_err() {
                    return;
                }
            }
        }))
    } else {
        None
    };

    // Deterministic trace, shared with `exp cluster` and the bench so
    // all three drive the same workload.
    let t0 = std::time::Instant::now();
    let mut shed = 0usize;
    for mut r in attn_qat::experiments::cluster::demo_trace(n_req, max_new, seed) {
        r.deadline_ms = deadline_ms;
        if cluster.submit(r)? != Admission::Accepted {
            shed += 1;
        }
    }
    let (done, stats) = cluster.drain()?;
    let wall = t0.elapsed().as_secs_f64();
    stop_writer.store(true, std::sync::atomic::Ordering::Relaxed);
    if let Some(h) = writer {
        let _ = h.join();
        if !want_json {
            println!("snapshots (every {stats_every_ms} ms) -> results/serve_cluster_stats.jsonl");
        }
    }

    if want_json {
        // Machine-readable mode: the one schema-versioned telemetry doc
        // (post-drain, so shard gauges hold their final published stats)
        // is the entire stdout output.
        println!("{}", telemetry.snapshot());
    } else {
        for s in &stats.shards {
            println!(
                "shard {:>2}: {:>4} req {:>7} tok  {:>9.1} tok/s  queue<= {:<3} \
                 p50 {:.3} ms  p99 {:.3} ms  qcache {}h/{}m  kv<= {} B",
                s.shard,
                s.requests,
                s.tokens,
                s.tokens_per_s,
                s.queue_peak,
                s.p50_token_ms,
                s.p99_token_ms,
                s.qcache_hits,
                s.qcache_misses,
                s.kv_bytes_peak,
            );
        }
        let total_tok = stats.total_tokens();
        println!(
            "\n{} completions, {} tokens in {:.2}s = {:.1} tok/s aggregate | \
             cluster p99 {:.3} ms | KV peak {} B",
            done.len(),
            total_tok,
            wall,
            total_tok as f64 / wall.max(1e-9),
            stats.p99_token_ms(),
            stats.kv_bytes_peak(),
        );
        if prefix_share {
            let (hits, pages, bytes, splits) = stats.prefix_totals();
            println!(
                "prefix sharing: {hits} hit(s), {pages} page ref(s) attached, {bytes} B \
                 saved, {splits} COW split(s), {} page(s) spilled",
                stats.spilled_pages(),
            );
        }
        if stats.restarts > 0 || faults.trips() > 0 {
            println!(
                "supervision: {} fault(s) tripped, {} restart(s), {} request(s) replayed, \
                 {} pass(es) recomputed",
                faults.trips(),
                stats.restarts,
                stats.replayed_requests,
                stats.recomputed_passes,
            );
        }
        if deadline_ms.is_some() {
            println!(
                "admission: {} accepted, {} shed on deadline, {} shed on capacity \
                 ({} submit retry(ies))",
                n_req - shed,
                stats.shed_deadline,
                stats.shed_capacity,
                stats.submit_retries,
            );
        }
    }
    if let Some(path) = &trace_out {
        let records = telemetry.spans().records();
        let doc = attn_qat::telemetry::chrome_trace(&records);
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, format!("{doc}\n"))?;
        if !want_json {
            println!("chrome trace ({} span(s)) -> {path}", records.len());
        }
    }
    if done.len() + shed != n_req {
        bail!(
            "lost completions: submitted {n_req}, shed {shed}, drained {}",
            done.len()
        );
    }
    Ok(())
}

/// `repro serve profile [--shards N] [--requests R] [--max-new M]
/// [--fold-out FILE]`
///
/// Self-profiler: drives the demo cluster workload under a large span
/// ring, folds the causal span tree into an inclusive/exclusive self-time
/// table (exclusive = a span's duration minus its direct children) and
/// prints it sorted by self time. `--fold-out FILE` additionally writes
/// collapsed-stack lines (`root;child;leaf N`, weights in µs) — pipe to
/// inferno or any FlameGraph-compatible renderer.
fn cmd_serve_profile(cli: &Cli) -> Result<()> {
    use attn_qat::serve::{ClusterConfig, DecodeCluster, SimLm, SimLmConfig};
    use attn_qat::telemetry::{self, Telemetry};

    let mut flags = std::collections::BTreeMap::new();
    let rest = &cli.args[1..];
    let mut i = 0;
    while i < rest.len() {
        let key = rest[i]
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --flag, got '{}'", rest[i]))?;
        let val = rest.get(i + 1).ok_or_else(|| anyhow!("--{key} needs a value"))?;
        flags.insert(key.to_string(), val.clone());
        i += 2;
    }
    const KNOWN: [&str; 4] = ["shards", "requests", "max-new", "fold-out"];
    if let Some(unknown) = flags.keys().find(|k| !KNOWN.contains(&k.as_str())) {
        bail!("unknown flag --{unknown} (expected one of: --{})", KNOWN.join(", --"));
    }
    let get_usize = |name: &str, default: usize| -> Result<usize> {
        match flags.get(name) {
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} wants an integer, got '{v}'")),
            None => Ok(default),
        }
    };
    let shards = get_usize("shards", 2)?;
    let n_req = get_usize("requests", 12)?;
    let max_new = get_usize("max-new", 12)?;
    let seed = cli.cfg.u64_or("seed", 42);

    let telemetry = Telemetry::with_span_capacity(16384);
    let cluster_cfg = ClusterConfig { shards, queue_depth: 32, ..ClusterConfig::default() };
    let lm_cfg = SimLmConfig { seed, ..SimLmConfig::default() };
    let mut cluster = DecodeCluster::spawn_observed(cluster_cfg, telemetry.clone(), move |_| {
        Box::new(SimLm::new(lm_cfg))
    });
    for r in attn_qat::experiments::cluster::demo_trace(n_req, max_new, seed) {
        cluster.submit(r)?;
    }
    let (done, _stats) = cluster.drain()?;
    let records = telemetry.spans().records();
    let rows = telemetry::self_time(&records);
    println!(
        "serve profile: {} request(s) over {shards} shard(s), {} span(s) recorded\n",
        done.len(),
        records.len()
    );
    print!("{}", telemetry::profile_table(&rows));
    if let Some(path) = flags.get("fold-out") {
        let lines = telemetry::flamegraph_lines(&records);
        std::fs::write(path, lines.join("\n") + "\n")?;
        println!("\ncollapsed stacks ({} line(s)) -> {path}", lines.len());
    }
    Ok(())
}

/// `repro bench summary` — fold every `results/bench/*.jsonl` (runmeta
/// provenance headers plus result rows) into the repo-root
/// `BENCH_summary.json` trajectory document. A missing or empty bench
/// directory degrades to an empty summary, not an error.
fn cmd_bench(cli: &Cli) -> Result<()> {
    match cli.args.first().map(String::as_str) {
        Some("summary") => {
            let doc =
                attn_qat::telemetry::summarize_bench_dir(std::path::Path::new("results/bench"));
            let out = "BENCH_summary.json";
            std::fs::write(out, format!("{doc}\n"))?;
            let n = doc.get("benches").as_obj().map_or(0, |b| b.len());
            println!("bench summary ({n} bench file(s)) -> {out}");
            Ok(())
        }
        _ => bail!("usage: repro bench summary"),
    }
}

const HELP: &str = "repro — Attn-QAT reproduction launcher

USAGE:
    repro <command> [args] [-c config.toml] [-s key=value] [--artifacts dir]

COMMANDS:
    list                         list registered artifacts
    train <artifact>             run a training loop on a *_train_* artifact
    train native                 finetune a native QatModel (Adam + grad
                                 clip), export the quantized checkpoint,
                                 and serve it from the sharded cluster —
                                 the train->serve round trip, no PJRT
    eval <size> [variant]        perplexity + benchmark suites
    sample <size>                diffusion sampling + VBench-proxy metrics
    serve [size]                 batched decode demo over the FP4 KV cache
    serve cluster [--shards N] [--requests R] [--max-new M]
                  [--queue-depth Q] [--lanes L] [--variant fp4|f32]
                  [--deadline-ms D] [--faults SPEC]
                  [--stall-timeout-ms T] [--max-restarts K]
                  [--prefix-share] [--kv-spill-dir DIR]
                  [--kv-spill-budget-kb N]
                  [--json] [--stats-every-ms T] [--trace-out FILE]
                                 native sharded decode cluster with shard
                                 supervision, deadline-aware shedding, and
                                 seeded fault injection (--faults takes
                                 comma-separated panic:S:P, stall:S:P:MS,
                                 every:S:K); no PJRT runtime or artifacts;
                                 --prefix-share dedups sealed KV pages and
                                 skips prefill for shared prompt prefixes;
                                 --kv-spill-dir spills cold sealed pages to
                                 disk under a resident-byte budget;
                                 --json emits one telemetry snapshot doc,
                                 --stats-every-ms streams snapshot lines to
                                 results/serve_cluster_stats.jsonl;
                                 --trace-out exports the causal request
                                 trace as Chrome trace-event JSON
                                 (Perfetto / chrome://tracing loadable)
    serve stats [flags]          serve cluster with --json forced on: the
                                 schema-versioned telemetry snapshot (live
                                 config, per-shard gauges, supervisor
                                 counters, spans) is the entire output
    serve profile [--shards N] [--requests R] [--max-new M]
                  [--fold-out FILE]
                                 self-profile the demo cluster: span
                                 inclusive/exclusive self-time table on
                                 stdout; --fold-out writes collapsed
                                 flamegraph stacks (inferno-compatible)
    bench summary                fold results/bench/*.jsonl (runmeta
                                 headers + rows) into BENCH_summary.json
    exp <id>                     regenerate a paper table/figure:
                                 table1 table2 table3 table4 fig1..fig5
                                 cluster faults fullstack all
";
