//! Shard supervision: catch_unwind workers, heartbeats, deterministic
//! replay, and seeded fault injection.
//!
//! The cluster's failure model (see [`crate::serve`] module docs) is
//! implemented here. Every shard worker thread runs its serving loop
//! under [`std::panic::catch_unwind`] and publishes a heartbeat through
//! a shared [`ShardTelemetry`]. The crate-internal `Supervisor` owns the worker
//! handles and, whenever it is consulted (on submits and while
//! draining), classifies each shard as:
//!
//! * **healthy** — heartbeat advancing, thread alive;
//! * **dead** — the thread finished outside a drain (a panic caught by
//!   the unwind guard, a worker `Err`, or a dropped channel);
//! * **stalled** — the heartbeat has not advanced for
//!   [`SupervisorConfig::stall_timeout_ms`] while the worker claims to
//!   be busy.
//!
//! Dead and stalled shards are **respawned** from the cluster's model
//! factory and their journaled requests are **replayed** from scratch.
//! Replay is exact because serving is placement-invariant: a sequence's
//! floats depend only on its own tokens, its own cache pages, the
//! (seed-determined) model weights, and its per-request sampling stream
//! — none of which the crash touched. A recovered run is therefore
//! bitwise identical to a fault-free run (pinned by
//! `rust/tests/fault_tolerance.rs`). A stalled thread cannot be killed,
//! so it is *abandoned*: its channel is dropped (it exits on its own
//! once it observes the disconnect) and its eventual results are
//! discarded — the replacement recomputes them. Respawns are bounded by
//! [`SupervisorConfig::max_restarts`] per shard; past the budget the
//! shard is declared dead and its original error surfaces at drain.
//!
//! [`FaultPlan`] is the deterministic fault-injection seam: it wraps a
//! shard's [`TokenModel`] and counts forward passes (`embed` is called
//! exactly once per pass), firing configured panics or stalls at exact
//! pass numbers. Fault state is shared across incarnations, so a
//! one-shot fault does not re-fire after the respawn replays the journal
//! — while [`FaultKind::PanicEvery`] deliberately re-fires to exercise
//! the bounded-restart give-up path.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Once};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::telemetry::{Counter, Telemetry};

use super::model::TokenModel;
use super::shard::{ShardConfig, ShardStats, ShardWorker};
use super::{Completion, Request};

/// Supervision knobs (carried by `ClusterConfig`).
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// A busy shard whose heartbeat is frozen longer than this is
    /// declared stalled, abandoned, and respawned.
    pub stall_timeout_ms: f64,
    /// Respawn budget per shard; exceeding it marks the shard dead and
    /// surfaces its error at drain.
    pub max_restarts: usize,
    /// Bounded retry count for deadline-carrying submits against a full
    /// shard queue (deadline-less submits keep blocking — backpressure).
    pub submit_retries: usize,
    /// Initial submit retry backoff (doubles per attempt, capped).
    pub retry_backoff_us: u64,
}

impl Default for SupervisorConfig {
    fn default() -> SupervisorConfig {
        SupervisorConfig {
            stall_timeout_ms: 2_000.0,
            max_restarts: 4,
            submit_retries: 16,
            retry_backoff_us: 50,
        }
    }
}

/// What a [`FaultPlan`] injects, per fault.
#[derive(Clone, Copy, Debug)]
pub enum FaultKind {
    /// Panic once, on the first forward pass `>= at_pass` of the shard.
    /// Fires once *across incarnations* — replay does not re-trip it.
    Panic { at_pass: u64 },
    /// Sleep `ms` inside one forward pass (a stall the heartbeat
    /// exposes). Also one-shot across incarnations.
    Stall { at_pass: u64, ms: u64 },
    /// Panic on every `period`-th pass, counted across incarnations —
    /// each respawn dies again, exhausting the restart budget.
    PanicEvery { period: u64 },
}

/// One injected fault: which shard, and what happens.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    pub shard: usize,
    pub kind: FaultKind,
}

struct FaultState {
    faults: Vec<FaultSpec>,
    /// One-shot latches (Panic/Stall), shared across incarnations.
    fired: Vec<AtomicBool>,
    /// Passes counted across incarnations (drives `PanicEvery`).
    global_passes: AtomicU64,
    /// Total faults actually triggered.
    trips: AtomicU64,
}

/// A seeded, deterministic set of injected faults, shared by every
/// incarnation of the shards it targets. Cloning shares state, so the
/// submitter can observe [`FaultPlan::trips`] after the run.
#[derive(Clone)]
pub struct FaultPlan {
    state: Arc<FaultState>,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// A plan with no faults — `wrap` is then a free pass-through.
    pub fn none() -> FaultPlan {
        FaultPlan::from_specs(Vec::new())
    }

    fn from_specs(faults: Vec<FaultSpec>) -> FaultPlan {
        let fired = faults.iter().map(|_| AtomicBool::new(false)).collect();
        FaultPlan {
            state: Arc::new(FaultState {
                faults,
                fired,
                global_passes: AtomicU64::new(0),
                trips: AtomicU64::new(0),
            }),
        }
    }

    /// Panic `shard` once at its `pass`-th forward pass (1-based).
    pub fn panic_at(shard: usize, pass: u64) -> FaultPlan {
        FaultPlan::from_specs(vec![FaultSpec { shard, kind: FaultKind::Panic { at_pass: pass } }])
    }

    /// Stall `shard` for `ms` milliseconds at its `pass`-th forward pass.
    pub fn stall_at(shard: usize, pass: u64, ms: u64) -> FaultPlan {
        FaultPlan::from_specs(vec![FaultSpec {
            shard,
            kind: FaultKind::Stall { at_pass: pass, ms },
        }])
    }

    /// Panic `shard` on every `period`-th pass, forever.
    pub fn panic_every(shard: usize, period: u64) -> FaultPlan {
        FaultPlan::from_specs(vec![FaultSpec {
            shard,
            kind: FaultKind::PanicEvery { period: period.max(1) },
        }])
    }

    /// Parse a CLI spec: comma-separated `panic:SHARD:PASS`,
    /// `stall:SHARD:PASS:MS`, or `every:SHARD:PERIOD` clauses.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for clause in spec.split(',').filter(|c| !c.trim().is_empty()) {
            let parts: Vec<&str> = clause.trim().split(':').collect();
            let num = |i: usize| -> Result<u64> {
                parts
                    .get(i)
                    .and_then(|p| p.parse::<u64>().ok())
                    .ok_or_else(|| anyhow!("bad fault clause {clause:?}"))
            };
            let kind = match parts[0] {
                "panic" if parts.len() == 3 => FaultKind::Panic { at_pass: num(2)? },
                "stall" if parts.len() == 4 => {
                    FaultKind::Stall { at_pass: num(2)?, ms: num(3)? }
                }
                "every" if parts.len() == 3 => FaultKind::PanicEvery { period: num(2)?.max(1) },
                _ => bail!(
                    "bad fault clause {clause:?} (want panic:S:P, stall:S:P:MS, or every:S:K)"
                ),
            };
            faults.push(FaultSpec { shard: num(1)? as usize, kind });
        }
        Ok(FaultPlan::from_specs(faults))
    }

    /// No faults configured at all.
    pub fn is_empty(&self) -> bool {
        self.state.faults.is_empty()
    }

    /// Faults actually triggered so far (across all shards/incarnations).
    pub fn trips(&self) -> u64 {
        self.state.trips.load(Ordering::SeqCst)
    }

    /// Wrap shard `shard`'s model with this plan's fault injection. A
    /// plan with no fault aimed at `shard` returns the model unwrapped.
    pub fn wrap(&self, shard: usize, inner: Box<dyn TokenModel>) -> Box<dyn TokenModel> {
        if self.state.faults.iter().all(|f| f.shard != shard) {
            return inner;
        }
        Box::new(FaultyModel { inner, shard, passes: AtomicU64::new(0), state: self.state.clone() })
    }
}

/// [`TokenModel`] wrapper that counts forward passes in `embed` (called
/// exactly once per pass: one batched call per prefill, one per decode
/// step) and fires the plan's faults for its shard.
struct FaultyModel {
    inner: Box<dyn TokenModel>,
    shard: usize,
    /// Passes of *this incarnation* (one-shot faults key on it so "pass
    /// N" means the same pass before and after a replay).
    passes: AtomicU64,
    state: Arc<FaultState>,
}

impl FaultyModel {
    fn tick(&self) {
        let pass = self.passes.fetch_add(1, Ordering::SeqCst) + 1;
        let global = self.state.global_passes.fetch_add(1, Ordering::SeqCst) + 1;
        for (spec, fired) in self.state.faults.iter().zip(&self.state.fired) {
            if spec.shard != self.shard {
                continue;
            }
            match spec.kind {
                FaultKind::Panic { at_pass } => {
                    if pass >= at_pass && !fired.swap(true, Ordering::SeqCst) {
                        self.state.trips.fetch_add(1, Ordering::SeqCst);
                        panic!("injected fault: shard {} panic at pass {pass}", self.shard);
                    }
                }
                FaultKind::Stall { at_pass, ms } => {
                    if pass >= at_pass && !fired.swap(true, Ordering::SeqCst) {
                        self.state.trips.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                }
                FaultKind::PanicEvery { period } => {
                    if global % period == 0 {
                        self.state.trips.fetch_add(1, Ordering::SeqCst);
                        panic!(
                            "injected fault: shard {} periodic panic (period {period})",
                            self.shard
                        );
                    }
                }
            }
        }
    }
}

impl TokenModel for FaultyModel {
    fn layers(&self) -> usize {
        self.inner.layers()
    }

    fn heads(&self) -> usize {
        self.inner.heads()
    }

    fn head_dim(&self) -> usize {
        self.inner.head_dim()
    }

    fn d_model(&self) -> usize {
        self.inner.d_model()
    }

    fn embed(&self, tokens: &[u8], pos0: usize, h: &mut [f32]) {
        self.tick();
        self.inner.embed(tokens, pos0, h)
    }

    fn qkv(&self, layer: usize, h: &[f32], q: &mut [f32], k: &mut [f32], v: &mut [f32]) {
        self.inner.qkv(layer, h, q, k, v)
    }

    fn mix(&self, layer: usize, h: &mut [f32], attn: &[f32]) {
        self.inner.mix(layer, h, attn)
    }

    fn logits(&self, h: &[f32], logits: &mut [f32]) {
        self.inner.logits(h, logits)
    }
}

/// Live per-incarnation health/progress counters a worker publishes and
/// the supervisor (and admission estimator) read lock-free.
#[derive(Debug, Default)]
pub struct ShardTelemetry {
    /// Incremented once per worker loop iteration — the heartbeat.
    beats: AtomicU64,
    /// True while the worker is between intake and step (i.e. a frozen
    /// heartbeat means a wedged step, not an idle blocking recv).
    busy: AtomicBool,
    /// Forward passes completed by this incarnation.
    passes: AtomicU64,
    /// EWMA of per-pass wall ms, stored as f64 bits (0 = no sample yet).
    ewma_bits: AtomicU64,
}

/// EWMA smoothing factor for the per-pass latency estimate (shared with
/// the post-drain `ShardStats::ewma_token_ms` so the two agree).
pub(crate) const EWMA_ALPHA: f64 = 0.2;

impl ShardTelemetry {
    fn beat(&self) {
        self.beats.fetch_add(1, Ordering::SeqCst);
    }

    fn beats(&self) -> u64 {
        self.beats.load(Ordering::SeqCst)
    }

    fn set_busy(&self, busy: bool) {
        self.busy.store(busy, Ordering::SeqCst);
    }

    fn busy(&self) -> bool {
        self.busy.load(Ordering::SeqCst)
    }

    /// Forward passes completed by the current incarnation.
    pub fn passes(&self) -> u64 {
        self.passes.load(Ordering::SeqCst)
    }

    /// Smoothed per-pass latency, `None` until a first step completes.
    pub fn ewma_token_ms(&self) -> Option<f64> {
        match self.ewma_bits.load(Ordering::SeqCst) {
            0 => None,
            bits => Some(f64::from_bits(bits)),
        }
    }

    fn record_step(&self, passes: usize, ms_per_pass: f64) {
        self.passes.fetch_add(passes as u64, Ordering::SeqCst);
        let next = match self.ewma_token_ms() {
            None => ms_per_pass,
            Some(prev) => (1.0 - EWMA_ALPHA) * prev + EWMA_ALPHA * ms_per_pass,
        };
        self.ewma_bits.store(next.to_bits(), Ordering::SeqCst);
    }
}

/// Messages on a shard's bounded submission channel.
pub(crate) enum ShardMsg {
    Req(Request),
    Drain,
}

/// Outcome of a non-blocking journaled send.
pub(crate) enum SendOutcome {
    Sent,
    /// Queue full right now; the request comes back to the caller.
    Full(Request),
    /// Channel disconnected (the worker died); the caller should run a
    /// health check — the next send reaches the respawned worker.
    Gone(Request),
}

type ShardResult = Result<(Vec<Completion>, ShardStats)>;

struct Slot {
    tx: SyncSender<ShardMsg>,
    join: Option<JoinHandle<ShardResult>>,
    telemetry: Arc<ShardTelemetry>,
    /// Heartbeat watermark + when it last advanced.
    last_beat: u64,
    last_beat_at: Instant,
    restarts: usize,
    /// Every request routed here since spawn. Completions only surface
    /// at drain, so the whole journal is potentially in flight — replay
    /// resends all of it into a fresh worker (dedup is unnecessary: the
    /// fresh worker has served none of them).
    journal: Vec<Request>,
    draining: bool,
    /// Set once the restart budget is exhausted; the message surfaces at
    /// drain.
    dead: Option<String>,
}

/// Everything drain recovers from the supervised shards.
pub(crate) struct SupervisorReport {
    pub completions: Vec<Completion>,
    pub shards: Vec<ShardStats>,
    pub restarts: usize,
    pub replayed: usize,
    pub recomputed_passes: usize,
}

/// Owns the shard worker threads: spawn, health checks, respawn+replay,
/// and the supervised drain. The cluster's router delegates all shard
/// lifecycle to this.
pub(crate) struct Supervisor {
    cfg: SupervisorConfig,
    shard_cfg: ShardConfig,
    queue_depth: usize,
    factory: Box<dyn Fn(usize) -> Box<dyn TokenModel>>,
    shards: Vec<Slot>,
    restarts: usize,
    replayed: usize,
    recomputed_passes: usize,
    /// Cluster-wide observability domain; each (re)spawned worker
    /// attaches its `serve.shard{i}.*` handles to this.
    obs: Telemetry,
    restarts_ctr: Counter,
    replayed_ctr: Counter,
    recomputed_ctr: Counter,
}

impl Supervisor {
    pub(crate) fn new(
        n_shards: usize,
        queue_depth: usize,
        shard_cfg: ShardConfig,
        cfg: SupervisorConfig,
        obs: Telemetry,
        factory: Box<dyn Fn(usize) -> Box<dyn TokenModel>>,
    ) -> Supervisor {
        let shards = (0..n_shards)
            .map(|id| {
                let (tx, join, telemetry) =
                    spawn_shard(id, factory(id), shard_cfg.clone(), queue_depth, obs.clone());
                Slot {
                    tx,
                    join: Some(join),
                    telemetry,
                    last_beat: 0,
                    last_beat_at: Instant::now(),
                    restarts: 0,
                    journal: Vec::new(),
                    draining: false,
                    dead: None,
                }
            })
            .collect();
        let reg = obs.registry();
        let restarts_ctr = reg.counter("serve.supervisor.restarts");
        let replayed_ctr = reg.counter("serve.supervisor.replayed_requests");
        let recomputed_ctr = reg.counter("serve.supervisor.recomputed_passes");
        Supervisor {
            cfg,
            shard_cfg,
            queue_depth,
            factory,
            shards,
            restarts: 0,
            replayed: 0,
            recomputed_passes: 0,
            obs,
            restarts_ctr,
            replayed_ctr,
            recomputed_ctr,
        }
    }

    pub(crate) fn config(&self) -> SupervisorConfig {
        self.cfg
    }

    /// Live smoothed per-pass latency of `shard`'s current incarnation.
    pub(crate) fn ewma_token_ms(&self, shard: usize) -> Option<f64> {
        self.shards[shard].telemetry.ewma_token_ms()
    }

    /// Journaled passes not yet executed by the current incarnation
    /// (prompt rows + token budgets, an upper bound on remaining work).
    pub(crate) fn backlog_passes(&self, shard: usize) -> usize {
        let queued: usize = self.shards[shard]
            .journal
            .iter()
            .map(|r| r.prompt.len().max(1) + r.max_new_tokens)
            .sum();
        queued.saturating_sub(self.shards[shard].telemetry.passes() as usize)
    }

    /// Journaled, non-blocking send to `shard`.
    pub(crate) fn try_send(&mut self, shard: usize, req: Request) -> SendOutcome {
        self.shards[shard].journal.push(req.clone());
        match self.shards[shard].tx.try_send(ShardMsg::Req(req)) {
            Ok(()) => SendOutcome::Sent,
            Err(TrySendError::Full(ShardMsg::Req(r))) => {
                self.shards[shard].journal.pop();
                SendOutcome::Full(r)
            }
            Err(TrySendError::Disconnected(ShardMsg::Req(r))) => {
                self.shards[shard].journal.pop();
                SendOutcome::Gone(r)
            }
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                unreachable!("only requests are try-sent")
            }
        }
    }

    /// Health-check one shard: join-and-respawn a dead worker, abandon-
    /// and-respawn a stalled one. `Err` only once the shard has exhausted
    /// its restart budget. Called on the submit path (pre-drain only —
    /// any finished thread here is abnormal).
    pub(crate) fn check(&mut self, shard: usize) -> Result<()> {
        if let Some(msg) = &self.shards[shard].dead {
            bail!("shard {shard} is dead: {msg}");
        }
        if self.shards[shard].join.as_ref().is_some_and(|j| j.is_finished()) {
            let why = match self.shards[shard].join.take().expect("handle present").join() {
                Ok(Err(e)) => e.to_string(),
                Ok(Ok(_)) => "worker exited before drain".to_string(),
                Err(p) => format!("worker panicked outside catch_unwind: {}", panic_msg(&p)),
            };
            return self.respawn_and_replay(shard, why);
        }
        if heartbeat_stalled(&mut self.shards[shard], self.cfg.stall_timeout_ms) {
            let why =
                format!("stalled (no heartbeat within {:.0} ms)", self.cfg.stall_timeout_ms);
            return self.respawn_and_replay(shard, why);
        }
        Ok(())
    }

    /// Replace `shard`'s worker with a fresh incarnation and replay its
    /// journal into it. Loops while replay itself keeps dying, up to the
    /// restart budget.
    fn respawn_and_replay(&mut self, shard: usize, mut why: String) -> Result<()> {
        loop {
            if self.shards[shard].restarts >= self.cfg.max_restarts {
                let msg = format!(
                    "gave up after {} restarts; last failure: {why}",
                    self.shards[shard].restarts
                );
                self.shards[shard].dead = Some(msg.clone());
                return Err(anyhow!("shard {shard} {msg}"));
            }
            self.shards[shard].restarts += 1;
            self.restarts += 1;
            self.restarts_ctr.inc();
            // The dead incarnation's finished passes are lost with it and
            // recomputed by replay.
            let lost = self.shards[shard].telemetry.passes();
            self.recomputed_passes += lost as usize;
            self.recomputed_ctr.add(lost);
            eprintln!(
                "[supervisor] shard {shard}: {why}; respawn {}/{} replaying {} request(s)",
                self.shards[shard].restarts,
                self.cfg.max_restarts,
                self.shards[shard].journal.len()
            );
            let model = (self.factory)(shard);
            let (tx, join, telemetry) =
                spawn_shard(shard, model, self.shard_cfg.clone(), self.queue_depth, self.obs.clone());
            // Replacing tx abandons the old incarnation: if it was merely
            // stalled (unkillable), it exits on its own once it observes
            // the disconnected channel, and its late results are dropped
            // — replay recomputes them deterministically.
            let slot = &mut self.shards[shard];
            slot.tx = tx;
            slot.join = Some(join);
            slot.telemetry = telemetry;
            slot.last_beat = 0;
            slot.last_beat_at = Instant::now();
            let journal = slot.journal.clone();
            self.replayed += journal.len();
            self.replayed_ctr.add(journal.len() as u64);
            match self.replay(shard, journal) {
                None => return Ok(()),
                Some(failure) => why = failure,
            }
        }
    }

    /// Feed `journal` (and the drain marker, if draining) into the fresh
    /// worker. Returns `Some(reason)` if the worker died or stalled
    /// mid-replay.
    fn replay(&mut self, shard: usize, journal: Vec<Request>) -> Option<String> {
        let spans = self.obs.spans().clone();
        let incarnation = self.shards[shard].restarts as u64;
        for req in journal {
            // Re-anchor the recovery under the original request's root
            // span (the journal preserves `Request::trace`), tagged with
            // the incarnation recomputing it — recovery cost stays
            // attributable per request in the exported trace.
            spans.record_at("replay", "incarnation", incarnation, req.trace, spans.now_us(), 0);
            let mut pending = req;
            loop {
                match self.shards[shard].tx.try_send(ShardMsg::Req(pending)) {
                    Ok(()) => break,
                    Err(TrySendError::Full(ShardMsg::Req(r))) => {
                        pending = r;
                        if heartbeat_stalled(&mut self.shards[shard], self.cfg.stall_timeout_ms)
                        {
                            return Some("stalled during journal replay".to_string());
                        }
                        std::thread::sleep(Duration::from_micros(100));
                    }
                    Err(TrySendError::Disconnected(_)) => {
                        return Some("died during journal replay".to_string());
                    }
                    Err(TrySendError::Full(_)) => unreachable!("only requests are try-sent"),
                }
            }
        }
        if self.shards[shard].draining
            && self.shards[shard].tx.send(ShardMsg::Drain).is_err()
        {
            return Some("died before accepting the drain marker".to_string());
        }
        None
    }

    /// Supervised drain: deliver drain markers, then poll every shard to
    /// completion — collecting clean results, respawning + replaying dead
    /// or stalled shards (which then re-drain), and recording permanent
    /// failures. All shards are driven to a terminal state before the
    /// first error (if any) is returned.
    pub(crate) fn drain(mut self) -> Result<SupervisorReport> {
        let n = self.shards.len();
        for i in 0..n {
            self.shards[i].draining = true;
            if self.shards[i].dead.is_none() {
                // A dead worker's send fails; the poll below handles it.
                let _ = self.shards[i].tx.send(ShardMsg::Drain);
            }
        }
        let mut completions = Vec::new();
        let mut stats = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        let mut open: Vec<usize> = (0..n).collect();
        while !open.is_empty() {
            let mut next_open = Vec::with_capacity(open.len());
            for i in open {
                if let Some(msg) = self.shards[i].dead.clone() {
                    first_err = first_err.or_else(|| Some(anyhow!("shard {i} {msg}")));
                    continue;
                }
                if self.shards[i].join.as_ref().is_some_and(|j| j.is_finished()) {
                    match self.shards[i].join.take().expect("handle present").join() {
                        Ok(Ok((mut done, s))) => {
                            completions.append(&mut done);
                            stats.push(s);
                        }
                        Ok(Err(e)) => match self.respawn_and_replay(i, e.to_string()) {
                            Ok(()) => next_open.push(i),
                            Err(fatal) => first_err = first_err.or(Some(fatal)),
                        },
                        Err(p) => {
                            let why = format!(
                                "worker panicked outside catch_unwind: {}",
                                panic_msg(&p)
                            );
                            match self.respawn_and_replay(i, why) {
                                Ok(()) => next_open.push(i),
                                Err(fatal) => first_err = first_err.or(Some(fatal)),
                            }
                        }
                    }
                    continue;
                }
                if heartbeat_stalled(&mut self.shards[i], self.cfg.stall_timeout_ms) {
                    match self.respawn_and_replay(i, "stalled during drain".to_string()) {
                        Ok(()) => next_open.push(i),
                        Err(fatal) => first_err = first_err.or(Some(fatal)),
                    }
                    continue;
                }
                next_open.push(i);
            }
            open = next_open;
            if !open.is_empty() {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(SupervisorReport {
            completions,
            shards: stats,
            restarts: self.restarts,
            replayed: self.replayed,
            recomputed_passes: self.recomputed_passes,
        })
    }
}

/// Advance the heartbeat watermark; true when the shard claims busy but
/// its heartbeat has been frozen past `timeout_ms`.
fn heartbeat_stalled(slot: &mut Slot, timeout_ms: f64) -> bool {
    let beats = slot.telemetry.beats();
    if beats != slot.last_beat {
        slot.last_beat = beats;
        slot.last_beat_at = Instant::now();
        return false;
    }
    slot.telemetry.busy() && slot.last_beat_at.elapsed().as_secs_f64() * 1e3 > timeout_ms
}

thread_local! {
    /// True on threads whose panics the supervisor will catch + report.
    static SUPERVISED: Cell<bool> = const { Cell::new(false) };
}

static HOOK: Once = Once::new();

/// Silence the default panic printout on supervised worker threads: the
/// panic is caught by the unwind guard and reported by the supervisor
/// (one line with shard + restart context) instead of splatting the raw
/// panic over the console. All other threads keep the previous hook.
fn install_supervised_hook() {
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !SUPERVISED.with(|s| s.get()) {
                prev(info);
            }
        }));
    });
}

/// Spawn one shard worker thread running [`shard_loop`] under an unwind
/// guard, with a fresh channel + telemetry.
fn spawn_shard(
    shard_id: usize,
    model: Box<dyn TokenModel>,
    cfg: ShardConfig,
    queue_depth: usize,
    obs: Telemetry,
) -> (SyncSender<ShardMsg>, JoinHandle<ShardResult>, Arc<ShardTelemetry>) {
    install_supervised_hook();
    let (tx, rx) = sync_channel::<ShardMsg>(queue_depth);
    let telemetry = Arc::new(ShardTelemetry::default());
    let tele = telemetry.clone();
    let join = std::thread::spawn(move || {
        SUPERVISED.with(|s| s.set(true));
        let loop_body = || shard_loop(shard_id, model, cfg, rx, tele, obs);
        match catch_unwind(AssertUnwindSafe(loop_body)) {
            Ok(res) => res,
            Err(p) => Err(anyhow!("shard {shard_id} panicked: {}", panic_msg(&p))),
        }
    });
    (tx, join, telemetry)
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// One shard thread: interleave queue intake with serving steps,
/// publishing a heartbeat each iteration. Blocks on the channel only
/// when fully idle (marked not-busy, so a frozen heartbeat there is not
/// a stall); while busy it polls between steps so mid-flight submissions
/// join the continuous batch. It pulls a request off the channel only
/// while a lane can absorb it ([`ShardWorker::wants_work`]) — the
/// bounded channel itself is the shard's queue, so `queue_depth` is a
/// real backpressure bound rather than a per-step trickle into an
/// unbounded local buffer. The drain marker trails every request in
/// channel order, so stopping intake at full lanes never strands it.
fn shard_loop(
    shard_id: usize,
    model: Box<dyn TokenModel>,
    cfg: ShardConfig,
    rx: Receiver<ShardMsg>,
    telemetry: Arc<ShardTelemetry>,
    obs: Telemetry,
) -> ShardResult {
    let mut w = ShardWorker::new(model, cfg);
    w.attach_telemetry(&obs, shard_id);
    let mut draining = false;
    loop {
        telemetry.beat();
        if w.is_idle() && !draining {
            telemetry.set_busy(false);
            match rx.recv() {
                Ok(ShardMsg::Req(req)) => w.submit(req),
                Ok(ShardMsg::Drain) | Err(_) => draining = true,
            }
            telemetry.set_busy(true);
        }
        while !draining && w.wants_work() {
            match rx.try_recv() {
                Ok(ShardMsg::Req(req)) => w.submit(req),
                Ok(ShardMsg::Drain) => draining = true,
                Err(_) => break, // empty or disconnected
            }
        }
        if w.is_idle() {
            if draining {
                break;
            }
            continue;
        }
        let t0 = Instant::now();
        let processed = w.step()?;
        if processed > 0 {
            let ms = t0.elapsed().as_secs_f64() * 1e3 / processed as f64;
            telemetry.record_step(processed, ms);
        }
    }
    telemetry.set_busy(false);
    let done = w.take_done();
    let stats = w.stats(shard_id);
    Ok((done, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model::{SimLm, SimLmConfig};

    #[test]
    fn fault_plan_parses_and_fires_once() {
        let plan = FaultPlan::parse("stall:1:3:25,every:2:4").unwrap();
        assert!(!plan.is_empty());
        assert!(FaultPlan::parse("panic:0").is_err());
        assert!(FaultPlan::parse("panic:0:notanum").is_err());
        assert!(FaultPlan::none().is_empty());

        // A one-shot stall fires exactly once even when re-armed passes
        // keep flowing (and never on the wrong shard).
        let plan = FaultPlan::stall_at(0, 2, 1);
        let wrong = plan.wrap(1, Box::new(SimLm::new(SimLmConfig::default())));
        let m = plan.wrap(0, Box::new(SimLm::new(SimLmConfig::default())));
        let d = m.d_model();
        let mut h = vec![0.0f32; d];
        for _ in 0..4 {
            m.embed(b"x", 0, &mut h);
            wrong.embed(b"x", 0, &mut h);
        }
        assert_eq!(plan.trips(), 1, "stall is one-shot across all passes");
    }

    #[test]
    fn fault_plan_periodic_counts_across_incarnations() {
        let plan = FaultPlan::panic_every(0, 3);
        let m = plan.wrap(0, Box::new(SimLm::new(SimLmConfig::default())));
        let d = m.d_model();
        let mut h = vec![0.0f32; d];
        m.embed(b"x", 0, &mut h);
        m.embed(b"x", 0, &mut h);
        // Third pass fires — from a *fresh incarnation*, proving the
        // period is counted on shared cross-incarnation state. Mark this
        // thread supervised so the expected panic prints nothing.
        install_supervised_hook();
        SUPERVISED.with(|s| s.set(true));
        let m2 = plan.wrap(0, Box::new(SimLm::new(SimLmConfig::default())));
        let err = catch_unwind(AssertUnwindSafe(|| {
            let mut h2 = vec![0.0f32; d];
            m2.embed(b"x", 0, &mut h2);
        }));
        SUPERVISED.with(|s| s.set(false));
        assert!(err.is_err(), "every-3rd pass must panic");
        assert_eq!(plan.trips(), 1);
    }

    #[test]
    fn telemetry_ewma_smooths_and_defaults_to_none() {
        let t = ShardTelemetry::default();
        assert_eq!(t.ewma_token_ms(), None);
        t.record_step(1, 10.0);
        assert_eq!(t.ewma_token_ms(), Some(10.0));
        t.record_step(1, 20.0);
        let e = t.ewma_token_ms().unwrap();
        assert!((e - 12.0).abs() < 1e-12, "0.8*10 + 0.2*20 = {e}");
        assert_eq!(t.passes(), 2);
    }
}
