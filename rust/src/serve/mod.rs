//! Serving over the FP4 paged KV cache: the sharded decode cluster
//! (§5's deployment path, scaled out).
//!
//! ```text
//!   submit(Request) ─▶ DecodeCluster ── hash(request.id) % N ──┐
//!                                                              ▼
//!        ┌────────────────────┬────────────────────┬────────────────────┐
//!        │ shard 0 (thread)   │ shard 1 (thread)   │ shard N−1 (thread) │
//!        │  bounded queue     │  bounded queue     │  bounded queue     │
//!        │  ShardWorker       │  ShardWorker       │  ShardWorker       │
//!        │   ├ TokenModel     │   ├ TokenModel     │   ├ TokenModel     │
//!        │   ├ PagedKvCache   │   ├ PagedKvCache   │   ├ PagedKvCache   │
//!        │   │  (SeqSlot-     │   │                │   │                │
//!        │   │   indexed)     │   │                │   │                │
//!        │   └ AttnEngine per │   └ AttnEngine per │   └ AttnEngine per │
//!        │     batch lane     │     batch lane     │     batch lane     │
//!        └────────────────────┴────────────────────┴────────────────────┘
//!                       drain() ─▶ completions + ClusterStats
//! ```
//!
//! Four layers, shared-nothing by construction:
//!
//! * [`cluster::DecodeCluster`] — the router + admission controller.
//!   Requests hash on id onto N shard threads through **bounded**
//!   `sync_channel`s. [`cluster::DecodeCluster::drain`] finishes all
//!   in-flight work and returns pooled completions plus per-shard
//!   [`shard::ShardStats`] (tokens/s, queue peaks, p50/p99 per-token
//!   latency, quantized-query-cache hit rates, KV memory peaks) and the
//!   recovery counters (restarts, replays, shed counts).
//! * `supervisor::Supervisor` (crate-internal) — shard lifecycle.
//!   Workers run under `catch_unwind` with a heartbeat; dead or stalled
//!   shards are respawned from the cluster's model factory and their
//!   journaled requests replayed. [`supervisor::FaultPlan`] is the
//!   deterministic fault-injection seam used by the fault-tolerance
//!   tests, `exp faults`, and the bench's faulted scenario.
//! * [`shard::ShardWorker`] — one shard's continuous-batching loop. Owns
//!   a private [`crate::kvcache::PagedKvCache`] addressed by
//!   [`crate::kvcache::SeqSlot`] handles (zero map lookups per token) and
//!   one [`AttnEngine`] per batch lane; prompts are ingested through the
//!   batched [`AttnEngine::prefill_slot`] path, then sequences decode
//!   token-at-a-time until they finish and free their slot.
//! * [`model::TokenModel`] — the pluggable non-attention compute.
//!   [`model::SimLm`] (deterministic seeded weights) is the native
//!   default, so the whole cluster runs, tests, and benchmarks **without
//!   the PJRT runtime**; [`crate::model::QatModel`] implements the same
//!   trait, and the compiled-artifact transformer fills the role for
//!   [`DecodeServer`] below.
//!
//! ## Shared-prefix admission
//!
//! With [`ShardConfig::prefix_share`] on, each shard keeps a
//! [`prefix::PrefixIndex`] — a radix trie over prompt bytes at sealed-page
//! (16-token) granularity — in front of its cache's refcounted
//! [`crate::kvcache::PagePool`]. Admission looks up the longest
//! already-sealed prefix run, attaches those immutable pages by reference
//! (copy-on-write: divergence just starts a private hot page; no bytes are
//! ever copied), and prefills **only the suffix**, so admission cost drops
//! from O(prompt) to O(suffix) and common system prompts are stored once
//! per shard. Sealed pages are deterministic functions of the token prefix
//! and weights, so sharing is bitwise invisible to decode outputs; the
//! pool is per-shard and routing stays hash-on-id, so placement invariance
//! and replay determinism extend unchanged (pinned by
//! `rust/tests/prefix_cache.rs`). Cold sealed pages can additionally spill
//! to disk under a resident-byte budget (`--kv-spill-dir`) and reload
//! transparently on next attend.
//!
//! ## Failure model
//!
//! Survivable faults, all recovered without losing a single accepted
//! request (pinned by `rust/tests/fault_tolerance.rs`):
//!
//! * **shard panic** — caught by the worker's unwind guard; the
//!   supervisor joins the dead thread, respawns the shard from the
//!   model factory, and replays its journal;
//! * **shard stall** — a busy worker whose heartbeat freezes past the
//!   configured timeout is *abandoned* (threads can't be killed; the
//!   orphan exits once it sees its channel disconnect, its late results
//!   are discarded) and a fresh incarnation replays the journal;
//! * **channel disconnect** — a dead receiver surfaces on the submit
//!   path and heals the same way, transparently to the submitter.
//!
//! **Replay determinism contract.** Replay restarts a shard's requests
//! from scratch, and the result is *bitwise identical* to a fault-free
//! run because a sequence's floats depend only on (a) its own tokens,
//! (b) its own cache pages, (c) the model weights, and (d) its
//! per-request sampling stream seeded by request id — never on timing,
//! lane, shard, or co-resident sequences. The model factory must
//! rebuild identical weights (same seed) for this to hold; partial
//! output is never surfaced (completions only leave a shard at drain),
//! so recovery is exactly-once delivery per accepted request. Restarts
//! are bounded per shard; a shard that exhausts its budget surfaces its
//! error at drain, after every healthy shard is collected.
//!
//! **Shed vs backpressure.** A request without a deadline is never
//! rejected by admission: a full shard queue *blocks* the submitter
//! (backpressure). A request carrying [`Request::deadline_ms`] is
//! instead **shed** when infeasible — up front, when the shard's
//! per-pass-latency EWMA times its outstanding work exceeds the
//! deadline, or after bounded full-queue retries with exponential
//! backoff. Shed counts are reported in [`ClusterStats`] separately
//! from everything else ([`cluster::Admission`] is the per-submit
//! verdict); shed requests produce **no completion** — distinct from
//! shard-level *rejections* (invalid requests: zero budget, oversized
//! prompt, duplicate in-flight id), which do complete with
//! `new_tokens == 0`.
//!
//! ## Observability
//!
//! Every layer publishes into the unified [`crate::telemetry`] registry:
//! shard workers own `serve.shard{i}.*` gauges/histograms (queue depth,
//! tokens/s, p50/p99 per-token latency, aggregated quantized-query-cache
//! hit rate, KV bytes), the supervisor counts `serve.supervisor.*`
//! restarts/replays/recomputes, and the router counts `serve.cluster.*`
//! admissions and sheds — the full metric-name → source-site map lives
//! in the [`crate::telemetry`] module docs. The typed [`ClusterStats`] /
//! [`shard::ShardStats`] facades remain the drain-time source of truth;
//! the registry republishes exactly those values at drain (pinned by the
//! parity test in `rust/tests/telemetry.rs`), so dashboards and tests
//! never disagree.
//!
//! The reflection endpoint is [`cluster::DecodeCluster::introspect`] /
//! [`crate::telemetry::Telemetry::snapshot`]: one schema-versioned JSON
//! doc with the live [`ClusterConfig`] (per-layer attention included),
//! every metric, and span-ring statistics over the
//! admission→route→prefill→decode→drain path. `repro serve cluster
//! --json` (or `repro serve stats`) prints it; `--stats-every-ms T`
//! streams snapshot lines to `results/serve_cluster_stats.jsonl` while
//! the run is live.
//!
//! Instrumentation never perturbs the math: probes are relaxed atomic
//! stores off the per-token float path, a detached or disabled
//! [`crate::telemetry::Telemetry`] costs one atomic load per span site,
//! and respawned shard incarnations re-attach to the same metric names.
//! The bitwise placement-invariance and replay contracts below hold with
//! telemetry on or off (guarded within 3% tokens/s by
//! `benches/cluster_serve.rs`).
//!
//! ## Tracing & profiling
//!
//! On top of the metric probes, the cluster emits a **causal trace**: at
//! submit the router opens a per-request root span and stamps its
//! [`TraceContext`] onto [`Request::trace`]; the context rides the
//! bounded channel into the shard worker, where queue-wait, admission
//! (prefix attach / copy-on-write split included), suffix prefill,
//! sampled per-token decode, and finish spans all re-anchor under that
//! root — so one request's lifecycle reconstructs as a tree *across
//! threads*. Supervisor replays re-anchor the same way and tag their
//! spans with the shard incarnation, making recovery cost attributable
//! per request. The full span-name schema lives in the
//! [`crate::telemetry`] module docs.
//!
//! Two consumers ship with the CLI:
//!
//! * `repro serve cluster --trace-out FILE` (also `exp faults` via the
//!   `faults.trace_out` config key) exports the span ring as Chrome
//!   trace-event JSON ([`crate::telemetry::chrome_trace`]) — load the
//!   file in Perfetto / `chrome://tracing` to scrub the timeline, one
//!   track per request.
//! * `repro serve profile` runs the demo cluster under a large span ring
//!   and folds the tree into an inclusive/exclusive self-time table
//!   ([`crate::telemetry::self_time`]) plus collapsed-stack flamegraph
//!   lines ([`crate::telemetry::flamegraph_lines`], `--fold-out FILE`,
//!   one `root;child;leaf N` line per stack — pipe to inferno or any
//!   FlameGraph-compatible renderer).
//!
//! Deadline shedding closes its loop through the same trace: drain
//! classifies every admitted deadline as met (slack into
//! `serve.slo.slack_ms`) or missed (`serve.slo.false_admit`,
//! `serve.slo.overrun_ms`) and re-judges every shed against the shard's
//! final latency EWMA (`serve.slo.false_shed`) — so the admission
//! controller's feasibility prediction is itself measured.
//!
//! ## Train→serve
//!
//! Since the `model` subsystem landed, the cluster serves **trained**
//! weights, not just simulated ones:
//!
//! ```text
//! model::TrainSession (Adam + grad-clip, per-layer Attn-QAT backward)
//!   └─ model::QatModel ── save_quantized() ─▶ checkpoint ─▶ load()
//!        └─ impl TokenModel ──▶ DecodeCluster::spawn(|_| Box::new(model.clone()))
//! ```
//!
//! `QatModel` shares the per-row kernels of `SimLm` (`model::modules`),
//! so its serving math is its training math — only attention switches
//! from the engine training forward to the paged FP4 decode. The round
//! trip — finetune, export, import, serve at 1 and 4 shards, compare
//! bitwise against `model::greedy_decode` — is pinned by
//! `rust/tests/train_serve.rs` and demoed by `repro train native`.
//!
//! Sharding changes wall-clock, never tokens: a sequence's floats depend
//! only on its own cache and sampling stream, so for any trace of
//! **unique request ids** (the id keys the cache slot and the sampling
//! stream; concurrent duplicates are rejected, but reuse of a finished id
//! is timing-dependent) an N-shard run is bitwise identical to the
//! single-worker server (pinned by `rust/tests/cluster_serve.rs`;
//! scaling curves in `benches/cluster_serve.rs`).
//!
//! [`DecodeServer`] remains the single-threaded compiled-artifact demo:
//! the transformer's non-attention compute runs as per-layer HLO
//! artifacts while attention runs natively over the same FP4 pages — the
//! path that needs a real PJRT backend.

pub mod cluster;
pub mod model;
pub mod prefix;
pub mod shard;
pub mod supervisor;

pub use cluster::{Admission, ClusterConfig, ClusterStats, DecodeCluster};
pub use model::{SimLm, SimLmConfig, TokenModel};
pub use prefix::{PrefixIndex, PrefixMatch, PrefixStats};
pub use shard::{ShardConfig, ShardStats, ShardWorker};
pub use supervisor::{FaultKind, FaultPlan, FaultSpec, SupervisorConfig};

pub use crate::telemetry::TraceContext;

use std::collections::VecDeque;

use anyhow::{anyhow, bail, Result};

use crate::attention::{AttnConfig, AttnEngine};
use crate::kvcache::PagedKvCache;
use crate::rng::Rng;
use crate::runtime::{Runtime, Value};
use crate::tensor::Tensor;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling.
    pub temperature: f32,
    /// Optional SLO: milliseconds from submission within which the whole
    /// completion must land. The cluster sheds the request at admission
    /// when its estimate says the deadline is infeasible (see the module
    /// docs' shed-vs-backpressure contract); `None` never sheds. The
    /// single-threaded [`DecodeServer`] demo ignores it.
    pub deadline_ms: Option<f64>,
    /// Causal-trace anchor, assigned by [`cluster::DecodeCluster::submit`]
    /// when it opens the per-request root span. Rides the channel into the
    /// shard worker so queue/admit/prefill/decode spans on the worker
    /// thread re-anchor under the submitter's root, and survives in the
    /// supervisor journal so replayed work stays attributed to the
    /// original request. Default ([`TraceContext::NONE`]) means untraced;
    /// builders never need to set it.
    pub trace: crate::telemetry::TraceContext,
}

impl Request {
    /// Tag this request with an SLO deadline (ms from submission).
    pub fn with_deadline_ms(mut self, ms: f64) -> Request {
        self.deadline_ms = Some(ms);
        self
    }
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub text: Vec<u8>,
    pub prompt_tokens: usize,
    pub new_tokens: usize,
    pub wall_ms: f64,
}

struct Active {
    req: Request,
    tokens: Vec<u8>,
    pos: usize,
    generated: usize,
    started: std::time::Instant,
}

/// Decode-server statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    pub steps: usize,
    pub tokens_decoded: usize,
    pub kv_bytes: usize,
    pub kv_bytes_f32_equiv: usize,
}

/// The server. Single-threaded (the PJRT client is not `Send`); callers
/// submit requests and pump [`DecodeServer::step`] — or use
/// [`DecodeServer::run`] to drain the queue.
pub struct DecodeServer<'rt> {
    rt: &'rt Runtime,
    size: String,
    weights: Vec<(String, Tensor)>,
    layers: usize,
    heads: usize,
    head_dim: usize,
    d_model: usize,
    seq_max: usize,
    batch: usize,
    cache: PagedKvCache,
    queue: VecDeque<Request>,
    active: Vec<Active>,
    done: Vec<Completion>,
    rng: Rng,
    /// Attention session config every slot engine is built from.
    attn_cfg: AttnConfig,
    /// Per-slot attention engines (owned workspaces), reused every step —
    /// no steady-state allocation.
    engines: Vec<AttnEngine>,
    pub stats: ServeStats,
}

impl<'rt> DecodeServer<'rt> {
    /// Build a server for model `size` with `weights` = the `lm_init_*` /
    /// checkpoint parameters (named, any order).
    pub fn new(rt: &'rt Runtime, size: &str, weights: Vec<(String, Tensor)>) -> Result<Self> {
        let meta = rt.meta(&format!("lm_embed_{size}"))?;
        let model = &meta.raw.get("model").clone();
        let layers = model.get("n_layers").as_usize().ok_or_else(|| anyhow!("n_layers"))?;
        let heads = model.get("n_heads").as_usize().ok_or_else(|| anyhow!("n_heads"))?;
        let d_model = model.get("d_model").as_usize().ok_or_else(|| anyhow!("d_model"))?;
        let seq_max = model.get("seq_len").as_usize().ok_or_else(|| anyhow!("seq_len"))?;
        let batch = meta.usize_field("batch").ok_or_else(|| anyhow!("batch"))?;
        let head_dim = d_model / heads;
        Ok(DecodeServer {
            rt,
            size: size.to_string(),
            weights,
            layers,
            heads,
            head_dim,
            d_model,
            seq_max,
            batch,
            cache: PagedKvCache::new(layers, heads, head_dim),
            queue: VecDeque::new(),
            active: Vec::new(),
            done: Vec::new(),
            rng: Rng::new(0x5e7e),
            attn_cfg: AttnConfig::fp4(),
            engines: Vec::new(),
            stats: ServeStats::default(),
        })
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    /// Reconfigure the attention sessions (existing engines are rebuilt).
    ///
    /// The default is the fused packed decode (`AttnConfig::fp4()`);
    /// passing [`AttnConfig::f32`] selects the materialising gather + f32
    /// baseline — the A/B comparison the server used to carry as a
    /// dedicated bool.
    pub fn set_attention(&mut self, cfg: AttnConfig) {
        self.attn_cfg = cfg;
        self.engines.clear();
    }

    fn weight(&self, name: &str) -> Result<&Tensor> {
        self.weights
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| t)
            .ok_or_else(|| anyhow!("missing weight {name}"))
    }

    /// Slice layer `l` out of a stacked (L, ...) parameter.
    fn layer_weight(&self, name: &str, l: usize) -> Result<Tensor> {
        let t = self.weight(name)?;
        if t.shape.is_empty() || t.shape[0] <= l {
            bail!("{name} not stacked over {l} layers: {:?}", t.shape);
        }
        let per = t.data.len() / t.shape[0];
        Tensor::new(t.shape[1..].to_vec(), t.data[l * per..(l + 1) * per].to_vec())
    }

    /// Admit queued requests into free batch slots.
    fn admit(&mut self) {
        while self.active.len() < self.batch {
            let Some(req) = self.queue.pop_front() else { break };
            let seq = req.id;
            self.cache.add_seq(seq);
            self.active.push(Active {
                tokens: req.prompt.clone(),
                pos: 0,
                generated: 0,
                started: std::time::Instant::now(),
                req,
            });
        }
    }

    /// One decode step: each active sequence consumes its next token
    /// (prompt prefill happens token-by-token through the same path).
    pub fn step(&mut self) -> Result<()> {
        self.admit();
        if self.active.is_empty() {
            return Ok(());
        }
        let b = self.batch;
        let d = self.d_model;

        // Current token + position per slot (pad with zeros).
        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        for (s, a) in self.active.iter().enumerate() {
            toks[s] = *a.tokens.get(a.pos).unwrap_or(&b' ') as i32;
            pos[s] = a.pos as i32;
        }

        // h = embed(token, pos)
        let embed = format!("lm_embed_{}", self.size);
        let mut h = self
            .rt
            .run(
                &embed,
                &[
                    Value::F32(self.weight("tok_emb")?.clone()),
                    Value::F32(self.weight("pos_emb")?.clone()),
                    Value::I32(toks, vec![b]),
                    Value::I32(pos, vec![b]),
                ],
            )?
            .remove(0);

        let pre = format!("lm_layer_pre_{}", self.size);
        let post = format!("lm_layer_post_{}", self.size);
        for l in 0..self.layers {
            let qkv = self.rt.run(
                &pre,
                &[
                    Value::F32(h.clone()),
                    Value::F32(self.layer_weight("ln1_w", l)?),
                    Value::F32(self.layer_weight("ln1_b", l)?),
                    Value::F32(self.layer_weight("wqkv", l)?),
                    Value::F32(self.layer_weight("bqkv", l)?),
                ],
            )?;
            let (q, k, v) = (&qkv[0], &qkv[1], &qkv[2]);

            // Native attention over the FP4 KV cache, per (slot, head).
            // Phase 1: append this step's K/V (mutates the cache).
            let hd = self.head_dim;
            let mut attn = Tensor::zeros(vec![b, d]);
            for (s, a) in self.active.iter().enumerate() {
                let seq = a.req.id;
                for head in 0..self.heads {
                    let off = s * d + head * hd;
                    self.cache
                        .append(seq, l, head, &k.data[off..off + hd], &v.data[off..off + hd])?;
                }
            }
            // Phase 2: attend — one engine `decode` call per slot covers
            // every head of the layer. The engine config decides the path:
            // fused packed decode by default, gather + f32 when the server
            // was reconfigured with the baseline config. Slots fan out via
            // `std::thread::scope` (the cache is read-only here and each
            // slot's engine writes a disjoint row of `attn`).
            while self.engines.len() < self.active.len() {
                self.engines.push(AttnEngine::new(self.attn_cfg));
            }
            if self.active.len() == 1 {
                // One slot: thread spawn/join would dwarf the attention
                // work on short caches — run inline.
                let seq = self.active[0].req.id;
                self.engines[0].decode(&self.cache, seq, l, &q.data[..d], &mut attn.data[..d])?;
            } else {
                let cache = &self.cache;
                let active = &self.active;
                let qd = &q.data;
                let results: Vec<Result<()>> = std::thread::scope(|scope| {
                    let mut handles = Vec::with_capacity(active.len());
                    for ((s, (a, row)), engine) in active
                        .iter()
                        .zip(attn.data.chunks_mut(d))
                        .enumerate()
                        .zip(self.engines.iter_mut())
                    {
                        let seq = a.req.id;
                        handles.push(scope.spawn(move || {
                            engine.decode(cache, seq, l, &qd[s * d..(s + 1) * d], row)
                        }));
                    }
                    handles.into_iter().map(|h| h.join().expect("attend thread panicked")).collect()
                });
                for r in results {
                    r?;
                }
            }

            h = self
                .rt
                .run(
                    &post,
                    &[
                        Value::F32(h),
                        Value::F32(attn),
                        Value::F32(self.layer_weight("wo", l)?),
                        Value::F32(self.layer_weight("bo", l)?),
                        Value::F32(self.layer_weight("ln2_w", l)?),
                        Value::F32(self.layer_weight("ln2_b", l)?),
                        Value::F32(self.layer_weight("win", l)?),
                        Value::F32(self.layer_weight("bin", l)?),
                        Value::F32(self.layer_weight("wout", l)?),
                        Value::F32(self.layer_weight("bout", l)?),
                    ],
                )?
                .remove(0);
        }

        let head_art = format!("lm_head_{}", self.size);
        let logits = self
            .rt
            .run(
                &head_art,
                &[
                    Value::F32(h),
                    Value::F32(self.weight("lnf_w")?.clone()),
                    Value::F32(self.weight("lnf_b")?.clone()),
                    Value::F32(self.weight("head")?.clone()),
                ],
            )?
            .remove(0);
        let vocab = logits.cols();

        // Advance each active sequence.
        let mut finished = Vec::new();
        for (s, a) in self.active.iter_mut().enumerate() {
            a.pos += 1;
            self.stats.tokens_decoded += 1;
            if a.pos < a.tokens.len() {
                continue; // still prefilling the prompt
            }
            // Sample the next token from this slot's logits.
            let row = &logits.data[s * vocab..(s + 1) * vocab];
            let next = if a.req.temperature <= 0.0 {
                argmax(row)
            } else {
                sample_temp(row, a.req.temperature, &mut self.rng)
            } as u8;
            a.tokens.push(next);
            a.generated += 1;
            if a.generated >= a.req.max_new_tokens
                || next == b'$'
                || a.tokens.len() >= self.seq_max
            {
                finished.push(s);
            }
        }
        for &s in finished.iter().rev() {
            let a = self.active.swap_remove(s);
            self.cache.drop_seq(a.req.id)?;
            self.done.push(Completion {
                id: a.req.id,
                prompt_tokens: a.req.prompt.len(),
                new_tokens: a.generated,
                text: a.tokens,
                wall_ms: a.started.elapsed().as_secs_f64() * 1e3,
            });
        }
        self.stats.steps += 1;
        let (used, equiv) = self.cache.memory_stats();
        self.stats.kv_bytes = self.stats.kv_bytes.max(used);
        self.stats.kv_bytes_f32_equiv = self.stats.kv_bytes_f32_equiv.max(equiv);
        Ok(())
    }

    /// Pump steps until queue and active set drain; returns completions.
    pub fn run(&mut self) -> Result<Vec<Completion>> {
        while !self.queue.is_empty() || !self.active.is_empty() {
            self.step()?;
        }
        Ok(std::mem::take(&mut self.done))
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }
}

pub(crate) fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

pub(crate) fn sample_temp(row: &[f32], temp: f32, rng: &mut Rng) -> usize {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let weights: Vec<f32> = row.iter().map(|&x| ((x - m) / temp).exp()).collect();
    rng.categorical(&weights)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_sampling() {
        let row = [0.0f32, 10.0, -1.0];
        assert_eq!(argmax(&row), 1);
        let mut rng = Rng::new(1);
        // Low temperature: overwhelmingly the argmax.
        let hits = (0..100)
            .filter(|_| sample_temp(&row, 0.1, &mut rng) == 1)
            .count();
        assert!(hits > 95, "{hits}");
    }
}
