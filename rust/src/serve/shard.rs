//! One decode shard: a self-contained continuous-batching worker.
//!
//! A [`ShardWorker`] owns everything one shard of the cluster needs — its
//! own [`PagedKvCache`], one [`AttnEngine`] per batch lane, a
//! [`TokenModel`], and the request queue — so shards share **nothing** and
//! the cluster needs no locks: the router hands a shard its requests and
//! the worker thread pumps [`ShardWorker::step`] until drained.
//!
//! Scheduling is continuous batching at token granularity, with **batched
//! prompt admission**: an admitted request's whole prompt is ingested in
//! one pass per layer through [`AttnEngine::prefill_slot`] (one page walk
//! per query instead of one full decode call per prompt token), then the
//! sequence joins the per-token decode loop alongside the other lanes.
//! Sequences are addressed by their [`SeqSlot`] handle, resolved once at
//! admission — the per-token path does zero map lookups.
//!
//! Determinism: every float a sequence sees depends only on its own
//! tokens, its own cache pages, and the model weights — never on which
//! lane or shard it landed in, or on what other sequences are in flight.
//! Temperature sampling draws from a per-request stream seeded by the
//! request id, so completions are bitwise reproducible under any shard
//! count (pinned by `rust/tests/cluster_serve.rs`).

use std::collections::VecDeque;

use anyhow::Result;

use crate::attention::{AttnConfig, AttnEngine};
use crate::kvcache::{PagedKvCache, SeqSlot};
use crate::rng::Rng;
use crate::telemetry::{Counter, Gauge, Histogram, Telemetry};

use super::model::{TokenModel, VOCAB};
use super::{argmax, Completion, Request, sample_temp};

/// Per-shard serving knobs.
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    /// Concurrent batch lanes (sequences decoding per step).
    pub slots: usize,
    /// Attention session config for every lane engine —
    /// [`AttnConfig::fp4`] is the fused packed path,
    /// [`AttnConfig::f32`] the gather + f32 baseline.
    pub attn: AttnConfig,
    /// Hard cap on prompt + generated tokens per sequence.
    pub seq_max: usize,
    /// Seed of the per-request sampling streams (request id is mixed in,
    /// so placement never shifts a sequence's draws).
    pub sample_seed: u64,
}

impl Default for ShardConfig {
    fn default() -> ShardConfig {
        ShardConfig { slots: 4, attn: AttnConfig::fp4(), seq_max: 512, sample_seed: 0x5e7e }
    }
}

/// Post-drain per-shard report: throughput, queueing, tail latency, and
/// the aggregated quantized-query cache counters of the shard's engines.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub shard: usize,
    /// Requests accepted into a lane.
    pub requests: usize,
    /// Requests rejected at admission (zero token budget, prompt beyond
    /// `seq_max`, duplicate in-flight id); each still yields a completion
    /// with `new_tokens == 0` so submitters see every id come back.
    pub rejected: usize,
    pub steps: usize,
    /// Forward passes run (prompt rows + decode steps across sequences).
    pub tokens: usize,
    /// Wall time spent inside [`ShardWorker::step`].
    pub busy_ms: f64,
    pub tokens_per_s: f64,
    /// Peak of the worker-local queue (submitted but not yet in a lane).
    /// Under the cluster's lane-bounded intake this stays at most the
    /// lane count — the bounded channel is the real waiting line; a
    /// standalone worker's direct submissions all land here instead.
    pub queue_peak: usize,
    pub p50_token_ms: f64,
    pub p99_token_ms: f64,
    /// EWMA (α from the supervisor's live estimator) over the per-token
    /// latency series; `None` when no token was served — never NaN.
    pub ewma_token_ms: Option<f64>,
    /// Quantized-query cache hits/misses summed over the shard's lane
    /// engines (per-shard caches: no cross-shard thrash by construction).
    pub qcache_hits: u64,
    pub qcache_misses: u64,
    pub kv_bytes_peak: usize,
    pub kv_bytes_f32_equiv_peak: usize,
}

struct ActiveSeq {
    req: Request,
    slot: SeqSlot,
    tokens: Vec<u8>,
    /// Prompt rows actually decoded (1 for an empty prompt's pad byte) —
    /// keeps `text.len() == prompt_tokens + new_tokens` exact.
    prompt_tokens: usize,
    generated: usize,
    rng: Rng,
    started: std::time::Instant,
}

/// Reused forward-pass buffers (token-major rows plus the head-major
/// staging the engine's prefill layout needs); capacity persists across
/// steps so the steady-state loop does not allocate.
#[derive(Default)]
struct StepBufs {
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    /// Head-major (heads × nq × head_dim) staging for prefill Q / output.
    qhm: Vec<f32>,
    ohm: Vec<f32>,
    logits: Vec<f32>,
}

/// Pre-registered `serve.shard{i}.*` telemetry handles (the full name →
/// site map lives in the [`crate::telemetry`] module docs). Handles are
/// resolved once at [`ShardWorker::attach_telemetry`]; the per-pass
/// publish path is relaxed atomic stores only.
struct ShardProbes {
    telemetry: Telemetry,
    shard: usize,
    queue_depth: Gauge,
    active: Gauge,
    requests: Counter,
    rejected: Counter,
    steps: Counter,
    tokens: Counter,
    tokens_per_s: Gauge,
    p50_token_ms: Gauge,
    p99_token_ms: Gauge,
    ewma_token_ms: Gauge,
    token_ms: Histogram,
    qcache_hits: Gauge,
    qcache_misses: Gauge,
    qcache_hit_rate: Gauge,
    kv_bytes: Gauge,
    kv_bytes_peak: Gauge,
    kv_bytes_f32_equiv_peak: Gauge,
}

impl ShardProbes {
    /// Republish the authoritative drain-time values so the registry view
    /// and the [`ShardStats`] facade agree exactly (pinned by the parity
    /// test in `rust/tests/telemetry.rs`).
    fn publish_final(&self, s: &ShardStats) {
        self.requests.set(s.requests as u64);
        self.rejected.set(s.rejected as u64);
        self.steps.set(s.steps as u64);
        self.tokens.set(s.tokens as u64);
        self.tokens_per_s.set(s.tokens_per_s);
        self.p50_token_ms.set(s.p50_token_ms);
        self.p99_token_ms.set(s.p99_token_ms);
        if let Some(ewma) = s.ewma_token_ms {
            self.ewma_token_ms.set(ewma);
        }
        self.qcache_hits.set(s.qcache_hits as f64);
        self.qcache_misses.set(s.qcache_misses as f64);
        let lookups = s.qcache_hits + s.qcache_misses;
        if lookups > 0 {
            self.qcache_hit_rate.set(s.qcache_hits as f64 / lookups as f64);
        }
        self.kv_bytes_peak.set(s.kv_bytes_peak as f64);
        self.kv_bytes_f32_equiv_peak.set(s.kv_bytes_f32_equiv_peak as f64);
    }
}

/// A single decode shard (usable standalone as a native single-worker
/// decode server — the cluster's reference for bitwise determinism).
pub struct ShardWorker {
    cfg: ShardConfig,
    model: Box<dyn TokenModel>,
    cache: PagedKvCache,
    /// One engine per batch lane (lane i serves `active[i]`).
    engines: Vec<AttnEngine>,
    queue: VecDeque<Request>,
    active: Vec<ActiveSeq>,
    done: Vec<Completion>,
    bufs: StepBufs,
    // Stats accumulators.
    requests: usize,
    rejected: usize,
    steps: usize,
    tokens: usize,
    busy_ns: f64,
    queue_peak: usize,
    token_ms: Vec<f64>,
    kv_peak: usize,
    kv_f32_peak: usize,
    /// `None` until [`ShardWorker::attach_telemetry`] — a detached worker
    /// publishes nothing and behaves bitwise as before.
    probes: Option<ShardProbes>,
}

impl ShardWorker {
    pub fn new(model: Box<dyn TokenModel>, cfg: ShardConfig) -> ShardWorker {
        assert!(cfg.slots > 0, "shard needs at least one lane");
        let cache = PagedKvCache::new(model.layers(), model.heads(), model.head_dim());
        let engines = (0..cfg.slots).map(|_| AttnEngine::new(cfg.attn)).collect();
        ShardWorker {
            cfg,
            model,
            cache,
            engines,
            queue: VecDeque::new(),
            active: Vec::new(),
            done: Vec::new(),
            bufs: StepBufs::default(),
            requests: 0,
            rejected: 0,
            steps: 0,
            tokens: 0,
            busy_ns: 0.0,
            queue_peak: 0,
            token_ms: Vec::new(),
            kv_peak: 0,
            kv_f32_peak: 0,
            probes: None,
        }
    }

    /// Register this worker's `serve.shard{shard}.*` metrics in
    /// `telemetry` and publish into them from here on — live gauges from
    /// [`ShardWorker::step`], authoritative totals from
    /// [`ShardWorker::stats`] at drain.
    pub fn attach_telemetry(&mut self, telemetry: &Telemetry, shard: usize) {
        let reg = telemetry.registry();
        let name = |metric: &str| format!("serve.shard{shard}.{metric}");
        self.probes = Some(ShardProbes {
            telemetry: telemetry.clone(),
            shard,
            queue_depth: reg.gauge(&name("queue_depth")),
            active: reg.gauge(&name("active")),
            requests: reg.counter(&name("requests")),
            rejected: reg.counter(&name("rejected")),
            steps: reg.counter(&name("steps")),
            tokens: reg.counter(&name("tokens")),
            tokens_per_s: reg.gauge(&name("tokens_per_s")),
            p50_token_ms: reg.gauge(&name("p50_token_ms")),
            p99_token_ms: reg.gauge(&name("p99_token_ms")),
            ewma_token_ms: reg.gauge(&name("ewma_token_ms")),
            token_ms: reg.histogram(&name("token_ms")),
            qcache_hits: reg.gauge(&name("qcache_hits")),
            qcache_misses: reg.gauge(&name("qcache_misses")),
            qcache_hit_rate: reg.gauge(&name("qcache_hit_rate")),
            kv_bytes: reg.gauge(&name("kv_bytes")),
            kv_bytes_peak: reg.gauge(&name("kv_bytes_peak")),
            kv_bytes_f32_equiv_peak: reg.gauge(&name("kv_bytes_f32_equiv_peak")),
        });
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
        self.queue_peak = self.queue_peak.max(self.queue.len());
    }

    /// Nothing queued and no lane occupied.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// Could another submission be admitted right now (a lane is free and
    /// not already spoken for)? The cluster's shard loop pulls from its
    /// bounded channel only while this holds, so the channel — not a
    /// worker-local buffer — is the queue that `queue_depth` bounds.
    pub fn wants_work(&self) -> bool {
        self.queue.len() + self.active.len() < self.cfg.slots
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// One scheduling round: admit queued requests into free lanes
    /// (prefilling their prompts in batched passes), then decode one token
    /// for every active lane. Returns the number of forward passes run.
    pub fn step(&mut self) -> Result<usize> {
        let t0 = std::time::Instant::now();
        let mut processed = 0usize;

        // Span recorder cloned out of the probes (Arc bump, no alloc) so
        // guards never hold a borrow of `self` across `&mut self` calls.
        let spans = self.probes.as_ref().map(|p| (p.telemetry.spans().clone(), p.shard));

        // Admission: prompt prefill + first sampled token per request.
        if !self.queue.is_empty() {
            let _span = spans.as_ref().map(|(s, sh)| crate::span!(s, "admit", shard = *sh));
            while self.active.len() < self.cfg.slots {
                let Some(req) = self.queue.pop_front() else { break };
                processed += self.admit(req)?;
            }
        }

        // Decode: one token per active lane.
        if !self.active.is_empty() {
            let _span = spans.as_ref().map(|(s, sh)| crate::span!(s, "decode", shard = *sh));
            let dec0 = std::time::Instant::now();
            let mut finished = Vec::new();
            for lane in 0..self.active.len() {
                let a = &self.active[lane];
                let (slot, pos) = (a.slot, a.tokens.len() - 1);
                let tok = *a.tokens.last().expect("active seq has tokens");
                forward_rows(
                    self.model.as_ref(),
                    &mut self.cache,
                    &mut self.engines[lane],
                    &mut self.bufs,
                    slot,
                    &[tok],
                    pos,
                )?;
                processed += 1;
                let d = self.model.d_model();
                self.bufs.logits.resize(VOCAB, 0.0);
                self.model.logits(&self.bufs.h[..d], &mut self.bufs.logits);
                let a = &mut self.active[lane];
                let next = if a.req.temperature <= 0.0 {
                    argmax(&self.bufs.logits)
                } else {
                    sample_temp(&self.bufs.logits, a.req.temperature, &mut a.rng)
                } as u8;
                a.tokens.push(next);
                a.generated += 1;
                if a.generated >= a.req.max_new_tokens
                    || next == b'$'
                    || a.tokens.len() >= self.cfg.seq_max
                {
                    finished.push(lane);
                }
            }
            let per_tok_ms = dec0.elapsed().as_secs_f64() * 1e3 / self.active.len() as f64;
            for _ in 0..self.active.len() {
                self.token_ms.push(per_tok_ms);
                if let Some(p) = &self.probes {
                    p.token_ms.record(per_tok_ms);
                }
            }
            for &lane in finished.iter().rev() {
                self.finish(lane)?;
            }
        }

        self.steps += 1;
        self.tokens += processed;
        self.busy_ns += t0.elapsed().as_nanos() as f64;
        if let Some(p) = &self.probes {
            p.queue_depth.set(self.queue.len() as f64);
            p.active.set(self.active.len() as f64);
            p.steps.set(self.steps as u64);
            p.tokens.set(self.tokens as u64);
        }
        Ok(processed)
    }

    /// Record KV memory peaks. Cache bytes only grow between admissions
    /// and completions (per-token appends are monotonic), so sampling at
    /// those two points captures every peak without walking the page
    /// lists on each decode step.
    fn sample_kv_peaks(&mut self) {
        let (used, equiv) = self.cache.memory_stats();
        self.kv_peak = self.kv_peak.max(used);
        self.kv_f32_peak = self.kv_f32_peak.max(equiv);
        if let Some(p) = &self.probes {
            p.kv_bytes.set(used as f64);
        }
    }

    /// Admit one request: resolve its slot, ingest the whole prompt
    /// through the batched prefill path, sample its first token. Returns
    /// prompt rows processed. A request that finishes at admission (e.g.
    /// `max_new_tokens == 1`) completes without occupying a lane.
    ///
    /// Invalid requests are **rejected, never shard-fatal**: a zero token
    /// budget, a prompt beyond `seq_max`, or an id already in flight (it
    /// would share that sequence's cache slot; the router hashes on id,
    /// so a concurrent duplicate always reaches the same shard) completes
    /// immediately with `new_tokens == 0` — the rejection marker, since
    /// an accepted request always generates at least one token — leaving
    /// every other request unharmed. Note the check only guards ids
    /// currently *in flight*: an id resubmitted after its sequence
    /// completed is served fresh, so whether a duplicate is rejected or
    /// re-served depends on arrival timing — the bitwise-determinism
    /// guarantee is scoped to traces of unique request ids.
    fn admit(&mut self, req: Request) -> Result<usize> {
        let too_long = req.prompt.len().max(1) + 1 > self.cfg.seq_max;
        if req.max_new_tokens == 0 || too_long || self.cache.slot(req.id).is_ok() {
            self.rejected += 1;
            self.done.push(Completion {
                id: req.id,
                prompt_tokens: req.prompt.len(),
                new_tokens: 0,
                text: req.prompt,
                wall_ms: 0.0,
            });
            return Ok(0);
        }
        // An empty prompt decodes from a single pad byte, which counts as
        // its one prompt row.
        let mut tokens = if req.prompt.is_empty() {
            vec![b' ']
        } else {
            req.prompt.clone()
        };
        let started = std::time::Instant::now();
        self.requests += 1;
        let slot = self.cache.add_seq(req.id);
        let lane = self.active.len();
        let nq = tokens.len();
        {
            let _span = self
                .probes
                .as_ref()
                .map(|p| crate::span!(p.telemetry.spans(), "prefill", shard = p.shard));
            forward_rows(
                self.model.as_ref(),
                &mut self.cache,
                &mut self.engines[lane],
                &mut self.bufs,
                slot,
                &tokens,
                0,
            )?;
        }
        let d = self.model.d_model();
        self.bufs.logits.resize(VOCAB, 0.0);
        self.model.logits(&self.bufs.h[(nq - 1) * d..nq * d], &mut self.bufs.logits);
        let mut rng = Rng::new(self.cfg.sample_seed).split(&format!("req-{}", req.id));
        let next = if req.temperature <= 0.0 {
            argmax(&self.bufs.logits)
        } else {
            sample_temp(&self.bufs.logits, req.temperature, &mut rng)
        } as u8;
        tokens.push(next);
        let per_tok_ms = started.elapsed().as_secs_f64() * 1e3 / nq as f64;
        for _ in 0..nq {
            self.token_ms.push(per_tok_ms);
            if let Some(p) = &self.probes {
                p.token_ms.record(per_tok_ms);
            }
        }
        let a = ActiveSeq { req, slot, tokens, prompt_tokens: nq, generated: 1, rng, started };
        self.active.push(a);
        self.sample_kv_peaks();
        let a = &self.active[lane];
        if a.generated >= a.req.max_new_tokens
            || next == b'$'
            || a.tokens.len() >= self.cfg.seq_max
        {
            self.finish(lane)?;
        }
        Ok(nq)
    }

    /// Retire lane `lane`: free its cache slot, record the completion.
    fn finish(&mut self, lane: usize) -> Result<()> {
        self.sample_kv_peaks();
        let a = self.active.swap_remove(lane);
        self.cache.drop_slot(a.slot)?;
        self.done.push(Completion {
            id: a.req.id,
            prompt_tokens: a.prompt_tokens,
            new_tokens: a.generated,
            text: a.tokens,
            wall_ms: a.started.elapsed().as_secs_f64() * 1e3,
        });
        Ok(())
    }

    /// Pump [`ShardWorker::step`] until idle; returns all completions so
    /// far (the standalone single-worker server loop).
    pub fn run(&mut self) -> Result<Vec<Completion>> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.take_done())
    }

    pub fn take_done(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.done)
    }

    /// Quantized-query cache hits/misses aggregated across this shard's
    /// engine lanes — the one authoritative per-shard rollup behind both
    /// [`ShardStats`] and the `serve.shard{i}.qcache_*` gauges.
    pub fn qcache_totals(&self) -> (u64, u64) {
        self.engines.iter().fold((0u64, 0u64), |(hits, misses), e| {
            let (h, m) = e.query_cache_stats();
            (hits + h, misses + m)
        })
    }

    /// Snapshot the shard's statistics (percentiles computed here).
    pub fn stats(&self, shard: usize) -> ShardStats {
        let mut sorted = self.token_ms.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| {
            if sorted.is_empty() {
                0.0
            } else {
                sorted[((sorted.len() - 1) as f64 * p).round() as usize]
            }
        };
        let (hits, misses) = self.qcache_totals();
        let busy_s = self.busy_ns * 1e-9;
        let alpha = crate::serve::supervisor::EWMA_ALPHA;
        let ewma = self.token_ms.iter().fold(None, |acc, &ms| match acc {
            None => Some(ms),
            Some(prev) => Some((1.0 - alpha) * prev + alpha * ms),
        });
        let stats = ShardStats {
            shard,
            requests: self.requests,
            rejected: self.rejected,
            steps: self.steps,
            tokens: self.tokens,
            busy_ms: self.busy_ns * 1e-6,
            tokens_per_s: self.tokens as f64 / busy_s.max(1e-12),
            queue_peak: self.queue_peak,
            p50_token_ms: pct(0.5),
            p99_token_ms: pct(0.99),
            ewma_token_ms: ewma,
            qcache_hits: hits,
            qcache_misses: misses,
            kv_bytes_peak: self.kv_peak,
            kv_bytes_f32_equiv_peak: self.kv_f32_peak,
        };
        if let Some(p) = &self.probes {
            p.publish_final(&stats);
        }
        stats
    }
}

/// One forward pass over `tokens` (positions `pos0..`) for the sequence in
/// `slot`: embed, then per layer project Q/K/V, append K/V to the paged
/// cache, attend (single-query decode for one row, batched prefill for
/// many), and mix. Leaves the final hidden rows in `bufs.h`
/// (`tokens.len() × d_model`).
///
/// Free function over explicit parts (not `&mut self`) so the worker can
/// borrow its model, cache, one lane engine, and the buffers
/// simultaneously.
fn forward_rows(
    model: &dyn TokenModel,
    cache: &mut PagedKvCache,
    engine: &mut AttnEngine,
    bufs: &mut StepBufs,
    slot: SeqSlot,
    tokens: &[u8],
    pos0: usize,
) -> Result<()> {
    let d = model.d_model();
    let hd = model.head_dim();
    let heads = model.heads();
    let nq = tokens.len();
    let n = nq * d;
    bufs.h.resize(n, 0.0);
    bufs.q.resize(n, 0.0);
    bufs.k.resize(n, 0.0);
    bufs.v.resize(n, 0.0);
    bufs.attn.resize(n, 0.0);
    model.embed(tokens, pos0, &mut bufs.h[..n]);
    for layer in 0..model.layers() {
        model.qkv(layer, &bufs.h[..n], &mut bufs.q[..n], &mut bufs.k[..n], &mut bufs.v[..n]);
        for i in 0..nq {
            for head in 0..heads {
                let off = i * d + head * hd;
                cache.append_at(
                    slot,
                    layer,
                    head,
                    &bufs.k[off..off + hd],
                    &bufs.v[off..off + hd],
                )?;
            }
        }
        if nq == 1 {
            // A single row is already (heads × head_dim): fused decode.
            engine.decode_slot(cache, slot, layer, &bufs.q[..d], &mut bufs.attn[..d])?;
        } else {
            // Restage token-major rows head-major for the batched prefill,
            // then scatter the outputs back.
            bufs.qhm.resize(n, 0.0);
            bufs.ohm.resize(n, 0.0);
            for head in 0..heads {
                for i in 0..nq {
                    let src = i * d + head * hd;
                    let dst = head * nq * hd + i * hd;
                    bufs.qhm[dst..dst + hd].copy_from_slice(&bufs.q[src..src + hd]);
                }
            }
            engine.prefill_slot(cache, slot, layer, &bufs.qhm[..n], nq, &mut bufs.ohm[..n])?;
            for head in 0..heads {
                for i in 0..nq {
                    let src = head * nq * hd + i * hd;
                    let dst = i * d + head * hd;
                    bufs.attn[dst..dst + hd].copy_from_slice(&bufs.ohm[src..src + hd]);
                }
            }
        }
        model.mix(layer, &mut bufs.h[..n], &bufs.attn[..n]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model::{SimLm, SimLmConfig};

    fn worker(cfg: ShardConfig) -> ShardWorker {
        ShardWorker::new(Box::new(SimLm::new(SimLmConfig::default())), cfg)
    }

    fn req(id: u64, prompt: &[u8], max_new: usize) -> Request {
        Request {
            id,
            prompt: prompt.to_vec(),
            max_new_tokens: max_new,
            temperature: 0.0,
            deadline_ms: None,
        }
    }

    #[test]
    fn serves_requests_to_completion() {
        let mut w = worker(ShardConfig::default());
        for i in 0..6 {
            w.submit(req(i + 1, b"A hello#", 6));
        }
        let done = w.run().unwrap();
        assert_eq!(done.len(), 6);
        for c in &done {
            assert_eq!(c.prompt_tokens, 8);
            assert!(c.new_tokens >= 1 && c.new_tokens <= 6);
            assert_eq!(c.text.len(), c.prompt_tokens + c.new_tokens);
            assert!(c.text.starts_with(b"A hello#"));
        }
        assert!(w.is_idle());
        let s = w.stats(0);
        assert_eq!(s.requests, 6);
        assert!(s.tokens >= 6 * 8, "tokens {}", s.tokens);
        assert!(s.p50_token_ms <= s.p99_token_ms);
        // All slots freed: the drained cache holds nothing.
        assert!(s.kv_bytes_peak > 0);
    }

    #[test]
    fn deterministic_across_reruns_and_greedy_equals_itself() {
        let trace: Vec<Request> = (0..5)
            .map(|i| Request {
                id: 100 + i,
                prompt: format!("B q{i}#").into_bytes(),
                max_new_tokens: 5,
                temperature: if i % 2 == 0 { 0.0 } else { 0.8 },
                deadline_ms: None,
            })
            .collect();
        let mut a = worker(ShardConfig::default());
        let mut b = worker(ShardConfig { slots: 2, ..ShardConfig::default() });
        for r in &trace {
            a.submit(r.clone());
            b.submit(r.clone());
        }
        let mut da = a.run().unwrap();
        let mut db = b.run().unwrap();
        da.sort_by_key(|c| c.id);
        db.sort_by_key(|c| c.id);
        // Different lane counts reorder the work, never the tokens —
        // including the temperature>0 requests (per-request rng streams).
        for (x, y) in da.iter().zip(&db) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.text, y.text);
            assert_eq!(x.new_tokens, y.new_tokens);
        }
    }

    #[test]
    fn empty_prompt_and_zero_budget_edges() {
        let mut w = worker(ShardConfig::default());
        w.submit(req(1, b"", 2));
        let done = w.run().unwrap();
        assert_eq!(done.len(), 1);
        // The pad byte counts as the one decoded prompt row, keeping the
        // text.len() == prompt_tokens + new_tokens invariant exact.
        assert_eq!(done[0].prompt_tokens, 1);
        assert_eq!(done[0].text.len(), done[0].prompt_tokens + done[0].new_tokens);
        assert!(done[0].new_tokens >= 1);

        // Zero-token budget: rejected (new_tokens == 0), never shard-fatal.
        let mut w = worker(ShardConfig::default());
        w.submit(req(2, b"x", 0));
        w.submit(req(3, b"ok#", 2));
        let done = w.run().unwrap();
        assert_eq!(done.len(), 2, "rejection must not kill the healthy request");
        let rej = done.iter().find(|c| c.id == 2).unwrap();
        assert_eq!((rej.new_tokens, rej.text.as_slice()), (0, b"x".as_slice()));
        assert!(done.iter().find(|c| c.id == 3).unwrap().new_tokens >= 1);
        assert_eq!(w.stats(0).rejected, 1);
    }

    #[test]
    fn duplicate_in_flight_ids_and_oversized_prompts_are_rejected() {
        // slots=2: request 7 is still in flight (lane 0) when its
        // duplicate reaches admission in the same scheduling round.
        let mut w = worker(ShardConfig { slots: 2, ..ShardConfig::default() });
        w.submit(req(7, b"first#", 4));
        w.submit(req(7, b"second#", 4));
        w.submit(req(8, &[b'L'; 600], 4)); // prompt beyond seq_max=512
        let done = w.run().unwrap();
        assert_eq!(done.len(), 3);
        let dup: Vec<_> = done.iter().filter(|c| c.id == 7).collect();
        assert_eq!(dup.len(), 2);
        assert!(dup.iter().any(|c| c.new_tokens == 0), "duplicate rejected");
        assert!(dup.iter().any(|c| c.new_tokens >= 1), "original served");
        assert_eq!(done.iter().find(|c| c.id == 8).unwrap().new_tokens, 0);
        assert_eq!(w.stats(0).rejected, 2);
    }
}
