//! One decode shard: a self-contained continuous-batching worker.
//!
//! A [`ShardWorker`] owns everything one shard of the cluster needs — its
//! own [`PagedKvCache`], one [`AttnEngine`] per batch lane, a
//! [`TokenModel`], and the request queue — so shards share **nothing** and
//! the cluster needs no locks: the router hands a shard its requests and
//! the worker thread pumps [`ShardWorker::step`] until drained.
//!
//! Scheduling is continuous batching at token granularity, with **batched
//! prompt admission**: an admitted request's whole prompt is ingested in
//! one pass per layer through [`AttnEngine::prefill_slot`] (one page walk
//! per query instead of one full decode call per prompt token), then the
//! sequence joins the per-token decode loop alongside the other lanes.
//! Sequences are addressed by their [`SeqSlot`] handle, resolved once at
//! admission — the per-token path does zero map lookups.
//!
//! Determinism: every float a sequence sees depends only on its own
//! tokens, its own cache pages, and the model weights — never on which
//! lane or shard it landed in, or on what other sequences are in flight.
//! Temperature sampling draws from a per-request stream seeded by the
//! request id, so completions are bitwise reproducible under any shard
//! count (pinned by `rust/tests/cluster_serve.rs`).
//!
//! Shared-prefix admission ([`ShardConfig::prefix_share`]): the worker
//! keeps a per-shard [`PrefixIndex`] mapping prompt prefixes to sealed
//! page runs. A matching prompt attaches the shared run (refcounted, no
//! byte copy) and prefills only its suffix — admission cost O(suffix)
//! instead of O(prompt), KV bytes per sequence collapse for
//! common-system-prompt traffic, and because sealed pages are immutable
//! and quantization is deterministic the decode output stays **bitwise
//! identical** to the unshared path (pinned by
//! `rust/tests/prefix_cache.rs`). Cold sealed pages can additionally
//! spill to disk ([`ShardConfig::kv_spill`]) and reload transparently.

use std::collections::VecDeque;

use anyhow::Result;

use crate::attention::{AttnConfig, AttnEngine};
use crate::kvcache::{PagedKvCache, SeqSlot, SpillConfig, PAGE_SIZE};
use crate::rng::Rng;
use crate::telemetry::{Counter, Gauge, Histogram, Telemetry, TraceContext};

use super::model::{TokenModel, VOCAB};
use super::prefix::{PrefixIndex, PrefixMatch};
use super::{argmax, Completion, Request, sample_temp};

/// Per-shard serving knobs.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Concurrent batch lanes (sequences decoding per step).
    pub slots: usize,
    /// Attention session config for every lane engine —
    /// [`AttnConfig::fp4`] is the fused packed path,
    /// [`AttnConfig::f32`] the gather + f32 baseline.
    pub attn: AttnConfig,
    /// Hard cap on prompt + generated tokens per sequence.
    pub seq_max: usize,
    /// Seed of the per-request sampling streams (request id is mixed in,
    /// so placement never shifts a sequence's draws).
    pub sample_seed: u64,
    /// Shared-prefix admission: content-dedup sealed pages and attach
    /// prompts to already-sealed prefix runs via the per-shard
    /// [`PrefixIndex`] (admission cost drops from O(prompt) to
    /// O(suffix); decode outputs are bitwise unchanged). Off by default:
    /// sharing changes which prefill rows run, which shifts qcache
    /// patterns the determinism pins compare.
    pub prefix_share: bool,
    /// Prefix-index capacity (registered 16-token chunks).
    pub prefix_cap: usize,
    /// Spill cold sealed pages to disk under this config (`serve cluster
    /// --kv-spill-dir`); `None` keeps everything resident.
    pub kv_spill: Option<SpillConfig>,
}

impl Default for ShardConfig {
    fn default() -> ShardConfig {
        ShardConfig {
            slots: 4,
            attn: AttnConfig::fp4(),
            seq_max: 512,
            sample_seed: 0x5e7e,
            prefix_share: false,
            prefix_cap: 512,
            kv_spill: None,
        }
    }
}

/// Post-drain per-shard report: throughput, queueing, tail latency, and
/// the aggregated quantized-query cache counters of the shard's engines.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub shard: usize,
    /// Requests accepted into a lane.
    pub requests: usize,
    /// Requests rejected at admission (zero token budget, prompt beyond
    /// `seq_max`, duplicate in-flight id); each still yields a completion
    /// with `new_tokens == 0` so submitters see every id come back.
    pub rejected: usize,
    pub steps: usize,
    /// Forward passes run (prompt rows + decode steps across sequences).
    pub tokens: usize,
    /// Wall time spent inside [`ShardWorker::step`].
    pub busy_ms: f64,
    pub tokens_per_s: f64,
    /// Peak of the worker-local queue (submitted but not yet in a lane).
    /// Under the cluster's lane-bounded intake this stays at most the
    /// lane count — the bounded channel is the real waiting line; a
    /// standalone worker's direct submissions all land here instead.
    pub queue_peak: usize,
    pub p50_token_ms: f64,
    pub p99_token_ms: f64,
    /// EWMA (α from the supervisor's live estimator) over the per-token
    /// latency series; `None` when no token was served — never NaN.
    pub ewma_token_ms: Option<f64>,
    /// Quantized-query cache hits/misses summed over the shard's lane
    /// engines (per-shard caches: no cross-shard thrash by construction).
    pub qcache_hits: u64,
    pub qcache_misses: u64,
    pub kv_bytes_peak: usize,
    pub kv_bytes_f32_equiv_peak: usize,
    /// Admissions that attached at least one shared sealed prefix page.
    pub prefix_hits: u64,
    /// (layer, head) pages attached from the prefix index instead of
    /// re-prefilled.
    pub prefix_pages_shared: u64,
    /// Packed bytes those attached pages would have re-allocated.
    pub prefix_bytes_saved: u64,
    /// Admissions that diverged from a registered prefix (copy-on-write
    /// split: shared run attached, private hot page opened).
    pub prefix_cow_splits: u64,
    /// Sealed pages written to the spill directory (lifetime total).
    pub spilled_pages: u64,
    /// Spilled pages transparently reloaded by an attend.
    pub reloaded_pages: u64,
    /// Mean admission wall time (prompt prefill + first token), ms.
    pub admit_ms_mean: f64,
    /// Mean fresh KV bytes allocated per admitted sequence (pool fresh
    /// bytes + f32 hot tail) — the shared-prefix bench headline.
    pub kv_admit_bytes_per_seq: f64,
}

struct ActiveSeq {
    req: Request,
    slot: SeqSlot,
    tokens: Vec<u8>,
    /// Prompt rows actually decoded (1 for an empty prompt's pad byte) —
    /// keeps `text.len() == prompt_tokens + new_tokens` exact.
    prompt_tokens: usize,
    generated: usize,
    rng: Rng,
    started: std::time::Instant,
}

/// Reused forward-pass buffers (token-major rows plus the head-major
/// staging the engine's prefill layout needs); capacity persists across
/// steps so the steady-state loop does not allocate.
#[derive(Default)]
struct StepBufs {
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    /// Head-major (heads × nq × head_dim) staging for prefill Q / output.
    qhm: Vec<f32>,
    ohm: Vec<f32>,
    logits: Vec<f32>,
}

/// Pre-registered `serve.shard{i}.*` telemetry handles (the full name →
/// site map lives in the [`crate::telemetry`] module docs). Handles are
/// resolved once at [`ShardWorker::attach_telemetry`]; the per-pass
/// publish path is relaxed atomic stores only.
struct ShardProbes {
    telemetry: Telemetry,
    shard: usize,
    queue_depth: Gauge,
    active: Gauge,
    requests: Counter,
    rejected: Counter,
    steps: Counter,
    tokens: Counter,
    tokens_per_s: Gauge,
    p50_token_ms: Gauge,
    p99_token_ms: Gauge,
    ewma_token_ms: Gauge,
    token_ms: Histogram,
    qcache_hits: Gauge,
    qcache_misses: Gauge,
    qcache_hit_rate: Gauge,
    kv_bytes: Gauge,
    kv_bytes_peak: Gauge,
    kv_bytes_f32_equiv_peak: Gauge,
    admit_ms_mean: Gauge,
    kv_admit_bytes_per_seq: Gauge,
    /// Per-shard pool occupancy gauges (`serve.shard{i}.pool.*`).
    pool_pages: Gauge,
    pool_shared_pages: Gauge,
    pool_spilled_pages: Gauge,
    pool_resident_bytes: Gauge,
    /// Cluster-global `serve.prefix.*` counters: handles for one name
    /// alias a single atomic cell, so every shard's worker increments the
    /// same totals. Event-driven (inc/add at admission), never republished
    /// from drain-time stats — a republish would clobber across shards.
    prefix_lookup_hits: Counter,
    prefix_pages_shared: Counter,
    prefix_bytes_saved: Counter,
    prefix_cow_splits: Counter,
    prefix_spilled_pages: Counter,
}

impl ShardProbes {
    /// Republish the authoritative drain-time values so the registry view
    /// and the [`ShardStats`] facade agree exactly (pinned by the parity
    /// test in `rust/tests/telemetry.rs`).
    fn publish_final(&self, s: &ShardStats) {
        self.requests.set(s.requests as u64);
        self.rejected.set(s.rejected as u64);
        self.steps.set(s.steps as u64);
        self.tokens.set(s.tokens as u64);
        self.tokens_per_s.set(s.tokens_per_s);
        self.p50_token_ms.set(s.p50_token_ms);
        self.p99_token_ms.set(s.p99_token_ms);
        if let Some(ewma) = s.ewma_token_ms {
            self.ewma_token_ms.set(ewma);
        }
        self.qcache_hits.set(s.qcache_hits as f64);
        self.qcache_misses.set(s.qcache_misses as f64);
        let lookups = s.qcache_hits + s.qcache_misses;
        if lookups > 0 {
            self.qcache_hit_rate.set(s.qcache_hits as f64 / lookups as f64);
        }
        self.kv_bytes_peak.set(s.kv_bytes_peak as f64);
        self.kv_bytes_f32_equiv_peak.set(s.kv_bytes_f32_equiv_peak as f64);
        self.admit_ms_mean.set(s.admit_ms_mean);
        self.kv_admit_bytes_per_seq.set(s.kv_admit_bytes_per_seq);
    }
}

/// A single decode shard (usable standalone as a native single-worker
/// decode server — the cluster's reference for bitwise determinism).
pub struct ShardWorker {
    cfg: ShardConfig,
    model: Box<dyn TokenModel>,
    cache: PagedKvCache,
    /// Prompt-prefix → sealed-page-run index; `Some` iff
    /// `cfg.prefix_share` (the per-shard sharing scope: routing is
    /// hash-on-id, so placement invariance is untouched).
    prefix: Option<PrefixIndex>,
    /// One engine per batch lane (lane i serves `active[i]`).
    engines: Vec<AttnEngine>,
    queue: VecDeque<Request>,
    active: Vec<ActiveSeq>,
    done: Vec<Completion>,
    bufs: StepBufs,
    // Stats accumulators.
    requests: usize,
    rejected: usize,
    steps: usize,
    tokens: usize,
    busy_ns: f64,
    queue_peak: usize,
    /// Bounded per-token latency sketch (log2 buckets) — O(1) memory for
    /// any run length, quantiles within one bucket width of exact
    /// (replaces the old unbounded per-token `Vec<f64>`).
    token_hist: Histogram,
    /// Per-token latency EWMA, folded incrementally in arrival order (α
    /// shared with the supervisor's live estimator, so the two agree).
    token_ewma: Option<f64>,
    kv_peak: usize,
    kv_f32_peak: usize,
    prefix_hits: u64,
    prefix_pages_shared: u64,
    prefix_bytes_saved: u64,
    prefix_cow_splits: u64,
    admit_ms_sum: f64,
    alloc_bytes_sum: u64,
    /// `None` until [`ShardWorker::attach_telemetry`] — a detached worker
    /// publishes nothing and behaves bitwise as before.
    probes: Option<ShardProbes>,
}

impl ShardWorker {
    pub fn new(model: Box<dyn TokenModel>, cfg: ShardConfig) -> ShardWorker {
        assert!(cfg.slots > 0, "shard needs at least one lane");
        let mut cache = PagedKvCache::new(model.layers(), model.heads(), model.head_dim());
        // Content dedup rides the sharing switch so the sharing-off
        // baseline allocates exactly what a pool-less cache would — the
        // on/off comparison in benches measures sharing, nothing else.
        cache.set_dedup(cfg.prefix_share);
        cache.set_spill(cfg.kv_spill.clone());
        let prefix = cfg.prefix_share.then(|| PrefixIndex::with_capacity(cfg.prefix_cap));
        let engines = (0..cfg.slots).map(|_| AttnEngine::new(cfg.attn)).collect();
        ShardWorker {
            cfg,
            model,
            cache,
            prefix,
            engines,
            queue: VecDeque::new(),
            active: Vec::new(),
            done: Vec::new(),
            bufs: StepBufs::default(),
            requests: 0,
            rejected: 0,
            steps: 0,
            tokens: 0,
            busy_ns: 0.0,
            queue_peak: 0,
            token_hist: Histogram::default(),
            token_ewma: None,
            kv_peak: 0,
            kv_f32_peak: 0,
            prefix_hits: 0,
            prefix_pages_shared: 0,
            prefix_bytes_saved: 0,
            prefix_cow_splits: 0,
            admit_ms_sum: 0.0,
            alloc_bytes_sum: 0,
            probes: None,
        }
    }

    /// Register this worker's `serve.shard{shard}.*` metrics in
    /// `telemetry` and publish into them from here on — live gauges from
    /// [`ShardWorker::step`], authoritative totals from
    /// [`ShardWorker::stats`] at drain.
    pub fn attach_telemetry(&mut self, telemetry: &Telemetry, shard: usize) {
        let reg = telemetry.registry();
        let name = |metric: &str| format!("serve.shard{shard}.{metric}");
        self.probes = Some(ShardProbes {
            telemetry: telemetry.clone(),
            shard,
            queue_depth: reg.gauge(&name("queue_depth")),
            active: reg.gauge(&name("active")),
            requests: reg.counter(&name("requests")),
            rejected: reg.counter(&name("rejected")),
            steps: reg.counter(&name("steps")),
            tokens: reg.counter(&name("tokens")),
            tokens_per_s: reg.gauge(&name("tokens_per_s")),
            p50_token_ms: reg.gauge(&name("p50_token_ms")),
            p99_token_ms: reg.gauge(&name("p99_token_ms")),
            ewma_token_ms: reg.gauge(&name("ewma_token_ms")),
            token_ms: reg.histogram(&name("token_ms")),
            qcache_hits: reg.gauge(&name("qcache_hits")),
            qcache_misses: reg.gauge(&name("qcache_misses")),
            qcache_hit_rate: reg.gauge(&name("qcache_hit_rate")),
            kv_bytes: reg.gauge(&name("kv_bytes")),
            kv_bytes_peak: reg.gauge(&name("kv_bytes_peak")),
            kv_bytes_f32_equiv_peak: reg.gauge(&name("kv_bytes_f32_equiv_peak")),
            admit_ms_mean: reg.gauge(&name("admit_ms_mean")),
            kv_admit_bytes_per_seq: reg.gauge(&name("kv_admit_bytes_per_seq")),
            pool_pages: reg.gauge(&name("pool.pages")),
            pool_shared_pages: reg.gauge(&name("pool.shared_pages")),
            pool_spilled_pages: reg.gauge(&name("pool.spilled_pages")),
            pool_resident_bytes: reg.gauge(&name("pool.resident_bytes")),
            prefix_lookup_hits: reg.counter("serve.prefix.lookup_hits"),
            prefix_pages_shared: reg.counter("serve.prefix.pages_shared"),
            prefix_bytes_saved: reg.counter("serve.prefix.bytes_saved"),
            prefix_cow_splits: reg.counter("serve.prefix.cow_splits"),
            prefix_spilled_pages: reg.counter("serve.prefix.spilled_pages"),
        });
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
        self.queue_peak = self.queue_peak.max(self.queue.len());
    }

    /// Nothing queued and no lane occupied.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// Could another submission be admitted right now (a lane is free and
    /// not already spoken for)? The cluster's shard loop pulls from its
    /// bounded channel only while this holds, so the channel — not a
    /// worker-local buffer — is the queue that `queue_depth` bounds.
    pub fn wants_work(&self) -> bool {
        self.queue.len() + self.active.len() < self.cfg.slots
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// One scheduling round: admit queued requests into free lanes
    /// (prefilling their prompts in batched passes), then decode one token
    /// for every active lane. Returns the number of forward passes run.
    pub fn step(&mut self) -> Result<usize> {
        let t0 = std::time::Instant::now();
        let mut processed = 0usize;

        // Span recorder cloned out of the probes (Arc bump, no alloc) so
        // guards never hold a borrow of `self` across `&mut self` calls.
        let spans = self.probes.as_ref().map(|p| (p.telemetry.spans().clone(), p.shard));

        // Admission: prompt prefill + first sampled token per request.
        // The batch-level span is untraced (`step.admit`); the per-request
        // `admit` span inside [`ShardWorker::admit`] carries the trace.
        if !self.queue.is_empty() {
            let _span = spans.as_ref().map(|(s, sh)| crate::span!(s, "step.admit", shard = *sh));
            while self.active.len() < self.cfg.slots {
                let Some(req) = self.queue.pop_front() else { break };
                processed += self.admit(req)?;
            }
        }

        // Decode: one token per active lane.
        if !self.active.is_empty() {
            let _span = spans.as_ref().map(|(s, sh)| crate::span!(s, "step.decode", shard = *sh));
            let dec0 = std::time::Instant::now();
            let mut finished = Vec::new();
            for lane in 0..self.active.len() {
                let a = &self.active[lane];
                let (slot, pos) = (a.slot, a.tokens.len() - 1);
                let tok = *a.tokens.last().expect("active seq has tokens");
                // Sampled per-token trace spans: the first decode pass of
                // a sequence plus every 4th after — enough to reconstruct
                // per-request decode timing in the exported trace without
                // paying one span per token. Anchored on the request root
                // (not the batch span), so the parent chain of every
                // decode span resolves to its request.
                let sampled = a.generated == 1 || a.generated % 4 == 0;
                let (rid, rtrace) = (a.req.id, a.req.trace);
                {
                    let _tok_span = match (&spans, sampled) {
                        (Some((s, _)), true) => {
                            Some(s.start_child("decode.token", "req", rid, rtrace))
                        }
                        _ => None,
                    };
                    forward_rows(
                        self.model.as_ref(),
                        &mut self.cache,
                        &mut self.engines[lane],
                        &mut self.bufs,
                        slot,
                        &[tok],
                        pos,
                    )?;
                }
                processed += 1;
                let d = self.model.d_model();
                self.bufs.logits.resize(VOCAB, 0.0);
                self.model.logits(&self.bufs.h[..d], &mut self.bufs.logits);
                let a = &mut self.active[lane];
                let next = if a.req.temperature <= 0.0 {
                    argmax(&self.bufs.logits)
                } else {
                    sample_temp(&self.bufs.logits, a.req.temperature, &mut a.rng)
                } as u8;
                a.tokens.push(next);
                a.generated += 1;
                if a.generated >= a.req.max_new_tokens
                    || next == b'$'
                    || a.tokens.len() >= self.cfg.seq_max
                {
                    finished.push(lane);
                }
            }
            let per_tok_ms = dec0.elapsed().as_secs_f64() * 1e3 / self.active.len() as f64;
            let lanes = self.active.len();
            self.record_token_ms(per_tok_ms, lanes);
            for &lane in finished.iter().rev() {
                self.finish(lane)?;
            }
        }

        self.steps += 1;
        self.tokens += processed;
        self.busy_ns += t0.elapsed().as_nanos() as f64;
        if let Some(p) = &self.probes {
            p.queue_depth.set(self.queue.len() as f64);
            p.active.set(self.active.len() as f64);
            p.steps.set(self.steps as u64);
            p.tokens.set(self.tokens as u64);
        }
        Ok(processed)
    }

    /// Fold `n` passes at `ms` each into the bounded latency accounting:
    /// the local sketch (quantiles), the incremental EWMA (same arrival
    /// order as the old per-token vector fold), and the published
    /// `serve.shard{i}.token_ms` histogram.
    fn record_token_ms(&mut self, ms: f64, n: usize) {
        let alpha = crate::serve::supervisor::EWMA_ALPHA;
        for _ in 0..n {
            self.token_hist.record(ms);
            self.token_ewma = Some(match self.token_ewma {
                None => ms,
                Some(prev) => (1.0 - alpha) * prev + alpha * ms,
            });
            if let Some(p) = &self.probes {
                p.token_ms.record(ms);
            }
        }
    }

    /// Record KV memory peaks. Cache bytes only grow between admissions
    /// and completions (per-token appends are monotonic), so sampling at
    /// those two points captures every peak without walking the page
    /// lists on each decode step.
    fn sample_kv_peaks(&mut self) {
        let (used, equiv) = self.cache.memory_stats();
        self.kv_peak = self.kv_peak.max(used);
        self.kv_f32_peak = self.kv_f32_peak.max(equiv);
        if let Some(p) = &self.probes {
            p.kv_bytes.set(used as f64);
            let pool = self.cache.pool();
            p.pool_pages.set(pool.live_pages() as f64);
            p.pool_shared_pages.set(pool.shared_pages() as f64);
            p.pool_spilled_pages.set(pool.spilled_pages() as f64);
            p.pool_resident_bytes.set(pool.resident_bytes() as f64);
        }
    }

    /// Admit one request: resolve its slot, ingest the whole prompt
    /// through the batched prefill path, sample its first token. Returns
    /// prompt rows processed. A request that finishes at admission (e.g.
    /// `max_new_tokens == 1`) completes without occupying a lane.
    ///
    /// Invalid requests are **rejected, never shard-fatal**: a zero token
    /// budget, a prompt beyond `seq_max`, or an id already in flight (it
    /// would share that sequence's cache slot; the router hashes on id,
    /// so a concurrent duplicate always reaches the same shard) completes
    /// immediately with `new_tokens == 0` — the rejection marker, since
    /// an accepted request always generates at least one token — leaving
    /// every other request unharmed. Note the check only guards ids
    /// currently *in flight*: an id resubmitted after its sequence
    /// completed is served fresh, so whether a duplicate is rejected or
    /// re-served depends on arrival timing — the bitwise-determinism
    /// guarantee is scoped to traces of unique request ids.
    fn admit(&mut self, req: Request) -> Result<usize> {
        let too_long = req.prompt.len().max(1) + 1 > self.cfg.seq_max;
        if req.max_new_tokens == 0 || too_long || self.cache.slot(req.id).is_ok() {
            self.rejected += 1;
            self.done.push(Completion {
                id: req.id,
                prompt_tokens: req.prompt.len(),
                new_tokens: 0,
                text: req.prompt,
                wall_ms: 0.0,
            });
            return Ok(0);
        }
        // An empty prompt decodes from a single pad byte, which counts as
        // its one prompt row.
        let mut tokens = if req.prompt.is_empty() {
            vec![b' ']
        } else {
            req.prompt.clone()
        };
        let started = std::time::Instant::now();
        let spans = self.probes.as_ref().map(|p| (p.telemetry.spans().clone(), p.shard));
        // Queue wait: root-span open at submit → this admission, measured
        // against the context that rode the channel (no second clock
        // exchange needed; covers routing + channel residency).
        if let Some((s, _)) = &spans {
            if req.trace.is_some() {
                let now = s.now_us();
                s.record_at(
                    "queue",
                    "",
                    0,
                    req.trace,
                    req.trace.start_us,
                    now.saturating_sub(req.trace.start_us),
                );
            }
        }
        // Per-request admission span: the prefix attach / COW markers and
        // the suffix prefill below all nest under it — and through it,
        // under the request root that crossed the channel.
        let admit_span =
            spans.as_ref().map(|(s, sh)| s.start_child("admit", "shard", *sh as u64, req.trace));
        let admit_ctx = admit_span.as_ref().map_or(TraceContext::NONE, |g| g.context());
        self.requests += 1;
        let slot = self.cache.add_seq(req.id);
        let lane = self.active.len();
        let prompt_len = tokens.len();
        let fresh0 = self.cache.pool().stats().fresh_bytes;
        // Shared-prefix attach: the longest registered sealed run, capped
        // one page short of the full prompt so the logits row always lives
        // in the prefilled suffix. Attaching is pure ref-taking — the
        // suffix prefill then attends those pages byte-for-byte as if this
        // sequence had sealed them itself, so decode stays bitwise equal
        // to the unshared path while admission drops to O(suffix).
        let matched = match &mut self.prefix {
            Some(idx) => idx.lookup(&tokens, (prompt_len - 1) / PAGE_SIZE),
            None => PrefixMatch::default(),
        };
        if !matched.pages.is_empty() {
            let mut bytes = 0u64;
            for run in &matched.pages {
                for &r in run {
                    bytes += self.cache.pool().page_bytes(r) as u64;
                }
            }
            self.cache.attach_prefix_at(slot, &matched.pages)?;
            let shared =
                (matched.pages.len() * self.model.layers() * self.model.heads()) as u64;
            self.prefix_hits += 1;
            self.prefix_pages_shared += shared;
            self.prefix_bytes_saved += bytes;
            if let Some(p) = &self.probes {
                p.prefix_lookup_hits.inc();
                p.prefix_pages_shared.add(shared);
                p.prefix_bytes_saved.add(bytes);
            }
            if let Some((s, _)) = &spans {
                s.record_at("prefix.attach", "pages", shared, admit_ctx, s.now_us(), 0);
            }
        }
        if matched.cow_split {
            self.prefix_cow_splits += 1;
            if let Some(p) = &self.probes {
                p.prefix_cow_splits.inc();
            }
            if let Some((s, _)) = &spans {
                s.record_at("prefix.cow", "", 0, admit_ctx, s.now_us(), 0);
            }
        }
        let skip = matched.pages.len() * PAGE_SIZE;
        let nq = prompt_len - skip;
        {
            // Plain `start`: nests under the open per-request admit span
            // on this thread, so prefill's parent chain reaches the root.
            let _span = spans.as_ref().map(|(s, sh)| crate::span!(s, "prefill", shard = *sh));
            forward_rows(
                self.model.as_ref(),
                &mut self.cache,
                &mut self.engines[lane],
                &mut self.bufs,
                slot,
                &tokens[skip..],
                skip,
            )?;
        }
        let d = self.model.d_model();
        self.bufs.logits.resize(VOCAB, 0.0);
        self.model.logits(&self.bufs.h[(nq - 1) * d..nq * d], &mut self.bufs.logits);
        let mut rng = Rng::new(self.cfg.sample_seed).split(&format!("req-{}", req.id));
        let next = if req.temperature <= 0.0 {
            argmax(&self.bufs.logits)
        } else {
            sample_temp(&self.bufs.logits, req.temperature, &mut rng)
        } as u8;
        // Register the prompt's sealed pages before the sampled token
        // joins `tokens` — the index keys on prompt bytes only, so the
        // next request with this prefix attaches instead of prefilling.
        if let Some(idx) = &mut self.prefix {
            let n_pages = prompt_len / PAGE_SIZE;
            if n_pages > 0 {
                let runs = self.cache.sealed_prefix_refs_at(slot, n_pages)?;
                idx.register(&tokens[..n_pages * PAGE_SIZE], &runs, self.cache.pool_mut());
            }
        }
        tokens.push(next);
        // Admission accounting: wall time to first token, and fresh KV
        // bytes this sequence actually allocated (newly sealed pool pages
        // plus the f32 hot tail) — attached shared pages cost nothing.
        let hot_tail = ((prompt_len % PAGE_SIZE)
            * self.model.head_dim()
            * 4
            * 2
            * self.model.layers()
            * self.model.heads()) as u64;
        self.alloc_bytes_sum += (self.cache.pool().stats().fresh_bytes - fresh0) + hot_tail;
        let admit_ms = started.elapsed().as_secs_f64() * 1e3;
        self.admit_ms_sum += admit_ms;
        self.record_token_ms(admit_ms / nq as f64, nq);
        let a =
            ActiveSeq { req, slot, tokens, prompt_tokens: prompt_len, generated: 1, rng, started };
        self.active.push(a);
        self.sample_kv_peaks();
        // Admission is where resident pool bytes grow; spill cold pages
        // down to the budget here (no-op without a spill config).
        let spilled = self.cache.spill_to_budget()?;
        if spilled > 0 {
            if let Some(p) = &self.probes {
                p.prefix_spilled_pages.add(spilled as u64);
            }
        }
        let a = &self.active[lane];
        if a.generated >= a.req.max_new_tokens
            || next == b'$'
            || a.tokens.len() >= self.cfg.seq_max
        {
            self.finish(lane)?;
        }
        Ok(nq)
    }

    /// Retire lane `lane`: free its cache slot, record the completion.
    fn finish(&mut self, lane: usize) -> Result<()> {
        self.sample_kv_peaks();
        let a = self.active.swap_remove(lane);
        self.cache.drop_slot(a.slot)?;
        // Zero-duration marker closing the request's trace on this shard.
        if let Some(p) = &self.probes {
            let s = p.telemetry.spans();
            s.record_at("finish", "tokens", a.generated as u64, a.req.trace, s.now_us(), 0);
        }
        self.done.push(Completion {
            id: a.req.id,
            prompt_tokens: a.prompt_tokens,
            new_tokens: a.generated,
            text: a.tokens,
            wall_ms: a.started.elapsed().as_secs_f64() * 1e3,
        });
        Ok(())
    }

    /// Pump [`ShardWorker::step`] until idle; returns all completions so
    /// far (the standalone single-worker server loop).
    pub fn run(&mut self) -> Result<Vec<Completion>> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.take_done())
    }

    pub fn take_done(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.done)
    }

    /// Quantized-query cache hits/misses aggregated across this shard's
    /// engine lanes — the one authoritative per-shard rollup behind both
    /// [`ShardStats`] and the `serve.shard{i}.qcache_*` gauges.
    pub fn qcache_totals(&self) -> (u64, u64) {
        self.engines.iter().fold((0u64, 0u64), |(hits, misses), e| {
            let (h, m) = e.query_cache_stats();
            (hits + h, misses + m)
        })
    }

    /// Snapshot the shard's statistics (percentiles estimated from the
    /// bounded log2-bucket sketch — within one bucket width of the exact
    /// sorted-sample quantiles the old unbounded vector produced).
    pub fn stats(&self, shard: usize) -> ShardStats {
        let pct = |p: f64| self.token_hist.quantile(p).unwrap_or(0.0);
        let (hits, misses) = self.qcache_totals();
        let busy_s = self.busy_ns * 1e-9;
        let ewma = self.token_ewma;
        let pool = self.cache.pool().stats();
        let stats = ShardStats {
            shard,
            requests: self.requests,
            rejected: self.rejected,
            steps: self.steps,
            tokens: self.tokens,
            busy_ms: self.busy_ns * 1e-6,
            tokens_per_s: self.tokens as f64 / busy_s.max(1e-12),
            queue_peak: self.queue_peak,
            p50_token_ms: pct(0.5),
            p99_token_ms: pct(0.99),
            ewma_token_ms: ewma,
            qcache_hits: hits,
            qcache_misses: misses,
            kv_bytes_peak: self.kv_peak,
            kv_bytes_f32_equiv_peak: self.kv_f32_peak,
            prefix_hits: self.prefix_hits,
            prefix_pages_shared: self.prefix_pages_shared,
            prefix_bytes_saved: self.prefix_bytes_saved,
            prefix_cow_splits: self.prefix_cow_splits,
            spilled_pages: pool.spilled_total,
            reloaded_pages: pool.reloaded,
            admit_ms_mean: if self.requests > 0 {
                self.admit_ms_sum / self.requests as f64
            } else {
                0.0
            },
            kv_admit_bytes_per_seq: if self.requests > 0 {
                self.alloc_bytes_sum as f64 / self.requests as f64
            } else {
                0.0
            },
        };
        if let Some(p) = &self.probes {
            p.publish_final(&stats);
        }
        stats
    }
}

/// One forward pass over `tokens` (positions `pos0..`) for the sequence in
/// `slot`: embed, then per layer project Q/K/V, append K/V to the paged
/// cache, attend (single-query decode for one row, batched prefill for
/// many), and mix. Leaves the final hidden rows in `bufs.h`
/// (`tokens.len() × d_model`).
///
/// Free function over explicit parts (not `&mut self`) so the worker can
/// borrow its model, cache, one lane engine, and the buffers
/// simultaneously.
fn forward_rows(
    model: &dyn TokenModel,
    cache: &mut PagedKvCache,
    engine: &mut AttnEngine,
    bufs: &mut StepBufs,
    slot: SeqSlot,
    tokens: &[u8],
    pos0: usize,
) -> Result<()> {
    let d = model.d_model();
    let hd = model.head_dim();
    let heads = model.heads();
    let nq = tokens.len();
    let n = nq * d;
    bufs.h.resize(n, 0.0);
    bufs.q.resize(n, 0.0);
    bufs.k.resize(n, 0.0);
    bufs.v.resize(n, 0.0);
    bufs.attn.resize(n, 0.0);
    model.embed(tokens, pos0, &mut bufs.h[..n]);
    for layer in 0..model.layers() {
        model.qkv(layer, &bufs.h[..n], &mut bufs.q[..n], &mut bufs.k[..n], &mut bufs.v[..n]);
        for i in 0..nq {
            for head in 0..heads {
                let off = i * d + head * hd;
                cache.append_at(
                    slot,
                    layer,
                    head,
                    &bufs.k[off..off + hd],
                    &bufs.v[off..off + hd],
                )?;
            }
        }
        if nq == 1 {
            // A single row is already (heads × head_dim): fused decode.
            engine.decode_slot(cache, slot, layer, &bufs.q[..d], &mut bufs.attn[..d])?;
        } else {
            // Restage token-major rows head-major for the batched prefill,
            // then scatter the outputs back.
            bufs.qhm.resize(n, 0.0);
            bufs.ohm.resize(n, 0.0);
            for head in 0..heads {
                for i in 0..nq {
                    let src = i * d + head * hd;
                    let dst = head * nq * hd + i * hd;
                    bufs.qhm[dst..dst + hd].copy_from_slice(&bufs.q[src..src + hd]);
                }
            }
            engine.prefill_slot(cache, slot, layer, &bufs.qhm[..n], nq, &mut bufs.ohm[..n])?;
            for head in 0..heads {
                for i in 0..nq {
                    let src = head * nq * hd + i * hd;
                    let dst = i * d + head * hd;
                    bufs.attn[dst..dst + hd].copy_from_slice(&bufs.ohm[src..src + hd]);
                }
            }
        }
        model.mix(layer, &mut bufs.h[..n], &bufs.attn[..n]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model::{SimLm, SimLmConfig};

    fn worker(cfg: ShardConfig) -> ShardWorker {
        ShardWorker::new(Box::new(SimLm::new(SimLmConfig::default())), cfg)
    }

    fn req(id: u64, prompt: &[u8], max_new: usize) -> Request {
        Request {
            id,
            prompt: prompt.to_vec(),
            max_new_tokens: max_new,
            temperature: 0.0,
            deadline_ms: None,
            trace: Default::default(),
        }
    }

    #[test]
    fn serves_requests_to_completion() {
        let mut w = worker(ShardConfig::default());
        for i in 0..6 {
            w.submit(req(i + 1, b"A hello#", 6));
        }
        let done = w.run().unwrap();
        assert_eq!(done.len(), 6);
        for c in &done {
            assert_eq!(c.prompt_tokens, 8);
            assert!(c.new_tokens >= 1 && c.new_tokens <= 6);
            assert_eq!(c.text.len(), c.prompt_tokens + c.new_tokens);
            assert!(c.text.starts_with(b"A hello#"));
        }
        assert!(w.is_idle());
        let s = w.stats(0);
        assert_eq!(s.requests, 6);
        assert!(s.tokens >= 6 * 8, "tokens {}", s.tokens);
        assert!(s.p50_token_ms <= s.p99_token_ms);
        // All slots freed: the drained cache holds nothing.
        assert!(s.kv_bytes_peak > 0);
    }

    #[test]
    fn deterministic_across_reruns_and_greedy_equals_itself() {
        let trace: Vec<Request> = (0..5)
            .map(|i| Request {
                id: 100 + i,
                prompt: format!("B q{i}#").into_bytes(),
                max_new_tokens: 5,
                temperature: if i % 2 == 0 { 0.0 } else { 0.8 },
                deadline_ms: None,
                trace: Default::default(),
            })
            .collect();
        let mut a = worker(ShardConfig::default());
        let mut b = worker(ShardConfig { slots: 2, ..ShardConfig::default() });
        for r in &trace {
            a.submit(r.clone());
            b.submit(r.clone());
        }
        let mut da = a.run().unwrap();
        let mut db = b.run().unwrap();
        da.sort_by_key(|c| c.id);
        db.sort_by_key(|c| c.id);
        // Different lane counts reorder the work, never the tokens —
        // including the temperature>0 requests (per-request rng streams).
        for (x, y) in da.iter().zip(&db) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.text, y.text);
            assert_eq!(x.new_tokens, y.new_tokens);
        }
    }

    #[test]
    fn empty_prompt_and_zero_budget_edges() {
        let mut w = worker(ShardConfig::default());
        w.submit(req(1, b"", 2));
        let done = w.run().unwrap();
        assert_eq!(done.len(), 1);
        // The pad byte counts as the one decoded prompt row, keeping the
        // text.len() == prompt_tokens + new_tokens invariant exact.
        assert_eq!(done[0].prompt_tokens, 1);
        assert_eq!(done[0].text.len(), done[0].prompt_tokens + done[0].new_tokens);
        assert!(done[0].new_tokens >= 1);

        // Zero-token budget: rejected (new_tokens == 0), never shard-fatal.
        let mut w = worker(ShardConfig::default());
        w.submit(req(2, b"x", 0));
        w.submit(req(3, b"ok#", 2));
        let done = w.run().unwrap();
        assert_eq!(done.len(), 2, "rejection must not kill the healthy request");
        let rej = done.iter().find(|c| c.id == 2).unwrap();
        assert_eq!((rej.new_tokens, rej.text.as_slice()), (0, b"x".as_slice()));
        assert!(done.iter().find(|c| c.id == 3).unwrap().new_tokens >= 1);
        assert_eq!(w.stats(0).rejected, 1);
    }

    #[test]
    fn prefix_share_is_bitwise_identical_and_skips_prefill_work() {
        // Common 64-byte system prompt (4 sealed pages) + unique tails:
        // sharing must change admission cost and KV allocation, never a
        // single output byte.
        let mut sys = b"C shared system prompt: answer briefly and politely".to_vec();
        sys.resize(64, b'.');
        let trace: Vec<Request> = (0..6)
            .map(|i| {
                let mut prompt = sys.clone();
                prompt.extend(format!(" q{i}#").into_bytes());
                req(i + 1, &prompt, 5)
            })
            .collect();
        let mut on = worker(ShardConfig { prefix_share: true, ..ShardConfig::default() });
        let mut off = worker(ShardConfig::default());
        for r in &trace {
            on.submit(r.clone());
            off.submit(r.clone());
        }
        let mut da = on.run().unwrap();
        let mut db = off.run().unwrap();
        da.sort_by_key(|c| c.id);
        db.sort_by_key(|c| c.id);
        assert_eq!(da.len(), db.len());
        for (x, y) in da.iter().zip(&db) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.text, y.text, "sharing must be bitwise invisible");
            assert_eq!(x.new_tokens, y.new_tokens);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
        let s_on = on.stats(0);
        let s_off = off.stats(0);
        assert!(s_on.prefix_hits >= 5, "later requests must hit the index");
        assert!(s_on.prefix_pages_shared > 0);
        assert!(s_on.prefix_bytes_saved > 0);
        assert_eq!(s_off.prefix_hits, 0);
        assert!(
            s_on.tokens < s_off.tokens,
            "shared admission must skip prefill rows ({} vs {})",
            s_on.tokens,
            s_off.tokens
        );
        assert!(s_on.kv_admit_bytes_per_seq < s_off.kv_admit_bytes_per_seq / 2.0);
    }

    #[test]
    fn bucketed_token_quantiles_stay_within_one_bucket_of_exact() {
        // Parity pin for the bounded sketch that replaced the unbounded
        // per-token Vec<f64>: p50/p99 within one log2 bucket ([0.75,
        // 1.5]×) of the exact sorted-sample quantiles at small n, and the
        // EWMA bitwise-matching the old vector fold (same arrival order).
        let mut w = worker(ShardConfig::default());
        let samples = [0.3, 0.5, 0.9, 1.7, 2.2, 3.8, 7.5, 12.0, 31.0];
        for &ms in &samples {
            w.record_token_ms(ms, 1);
        }
        let s = w.stats(0);
        let exact = |q: f64| {
            let mut v = samples.to_vec();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[((v.len() - 1) as f64 * q).round() as usize]
        };
        assert!(
            s.p50_token_ms >= 0.74 * exact(0.5) && s.p50_token_ms <= 1.51 * exact(0.5),
            "p50 {} vs exact {}",
            s.p50_token_ms,
            exact(0.5)
        );
        assert!(
            s.p99_token_ms >= 0.74 * exact(0.99) && s.p99_token_ms <= 1.51 * exact(0.99),
            "p99 {} vs exact {}",
            s.p99_token_ms,
            exact(0.99)
        );
        let alpha = crate::serve::supervisor::EWMA_ALPHA;
        let want = samples
            .iter()
            .fold(None, |acc, &ms| match acc {
                None => Some(ms),
                Some(prev) => Some((1.0 - alpha) * prev + alpha * ms),
            })
            .unwrap();
        assert!((s.ewma_token_ms.unwrap() - want).abs() < 1e-12);
    }

    #[test]
    fn duplicate_in_flight_ids_and_oversized_prompts_are_rejected() {
        // slots=2: request 7 is still in flight (lane 0) when its
        // duplicate reaches admission in the same scheduling round.
        let mut w = worker(ShardConfig { slots: 2, ..ShardConfig::default() });
        w.submit(req(7, b"first#", 4));
        w.submit(req(7, b"second#", 4));
        w.submit(req(8, &[b'L'; 600], 4)); // prompt beyond seq_max=512
        let done = w.run().unwrap();
        assert_eq!(done.len(), 3);
        let dup: Vec<_> = done.iter().filter(|c| c.id == 7).collect();
        assert_eq!(dup.len(), 2);
        assert!(dup.iter().any(|c| c.new_tokens == 0), "duplicate rejected");
        assert!(dup.iter().any(|c| c.new_tokens >= 1), "original served");
        assert_eq!(done.iter().find(|c| c.id == 8).unwrap().new_tokens, 0);
        assert_eq!(w.stats(0).rejected, 2);
    }
}
