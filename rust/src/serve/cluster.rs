//! Sharded decode cluster: N supervised shard workers behind a
//! hash-on-request-id router with deadline-aware admission.
//!
//! ```text
//!                    ┌──────────────────────────────────────────────┐
//!  submit(req) ──────│ router: shard = mix(req.id) % N              │
//!     │              │ admission: EWMA·(backlog+cost) vs deadline   │
//!     ▼ shed?        └──┬───────────────┬───────────────┬───────────┘
//!            bounded    │               │               │   sync_channel(queue_depth)
//!            queues ─▶  ▼               ▼               ▼   (full ⇒ retry w/ backoff)
//!                 ┌───────────┐   ┌───────────┐   ┌───────────┐
//!                 │ shard 0   │   │ shard 1   │   │ shard N−1 │  one thread each,
//!                 │ worker    │   │ worker    │   │ worker    │  catch_unwind +
//!                 └─────┬─────┘   └─────┬─────┘   └─────┬─────┘  heartbeat
//!                       └── supervisor: respawn + journal replay ──┘
//! ```
//!
//! Each worker thread owns its whole serving state — `PagedKvCache`,
//! per-lane `AttnEngine`s, `TokenModel` — so there is no shared mutable
//! state and no lock anywhere on the decode path. The submission queues
//! are bounded `sync_channel`s: a full shard pushes back on the submitter
//! instead of buffering unboundedly. The [`crate::serve::supervisor`]
//! layer makes shard death survivable: panicked or stalled workers are
//! respawned and their journaled requests replayed, bitwise exactly.
//!
//! Admission is deadline-aware rather than blind: a request carrying
//! [`Request::deadline_ms`] is shed up front when its shard's smoothed
//! per-pass latency (EWMA) times the outstanding work says the deadline
//! cannot be met — [`Admission::ShedDeadline`], counted separately from
//! [`Admission::ShedCapacity`] (bounded retries against a persistently
//! full queue). Deadline-less requests never shed: they block, which is
//! the classic backpressure contract.
//!
//! Placement never changes tokens: sequences are independent (own cache
//! slot, own sampling stream), so on any trace of unique request ids an
//! N-shard cluster is bitwise identical to the single-worker server —
//! sharding buys wall-clock only. Pinned by `rust/tests/cluster_serve.rs`
//! and (under injected faults) `rust/tests/fault_tolerance.rs`.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::Result;

use crate::json::Json;
use crate::telemetry::{Counter, Histogram, Telemetry};

use super::model::TokenModel;
use super::shard::{ShardConfig, ShardStats};
use super::supervisor::{SendOutcome, Supervisor, SupervisorConfig};
use super::{Completion, Request};

/// Cluster-level knobs.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Shard worker count.
    pub shards: usize,
    /// Bounded submission-queue depth per shard (backpressure threshold).
    pub queue_depth: usize,
    /// Per-shard serving config.
    pub shard: ShardConfig,
    /// Supervision: stall timeout, restart budget, submit retry policy.
    pub supervisor: SupervisorConfig,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig {
            shards: 4,
            queue_depth: 64,
            shard: ShardConfig::default(),
            supervisor: SupervisorConfig::default(),
        }
    }
}

impl ClusterConfig {
    /// Reflect the full cluster shape for the telemetry snapshot's
    /// `config.cluster` section.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::Num(self.shards as f64)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            (
                "shard",
                Json::obj(vec![
                    ("slots", Json::Num(self.shard.slots as f64)),
                    ("seq_max", Json::Num(self.shard.seq_max as f64)),
                    ("sample_seed", Json::Num(self.shard.sample_seed as f64)),
                    ("prefix_share", Json::Bool(self.shard.prefix_share)),
                    ("prefix_cap", Json::Num(self.shard.prefix_cap as f64)),
                    (
                        "kv_spill_dir",
                        match &self.shard.kv_spill {
                            Some(s) => Json::Str(s.dir.display().to_string()),
                            None => Json::Null,
                        },
                    ),
                    (
                        "kv_spill_budget_bytes",
                        match &self.shard.kv_spill {
                            Some(s) => Json::Num(s.budget_bytes as f64),
                            None => Json::Null,
                        },
                    ),
                    ("attn", self.shard.attn.to_json()),
                ]),
            ),
            (
                "supervisor",
                Json::obj(vec![
                    ("stall_timeout_ms", Json::Num(self.supervisor.stall_timeout_ms)),
                    ("max_restarts", Json::Num(self.supervisor.max_restarts as f64)),
                    ("submit_retries", Json::Num(self.supervisor.submit_retries as f64)),
                    ("retry_backoff_us", Json::Num(self.supervisor.retry_backoff_us as f64)),
                ]),
            ),
        ])
    }
}

/// Outcome of a [`DecodeCluster::submit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Queued on its shard (the only outcome for deadline-less requests).
    Accepted,
    /// Shed at admission: the shard's EWMA latency estimate says the
    /// request's deadline cannot be met. Never returned for requests
    /// without a deadline, and never before a first latency sample
    /// exists (a cold estimator admits — it has no evidence to shed on).
    ShedDeadline,
    /// Shed after bounded retries against a persistently full shard
    /// queue (deadline-carrying requests only; deadline-less requests
    /// keep blocking instead).
    ShedCapacity,
}

/// Post-drain cluster report.
#[derive(Clone, Debug)]
pub struct ClusterStats {
    pub shards: Vec<ShardStats>,
    /// Requests shed at admission because their deadline was infeasible
    /// under the EWMA completion-time estimate.
    pub shed_deadline: usize,
    /// Deadline-carrying requests shed after exhausting bounded
    /// full-queue retries (distinct from backpressure, which blocks).
    pub shed_capacity: usize,
    /// try-send retries performed across all blocking submits.
    pub submit_retries: usize,
    /// Shard incarnations beyond the first (supervisor respawns).
    pub restarts: usize,
    /// Requests re-sent to respawned shards from the journals.
    pub replayed_requests: usize,
    /// Forward passes that died with lost incarnations and were re-run
    /// during replay (the compute cost of recovery).
    pub recomputed_passes: usize,
}

impl ClusterStats {
    /// Forward passes summed over shards.
    pub fn total_tokens(&self) -> usize {
        self.shards.iter().map(|s| s.tokens).sum()
    }

    pub fn total_requests(&self) -> usize {
        self.shards.iter().map(|s| s.requests).sum()
    }

    /// Requests shed at admission, either way.
    pub fn total_shed(&self) -> usize {
        self.shed_deadline + self.shed_capacity
    }

    /// Quantized-query cache (hits, misses) summed over every shard's
    /// lane engines.
    pub fn qcache_totals(&self) -> (u64, u64) {
        self.shards.iter().fold((0, 0), |(h, m), s| (h + s.qcache_hits, m + s.qcache_misses))
    }

    /// Worst shard p99 per-token latency (ms) — the cluster's tail.
    /// Well-defined on an empty drain: 0.0, never NaN.
    pub fn p99_token_ms(&self) -> f64 {
        self.shards
            .iter()
            .map(|s| s.p99_token_ms)
            .filter(|v| v.is_finite())
            .fold(0.0, f64::max)
    }

    /// Mean of the shards' final per-pass latency EWMAs; `None` when no
    /// shard served a single pass (never NaN).
    pub fn ewma_token_ms(&self) -> Option<f64> {
        let vals: Vec<f64> =
            self.shards.iter().filter_map(|s| s.ewma_token_ms).filter(|v| v.is_finite()).collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f64>() / vals.len() as f64)
        }
    }

    /// Peak KV bytes summed over shards.
    pub fn kv_bytes_peak(&self) -> usize {
        self.shards.iter().map(|s| s.kv_bytes_peak).sum()
    }

    /// Prefix-sharing totals summed over shards:
    /// `(lookup_hits, pages_shared, bytes_saved, cow_splits)`.
    pub fn prefix_totals(&self) -> (u64, u64, u64, u64) {
        self.shards.iter().fold((0, 0, 0, 0), |(h, p, b, c), s| {
            (
                h + s.prefix_hits,
                p + s.prefix_pages_shared,
                b + s.prefix_bytes_saved,
                c + s.prefix_cow_splits,
            )
        })
    }

    /// Sealed pages spilled to disk, summed over shards.
    pub fn spilled_pages(&self) -> u64 {
        self.shards.iter().map(|s| s.spilled_pages).sum()
    }

    /// Request-weighted mean admission wall time (ms) across shards;
    /// `None` when no requests were admitted anywhere.
    pub fn admit_ms_mean(&self) -> Option<f64> {
        let reqs: usize = self.shards.iter().map(|s| s.requests).sum();
        if reqs == 0 {
            return None;
        }
        let sum: f64 =
            self.shards.iter().map(|s| s.admit_ms_mean * s.requests as f64).sum();
        Some(sum / reqs as f64)
    }

    /// Request-weighted mean freshly-allocated KV bytes per admitted
    /// sequence — the headline prefix-sharing memory metric. `None`
    /// when no requests were admitted.
    pub fn kv_admit_bytes_per_seq(&self) -> Option<f64> {
        let reqs: usize = self.shards.iter().map(|s| s.requests).sum();
        if reqs == 0 {
            return None;
        }
        let sum: f64 =
            self.shards.iter().map(|s| s.kv_admit_bytes_per_seq * s.requests as f64).sum();
        Some(sum / reqs as f64)
    }
}

/// SplitMix64 step (shared with [`crate::rng`]) — the request-id router
/// hash. Consecutive ids spread uniformly instead of striding the shards
/// in lockstep.
fn mix_id(id: u64) -> u64 {
    let mut state = id;
    crate::rng::splitmix64(&mut state)
}

/// Pre-registered `serve.cluster.*` counters (admission outcomes) and
/// `serve.slo.*` shed-accuracy accounting (resolved at drain).
struct ClusterProbes {
    submitted: Counter,
    shed_deadline: Counter,
    shed_capacity: Counter,
    submit_retries: Counter,
    /// Deadline-carrying completions that met their deadline.
    slo_met: Counter,
    /// Admitted as feasible, yet missed the deadline — the EWMA
    /// prediction was wrong in the optimistic direction.
    slo_false_admit: Counter,
    /// Shed as infeasible although, at the shard's *final* EWMA, the
    /// request's own cost alone would have fit — wrong in the
    /// pessimistic direction (backlog or a cold-hot estimator).
    slo_false_shed: Counter,
    /// deadline − wall for met deadlines, ms.
    slo_slack_ms: Histogram,
    /// wall − deadline for missed deadlines, ms.
    slo_overrun_ms: Histogram,
}

/// The sharded decode cluster (see module docs).
pub struct DecodeCluster {
    cfg: ClusterConfig,
    sup: Supervisor,
    telemetry: Telemetry,
    probes: ClusterProbes,
    submitted: usize,
    shed_deadline: usize,
    shed_capacity: usize,
    submit_retries: usize,
    /// `(id, deadline_ms)` of accepted deadline-carrying requests —
    /// matched against completions at drain for slack / false-admit.
    slo_admitted: Vec<(u64, f64)>,
    /// `(shard, deadline_ms, own_passes)` of deadline sheds — re-judged
    /// against the shard's final EWMA at drain for false-shed.
    slo_shed: Vec<(usize, f64, usize)>,
}

impl DecodeCluster {
    /// Spawn `cfg.shards` supervised worker threads. `model_factory
    /// (shard_id)` builds each shard's private [`TokenModel`] — build
    /// from one seed for a homogeneous cluster (every shard then holds
    /// bitwise-identical weights). The factory is retained: the
    /// supervisor re-invokes it to respawn a dead or stalled shard, so
    /// it must rebuild an identical model (same seed ⇒ replay is exact).
    ///
    /// Observability comes on by default (a fresh enabled [`Telemetry`]
    /// domain); use [`DecodeCluster::spawn_observed`] to share a domain
    /// with the caller or to serve with telemetry disabled.
    pub fn spawn<F>(cfg: ClusterConfig, model_factory: F) -> DecodeCluster
    where
        F: Fn(usize) -> Box<dyn TokenModel> + 'static,
    {
        DecodeCluster::spawn_observed(cfg, Telemetry::new(), model_factory)
    }

    /// [`DecodeCluster::spawn`] publishing into a caller-owned
    /// [`Telemetry`] domain. The caller keeps a clone of `telemetry` to
    /// read snapshots during the run and after [`DecodeCluster::drain`]
    /// (which consumes the cluster).
    pub fn spawn_observed<F>(
        cfg: ClusterConfig,
        telemetry: Telemetry,
        model_factory: F,
    ) -> DecodeCluster
    where
        F: Fn(usize) -> Box<dyn TokenModel> + 'static,
    {
        assert!(cfg.shards > 0, "cluster needs at least one shard");
        assert!(cfg.queue_depth > 0, "queue depth must be positive");
        telemetry.set_config("cluster", cfg.to_json());
        let reg = telemetry.registry();
        let probes = ClusterProbes {
            submitted: reg.counter("serve.cluster.submitted"),
            shed_deadline: reg.counter("serve.cluster.shed_deadline"),
            shed_capacity: reg.counter("serve.cluster.shed_capacity"),
            submit_retries: reg.counter("serve.cluster.submit_retries"),
            slo_met: reg.counter("serve.slo.deadlines_met"),
            slo_false_admit: reg.counter("serve.slo.false_admit"),
            slo_false_shed: reg.counter("serve.slo.false_shed"),
            slo_slack_ms: reg.histogram("serve.slo.slack_ms"),
            slo_overrun_ms: reg.histogram("serve.slo.overrun_ms"),
        };
        let sup = Supervisor::new(
            cfg.shards,
            cfg.queue_depth,
            cfg.shard.clone(),
            cfg.supervisor,
            telemetry.clone(),
            Box::new(model_factory),
        );
        DecodeCluster {
            cfg,
            sup,
            telemetry,
            probes,
            submitted: 0,
            shed_deadline: 0,
            shed_capacity: 0,
            submit_retries: 0,
            slo_admitted: Vec::new(),
            slo_shed: Vec::new(),
        }
    }

    /// The cluster's observability domain (clone it to keep reading after
    /// drain).
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// One schema-versioned JSON document reflecting live config, the
    /// full metric registry (per-shard queue depths, throughput, tail
    /// latency, qcache hit rates, KV occupancy, supervisor health), and
    /// the span summary — [`Telemetry::snapshot`] over the cluster's
    /// domain. The shape is pinned by `rust/tests/telemetry.rs`.
    pub fn introspect(&self) -> Json {
        self.telemetry.snapshot()
    }

    /// Which shard serves request id `id`.
    pub fn route(&self, id: u64) -> usize {
        (mix_id(id) % self.cfg.shards as u64) as usize
    }

    /// Live smoothed per-pass latency of `shard` (None until its worker
    /// has completed a first step) — the admission estimator's input,
    /// exposed so callers can wait for a warm estimator in tests/drivers.
    pub fn token_latency_ewma(&self, shard: usize) -> Option<f64> {
        self.sup.ewma_token_ms(shard)
    }

    /// Estimated completion time (ms) for `req` on `shard`: smoothed
    /// per-pass latency × (journaled backlog + this request's own prompt
    /// rows and token budget). `None` while the estimator is cold.
    /// Conservative: early-terminating sequences finish sooner.
    fn estimate_ms(&self, shard: usize, req: &Request) -> Option<f64> {
        let ewma = self.sup.ewma_token_ms(shard)?;
        let cost = req.prompt.len().max(1) + req.max_new_tokens;
        Some(ewma * (self.sup.backlog_passes(shard) + cost) as f64)
    }

    /// Submit a request to its shard. Deadline-less requests **block**
    /// while the shard's queue is full (backpressure); requests carrying
    /// [`Request::deadline_ms`] are shed instead when infeasible —
    /// either up front ([`Admission::ShedDeadline`], EWMA estimate over
    /// the deadline) or after bounded full-queue retries with
    /// exponential backoff ([`Admission::ShedCapacity`]). Either way the
    /// submit path runs supervision: a dead or stalled shard is healed
    /// before and during the retry loop, so a fault never turns into a
    /// submission error until the restart budget is truly exhausted
    /// (the only `Err` case).
    pub fn submit(&mut self, req: Request) -> Result<Admission> {
        let shard = self.route(req.id);
        let spans = self.telemetry.spans().clone();
        // Root of this request's trace: everything downstream — route,
        // queue wait, admit/prefill, sampled decode, finish, even a
        // post-fault replay — parents back to this span, across threads,
        // via the context copied into `Request::trace`.
        let root = spans.start_root("request", "req", req.id);
        let mut req = req;
        req.trace = root.context();
        let own_passes = req.prompt.len().max(1) + req.max_new_tokens;
        let _span = crate::span!(spans, "route", shard = shard);
        self.sup.check(shard)?;
        if self.infeasible(shard, &req) {
            self.shed_deadline += 1;
            self.probes.shed_deadline.inc();
            if let Some(dl) = req.deadline_ms {
                self.slo_shed.push((shard, dl, own_passes));
            }
            return Ok(Admission::ShedDeadline);
        }
        let mut attempts = 0usize;
        loop {
            let (id, deadline) = (req.id, req.deadline_ms);
            match self.sup.try_send(shard, req) {
                SendOutcome::Sent => {
                    self.submitted += 1;
                    self.probes.submitted.inc();
                    if let Some(dl) = deadline {
                        self.slo_admitted.push((id, dl));
                    }
                    return Ok(Admission::Accepted);
                }
                SendOutcome::Full(r) | SendOutcome::Gone(r) => {
                    req = r;
                    attempts += 1;
                    self.submit_retries += 1;
                    self.probes.submit_retries.inc();
                    let sup_cfg = self.sup.config();
                    if req.deadline_ms.is_some() && attempts > sup_cfg.submit_retries {
                        self.shed_capacity += 1;
                        self.probes.shed_capacity.inc();
                        return Ok(Admission::ShedCapacity);
                    }
                    // Exponential backoff, capped at 5 ms per wait.
                    let us = (sup_cfg.retry_backoff_us << attempts.min(6) as u64).min(5_000);
                    std::thread::sleep(Duration::from_micros(us));
                    // Heal the shard before retrying (a `Gone` outcome is
                    // a dead worker — check() respawns + replays it).
                    self.sup.check(shard)?;
                    // The wait may have made the deadline infeasible.
                    if self.infeasible(shard, &req) {
                        self.shed_deadline += 1;
                        self.probes.shed_deadline.inc();
                        if let Some(dl) = req.deadline_ms {
                            self.slo_shed.push((shard, dl, own_passes));
                        }
                        return Ok(Admission::ShedDeadline);
                    }
                }
            }
        }
    }

    fn infeasible(&self, shard: usize, req: &Request) -> bool {
        match (req.deadline_ms, self.estimate_ms(shard, req)) {
            (Some(deadline), Some(est)) => est > deadline,
            _ => false,
        }
    }

    /// Non-blocking capacity probe: hands the request back if the
    /// shard's queue is full right now (callers implement their own
    /// retry/shedding policy — deadline admission is `submit`'s job).
    /// Runs supervision first, so a dead shard is healed rather than an
    /// error.
    pub fn try_submit(&mut self, req: Request) -> Result<Option<Request>> {
        let shard = self.route(req.id);
        self.sup.check(shard)?;
        match self.sup.try_send(shard, req) {
            SendOutcome::Sent => {
                self.submitted += 1;
                self.probes.submitted.inc();
                Ok(None)
            }
            SendOutcome::Full(r) | SendOutcome::Gone(r) => Ok(Some(r)),
        }
    }

    /// Requests accepted so far (shed requests are not counted).
    pub fn submitted(&self) -> usize {
        self.submitted
    }

    /// Graceful drain: every shard finishes its queued and in-flight
    /// sequences, then reports. Returns all completions (sorted by
    /// request id) and the per-shard + recovery statistics. The drain is
    /// supervised: a shard that dies or stalls mid-drain is respawned
    /// and replayed like any other fault; only a shard past its restart
    /// budget surfaces its error (after every other shard is collected).
    pub fn drain(self) -> Result<(Vec<Completion>, ClusterStats)> {
        let (shed_deadline, shed_capacity, submit_retries) =
            (self.shed_deadline, self.shed_capacity, self.submit_retries);
        let spans = self.telemetry.spans().clone();
        let _span = crate::span!(spans, "drain");
        let report = self.sup.drain()?;
        let mut shards = report.shards;
        shards.sort_by_key(|s| s.shard);
        let mut completions = report.completions;
        completions.sort_by_key(|c| c.id);
        // SLO accounting: close the loop on the EWMA feasibility
        // prediction made at admission. Admitted deadline-carriers are
        // judged by realized wall time (slack histogram + false-admit);
        // deadline sheds are re-judged with hindsight — if the shard's
        // *final* EWMA says the request's own cost alone fit the
        // deadline, the shed was backlog- or cold-estimator-driven and
        // counts as a false shed.
        let deadline_of: BTreeMap<u64, f64> = self.slo_admitted.iter().copied().collect();
        for c in &completions {
            if let Some(&dl) = deadline_of.get(&c.id) {
                let slack = dl - c.wall_ms;
                if slack >= 0.0 {
                    self.probes.slo_met.inc();
                    self.probes.slo_slack_ms.record(slack);
                } else {
                    self.probes.slo_false_admit.inc();
                    self.probes.slo_overrun_ms.record(-slack);
                }
            }
        }
        for &(shard, dl, own_passes) in &self.slo_shed {
            let hindsight = shards.iter().find(|s| s.shard == shard).and_then(|s| s.ewma_token_ms);
            if let Some(ewma) = hindsight {
                if ewma * own_passes as f64 <= dl {
                    self.probes.slo_false_shed.inc();
                }
            }
        }
        Ok((
            completions,
            ClusterStats {
                shards,
                shed_deadline,
                shed_capacity,
                submit_retries,
                restarts: report.restarts,
                replayed_requests: report.replayed,
                recomputed_passes: report.recomputed_passes,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn router_is_stable_and_covers_shards() {
        let cluster = DecodeCluster::spawn(
            ClusterConfig { shards: 4, ..ClusterConfig::default() },
            |_| Box::new(crate::serve::model::SimLm::new(Default::default())),
        );
        let mut seen = [false; 4];
        for id in 0..64u64 {
            let s = cluster.route(id);
            assert_eq!(s, cluster.route(id), "routing must be deterministic");
            seen[s] = true;
        }
        assert!(seen.iter().all(|&s| s), "64 ids should touch all 4 shards");
        let (done, stats) = cluster.drain().unwrap();
        assert!(done.is_empty());
        assert_eq!(stats.total_requests(), 0);
    }

    #[test]
    fn empty_drain_has_well_defined_stats() {
        // Satellite fix: an empty drain must report 0.0 / None, not NaN.
        let cluster = DecodeCluster::spawn(ClusterConfig::default(), |_| {
            Box::new(crate::serve::model::SimLm::new(Default::default()))
        });
        assert_eq!(cluster.token_latency_ewma(0), None, "cold estimator");
        let (done, stats) = cluster.drain().unwrap();
        assert!(done.is_empty());
        assert_eq!(stats.shards.len(), 4);
        let p99 = stats.p99_token_ms();
        assert!(!p99.is_nan());
        assert_eq!(p99, 0.0);
        assert_eq!(stats.ewma_token_ms(), None);
        for s in &stats.shards {
            assert_eq!(s.ewma_token_ms, None, "no passes served ⇒ no EWMA");
        }
        assert_eq!((stats.shed_deadline, stats.shed_capacity), (0, 0));
        assert_eq!((stats.restarts, stats.replayed_requests), (0, 0));
    }
}
