//! Sharded decode cluster: N independent shard workers behind a
//! hash-on-request-id router.
//!
//! ```text
//!                    ┌──────────────────────────────────────────────┐
//!  submit(req) ──────│ router: shard = mix(req.id) % N              │
//!                    └──┬───────────────┬───────────────┬───────────┘
//!            bounded    │               │               │   sync_channel(queue_depth)
//!            queues ─▶  ▼               ▼               ▼   (full ⇒ submit blocks)
//!                 ┌───────────┐   ┌───────────┐   ┌───────────┐
//!                 │ shard 0   │   │ shard 1   │   │ shard N−1 │  one thread each
//!                 │ worker    │   │ worker    │   │ worker    │
//!                 └───────────┘   └───────────┘   └───────────┘
//! ```
//!
//! Each worker thread owns its whole serving state — `PagedKvCache`,
//! per-lane `AttnEngine`s, `TokenModel` — so there is no shared mutable
//! state and no lock anywhere on the decode path. The submission queues
//! are bounded `sync_channel`s: a full shard pushes back on the submitter
//! instead of buffering unboundedly. [`DecodeCluster::drain`] delivers a
//! drain marker to every shard, lets them finish queued + in-flight work,
//! and joins them into the pooled completions and [`ClusterStats`].
//!
//! Placement never changes tokens: sequences are independent (own cache
//! slot, own sampling stream), so on any trace of unique request ids an
//! N-shard cluster is bitwise identical to the single-worker server —
//! sharding buys wall-clock only. Pinned by `rust/tests/cluster_serve.rs`.

use std::sync::mpsc::{Receiver, sync_channel, SyncSender, TrySendError};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use super::model::TokenModel;
use super::shard::{ShardConfig, ShardStats, ShardWorker};
use super::{Completion, Request};

/// Cluster-level knobs.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// Shard worker count.
    pub shards: usize,
    /// Bounded submission-queue depth per shard (backpressure threshold).
    pub queue_depth: usize,
    /// Per-shard serving config.
    pub shard: ShardConfig,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig { shards: 4, queue_depth: 64, shard: ShardConfig::default() }
    }
}

/// Post-drain cluster report.
#[derive(Clone, Debug)]
pub struct ClusterStats {
    pub shards: Vec<ShardStats>,
}

impl ClusterStats {
    /// Forward passes summed over shards.
    pub fn total_tokens(&self) -> usize {
        self.shards.iter().map(|s| s.tokens).sum()
    }

    pub fn total_requests(&self) -> usize {
        self.shards.iter().map(|s| s.requests).sum()
    }

    /// Quantized-query cache (hits, misses) summed over every shard's
    /// lane engines.
    pub fn qcache_totals(&self) -> (u64, u64) {
        self.shards.iter().fold((0, 0), |(h, m), s| (h + s.qcache_hits, m + s.qcache_misses))
    }

    /// Worst shard p99 per-token latency (ms) — the cluster's tail.
    pub fn p99_token_ms(&self) -> f64 {
        self.shards.iter().map(|s| s.p99_token_ms).fold(0.0, f64::max)
    }

    /// Peak KV bytes summed over shards.
    pub fn kv_bytes_peak(&self) -> usize {
        self.shards.iter().map(|s| s.kv_bytes_peak).sum()
    }
}

enum ShardMsg {
    Req(Request),
    Drain,
}

/// SplitMix64 step (shared with [`crate::rng`]) — the request-id router
/// hash. Consecutive ids spread uniformly instead of striding the shards
/// in lockstep.
fn mix_id(id: u64) -> u64 {
    let mut state = id;
    crate::rng::splitmix64(&mut state)
}

struct ShardHandle {
    tx: SyncSender<ShardMsg>,
    join: JoinHandle<Result<(Vec<Completion>, ShardStats)>>,
}

/// The sharded decode cluster (see module docs).
pub struct DecodeCluster {
    cfg: ClusterConfig,
    workers: Vec<ShardHandle>,
    submitted: usize,
}

impl DecodeCluster {
    /// Spawn `cfg.shards` worker threads. `model_factory(shard_id)` builds
    /// each shard's private [`TokenModel`] — build from one seed for a
    /// homogeneous cluster (every shard then holds bitwise-identical
    /// weights).
    pub fn spawn<F>(cfg: ClusterConfig, model_factory: F) -> DecodeCluster
    where
        F: Fn(usize) -> Box<dyn TokenModel>,
    {
        assert!(cfg.shards > 0, "cluster needs at least one shard");
        assert!(cfg.queue_depth > 0, "queue depth must be positive");
        let workers = (0..cfg.shards)
            .map(|shard_id| {
                let (tx, rx) = sync_channel::<ShardMsg>(cfg.queue_depth);
                let model = model_factory(shard_id);
                let shard_cfg = cfg.shard;
                let join = std::thread::spawn(move || shard_loop(shard_id, model, shard_cfg, rx));
                ShardHandle { tx, join }
            })
            .collect();
        DecodeCluster { cfg, workers, submitted: 0 }
    }

    /// Which shard serves request id `id`.
    pub fn route(&self, id: u64) -> usize {
        (mix_id(id) % self.cfg.shards as u64) as usize
    }

    /// Submit a request to its shard. **Blocks** while that shard's
    /// submission queue is full — the cluster's backpressure: a slow
    /// shard throttles its submitters instead of buffering without bound.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        let shard = self.route(req.id);
        let tx = &self.workers[shard].tx;
        tx.send(ShardMsg::Req(req)).map_err(|_| anyhow!("shard {shard} is gone"))?;
        self.submitted += 1;
        Ok(())
    }

    /// Non-blocking submit: hands the request back if the shard's queue
    /// is full right now (callers implement their own retry/shedding).
    pub fn try_submit(&mut self, req: Request) -> Result<Option<Request>> {
        let shard = self.route(req.id);
        match self.workers[shard].tx.try_send(ShardMsg::Req(req)) {
            Ok(()) => {
                self.submitted += 1;
                Ok(None)
            }
            Err(TrySendError::Full(ShardMsg::Req(req))) => Ok(Some(req)),
            Err(TrySendError::Full(_)) => unreachable!("only requests are try-sent"),
            Err(TrySendError::Disconnected(_)) => bail!("shard {shard} is gone"),
        }
    }

    /// Requests submitted so far.
    pub fn submitted(&self) -> usize {
        self.submitted
    }

    /// Graceful drain: every shard finishes its queued and in-flight
    /// sequences, then reports. Returns all completions (sorted by
    /// request id) and the per-shard statistics.
    ///
    /// Every shard thread is joined even when one failed; the first
    /// shard's own error (not a generic channel error) is what surfaces.
    pub fn drain(self) -> Result<(Vec<Completion>, ClusterStats)> {
        // Deliver the drain marker; a full queue blocks until the worker
        // makes room. A dead shard has dropped its receiver — the send
        // fails, and its real error is collected at join below.
        for w in &self.workers {
            let _ = w.tx.send(ShardMsg::Drain);
        }
        let mut completions = Vec::new();
        let mut shards = Vec::with_capacity(self.workers.len());
        let mut first_err = None;
        for w in self.workers {
            drop(w.tx);
            match w.join.join() {
                Ok(Ok((mut done, stats))) => {
                    completions.append(&mut done);
                    shards.push(stats);
                }
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => first_err = first_err.or_else(|| Some(anyhow!("shard thread panicked"))),
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        shards.sort_by_key(|s| s.shard);
        completions.sort_by_key(|c| c.id);
        Ok((completions, ClusterStats { shards }))
    }
}

/// One shard thread: interleave queue intake with serving steps. Blocks
/// on the channel only when fully idle; while busy it polls between steps
/// so mid-flight submissions join the continuous batch. Crucially it
/// pulls a request off the channel only while a lane can absorb it
/// ([`ShardWorker::wants_work`]) — the bounded channel itself is the
/// shard's queue, so `queue_depth` is a real backpressure bound rather
/// than a per-step trickle into an unbounded local buffer.
fn shard_loop(
    shard_id: usize,
    model: Box<dyn TokenModel>,
    cfg: ShardConfig,
    rx: Receiver<ShardMsg>,
) -> Result<(Vec<Completion>, ShardStats)> {
    let mut w = ShardWorker::new(model, cfg);
    let mut draining = false;
    loop {
        // Idle and not draining: nothing to do until a message arrives.
        if w.is_idle() && !draining {
            match rx.recv() {
                Ok(ShardMsg::Req(req)) => w.submit(req),
                Ok(ShardMsg::Drain) | Err(_) => draining = true,
            }
        }
        // Lane-bounded intake. The drain marker trails every request in
        // channel order, so stopping at full lanes never strands it.
        while !draining && w.wants_work() {
            match rx.try_recv() {
                Ok(ShardMsg::Req(req)) => w.submit(req),
                Ok(ShardMsg::Drain) => draining = true,
                Err(_) => break, // empty or disconnected
            }
        }
        if w.is_idle() {
            if draining {
                break;
            }
            continue;
        }
        w.step()?;
    }
    let done = w.take_done();
    let stats = w.stats(shard_id);
    Ok((done, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn router_is_stable_and_covers_shards() {
        let cluster = DecodeCluster::spawn(
            ClusterConfig { shards: 4, ..ClusterConfig::default() },
            |_| Box::new(crate::serve::model::SimLm::new(Default::default())),
        );
        let mut seen = [false; 4];
        for id in 0..64u64 {
            let s = cluster.route(id);
            assert_eq!(s, cluster.route(id), "routing must be deterministic");
            seen[s] = true;
        }
        assert!(seen.iter().all(|&s| s), "64 ids should touch all 4 shards");
        let (done, stats) = cluster.drain().unwrap();
        assert!(done.is_empty());
        assert_eq!(stats.total_requests(), 0);
    }

    #[test]
    fn empty_drain_does_not_hang() {
        let cluster = DecodeCluster::spawn(ClusterConfig::default(), |_| {
            Box::new(crate::serve::model::SimLm::new(Default::default()))
        });
        let (done, stats) = cluster.drain().unwrap();
        assert!(done.is_empty());
        assert_eq!(stats.shards.len(), 4);
    }
}
