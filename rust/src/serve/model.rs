//! Pluggable token models for the native serving path.
//!
//! The decode cluster separates *what produces Q/K/V and logits* from *how
//! attention over the FP4 paged cache is scheduled*: a [`TokenModel`] owns
//! the non-attention compute (embedding, projections, residual mixing, the
//! LM head) while the shard worker owns the cache, the per-slot
//! [`crate::attention::AttnEngine`]s, and the batching loop. The compiled
//! PJRT artifacts fill the same role for `DecodeServer`; [`SimLm`] is the
//! native default — a deterministic simulated byte-LM built from seeded
//! random weights, so the whole serving stack runs, tests, and benchmarks
//! **without any compiled artifact or PJRT backend**. A
//! [`crate::model::QatModel`] finetuned by `model::TrainSession`
//! implements the same trait (sharing these row kernels via
//! `model::modules`), which is how trained weights reach the cluster.
//!
//! The per-token contract mirrors a pre-norm transformer step:
//!
//! ```text
//! h = embed(token, pos)
//! for layer l:  (q, k, v) = qkv(l, norm(h))     # worker appends k, v
//!               attn       = engine.decode(...)  # FP4 paged attention
//!               h          = mix(l, h, attn)     # Wo residual + MLP
//! logits = logits(norm(h))
//! ```
//!
//! All methods take `&self` and implementations must be `Send`, so one
//! model instance can be moved into a shard worker thread (each shard
//! builds its own from the same seed — weights are bitwise identical).

use crate::model::modules::{rms_norm, vec_mat_acc};
use crate::rng::Rng;

/// Byte-level vocabulary: the serving path speaks raw bytes end to end.
pub const VOCAB: usize = 256;

/// The non-attention compute of one decoder step, batched over rows.
///
/// `h`, `q`, `k`, `v`, `attn` buffers are `(rows × d_model)` row-major
/// with heads concatenated along the feature axis (`d_model = heads ×
/// head_dim`), matching the layouts `AttnEngine::decode` expects for a
/// single row. Multi-row calls serve batched prompt prefill.
///
/// The trait is also the cluster's **fault-injection seam**:
/// [`crate::serve::FaultPlan::wrap`] interposes a wrapper that counts
/// forward passes in [`TokenModel::embed`] — called exactly once per
/// pass (one batched call per prefill, one per decode step) — and fires
/// seeded panics/stalls at exact pass numbers for the recovery tests.
pub trait TokenModel: Send {
    /// Transformer layers (== KV-cache layers).
    fn layers(&self) -> usize;
    /// Attention heads per layer.
    fn heads(&self) -> usize;
    /// Per-head feature width (multiple of 16 for the FP4 cache).
    fn head_dim(&self) -> usize;
    /// Model width; always `heads × head_dim`.
    fn d_model(&self) -> usize {
        self.heads() * self.head_dim()
    }

    /// Embed `tokens[i]` at absolute position `pos0 + i` into row `i` of
    /// `h` (`tokens.len() × d_model`).
    fn embed(&self, tokens: &[u8], pos0: usize, h: &mut [f32]);

    /// Project hidden rows into per-layer Q/K/V rows (each `rows ×
    /// d_model`, heads concatenated). Implementations normalize
    /// internally if their architecture calls for it.
    fn qkv(&self, layer: usize, h: &[f32], q: &mut [f32], k: &mut [f32], v: &mut [f32]);

    /// Post-attention mixing for `layer`: fold the attention rows back
    /// into `h` (output projection residual + feed-forward residual).
    fn mix(&self, layer: usize, h: &mut [f32], attn: &[f32]);

    /// Next-token logits for one hidden row (`d_model` → [`VOCAB`]).
    fn logits(&self, h: &[f32], logits: &mut [f32]);
}

/// Configuration of the [`SimLm`] simulated byte-LM.
#[derive(Clone, Copy, Debug)]
pub struct SimLmConfig {
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// Feed-forward width (default `2 × d_model`).
    pub ff: usize,
    /// Positional-embedding table length (positions wrap past it).
    pub max_pos: usize,
    /// Weight seed: equal seeds ⇒ bitwise-identical models, which is what
    /// lets every shard build its own copy.
    pub seed: u64,
    /// Tie all heads' Q projections to head 0's (a GQA-style shared
    /// query). Every head of a decode step then quantizes the *same*
    /// query row, which the quantized-query cache serves from residency —
    /// the deterministic hit pattern the cluster's cache tests pin.
    pub tied_q: bool,
}

impl Default for SimLmConfig {
    fn default() -> SimLmConfig {
        SimLmConfig {
            layers: 2,
            heads: 2,
            head_dim: 16,
            ff: 64,
            max_pos: 512,
            seed: 0xa77,
            tied_q: false,
        }
    }
}

/// Deterministic simulated byte-LM: seeded random weights in a pre-norm
/// transformer shape. It has nothing to *say* — what matters is that it
/// exercises the real serving dataflow (per-layer Q/K/V into the FP4
/// paged cache, per-slot engines, logits, sampling) with reproducible
/// floats, natively.
pub struct SimLm {
    cfg: SimLmConfig,
    /// (VOCAB × d) token embeddings.
    tok_emb: Vec<f32>,
    /// (max_pos × d) positional embeddings.
    pos_emb: Vec<f32>,
    /// Per-layer stacked (L × d × d) projections.
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    /// Per-layer MLP: (L × d × ff) in, (L × ff × d) out.
    win: Vec<f32>,
    wout: Vec<f32>,
    /// (d × VOCAB) LM head.
    whead: Vec<f32>,
}

impl SimLm {
    pub fn new(cfg: SimLmConfig) -> SimLm {
        assert!(cfg.layers > 0 && cfg.heads > 0, "need at least one layer and head");
        assert_eq!(cfg.head_dim % 16, 0, "head_dim must be a multiple of 16");
        assert!(cfg.max_pos > 0 && cfg.ff > 0);
        let d = cfg.heads * cfg.head_dim;
        let mut rng = Rng::new(cfg.seed).split("sim_lm");
        let emb_std = 0.5;
        let proj_std = 1.0 / (d as f32).sqrt();
        let ff_std = 1.0 / (cfg.ff as f32).sqrt();
        let tok_emb = rng.normal_vec(VOCAB * d, 0.0, emb_std);
        let pos_emb = rng.normal_vec(cfg.max_pos * d, 0.0, emb_std);
        let mut wq = rng.normal_vec(cfg.layers * d * d, 0.0, proj_std);
        let wk = rng.normal_vec(cfg.layers * d * d, 0.0, proj_std);
        let wv = rng.normal_vec(cfg.layers * d * d, 0.0, proj_std);
        let wo = rng.normal_vec(cfg.layers * d * d, 0.0, proj_std);
        let win = rng.normal_vec(cfg.layers * d * cfg.ff, 0.0, proj_std);
        let wout = rng.normal_vec(cfg.layers * cfg.ff * d, 0.0, ff_std);
        let whead = rng.normal_vec(d * VOCAB, 0.0, proj_std);
        if cfg.tied_q {
            // Copy head 0's Wq column block over every other head's, per
            // layer: all heads then project identical query rows.
            let hd = cfg.head_dim;
            for l in 0..cfg.layers {
                let base = l * d * d;
                for m in 0..d {
                    let row = base + m * d;
                    for h in 1..cfg.heads {
                        for c in 0..hd {
                            wq[row + h * hd + c] = wq[row + c];
                        }
                    }
                }
            }
        }
        SimLm { cfg, tok_emb, pos_emb, wq, wk, wv, wo, win, wout, whead }
    }

    pub fn config(&self) -> &SimLmConfig {
        &self.cfg
    }

    /// Layer-`l` slice of a stacked (L × rows × cols) parameter.
    fn layer<'a>(&self, stacked: &'a [f32], l: usize, rows: usize, cols: usize) -> &'a [f32] {
        &stacked[l * rows * cols..(l + 1) * rows * cols]
    }
}

impl TokenModel for SimLm {
    fn layers(&self) -> usize {
        self.cfg.layers
    }

    fn heads(&self) -> usize {
        self.cfg.heads
    }

    fn head_dim(&self) -> usize {
        self.cfg.head_dim
    }

    fn embed(&self, tokens: &[u8], pos0: usize, h: &mut [f32]) {
        let d = self.d_model();
        assert_eq!(h.len(), tokens.len() * d, "h must be (rows x d_model)");
        for (i, &tok) in tokens.iter().enumerate() {
            let row = &mut h[i * d..(i + 1) * d];
            let te = &self.tok_emb[tok as usize * d..(tok as usize + 1) * d];
            let p = (pos0 + i) % self.cfg.max_pos;
            let pe = &self.pos_emb[p * d..(p + 1) * d];
            for ((o, &t), &pv) in row.iter_mut().zip(te).zip(pe) {
                *o = t + pv;
            }
        }
    }

    fn qkv(&self, layer: usize, h: &[f32], q: &mut [f32], k: &mut [f32], v: &mut [f32]) {
        let d = self.d_model();
        let rows = h.len() / d;
        assert_eq!(h.len(), rows * d);
        assert!(q.len() == h.len() && k.len() == h.len() && v.len() == h.len());
        let (wq, wk, wv) = (
            self.layer(&self.wq, layer, d, d),
            self.layer(&self.wk, layer, d, d),
            self.layer(&self.wv, layer, d, d),
        );
        let mut xn = vec![0.0f32; d];
        for r in 0..rows {
            rms_norm(&h[r * d..(r + 1) * d], &mut xn);
            let (qr, kr, vr) = (
                &mut q[r * d..(r + 1) * d],
                &mut k[r * d..(r + 1) * d],
                &mut v[r * d..(r + 1) * d],
            );
            qr.fill(0.0);
            kr.fill(0.0);
            vr.fill(0.0);
            vec_mat_acc(&xn, wq, d, qr);
            vec_mat_acc(&xn, wk, d, kr);
            vec_mat_acc(&xn, wv, d, vr);
        }
    }

    fn mix(&self, layer: usize, h: &mut [f32], attn: &[f32]) {
        let d = self.d_model();
        let ff = self.cfg.ff;
        let rows = h.len() / d;
        assert_eq!(attn.len(), h.len());
        let wo = self.layer(&self.wo, layer, d, d);
        let win = self.layer(&self.win, layer, d, ff);
        let wout = self.layer(&self.wout, layer, ff, d);
        let mut xn = vec![0.0f32; d];
        let mut f = vec![0.0f32; ff];
        for r in 0..rows {
            let hr = &mut h[r * d..(r + 1) * d];
            // Attention output projection, residual.
            vec_mat_acc(&attn[r * d..(r + 1) * d], wo, d, hr);
            // Pre-norm tanh MLP, residual.
            rms_norm(hr, &mut xn);
            f.fill(0.0);
            vec_mat_acc(&xn, win, ff, &mut f);
            for x in f.iter_mut() {
                *x = x.tanh();
            }
            vec_mat_acc(&f, wout, d, hr);
        }
    }

    fn logits(&self, h: &[f32], logits: &mut [f32]) {
        let d = self.d_model();
        assert_eq!(h.len(), d, "logits takes one hidden row");
        assert_eq!(logits.len(), VOCAB);
        let mut xn = vec![0.0f32; d];
        rms_norm(h, &mut xn);
        logits.fill(0.0);
        vec_mat_acc(&xn, &self.whead, VOCAB, logits);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_model() {
        let a = SimLm::new(SimLmConfig::default());
        let b = SimLm::new(SimLmConfig::default());
        let c = SimLm::new(SimLmConfig { seed: 1, ..SimLmConfig::default() });
        assert_eq!(a.whead, b.whead);
        assert_ne!(a.whead, c.whead);
        assert_eq!(a.d_model(), 32);
    }

    #[test]
    fn batched_rows_match_single_rows_bitwise() {
        // Prefill feeds multi-row buffers; decode feeds one row at a time.
        // Row r of a batched call must equal the same row computed alone.
        let lm = SimLm::new(SimLmConfig::default());
        let d = lm.d_model();
        let tokens = b"AB#x";
        let mut h = vec![0.0f32; tokens.len() * d];
        lm.embed(tokens, 0, &mut h);
        let (mut q, mut k, mut v) = (h.clone(), h.clone(), h.clone());
        lm.qkv(0, &h, &mut q, &mut k, &mut v);
        for (r, &tok) in tokens.iter().enumerate() {
            let mut h1 = vec![0.0f32; d];
            lm.embed(&[tok], r, &mut h1);
            assert_eq!(&h[r * d..(r + 1) * d], &h1[..], "embed row {r}");
            let (mut q1, mut k1, mut v1) = (vec![0.0f32; d], vec![0.0f32; d], vec![0.0f32; d]);
            lm.qkv(0, &h1, &mut q1, &mut k1, &mut v1);
            assert_eq!(&q[r * d..(r + 1) * d], &q1[..], "q row {r}");
            assert_eq!(&k[r * d..(r + 1) * d], &k1[..], "k row {r}");
            assert_eq!(&v[r * d..(r + 1) * d], &v1[..], "v row {r}");
        }
    }

    #[test]
    fn tied_q_projects_identical_head_rows() {
        let lm = SimLm::new(SimLmConfig { tied_q: true, heads: 4, ..SimLmConfig::default() });
        let d = lm.d_model();
        let hd = lm.head_dim();
        let mut h = vec![0.0f32; d];
        lm.embed(b"Q", 3, &mut h);
        let (mut q, mut k, mut v) = (vec![0.0f32; d], vec![0.0f32; d], vec![0.0f32; d]);
        lm.qkv(1, &h, &mut q, &mut k, &mut v);
        for head in 1..4 {
            assert_eq!(&q[head * hd..(head + 1) * hd], &q[..hd], "head {head}");
        }
        // K stays per-head distinct (the cache still holds real per-head
        // pages — only the query is shared).
        assert_ne!(&k[hd..2 * hd], &k[..hd]);
    }

    #[test]
    fn outputs_stay_finite_through_layers() {
        // Random-weight towers can blow up without normalization; pin that
        // repeated mixing keeps the hidden state bounded.
        let lm = SimLm::new(SimLmConfig { layers: 4, ..SimLmConfig::default() });
        let d = lm.d_model();
        let mut h = vec![0.0f32; d];
        lm.embed(b"Z", 0, &mut h);
        let (mut q, mut k, mut v) = (vec![0.0f32; d], vec![0.0f32; d], vec![0.0f32; d]);
        for l in 0..4 {
            lm.qkv(l, &h, &mut q, &mut k, &mut v);
            // Stand in for attention with the V row itself.
            let attn = v.clone();
            lm.mix(l, &mut h, &attn);
        }
        assert!(h.iter().all(|x| x.is_finite()));
        let norm: f32 = h.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(norm < 1e3, "hidden norm {norm}");
        let mut logits = vec![0.0f32; VOCAB];
        lm.logits(&h, &mut logits);
        assert!(logits.iter().all(|x| x.is_finite()));
    }
}
