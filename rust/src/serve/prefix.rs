//! Prefix index: prompt bytes → already-sealed KV page runs.
//!
//! A radix trie keyed on token bytes at [`PAGE_SIZE`]-token (16-token)
//! granularity — one trie edge per full prompt chunk, so the index only
//! ever talks about whole sealed pages. Each node stores the layer-major
//! run of [`PageRef`]s for the chunk that ends at it (the same shape
//! `PagedKvCache::attach_prefix_at` consumes) and holds one pool ref per
//! page so an indexed prefix survives the sequences that built it.
//!
//! Correctness: K/V at page `p` is a pure function of tokens
//! `0 .. 16·(p+1)` and the model weights (quantization is
//! deterministic), so keying on the *full chunk path* is exact — a hit
//! can be attached without re-running prefill attention over those
//! tokens, and the decode result is bitwise identical to the unshared
//! path.
//!
//! Copy-on-write is the trie's no-op: a prompt that diverges from every
//! registered prefix simply stops matching — the worker attaches the
//! matched run and prefills only the suffix, whose first token opens a
//! private hot page. Divergence is observable as
//! [`PrefixMatch::cow_split`] (the walk stopped at a node that has other
//! continuations).
//!
//! The index is capacity-bounded: past `cap_nodes` registered chunks it
//! evicts the least-recently-touched **leaf** (deepest-first, so shared
//! trunks survive their cold tails) and releases that run's pool refs —
//! unpopular suffixes age out instead of pinning pages forever.

use std::collections::BTreeMap;

use crate::kvcache::{PagePool, PageRef, PAGE_SIZE};

/// One trie node == one registered 16-token chunk.
struct Node {
    children: BTreeMap<[u8; PAGE_SIZE], Node>,
    /// Layer-major `[layer * heads + head]` sealed refs for this chunk.
    /// Always non-empty for a registered node (set on first register).
    pages: Vec<PageRef>,
    last_touch: u64,
}

impl Node {
    fn new() -> Node {
        Node { children: BTreeMap::new(), pages: Vec::new(), last_touch: 0 }
    }
}

/// Result of a prefix lookup: the longest matching sealed run (possibly
/// empty) and whether the prompt diverged from registered continuations
/// at the match point (a copy-on-write split).
#[derive(Default)]
pub struct PrefixMatch {
    /// `pages[p]` is page `p`'s layer-major ref run.
    pub pages: Vec<Vec<PageRef>>,
    pub cow_split: bool,
}

/// Monotonic index counters plus current occupancy.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefixStats {
    pub lookups: u64,
    /// Lookups that matched at least one page.
    pub hits: u64,
    pub pages_matched: u64,
    /// Lookups that diverged from a registered continuation.
    pub cow_splits: u64,
    /// Chunks registered (nodes created).
    pub registered: u64,
    /// Chunks evicted by the capacity bound.
    pub evicted: u64,
    /// Registered chunks currently held.
    pub nodes: usize,
}

/// The index. One per shard worker — sequences routed to a shard by
/// hash-on-id share through their shard's pool only, so cluster
/// placement invariance is untouched.
pub struct PrefixIndex {
    root: Node,
    cap_nodes: usize,
    /// Logical LRU clock (one tick per lookup/register).
    clock: u64,
    nodes: usize,
    lookups: u64,
    hits: u64,
    pages_matched: u64,
    cow_splits: u64,
    registered: u64,
    evicted: u64,
}

impl PrefixIndex {
    /// `cap_nodes` bounds registered chunks (== pinned page runs).
    pub fn with_capacity(cap_nodes: usize) -> PrefixIndex {
        PrefixIndex {
            root: Node::new(),
            cap_nodes: cap_nodes.max(1),
            clock: 0,
            nodes: 0,
            lookups: 0,
            hits: 0,
            pages_matched: 0,
            cow_splits: 0,
            registered: 0,
            evicted: 0,
        }
    }

    /// Longest registered prefix of `prompt`, capped at `max_pages`
    /// (admission caps at `(prompt_len − 1) / PAGE_SIZE` so the logits
    /// row always stays in the prefilled suffix).
    pub fn lookup(&mut self, prompt: &[u8], max_pages: usize) -> PrefixMatch {
        self.clock += 1;
        self.lookups += 1;
        let now = self.clock;
        let mut node = &mut self.root;
        let mut run: Vec<Vec<PageRef>> = Vec::new();
        let mut capped = false;
        for chunk in prompt.chunks_exact(PAGE_SIZE) {
            if run.len() == max_pages {
                capped = true;
                break;
            }
            let key: [u8; PAGE_SIZE] = chunk.try_into().unwrap();
            match node.children.get_mut(&key) {
                Some(child) => {
                    child.last_touch = now;
                    run.push(child.pages.clone());
                    node = child;
                }
                None => break,
            }
        }
        // Divergence: the walk stopped early while the stop node has
        // registered continuations — the first unmatched token is a COW
        // split (offset classes: first token == empty run at a non-empty
        // root; page boundary == stop exactly between chunks; mid-page ==
        // the divergent chunk itself never matches a key).
        let cow_split = !capped && !node.children.is_empty();
        if !run.is_empty() {
            self.hits += 1;
            self.pages_matched += run.len() as u64;
        }
        if cow_split {
            self.cow_splits += 1;
        }
        PrefixMatch { pages: run, cow_split }
    }

    /// Register `runs[p]` as the sealed run for prompt chunk `p`, taking
    /// one pool ref per newly indexed page. Chunks already registered
    /// (the common shared trunk) are only touched. Evicts LRU leaves
    /// past capacity.
    pub fn register(&mut self, prompt: &[u8], runs: &[Vec<PageRef>], pool: &mut PagePool) {
        self.clock += 1;
        let now = self.clock;
        let mut new_nodes = 0usize;
        let mut node = &mut self.root;
        for (p, chunk) in prompt.chunks_exact(PAGE_SIZE).enumerate().take(runs.len()) {
            let key: [u8; PAGE_SIZE] = chunk.try_into().unwrap();
            let child = node.children.entry(key).or_insert_with(|| {
                new_nodes += 1;
                Node::new()
            });
            child.last_touch = now;
            if child.pages.is_empty() {
                for &r in &runs[p] {
                    pool.retain(r);
                }
                child.pages = runs[p].clone();
            }
            node = child;
        }
        self.nodes += new_nodes;
        self.registered += new_nodes as u64;
        while self.nodes > self.cap_nodes {
            if !self.evict_lru_leaf(pool) {
                break;
            }
        }
    }

    /// Drop every indexed run, releasing all pool refs (drain/teardown).
    pub fn release_all(&mut self, pool: &mut PagePool) {
        release_node(&mut self.root, pool);
        self.nodes = 0;
    }

    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            lookups: self.lookups,
            hits: self.hits,
            pages_matched: self.pages_matched,
            cow_splits: self.cow_splits,
            registered: self.registered,
            evicted: self.evicted,
            nodes: self.nodes,
        }
    }

    fn evict_lru_leaf(&mut self, pool: &mut PagePool) -> bool {
        let Some(target) = min_leaf_touch(&self.root) else { return false };
        let removed = remove_leaf(&mut self.root, target, pool);
        if removed {
            self.nodes -= 1;
            self.evicted += 1;
        }
        removed
    }
}

/// Minimum last-touch over all leaves below `node` (None if childless).
fn min_leaf_touch(node: &Node) -> Option<u64> {
    node.children
        .values()
        .filter_map(|c| if c.children.is_empty() { Some(c.last_touch) } else { min_leaf_touch(c) })
        .min()
}

/// Remove the (unique) leaf with `target` touch, releasing its refs.
fn remove_leaf(node: &mut Node, target: u64, pool: &mut PagePool) -> bool {
    let mut leaf_key = None;
    for (key, c) in node.children.iter_mut() {
        if c.children.is_empty() {
            if c.last_touch == target {
                leaf_key = Some(*key);
                break;
            }
        } else if remove_leaf(c, target, pool) {
            return true;
        }
    }
    if let Some(key) = leaf_key {
        let leaf = node.children.remove(&key).unwrap();
        for r in leaf.pages {
            pool.release(r);
        }
        return true;
    }
    false
}

fn release_node(node: &mut Node, pool: &mut PagePool) {
    for (_, mut c) in std::mem::take(&mut node.children) {
        for r in c.pages.drain(..) {
            pool.release(r);
        }
        release_node(&mut c, pool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::tensor4::PackedNvfp4;
    use crate::kvcache::SealedPage;

    /// A distinct, well-formed fake sealed page per tag.
    fn fake_page(tag: u8) -> SealedPage {
        let d = 16;
        SealedPage {
            k: PackedNvfp4 {
                rows: PAGE_SIZE,
                cols: d,
                codes: vec![tag; PAGE_SIZE * d / 2],
                scales: vec![0x30; PAGE_SIZE * d / 16],
            },
            vt: PackedNvfp4 {
                rows: d,
                cols: PAGE_SIZE,
                codes: vec![tag.wrapping_add(1); d * PAGE_SIZE / 2],
                scales: vec![0x30; d * PAGE_SIZE / 16],
            },
        }
    }

    fn chunk(tag: u8) -> Vec<u8> {
        vec![tag; PAGE_SIZE]
    }

    /// Register a prompt of `tags.len()` chunks, one fresh page per chunk.
    fn register_prompt(
        idx: &mut PrefixIndex,
        pool: &mut PagePool,
        tags: &[u8],
        page_tag0: u8,
    ) -> Vec<PageRef> {
        let prompt: Vec<u8> = tags.iter().flat_map(|&t| chunk(t)).collect();
        let refs: Vec<PageRef> = (0..tags.len())
            .map(|p| pool.insert(fake_page(page_tag0 + p as u8)))
            .collect();
        let runs: Vec<Vec<PageRef>> = refs.iter().map(|&r| vec![r]).collect();
        idx.register(&prompt, &runs, pool);
        // The sequence that sealed these pages drops them; the index ref
        // keeps them alive.
        for &r in &refs {
            pool.release(r);
        }
        refs
    }

    #[test]
    fn lookup_matches_longest_prefix_and_flags_divergence_classes() {
        let mut pool = PagePool::new();
        let mut idx = PrefixIndex::with_capacity(64);
        let refs = register_prompt(&mut idx, &mut pool, &[1, 2, 3], 10);
        assert_eq!(idx.stats().nodes, 3);
        assert_eq!(pool.live_pages(), 3, "index holds the registered pages");

        // Full match, capped below the registered depth (logits-row cap).
        let prompt: Vec<u8> = [chunk(1), chunk(2), chunk(3)].concat();
        let m = idx.lookup(&prompt, 2);
        assert_eq!(m.pages.len(), 2);
        assert_eq!(m.pages[0], vec![refs[0]]);
        assert_eq!(m.pages[1], vec![refs[1]]);
        assert!(!m.cow_split, "capped walk is not a divergence");

        // Page-boundary divergence: chunks 1,2 match, chunk 9 does not.
        let prompt: Vec<u8> = [chunk(1), chunk(2), chunk(9)].concat();
        let m = idx.lookup(&prompt, 3);
        assert_eq!(m.pages.len(), 2);
        assert!(m.cow_split, "registered continuation exists past the match");

        // Mid-page divergence: second chunk differs in its 8th byte.
        let mut mid = chunk(2);
        mid[8] = 0xff;
        let prompt: Vec<u8> = [chunk(1), mid, chunk(3)].concat();
        let m = idx.lookup(&prompt, 3);
        assert_eq!(m.pages.len(), 1);
        assert!(m.cow_split);

        // First-token divergence: nothing matches, root has children.
        let prompt: Vec<u8> = [chunk(8), chunk(2)].concat();
        let m = idx.lookup(&prompt, 2);
        assert!(m.pages.is_empty());
        assert!(m.cow_split);

        // Short prompt (< one page): no chunks, no divergence walk...
        let m = idx.lookup(&chunk(1)[..8], 0);
        assert!(m.pages.is_empty());

        let s = idx.stats();
        assert_eq!(s.lookups, 5);
        assert_eq!(s.hits, 3);
        assert_eq!(s.pages_matched, 5);
        assert!(s.cow_splits >= 3);
    }

    #[test]
    fn register_shared_trunk_takes_one_ref_per_unique_chunk() {
        let mut pool = PagePool::new();
        let mut idx = PrefixIndex::with_capacity(64);
        register_prompt(&mut idx, &mut pool, &[1, 2], 10);
        let before = pool.live_pages();
        // Same trunk again (e.g. the second request of a template): no new
        // nodes, no new refs.
        let prompt: Vec<u8> = [chunk(1), chunk(2)].concat();
        let m = idx.lookup(&prompt, 2);
        idx.register(&prompt, &m.pages, &mut pool);
        assert_eq!(idx.stats().nodes, 2);
        assert_eq!(pool.live_pages(), before);
        // Diverging tail adds only the new chunk.
        register_prompt(&mut idx, &mut pool, &[1, 7], 20);
        assert_eq!(idx.stats().nodes, 3, "trunk chunk 1 is shared");
    }

    #[test]
    fn capacity_evicts_lru_leaf_and_releases_refs() {
        let mut pool = PagePool::new();
        let mut idx = PrefixIndex::with_capacity(3);
        register_prompt(&mut idx, &mut pool, &[1, 2], 10); // nodes 1-2
        register_prompt(&mut idx, &mut pool, &[3], 20); // node 3
        assert_eq!(idx.stats().nodes, 3);
        assert_eq!(pool.live_pages(), 3);
        // Touch the [1,2] branch so [3] becomes the LRU leaf.
        let prompt: Vec<u8> = [chunk(1), chunk(2)].concat();
        idx.lookup(&prompt, 2);
        // A new chunk pushes past capacity: [3] is evicted, its page freed.
        register_prompt(&mut idx, &mut pool, &[4], 30);
        let s = idx.stats();
        assert_eq!(s.nodes, 3);
        assert_eq!(s.evicted, 1);
        assert_eq!(pool.live_pages(), 3);
        let m = idx.lookup(&chunk(3), 1);
        assert!(m.pages.is_empty(), "evicted chunk no longer matches");
        // The shared trunk survived: deepest-first eviction only takes
        // leaves, and the [1,2] branch was recently touched.
        let m = idx.lookup(&prompt, 2);
        assert_eq!(m.pages.len(), 2);
        // Teardown drains every ref the index holds.
        idx.release_all(&mut pool);
        assert_eq!(idx.stats().nodes, 0);
        assert_eq!(pool.live_pages(), 0);
    }
}
