//! Low-precision **full-stack FP4 training**: quantized projection GEMMs
//! and FP8 optimizer state under the existing session machinery.
//!
//! The paper quantizes attention; this module quantizes the rest of the
//! stack, following *Full-Stack FP4* / *FP4 All the Way* (PAPERS.md):
//!
//! - **[`ProjQuant`]** — per-model policy for fake-quantizing the
//!   projection GEMMs (`Wq/Wk/Wv/Wo/W_in/W_out`, optionally embeddings
//!   and the rms-normed activations feeding them) onto the NVFP4
//!   lattice. [`ProjQuantMode::Ste`] quantizes a *scratch copy* of the
//!   weights each forward and backpropagates with the straight-through
//!   estimator (the exact recipe `qat::ste` applies to attention
//!   inputs): `dW` lands on the f32 master weights, `dx` flows through
//!   the same quantized weights the forward used — matched recompute, no
//!   drift. [`ProjQuantMode::Naive`] instead hard-requantizes the master
//!   weights in place every step — the deliberately wrong baseline whose
//!   update-erasure stall the `exp fullstack` ablation demonstrates
//!   (lattice step ≈ scale/2 ≫ an Adam-scale update, so RNE erases it).
//! - **[`wht16`]** — an orthonormal 16-point Walsh–Hadamard transform
//!   matching the NVFP4 block size (*Training Transformers with 4-bit
//!   Integers*' outlier weapon): rotate each block, quantize in the
//!   rotated domain where outliers are spread across the block, rotate
//!   back. Enabled per-policy with [`ProjQuant::with_hadamard`].
//! - **[`LowPAdam`]** — Adam whose first/second moments live in **E4M3
//!   bytes** (2 bytes/param total) behind a per-tensor power-of-two
//!   scale, written back with *stochastic rounding*
//!   ([`crate::formats::e4m3::encode_stochastic`]) so quantization noise
//!   is unbiased and tiny moment updates survive in expectation. The
//!   rounding stream is keyed on `(seed, step, tensor)` through the
//!   crate [`Rng`], so runs are deterministic, watchdog rollbacks replay
//!   bitwise, and checkpointed state resumes bitwise.
//!
//! The module publishes per-step health through [`LowPStats`] (moment
//! saturation fraction, empirical stochastic-rounding bias), surfaced as
//! `train.lowp.*` gauges by [`super::TrainSession`].

use crate::formats::block::{nvfp4_block_scale, nvfp4_fake_quant_row, NVFP4_BLOCK};
use crate::formats::e4m3;
use crate::rng::Rng;

use super::modules::{rms_norm, rms_norm_bwd_rows, vec_mat_acc, Linear, Mlp, MlpActs};
use super::optim::{Optimizer, OptimizerState};

/// How projection weights are quantized during training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjQuantMode {
    /// Projections stay f32 (the pre-existing behaviour).
    Off,
    /// Fake-quantize a scratch copy of each projection weight every
    /// forward; backward uses the straight-through estimator (`dW` onto
    /// the f32 master, `dx` through the quantized copy).
    Ste,
    /// Hard-requantize the master weights in place at the start of every
    /// training step — no STE, no master copy. The naive baseline that
    /// stalls (updates smaller than a lattice step are erased).
    Naive,
}

/// Per-model projection-quantization policy. Composes with the per-layer
/// [`crate::attention::AttnConfig`]: attention quantization and
/// projection quantization are selected independently, which is what the
/// `exp fullstack` ablation grid sweeps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProjQuant {
    pub mode: ProjQuantMode,
    /// Rotate each 16-block with [`wht16`] before quantizing (and back
    /// after) — spreads outliers so the block scale is not dominated by
    /// a single large weight.
    pub hadamard: bool,
    /// Also fake-quantize the rms-normed activation rows entering each
    /// projection (STE through the quantizer; cached operands are the
    /// quantized rows, so backward is automatically matched).
    pub activations: bool,
    /// Also quantize the embedding output rows (Ste) or the embedding
    /// tables in place (Naive).
    pub embeddings: bool,
}

impl ProjQuant {
    /// Projections stay f32.
    pub fn off() -> ProjQuant {
        ProjQuant {
            mode: ProjQuantMode::Off,
            hadamard: false,
            activations: false,
            embeddings: false,
        }
    }

    /// STE fake-quantized projection weights (the stable recipe).
    pub fn ste() -> ProjQuant {
        ProjQuant { mode: ProjQuantMode::Ste, ..ProjQuant::off() }
    }

    /// Hard in-place requantization every step (the unstable baseline).
    pub fn naive() -> ProjQuant {
        ProjQuant { mode: ProjQuantMode::Naive, ..ProjQuant::off() }
    }

    pub fn with_hadamard(mut self, on: bool) -> ProjQuant {
        self.hadamard = on;
        self
    }

    pub fn with_activations(mut self, on: bool) -> ProjQuant {
        self.activations = on;
        self
    }

    pub fn with_embeddings(mut self, on: bool) -> ProjQuant {
        self.embeddings = on;
        self
    }

    /// True when any quantization is active.
    pub fn enabled(&self) -> bool {
        self.mode != ProjQuantMode::Off
    }

    /// Short label for tables / telemetry (`off`, `ste`, `ste+had`, …).
    pub fn label(&self) -> String {
        let base = match self.mode {
            ProjQuantMode::Off => return "off".to_string(),
            ProjQuantMode::Ste => "ste",
            ProjQuantMode::Naive => "naive",
        };
        let mut s = base.to_string();
        if self.hadamard {
            s.push_str("+had");
        }
        if self.activations {
            s.push_str("+act");
        }
        if self.embeddings {
            s.push_str("+emb");
        }
        s
    }
}

impl Default for ProjQuant {
    fn default() -> ProjQuant {
        ProjQuant::off()
    }
}

/// In-place orthonormal 16-point Walsh–Hadamard transform (scaled by
/// 1/√16, so it is its own inverse and preserves the block's L2 norm).
pub fn wht16(block: &mut [f32]) {
    debug_assert_eq!(block.len(), NVFP4_BLOCK);
    let mut h = 1;
    while h < NVFP4_BLOCK {
        let mut i = 0;
        while i < NVFP4_BLOCK {
            for j in i..i + h {
                let (a, b) = (block[j], block[j + h]);
                block[j] = a + b;
                block[j + h] = a - b;
            }
            i += h * 2;
        }
        h *= 2;
    }
    for x in block.iter_mut() {
        *x *= 0.25;
    }
}

/// Fake-quantize one row (length a multiple of 16) onto the NVFP4
/// lattice, optionally rotating each 16-block with [`wht16`] first and
/// back after (quantize-in-rotated-domain).
pub fn fake_quant_row(row: &mut [f32], hadamard: bool) {
    debug_assert_eq!(row.len() % NVFP4_BLOCK, 0);
    if !hadamard {
        nvfp4_fake_quant_row(row);
        return;
    }
    for b in row.chunks_mut(NVFP4_BLOCK) {
        wht16(b);
    }
    nvfp4_fake_quant_row(row);
    for b in row.chunks_mut(NVFP4_BLOCK) {
        wht16(b);
    }
}

/// Fake-quantize a `(rows × cols)` weight matrix row-blocked along
/// `cols` (the layout `QatModel::save_quantized` exports), returning a
/// fresh quantized copy.
pub fn fake_quant_matrix(w: &[f32], cols: usize, hadamard: bool) -> Vec<f32> {
    let mut out = w.to_vec();
    for row in out.chunks_mut(cols) {
        fake_quant_row(row, hadamard);
    }
    out
}

/// Fake-quantize a matrix **in place** (the [`ProjQuantMode::Naive`]
/// hard-requant step).
pub fn fake_quant_matrix_inplace(w: &mut [f32], cols: usize, hadamard: bool) {
    for row in w.chunks_mut(cols) {
        fake_quant_row(row, hadamard);
    }
}

/// Ratio of the largest to the smallest nonzero NVFP4 block scale over a
/// weight tensor — the `train.lowp.proj_scale_range` health probe (a
/// large ratio means some blocks quantize much more coarsely).
pub fn proj_scale_range(w: &[f32]) -> f32 {
    let mut min_s = f32::INFINITY;
    let mut max_s = 0.0f32;
    for b in w.chunks(NVFP4_BLOCK) {
        let s = nvfp4_block_scale(b);
        if s > 0.0 {
            min_s = min_s.min(s);
            max_s = max_s.max(s);
        }
    }
    if max_s <= 0.0 || !min_s.is_finite() {
        1.0
    } else {
        max_s / min_s
    }
}

/// One block's fake-quantized projection weights — the scratch copies a
/// [`ProjQuantMode::Ste`] forward multiplies by. Cached in the model's
/// activation bundle so the backward multiplies by *exactly* the weights
/// the forward used (matched recompute, the paper's principle 1 applied
/// to projections).
pub(crate) struct QuantWeights {
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub win: Vec<f32>,
    pub wout: Vec<f32>,
}

impl QuantWeights {
    pub(crate) fn quantize(
        wq: &Linear,
        wk: &Linear,
        wv: &Linear,
        wo: &Linear,
        mlp: &Mlp,
        hadamard: bool,
    ) -> QuantWeights {
        QuantWeights {
            wq: fake_quant_matrix(&wq.w, wq.out_dim, hadamard),
            wk: fake_quant_matrix(&wk.w, wk.out_dim, hadamard),
            wv: fake_quant_matrix(&wv.w, wv.out_dim, hadamard),
            wo: fake_quant_matrix(&wo.w, wo.out_dim, hadamard),
            win: fake_quant_matrix(&mlp.win.w, mlp.win.out_dim, hadamard),
            wout: fake_quant_matrix(&mlp.wout.w, mlp.wout.out_dim, hadamard),
        }
    }
}

/// `out = x·W` over `n` rows with an explicit weight slice (the
/// quantized-scratch variant of [`Linear::forward`]; same per-row
/// kernel, so `w = master` reproduces it bitwise).
pub(crate) fn linear_forward_w(
    w: &[f32],
    x: &[f32],
    n: usize,
    in_dim: usize,
    out_dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), n * in_dim);
    debug_assert_eq!(out.len(), n * out_dim);
    out.fill(0.0);
    linear_forward_acc_w(w, x, n, in_dim, out_dim, out);
}

/// `out += x·W` with an explicit weight slice.
pub(crate) fn linear_forward_acc_w(
    w: &[f32],
    x: &[f32],
    n: usize,
    in_dim: usize,
    out_dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(w.len(), in_dim * out_dim);
    for (xr, or) in x.chunks(in_dim).zip(out.chunks_mut(out_dim)) {
        vec_mat_acc(xr, w, out_dim, or);
    }
}

/// [`Linear::backward`] with the forward's weights supplied explicitly:
/// accumulates `g += xᵀ·dy` (STE — the gradient lands on the f32 master
/// weights' accumulator) and `dx += dy·Wᵀ` through `w_used`, the
/// quantized copy the forward multiplied by.
#[allow(clippy::too_many_arguments)]
pub(crate) fn linear_backward_w(
    w_used: &[f32],
    g: &mut [f32],
    x: &[f32],
    dy: &[f32],
    n: usize,
    in_dim: usize,
    out_dim: usize,
    mut dx: Option<&mut [f32]>,
) {
    debug_assert_eq!(x.len(), n * in_dim);
    debug_assert_eq!(dy.len(), n * out_dim);
    debug_assert_eq!(w_used.len(), in_dim * out_dim);
    debug_assert_eq!(g.len(), in_dim * out_dim);
    for r in 0..n {
        let xr = &x[r * in_dim..(r + 1) * in_dim];
        let dyr = &dy[r * out_dim..(r + 1) * out_dim];
        for (m, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let grow = &mut g[m * out_dim..(m + 1) * out_dim];
            for (gg, &dv) in grow.iter_mut().zip(dyr) {
                *gg += xv * dv;
            }
        }
        if let Some(dx) = dx.as_deref_mut() {
            debug_assert_eq!(dx.len(), n * in_dim);
            let dxr = &mut dx[r * in_dim..(r + 1) * in_dim];
            for (m, o) in dxr.iter_mut().enumerate() {
                let wrow = &w_used[m * out_dim..(m + 1) * out_dim];
                let mut acc = 0.0f32;
                for (&wv, &dv) in wrow.iter().zip(dyr) {
                    acc += wv * dv;
                }
                *o += acc;
            }
        }
    }
}

/// [`Mlp::forward_train`] with quantized scratch weights and (optionally)
/// quantized rms-normed activations. The returned [`MlpActs`] caches the
/// *quantized* `xn` rows, so [`mlp_backward_w`] consumes exactly the
/// operands the forward multiplied.
pub(crate) fn mlp_forward_train_w(
    mlp: &Mlp,
    win: &[f32],
    wout: &[f32],
    quant_acts: bool,
    hadamard: bool,
    h: &mut [f32],
    n: usize,
) -> MlpActs {
    let d = mlp.win.in_dim;
    let ff = mlp.win.out_dim;
    debug_assert_eq!(h.len(), n * d);
    let mut xn = vec![0.0f32; n * d];
    let mut f = vec![0.0f32; n * ff];
    for ((hr, xr), fr) in h.chunks_mut(d).zip(xn.chunks_mut(d)).zip(f.chunks_mut(ff)) {
        rms_norm(hr, xr);
        if quant_acts {
            fake_quant_row(xr, hadamard);
        }
        vec_mat_acc(xr, win, ff, fr);
        for x in fr.iter_mut() {
            *x = x.tanh();
        }
        vec_mat_acc(fr, wout, d, hr);
    }
    MlpActs { xn, f }
}

/// [`Mlp::backward`] through the quantized scratch weights: `dW` onto
/// the master accumulators (STE), `dx` through the forward's quantized
/// copies; the rms chain uses the raw `h_in` (STE is identity through
/// the activation quantizer).
pub(crate) fn mlp_backward_w(
    mlp: &mut Mlp,
    win_q: &[f32],
    wout_q: &[f32],
    h_in: &[f32],
    acts: &MlpActs,
    dh: &mut [f32],
    n: usize,
) {
    let d = mlp.win.in_dim;
    let ff = mlp.win.out_dim;
    debug_assert_eq!(h_in.len(), n * d);
    debug_assert_eq!(dh.len(), n * d);
    let mut df = vec![0.0f32; n * ff];
    linear_backward_w(wout_q, &mut mlp.wout.g, &acts.f, dh, n, ff, d, Some(&mut df));
    for (dfv, &fv) in df.iter_mut().zip(&acts.f) {
        *dfv *= 1.0 - fv * fv;
    }
    let mut dxn = vec![0.0f32; n * d];
    linear_backward_w(win_q, &mut mlp.win.g, &acts.xn, &df, n, d, ff, Some(&mut dxn));
    rms_norm_bwd_rows(h_in, &dxn, d, dh);
}

/// Per-step health of a [`LowPAdam`] writeback, surfaced as
/// `train.lowp.*` gauges.
#[derive(Clone, Copy, Debug, Default)]
pub struct LowPStats {
    /// Fraction of first-moment elements that saturated at ±E4M3 MAX.
    pub m_sat_frac: f32,
    /// Fraction of second-moment elements that saturated.
    pub v_sat_frac: f32,
    /// Empirical stochastic-rounding bias: Σ(decoded − exact) over both
    /// moments, normalized by Σ|exact| — should hover near 0 (the SR
    /// unbiasedness guarantee, measured on live data).
    pub sr_bias: f32,
}

/// One tensor's E4M3 moment buffer: one byte per element under a single
/// power-of-two scale chosen per step so `amax/scale ∈ (MAX/2, MAX]`
/// (maximal precision without saturation; power-of-two so scaling is
/// exact in binary floating point).
#[derive(Clone, Debug)]
struct MomentBuf {
    bytes: Vec<u8>,
    scale: f32,
}

impl MomentBuf {
    fn empty() -> MomentBuf {
        MomentBuf { bytes: Vec::new(), scale: 1.0 }
    }
}

/// Smallest power of two `s` with `amax/s ≤ MAX` (1.0 for zero input).
fn pow2_scale(amax: f32) -> f32 {
    if amax <= 0.0 || !amax.is_finite() {
        return 1.0;
    }
    let mut s = (amax / e4m3::MAX).log2().ceil().exp2();
    if !(s.is_finite() && s > 0.0) {
        return 1.0;
    }
    // Guard one ulp of log2 error: never let the max element saturate
    // merely from the scale computation.
    if amax / s > e4m3::MAX {
        s *= 2.0;
    }
    s
}

/// Adam with E4M3 first/second moments (2 bytes/param of moment state)
/// and stochastic-rounding writeback. The update math runs in f32 on
/// freshly-decoded moments, so a step is ordinary Adam plus bounded,
/// unbiased storage noise. Deterministic: the rounding stream is
/// `Rng::new(seed ⊕ h(step) ⊕ h(tensor))`, independent of call history,
/// so watchdog rollback + replay and checkpoint resume are bitwise.
pub struct LowPAdam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Seed for the stochastic-rounding stream.
    pub seed: u64,
    t: i32,
    m: Vec<MomentBuf>,
    v: Vec<MomentBuf>,
    // Per-step stat accumulators (reset in begin_step).
    m_sat: usize,
    v_sat: usize,
    count: usize,
    bias_sum: f64,
    bias_ref: f64,
}

impl LowPAdam {
    pub fn new(beta1: f32, beta2: f32, eps: f32, seed: u64) -> LowPAdam {
        LowPAdam {
            beta1,
            beta2,
            eps,
            seed,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
            m_sat: 0,
            v_sat: 0,
            count: 0,
            bias_sum: 0.0,
            bias_ref: 0.0,
        }
    }

    /// Standard Adam defaults + a rounding seed.
    pub fn with_seed(seed: u64) -> LowPAdam {
        LowPAdam::new(0.9, 0.999, 1e-8, seed)
    }

    /// Rescale + stochastically round `vals` into `buf`; `draws[i]` is
    /// element `i`'s uniform sample. Returns the saturation count.
    fn writeback(
        buf: &mut MomentBuf,
        vals: &[f32],
        draws: &[f32],
        bias_sum: &mut f64,
        bias_ref: &mut f64,
    ) -> usize {
        let amax = vals.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        buf.scale = pow2_scale(amax);
        let inv = 1.0 / buf.scale;
        let mut sat = 0usize;
        for ((b, &x), &u) in buf.bytes.iter_mut().zip(vals).zip(draws) {
            let scaled = x * inv;
            if scaled.abs() >= e4m3::MAX {
                sat += 1;
            }
            *b = e4m3::encode_stochastic(scaled, u);
            let dec = buf.scale * e4m3::decode(*b);
            *bias_sum += (dec - x) as f64;
            *bias_ref += x.abs() as f64;
        }
        sat
    }
}

impl Optimizer for LowPAdam {
    fn begin_step(&mut self) {
        self.t += 1;
        self.m_sat = 0;
        self.v_sat = 0;
        self.count = 0;
        self.bias_sum = 0.0;
        self.bias_ref = 0.0;
    }

    fn update(&mut self, idx: usize, w: &mut [f32], g: &[f32], lr: f32) {
        while self.m.len() <= idx {
            self.m.push(MomentBuf::empty());
            self.v.push(MomentBuf::empty());
        }
        if self.m[idx].bytes.len() != g.len() {
            self.m[idx] = MomentBuf::empty();
            self.m[idx].bytes.resize(g.len(), 0);
            self.v[idx] = MomentBuf::empty();
            self.v[idx].bytes.resize(g.len(), 0);
        }
        let t = self.t.max(1);
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let bc1 = 1.0 - b1.powi(t);
        let bc2 = 1.0 - b2.powi(t);
        // Stateless rounding stream per (seed, step, tensor): replay after
        // a rollback or a checkpoint resume regenerates identical bits.
        let mut rng = Rng::new(
            self.seed
                ^ (t as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (idx as u64).wrapping_mul(0xBF58476D1CE4E5B9),
        );
        let (mb, vb) = (&mut self.m[idx], &mut self.v[idx]);
        let (sm, sv) = (mb.scale, vb.scale);
        let mut nm = vec![0.0f32; g.len()];
        let mut nv = vec![0.0f32; g.len()];
        for (i, ((wv, &gx), (mbyte, vbyte))) in w
            .iter_mut()
            .zip(g)
            .zip(mb.bytes.iter().zip(vb.bytes.iter()))
            .enumerate()
        {
            let m0 = sm * e4m3::decode(*mbyte);
            let v0 = sv * e4m3::decode(*vbyte);
            let m1 = b1 * m0 + (1.0 - b1) * gx;
            let v1 = b2 * v0 + (1.0 - b2) * gx * gx;
            let mh = m1 / bc1;
            let vh = v1 / bc2;
            *wv -= lr * mh / (vh.sqrt() + eps);
            nm[i] = m1;
            nv[i] = v1;
        }
        // Draw order is a stable part of the format: per element, one
        // uniform for m, then one for v.
        let mut mdraws = vec![0.0f32; g.len()];
        let mut vdraws = vec![0.0f32; g.len()];
        for (mu, vu) in mdraws.iter_mut().zip(vdraws.iter_mut()) {
            *mu = rng.uniform();
            *vu = rng.uniform();
        }
        self.m_sat += LowPAdam::writeback(mb, &nm, &mdraws, &mut self.bias_sum, &mut self.bias_ref);
        self.v_sat += LowPAdam::writeback(vb, &nv, &vdraws, &mut self.bias_sum, &mut self.bias_ref);
        self.count += g.len();
    }

    fn snapshot(&self) -> OptimizerState {
        OptimizerState {
            step: self.t,
            slots: vec![
                self.m.iter().map(|b| vec![b.scale]).collect(),
                self.v.iter().map(|b| vec![b.scale]).collect(),
            ],
            byte_slots: vec![
                self.m.iter().map(|b| b.bytes.clone()).collect(),
                self.v.iter().map(|b| b.bytes.clone()).collect(),
            ],
        }
    }

    fn restore(&mut self, state: &OptimizerState) {
        self.t = state.step;
        let scales = |slot: usize, i: usize| -> f32 {
            state
                .slots
                .get(slot)
                .and_then(|s| s.get(i))
                .and_then(|v| v.first().copied())
                .unwrap_or(1.0)
        };
        let rebuild = |slot: usize| -> Vec<MomentBuf> {
            state
                .byte_slots
                .get(slot)
                .map(|bufs| {
                    bufs.iter()
                        .enumerate()
                        .map(|(i, b)| MomentBuf { bytes: b.clone(), scale: scales(slot, i) })
                        .collect()
                })
                .unwrap_or_default()
        };
        self.m = rebuild(0);
        self.v = rebuild(1);
    }

    fn state_bytes(&self) -> usize {
        // One byte per element per moment, plus a 4-byte scale per tensor
        // per moment.
        self.m.iter().chain(self.v.iter()).map(|b| b.bytes.len() + 4).sum()
    }

    fn lowp_stats(&self) -> Option<LowPStats> {
        if self.count == 0 {
            return None;
        }
        let n = self.count as f32;
        Some(LowPStats {
            m_sat_frac: self.m_sat as f32 / n,
            v_sat_frac: self.v_sat as f32 / n,
            sr_bias: (self.bias_sum / (self.bias_ref + 1e-12)) as f32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::optim::Adam;

    #[test]
    fn wht16_is_self_inverse_and_orthonormal() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let x = rng.normal_vec(16, 0.0, 1.0);
            let mut y = x.clone();
            wht16(&mut y);
            let n_x: f32 = x.iter().map(|v| v * v).sum();
            let n_y: f32 = y.iter().map(|v| v * v).sum();
            assert!((n_x - n_y).abs() < 1e-4 * n_x.max(1.0), "norm {n_x} vs {n_y}");
            wht16(&mut y);
            for (a, b) in x.iter().zip(&y) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn hadamard_spreads_outliers() {
        let mut x = [0.01f32; 16];
        x[5] = 8.0;
        let mut y = x;
        wht16(&mut y);
        let amax_x = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let amax_y = y.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        assert!(amax_y < amax_x / 3.0, "{amax_y} vs {amax_x}");
    }

    #[test]
    fn fake_quant_matrix_bounds_error_and_actually_quantizes() {
        let mut rng = Rng::new(9);
        let w = rng.normal_vec(256, 0.0, 0.2);
        let l2: f32 = w.iter().map(|v| v * v).sum::<f32>().sqrt();
        for had in [false, true] {
            let q = fake_quant_matrix(&w, 32, had);
            assert_ne!(q, w, "had={had}: quantization must move weights");
            assert!(q.iter().all(|v| v.is_finite()));
            let err: f32 =
                w.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
            assert!(err / l2 < 0.5, "had={had}: relative L2 error {}", err / l2);
        }
    }

    #[test]
    fn quantized_helpers_match_modules_with_master_weights() {
        // With w_used = master weights, the _w helpers must reproduce
        // Linear/Mlp bitwise (they are the same kernels).
        let mut rng = Rng::new(21);
        let (n, d, ff) = (3, 16, 32);
        let mut lin = Linear::new(rng.normal_vec(d * ff, 0.0, 0.3), d, ff);
        let x = rng.normal_vec(n * d, 0.0, 1.0);
        let mut want = vec![0.0f32; n * ff];
        lin.forward(&x, n, &mut want);
        let mut got = vec![0.0f32; n * ff];
        linear_forward_w(&lin.w, &x, n, d, ff, &mut got);
        assert_eq!(got, want);
        let dy = rng.normal_vec(n * ff, 0.0, 1.0);
        let mut dx_want = vec![0.0f32; n * d];
        lin.backward(&x, &dy, n, Some(&mut dx_want));
        let g_want = lin.g.clone();
        let w_copy = lin.w.clone();
        let mut g_got = vec![0.0f32; d * ff];
        let mut dx_got = vec![0.0f32; n * d];
        linear_backward_w(&w_copy, &mut g_got, &x, &dy, n, d, ff, Some(&mut dx_got));
        assert_eq!(dx_got, dx_want);
        assert_eq!(g_got, g_want);
    }

    #[test]
    fn pow2_scale_keeps_amax_in_top_binade() {
        for amax in [0.001f32, 0.7, 3.0, 447.9, 448.0, 1000.0, 1e-30] {
            let s = pow2_scale(amax);
            assert!(amax / s <= e4m3::MAX, "amax {amax} scale {s}");
            assert!(amax / s > e4m3::MAX / 2.0 * 0.999, "amax {amax} scale {s}");
        }
        assert_eq!(pow2_scale(0.0), 1.0);
    }

    #[test]
    fn lowp_adam_first_step_is_signed_lr() {
        let mut opt = LowPAdam::with_seed(7);
        opt.begin_step();
        let mut w = vec![0.0f32, 0.0];
        opt.update(0, &mut w, &[3.0, -0.001], 0.01);
        assert!((w[0] + 0.01).abs() < 1e-5, "{}", w[0]);
        assert!((w[1] - 0.01).abs() < 1e-4, "{}", w[1]);
        let stats = opt.lowp_stats().unwrap();
        assert!(stats.m_sat_frac <= 0.51, "pow2 scale keeps moments unsaturated");
    }

    #[test]
    fn lowp_adam_snapshot_restore_replays_bitwise() {
        let mut opt = LowPAdam::with_seed(3);
        let mut w = vec![0.1f32; 8];
        opt.begin_step();
        opt.update(0, &mut w, &[0.5; 8], 0.01);
        let snap = opt.snapshot();
        let w_snap = w.clone();
        opt.begin_step();
        opt.update(0, &mut w, &[-0.25; 8], 0.01);
        let diverged = w.clone();
        opt.restore(&snap);
        let mut w2 = w_snap;
        opt.begin_step();
        opt.update(0, &mut w2, &[-0.25; 8], 0.01);
        assert_eq!(w2, diverged, "rollback + replay must be bitwise");
    }

    #[test]
    fn lowp_adam_tracks_f32_adam_on_quadratic() {
        // min ‖w − tgt‖²: both optimizers should land near tgt, within a
        // tolerance dominated by the E4M3 moment noise.
        let mut rng = Rng::new(5);
        let tgt = rng.normal_vec(64, 0.0, 0.5);
        let run = |lowp: bool| -> f32 {
            let mut w = vec![0.0f32; 64];
            let mut adam = Adam::new();
            let mut lp = LowPAdam::with_seed(11);
            for _ in 0..40 {
                let g: Vec<f32> = w.iter().zip(&tgt).map(|(&a, &b)| 2.0 * (a - b)).collect();
                if lowp {
                    lp.begin_step();
                    lp.update(0, &mut w, &g, 0.05);
                } else {
                    adam.begin_step();
                    adam.update(0, &mut w, &g, 0.05);
                }
            }
            w.iter().zip(&tgt).map(|(&a, &b)| (a - b) * (a - b)).sum()
        };
        let (f32_loss, lowp_loss) = (run(false), run(true));
        assert!(lowp_loss < 0.5, "lowp must converge: {lowp_loss}");
        assert!((lowp_loss - f32_loss).abs() < 0.5, "{lowp_loss} vs {f32_loss}");
    }

    #[test]
    fn lowp_state_is_two_bytes_per_param_plus_scales() {
        let mut opt = LowPAdam::with_seed(1);
        let mut w = vec![0.0f32; 100];
        let g = [0.1f32; 100];
        opt.begin_step();
        opt.update(0, &mut w, &g, 0.01);
        assert_eq!(opt.state_bytes(), 2 * 100 + 2 * 4);
    }
}
