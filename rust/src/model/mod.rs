//! Native model stack: one layered API for training **and** serving,
//! with an explicit **precision map** over every tensor a training step
//! touches.
//!
//! Before this module the repo had two disjoint model worlds: the `qat`
//! trainer drove a bespoke single-attention toy, while `serve` ran a
//! forward-only `SimLm` it could not train. `model` unifies them the way
//! `attention::AttnEngine` unified the attention kernels — and then
//! pushes the paper's 4-bit story past attention, across the whole step:
//!
//! | tensor class            | precision                   | where            |
//! |-------------------------|-----------------------------|------------------|
//! | attention Q/K/V/P̃      | NVFP4 fake-quant, per layer | [`crate::attention::AttnConfig`] |
//! | projection weights      | NVFP4 fake-quant (STE) or naive requant | [`ProjQuant`] in [`lowp`] |
//! | projection activations  | optional NVFP4 fake-quant   | [`ProjQuant::activations`] |
//! | optimizer moments (m,v) | E4M3 + stochastic rounding  | [`LowPAdam`] in [`lowp`] |
//! | master weights, grads   | f32 always                  | everywhere       |
//! | lm head                 | f32 always                  | [`QatModel`]     |
//!
//! The layers:
//!
//! * [`modules`] — composable trainable pieces ([`Linear`], [`Embedding`],
//!   [`Mlp`], rms-norm kernels) exposing `forward` / `forward_train` /
//!   `backward` with parameter+gradient views ([`Module::visit_params`]).
//! * [`lowp`] — the low-precision training toolbox: [`ProjQuant`]
//!   (straight-through fake-quantized projection GEMMs, with an optional
//!   16-point Hadamard rotation for outlier-heavy weights), [`LowPAdam`]
//!   (Adam whose moment state lives in E4M3 bytes — 2 bytes/param instead
//!   of 8 — written back with seeded stochastic rounding so runs replay
//!   bitwise), and the `train.lowp.*` health stats behind both.
//! * [`QatModel`] — a pre-norm byte transformer (embedding → N× {attention
//!   via [`crate::attention::AttnEngine`] with a **per-layer**
//!   [`crate::attention::AttnConfig`], MLP, norm} → logits). Training
//!   attention runs `forward_train` + `qat::flash_backward_cfg`, so the
//!   Fig-3 `BwdSwitches` ablations (and smooth-K / two-level P̃) apply per
//!   layer; [`QatModel::set_proj_quant`] extends the quantization to the
//!   projection GEMMs. The same weights implement
//!   [`crate::serve::TokenModel`], so a finetuned model serves directly
//!   from the sharded [`crate::serve::DecodeCluster`] — the repo's
//!   train→serve round trip ([`QatModel::save_quantized`] /
//!   [`QatModel::load`] move the quantized weights between the two).
//! * [`TrainSession`] — the config-driven training loop ([`TrainConfig`]:
//!   [`Optimizer`] choice — SGD+momentum, Adam, or [`LowPAdam`] — global
//!   grad-clip, lr schedule, microbatch grad accumulation, `StepMetrics`
//!   history, v3 train checkpoints via `TrainSession::save_checkpoint`).
//!   [`AttnRegressor`] is the old Fig-3 toy task as a [`TrainableModel`];
//!   `qat::NativeTrainer` remains as a deprecated shim over
//!   [`AttnRegressor::session`].
//!
//! ```no_run
//! use attn_qat::model::{LmTrainTask, ProjQuant, QatModel, QatModelConfig};
//! use attn_qat::model::{TrainConfig, TrainSession};
//!
//! // Full-stack FP4 finetune: quantized projections (STE), E4M3 Adam
//! // moments, 4-sequence microbatches.
//! let mut model = QatModel::new(QatModelConfig::default());
//! model.set_proj_quant(ProjQuant::ste());
//! let task = LmTrainTask::new(model, 48, 42);
//! let cfg = TrainConfig::lowp_adam(5e-3, 0xA77).with_microbatch(4);
//! let mut session = TrainSession::new(task, cfg);
//! session.run(100, 10, |m| println!("step {} loss {:.4}", m.step, m.loss));
//! // ... then serve the same weights from the cluster.
//! let model = session.model.into_model();
//! # let _ = model;
//! ```

pub mod lowp;
pub mod modules;
pub mod optim;
pub mod qat_model;
pub mod regressor;
pub mod session;

pub use lowp::{LowPAdam, LowPStats, ProjQuant, ProjQuantMode};
pub use modules::{cross_entropy, Embedding, Linear, Mlp, Module};
pub use optim::{Adam, Optimizer, OptimizerState, Sgd};
pub use qat_model::{LmTrainTask, ModelActs, QatModel, QatModelConfig};
pub use regressor::AttnRegressor;
pub use session::{OptimizerKind, TrainConfig, TrainSession, TrainableModel, WatchdogConfig};

use anyhow::{ensure, Result};

use crate::attention::{AttnConfig, AttnEngine};
use crate::kvcache::{PagedKvCache, SeqSlot};
use crate::serve::argmax;
use crate::serve::model::{TokenModel, VOCAB};

use self::modules::{to_head_major, to_token_major};

/// Standalone greedy decode over any [`TokenModel`], using the serving
/// dataflow (own paged FP4 cache + one [`AttnEngine`]): batched prompt
/// prefill, then token-at-a-time decode until `max_new` tokens, a `'$'`
/// terminator, or `seq_max`.
///
/// This replicates the per-sequence math of `serve::ShardWorker` exactly
/// (same cache appends, same engine calls, same sampling rule), so it is
/// the **direct model eval** the cluster-parity tests compare against:
/// cluster(N) == cluster(1) == this function, bitwise, for greedy
/// requests.
pub fn greedy_decode(
    model: &dyn TokenModel,
    attn: AttnConfig,
    prompt: &[u8],
    max_new: usize,
    seq_max: usize,
) -> Result<Vec<u8>> {
    ensure!(max_new > 0, "need a token budget");
    ensure!(prompt.len().max(1) + 1 <= seq_max, "prompt beyond seq_max");
    let mut cache = PagedKvCache::new(model.layers(), model.heads(), model.head_dim());
    let slot = cache.add_seq(0);
    let mut engine = AttnEngine::new(attn);
    let mut tokens = if prompt.is_empty() { vec![b' '] } else { prompt.to_vec() };
    let d = model.d_model();
    let mut logits = vec![0.0f32; VOCAB];
    // Prompt prefill + first sampled token.
    let nq = tokens.len();
    let h = forward_rows(model, &mut cache, &mut engine, slot, &tokens, 0)?;
    model.logits(&h[(nq - 1) * d..nq * d], &mut logits);
    let mut next = argmax(&logits) as u8;
    tokens.push(next);
    let mut generated = 1usize;
    // Token-at-a-time decode.
    while generated < max_new && next != b'$' && tokens.len() < seq_max {
        let pos = tokens.len() - 1;
        let tok = *tokens.last().expect("non-empty");
        let h = forward_rows(model, &mut cache, &mut engine, slot, &[tok], pos)?;
        model.logits(&h[..d], &mut logits);
        next = argmax(&logits) as u8;
        tokens.push(next);
        generated += 1;
    }
    Ok(tokens)
}

/// One forward pass over `tokens` for the sequence in `slot` — the same
/// per-layer dataflow as `serve::shard`'s worker (embed → qkv → append →
/// attend (decode for one row, batched prefill for many) → mix). Returns
/// the final hidden rows.
fn forward_rows(
    model: &dyn TokenModel,
    cache: &mut PagedKvCache,
    engine: &mut AttnEngine,
    slot: SeqSlot,
    tokens: &[u8],
    pos0: usize,
) -> Result<Vec<f32>> {
    let d = model.d_model();
    let hd = model.head_dim();
    let heads = model.heads();
    let nq = tokens.len();
    let n = nq * d;
    let mut h = vec![0.0f32; n];
    let mut q = vec![0.0f32; n];
    let mut k = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    let mut attn = vec![0.0f32; n];
    model.embed(tokens, pos0, &mut h);
    for layer in 0..model.layers() {
        model.qkv(layer, &h, &mut q, &mut k, &mut v);
        for i in 0..nq {
            for head in 0..heads {
                let off = i * d + head * hd;
                cache.append_at(slot, layer, head, &k[off..off + hd], &v[off..off + hd])?;
            }
        }
        if nq == 1 {
            engine.decode_slot(cache, slot, layer, &q[..d], &mut attn[..d])?;
        } else {
            // Restage token-major rows head-major for the batched prefill.
            let qhm = to_head_major(&q, nq, heads, hd);
            let mut ohm = vec![0.0f32; n];
            engine.prefill_slot(cache, slot, layer, &qhm, nq, &mut ohm)?;
            attn = to_token_major(&ohm, nq, heads, hd);
        }
        model.mix(layer, &mut h, &attn);
    }
    Ok(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{SimLm, SimLmConfig};

    #[test]
    fn greedy_decode_runs_on_sim_lm_and_is_deterministic() {
        let lm = SimLm::new(SimLmConfig::default());
        let a = greedy_decode(&lm, AttnConfig::fp4(), b"A hello#", 6, 128).unwrap();
        let b = greedy_decode(&lm, AttnConfig::fp4(), b"A hello#", 6, 128).unwrap();
        assert_eq!(a, b);
        assert!(a.starts_with(b"A hello#"));
        assert!(a.len() > 8 && a.len() <= 8 + 6);
        // The f32 baseline config runs the gather path.
        let c = greedy_decode(&lm, AttnConfig::f32(), b"A hello#", 6, 128).unwrap();
        assert!(c.starts_with(b"A hello#"));
    }

    #[test]
    fn greedy_decode_empty_prompt_pads() {
        let lm = SimLm::new(SimLmConfig::default());
        let out = greedy_decode(&lm, AttnConfig::fp4(), b"", 3, 64).unwrap();
        assert_eq!(out[0], b' ');
        assert!(out.len() >= 2);
    }
}
