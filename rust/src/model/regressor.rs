//! The Figure-3 attention-regression task as a [`TrainableModel`].
//!
//! This is the old `qat::NativeTrainer`'s model, extracted: a frozen f32
//! teacher attention generates targets and a student with trainable
//! Q/K/V projections chases them through the configured forward/backward.
//! The step math — rng splits, batch synthesis, matmul order, loss
//! accumulation, gradient chain — is an **exact port**, so a
//! [`TrainSession`] configured with [`super::TrainConfig::sgd`] at the
//! `TrainerConfig`'s lr/momentum reproduces the old trainer's
//! `StepMetrics` history bitwise (pinned by the deprecated shim's tests).
//!
//! Why this reproduces the paper's instability: the student starts *at*
//! the teacher (the finetune setting), so the only initial loss is FP4
//! quantization error. The drop-in backward recomputes S from the raw f32
//! Q/K while the forward ran on quantized ones — `P = exp(S_raw − lse_quant)`
//! overshoots wherever quantization moved a score down, and the naive
//! `D = rowsum(dO ∘ O)` adds a spurious non-cancelling component to every
//! dS row (Fix B's missing term). Both biases grow with |S|, larger weights
//! mean larger |S|, and at the Fig-3 learning rate the feedback loop spikes
//! the grad norm and diverges — while the matched Attn-QAT backward trains
//! through the identical forward without incident.

use crate::attention::{AttnConfig, AttnEngine};
use crate::qat::flash_backward_cfg;
use crate::qat::TrainerConfig;
use crate::rng::Rng;

use super::modules::{matmul, matmul_tn};
use super::session::{TrainConfig, TrainSession, TrainableModel};

/// One trainable projection (weights + gradient accumulator).
#[derive(Clone)]
struct Proj {
    w: Vec<f32>,
    g: Vec<f32>,
}

impl Proj {
    fn new(w: Vec<f32>) -> Proj {
        let g = vec![0.0f32; w.len()];
        Proj { w, g }
    }
}

/// Teacher-regression over one attention layer (the Fig-3 harness).
pub struct AttnRegressor {
    pub cfg: TrainerConfig,
    /// The unified attention config driving the student's forward and the
    /// backward ablation switches (causal flag forced to `cfg.causal`).
    pub attn: AttnConfig,
    /// Student attention session (the variant's engine).
    engine: AttnEngine,
    /// Frozen f32 teacher session.
    teacher: AttnEngine,
    wq: Proj,
    wk: Proj,
    wv: Proj,
    /// Frozen teacher projections (the "pretrained base").
    tq: Vec<f32>,
    tk: Vec<f32>,
    tv: Vec<f32>,
    data: Rng,
}

impl AttnRegressor {
    /// Build the task from an explicit [`AttnConfig`]; `cfg.causal`
    /// overrides the config's causal flag so teacher and student always
    /// agree with the task setting. Rng splits match the old trainer.
    pub fn new(cfg: TrainerConfig, attn: AttnConfig) -> AttnRegressor {
        let attn = attn.with_causal(cfg.causal);
        let (dm, dh) = (cfg.d_model, cfg.d_head);
        assert_eq!(dh % 16, 0, "d_head must be a multiple of 16");
        let root = Rng::new(cfg.seed);
        let std = 1.0 / (dm as f32).sqrt();
        let mut teacher = root.split("teacher");
        let tq = teacher.normal_vec(dm * dh, 0.0, std);
        let tk = teacher.normal_vec(dm * dh, 0.0, std);
        let tv = teacher.normal_vec(dm * dh, 0.0, std);
        let (mut wq, mut wk, mut wv) = (tq.clone(), tk.clone(), tv.clone());
        if cfg.init_jitter > 0.0 {
            let mut init = root.split("init");
            for w in [&mut wq, &mut wk, &mut wv] {
                for (x, j) in w.iter_mut().zip(init.normal_vec(dm * dh, 0.0, cfg.init_jitter)) {
                    *x += j;
                }
            }
        }
        let data = root.split("data");
        AttnRegressor {
            cfg,
            attn,
            engine: AttnEngine::new(attn),
            teacher: AttnEngine::new(AttnConfig::f32().with_causal(attn.causal)),
            wq: Proj::new(wq),
            wk: Proj::new(wk),
            wv: Proj::new(wv),
            tq,
            tk,
            tv,
            data,
        }
    }

    /// The Fig-3 session preset: this task under SGD+momentum at the
    /// `TrainerConfig`'s constant lr — exactly the optimizer the old
    /// `NativeTrainer` hand-rolled, so histories match it bitwise.
    pub fn session(cfg: TrainerConfig, attn: AttnConfig) -> TrainSession<AttnRegressor> {
        let train = TrainConfig::sgd(cfg.lr, cfg.momentum);
        TrainSession::new(AttnRegressor::new(cfg, attn), train)
    }
}

impl TrainableModel for AttnRegressor {
    fn train_step(&mut self) -> f32 {
        let (n, dm, dh) = (self.cfg.n, self.cfg.d_model, self.cfg.d_head);

        // Heavy-tailed batch: N(0,1) with every 8th feature amplified.
        let mut x = self.data.normal_vec(n * dm, 0.0, 1.0);
        for r in 0..n {
            for c in (0..dm).step_by(8) {
                x[r * dm + c] *= self.cfg.outlier;
            }
        }

        // Teacher target (always f32).
        let qs = matmul(&x, &self.tq, n, dm, dh);
        let ks = matmul(&x, &self.tk, n, dm, dh);
        let vs = matmul(&x, &self.tv, n, dm, dh);
        let y = self.teacher.forward(&qs, &ks, &vs, 1, n, n, dh).o;

        // Student training forward through the session's engine (for f32
        // sessions O′ == O, so one call covers every variant).
        let q = matmul(&x, &self.wq.w, n, dm, dh);
        let k = matmul(&x, &self.wk.w, n, dm, dh);
        let v = matmul(&x, &self.wv.w, n, dm, dh);
        let t = self.engine.forward_train(&q, &k, &v, 1, n, n, dh);
        let (o, o_prime, lse) = (t.o, t.o_prime, t.lse);

        // MSE on the quantized-path output.
        let numel = (n * dh) as f32;
        let mut loss_acc = 0.0f64;
        let mut dout = vec![0.0f32; n * dh];
        for (g, (&oc, &yc)) in dout.iter_mut().zip(o.iter().zip(&y)) {
            let e = oc - yc;
            loss_acc += e as f64 * e as f64;
            *g = 2.0 * e / numel;
        }
        let loss = (loss_acc / numel as f64) as f32;

        // Attention backward (STE grads w.r.t. raw Q/K/V) → weight grads.
        let g = flash_backward_cfg(
            &self.attn, &q, &k, &v, n, n, dh, &o, &o_prime, &lse, &dout,
        );
        self.wq.g.copy_from_slice(&matmul_tn(&x, &g.dq, n, dm, dh));
        self.wk.g.copy_from_slice(&matmul_tn(&x, &g.dk, n, dm, dh));
        self.wv.g.copy_from_slice(&matmul_tn(&x, &g.dv, n, dm, dh));
        loss
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        f(&mut self.wq.w, &mut self.wq.g);
        f(&mut self.wk.w, &mut self.wk.g);
        f(&mut self.wv.w, &mut self.wv.g);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qat::QatVariant;

    #[test]
    fn regressor_session_reproduces_fig3_extremes() {
        // The paper's headline training-dynamics result through the new
        // session API: Attn-QAT stable, drop-in spikes/diverges.
        let steps = 150;
        let mut qat = AttnRegressor::session(
            TrainerConfig::default(),
            QatVariant::AttnQat.config(),
        );
        qat.run(steps, 0, |_| {});
        assert!(!qat.diverged(), "Attn-QAT must not diverge");
        assert!(qat.max_grad_norm() < 50.0, "gnorm {}", qat.max_grad_norm());

        let mut dropin = AttnRegressor::session(
            TrainerConfig::default(),
            QatVariant::DropIn.config(),
        );
        dropin.run(steps, 0, |_| {});
        assert!(
            dropin.diverged() || dropin.max_grad_norm() > 100.0,
            "drop-in QAT should spike/diverge; max gnorm {}",
            dropin.max_grad_norm()
        );
    }

    #[test]
    fn deterministic_across_sessions() {
        let mk = || {
            AttnRegressor::session(TrainerConfig::default(), QatVariant::AttnQat.config())
        };
        let (mut a, mut b) = (mk(), mk());
        a.run(5, 0, |_| {});
        b.run(5, 0, |_| {});
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(x.loss, y.loss);
            assert_eq!(x.grad_norm, y.grad_norm);
        }
    }
}
