//! Composable trainable modules: the building blocks of [`super::QatModel`].
//!
//! Each module owns its parameters **and** their gradient accumulators as
//! plain f32 buffers, exposed through the [`Module`] trait's
//! `visit_params` — the parameter+gradient views the
//! [`super::TrainSession`] optimizer and grad-clip loop consume. Forward
//! passes take `&self` (so the same weights serve inference through
//! `serve::model::TokenModel`); backward passes take `&mut self` and
//! *accumulate* into the grad buffers, which the session zeroes at the
//! start of every step.
//!
//! The row-level kernels ([`rms_norm`], [`vec_mat_acc`]) are the single
//! definitions shared with `serve::model::SimLm`, so a `QatModel`'s
//! non-attention serving math is the training forward's math — only the
//! attention kernel differs between the two (engine training forward vs
//! paged FP4 decode).
//!
//! All backward formulas are pinned by finite differences in
//! `rust/tests/grad_check.rs` (module level) and by the whole-model FD
//! check simulated for the `model` subsystem (worst relative error ~2e-8
//! in f64; the f32 asserts carry orders-of-magnitude margins).

/// RMS-normalization epsilon (matches `serve::model::SimLm`).
pub const RMS_EPS: f32 = 1e-6;

/// RMS-normalize `x` into `out` (same length).
pub fn rms_norm(x: &[f32], out: &mut [f32]) {
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + RMS_EPS).sqrt();
    for (o, &v) in out.iter_mut().zip(x) {
        *o = v * inv;
    }
}

/// [`rms_norm`] over `(rows × d)` row-major views.
pub fn rms_norm_rows(x: &[f32], d: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(x.len() % d, 0);
    for (xr, or) in x.chunks(d).zip(out.chunks_mut(d)) {
        rms_norm(xr, or);
    }
}

/// Backward of [`rms_norm`]: with `y = x·inv`, `inv = (mean(x²)+ε)^-1/2`,
///
/// ```text
/// dx_j += dy_j·inv − x_j·inv³·(Σ_i dy_i·x_i)/n
/// ```
///
/// **Accumulates** into `dx`.
pub fn rms_norm_bwd(x: &[f32], dy: &[f32], dx: &mut [f32]) {
    let n = x.len();
    debug_assert!(dy.len() == n && dx.len() == n);
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / n as f32;
    let inv = 1.0 / (ms + RMS_EPS).sqrt();
    let dot: f32 = dy.iter().zip(x).map(|(&g, &v)| g * v).sum();
    let c = inv * inv * inv * dot / n as f32;
    for ((o, &g), &v) in dx.iter_mut().zip(dy).zip(x) {
        *o += g * inv - v * c;
    }
}

/// [`rms_norm_bwd`] over `(rows × d)` row-major views (accumulating).
pub fn rms_norm_bwd_rows(x: &[f32], dy: &[f32], d: usize, dx: &mut [f32]) {
    debug_assert!(x.len() == dy.len() && x.len() == dx.len());
    for ((xr, gr), or) in x.chunks(d).zip(dy.chunks(d)).zip(dx.chunks_mut(d)) {
        rms_norm_bwd(xr, gr, or);
    }
}

/// `out[p] += Σ_m x[m]·w[m·p_dim + p]` — row-vector × matrix accumulate
/// (the serving-side kernel, shared with `serve::model`).
pub fn vec_mat_acc(x: &[f32], w: &[f32], p_dim: usize, out: &mut [f32]) {
    for (m, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let wrow = &w[m * p_dim..(m + 1) * p_dim];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += xv * wv;
        }
    }
}

/// `(n×m) · (m×p)` row-major f32 matmul (the training-side batch kernel;
/// same accumulation order as the original native trainer's).
pub(crate) fn matmul(a: &[f32], b: &[f32], n: usize, m: usize, p: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * p];
    for i in 0..n {
        for kk in 0..m {
            let aik = a[i * m + kk];
            let brow = &b[kk * p..(kk + 1) * p];
            let orow = &mut out[i * p..(i + 1) * p];
            for (x, &bv) in orow.iter_mut().zip(brow) {
                *x += aik * bv;
            }
        }
    }
    out
}

/// `aᵀ · b` for `a (n×m)`, `b (n×p)` → `(m×p)` (the projection-weight
/// chain rule dW = Xᵀ·dY).
pub(crate) fn matmul_tn(a: &[f32], b: &[f32], n: usize, m: usize, p: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * p];
    for i in 0..n {
        for kk in 0..m {
            let aik = a[i * m + kk];
            let brow = &b[i * p..(i + 1) * p];
            let orow = &mut out[kk * p..(kk + 1) * p];
            for (x, &bv) in orow.iter_mut().zip(brow) {
                *x += aik * bv;
            }
        }
    }
    out
}

/// Token-major `(n × heads·hd)` → head-major `(heads × n × hd)` — the
/// staging the attention engines' multi-head views expect.
pub(crate) fn to_head_major(x: &[f32], n: usize, heads: usize, hd: usize) -> Vec<f32> {
    let d = heads * hd;
    let mut out = vec![0.0f32; x.len()];
    for h in 0..heads {
        for i in 0..n {
            let src = i * d + h * hd;
            let dst = h * n * hd + i * hd;
            out[dst..dst + hd].copy_from_slice(&x[src..src + hd]);
        }
    }
    out
}

/// Head-major `(heads × n × hd)` → token-major `(n × heads·hd)`.
pub(crate) fn to_token_major(x: &[f32], n: usize, heads: usize, hd: usize) -> Vec<f32> {
    let d = heads * hd;
    let mut out = vec![0.0f32; x.len()];
    for h in 0..heads {
        for i in 0..n {
            let src = h * n * hd + i * hd;
            let dst = i * d + h * hd;
            out[dst..dst + hd].copy_from_slice(&x[src..src + hd]);
        }
    }
    out
}

/// A parameter-owning module: every trainable tensor is exposed as a
/// `(weights, gradients)` slice pair in a stable order.
pub trait Module {
    /// Visit every (param, grad) pair. The order is fixed per type — the
    /// optimizer keys its per-tensor state on the visit index.
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32]));

    /// Zero every gradient accumulator.
    fn zero_grad(&mut self) {
        self.visit_params(&mut |_, g| g.fill(0.0));
    }
}

/// A dense projection `y = x·W` with `W` `(in_dim × out_dim)` row-major —
/// the layout `serve::model::SimLm` serves with.
#[derive(Clone, Debug)]
pub struct Linear {
    pub w: Vec<f32>,
    pub g: Vec<f32>,
    pub in_dim: usize,
    pub out_dim: usize,
}

impl Linear {
    pub fn new(w: Vec<f32>, in_dim: usize, out_dim: usize) -> Linear {
        assert_eq!(w.len(), in_dim * out_dim);
        let g = vec![0.0f32; w.len()];
        Linear { w, g, in_dim, out_dim }
    }

    /// `out = x·W` over `n` rows (`out` is overwritten).
    pub fn forward(&self, x: &[f32], n: usize, out: &mut [f32]) {
        debug_assert_eq!(x.len(), n * self.in_dim);
        debug_assert_eq!(out.len(), n * self.out_dim);
        out.fill(0.0);
        self.forward_acc(x, n, out);
    }

    /// `out += x·W` over `n` rows (residual-style accumulate).
    pub fn forward_acc(&self, x: &[f32], n: usize, out: &mut [f32]) {
        debug_assert_eq!(x.len(), n * self.in_dim);
        debug_assert_eq!(out.len(), n * self.out_dim);
        for (xr, or) in x.chunks(self.in_dim).zip(out.chunks_mut(self.out_dim)) {
            vec_mat_acc(xr, &self.w, self.out_dim, or);
        }
    }

    /// Backward over `n` rows: accumulates `g += xᵀ·dy` and (when `dx` is
    /// given) `dx += dy·Wᵀ`.
    pub fn backward(&mut self, x: &[f32], dy: &[f32], n: usize, mut dx: Option<&mut [f32]>) {
        debug_assert_eq!(x.len(), n * self.in_dim);
        debug_assert_eq!(dy.len(), n * self.out_dim);
        let (ind, outd) = (self.in_dim, self.out_dim);
        for r in 0..n {
            let xr = &x[r * ind..(r + 1) * ind];
            let dyr = &dy[r * outd..(r + 1) * outd];
            for (m, &xv) in xr.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let grow = &mut self.g[m * outd..(m + 1) * outd];
                for (gg, &dv) in grow.iter_mut().zip(dyr) {
                    *gg += xv * dv;
                }
            }
            if let Some(dx) = dx.as_deref_mut() {
                debug_assert_eq!(dx.len(), n * ind);
                let dxr = &mut dx[r * ind..(r + 1) * ind];
                for (m, o) in dxr.iter_mut().enumerate() {
                    let wrow = &self.w[m * outd..(m + 1) * outd];
                    let mut acc = 0.0f32;
                    for (&wv, &dv) in wrow.iter().zip(dyr) {
                        acc += wv * dv;
                    }
                    *o += acc;
                }
            }
        }
    }
}

impl Module for Linear {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        f(&mut self.w, &mut self.g);
    }
}

/// Token + positional embedding table (byte vocabulary).
#[derive(Clone, Debug)]
pub struct Embedding {
    pub tok: Vec<f32>,
    pub tok_g: Vec<f32>,
    pub pos: Vec<f32>,
    pub pos_g: Vec<f32>,
    pub d: usize,
    pub max_pos: usize,
    pub vocab: usize,
}

impl Embedding {
    pub fn new(tok: Vec<f32>, pos: Vec<f32>, d: usize, max_pos: usize) -> Embedding {
        assert_eq!(tok.len() % d, 0);
        assert_eq!(pos.len(), max_pos * d);
        let vocab = tok.len() / d;
        let (tok_g, pos_g) = (vec![0.0f32; tok.len()], vec![0.0f32; pos.len()]);
        Embedding { tok, tok_g, pos, pos_g, d, max_pos, vocab }
    }

    /// `h[i] = tok[tokens[i]] + pos[(pos0+i) mod max_pos]`.
    pub fn forward(&self, tokens: &[u8], pos0: usize, h: &mut [f32]) {
        let d = self.d;
        debug_assert_eq!(h.len(), tokens.len() * d);
        for (i, &tok) in tokens.iter().enumerate() {
            let row = &mut h[i * d..(i + 1) * d];
            let te = &self.tok[tok as usize * d..(tok as usize + 1) * d];
            let p = (pos0 + i) % self.max_pos;
            let pe = &self.pos[p * d..(p + 1) * d];
            for ((o, &t), &pv) in row.iter_mut().zip(te).zip(pe) {
                *o = t + pv;
            }
        }
    }

    /// Scatter-accumulate `dh` rows back into the tables' gradients.
    pub fn backward(&mut self, tokens: &[u8], pos0: usize, dh: &[f32]) {
        let d = self.d;
        debug_assert_eq!(dh.len(), tokens.len() * d);
        for (i, &tok) in tokens.iter().enumerate() {
            let row = &dh[i * d..(i + 1) * d];
            let tg = &mut self.tok_g[tok as usize * d..(tok as usize + 1) * d];
            for (g, &v) in tg.iter_mut().zip(row) {
                *g += v;
            }
            let p = (pos0 + i) % self.max_pos;
            let pg = &mut self.pos_g[p * d..(p + 1) * d];
            for (g, &v) in pg.iter_mut().zip(row) {
                *g += v;
            }
        }
    }
}

impl Module for Embedding {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        f(&mut self.tok, &mut self.tok_g);
        f(&mut self.pos, &mut self.pos_g);
    }
}

/// Pre-norm tanh feed-forward with residual:
/// `h ← h + tanh(rms(h)·W_in)·W_out` (the `SimLm` MLP shape).
#[derive(Clone, Debug)]
pub struct Mlp {
    pub win: Linear,
    pub wout: Linear,
}

/// Residual-branch activations [`Mlp::forward_train`] caches for backward.
#[derive(Clone, Debug)]
pub struct MlpActs {
    /// rms-normed input rows (`n × d`).
    pub xn: Vec<f32>,
    /// post-tanh hidden rows (`n × ff`).
    pub f: Vec<f32>,
}

impl Mlp {
    pub fn new(win: Linear, wout: Linear) -> Mlp {
        assert_eq!(win.out_dim, wout.in_dim);
        assert_eq!(win.in_dim, wout.out_dim);
        Mlp { win, wout }
    }

    /// Inference forward, in place on `h` (`n × d`).
    pub fn forward(&self, h: &mut [f32], n: usize) {
        let d = self.win.in_dim;
        let ff = self.win.out_dim;
        debug_assert_eq!(h.len(), n * d);
        let mut xn = vec![0.0f32; d];
        let mut f = vec![0.0f32; ff];
        for hr in h.chunks_mut(d) {
            rms_norm(hr, &mut xn);
            f.fill(0.0);
            vec_mat_acc(&xn, &self.win.w, ff, &mut f);
            for x in f.iter_mut() {
                *x = x.tanh();
            }
            vec_mat_acc(&f, &self.wout.w, d, hr);
        }
    }

    /// Training forward, in place on `h`; returns the branch activations.
    /// Bitwise identical to [`Mlp::forward`] (same per-row kernels).
    pub fn forward_train(&self, h: &mut [f32], n: usize) -> MlpActs {
        let d = self.win.in_dim;
        let ff = self.win.out_dim;
        debug_assert_eq!(h.len(), n * d);
        let mut xn = vec![0.0f32; n * d];
        let mut f = vec![0.0f32; n * ff];
        for ((hr, xr), fr) in h.chunks_mut(d).zip(xn.chunks_mut(d)).zip(f.chunks_mut(ff)) {
            rms_norm(hr, xr);
            vec_mat_acc(xr, &self.win.w, ff, fr);
            for x in fr.iter_mut() {
                *x = x.tanh();
            }
            vec_mat_acc(fr, &self.wout.w, d, hr);
        }
        MlpActs { xn, f }
    }

    /// Backward: `dh` holds dL/d(output); on return it holds dL/d(input)
    /// (residual term plus the branch's chain through the norm). `h_in`
    /// is the block *input* (pre-residual) the forward normed.
    pub fn backward(&mut self, h_in: &[f32], acts: &MlpActs, dh: &mut [f32], n: usize) {
        let d = self.win.in_dim;
        let ff = self.win.out_dim;
        debug_assert_eq!(h_in.len(), n * d);
        debug_assert_eq!(dh.len(), n * d);
        let mut df = vec![0.0f32; n * ff];
        self.wout.backward(&acts.f, dh, n, Some(&mut df));
        // tanh'(x) = 1 − f².
        for (dfv, &fv) in df.iter_mut().zip(&acts.f) {
            *dfv *= 1.0 - fv * fv;
        }
        let mut dxn = vec![0.0f32; n * d];
        self.win.backward(&acts.xn, &df, n, Some(&mut dxn));
        rms_norm_bwd_rows(h_in, &dxn, d, dh);
    }
}

impl Module for Mlp {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        self.win.visit_params(f);
        self.wout.visit_params(f);
    }
}

/// Mean cross-entropy over next-token logits: returns `(loss, dlogits)`
/// with `dlogits = (softmax − onehot)/rows` — the gradient `QatModel`'s
/// backward consumes.
pub fn cross_entropy(logits: &[f32], vocab: usize, targets: &[u8]) -> (f32, Vec<f32>) {
    let rows = targets.len();
    debug_assert_eq!(logits.len(), rows * vocab);
    let mut dl = vec![0.0f32; logits.len()];
    let mut loss = 0.0f64;
    let inv = 1.0 / rows as f32;
    for (i, &t) in targets.iter().enumerate() {
        let row = &logits[i * vocab..(i + 1) * vocab];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let l: f32 = row.iter().map(|&x| (x - m).exp()).sum();
        let lse = m + l.ln();
        loss += (lse - row[t as usize]) as f64;
        let drow = &mut dl[i * vocab..(i + 1) * vocab];
        for (g, &x) in drow.iter_mut().zip(row) {
            *g = (x - lse).exp() * inv;
        }
        drow[t as usize] -= inv;
    }
    ((loss / rows as f64) as f32, dl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn linear_forward_backward_shapes_and_simple_values() {
        // 1×2 · (2×3): y = [x0·w00 + x1·w10, ...]; dW = xᵀdy; dx = dy·Wᵀ.
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut lin = Linear::new(w, 2, 3);
        let x = vec![2.0f32, -1.0];
        let mut y = vec![0.0f32; 3];
        lin.forward(&x, 1, &mut y);
        assert_eq!(y, vec![2.0 - 4.0, 4.0 - 5.0, 6.0 - 6.0]);
        let dy = vec![1.0f32, 0.0, -1.0];
        let mut dx = vec![0.0f32; 2];
        lin.backward(&x, &dy, 1, Some(&mut dx));
        assert_eq!(dx, vec![1.0 - 3.0, 4.0 - 6.0]);
        assert_eq!(lin.g, vec![2.0, 0.0, -2.0, -1.0, 0.0, 1.0]);
        // zero_grad clears the accumulators.
        lin.zero_grad();
        assert!(lin.g.iter().all(|&g| g == 0.0));
    }

    #[test]
    fn rms_norm_row_and_bwd_finiteness() {
        let mut rng = Rng::new(3);
        let x = rng.normal_vec(24, 0.0, 2.0);
        let mut y = vec![0.0f32; 24];
        rms_norm_rows(&x, 8, &mut y);
        // Each row has (approximately) unit RMS.
        for row in y.chunks(8) {
            let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / 8.0;
            assert!((ms - 1.0).abs() < 1e-3, "{ms}");
        }
        let dy = rng.normal_vec(24, 0.0, 1.0);
        let mut dx = vec![0.0f32; 24];
        rms_norm_bwd_rows(&x, &dy, 8, &mut dx);
        assert!(dx.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn cross_entropy_uniform_logits() {
        // All-zero logits: loss = ln(V); dl = (1/V − onehot)/rows.
        let vocab = 8;
        let targets = [3u8, 5u8];
        let logits = vec![0.0f32; 2 * vocab];
        let (loss, dl) = cross_entropy(&logits, vocab, &targets);
        assert!((loss - (vocab as f32).ln()).abs() < 1e-6, "{loss}");
        for (i, &t) in targets.iter().enumerate() {
            for j in 0..vocab {
                let uniform = 1.0 / vocab as f32;
                let base = if j == t as usize { uniform - 1.0 } else { uniform };
                assert!((dl[i * vocab + j] - base / 2.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn matmul_helpers_agree_with_vec_mat_acc() {
        let (n, m, p) = (3, 4, 5);
        let mut rng = Rng::new(4);
        let a = rng.normal_vec(n * m, 0.0, 1.0);
        let b = rng.normal_vec(m * p, 0.0, 1.0);
        let want = matmul(&a, &b, n, m, p);
        let lin = Linear::new(b.clone(), m, p);
        let mut got = vec![0.0f32; n * p];
        lin.forward(&a, n, &mut got);
        assert_eq!(got, want, "Linear::forward must match the batch matmul");
        // matmul_tn is the dW chain rule: (aᵀ·a) symmetric sanity.
        let tn = matmul_tn(&a, &a, n, m, m);
        for i in 0..m {
            for j in 0..m {
                assert!((tn[i * m + j] - tn[j * m + i]).abs() < 1e-5);
            }
        }
    }
}
