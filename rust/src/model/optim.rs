//! Optimizers behind one trait: SGD+momentum (ported from the old
//! `NativeTrainer`'s hand-rolled update, bitwise) and Adam (the paper's
//! finetune recipe, used with the session's global grad-clip).
//!
//! Per-tensor state (momentum / moment buffers) is keyed on the visit
//! index the session assigns while walking `Module::visit_params` — the
//! visit order is stable per model type, so state lines up across steps.
//! Buffers are sized lazily on first use.

use super::lowp::LowPStats;

/// A snapshot of an optimizer's full mutable state, for the training
/// watchdog's rollback and the v3 checkpoint's optimizer section:
/// `step` is Adam's bias-correction counter (0 for SGD), `slots` the
/// per-kind f32 state buffers (SGD: `[vel]`; Adam: `[m, v]`; LowPAdam:
/// per-tensor moment *scales*), each indexed per tensor, and
/// `byte_slots` raw byte-buffer state (LowPAdam's E4M3 moment bytes,
/// verbatim — empty for f32 optimizers).
#[derive(Clone, Debug, Default)]
pub struct OptimizerState {
    pub step: i32,
    pub slots: Vec<Vec<Vec<f32>>>,
    pub byte_slots: Vec<Vec<Vec<u8>>>,
}

/// One optimizer step over a model's parameter tensors.
pub trait Optimizer: Send {
    /// Called once per training step, before any [`Optimizer::update`]
    /// (Adam advances its bias-correction step count here).
    fn begin_step(&mut self) {}

    /// Update parameter tensor `idx` in place from its gradient.
    fn update(&mut self, idx: usize, w: &mut [f32], g: &[f32], lr: f32);

    /// Capture the full mutable state (for watchdog rollback).
    fn snapshot(&self) -> OptimizerState;

    /// Restore a state captured by [`Optimizer::snapshot`].
    fn restore(&mut self, state: &OptimizerState);

    /// Bytes of optimizer state currently held (0 until sized on first
    /// use; the figure of merit for low-precision moment storage).
    fn state_bytes(&self) -> usize {
        0
    }

    /// Low-precision health of the last step, when the optimizer tracks
    /// it ([`super::LowPAdam`] does; f32 optimizers return `None`).
    fn lowp_stats(&self) -> Option<LowPStats> {
        None
    }
}

/// SGD with momentum: `v ← μ·v + g`, `w ← w − lr·v` — element-for-element
/// the update the deprecated `qat::NativeTrainer` applied, so a session
/// configured with it reproduces the old trainer's history bitwise.
pub struct Sgd {
    momentum: f32,
    vel: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(momentum: f32) -> Sgd {
        Sgd { momentum, vel: Vec::new() }
    }
}

impl Optimizer for Sgd {
    fn update(&mut self, idx: usize, w: &mut [f32], g: &[f32], lr: f32) {
        while self.vel.len() <= idx {
            self.vel.push(Vec::new());
        }
        let v = &mut self.vel[idx];
        if v.len() != g.len() {
            v.clear();
            v.resize(g.len(), 0.0);
        }
        let mu = self.momentum;
        for ((w, v), &gx) in w.iter_mut().zip(v.iter_mut()).zip(g) {
            *v = mu * *v + gx;
            *w -= lr * *v;
        }
    }

    fn snapshot(&self) -> OptimizerState {
        OptimizerState { step: 0, slots: vec![self.vel.clone()], byte_slots: Vec::new() }
    }

    fn restore(&mut self, state: &OptimizerState) {
        self.vel = state.slots.first().cloned().unwrap_or_default();
    }

    fn state_bytes(&self) -> usize {
        self.vel.iter().map(|v| 4 * v.len()).sum()
    }
}

/// Adam (Kingma & Ba) with bias correction:
///
/// ```text
/// m ← β₁m + (1−β₁)g        v ← β₂v + (1−β₂)g²
/// w ← w − lr · (m/(1−β₁ᵗ)) / (√(v/(1−β₂ᵗ)) + ε)
/// ```
///
/// Pinned by the single-step golden in `rust/tests/grad_check.rs` (first
/// step moves every weight by `≈ lr·sign(g)`).
pub struct Adam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: i32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    /// The standard defaults (β₁ 0.9, β₂ 0.999, ε 1e-8).
    pub fn new() -> Adam {
        Adam::with_params(0.9, 0.999, 1e-8)
    }

    pub fn with_params(beta1: f32, beta2: f32, eps: f32) -> Adam {
        Adam { beta1, beta2, eps, t: 0, m: Vec::new(), v: Vec::new() }
    }
}

impl Default for Adam {
    fn default() -> Adam {
        Adam::new()
    }
}

impl Optimizer for Adam {
    fn begin_step(&mut self) {
        self.t += 1;
    }

    fn update(&mut self, idx: usize, w: &mut [f32], g: &[f32], lr: f32) {
        while self.m.len() <= idx {
            self.m.push(Vec::new());
            self.v.push(Vec::new());
        }
        if self.m[idx].len() != g.len() {
            self.m[idx].clear();
            self.m[idx].resize(g.len(), 0.0);
            self.v[idx].clear();
            self.v[idx].resize(g.len(), 0.0);
        }
        let t = self.t.max(1);
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let bc1 = 1.0 - b1.powi(t);
        let bc2 = 1.0 - b2.powi(t);
        let (ms, vs) = (&mut self.m[idx], &mut self.v[idx]);
        for (((w, m), v), &gx) in w.iter_mut().zip(ms.iter_mut()).zip(vs.iter_mut()).zip(g) {
            *m = b1 * *m + (1.0 - b1) * gx;
            *v = b2 * *v + (1.0 - b2) * gx * gx;
            let mh = *m / bc1;
            let vh = *v / bc2;
            *w -= lr * mh / (vh.sqrt() + eps);
        }
    }

    fn snapshot(&self) -> OptimizerState {
        OptimizerState {
            step: self.t,
            slots: vec![self.m.clone(), self.v.clone()],
            byte_slots: Vec::new(),
        }
    }

    fn restore(&mut self, state: &OptimizerState) {
        self.t = state.step;
        self.m = state.slots.first().cloned().unwrap_or_default();
        self.v = state.slots.get(1).cloned().unwrap_or_default();
    }

    fn state_bytes(&self) -> usize {
        // Two f32 moments per parameter: 8 bytes/param once sized.
        self.m.iter().chain(self.v.iter()).map(|v| 4 * v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_matches_hand_rolled_update() {
        let mut opt = Sgd::new(0.9);
        let mut w = vec![1.0f32, 2.0];
        let g = vec![0.5f32, -0.5];
        opt.update(0, &mut w, &g, 0.1);
        // v = g; w -= 0.1·v.
        assert_eq!(w, vec![1.0 - 0.05, 2.0 + 0.05]);
        opt.update(0, &mut w, &g, 0.1);
        // v = 0.9·0.5 + 0.5 = 0.95.
        assert!((w[0] - (0.95 - 0.095)).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        // With fresh moments, mhat/(√vhat+ε) = g/(|g|+ε′) ≈ sign(g).
        let mut opt = Adam::new();
        opt.begin_step();
        let mut w = vec![0.0f32, 0.0];
        let g = vec![3.0f32, -0.001];
        opt.update(0, &mut w, &g, 0.01);
        assert!((w[0] + 0.01).abs() < 1e-5, "{}", w[0]);
        assert!((w[1] - 0.01).abs() < 1e-4, "{}", w[1]);
    }

    #[test]
    fn snapshot_restore_roundtrips_adam_state() {
        let mut opt = Adam::new();
        opt.begin_step();
        let mut w = vec![0.0f32, 0.0];
        opt.update(0, &mut w, &[1.0, -2.0], 0.01);
        let snap = opt.snapshot();
        let w_snap = w.clone();
        opt.begin_step();
        opt.update(0, &mut w, &[5.0, 5.0], 0.01);
        let diverged = w.clone();
        assert_ne!(diverged, w_snap);
        // Restore moments + step count, replay the same step: bitwise
        // identical trajectory — the watchdog's rollback contract.
        opt.restore(&snap);
        let mut w2 = w_snap.clone();
        opt.begin_step();
        opt.update(0, &mut w2, &[5.0, 5.0], 0.01);
        assert_eq!(w2, diverged);
    }

    #[test]
    fn per_tensor_state_is_independent() {
        let mut opt = Sgd::new(0.5);
        let (mut w0, mut w1) = (vec![0.0f32], vec![0.0f32]);
        opt.update(0, &mut w0, &[1.0], 1.0);
        opt.update(1, &mut w1, &[1.0], 1.0);
        opt.update(0, &mut w0, &[0.0], 1.0);
        // Tensor 0's momentum (0.5) applies only to tensor 0.
        assert_eq!(w0[0], -1.5);
        assert_eq!(w1[0], -1.0);
    }
}
