//! [`QatModel`]: the native multi-head, multi-layer pre-norm transformer
//! that closes the repo's train→serve loop.
//!
//! ```text
//! h = tok_emb[token] + pos_emb[pos]                      Embedding
//! for layer l:   xn = rms(h)                             ┐
//!                q,k,v = xn·Wq, xn·Wk, xn·Wv             │ attention block
//!                a = AttnEngine(attn[l]).forward_train   │ (per-layer AttnConfig)
//!                h += a·Wo                               ┘
//!                h += tanh(rms(h)·W_in)·W_out            Mlp
//! logits = rms(h)·W_head
//! ```
//!
//! **Training** runs attention through the layer's
//! [`AttnEngine::forward_train`] and backpropagates through
//! `qat::flash_backward_cfg` with that layer's [`AttnConfig`] — so the
//! Fig-3 `BwdSwitches` ablations (and smoothing / two-level P̃) apply *per
//! layer*. **Serving** is the [`TokenModel`] impl: the same weights and
//! the same per-row kernels (`rms_norm`, `vec_mat_acc`) drive
//! `serve::ShardWorker` / `DecodeCluster` over the paged FP4 KV cache —
//! only the attention kernel differs between the two paths (engine
//! training forward vs paged decode), exactly like a real deployment.
//!
//! [`QatModel::save_quantized`] / [`QatModel::load`] round-trip the
//! weights through the `coordinator::checkpoint` container with every
//! transformer projection **fake-quantized onto the NVFP4 lattice**
//! (row-blocked along the output dim); embeddings and the LM head stay
//! f32, mirroring the paper's attention-focused recipe. The train→serve
//! round trip is pinned end-to-end by `rust/tests/train_serve.rs`.

use std::path::Path;

use anyhow::{anyhow, ensure, Result};

use crate::attention::{AttnConfig, AttnEngine, TrainBatch};
use crate::coordinator::checkpoint;
use crate::data::corpus::Corpus;
use crate::formats::block::nvfp4_fake_quant_row;
use crate::qat::flash_backward_cfg;
use crate::rng::Rng;
use crate::serve::model::{TokenModel, VOCAB};
use crate::telemetry::probes::e2m1_health;
use crate::telemetry::{Gauge, Telemetry};
use crate::tensor::Tensor;

use super::lowp::{self, ProjQuant, ProjQuantMode};
use super::modules::{
    cross_entropy, rms_norm, rms_norm_bwd_rows, rms_norm_rows, to_head_major, to_token_major,
    Embedding, Linear, Mlp, MlpActs, Module,
};
use super::session::TrainableModel;

/// Shape + seed + attention configuration of a [`QatModel`].
#[derive(Clone, Copy, Debug)]
pub struct QatModelConfig {
    pub layers: usize,
    pub heads: usize,
    /// Per-head width (multiple of 16 for the FP4 cache and engines).
    pub head_dim: usize,
    /// Feed-forward width (multiple of 16 for quantized export).
    pub ff: usize,
    /// Positional-embedding table length (positions wrap past it).
    pub max_pos: usize,
    pub seed: u64,
    /// Attention config applied to every layer (causal is forced on);
    /// override a single layer with [`QatModel::set_layer_attn`].
    pub attn: AttnConfig,
}

impl Default for QatModelConfig {
    fn default() -> QatModelConfig {
        QatModelConfig {
            layers: 2,
            heads: 2,
            head_dim: 16,
            ff: 64,
            max_pos: 512,
            seed: 0x9a70,
            attn: AttnConfig::attn_qat(),
        }
    }
}

/// One transformer block's parameter modules.
#[derive(Clone)]
struct Block {
    wq: Linear,
    wk: Linear,
    wv: Linear,
    wo: Linear,
    mlp: Mlp,
}

impl Block {
    fn visit(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        self.wq.visit_params(f);
        self.wk.visit_params(f);
        self.wv.visit_params(f);
        self.wo.visit_params(f);
        self.mlp.visit_params(f);
    }
}

/// The trainable + servable transformer (see module docs).
#[derive(Clone)]
pub struct QatModel {
    cfg: QatModelConfig,
    emb: Embedding,
    blocks: Vec<Block>,
    head: Linear,
    /// Per-layer attention configs (causal always on).
    attn: Vec<AttnConfig>,
    /// Projection-quantization policy (off by default — the pre-existing
    /// f32-projection behaviour). Set with [`QatModel::set_proj_quant`];
    /// composes freely with the per-layer attention configs. The LM head
    /// always stays f32.
    proj: ProjQuant,
}

/// Per-layer activation caches from [`QatModel::forward_train`].
struct BlockActs {
    /// Block input rows (`n × d`) — the residual stream before attention.
    h_in: Vec<f32>,
    /// rms-normed input rows.
    xn1: Vec<f32>,
    /// Raw projected Q/K/V, head-major `(heads × n × hd)`.
    qhm: Vec<f32>,
    khm: Vec<f32>,
    vhm: Vec<f32>,
    /// Engine training-forward residuals (O, O′, lse — head-major).
    train: TrainBatch,
    /// Attention output, token-major (`n × d`).
    ao: Vec<f32>,
    /// Residual stream after the attention sub-block (MLP input).
    h_mid: Vec<f32>,
    mlp: MlpActs,
    /// The fake-quantized projection weights this layer's forward used
    /// (`Some` only under [`ProjQuantMode::Ste`]) — backward multiplies
    /// by exactly these, never a re-quantized copy (matched recompute).
    qw: Option<lowp::QuantWeights>,
}

/// Everything [`QatModel::backward`] needs from the training forward.
pub struct ModelActs {
    n: usize,
    layers: Vec<BlockActs>,
    h_final: Vec<f32>,
    xn_head: Vec<f32>,
    /// Next-token logits (`n ×` [`VOCAB`]).
    pub logits: Vec<f32>,
}

impl QatModel {
    /// Assemble the module tree with `gen(len, std)` supplying each weight
    /// tensor in a fixed order (tok, pos, per-layer Wq/Wk/Wv/Wo/W_in/W_out,
    /// head) — the seeded-init and checkpoint-load paths share it.
    fn assemble(cfg: QatModelConfig, gen: &mut dyn FnMut(usize, f32) -> Vec<f32>) -> QatModel {
        assert!(cfg.layers > 0 && cfg.heads > 0, "need at least one layer and head");
        assert_eq!(cfg.head_dim % 16, 0, "head_dim must be a multiple of 16");
        assert_eq!(cfg.ff % 16, 0, "ff must be a multiple of 16 (quantized export)");
        assert!(cfg.max_pos > 0);
        let d = cfg.heads * cfg.head_dim;
        let emb_std = 0.5;
        let proj_std = 1.0 / (d as f32).sqrt();
        let ff_std = 1.0 / (cfg.ff as f32).sqrt();
        let emb = Embedding::new(
            gen(VOCAB * d, emb_std),
            gen(cfg.max_pos * d, emb_std),
            d,
            cfg.max_pos,
        );
        let mut blocks = Vec::with_capacity(cfg.layers);
        for _ in 0..cfg.layers {
            blocks.push(Block {
                wq: Linear::new(gen(d * d, proj_std), d, d),
                wk: Linear::new(gen(d * d, proj_std), d, d),
                wv: Linear::new(gen(d * d, proj_std), d, d),
                wo: Linear::new(gen(d * d, proj_std), d, d),
                mlp: Mlp::new(
                    Linear::new(gen(d * cfg.ff, proj_std), d, cfg.ff),
                    Linear::new(gen(cfg.ff * d, ff_std), cfg.ff, d),
                ),
            });
        }
        let head = Linear::new(gen(d * VOCAB, proj_std), d, VOCAB);
        let attn = vec![cfg.attn.with_causal(true); cfg.layers];
        QatModel { cfg, emb, blocks, head, attn, proj: ProjQuant::off() }
    }

    /// Seeded random init (SimLm-style standard deviations).
    pub fn new(cfg: QatModelConfig) -> QatModel {
        let mut rng = Rng::new(cfg.seed).split("qat_model");
        QatModel::assemble(cfg, &mut |len, std| rng.normal_vec(len, 0.0, std))
    }

    /// All-zero weights — the checkpoint-load path overwrites every
    /// tensor, so it skips the Box–Muller work a seeded init would waste.
    fn zeroed(cfg: QatModelConfig) -> QatModel {
        QatModel::assemble(cfg, &mut |len, _| vec![0.0f32; len])
    }

    pub fn config(&self) -> &QatModelConfig {
        &self.cfg
    }

    pub fn d_model(&self) -> usize {
        self.cfg.heads * self.cfg.head_dim
    }

    /// Attention config of `layer` (training forward + backward switches).
    pub fn layer_attn(&self, layer: usize) -> AttnConfig {
        self.attn[layer]
    }

    /// Override one layer's attention config (causal stays forced on) —
    /// per-layer Fig-3 ablations.
    pub fn set_layer_attn(&mut self, layer: usize, cfg: AttnConfig) {
        self.attn[layer] = cfg.with_causal(true);
    }

    /// Set the projection-quantization policy for every training step
    /// from now on (serving and the [`TokenModel`] path are unaffected —
    /// they read the master weights as before).
    pub fn set_proj_quant(&mut self, proj: ProjQuant) {
        self.proj = proj;
    }

    pub fn proj_quant(&self) -> ProjQuant {
        self.proj
    }

    /// The [`ProjQuantMode::Naive`] step: hard-requantize the master
    /// projection weights (and, per policy, the embedding tables) onto
    /// the NVFP4 lattice **in place**. No-op in other modes. Called by
    /// [`LmTrainTask`] at the start of every training step — the
    /// deliberately wrong baseline whose update erasure `exp fullstack`
    /// demonstrates.
    pub fn requant_naive(&mut self) {
        if self.proj.mode != ProjQuantMode::Naive {
            return;
        }
        let d = self.d_model();
        let ff = self.cfg.ff;
        let had = self.proj.hadamard;
        if self.proj.embeddings {
            lowp::fake_quant_matrix_inplace(&mut self.emb.tok, d, had);
            lowp::fake_quant_matrix_inplace(&mut self.emb.pos, d, had);
        }
        for b in self.blocks.iter_mut() {
            lowp::fake_quant_matrix_inplace(&mut b.wq.w, d, had);
            lowp::fake_quant_matrix_inplace(&mut b.wk.w, d, had);
            lowp::fake_quant_matrix_inplace(&mut b.wv.w, d, had);
            lowp::fake_quant_matrix_inplace(&mut b.wo.w, d, had);
            lowp::fake_quant_matrix_inplace(&mut b.mlp.win.w, ff, had);
            lowp::fake_quant_matrix_inplace(&mut b.mlp.wout.w, d, had);
        }
    }

    /// Largest block-scale spread (max/min nonzero NVFP4 block scale)
    /// over every projection weight — the `train.lowp.proj_scale_range`
    /// health probe.
    pub fn proj_scale_range(&self) -> f32 {
        let mut r = 1.0f32;
        for b in &self.blocks {
            for w in
                [&b.wq.w, &b.wk.w, &b.wv.w, &b.wo.w, &b.mlp.win.w, &b.mlp.wout.w]
            {
                r = r.max(lowp::proj_scale_range(w));
            }
        }
        r
    }

    /// One training engine per layer, built from the per-layer configs —
    /// what [`QatModel::forward_train`] consumes (callers keep them across
    /// steps so engine workspaces are reused).
    pub fn engines(&self) -> Vec<AttnEngine> {
        self.attn.iter().map(|c| AttnEngine::new(*c)).collect()
    }

    /// Training forward over `tokens` (positions `0..n`, causal): returns
    /// the activation caches plus logits. The non-attention math is
    /// bitwise the serving path's ([`TokenModel`] impl) — same per-row
    /// kernels over the same weights.
    pub fn forward_train(&self, tokens: &[u8], engines: &mut [AttnEngine]) -> ModelActs {
        let n = tokens.len();
        let d = self.d_model();
        let (heads, hd) = (self.cfg.heads, self.cfg.head_dim);
        assert!(n > 0, "empty batch");
        assert_eq!(engines.len(), self.cfg.layers, "one engine per layer (QatModel::engines)");
        for (l, engine) in engines.iter().enumerate() {
            // A stale engine (e.g. built before set_layer_attn) would run a
            // forward the layer's backward config does not describe — the
            // exact mismatched-recompute failure the grad checks show
            // collapses gradient quality. Reject it loudly instead.
            assert_eq!(
                *engine.config(),
                self.attn[l],
                "engine {l} config drifted from layer_attn({l}) — rebuild with QatModel::engines"
            );
        }
        let ste = self.proj.mode == ProjQuantMode::Ste;
        let mut h = vec![0.0f32; n * d];
        self.emb.forward(tokens, 0, &mut h);
        if ste && self.proj.embeddings {
            // Quantize the embedding *output* rows (STE: the f32 tables
            // keep learning; backward is identity through the quantizer).
            lowp::fake_quant_matrix_inplace(&mut h, d, self.proj.hadamard);
        }
        let mut layers = Vec::with_capacity(self.cfg.layers);
        for (block, engine) in self.blocks.iter().zip(engines.iter_mut()) {
            let h_in = h.clone();
            let mut xn1 = vec![0.0f32; n * d];
            rms_norm_rows(&h, d, &mut xn1);
            if ste && self.proj.activations {
                // The cached xn1 *is* the quantized rows, so backward
                // consumes the forward's exact operands for free.
                lowp::fake_quant_matrix_inplace(&mut xn1, d, self.proj.hadamard);
            }
            let qw = ste.then(|| {
                lowp::QuantWeights::quantize(
                    &block.wq,
                    &block.wk,
                    &block.wv,
                    &block.wo,
                    &block.mlp,
                    self.proj.hadamard,
                )
            });
            let mut q = vec![0.0f32; n * d];
            let mut k = vec![0.0f32; n * d];
            let mut v = vec![0.0f32; n * d];
            match &qw {
                Some(qw) => {
                    lowp::linear_forward_w(&qw.wq, &xn1, n, d, d, &mut q);
                    lowp::linear_forward_w(&qw.wk, &xn1, n, d, d, &mut k);
                    lowp::linear_forward_w(&qw.wv, &xn1, n, d, d, &mut v);
                }
                None => {
                    block.wq.forward(&xn1, n, &mut q);
                    block.wk.forward(&xn1, n, &mut k);
                    block.wv.forward(&xn1, n, &mut v);
                }
            }
            let qhm = to_head_major(&q, n, heads, hd);
            let khm = to_head_major(&k, n, heads, hd);
            let vhm = to_head_major(&v, n, heads, hd);
            let train = engine.forward_train(&qhm, &khm, &vhm, heads, n, n, hd);
            let ao = to_token_major(&train.o, n, heads, hd);
            match &qw {
                Some(qw) => lowp::linear_forward_acc_w(&qw.wo, &ao, n, d, d, &mut h),
                None => block.wo.forward_acc(&ao, n, &mut h),
            }
            let h_mid = h.clone();
            let mlp = match &qw {
                Some(qw) => lowp::mlp_forward_train_w(
                    &block.mlp,
                    &qw.win,
                    &qw.wout,
                    self.proj.activations,
                    self.proj.hadamard,
                    &mut h,
                    n,
                ),
                None => block.mlp.forward_train(&mut h, n),
            };
            layers.push(BlockActs { h_in, xn1, qhm, khm, vhm, train, ao, h_mid, mlp, qw });
        }
        let h_final = h;
        let mut xn_head = vec![0.0f32; n * d];
        rms_norm_rows(&h_final, d, &mut xn_head);
        let mut logits = vec![0.0f32; n * VOCAB];
        self.head.forward(&xn_head, n, &mut logits);
        ModelActs { n, layers, h_final, xn_head, logits }
    }

    /// Backward from `dlogits` (`n ×` [`VOCAB`]): accumulates gradients
    /// into every module's grad buffers. Attention layers backpropagate
    /// through `qat::flash_backward_cfg` with their own [`AttnConfig`]
    /// (STE gradients w.r.t. the raw per-head Q/K/V).
    pub fn backward(&mut self, tokens: &[u8], acts: &ModelActs, dlogits: &[f32]) {
        let n = acts.n;
        let d = self.d_model();
        let (heads, hd) = (self.cfg.heads, self.cfg.head_dim);
        debug_assert_eq!(tokens.len(), n);
        debug_assert_eq!(dlogits.len(), n * VOCAB);
        let mut dxn = vec![0.0f32; n * d];
        self.head.backward(&acts.xn_head, dlogits, n, Some(&mut dxn));
        let mut dh = vec![0.0f32; n * d];
        rms_norm_bwd_rows(&acts.h_final, &dxn, d, &mut dh);
        for l in (0..self.cfg.layers).rev() {
            let block = &mut self.blocks[l];
            let c = &acts.layers[l];
            // MLP residual: dh (dL/dh_out) becomes dL/dh_mid in place.
            match &c.qw {
                Some(qw) => lowp::mlp_backward_w(
                    &mut block.mlp,
                    &qw.win,
                    &qw.wout,
                    &c.h_mid,
                    &c.mlp,
                    &mut dh,
                    n,
                ),
                None => block.mlp.backward(&c.h_mid, &c.mlp, &mut dh, n),
            }
            // Attention output projection.
            let mut dao = vec![0.0f32; n * d];
            match &c.qw {
                Some(qw) => lowp::linear_backward_w(
                    &qw.wo,
                    &mut block.wo.g,
                    &c.ao,
                    &dh,
                    n,
                    d,
                    d,
                    Some(&mut dao),
                ),
                None => block.wo.backward(&c.ao, &dh, n, Some(&mut dao)),
            }
            // Per-head attention backward with this layer's config.
            let dohm = to_head_major(&dao, n, heads, hd);
            let attn_cfg = self.attn[l];
            let mut dq = vec![0.0f32; n * d];
            let mut dk = vec![0.0f32; n * d];
            let mut dv = vec![0.0f32; n * d];
            for hh in 0..heads {
                let s = hh * n * hd..(hh + 1) * n * hd;
                let g = flash_backward_cfg(
                    &attn_cfg,
                    &c.qhm[s.clone()],
                    &c.khm[s.clone()],
                    &c.vhm[s.clone()],
                    n,
                    n,
                    hd,
                    &c.train.o[s.clone()],
                    &c.train.o_prime[s.clone()],
                    &c.train.lse[hh * n..(hh + 1) * n],
                    &dohm[s.clone()],
                );
                dq[s.clone()].copy_from_slice(&g.dq);
                dk[s.clone()].copy_from_slice(&g.dk);
                dv[s].copy_from_slice(&g.dv);
            }
            let dq_tm = to_token_major(&dq, n, heads, hd);
            let dk_tm = to_token_major(&dk, n, heads, hd);
            let dv_tm = to_token_major(&dv, n, heads, hd);
            // Q/K/V projections; all three chains land in dxn1.
            let mut dxn1 = vec![0.0f32; n * d];
            match &c.qw {
                Some(qw) => {
                    let g = &mut block.wq.g;
                    lowp::linear_backward_w(&qw.wq, g, &c.xn1, &dq_tm, n, d, d, Some(&mut dxn1));
                    let g = &mut block.wk.g;
                    lowp::linear_backward_w(&qw.wk, g, &c.xn1, &dk_tm, n, d, d, Some(&mut dxn1));
                    let g = &mut block.wv.g;
                    lowp::linear_backward_w(&qw.wv, g, &c.xn1, &dv_tm, n, d, d, Some(&mut dxn1));
                }
                None => {
                    block.wq.backward(&c.xn1, &dq_tm, n, Some(&mut dxn1));
                    block.wk.backward(&c.xn1, &dk_tm, n, Some(&mut dxn1));
                    block.wv.backward(&c.xn1, &dv_tm, n, Some(&mut dxn1));
                }
            }
            // Norm chain joins the residual stream: dh ← dh_mid + rms′.
            rms_norm_bwd_rows(&c.h_in, &dxn1, d, &mut dh);
        }
        self.emb.backward(tokens, 0, &dh);
    }

    /// Per-block (layer) global gradient norm over the block's Wq/Wk/Wv/
    /// Wo/MLP grads, in layer order — the Fig-3 per-layer divergence
    /// probe (`train.layer{l}.grad_norm`). Read *after* a backward pass;
    /// embeddings and the LM head are shared across layers and excluded.
    pub fn layer_grad_norms(&mut self) -> Vec<f32> {
        self.blocks
            .iter_mut()
            .map(|b| {
                let mut sq = 0.0f64;
                b.visit(&mut |_, g| {
                    sq += g.iter().map(|&x| x as f64 * x as f64).sum::<f64>();
                });
                sq.sqrt() as f32
            })
            .collect()
    }

    /// Fake-quantize a weight matrix onto the NVFP4 lattice, row-blocked
    /// along `cols` (the output dim — a multiple of 16 by construction).
    fn quantize_weights(w: &[f32], cols: usize) -> Vec<f32> {
        let mut out = w.to_vec();
        for row in out.chunks_mut(cols) {
            nvfp4_fake_quant_row(row);
        }
        out
    }

    /// Export a serving checkpoint: transformer projections (Wq/Wk/Wv/Wo/
    /// W_in/W_out) fake-quantized onto the NVFP4 lattice, embeddings and
    /// LM head f32, plus a shape header. Loadable by [`QatModel::load`].
    pub fn save_quantized(&self, path: &Path) -> Result<()> {
        let d = self.d_model();
        let (layers, ff) = (self.cfg.layers, self.cfg.ff);
        fn stack(mats: &[&Linear], cols: usize) -> Vec<f32> {
            let mut out = Vec::new();
            for m in mats {
                out.extend_from_slice(&QatModel::quantize_weights(&m.w, cols));
            }
            out
        }
        let wq: Vec<&Linear> = self.blocks.iter().map(|b| &b.wq).collect();
        let wk: Vec<&Linear> = self.blocks.iter().map(|b| &b.wk).collect();
        let wv: Vec<&Linear> = self.blocks.iter().map(|b| &b.wv).collect();
        let wo: Vec<&Linear> = self.blocks.iter().map(|b| &b.wo).collect();
        let win: Vec<&Linear> = self.blocks.iter().map(|b| &b.mlp.win).collect();
        let wout: Vec<&Linear> = self.blocks.iter().map(|b| &b.mlp.wout).collect();
        let cfg_t = Tensor::new(
            vec![5],
            vec![
                layers as f32,
                self.cfg.heads as f32,
                self.cfg.head_dim as f32,
                ff as f32,
                self.cfg.max_pos as f32,
            ],
        )?;
        let tensors: Vec<(String, Tensor)> = vec![
            ("config".into(), cfg_t),
            ("tok_emb".into(), Tensor::new(vec![VOCAB, d], self.emb.tok.clone())?),
            ("pos_emb".into(), Tensor::new(vec![self.cfg.max_pos, d], self.emb.pos.clone())?),
            ("wq".into(), Tensor::new(vec![layers, d, d], stack(&wq, d))?),
            ("wk".into(), Tensor::new(vec![layers, d, d], stack(&wk, d))?),
            ("wv".into(), Tensor::new(vec![layers, d, d], stack(&wv, d))?),
            ("wo".into(), Tensor::new(vec![layers, d, d], stack(&wo, d))?),
            ("win".into(), Tensor::new(vec![layers, d, ff], stack(&win, ff))?),
            ("wout".into(), Tensor::new(vec![layers, ff, d], stack(&wout, d))?),
            ("head".into(), Tensor::new(vec![d, VOCAB], self.head.w.clone())?),
        ];
        let named: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        checkpoint::save(path, &named)
    }

    /// Load a checkpoint written by [`QatModel::save_quantized`]; `attn`
    /// supplies the (runtime) attention config for every layer.
    pub fn load(path: &Path, attn: AttnConfig) -> Result<QatModel> {
        let tensors = checkpoint::load(path)?;
        let get = |name: &str| -> Result<&Tensor> {
            tensors
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, t)| t)
                .ok_or_else(|| anyhow!("checkpoint missing tensor '{name}'"))
        };
        let c = get("config")?;
        ensure!(
            c.data.len() == 5 && c.data.iter().all(|v| v.is_finite() && *v >= 0.0),
            "malformed config tensor: {:?}",
            c.data
        );
        let cfg = QatModelConfig {
            layers: c.data[0] as usize,
            heads: c.data[1] as usize,
            head_dim: c.data[2] as usize,
            ff: c.data[3] as usize,
            max_pos: c.data[4] as usize,
            seed: 0,
            attn,
        };
        // Validate with Err (not the ctor asserts): a corrupt header must
        // surface as a load error, and implausible shapes must not drive
        // huge allocations before the per-tensor size checks below.
        ensure!(
            cfg.layers >= 1
                && cfg.layers <= 4096
                && cfg.heads >= 1
                && cfg.heads <= 4096
                && cfg.head_dim >= 16
                && cfg.head_dim % 16 == 0
                && cfg.head_dim <= 65536
                && cfg.ff >= 16
                && cfg.ff % 16 == 0
                && cfg.ff <= (1 << 20)
                && cfg.max_pos >= 1
                && cfg.max_pos <= (1 << 24),
            "implausible checkpoint config: {cfg:?}"
        );
        let mut model = QatModel::zeroed(cfg);
        let d = model.d_model();
        let ff = cfg.ff;
        let copy = |dst: &mut Vec<f32>, t: &Tensor, what: &str| -> Result<()> {
            ensure!(t.data.len() == dst.len(), "{what}: shape mismatch {:?}", t.shape);
            dst.copy_from_slice(&t.data);
            Ok(())
        };
        copy(&mut model.emb.tok, get("tok_emb")?, "tok_emb")?;
        copy(&mut model.emb.pos, get("pos_emb")?, "pos_emb")?;
        copy(&mut model.head.w, get("head")?, "head")?;
        for (name, pick) in
            [("wq", 0usize), ("wk", 1), ("wv", 2), ("wo", 3), ("win", 4), ("wout", 5)]
        {
            let t = get(name)?;
            let per = match pick {
                4 => d * ff,
                5 => ff * d,
                _ => d * d,
            };
            ensure!(t.data.len() == cfg.layers * per, "{name}: shape mismatch {:?}", t.shape);
            for (l, block) in model.blocks.iter_mut().enumerate() {
                let src = &t.data[l * per..(l + 1) * per];
                let dst = match pick {
                    0 => &mut block.wq.w,
                    1 => &mut block.wk.w,
                    2 => &mut block.wv.w,
                    3 => &mut block.wo.w,
                    4 => &mut block.mlp.win.w,
                    _ => &mut block.mlp.wout.w,
                };
                dst.copy_from_slice(src);
            }
        }
        Ok(model)
    }
}

impl Module for QatModel {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        self.emb.visit_params(f);
        for b in self.blocks.iter_mut() {
            b.visit(f);
        }
        self.head.visit_params(f);
    }
}

impl TokenModel for QatModel {
    fn layers(&self) -> usize {
        self.cfg.layers
    }

    fn heads(&self) -> usize {
        self.cfg.heads
    }

    fn head_dim(&self) -> usize {
        self.cfg.head_dim
    }

    fn embed(&self, tokens: &[u8], pos0: usize, h: &mut [f32]) {
        assert_eq!(h.len(), tokens.len() * self.d_model(), "h must be (rows x d_model)");
        self.emb.forward(tokens, pos0, h);
    }

    fn qkv(&self, layer: usize, h: &[f32], q: &mut [f32], k: &mut [f32], v: &mut [f32]) {
        let d = self.d_model();
        let rows = h.len() / d;
        assert_eq!(h.len(), rows * d);
        assert!(q.len() == h.len() && k.len() == h.len() && v.len() == h.len());
        let mut xn = vec![0.0f32; rows * d];
        rms_norm_rows(h, d, &mut xn);
        let b = &self.blocks[layer];
        b.wq.forward(&xn, rows, q);
        b.wk.forward(&xn, rows, k);
        b.wv.forward(&xn, rows, v);
    }

    fn mix(&self, layer: usize, h: &mut [f32], attn: &[f32]) {
        let d = self.d_model();
        let rows = h.len() / d;
        assert_eq!(attn.len(), h.len());
        let b = &self.blocks[layer];
        b.wo.forward_acc(attn, rows, h);
        b.mlp.forward(h, rows);
    }

    fn logits(&self, h: &[f32], logits: &mut [f32]) {
        let d = self.d_model();
        assert_eq!(h.len(), d, "logits takes one hidden row");
        assert_eq!(logits.len(), VOCAB);
        let mut xn = vec![0.0f32; d];
        rms_norm(h, &mut xn);
        self.head.forward(&xn, 1, logits);
    }
}

/// Pre-registered `train.layer{l}.*` gauges sampled every `every`-th
/// step (see the [`crate::telemetry`] module docs for the name map).
struct LayerProbes {
    telemetry: Telemetry,
    every: u64,
    tick: u64,
    grad_norm: Vec<Gauge>,
    q_sat: Vec<Gauge>,
    k_sat: Vec<Gauge>,
    v_sat: Vec<Gauge>,
    scale_range: Vec<Gauge>,
    /// `train.lowp.proj_scale_range` — projection-weight block-scale
    /// spread (only meaningful with projection quantization on, but
    /// cheap and well-defined for f32 weights too).
    proj_scale: Gauge,
}

/// Next-byte language modelling over the synthetic corpus: the
/// [`TrainableModel`] that drives a [`QatModel`] through a
/// [`super::TrainSession`] — the paper's finetune setting, natively.
pub struct LmTrainTask {
    pub model: QatModel,
    engines: Vec<AttnEngine>,
    corpus: Corpus,
    /// Tokens per step (causal window).
    pub seq: usize,
    /// `None` until [`LmTrainTask::attach_telemetry`] — a detached task
    /// samples nothing and behaves bitwise as before.
    probes: Option<LayerProbes>,
}

impl LmTrainTask {
    pub fn new(model: QatModel, seq: usize, data_seed: u64) -> LmTrainTask {
        assert!(seq > 0);
        let engines = model.engines();
        LmTrainTask { model, engines, corpus: Corpus::new(data_seed), seq, probes: None }
    }

    /// Register per-layer quantization-health gauges
    /// (`train.layer{l}.grad_norm` / `.{q,k,v}_sat_frac` /
    /// `.scale_range`) and sample them every `every`-th training step
    /// (clamped to ≥ 1). Sampling is skipped entirely while `telemetry`
    /// is disabled, so the probe costs nothing on production loops.
    pub fn attach_telemetry(&mut self, telemetry: &Telemetry, every: usize) {
        let reg = telemetry.registry();
        let layers = self.model.config().layers;
        let g = |l: usize, metric: &str| reg.gauge(&format!("train.layer{l}.{metric}"));
        self.probes = Some(LayerProbes {
            telemetry: telemetry.clone(),
            every: every.max(1) as u64,
            tick: 0,
            grad_norm: (0..layers).map(|l| g(l, "grad_norm")).collect(),
            q_sat: (0..layers).map(|l| g(l, "q_sat_frac")).collect(),
            k_sat: (0..layers).map(|l| g(l, "k_sat_frac")).collect(),
            v_sat: (0..layers).map(|l| g(l, "v_sat_frac")).collect(),
            scale_range: (0..layers).map(|l| g(l, "scale_range")).collect(),
            proj_scale: reg.gauge("train.lowp.proj_scale_range"),
        });
    }

    /// Publish the per-layer gauges from this step's activations + the
    /// just-accumulated gradients (every K-th step, enabled domains only).
    fn sample_probes(&mut self, acts: &ModelActs) {
        let Some(p) = &mut self.probes else { return };
        p.tick += 1;
        if !p.telemetry.is_enabled() || p.tick % p.every != 0 {
            return;
        }
        for (l, norm) in self.model.layer_grad_norms().iter().enumerate() {
            p.grad_norm[l].set(*norm as f64);
        }
        for (l, c) in acts.layers.iter().enumerate() {
            let q = e2m1_health(&c.qhm);
            let k = e2m1_health(&c.khm);
            let v = e2m1_health(&c.vhm);
            p.q_sat[l].set(q.sat_frac as f64);
            p.k_sat[l].set(k.sat_frac as f64);
            p.v_sat[l].set(v.sat_frac as f64);
            let range = q.scale_range().max(k.scale_range()).max(v.scale_range());
            p.scale_range[l].set(range as f64);
        }
        p.proj_scale.set(self.model.proj_scale_range() as f64);
    }

    /// Take the finetuned model out (e.g. to export and serve it).
    pub fn into_model(self) -> QatModel {
        self.model
    }

    /// Change one layer's attention config, keeping the task's engines in
    /// sync (mutating the model directly would leave a stale engine, which
    /// `forward_train` rejects).
    pub fn set_layer_attn(&mut self, layer: usize, cfg: AttnConfig) {
        self.model.set_layer_attn(layer, cfg);
        self.engines[layer] = AttnEngine::new(self.model.layer_attn(layer));
    }

    /// Discard `k` training batches from the corpus stream — aligns a
    /// freshly-built task's data stream with one that already ran `k`
    /// steps (checkpoint resume: the v3 file restores weights, counters,
    /// and moments; this restores the data position).
    pub fn skip_batches(&mut self, k: usize) {
        for _ in 0..k {
            let _ = self.corpus.stream(self.seq + 1);
        }
    }
}

impl TrainableModel for LmTrainTask {
    fn train_step(&mut self) -> f32 {
        // Naive projection quantization requantizes the master weights in
        // place before the step (no-op in Off/Ste modes).
        self.model.requant_naive();
        let bytes = self.corpus.stream(self.seq + 1);
        let inputs = &bytes[..self.seq];
        let targets = &bytes[1..];
        let spans = self.probes.as_ref().map(|p| p.telemetry.spans().clone());
        let acts = {
            let _span = spans.as_ref().map(|s| crate::span!(s, "train.forward"));
            self.model.forward_train(inputs, &mut self.engines)
        };
        let (loss, dlogits) = cross_entropy(&acts.logits, VOCAB, targets);
        {
            let _span = spans.as_ref().map(|s| crate::span!(s, "train.backward"));
            self.model.backward(inputs, &acts, &dlogits);
        }
        self.sample_probes(&acts);
        loss
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
        self.model.visit_params(f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::session::{TrainConfig, TrainSession};

    fn tiny_cfg() -> QatModelConfig {
        QatModelConfig { ff: 32, max_pos: 64, ..QatModelConfig::default() }
    }

    #[test]
    fn forward_train_matches_serving_math_per_row() {
        // The non-attention math must agree between the training forward
        // and the TokenModel path: embed + qkv projections of the same
        // rows are bitwise equal.
        let model = QatModel::new(tiny_cfg());
        let d = model.d_model();
        let tokens = b"Hello";
        let n = tokens.len();
        let mut h = vec![0.0f32; n * d];
        TokenModel::embed(&model, tokens, 0, &mut h);
        let (mut q, mut k, mut v) = (h.clone(), h.clone(), h.clone());
        model.qkv(0, &h, &mut q, &mut k, &mut v);
        let mut engines = model.engines();
        let acts = model.forward_train(tokens, &mut engines);
        // Reconstruct layer-0 token-major q from the head-major cache.
        let (heads, hd) = (model.heads(), model.head_dim());
        let q_tm = super::to_token_major(&acts.layers[0].qhm, n, heads, hd);
        assert_eq!(q_tm, q, "training q projection must equal serving qkv");
        assert_eq!(acts.logits.len(), n * VOCAB);
        assert!(acts.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn head_major_restaging_roundtrips() {
        let (n, heads, hd) = (5, 3, 16);
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(n * heads * hd, 0.0, 1.0);
        let hm = super::to_head_major(&x, n, heads, hd);
        assert_eq!(super::to_token_major(&hm, n, heads, hd), x);
    }

    #[test]
    fn lm_training_reduces_loss_and_stays_finite() {
        // A short Adam+clip finetune on the synthetic corpus: the fp4
        // attn-qat model must make progress (simulated: CE drops well
        // within 60 steps) without spikes.
        let model = QatModel::new(tiny_cfg());
        let task = LmTrainTask::new(model, 32, 0xfeed);
        let mut session = TrainSession::new(task, TrainConfig::adam(5e-3));
        session.run(50, 0, |_| {});
        assert!(!session.diverged(), "finetune must stay finite");
        let first = session.history[0].loss;
        let tail = session.tail_loss(10);
        assert!(
            tail < first,
            "loss should improve: first {first}, tail {tail}"
        );
    }

    #[test]
    fn layer_probes_publish_grad_norms_and_sat_fracs() {
        let model = QatModel::new(tiny_cfg());
        let mut task = LmTrainTask::new(model, 16, 0xabcd);
        let t = Telemetry::new();
        task.attach_telemetry(&t, 1);
        let mut session = TrainSession::new(task, TrainConfig::adam(1e-3));
        session.attach_telemetry(&t);
        session.run(2, 0, |_| {});
        let reg = t.registry();
        assert_eq!(reg.counter("train.steps").get(), 2);
        let g0 = reg.gauge("train.layer0.grad_norm").get().unwrap();
        assert!(g0.is_finite() && g0 > 0.0, "layer grad norm {g0}");
        for metric in ["q_sat_frac", "k_sat_frac", "v_sat_frac"] {
            let sat = reg.gauge(&format!("train.layer1.{metric}")).get().unwrap();
            assert!((0.0..=1.0).contains(&sat), "{metric} = {sat}");
        }
        assert!(reg.gauge("train.layer0.scale_range").get().unwrap() >= 1.0);
        let doc = t.snapshot();
        assert_eq!(doc.get("config").get("train").get("optimizer").as_str(), Some("adam"));
        assert!(doc.get("metrics").get("train").get("step_ms").get("count").as_f64().is_some());
    }

    #[test]
    fn smoothk_layers_run_the_smooth_forward_and_train() {
        // ROADMAP scenario (c): native smooth-K training forward, wired
        // through the per-layer configs. Parity pin: the model's cached
        // layer-0 attention output must equal a fresh smooth-configured
        // engine run on the same cached Q/K/V (no hidden divergence
        // between the model plumbing and the engine).
        let mut cfg = tiny_cfg();
        cfg.attn = AttnConfig::qat_smoothk();
        let model = QatModel::new(cfg);
        assert!(model.layer_attn(0).smooth, "preset must carry smoothing");
        let tokens = b"smooth-k parity!";
        let n = tokens.len();
        let mut engines = model.engines();
        let acts = model.forward_train(tokens, &mut engines);
        let (heads, hd) = (model.heads(), model.head_dim());
        let c = &acts.layers[0];
        let mut eng = AttnEngine::new(model.layer_attn(0));
        let want = eng.forward_train(&c.qhm, &c.khm, &c.vhm, heads, n, n, hd);
        assert_eq!(c.train.o, want.o, "model smooth-K forward must match the engine");
        // Same seed without smoothing: logits must differ (the smooth
        // path is actually reached), but only by quantization-noise
        // amounts (smoothing is softmax-invariant in exact arithmetic).
        let base = QatModel::new(tiny_cfg());
        let mut base_engines = base.engines();
        let base_acts = base.forward_train(tokens, &mut base_engines);
        assert_ne!(base_acts.logits, acts.logits, "smooth-K must reach the kernel");
        // And it trains: matched backward through the smoothed forward.
        let task = LmTrainTask::new(model, 32, 0xfeed);
        let mut session = TrainSession::new(task, TrainConfig::adam(5e-3));
        session.run(50, 0, |_| {});
        assert!(!session.diverged(), "smooth-K finetune must stay finite");
        assert!(session.tail_loss(10) < session.history[0].loss);
    }

    #[test]
    fn ste_proj_quant_trains_and_keeps_masters_off_lattice() {
        let mut model = QatModel::new(tiny_cfg());
        model.set_proj_quant(ProjQuant::ste().with_activations(true));
        let w0 = model.blocks[0].wq.w.clone();
        let task = LmTrainTask::new(model, 32, 0xfeed);
        let mut session = TrainSession::new(task, TrainConfig::adam(5e-3));
        session.run(50, 0, |_| {});
        assert!(!session.diverged(), "STE projections must stay finite");
        assert!(session.tail_loss(10) < session.history[0].loss);
        let m = session.model.into_model();
        // STE lands dW on the f32 masters: they moved, and they are NOT
        // hard-quantized (quantizing them still changes them).
        assert_ne!(m.blocks[0].wq.w, w0, "masters must learn under STE");
        let d = m.d_model();
        let q = lowp::fake_quant_matrix(&m.blocks[0].wq.w, d, false);
        assert_ne!(q, m.blocks[0].wq.w, "masters stay f32, not on the lattice");
        assert!(m.proj_scale_range() >= 1.0);
    }

    #[test]
    fn naive_requant_quantizes_masters_in_place_and_off_is_a_noop() {
        let mut model = QatModel::new(tiny_cfg());
        let w_before = model.blocks[0].wq.w.clone();
        let tok_before = model.emb.tok.clone();
        model.set_proj_quant(ProjQuant::naive().with_embeddings(true));
        model.requant_naive();
        assert_ne!(model.blocks[0].wq.w, w_before, "projections hard-requantized");
        assert_ne!(model.emb.tok, tok_before, "embedding tables requantized too");
        let mut off = QatModel::new(tiny_cfg());
        let w = off.blocks[0].wq.w.clone();
        off.requant_naive();
        assert_eq!(off.blocks[0].wq.w, w, "Off mode must not touch weights");
    }

    #[test]
    fn per_layer_ablation_configs_are_honored() {
        let mut model = QatModel::new(tiny_cfg());
        model.set_layer_attn(1, AttnConfig::fp4());
        assert_eq!(model.layer_attn(1).bwd, crate::attention::BwdSwitches::STOCK);
        assert!(model.layer_attn(1).causal, "causal stays forced on");
        assert_eq!(model.layer_attn(0).bwd, crate::attention::BwdSwitches::MATCHED);
    }

    #[test]
    fn checkpoint_roundtrip_preserves_weights_on_the_lattice() {
        let dir = std::env::temp_dir().join("attn_qat_model_ckpt_test");
        let path = dir.join("m.ckpt");
        let model = QatModel::new(tiny_cfg());
        model.save_quantized(&path).unwrap();
        let back = QatModel::load(&path, AttnConfig::fp4()).unwrap();
        // Embeddings and head round-trip bitwise; projections land on the
        // quantized lattice (load == quantize(save-side weights)).
        assert_eq!(back.emb.tok, model.emb.tok);
        assert_eq!(back.head.w, model.head.w);
        let d = model.d_model();
        let want = QatModel::quantize_weights(&model.blocks[0].wq.w, d);
        assert_eq!(back.blocks[0].wq.w, want);
        assert_ne!(back.blocks[0].wq.w, model.blocks[0].wq.w, "export must quantize");
        // A second round trip is stable in shape and loads cleanly.
        back.save_quantized(&path).unwrap();
        let again = QatModel::load(&path, AttnConfig::fp4()).unwrap();
        assert_eq!(again.config().layers, model.config().layers);
        std::fs::remove_dir_all(&dir).ok();
    }
}
