//! The training session: one config-driven loop over any
//! [`TrainableModel`] — the replacement for `qat::NativeTrainer`'s
//! hand-rolled step.
//!
//! A [`TrainSession`] owns the model, the optimizer state, and the
//! [`crate::coordinator::StepMetrics`]-compatible history (the same time
//! series the compiled-path `coordinator::Trainer` records, so every
//! Fig-3 writer consumes either interchangeably). Each step:
//!
//! 1. zero the grad buffers, run the model's `train_step` (forward +
//!    backward on a fresh self-generated batch),
//! 2. measure the **global** gradient norm (recorded pre-clip, matching
//!    both the old native trainer and the compiled trainer),
//! 3. optionally clip by global norm ([`TrainConfig::grad_clip`] — the
//!    paper's finetune recipe pairs this with Adam),
//! 4. apply the optimizer at the scheduled learning rate.
//!
//! Divergence is data, not a crash: steps keep running past the
//! threshold and the history records the spikes/NaNs for the figures.
//!
//! With a [`WatchdogConfig`] armed, divergence is also *recoverable*:
//! the session snapshots params + optimizer state every K good steps,
//! and a step whose loss goes non-finite or whose pre-clip grad norm
//! blows past the configured limit is rolled back to the last good
//! snapshot with the learning rate backed off (bounded retries). The
//! rollback is recorded in the step's [`StepMetrics::rollback`] flag —
//! the history keeps the spike (divergence stays observable data) while
//! the parameters survive it.
//!
//! **Tracing:** when the session runs under an enabled [`Telemetry`]
//! domain, each step opens a `train.step` span and the inner `train.clip`
//! / `train.optim` guards nest under it automatically via the
//! thread-local current-span context (see [`crate::telemetry::trace`]) —
//! no [`crate::telemetry::TraceContext`] plumbing is needed on this
//! single-threaded path, and the resulting tree shows up in
//! `serve profile`-style self-time tables and flamegraph exports like
//! any serving trace.

use std::path::Path;

use anyhow::{anyhow, ensure, Result};

use crate::coordinator::{checkpoint, LrSchedule, StepMetrics};
use crate::json::Json;
use crate::telemetry::{Counter, Gauge, Histogram, Telemetry};
use crate::tensor::Tensor;

use super::lowp::LowPAdam;
use super::optim::{Adam, Optimizer, OptimizerState, Sgd};

/// A model the session can drive: owns its parameters, gradients, and
/// data source.
pub trait TrainableModel {
    /// Forward + backward on a fresh batch; **accumulates** gradients into
    /// the (already zeroed) grad buffers and returns the scalar loss.
    fn train_step(&mut self) -> f32;

    /// Visit every (param, grad) tensor pair in a stable order (the
    /// optimizer keys per-tensor state on the visit index).
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32]));
}

/// Optimizer selection for [`TrainConfig`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OptimizerKind {
    /// SGD + momentum — the old `NativeTrainer` update, bitwise.
    Sgd { momentum: f32 },
    /// Adam with bias correction.
    Adam { beta1: f32, beta2: f32, eps: f32 },
    /// Adam with E4M3 moments + stochastic-rounding writeback
    /// ([`super::LowPAdam`]); `seed` keys the deterministic rounding
    /// stream.
    LowPAdam { beta1: f32, beta2: f32, eps: f32, seed: u64 },
}

impl OptimizerKind {
    fn build(self) -> Box<dyn Optimizer> {
        match self {
            OptimizerKind::Sgd { momentum } => Box::new(Sgd::new(momentum)),
            OptimizerKind::Adam { beta1, beta2, eps } => {
                Box::new(Adam::with_params(beta1, beta2, eps))
            }
            OptimizerKind::LowPAdam { beta1, beta2, eps, seed } => {
                Box::new(LowPAdam::new(beta1, beta2, eps, seed))
            }
        }
    }
}

/// Divergence watchdog: snapshot/rollback policy for [`TrainSession`].
#[derive(Clone, Copy, Debug)]
pub struct WatchdogConfig {
    /// Take a params+optimizer snapshot every this many *good* steps.
    pub snapshot_every: usize,
    /// A step whose pre-clip grad norm exceeds this (or whose loss or
    /// grad norm goes non-finite) is rolled back.
    pub grad_limit: f32,
    /// Learning-rate multiplier applied on every rollback (compounds).
    pub lr_backoff: f32,
    /// Rollback budget; past it bad steps are kept (the run then
    /// records divergence as data, exactly like a watchdog-less run).
    pub max_rollbacks: usize,
}

impl Default for WatchdogConfig {
    fn default() -> WatchdogConfig {
        WatchdogConfig { snapshot_every: 10, grad_limit: 50.0, lr_backoff: 0.5, max_rollbacks: 8 }
    }
}

/// Everything a training run is configurable on.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub optimizer: OptimizerKind,
    pub schedule: LrSchedule,
    /// Global-norm gradient clip (`None` = off). The recorded
    /// `grad_norm` is always the pre-clip norm.
    pub grad_clip: Option<f32>,
    /// Same semantics as `coordinator::Trainer`: runs continue past this —
    /// divergence is observable data.
    pub divergence_threshold: f32,
    /// `Some` arms the divergence watchdog (snapshot + rollback + lr
    /// backoff); `None` keeps the record-only behaviour.
    pub watchdog: Option<WatchdogConfig>,
    /// Sequences accumulated per optimizer step (gradients are averaged
    /// across the microbatch). `1` reproduces the single-sequence step
    /// bitwise.
    pub microbatch: usize,
}

impl TrainConfig {
    /// SGD + momentum at a constant lr, no clipping — exactly the old
    /// `NativeTrainer` loop.
    pub fn sgd(lr: f32, momentum: f32) -> TrainConfig {
        TrainConfig {
            optimizer: OptimizerKind::Sgd { momentum },
            schedule: LrSchedule::Constant(lr),
            grad_clip: None,
            divergence_threshold: 1e6,
            watchdog: None,
            microbatch: 1,
        }
    }

    /// Adam (standard betas) + global grad-clip at 1.0 — the paper's
    /// finetune recipe.
    pub fn adam(lr: f32) -> TrainConfig {
        TrainConfig {
            optimizer: OptimizerKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            schedule: LrSchedule::Constant(lr),
            grad_clip: Some(1.0),
            divergence_threshold: 1e6,
            watchdog: None,
            microbatch: 1,
        }
    }

    /// [`TrainConfig::adam`] with E4M3 moment storage + stochastic
    /// rounding keyed on `seed` (same betas/eps/clip).
    pub fn lowp_adam(lr: f32, seed: u64) -> TrainConfig {
        TrainConfig {
            optimizer: OptimizerKind::LowPAdam { beta1: 0.9, beta2: 0.999, eps: 1e-8, seed },
            ..TrainConfig::adam(lr)
        }
    }

    pub fn with_schedule(mut self, schedule: LrSchedule) -> TrainConfig {
        self.schedule = schedule;
        self
    }

    pub fn with_grad_clip(mut self, clip: Option<f32>) -> TrainConfig {
        self.grad_clip = clip;
        self
    }

    /// Arm the divergence watchdog.
    pub fn with_watchdog(mut self, wd: WatchdogConfig) -> TrainConfig {
        self.watchdog = Some(wd);
        self
    }

    /// Accumulate gradients over `micro` sequences per optimizer step.
    pub fn with_microbatch(mut self, micro: usize) -> TrainConfig {
        assert!(micro >= 1, "microbatch must be >= 1");
        self.microbatch = micro;
        self
    }

    /// Reflect the run's hyperparameters for the telemetry snapshot's
    /// `config.train` section.
    pub fn to_json(&self) -> Json {
        let optimizer = match self.optimizer {
            OptimizerKind::Sgd { .. } => "sgd",
            OptimizerKind::Adam { .. } => "adam",
            OptimizerKind::LowPAdam { .. } => "lowp_adam",
        };
        Json::obj(vec![
            ("optimizer", Json::Str(optimizer.to_string())),
            ("microbatch", Json::Num(self.microbatch as f64)),
            ("schedule", Json::Str(format!("{:?}", self.schedule))),
            (
                "grad_clip",
                self.grad_clip.map_or(Json::Null, |c| Json::Num(c as f64)),
            ),
            ("divergence_threshold", Json::Num(self.divergence_threshold as f64)),
            (
                "watchdog",
                self.watchdog.map_or(Json::Null, |wd| {
                    Json::obj(vec![
                        ("snapshot_every", Json::Num(wd.snapshot_every as f64)),
                        ("grad_limit", Json::Num(wd.grad_limit as f64)),
                        ("lr_backoff", Json::Num(wd.lr_backoff as f64)),
                        ("max_rollbacks", Json::Num(wd.max_rollbacks as f64)),
                    ])
                }),
            ),
        ])
    }
}

/// Pre-registered `train.*` handles (see the [`crate::telemetry`] module
/// docs for the name map).
struct SessionProbes {
    telemetry: Telemetry,
    steps: Counter,
    rollbacks: Counter,
    loss: Gauge,
    grad_norm: Gauge,
    lr: Gauge,
    step_ms: Histogram,
    /// `train.lowp.*` health gauges, published only when the optimizer
    /// reports [`crate::model::LowPStats`].
    lowp_m_sat: Gauge,
    lowp_v_sat: Gauge,
    lowp_sr_bias: Gauge,
}

/// A training run: model + optimizer state + metric history.
pub struct TrainSession<M: TrainableModel> {
    pub model: M,
    pub cfg: TrainConfig,
    opt: Box<dyn Optimizer>,
    step: usize,
    pub history: Vec<StepMetrics>,
    /// Last good (params, optimizer) snapshot, kept only when the
    /// watchdog is armed.
    snapshot: Option<(Vec<Vec<f32>>, OptimizerState)>,
    lr_scale: f32,
    rollbacks: usize,
    /// `None` until [`TrainSession::attach_telemetry`] — a detached
    /// session publishes nothing and behaves bitwise as before.
    probes: Option<SessionProbes>,
}

impl<M: TrainableModel> TrainSession<M> {
    pub fn new(model: M, cfg: TrainConfig) -> TrainSession<M> {
        TrainSession {
            model,
            cfg,
            opt: cfg.optimizer.build(),
            step: 0,
            history: Vec::new(),
            snapshot: None,
            lr_scale: 1.0,
            rollbacks: 0,
            probes: None,
        }
    }

    /// Register this session's `train.*` metrics in `telemetry`, reflect
    /// the [`TrainConfig`] into the snapshot's `config.train` section,
    /// and publish per-step gauges + spans from here on.
    pub fn attach_telemetry(&mut self, telemetry: &Telemetry) {
        telemetry.set_config("train", self.cfg.to_json());
        let reg = telemetry.registry();
        self.probes = Some(SessionProbes {
            telemetry: telemetry.clone(),
            steps: reg.counter("train.steps"),
            rollbacks: reg.counter("train.rollbacks"),
            loss: reg.gauge("train.loss"),
            grad_norm: reg.gauge("train.grad_norm"),
            lr: reg.gauge("train.lr"),
            step_ms: reg.histogram("train.step_ms"),
            lowp_m_sat: reg.gauge("train.lowp.m_sat_frac"),
            lowp_v_sat: reg.gauge("train.lowp.v_sat_frac"),
            lowp_sr_bias: reg.gauge("train.lowp.sr_bias"),
        });
    }

    /// Steps completed so far.
    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// Watchdog rollbacks performed so far (0 when unarmed).
    pub fn rollbacks(&self) -> usize {
        self.rollbacks
    }

    /// Current learning-rate backoff multiplier (1.0 until a rollback).
    pub fn lr_scale(&self) -> f32 {
        self.lr_scale
    }

    fn take_snapshot(&mut self) -> (Vec<Vec<f32>>, OptimizerState) {
        let mut params = Vec::new();
        self.model.visit_params(&mut |w, _| params.push(w.to_vec()));
        (params, self.opt.snapshot())
    }

    fn restore_snapshot(&mut self) {
        let (params, opt_state) =
            self.snapshot.as_ref().expect("watchdog rollback without a snapshot");
        let mut idx = 0usize;
        self.model.visit_params(&mut |w, _| {
            w.copy_from_slice(&params[idx]);
            idx += 1;
        });
        self.opt.restore(opt_state);
    }

    /// One optimizer step on a fresh batch. Returns the step metrics.
    ///
    /// With the watchdog armed, a step whose loss/grad-norm is bad is
    /// *not applied*: params + optimizer roll back to the last good
    /// snapshot, the lr backs off, and the metric (which keeps the bad
    /// loss and pre-clip grad norm, so figures still show the spike) is
    /// flagged with [`StepMetrics::rollback`]. Past the rollback budget
    /// bad steps apply as usual and the run records divergence as data.
    pub fn step(&mut self) -> StepMetrics {
        let t0 = std::time::Instant::now();
        // Recorder cloned out of the probes (Arc bump) so span guards
        // never hold a borrow of `self` across `&mut self` calls.
        let spans = self.probes.as_ref().map(|p| p.telemetry.spans().clone());
        let _step_span = spans.as_ref().map(|s| crate::span!(s, "train.step"));
        if self.cfg.watchdog.is_some() && self.snapshot.is_none() {
            // Baseline: the initial params are the first "last good" state.
            self.snapshot = Some(self.take_snapshot());
        }
        self.model.visit_params(&mut |_, g| g.fill(0.0));
        let micro = self.cfg.microbatch.max(1);
        let loss = if micro == 1 {
            // The single-sequence fast path: bitwise the pre-microbatch
            // step (no extra grad traversal, no loss rescale).
            self.model.train_step()
        } else {
            let mut total = 0.0f32;
            for _ in 0..micro {
                total += self.model.train_step();
            }
            let inv = 1.0 / micro as f32;
            self.model.visit_params(&mut |_, g| {
                for x in g.iter_mut() {
                    *x *= inv;
                }
            });
            total * inv
        };

        // Global grad norm: per-tensor f64 sums added in visit order (the
        // exact accumulation the old trainer used), recorded pre-clip.
        let mut sq = 0.0f64;
        self.model.visit_params(&mut |_, g| {
            sq += g.iter().map(|&x| x as f64 * x as f64).sum::<f64>();
        });
        let grad_norm = sq.sqrt() as f32;

        let lr = self.cfg.schedule.at(self.step) * self.lr_scale;
        let mut rolled_back = false;
        if let Some(wd) = self.cfg.watchdog {
            let bad = !loss.is_finite() || !grad_norm.is_finite() || grad_norm > wd.grad_limit;
            if bad && self.rollbacks < wd.max_rollbacks {
                self.restore_snapshot();
                self.lr_scale *= wd.lr_backoff;
                self.rollbacks += 1;
                rolled_back = true;
            }
        }

        if !rolled_back {
            if let Some(clip) = self.cfg.grad_clip {
                if grad_norm.is_finite() && grad_norm > clip {
                    let _span = spans.as_ref().map(|s| crate::span!(s, "train.clip"));
                    let s = clip / grad_norm;
                    self.model.visit_params(&mut |_, g| {
                        for x in g.iter_mut() {
                            *x *= s;
                        }
                    });
                }
            }
            let _span = spans.as_ref().map(|s| crate::span!(s, "train.optim"));
            self.opt.begin_step();
            let opt = &mut self.opt;
            let mut idx = 0usize;
            self.model.visit_params(&mut |w, g| {
                opt.update(idx, w, g, lr);
                idx += 1;
            });
        }

        self.step += 1;
        if let Some(wd) = self.cfg.watchdog {
            if !rolled_back
                && loss.is_finite()
                && grad_norm.is_finite()
                && self.step % wd.snapshot_every.max(1) == 0
            {
                self.snapshot = Some(self.take_snapshot());
            }
        }
        let m = StepMetrics {
            step: self.step,
            loss,
            grad_norm,
            lr,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            rollback: rolled_back,
        };
        if let Some(p) = &self.probes {
            p.steps.inc();
            if rolled_back {
                p.rollbacks.inc();
            }
            p.loss.set(loss as f64);
            p.grad_norm.set(grad_norm as f64);
            p.lr.set(lr as f64);
            p.step_ms.record(m.wall_ms);
            if let Some(st) = self.opt.lowp_stats() {
                p.lowp_m_sat.set(st.m_sat_frac as f64);
                p.lowp_v_sat.set(st.v_sat_frac as f64);
                p.lowp_sr_bias.set(st.sr_bias as f64);
            }
        }
        self.history.push(m);
        m
    }

    /// Run `steps` steps; `on_log` fires every `log_every` steps (and on
    /// the last one). `log_every = 0` is silent.
    pub fn run(&mut self, steps: usize, log_every: usize, mut on_log: impl FnMut(&StepMetrics)) {
        for i in 0..steps {
            let m = self.step();
            if log_every > 0 && (i % log_every == 0 || i + 1 == steps) {
                on_log(&m);
            }
        }
    }

    /// True if any recorded step went non-finite or past the threshold.
    pub fn diverged(&self) -> bool {
        self.history.iter().any(|m| {
            !m.loss.is_finite()
                || !m.grad_norm.is_finite()
                || m.loss.abs() > self.cfg.divergence_threshold
                || m.grad_norm > self.cfg.divergence_threshold
        })
    }

    /// Largest finite grad norm seen (0.0 if none recorded).
    pub fn max_grad_norm(&self) -> f32 {
        self.history
            .iter()
            .map(|m| m.grad_norm)
            .filter(|g| g.is_finite())
            .fold(0.0f32, f32::max)
    }

    /// Bytes of optimizer state currently held (0 until the first step
    /// sizes the buffers) — 8/param for Adam, ~2/param for LowPAdam.
    pub fn optimizer_state_bytes(&self) -> usize {
        self.opt.state_bytes()
    }

    /// Serialize params + session counters + the full optimizer state to
    /// a v3 checkpoint ([`checkpoint::save_train`]). LowPAdam's E4M3
    /// moment bytes are stored verbatim, so a resumed finetune replays
    /// bitwise (pair with `LmTrainTask::skip_batches` to re-align the
    /// data stream).
    pub fn save_checkpoint(&mut self, path: &Path) -> Result<()> {
        let mut tensors: Vec<(String, Tensor)> = Vec::new();
        let mut err = None;
        self.model.visit_params(&mut |w, _| {
            if err.is_some() {
                return;
            }
            let i = tensors.len();
            match Tensor::new(vec![w.len()], w.to_vec()) {
                Ok(t) => tensors.push((format!("param{i}"), t)),
                Err(e) => err = Some(e),
            }
        });
        if let Some(e) = err {
            return Err(e);
        }
        tensors.push((
            "session_meta".into(),
            Tensor::new(vec![3], vec![self.step as f32, self.lr_scale, self.rollbacks as f32])?,
        ));
        let named: Vec<(String, &Tensor)> = tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        checkpoint::save_train(path, &named, Some(&self.opt.snapshot()))
    }

    /// Load a checkpoint saved by [`TrainSession::save_checkpoint`]:
    /// params are copied into the model in visit order, the optimizer is
    /// rebuilt and (when the file carries one — v3) restored verbatim,
    /// and step counter / lr backoff / rollback count resume. The
    /// watchdog baseline snapshot is cleared and re-taken on the next
    /// step.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let (tensors, opt_state) = checkpoint::load_train(path)?;
        let mut err: Option<anyhow::Error> = None;
        let mut idx = 0usize;
        self.model.visit_params(&mut |w, _| {
            if err.is_some() {
                return;
            }
            let name = format!("param{idx}");
            match tensors.iter().find(|(n, _)| *n == name) {
                Some((_, t)) if t.data.len() == w.len() => w.copy_from_slice(&t.data),
                Some((_, t)) => {
                    err = Some(anyhow!("{name}: shape mismatch {:?}", t.shape));
                }
                None => err = Some(anyhow!("checkpoint missing tensor '{name}'")),
            }
            idx += 1;
        });
        if let Some(e) = err {
            return Err(e);
        }
        let meta = tensors
            .iter()
            .find(|(n, _)| n == "session_meta")
            .map(|(_, t)| t.data.clone())
            .unwrap_or_default();
        ensure!(meta.len() == 3, "checkpoint missing session_meta");
        self.step = meta[0] as usize;
        self.lr_scale = meta[1];
        self.rollbacks = meta[2] as usize;
        self.opt = self.cfg.optimizer.build();
        if let Some(state) = &opt_state {
            self.opt.restore(state);
        }
        self.snapshot = None;
        Ok(())
    }

    /// Mean loss over the last `k` finite steps (NaN if none).
    pub fn tail_loss(&self, k: usize) -> f32 {
        let tail: Vec<f32> = self
            .history
            .iter()
            .rev()
            .take(k)
            .map(|m| m.loss)
            .filter(|l| l.is_finite())
            .collect();
        if tail.is_empty() {
            f32::NAN
        } else {
            tail.iter().sum::<f32>() / tail.len() as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fixed-gradient toy: loss = Σw, grad = 1 everywhere.
    struct Toy {
        w: Vec<f32>,
        g: Vec<f32>,
        grad: Vec<f32>,
    }

    impl TrainableModel for Toy {
        fn train_step(&mut self) -> f32 {
            for (g, &v) in self.g.iter_mut().zip(&self.grad) {
                *g += v;
            }
            self.w.iter().sum()
        }

        fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
            f(&mut self.w, &mut self.g);
        }
    }

    #[test]
    fn sgd_session_descends_and_records_history() {
        let toy = Toy { w: vec![1.0; 4], g: vec![0.0; 4], grad: vec![1.0; 4] };
        let mut s = TrainSession::new(toy, TrainConfig::sgd(0.1, 0.0));
        s.run(3, 0, |_| {});
        assert_eq!(s.history.len(), 3);
        assert_eq!(s.history[0].step, 1);
        // grad norm = √4 = 2 every step; w decreases by 0.1 each step.
        assert_eq!(s.history[0].grad_norm, 2.0);
        assert!((s.model.w[0] - 0.7).abs() < 1e-6);
        assert!(s.history[0].loss > s.history[2].loss);
        assert!(!s.diverged());
    }

    #[test]
    fn grad_clip_scales_update_but_records_preclip_norm() {
        // grad = 3 per element over 4 elements: global norm 6 > clip 1.5;
        // with lr 0.1 and no momentum the step is lr·g·(1.5/6) = 0.075.
        let toy = Toy { w: vec![0.0; 4], g: vec![0.0; 4], grad: vec![3.0; 4] };
        let cfg = TrainConfig::sgd(0.1, 0.0).with_grad_clip(Some(1.5));
        let mut s = TrainSession::new(toy, cfg);
        let m = s.step();
        assert_eq!(m.grad_norm, 6.0, "recorded norm must be pre-clip");
        for &w in &s.model.w {
            assert!((w + 0.075).abs() < 1e-6, "{w}");
        }
        // Below the threshold nothing is scaled.
        let toy = Toy { w: vec![0.0; 4], g: vec![0.0; 4], grad: vec![0.1; 4] };
        let mut s = TrainSession::new(toy, TrainConfig::sgd(0.1, 0.0).with_grad_clip(Some(1.5)));
        s.step();
        for &w in &s.model.w {
            assert!((w + 0.01).abs() < 1e-7, "{w}");
        }
    }

    /// Scalar quadratic bowl: loss = (λ/2)·w², grad = λ·w. With
    /// lr·λ > 2 plain gradient descent oscillates with growing
    /// amplitude — the canonical recoverable divergence.
    struct Bowl {
        lambda: f32,
        w: Vec<f32>,
        g: Vec<f32>,
    }

    impl TrainableModel for Bowl {
        fn train_step(&mut self) -> f32 {
            self.g[0] += self.lambda * self.w[0];
            0.5 * self.lambda * self.w[0] * self.w[0]
        }

        fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
            f(&mut self.w, &mut self.g);
        }
    }

    #[test]
    fn watchdog_rolls_back_divergence_and_backs_off_lr() {
        // λ=3, lr=1 → each step multiplies w by (1 − lr·λ) = −2, so
        // |w| doubles per step: 1, −2, 4, −8, … The grad at w=−8 is
        // −24, past the limit of 20 → rollback to the step-2 snapshot
        // (w=4) and halve the lr; at lr=0.5 the factor is −0.5 and the
        // run converges: 4 → −2 → 1 → −0.5 → 0.25.
        let bowl = Bowl { lambda: 3.0, w: vec![1.0], g: vec![0.0] };
        let wd = WatchdogConfig {
            snapshot_every: 2,
            grad_limit: 20.0,
            lr_backoff: 0.5,
            max_rollbacks: 8,
        };
        let mut s = TrainSession::new(bowl, TrainConfig::sgd(1.0, 0.0).with_watchdog(wd));
        s.run(8, 0, |_| {});

        assert_eq!(s.rollbacks(), 1);
        assert_eq!(s.lr_scale(), 0.5);
        assert!((s.model.w[0] - 0.25).abs() < 1e-6, "w = {}", s.model.w[0]);
        // The rolled-back step keeps the spike in the record.
        let bad = &s.history[3];
        assert!(bad.rollback);
        assert!((bad.grad_norm - 24.0).abs() < 1e-5);
        assert!((bad.loss - 96.0).abs() < 1e-4);
        assert_eq!(s.history.iter().filter(|m| m.rollback).count(), 1);
        // lr history: 1.0 up to the rollback, 0.5 after.
        assert_eq!(s.history[2].lr, 1.0);
        assert_eq!(s.history[4].lr, 0.5);
        assert_eq!(s.history[7].lr, 0.5);
    }

    #[test]
    fn watchdog_budget_exhaustion_reverts_to_record_only() {
        // grad_limit 0 trips every step; with max_rollbacks 2 the first
        // two steps roll back (w stays put) and later steps apply.
        let bowl = Bowl { lambda: 1.0, w: vec![1.0], g: vec![0.0] };
        let wd = WatchdogConfig {
            snapshot_every: 1,
            grad_limit: 0.0,
            lr_backoff: 0.5,
            max_rollbacks: 2,
        };
        let mut s = TrainSession::new(bowl, TrainConfig::sgd(0.1, 0.0).with_watchdog(wd));
        s.run(2, 0, |_| {});
        assert_eq!(s.rollbacks(), 2);
        assert_eq!(s.model.w[0], 1.0, "rolled-back steps must not move params");
        s.run(1, 0, |_| {});
        assert_eq!(s.rollbacks(), 2, "budget exhausted: no further rollbacks");
        // Step applied at lr 0.1·0.25: w = 1 − 0.025.
        assert!((s.model.w[0] - 0.975).abs() < 1e-6, "w = {}", s.model.w[0]);
        assert!(!s.history[2].rollback);
    }

    #[test]
    fn unarmed_session_never_rolls_back() {
        let bowl = Bowl { lambda: 3.0, w: vec![1.0], g: vec![0.0] };
        let mut s = TrainSession::new(bowl, TrainConfig::sgd(1.0, 0.0));
        s.run(6, 0, |_| {});
        assert_eq!(s.rollbacks(), 0);
        assert!(s.history.iter().all(|m| !m.rollback));
        // |w| = 2⁶ — divergence stays observable data.
        assert_eq!(s.model.w[0].abs(), 64.0);
        assert!(s.diverged() || s.max_grad_norm() > 50.0);
    }

    #[test]
    fn microbatch_averages_to_the_single_sequence_step() {
        // Toy's gradient is deterministic per call, so accumulating k
        // identical grads and scaling by 1/k reproduces mb=1 exactly
        // (binary-exact for k a power of two).
        let toy = Toy { w: vec![1.0; 4], g: vec![0.0; 4], grad: vec![1.0; 4] };
        let mut s1 = TrainSession::new(toy, TrainConfig::sgd(0.1, 0.0));
        s1.run(3, 0, |_| {});
        let toy = Toy { w: vec![1.0; 4], g: vec![0.0; 4], grad: vec![1.0; 4] };
        let mut s4 = TrainSession::new(toy, TrainConfig::sgd(0.1, 0.0).with_microbatch(4));
        s4.run(3, 0, |_| {});
        assert_eq!(s1.model.w, s4.model.w);
        assert_eq!(s1.history[0].grad_norm, s4.history[0].grad_norm);
        assert_eq!(s1.history[2].loss, s4.history[2].loss);
    }

    #[test]
    fn checkpoint_roundtrip_restores_params_counters_and_moments() {
        let dir = std::env::temp_dir().join("attn_qat_session_ckpt_test");
        let path = dir.join("s.ckpt");
        let toy = Toy { w: vec![1.0; 4], g: vec![0.0; 4], grad: vec![0.5; 4] };
        let mut a = TrainSession::new(toy, TrainConfig::lowp_adam(0.05, 0xbeef));
        a.run(3, 0, |_| {});
        a.save_checkpoint(&path).unwrap();
        a.run(2, 0, |_| {});

        let toy = Toy { w: vec![9.0; 4], g: vec![0.0; 4], grad: vec![0.5; 4] };
        let mut b = TrainSession::new(toy, TrainConfig::lowp_adam(0.05, 0xbeef));
        b.load_checkpoint(&path).unwrap();
        assert_eq!(b.steps_done(), 3);
        // Toy's gradient stream is stateless, so a resumed run must
        // reproduce the original continuation bitwise — params AND the
        // E4M3 moment bytes came back verbatim.
        b.run(2, 0, |_| {});
        assert_eq!(a.model.w, b.model.w);
        assert_eq!(a.history[4].loss, b.history[1].loss);
        assert_eq!(a.history[4].grad_norm, b.history[1].grad_norm);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cosine_schedule_is_consumed() {
        let toy = Toy { w: vec![0.0; 2], g: vec![0.0; 2], grad: vec![1.0; 2] };
        let cfg = TrainConfig::sgd(1.0, 0.0).with_schedule(LrSchedule::Cosine {
            warmup: 2,
            peak: 1.0,
            total: 10,
            floor_frac: 0.1,
        });
        let mut s = TrainSession::new(toy, cfg);
        s.run(3, 0, |_| {});
        assert!((s.history[0].lr - 0.5).abs() < 1e-6, "warmup step 0");
        assert!((s.history[1].lr - 1.0).abs() < 1e-6, "warmup step 1");
        assert!(s.history[2].lr <= 1.0);
    }
}
