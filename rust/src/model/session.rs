//! The training session: one config-driven loop over any
//! [`TrainableModel`] — the replacement for `qat::NativeTrainer`'s
//! hand-rolled step.
//!
//! A [`TrainSession`] owns the model, the optimizer state, and the
//! [`crate::coordinator::StepMetrics`]-compatible history (the same time
//! series the compiled-path `coordinator::Trainer` records, so every
//! Fig-3 writer consumes either interchangeably). Each step:
//!
//! 1. zero the grad buffers, run the model's `train_step` (forward +
//!    backward on a fresh self-generated batch),
//! 2. measure the **global** gradient norm (recorded pre-clip, matching
//!    both the old native trainer and the compiled trainer),
//! 3. optionally clip by global norm ([`TrainConfig::grad_clip`] — the
//!    paper's finetune recipe pairs this with Adam),
//! 4. apply the optimizer at the scheduled learning rate.
//!
//! Divergence is data, not a crash: steps keep running past the
//! threshold and the history records the spikes/NaNs for the figures.

use crate::coordinator::{LrSchedule, StepMetrics};

use super::optim::{Adam, Optimizer, Sgd};

/// A model the session can drive: owns its parameters, gradients, and
/// data source.
pub trait TrainableModel {
    /// Forward + backward on a fresh batch; **accumulates** gradients into
    /// the (already zeroed) grad buffers and returns the scalar loss.
    fn train_step(&mut self) -> f32;

    /// Visit every (param, grad) tensor pair in a stable order (the
    /// optimizer keys per-tensor state on the visit index).
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32]));
}

/// Optimizer selection for [`TrainConfig`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OptimizerKind {
    /// SGD + momentum — the old `NativeTrainer` update, bitwise.
    Sgd { momentum: f32 },
    /// Adam with bias correction.
    Adam { beta1: f32, beta2: f32, eps: f32 },
}

impl OptimizerKind {
    fn build(self) -> Box<dyn Optimizer> {
        match self {
            OptimizerKind::Sgd { momentum } => Box::new(Sgd::new(momentum)),
            OptimizerKind::Adam { beta1, beta2, eps } => {
                Box::new(Adam::with_params(beta1, beta2, eps))
            }
        }
    }
}

/// Everything a training run is configurable on.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub optimizer: OptimizerKind,
    pub schedule: LrSchedule,
    /// Global-norm gradient clip (`None` = off). The recorded
    /// `grad_norm` is always the pre-clip norm.
    pub grad_clip: Option<f32>,
    /// Same semantics as `coordinator::Trainer`: runs continue past this —
    /// divergence is observable data.
    pub divergence_threshold: f32,
}

impl TrainConfig {
    /// SGD + momentum at a constant lr, no clipping — exactly the old
    /// `NativeTrainer` loop.
    pub fn sgd(lr: f32, momentum: f32) -> TrainConfig {
        TrainConfig {
            optimizer: OptimizerKind::Sgd { momentum },
            schedule: LrSchedule::Constant(lr),
            grad_clip: None,
            divergence_threshold: 1e6,
        }
    }

    /// Adam (standard betas) + global grad-clip at 1.0 — the paper's
    /// finetune recipe.
    pub fn adam(lr: f32) -> TrainConfig {
        TrainConfig {
            optimizer: OptimizerKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            schedule: LrSchedule::Constant(lr),
            grad_clip: Some(1.0),
            divergence_threshold: 1e6,
        }
    }

    pub fn with_schedule(mut self, schedule: LrSchedule) -> TrainConfig {
        self.schedule = schedule;
        self
    }

    pub fn with_grad_clip(mut self, clip: Option<f32>) -> TrainConfig {
        self.grad_clip = clip;
        self
    }
}

/// A training run: model + optimizer state + metric history.
pub struct TrainSession<M: TrainableModel> {
    pub model: M,
    pub cfg: TrainConfig,
    opt: Box<dyn Optimizer>,
    step: usize,
    pub history: Vec<StepMetrics>,
}

impl<M: TrainableModel> TrainSession<M> {
    pub fn new(model: M, cfg: TrainConfig) -> TrainSession<M> {
        TrainSession { model, cfg, opt: cfg.optimizer.build(), step: 0, history: Vec::new() }
    }

    /// Steps completed so far.
    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// One optimizer step on a fresh batch. Returns the step metrics.
    pub fn step(&mut self) -> StepMetrics {
        let t0 = std::time::Instant::now();
        self.model.visit_params(&mut |_, g| g.fill(0.0));
        let loss = self.model.train_step();

        // Global grad norm: per-tensor f64 sums added in visit order (the
        // exact accumulation the old trainer used), recorded pre-clip.
        let mut sq = 0.0f64;
        self.model.visit_params(&mut |_, g| {
            sq += g.iter().map(|&x| x as f64 * x as f64).sum::<f64>();
        });
        let grad_norm = sq.sqrt() as f32;
        if let Some(clip) = self.cfg.grad_clip {
            if grad_norm.is_finite() && grad_norm > clip {
                let s = clip / grad_norm;
                self.model.visit_params(&mut |_, g| {
                    for x in g.iter_mut() {
                        *x *= s;
                    }
                });
            }
        }

        let lr = self.cfg.schedule.at(self.step);
        self.opt.begin_step();
        let opt = &mut self.opt;
        let mut idx = 0usize;
        self.model.visit_params(&mut |w, g| {
            opt.update(idx, w, g, lr);
            idx += 1;
        });

        self.step += 1;
        let m = StepMetrics {
            step: self.step,
            loss,
            grad_norm,
            lr,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        };
        self.history.push(m);
        m
    }

    /// Run `steps` steps; `on_log` fires every `log_every` steps (and on
    /// the last one). `log_every = 0` is silent.
    pub fn run(&mut self, steps: usize, log_every: usize, mut on_log: impl FnMut(&StepMetrics)) {
        for i in 0..steps {
            let m = self.step();
            if log_every > 0 && (i % log_every == 0 || i + 1 == steps) {
                on_log(&m);
            }
        }
    }

    /// True if any recorded step went non-finite or past the threshold.
    pub fn diverged(&self) -> bool {
        self.history.iter().any(|m| {
            !m.loss.is_finite()
                || !m.grad_norm.is_finite()
                || m.loss.abs() > self.cfg.divergence_threshold
                || m.grad_norm > self.cfg.divergence_threshold
        })
    }

    /// Largest finite grad norm seen (0.0 if none recorded).
    pub fn max_grad_norm(&self) -> f32 {
        self.history
            .iter()
            .map(|m| m.grad_norm)
            .filter(|g| g.is_finite())
            .fold(0.0f32, f32::max)
    }

    /// Mean loss over the last `k` finite steps (NaN if none).
    pub fn tail_loss(&self, k: usize) -> f32 {
        let tail: Vec<f32> = self
            .history
            .iter()
            .rev()
            .take(k)
            .map(|m| m.loss)
            .filter(|l| l.is_finite())
            .collect();
        if tail.is_empty() {
            f32::NAN
        } else {
            tail.iter().sum::<f32>() / tail.len() as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fixed-gradient toy: loss = Σw, grad = 1 everywhere.
    struct Toy {
        w: Vec<f32>,
        g: Vec<f32>,
        grad: Vec<f32>,
    }

    impl TrainableModel for Toy {
        fn train_step(&mut self) -> f32 {
            for (g, &v) in self.g.iter_mut().zip(&self.grad) {
                *g += v;
            }
            self.w.iter().sum()
        }

        fn visit_params(&mut self, f: &mut dyn FnMut(&mut [f32], &mut [f32])) {
            f(&mut self.w, &mut self.g);
        }
    }

    #[test]
    fn sgd_session_descends_and_records_history() {
        let toy = Toy { w: vec![1.0; 4], g: vec![0.0; 4], grad: vec![1.0; 4] };
        let mut s = TrainSession::new(toy, TrainConfig::sgd(0.1, 0.0));
        s.run(3, 0, |_| {});
        assert_eq!(s.history.len(), 3);
        assert_eq!(s.history[0].step, 1);
        // grad norm = √4 = 2 every step; w decreases by 0.1 each step.
        assert_eq!(s.history[0].grad_norm, 2.0);
        assert!((s.model.w[0] - 0.7).abs() < 1e-6);
        assert!(s.history[0].loss > s.history[2].loss);
        assert!(!s.diverged());
    }

    #[test]
    fn grad_clip_scales_update_but_records_preclip_norm() {
        // grad = 3 per element over 4 elements: global norm 6 > clip 1.5;
        // with lr 0.1 and no momentum the step is lr·g·(1.5/6) = 0.075.
        let toy = Toy { w: vec![0.0; 4], g: vec![0.0; 4], grad: vec![3.0; 4] };
        let cfg = TrainConfig::sgd(0.1, 0.0).with_grad_clip(Some(1.5));
        let mut s = TrainSession::new(toy, cfg);
        let m = s.step();
        assert_eq!(m.grad_norm, 6.0, "recorded norm must be pre-clip");
        for &w in &s.model.w {
            assert!((w + 0.075).abs() < 1e-6, "{w}");
        }
        // Below the threshold nothing is scaled.
        let toy = Toy { w: vec![0.0; 4], g: vec![0.0; 4], grad: vec![0.1; 4] };
        let mut s = TrainSession::new(toy, TrainConfig::sgd(0.1, 0.0).with_grad_clip(Some(1.5)));
        s.step();
        for &w in &s.model.w {
            assert!((w + 0.01).abs() < 1e-7, "{w}");
        }
    }

    #[test]
    fn cosine_schedule_is_consumed() {
        let toy = Toy { w: vec![0.0; 2], g: vec![0.0; 2], grad: vec![1.0; 2] };
        let cfg = TrainConfig::sgd(1.0, 0.0).with_schedule(LrSchedule::Cosine {
            warmup: 2,
            peak: 1.0,
            total: 10,
            floor_frac: 0.1,
        });
        let mut s = TrainSession::new(toy, cfg);
        s.run(3, 0, |_| {});
        assert!((s.history[0].lr - 0.5).abs() < 1e-6, "warmup step 0");
        assert!((s.history[1].lr - 1.0).abs() < 1e-6, "warmup step 1");
        assert!(s.history[2].lr <= 1.0);
    }
}
