//! Straight-through-estimator hooks over the NVFP4 quantizers (Eq. 7).
//!
//! The quantizer φ⁻¹∘φ is piecewise constant, so its true derivative is
//! zero almost everywhere. QAT instead trains with the STE surrogate: the
//! forward uses the fake-quantized value, the backward treats the quantizer
//! as identity —
//!
//! ```text
//! value(x) = φ⁻¹(φ(x)),      ∂value/∂x ≈ I      (Eq. 7)
//! ```
//!
//! [`quantize_attn_inputs_ste`] is the single quantization point of the
//! native training path: it packs Q/K/V once (exactly like the inference
//! engine, via [`pack_qkv_for_attention`]) and exposes both views the
//! backward needs — the **packed** 4-bit form for the LUT-domain S/P
//! recomputation, and the dequantized f32 values Q^F/K^F/V^F for the
//! dV/dQ/dK matmuls whose contraction axes don't line up with the
//! quantization blocks. [`ste_grad`] then maps gradients w.r.t. the
//! quantized tensors back to the raw tensors (identity, per Eq. 7).

use crate::attention::engine::pack_qkv_for_attention;
use crate::formats::tensor4::PackedNvfp4;

/// Quantized attention inputs: packed storage + dequantized f32 views.
///
/// Layouts match the engine contract: `q4`/`k4` are `(n × d_pad)` with
/// blocks along the head dimension, `v4t` is Vᵀ `(d × nk_pad)` with blocks
/// along the token axis. The f32 views are trimmed back to logical shapes
/// (`qf`/`kf`: `n × d`, `vf`: `nk × d` row-major, un-transposed).
pub struct SteAttnInputs {
    pub q4: PackedNvfp4,
    pub k4: PackedNvfp4,
    pub v4t: PackedNvfp4,
    pub qf: Vec<f32>,
    pub kf: Vec<f32>,
    pub vf: Vec<f32>,
}

/// Quantize raw Q/K/V once for the training path (forward + backward share
/// the same bits — the "matched recomputation" precondition of Fix A).
pub fn quantize_attn_inputs_ste(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
) -> SteAttnInputs {
    let (q4, k4, v4t) = pack_qkv_for_attention(q, k, v, nq, nk, d);
    let qf = dequant_trim(&q4, nq, d);
    let kf = dequant_trim(&k4, nk, d);
    let vf = dequant_transpose_trim(&v4t, nk, d);
    SteAttnInputs { q4, k4, v4t, qf, kf, vf }
}

/// STE backward through a quantizer: the gradient passes unchanged (Eq. 7).
///
/// Kept as an explicit (inlined-away) function so call sites document
/// *where* the estimator is applied rather than silently reusing buffers.
#[inline]
pub fn ste_grad(upstream: Vec<f32>) -> Vec<f32> {
    upstream
}

/// Dequantize a row-blocked packed matrix, trimming column padding.
fn dequant_trim(p: &PackedNvfp4, rows: usize, cols: usize) -> Vec<f32> {
    debug_assert!(p.rows >= rows && p.cols >= cols);
    let mut row_buf = vec![0.0f32; p.cols];
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        p.dequant_row_into(r, &mut row_buf);
        out[r * cols..(r + 1) * cols].copy_from_slice(&row_buf[..cols]);
    }
    out
}

/// Dequantize packed Vᵀ `(d × nk_pad)` back to row-major V^F `(nk × d)`.
fn dequant_transpose_trim(vt: &PackedNvfp4, nk: usize, d: usize) -> Vec<f32> {
    debug_assert_eq!(vt.rows, d);
    debug_assert!(vt.cols >= nk);
    let mut row_buf = vec![0.0f32; vt.cols];
    let mut out = vec![0.0f32; nk * d];
    for c in 0..d {
        vt.dequant_row_into(c, &mut row_buf);
        for j in 0..nk {
            out[j * d + c] = row_buf[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::block::nvfp4_fake_quant_row;
    use crate::rng::Rng;

    #[test]
    fn dequant_views_match_fake_quant() {
        // The f32 views must be exactly φ⁻¹(φ(·)) with the engine's axis
        // conventions: Q/K along d, V along the token axis.
        let (nq, nk, d) = (5, 7, 32);
        let mut rng = Rng::new(31);
        let q = rng.normal_vec(nq * d, 0.0, 1.0);
        let k = rng.normal_vec(nk * d, 0.0, 1.0);
        let v = rng.normal_vec(nk * d, 0.0, 1.0);
        let inp = quantize_attn_inputs_ste(&q, &k, &v, nq, nk, d);

        let mut qf = q.clone();
        for row in qf.chunks_mut(d) {
            nvfp4_fake_quant_row(row);
        }
        assert_eq!(inp.qf, qf);

        let mut kf = k.clone();
        for row in kf.chunks_mut(d) {
            nvfp4_fake_quant_row(row);
        }
        assert_eq!(inp.kf, kf);

        // V: quantize the transpose (blocks along tokens, padded to 16),
        // then transpose back.
        let nkp = nk.div_ceil(16) * 16;
        let mut vt = vec![0.0f32; d * nkp];
        for j in 0..nk {
            for c in 0..d {
                vt[c * nkp + j] = v[j * d + c];
            }
        }
        for row in vt.chunks_mut(nkp) {
            nvfp4_fake_quant_row(row);
        }
        for j in 0..nk {
            for c in 0..d {
                assert_eq!(inp.vf[j * d + c], vt[c * nkp + j], "v[{j},{c}]");
            }
        }
    }

    #[test]
    fn ste_grad_is_identity() {
        let g = vec![1.0f32, -2.5, 0.0, 1e-8];
        assert_eq!(ste_grad(g.clone()), g);
    }

    #[test]
    fn packed_and_f32_views_share_bits() {
        // Dequantizing the packed form must reproduce the f32 view — the
        // backward's LUT dots and f32 matmuls consume the same lattice.
        let (nq, nk, d) = (3, 19, 16);
        let mut rng = Rng::new(32);
        let q = rng.normal_vec(nq * d, 0.0, 2.0);
        let k = rng.normal_vec(nk * d, 0.0, 2.0);
        let v = rng.normal_vec(nk * d, 0.0, 2.0);
        let inp = quantize_attn_inputs_ste(&q, &k, &v, nq, nk, d);
        assert_eq!(dequant_trim(&inp.q4, nq, d), inp.qf);
        assert_eq!(dequant_trim(&inp.k4, nk, d), inp.kf);
        assert_eq!(dequant_transpose_trim(&inp.v4t, nk, d), inp.vf);
    }
}
