//! Minimal native trainer: the Figure-3 training-dynamics harness.
//!
//! A deliberately small attention-regression problem that needs **no
//! compiled artifacts**: a frozen f32 teacher attention generates targets,
//! and a student with trainable Q/K/V projections chases them through the
//! variant's forward/backward ([`QatVariant`]). SGD + momentum, per-step
//! loss and pre-clip grad-norm history in [`StepMetrics`] form — the same
//! time series the compiled-path `coordinator::Trainer` records, so the
//! Fig-3 writers consume either interchangeably.
//!
//! Why this reproduces the paper's instability: the student starts *at*
//! the teacher (the finetune setting), so the only initial loss is FP4
//! quantization error. The drop-in backward recomputes S from the raw f32
//! Q/K while the forward ran on quantized ones — `P = exp(S_raw − lse_quant)`
//! overshoots wherever quantization moved a score down, and the naive
//! `D = rowsum(dO ∘ O)` adds a spurious non-cancelling component to every
//! dS row (Fix B's missing term). Both biases grow with |S|, larger weights
//! mean larger |S|, and at the Fig-3 learning rate the feedback loop spikes
//! the grad norm and diverges — while the matched Attn-QAT backward trains
//! through the identical forward without incident. Divergence is *data*
//! here (mirroring `coordinator::Trainer`): steps keep running and the
//! history records the NaNs/spikes for the figure.

use crate::attention::{AttnConfig, AttnEngine};
use crate::coordinator::StepMetrics;
use crate::rng::Rng;

use super::{flash_backward, QatVariant};

/// Native trainer hyper-parameters (defaults = the Fig-3a/b setting).
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    /// Tokens per step (sequence length).
    pub n: usize,
    /// Input feature dimension.
    pub d_model: usize,
    /// Attention head dimension (multiple of 16 keeps padding trivial).
    pub d_head: usize,
    pub lr: f32,
    pub momentum: f32,
    pub causal: bool,
    pub seed: u64,
    /// Every 8th input feature is scaled by this (heavy-tailed activations,
    /// the regime where FP4 quantization error is material).
    pub outlier: f32,
    /// Std of N(0,1) noise added to the student init; 0 = start at the
    /// teacher (finetune setting), >0 = SFT-style gap the run must close.
    pub init_jitter: f32,
}

impl Default for TrainerConfig {
    fn default() -> TrainerConfig {
        TrainerConfig {
            n: 32,
            d_model: 16,
            d_head: 16,
            lr: 0.2,
            momentum: 0.9,
            causal: true,
            seed: 42,
            outlier: 2.0,
            init_jitter: 0.0,
        }
    }
}

/// `(n×m) · (m×p)` row-major f32 matmul.
fn matmul(a: &[f32], b: &[f32], n: usize, m: usize, p: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * p];
    for i in 0..n {
        for kk in 0..m {
            let aik = a[i * m + kk];
            let brow = &b[kk * p..(kk + 1) * p];
            let orow = &mut out[i * p..(i + 1) * p];
            for (x, &bv) in orow.iter_mut().zip(brow) {
                *x += aik * bv;
            }
        }
    }
    out
}

/// `aᵀ · b` for `a (n×m)`, `b (n×p)` → `(m×p)` (the projection-weight
/// chain rule dW = Xᵀ·dY).
fn matmul_tn(a: &[f32], b: &[f32], n: usize, m: usize, p: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * p];
    for i in 0..n {
        for kk in 0..m {
            let aik = a[i * m + kk];
            let brow = &b[i * p..(i + 1) * p];
            let orow = &mut out[kk * p..(kk + 1) * p];
            for (x, &bv) in orow.iter_mut().zip(brow) {
                *x += aik * bv;
            }
        }
    }
    out
}

/// One trainable projection with its SGD-momentum velocity.
struct Param {
    w: Vec<f32>,
    vel: Vec<f32>,
}

impl Param {
    fn new(w: Vec<f32>) -> Param {
        let vel = vec![0.0f32; w.len()];
        Param { w, vel }
    }

    /// v ← μv + g;  w ← w − lr·v. Returns Σ g² (for the grad norm).
    fn apply(&mut self, grad: &[f32], lr: f32, momentum: f32) -> f64 {
        let sq: f64 = grad.iter().map(|&g| g as f64 * g as f64).sum();
        for ((w, v), &g) in self.w.iter_mut().zip(self.vel.iter_mut()).zip(grad) {
            *v = momentum * *v + g;
            *w -= lr * *v;
        }
        sq
    }
}

/// Native SGD+momentum trainer over one attention layer.
pub struct NativeTrainer {
    pub cfg: TrainerConfig,
    /// The unified attention config driving the student's forward and the
    /// backward ablation switches.
    pub attn: AttnConfig,
    /// Student attention session (the variant's engine).
    engine: AttnEngine,
    /// Frozen f32 teacher session.
    teacher: AttnEngine,
    wq: Param,
    wk: Param,
    wv: Param,
    /// Frozen teacher projections (the "pretrained base").
    tq: Vec<f32>,
    tk: Vec<f32>,
    tv: Vec<f32>,
    data: Rng,
    step: usize,
    pub history: Vec<StepMetrics>,
    /// Same semantics as `coordinator::Trainer`: runs continue past this —
    /// divergence is observable data, not a crash.
    pub divergence_threshold: f32,
}

impl NativeTrainer {
    /// Build a trainer from one of the named ablation presets.
    pub fn new(cfg: TrainerConfig, variant: QatVariant) -> NativeTrainer {
        let attn = variant.config();
        NativeTrainer::with_attention(cfg, attn)
    }

    /// Build a trainer from an explicit [`AttnConfig`] (e.g. from
    /// `AttnConfig::parse`); `cfg.causal` overrides the config's causal
    /// flag so the teacher and student always agree with the trainer
    /// setting.
    pub fn with_attention(cfg: TrainerConfig, attn: AttnConfig) -> NativeTrainer {
        let attn = attn.with_causal(cfg.causal);
        let (dm, dh) = (cfg.d_model, cfg.d_head);
        assert_eq!(dh % 16, 0, "d_head must be a multiple of 16");
        let root = Rng::new(cfg.seed);
        let std = 1.0 / (dm as f32).sqrt();
        let mut teacher = root.split("teacher");
        let tq = teacher.normal_vec(dm * dh, 0.0, std);
        let tk = teacher.normal_vec(dm * dh, 0.0, std);
        let tv = teacher.normal_vec(dm * dh, 0.0, std);
        let (mut wq, mut wk, mut wv) = (tq.clone(), tk.clone(), tv.clone());
        if cfg.init_jitter > 0.0 {
            let mut init = root.split("init");
            for w in [&mut wq, &mut wk, &mut wv] {
                for (x, j) in w.iter_mut().zip(init.normal_vec(dm * dh, 0.0, cfg.init_jitter)) {
                    *x += j;
                }
            }
        }
        let data = root.split("data");
        NativeTrainer {
            cfg,
            attn,
            engine: AttnEngine::new(attn),
            teacher: AttnEngine::new(AttnConfig::f32().with_causal(attn.causal)),
            wq: Param::new(wq),
            wk: Param::new(wk),
            wv: Param::new(wv),
            tq,
            tk,
            tv,
            data,
            step: 0,
            history: Vec::new(),
            divergence_threshold: 1e6,
        }
    }

    /// One SGD step on a fresh synthetic batch. Returns the step metrics.
    pub fn step(&mut self) -> StepMetrics {
        let t0 = std::time::Instant::now();
        let (n, dm, dh) = (self.cfg.n, self.cfg.d_model, self.cfg.d_head);
        let causal = self.cfg.causal;

        // Heavy-tailed batch: N(0,1) with every 8th feature amplified.
        let mut x = self.data.normal_vec(n * dm, 0.0, 1.0);
        for r in 0..n {
            for c in (0..dm).step_by(8) {
                x[r * dm + c] *= self.cfg.outlier;
            }
        }

        // Teacher target (always f32).
        let qs = matmul(&x, &self.tq, n, dm, dh);
        let ks = matmul(&x, &self.tk, n, dm, dh);
        let vs = matmul(&x, &self.tv, n, dm, dh);
        let y = self.teacher.forward(&qs, &ks, &vs, 1, n, n, dh).o;

        // Student training forward through the session's engine (for f32
        // sessions O′ == O, so one call covers every variant).
        let q = matmul(&x, &self.wq.w, n, dm, dh);
        let k = matmul(&x, &self.wk.w, n, dm, dh);
        let v = matmul(&x, &self.wv.w, n, dm, dh);
        let t = self.engine.forward_train(&q, &k, &v, 1, n, n, dh);
        let (o, o_prime, lse) = (t.o, t.o_prime, t.lse);

        // MSE on the quantized-path output.
        let numel = (n * dh) as f32;
        let mut loss_acc = 0.0f64;
        let mut dout = vec![0.0f32; n * dh];
        for (g, (&oc, &yc)) in dout.iter_mut().zip(o.iter().zip(&y)) {
            let e = oc - yc;
            loss_acc += e as f64 * e as f64;
            *g = 2.0 * e / numel;
        }
        let loss = (loss_acc / numel as f64) as f32;

        // Attention backward (STE grads w.r.t. raw Q/K/V) → weight grads.
        let g = flash_backward(
            &q,
            &k,
            &v,
            n,
            n,
            dh,
            causal,
            &o,
            &o_prime,
            &lse,
            &dout,
            self.attn.bwd,
        );
        let gq = matmul_tn(&x, &g.dq, n, dm, dh);
        let gk = matmul_tn(&x, &g.dk, n, dm, dh);
        let gv = matmul_tn(&x, &g.dv, n, dm, dh);

        let (lr, mu) = (self.cfg.lr, self.cfg.momentum);
        let sq = self.wq.apply(&gq, lr, mu) + self.wk.apply(&gk, lr, mu)
            + self.wv.apply(&gv, lr, mu);
        let grad_norm = sq.sqrt() as f32;

        self.step += 1;
        let m = StepMetrics {
            step: self.step,
            loss,
            grad_norm,
            lr,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        };
        self.history.push(m);
        m
    }

    /// Run `steps` steps; `on_log` fires every `log_every` steps (and on
    /// the last one). `log_every = 0` is silent.
    pub fn run(&mut self, steps: usize, log_every: usize, mut on_log: impl FnMut(&StepMetrics)) {
        for i in 0..steps {
            let m = self.step();
            if log_every > 0 && (i % log_every == 0 || i + 1 == steps) {
                on_log(&m);
            }
        }
    }

    /// True if any recorded step went non-finite or past the threshold.
    pub fn diverged(&self) -> bool {
        self.history.iter().any(|m| {
            !m.loss.is_finite()
                || !m.grad_norm.is_finite()
                || m.loss.abs() > self.divergence_threshold
                || m.grad_norm > self.divergence_threshold
        })
    }

    /// Largest finite grad norm seen (0.0 if none recorded).
    pub fn max_grad_norm(&self) -> f32 {
        self.history
            .iter()
            .map(|m| m.grad_norm)
            .filter(|g| g.is_finite())
            .fold(0.0f32, f32::max)
    }

    /// Mean loss over the last `k` finite steps (NaN if none).
    pub fn tail_loss(&self, k: usize) -> f32 {
        let tail: Vec<f32> = self
            .history
            .iter()
            .rev()
            .take(k)
            .map(|m| m.loss)
            .filter(|l| l.is_finite())
            .collect();
        if tail.is_empty() {
            f32::NAN
        } else {
            tail.iter().sum::<f32>() / tail.len() as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_history() {
        let mut a = NativeTrainer::new(TrainerConfig::default(), QatVariant::AttnQat);
        let mut b = NativeTrainer::new(TrainerConfig::default(), QatVariant::AttnQat);
        a.run(5, 0, |_| {});
        b.run(5, 0, |_| {});
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(x.loss, y.loss);
            assert_eq!(x.grad_norm, y.grad_norm);
        }
    }

    #[test]
    fn fig3_dropin_unstable_attn_qat_stable() {
        // The paper's headline training-dynamics result (Fig. 3a/b), on the
        // native path. Margins are wide: in simulation across seeds the
        // drop-in max grad-norm is ≥ 361 (often NaN) while Attn-QAT stays
        // ≤ 1.7 under the same hot learning rate.
        let steps = 150;
        let mut qat = NativeTrainer::new(TrainerConfig::default(), QatVariant::AttnQat);
        qat.run(steps, 0, |_| {});
        assert!(!qat.diverged(), "Attn-QAT must not diverge");
        assert!(
            qat.max_grad_norm() < 50.0,
            "Attn-QAT grad norm spiked: {}",
            qat.max_grad_norm()
        );

        let mut dropin = NativeTrainer::new(TrainerConfig::default(), QatVariant::DropIn);
        dropin.run(steps, 0, |_| {});
        assert!(
            dropin.diverged() || dropin.max_grad_norm() > 100.0,
            "drop-in QAT should spike/diverge; max gnorm {}",
            dropin.max_grad_norm()
        );
    }

    #[test]
    fn partial_fixes_run_without_divergence_at_fig3_lr() {
        // The two single-fix ablations sit between the extremes; at the
        // Fig-3 setting both stay finite (their curves are the point).
        for variant in [QatVariant::NoHighPrecO, QatVariant::NoFqP] {
            let mut t = NativeTrainer::new(TrainerConfig::default(), variant);
            t.run(80, 0, |_| {});
            assert!(!t.diverged(), "{variant:?} diverged");
        }
    }

    #[test]
    fn f32_and_qat_converge_at_sft_lr() {
        // Fig. 3c proxy: from a jittered init at a normal lr, both the f32
        // baseline and Attn-QAT close most of the gap (QAT plateaus at its
        // quantization floor). Simulated improvements: ~108× and ~20×.
        let cfg = TrainerConfig {
            lr: 0.05,
            init_jitter: 0.125,
            ..TrainerConfig::default()
        };
        for (variant, min_improvement) in
            [(QatVariant::F32, 10.0f32), (QatVariant::AttnQat, 3.0)]
        {
            let mut t = NativeTrainer::new(cfg.clone(), variant);
            t.run(150, 0, |_| {});
            assert!(!t.diverged(), "{variant:?} diverged");
            let first = t.history[0].loss;
            let tail = t.tail_loss(10);
            assert!(
                first / tail > min_improvement,
                "{variant:?}: loss {first} -> {tail}"
            );
        }
    }
}
