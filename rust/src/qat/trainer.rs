//! The old native trainer, now a deprecated shim over the unified model
//! stack.
//!
//! The Figure-3 harness lives on as [`crate::model::AttnRegressor`] (the
//! task: a frozen f32 teacher attention chased by trainable Q/K/V
//! projections) driven by a [`crate::model::TrainSession`] (the loop:
//! optimizer trait, lr schedule, grad clip, `StepMetrics` history).
//! [`NativeTrainer`] simply wraps `AttnRegressor::session` — its step
//! math was ported verbatim, so histories match the pre-refactor trainer
//! **bitwise** (pinned by `shim_matches_session_bitwise` below plus the
//! Fig-3 behavior tests, which run on the session API).
//!
//! Migration:
//!
//! | old | new |
//! |-----|-----|
//! | `NativeTrainer::new(cfg, variant)` | `AttnRegressor::session(cfg, variant.config())` |
//! | `NativeTrainer::with_attention(cfg, attn)` | `AttnRegressor::session(cfg, attn)` |
//! | `trainer.history` (field) | `session.history` (field) |
//! | `trainer.step()/run()/diverged()/...` | same methods on `TrainSession` |
//! | hand-rolled SGD | `TrainConfig::sgd(lr, momentum)` |
//! | — | `TrainConfig::adam(lr)` (+ global grad-clip, lr schedules) |

use crate::attention::AttnConfig;
use crate::coordinator::StepMetrics;
use crate::model::{AttnRegressor, TrainSession};

use super::QatVariant;

/// Native trainer hyper-parameters (defaults = the Fig-3a/b setting).
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    /// Tokens per step (sequence length).
    pub n: usize,
    /// Input feature dimension.
    pub d_model: usize,
    /// Attention head dimension (multiple of 16 keeps padding trivial).
    pub d_head: usize,
    pub lr: f32,
    pub momentum: f32,
    pub causal: bool,
    pub seed: u64,
    /// Every 8th input feature is scaled by this (heavy-tailed activations,
    /// the regime where FP4 quantization error is material).
    pub outlier: f32,
    /// Std of N(0,1) noise added to the student init; 0 = start at the
    /// teacher (finetune setting), >0 = SFT-style gap the run must close.
    pub init_jitter: f32,
}

impl Default for TrainerConfig {
    fn default() -> TrainerConfig {
        TrainerConfig {
            n: 32,
            d_model: 16,
            d_head: 16,
            lr: 0.2,
            momentum: 0.9,
            causal: true,
            seed: 42,
            outlier: 2.0,
            init_jitter: 0.0,
        }
    }
}

/// Deprecated shim over [`TrainSession`]`<`[`AttnRegressor`]`>` — see the
/// module docs for the migration table.
#[deprecated(note = "use model::AttnRegressor::session (TrainSession over the Fig-3 task)")]
pub struct NativeTrainer {
    session: TrainSession<AttnRegressor>,
}

#[allow(deprecated)]
impl NativeTrainer {
    /// Build a trainer from one of the named ablation presets.
    pub fn new(cfg: TrainerConfig, variant: QatVariant) -> NativeTrainer {
        NativeTrainer::with_attention(cfg, variant.config())
    }

    /// Build a trainer from an explicit [`AttnConfig`]; `cfg.causal`
    /// overrides the config's causal flag.
    pub fn with_attention(cfg: TrainerConfig, attn: AttnConfig) -> NativeTrainer {
        NativeTrainer { session: AttnRegressor::session(cfg, attn) }
    }

    /// The unified attention config driving the student (causal resolved).
    pub fn attn(&self) -> AttnConfig {
        self.session.model.attn
    }

    /// One SGD step on a fresh synthetic batch. Returns the step metrics.
    pub fn step(&mut self) -> StepMetrics {
        self.session.step()
    }

    /// Run `steps` steps; `on_log` fires every `log_every` steps (and on
    /// the last one). `log_every = 0` is silent.
    pub fn run(&mut self, steps: usize, log_every: usize, on_log: impl FnMut(&StepMetrics)) {
        self.session.run(steps, log_every, on_log)
    }

    /// Recorded step history (same `StepMetrics` series as before).
    pub fn history(&self) -> &[StepMetrics] {
        &self.session.history
    }

    /// True if any recorded step went non-finite or past the threshold.
    pub fn diverged(&self) -> bool {
        self.session.diverged()
    }

    /// Largest finite grad norm seen (0.0 if none recorded).
    pub fn max_grad_norm(&self) -> f32 {
        self.session.max_grad_norm()
    }

    /// Mean loss over the last `k` finite steps (NaN if none).
    pub fn tail_loss(&self, k: usize) -> f32 {
        self.session.tail_loss(k)
    }
}

#[cfg(test)]
#[allow(deprecated)] // the shim is exactly what these tests pin
mod tests {
    use super::*;
    use crate::model::AttnRegressor;

    #[test]
    fn shim_matches_session_bitwise() {
        // The deprecated shim and a hand-built session must produce the
        // same float sequence — the API migration cannot change fig3.
        let mut shim = NativeTrainer::new(TrainerConfig::default(), QatVariant::AttnQat);
        let mut session =
            AttnRegressor::session(TrainerConfig::default(), QatVariant::AttnQat.config());
        shim.run(10, 0, |_| {});
        session.run(10, 0, |_| {});
        assert_eq!(shim.history().len(), session.history.len());
        for (a, b) in shim.history().iter().zip(&session.history) {
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.grad_norm, b.grad_norm);
            assert_eq!(a.lr, b.lr);
        }
    }

    #[test]
    fn deterministic_history() {
        let mut a = NativeTrainer::new(TrainerConfig::default(), QatVariant::AttnQat);
        let mut b = NativeTrainer::new(TrainerConfig::default(), QatVariant::AttnQat);
        a.run(5, 0, |_| {});
        b.run(5, 0, |_| {});
        for (x, y) in a.history().iter().zip(b.history()) {
            assert_eq!(x.loss, y.loss);
            assert_eq!(x.grad_norm, y.grad_norm);
        }
    }

    #[test]
    fn fig3_dropin_unstable_attn_qat_stable() {
        // The paper's headline training-dynamics result (Fig. 3a/b),
        // through the shim (the session-API version lives in
        // model::regressor). Margins are wide: in simulation across seeds
        // the drop-in max grad-norm is ≥ 361 (often NaN) while Attn-QAT
        // stays ≤ 1.7 under the same hot learning rate.
        let steps = 150;
        let mut qat = NativeTrainer::new(TrainerConfig::default(), QatVariant::AttnQat);
        qat.run(steps, 0, |_| {});
        assert!(!qat.diverged(), "Attn-QAT must not diverge");
        assert!(
            qat.max_grad_norm() < 50.0,
            "Attn-QAT grad norm spiked: {}",
            qat.max_grad_norm()
        );

        let mut dropin = NativeTrainer::new(TrainerConfig::default(), QatVariant::DropIn);
        dropin.run(steps, 0, |_| {});
        assert!(
            dropin.diverged() || dropin.max_grad_norm() > 100.0,
            "drop-in QAT should spike/diverge; max gnorm {}",
            dropin.max_grad_norm()
        );
    }

    #[test]
    fn partial_fixes_run_without_divergence_at_fig3_lr() {
        // The two single-fix ablations sit between the extremes; at the
        // Fig-3 setting both stay finite (their curves are the point).
        for variant in [QatVariant::NoHighPrecO, QatVariant::NoFqP] {
            let mut t = NativeTrainer::new(TrainerConfig::default(), variant);
            t.run(80, 0, |_| {});
            assert!(!t.diverged(), "{variant:?} diverged");
        }
    }

    #[test]
    fn f32_and_qat_converge_at_sft_lr() {
        // Fig. 3c proxy: from a jittered init at a normal lr, both the f32
        // baseline and Attn-QAT close most of the gap (QAT plateaus at its
        // quantization floor). Simulated improvements: ~108× and ~20×.
        let cfg = TrainerConfig {
            lr: 0.05,
            init_jitter: 0.125,
            ..TrainerConfig::default()
        };
        for (variant, min_improvement) in
            [(QatVariant::F32, 10.0f32), (QatVariant::AttnQat, 3.0)]
        {
            let mut t = NativeTrainer::new(cfg.clone(), variant);
            t.run(150, 0, |_| {});
            assert!(!t.diverged(), "{variant:?} diverged");
            let first = t.history()[0].loss;
            let tail = t.tail_loss(10);
            assert!(
                first / tail > min_improvement,
                "{variant:?}: loss {first} -> {tail}"
            );
        }
    }
}
