//! Native Attn-QAT training subsystem: the paper's backward pass, in Rust.
//!
//! `qat` owns the **attention gradient math**; the model/optimizer layers
//! above it live in [`crate::model`] ([`crate::model::QatModel`] routes
//! every attention layer's backward through here, and
//! [`crate::model::TrainSession`] drives the optimizer loop). Together
//! they make the paper's training-side results reproduce with plain
//! `cargo run -- exp fig3` — no XLA, no compiled artifacts.
//!
//! The paper identifies two principles for stable FP4 attention training
//! (§3.2), both implemented by [`backward::flash_backward`]:
//!
//! 1. **Matched low-precision recomputation** (Fix A): the FA2-style
//!    backward recomputes S and P from the *same quantized operands* the
//!    forward used — here literally from the **packed** NVFP4 Q/K via the
//!    byte-pair LUT ([`crate::formats::lut`]), and the recomputed P is
//!    fake-quantized again before the dV matmul (Alg. 3 l.11). A stock FA
//!    backward recomputes from the raw f32 tensors, so its gradients
//!    describe a different function than the one the forward evaluated.
//! 2. **Resolved implicit precision assumption in D** (Fix B): Flash
//!    Attention's gradient term `D = rowsum(dO ∘ O)` silently assumes O was
//!    computed from the *unquantized* P. With a quantized forward that
//!    assumption breaks — the softmax gradient rows no longer sum to zero
//!    and a spurious component accumulates. The training forward therefore
//!    also returns the high-precision `O′ = P·V^F / l` (Alg. 2 l.13) and
//!    the backward computes `D = rowsum(dO ∘ O′)` (Alg. 3 l.3).
//!
//! [`backward::flash_backward_cfg`] extends the matched recompute to the
//! forward's SageAttention3 knobs — smooth-K/Q (Eq. 4, including the
//! high-precision ΔS fixup and the K-mean chain rule) and two-level P̃ —
//! so every `attention::AttnConfig` a training forward accepts has a
//! matching backward.
//!
//! Ablation switches → Figure-3 curves (same labels as the compiled path):
//!
//! | [`QatVariant`]   | recompute      | P in dV     | D from | Fig. 3 curve |
//! |------------------|----------------|-------------|--------|--------------|
//! | `AttnQat`        | packed FP4     | fake-quant  | O′     | "Attn-QAT" (stable) |
//! | `NoHighPrecO`    | packed FP4     | fake-quant  | O      | "- High prec. O in BWD" |
//! | `NoFqP`          | packed FP4     | high-prec   | O′     | "- Fake quant P in BWD" |
//! | `DropIn`         | raw f32        | high-prec   | O      | "naive drop-in" (spikes/diverges) |
//! | `F32`            | raw f32        | high-prec   | O (=O′)| "BF16" baseline (f32 fwd too) |
//!
//! Gradients leave the subsystem with respect to the **raw** Q/K/V via the
//! straight-through estimator ([`ste`], Eq. 7). The optimizer side moved
//! to [`crate::model`]: [`trainer::NativeTrainer`] survives only as a
//! `#[deprecated]` shim over `model::AttnRegressor::session` (bitwise —
//! see its migration table), and `model::TrainSession` adds Adam + global
//! grad-clip (the paper's finetune recipe) behind an optimizer trait.
//!
//! ## Where `qat` sits in the full-stack precision map
//!
//! This module quantizes exactly one tensor class — the attention
//! operands Q/K/V/P̃ — and keeps everything it *touches* in f32: incoming
//! activations, outgoing gradients, master weights. The rest of the
//! training step goes low-precision in [`crate::model::lowp`], built on
//! the same two principles proven here:
//!
//! * projection/MLP GEMMs: NVFP4 fake-quant weights with STE, **matched
//!   recompute** (the backward multiplies by the same quantized scratch
//!   weights the forward used — Fix A, applied one level up) —
//!   [`crate::model::ProjQuant`];
//! * optimizer moments: E4M3 bytes with seeded stochastic rounding
//!   (unbiased where RNE would silently stall Adam-scale updates, the
//!   same failure mode as the naive drop-in row above) —
//!   [`crate::model::LowPAdam`];
//! * the per-component ablation grid lives in
//!   `experiments::fullstack` (`cargo run -- exp fullstack`), the
//!   full-stack analogue of the Fig-3 switches table.

pub mod backward;
pub mod ste;
pub mod trainer;

pub use backward::{flash_backward, flash_backward_cfg, AttnGrads, BwdSwitches};
#[allow(deprecated)]
pub use trainer::NativeTrainer;
pub use trainer::TrainerConfig;

use crate::attention::AttnConfig;

/// Training variant: forward precision + backward ablation switches.
///
/// Each variant is a named preset over the unified [`AttnConfig`]
/// (see [`QatVariant::config`]); parse strings through
/// [`AttnConfig::parse`], which covers this vocabulary and the forward
/// variants in one place.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QatVariant {
    /// f32 forward and backward (the paper's "BF16" baseline).
    F32,
    /// FP4 forward + matched backward with both fixes (Alg. 2 + Alg. 3).
    AttnQat,
    /// Attn-QAT without Fix B: D from the quantized-path O (Table 2 Exp. 7).
    NoHighPrecO,
    /// Attn-QAT without Fix A's P quantization in dV (Table 2 Exp. 8).
    NoFqP,
    /// FP4 forward + stock f32 FA backward — the unstable "drop-in" QAT.
    DropIn,
}

impl QatVariant {
    #[deprecated(note = "use AttnConfig::parse — one vocabulary, errors list the valid names")]
    pub fn parse(s: &str) -> Option<QatVariant> {
        match s {
            "f32" | "bf16" => Some(QatVariant::F32),
            "qat" | "attn_qat" => Some(QatVariant::AttnQat),
            "qat_no_o_prime" => Some(QatVariant::NoHighPrecO),
            "qat_no_fq_p" => Some(QatVariant::NoFqP),
            "fp4" | "dropin" => Some(QatVariant::DropIn),
            _ => None,
        }
    }

    /// The unified engine config this preset names: forward precision plus
    /// this ablation's backward switches.
    pub fn config(self) -> AttnConfig {
        let base = if self.quantized_forward() { AttnConfig::fp4() } else { AttnConfig::f32() };
        base.with_bwd(self.switches())
    }

    /// Does the forward run through the quantized FP4 engine?
    pub fn quantized_forward(self) -> bool {
        !matches!(self, QatVariant::F32)
    }

    /// Backward ablation switches for this variant.
    pub fn switches(self) -> BwdSwitches {
        match self {
            QatVariant::F32 | QatVariant::DropIn => BwdSwitches {
                fq_inputs: false,
                fq_p: false,
                high_prec_o: false,
            },
            QatVariant::AttnQat => BwdSwitches { fq_inputs: true, fq_p: true, high_prec_o: true },
            QatVariant::NoHighPrecO => {
                BwdSwitches { fq_inputs: true, fq_p: true, high_prec_o: false }
            }
            QatVariant::NoFqP => BwdSwitches { fq_inputs: true, fq_p: false, high_prec_o: true },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_switch_table() {
        // The mapping above *is* the paper's ablation table — pin it.
        let s = QatVariant::AttnQat.switches();
        assert!(s.fq_inputs && s.fq_p && s.high_prec_o);
        let s = QatVariant::DropIn.switches();
        assert!(!s.fq_inputs && !s.fq_p && !s.high_prec_o);
        assert!(!QatVariant::NoHighPrecO.switches().high_prec_o);
        assert!(!QatVariant::NoFqP.switches().fq_p);
        assert!(!QatVariant::F32.quantized_forward());
        assert!(QatVariant::DropIn.quantized_forward());
        #[allow(deprecated)]
        {
            assert_eq!(QatVariant::parse("qat"), Some(QatVariant::AttnQat));
            assert_eq!(QatVariant::parse("fp4"), Some(QatVariant::DropIn));
            assert_eq!(QatVariant::parse("nope"), None);
        }
    }

    #[test]
    fn variant_configs_match_unified_parse() {
        // Each named preset must agree with the AttnConfig::parse entry of
        // the same name — the two vocabularies cannot drift.
        for (name, variant) in [
            ("f32", QatVariant::F32),
            ("qat", QatVariant::AttnQat),
            ("qat_no_o_prime", QatVariant::NoHighPrecO),
            ("qat_no_fq_p", QatVariant::NoFqP),
            ("fp4", QatVariant::DropIn),
        ] {
            assert_eq!(variant.config(), AttnConfig::parse(name).unwrap(), "{name}");
        }
    }
}
