//! FA2-style attention backward with the paper's two fixes (Algorithm 3).
//!
//! Recomputation strategy mirrors FlashAttention-2: nothing from the
//! forward survives except `(O, O′, lse)`; S and P are rebuilt row by row.
//! Under Fix A ([`BwdSwitches::fq_inputs`]) the rebuild happens **in the
//! packed 4-bit domain** — `S_ij = LUT-dot(Q̂_i, K̂_j) · scale` over the same
//! packed codes the forward consumed, so forward and backward see bitwise
//! identical scores (the per-block LUT dots are exact; see `formats::lut`).
//! The whole score row is rebuilt in one [`lut::packed_row_dots_into`]
//! call — the forward's block-dot path with the query-side row setup
//! hoisted out of the key loop (the `fig3_backward` bench records the
//! per-pair vs batched comparison). The recomputed probabilities
//! `P = exp(S − lse)` are then fake-quantized along the key axis before
//! the dV accumulation (Alg. 3 l.11), exactly as the forward quantized P̃.
//!
//! [`flash_backward_cfg`] extends the matched recompute to the forward's
//! SageAttention3 knobs, mirroring `attention::AttnConfig` exactly:
//!
//! * **smoothing** — the backward re-applies Eq. 4 (per-column K mean,
//!   per-tile Q mean) with the *same* `attention::engine::smooth_qk`
//!   preprocessing, quantizes the smoothed operands, and rebuilds
//!   `S = (Q̂·K̂ + q̄_tile·K^F)·scale` including the high-precision ΔS fixup
//!   — bitwise the forward's score. Under the STE the q̄ terms cancel in
//!   dQ (`∂S/∂q̄ = (−B + B) = 0`), while dK picks up the mean-subtraction
//!   chain rule: `dK_j = dB_j − mean_j′(dB_j′)` with
//!   `dB_j = Σ_i dS_ij·(Q̂^F_i + q̄_tile)`.
//! * **two-level P̃** — the Fix-A fake-quantization of the recomputed P
//!   first rescales the row into the E4M3 range (`448·6 / rowmax`) and
//!   divides back after, matching the forward's two-level quantizer.
//!
//! The remaining matmuls (dV = P^Fᵀ·dO, dP = dO·V^Fᵀ, dQ = dS·K^F,
//! dK = dSᵀ·Q^F) contract along axes that do not line up with the NVFP4
//! block axes, so they run in f32 over the *dequantized* quantized values —
//! the same semantics as FP4MM's f32 accumulation, just without a second
//! packing step (matches `ref.flash_backward`).
//!
//! Gradients are returned with respect to the **raw** q/k/v via the
//! straight-through estimator (`ste::ste_grad`, Eq. 7): dQ ≈ dQ^F etc.
//!
//! Pinned to the JAX oracle by `rust/tests/golden/attention_bwd_golden.json`
//! (parity for every ablation mode) and by finite-difference checks in
//! `rust/tests/grad_check.rs` (including the smooth / two-level recompute:
//! simulated cosine vs the FD gradient ≥ 0.98 where a *mismatched*
//! non-smooth recompute of the same residuals drops to ≈ 0.3–0.44).

use crate::attention::engine::smooth_qk;
use crate::attention::packed::{causal_limit, smooth_delta_for_key};
use crate::attention::AttnConfig;
use crate::formats::block::{nvfp4_fake_quant_row, NVFP4_BLOCK};
use crate::formats::lut;

use super::ste::{quantize_attn_inputs_ste, ste_grad};

/// Gradients w.r.t. the raw attention inputs (row-major, same shapes).
#[derive(Clone, Debug)]
pub struct AttnGrads {
    pub dq: Vec<f32>,
    pub dk: Vec<f32>,
    pub dv: Vec<f32>,
}

// The switch struct now lives with the unified config (an `AttnConfig`
// carries it as `.bwd`); re-exported here so `qat::BwdSwitches` keeps
// working.
pub use crate::attention::BwdSwitches;

/// Attention backward over `(O, O′, lse, dO)` residuals.
///
/// `q/k/v` are the **raw** inputs (`nq×d`, `nk×d`); `o`, `o_prime`, `dout`
/// are `nq×d`; `lse` is the per-row logsumexp from the forward (rows with
/// `lse = -inf` — empty causal rows when `nk < nq` — contribute nothing).
/// Causality uses aligned ends, identical to the forward engines.
///
/// This entry point covers the plain-FP4 forwards; a forward configured
/// with smoothing or two-level P̃ needs the matching recompute of
/// [`flash_backward_cfg`].
#[allow(clippy::too_many_arguments)]
pub fn flash_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
    o: &[f32],
    o_prime: &[f32],
    lse: &[f32],
    dout: &[f32],
    sw: BwdSwitches,
) -> AttnGrads {
    flash_backward_core(
        q, k, v, nq, nk, d, causal, o, o_prime, lse, dout, sw, false, false, NVFP4_BLOCK,
    )
}

/// Config-driven backward: [`flash_backward`] whose recompute mirrors
/// *every* forward knob of the [`AttnConfig`] — causal flag, ablation
/// switches, smoothing, two-level P̃, and the Q-tile size. This is what
/// `model::QatModel` routes each layer's backward through, so the Fig-3
/// `BwdSwitches` ablations (and the smooth-K / Sage3 variants) apply per
/// layer.
#[allow(clippy::too_many_arguments)]
pub fn flash_backward_cfg(
    cfg: &AttnConfig,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    o: &[f32],
    o_prime: &[f32],
    lse: &[f32],
    dout: &[f32],
) -> AttnGrads {
    flash_backward_core(
        q,
        k,
        v,
        nq,
        nk,
        d,
        cfg.causal,
        o,
        o_prime,
        lse,
        dout,
        cfg.bwd,
        cfg.smooth,
        cfg.two_level_p,
        cfg.block_q,
    )
}

#[allow(clippy::too_many_arguments)]
fn flash_backward_core(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    causal: bool,
    o: &[f32],
    o_prime: &[f32],
    lse: &[f32],
    dout: &[f32],
    sw: BwdSwitches,
    smooth: bool,
    two_level_p: bool,
    block_q: usize,
) -> AttnGrads {
    debug_assert_eq!(q.len(), nq * d);
    debug_assert_eq!(k.len(), nk * d);
    debug_assert_eq!(v.len(), nk * d);
    debug_assert_eq!(o.len(), nq * d);
    debug_assert_eq!(o_prime.len(), nq * d);
    debug_assert_eq!(lse.len(), nq);
    debug_assert_eq!(dout.len(), nq * d);
    let scale = 1.0 / (d as f32).sqrt();
    let nkp = nk.div_ceil(NVFP4_BLOCK) * NVFP4_BLOCK;

    // Fix A precondition: the backward's operands. Quantized (packed +
    // dequantized views sharing one set of bits) or raw f32. Smoothing is
    // a pre-quantization transform, so it applies before the single
    // quantization point — exactly as the forward's engine does.
    let smooth = smooth && sw.fq_inputs;
    let tiles = nq.div_ceil(block_q);
    let (quant, q_means) = if sw.fq_inputs {
        if smooth {
            let (qs, ks, qm) = smooth_qk(q, k, nq, nk, d, block_q);
            (Some(quantize_attn_inputs_ste(&qs, &ks, v, nq, nk, d)), qm)
        } else {
            (Some(quantize_attn_inputs_ste(q, k, v, nq, nk, d)), Vec::new())
        }
    } else {
        (None, Vec::new())
    };
    let (qf, kf, vf): (&[f32], &[f32], &[f32]) = match &quant {
        Some(inp) => (&inp.qf, &inp.kf, &inp.vf),
        None => (q, k, v),
    };
    let lut_table = lut::pair_dot();

    // Smooth ΔS fixup, rebuilt with the forward's own helper (same
    // accumulation order ⇒ the recomputed S matches the forward bitwise):
    // per (tile, j) high-precision q̄_t · K^F_j over the dequantized
    // smoothed K rows.
    let mut delta = Vec::new();
    if smooth {
        delta.resize(tiles * nk, 0.0f32);
        for j in 0..nk {
            let kj = &kf[j * d..(j + 1) * d];
            smooth_delta_for_key(&q_means, tiles, d, kj, j, nk, &mut delta);
        }
    }

    // Fix B: D = rowsum(dO ∘ O′) — or the naive rowsum(dO ∘ O).
    let o_for_d = if sw.high_prec_o { o_prime } else { o };
    let mut d_vec = vec![0.0f32; nq];
    for i in 0..nq {
        let mut acc = 0.0f32;
        for c in 0..d {
            acc += dout[i * d + c] * o_for_d[i * d + c];
        }
        d_vec[i] = acc;
    }

    let mut dq = vec![0.0f32; nq * d];
    let mut dk = vec![0.0f32; nk * d];
    let mut dv = vec![0.0f32; nk * d];
    let mut s_row = vec![0.0f32; nk];
    let mut p_row = vec![0.0f32; nkp];
    let mut pf_row = vec![0.0f32; nkp];
    let mut q_eff = vec![0.0f32; d];

    for i in 0..nq {
        let tile = i / block_q;
        let limit = if causal { causal_limit(i, nq, nk) } else { nk };
        if limit == 0 {
            continue; // empty causal row: zero gradient everywhere
        }
        let doi = &dout[i * d..(i + 1) * d];
        // --- recompute S, P (Alg. 3 l.9-10) -------------------------------
        match &quant {
            Some(inp) => {
                // One batched block-dot call per score row (the forward's
                // LUT path, query-side setup hoisted out of the key loop).
                lut::packed_row_dots_into(lut_table, &inp.q4, i, &inp.k4, limit, &mut s_row);
            }
            None => {
                let qi = &q[i * d..(i + 1) * d];
                for (j, s) in s_row[..limit].iter_mut().enumerate() {
                    let kj = &k[j * d..(j + 1) * d];
                    let mut acc = 0.0f32;
                    for c in 0..d {
                        acc += qi[c] * kj[c];
                    }
                    *s = acc;
                }
            }
        }
        for j in 0..limit {
            let mut acc = s_row[j];
            if smooth {
                acc += delta[tile * nk + j];
            }
            p_row[j] = (acc * scale - lse[i]).exp();
        }
        for p in p_row[limit..].iter_mut() {
            *p = 0.0;
        }
        // --- Fix A: fake-quantize the recomputed P (Alg. 3 l.11) ----------
        let pf: &[f32] = if sw.fq_p {
            pf_row.copy_from_slice(&p_row);
            if two_level_p {
                // Two-level P̃: rescale into the E4M3 range before the
                // NVFP4 pass, divide back after (the forward's quantizer).
                let rmax = pf_row[..limit].iter().fold(0.0f32, |a, &b| a.max(b));
                let factor = if rmax > 0.0 { 448.0 * 6.0 / rmax } else { 1.0 };
                for p in pf_row.iter_mut() {
                    *p *= factor;
                }
                nvfp4_fake_quant_row(&mut pf_row);
                let inv_factor = 1.0 / factor;
                for p in pf_row.iter_mut() {
                    *p *= inv_factor;
                }
            } else {
                nvfp4_fake_quant_row(&mut pf_row);
            }
            &pf_row
        } else {
            &p_row
        };
        // --- dV += P^Fᵀ · dO (Alg. 3 l.12) --------------------------------
        for j in 0..limit {
            let p = pf[j];
            if p == 0.0 {
                continue;
            }
            let dvj = &mut dv[j * d..(j + 1) * d];
            for (x, &g) in dvj.iter_mut().zip(doi) {
                *x += p * g;
            }
        }
        // --- dS = P ∘ (dP − D) · scale; dQ, dK (Alg. 3 l.13-16) -----------
        let dqi = &mut dq[i * d..(i + 1) * d];
        let qfi = &qf[i * d..(i + 1) * d];
        // dK accumulates against the *effective* query coefficient
        // ∂S/∂K^F_j: the quantized row itself, plus the tile mean under
        // smoothing (the ΔS term's factor).
        let q_row: &[f32] = if smooth {
            let qmt = &q_means[tile * d..(tile + 1) * d];
            for ((x, &a), &b) in q_eff.iter_mut().zip(qfi).zip(qmt) {
                *x = a + b;
            }
            &q_eff
        } else {
            qfi
        };
        for j in 0..limit {
            let p = p_row[j];
            if p == 0.0 {
                continue;
            }
            let vj = &vf[j * d..(j + 1) * d];
            let mut dp = 0.0f32;
            for c in 0..d {
                dp += doi[c] * vj[c];
            }
            let ds = p * (dp - d_vec[i]) * scale;
            let kj = &kf[j * d..(j + 1) * d];
            for (x, &kc) in dqi.iter_mut().zip(kj) {
                *x += ds * kc;
            }
            let dkj = &mut dk[j * d..(j + 1) * d];
            for (x, &qc) in dkj.iter_mut().zip(q_row) {
                *x += ds * qc;
            }
        }
    }
    // Smoothing chain rule for the K mean: K^F_j = φ(k_j − k̄) with
    // k̄ = mean_j(k_j), so dk_j = dB_j − mean_j′(dB_j′). (The q̄ terms
    // cancel exactly in dQ: ∂S/∂q̄ = (−K^F + K^F) = 0.)
    if smooth && nk > 0 {
        let inv = 1.0 / nk as f32;
        for c in 0..d {
            let mut mean = 0.0f32;
            for j in 0..nk {
                mean += dk[j * d + c];
            }
            mean *= inv;
            for j in 0..nk {
                dk[j * d + c] -= mean;
            }
        }
    }
    // STE (Eq. 7): gradients w.r.t. the quantized operands pass through the
    // quantizers unchanged to the raw tensors.
    AttnGrads { dq: ste_grad(dq), dk: ste_grad(dk), dv: ste_grad(dv) }
}

#[cfg(test)]
#[allow(deprecated)] // residuals come from the pinned forward shims
mod tests {
    use super::*;
    use crate::attention::engine::attend_fp4_train;
    use crate::attention::flash::attend_f32;
    use crate::attention::{AttnConfig, AttnEngine};
    use crate::rng::Rng;

    const QAT: BwdSwitches = BwdSwitches::MATCHED;
    const DROPIN: BwdSwitches = BwdSwitches::STOCK;

    fn rand_case(nq: usize, nk: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            rng.normal_vec(nq * d, 0.0, 1.0),
            rng.normal_vec(nk * d, 0.0, 1.0),
            rng.normal_vec(nk * d, 0.0, 1.0),
            rng.normal_vec(nq * d, 0.0, 1.0),
        )
    }

    #[test]
    fn softmax_row_gradient_sums_to_zero_with_fix_b() {
        // With D = rowsum(dO ∘ O′), each query row's dS sums to zero, so
        // Σ_i dq_i ≈ Σ_j (Σ_i ds_ij) k_j stays bounded. The telltale:
        // replacing O′ with O (NoHighPrecO) breaks the cancellation.
        let (nq, nk, d) = (16, 16, 16);
        let (q, k, v, dout) = rand_case(nq, nk, d, 41);
        let t = attend_fp4_train(&q, &k, &v, nq, nk, d, false);
        let fixed = flash_backward(
            &q, &k, &v, nq, nk, d, false, &t.o, &t.o_prime, &t.lse, &dout, QAT,
        );
        let naive = flash_backward(
            &q, &k, &v, nq, nk, d, false, &t.o, &t.o_prime, &t.lse, &dout,
            BwdSwitches { high_prec_o: false, ..QAT },
        );
        // Row-sum residual of dS shows up as |Σ_j ds_ij| = |dO_i·(O′_i−O_i)|;
        // measure it through dq magnitudes: the naive-D dq must differ.
        let diff: f32 = fixed
            .dq
            .iter()
            .zip(&naive.dq)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff > 1e-6, "Fix B must change dq: {diff}");
        assert!(fixed.dq.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn empty_causal_rows_have_zero_dq() {
        // nk < nq causal: leading queries see no keys (PR-1 forward edge);
        // their dq rows must be exactly zero and nothing may NaN.
        let (nq, nk, d) = (6, 2, 16);
        let (q, k, v, dout) = rand_case(nq, nk, d, 42);
        let t = attend_fp4_train(&q, &k, &v, nq, nk, d, true);
        let g = flash_backward(
            &q, &k, &v, nq, nk, d, true, &t.o, &t.o_prime, &t.lse, &dout, QAT,
        );
        for i in 0..nq - nk {
            assert!(g.dq[i * d..(i + 1) * d].iter().all(|&x| x == 0.0), "row {i}");
        }
        for x in g.dq.iter().chain(&g.dk).chain(&g.dv) {
            assert!(x.is_finite());
        }
    }

    #[test]
    fn dropin_recomputes_from_raw_inputs() {
        // DropIn uses the raw f32 operands: with a quantized forward the
        // recomputed S mismatches, so the gradients must differ from the
        // matched AttnQat ones on the same residuals.
        let (nq, nk, d) = (16, 16, 16);
        let (mut q, mut k, v, dout) = rand_case(nq, nk, d, 43);
        for x in q.iter_mut().step_by(5) {
            *x *= 8.0;
        }
        for x in k.iter_mut().step_by(7) {
            *x *= 8.0;
        }
        let t = attend_fp4_train(&q, &k, &v, nq, nk, d, false);
        let a = flash_backward(&q, &k, &v, nq, nk, d, false, &t.o, &t.o_prime, &t.lse, &dout, QAT);
        let b =
            flash_backward(&q, &k, &v, nq, nk, d, false, &t.o, &t.o_prime, &t.lse, &dout, DROPIN);
        let diff: f32 =
            a.dk.iter().zip(&b.dk).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
        assert!(diff > 1e-4, "drop-in must mismatch on outliers: {diff}");
    }

    #[test]
    fn f32_mode_matches_softmax_identity() {
        // No quantization anywhere: dV = Pᵀ dO with P the true softmax. For
        // uniform attention (q ⟂ k) every dv row is mean(dO)/... — check
        // the simplest closed form: nq=1 ⇒ dv_j = p_j · dO.
        let (nk, d) = (8, 8);
        let mut rng = Rng::new(44);
        let q = vec![0.0f32; d];
        let k = rng.normal_vec(nk * d, 0.0, 1.0);
        let v = rng.normal_vec(nk * d, 0.0, 1.0);
        let dout = rng.normal_vec(d, 0.0, 1.0);
        let out = attend_f32(&q, &k, &v, 1, nk, d, false);
        let g = flash_backward(
            &q, &k, &v, 1, nk, d, false, &out.o, &out.o, &out.lse, &dout, DROPIN,
        );
        // q = 0 ⇒ uniform p = 1/nk.
        for j in 0..nk {
            for c in 0..d {
                let want = dout[c] / nk as f32;
                assert!((g.dv[j * d + c] - want).abs() < 1e-5, "dv[{j},{c}]");
            }
        }
    }

    #[test]
    fn cfg_entry_point_matches_plain_backward_bitwise() {
        // flash_backward_cfg with no smoothing / two-level knobs must be
        // the old entry point exactly — the wrapper cannot drift.
        let (nq, nk, d) = (9, 13, 16);
        let (q, k, v, dout) = rand_case(nq, nk, d, 45);
        for causal in [false, true] {
            let t = attend_fp4_train(&q, &k, &v, nq, nk, d, causal);
            let cfg = AttnConfig::attn_qat().with_causal(causal);
            let a = flash_backward_cfg(&cfg, &q, &k, &v, nq, nk, d, &t.o, &t.o_prime, &t.lse, &dout);
            let b = flash_backward(
                &q, &k, &v, nq, nk, d, causal, &t.o, &t.o_prime, &t.lse, &dout, QAT,
            );
            assert_eq!(a.dq, b.dq, "causal={causal}");
            assert_eq!(a.dk, b.dk, "causal={causal}");
            assert_eq!(a.dv, b.dv, "causal={causal}");
        }
    }

    #[test]
    fn smooth_recompute_changes_gradients_and_stays_finite() {
        // A large shared K offset is what smoothing absorbs; the matched
        // smooth backward must (a) differ from the non-smooth recompute on
        // the same residuals and (b) produce finite, softmax-consistent
        // gradients. (Gradient *quality* vs FD is pinned in grad_check.)
        let (nq, nk, d) = (16, 16, 16);
        let (q, mut k, v, dout) = rand_case(nq, nk, d, 46);
        for x in k.iter_mut() {
            *x += 4.0;
        }
        let cfg = AttnConfig::attn_qat().with_smooth(true).with_two_level_p(true);
        let mut engine = AttnEngine::new(cfg);
        let t = engine.forward_train(&q, &k, &v, 1, nq, nk, d);
        let a = flash_backward_cfg(&cfg, &q, &k, &v, nq, nk, d, &t.o, &t.o_prime, &t.lse, &dout);
        let plain = AttnConfig::attn_qat();
        let b =
            flash_backward_cfg(&plain, &q, &k, &v, nq, nk, d, &t.o, &t.o_prime, &t.lse, &dout);
        let diff: f32 =
            a.dk.iter().zip(&b.dk).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
        assert!(diff > 1e-3, "smooth recompute must differ: {diff}");
        for x in a.dq.iter().chain(&a.dk).chain(&a.dv) {
            assert!(x.is_finite());
        }
        // The K-mean chain rule zeroes every column sum of the dB
        // redistribution: Σ_j dk_j must be (numerically) tiny compared to
        // the per-row magnitudes.
        let mag: f32 = a.dk.iter().map(|x| x.abs()).fold(0.0, f32::max);
        for c in 0..d {
            let col: f32 = (0..nk).map(|j| a.dk[j * d + c]).sum();
            assert!(col.abs() <= 1e-4 * mag.max(1.0) * nk as f32, "col {c}: {col} vs {mag}");
        }
    }
}
