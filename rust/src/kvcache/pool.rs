//! Refcounted, content-addressed pool of **sealed** NVFP4 pages.
//!
//! A sealed page (16 tokens of packed K + packed Vᵀ for one (layer,
//! head)) is immutable: quantization is deterministic, so byte-identical
//! token prefixes under the same weights produce byte-identical sealed
//! pages. That makes sealed pages natural shared objects — the pool owns
//! them behind small [`PageRef`] handles, deduplicates inserts by
//! content hash, and counts every page's bytes **once** no matter how
//! many sequences (or prefix-index nodes) hold a ref.
//!
//! Lifecycle:
//!
//! * [`PagePool::insert`] — a cache seals a page; with dedup on, a
//!   byte-identical live page is re-used (`refs += 1`) instead of
//!   allocated. Only genuinely fresh pages grow `fresh_bytes`.
//! * [`PagePool::retain`] / [`PagePool::release`] — attach/detach of
//!   refs is the whole copy-on-write story: sealed pages never mutate,
//!   so a sequence diverging from a shared prefix just stops at the
//!   shared run and appends private hot pages after it. A page whose
//!   refcount reaches zero is freed (and its spill file deleted).
//! * [`PagePool::page`] — the read path. Takes `&self` (attends fan out
//!   across threads), bumps the LRU touch clock, and transparently
//!   reloads a spilled page from disk.
//! * [`PagePool::spill_to_budget`] — writes least-recently-touched
//!   resident pages to the configured spill directory until the
//!   resident byte total fits the budget (ROADMAP item (d): cold sealed
//!   pages leave RAM, long contexts keep decoding).
//!
//! Concurrency: mutation (`insert`/`retain`/`release`/spill) is `&mut`
//! and stays on the single worker thread that owns the cache; reads are
//! `&self` behind a per-entry `Mutex` (held only to clone the `Arc` or
//! swap a reloaded page in, never across an attention walk), so the
//! pool is `Sync` for the multi-threaded decode fan-out.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::formats::tensor4::PackedNvfp4;

/// One immutable sealed page: K packed (PAGE_SIZE × d, blocks along d)
/// and V packed transposed (d × PAGE_SIZE, blocks along the token axis).
pub struct SealedPage {
    pub k: PackedNvfp4,
    pub vt: PackedNvfp4,
}

impl SealedPage {
    /// Packed bytes this page occupies (codes + scales of both halves).
    pub fn packed_bytes(&self) -> usize {
        self.k.memory_bytes() + self.vt.memory_bytes()
    }

    /// FNV-1a over dims, codes, and scales of both halves — the pool's
    /// content address. Collisions are disambiguated by a byte compare.
    fn content_hash(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        for half in [&self.k, &self.vt] {
            eat(&(half.rows as u32).to_le_bytes());
            eat(&(half.cols as u32).to_le_bytes());
            eat(&half.codes);
            eat(&half.scales);
        }
        h
    }

    fn content_eq(&self, other: &SealedPage) -> bool {
        self.k.rows == other.k.rows
            && self.k.cols == other.k.cols
            && self.vt.rows == other.vt.rows
            && self.vt.cols == other.vt.cols
            && self.k.codes == other.k.codes
            && self.k.scales == other.k.scales
            && self.vt.codes == other.vt.codes
            && self.vt.scales == other.vt.scales
    }
}

/// Shared handle to a pooled sealed page: a plain index, `Copy`, valid
/// while at least one ref is held. All byte accounting lives in the
/// pool, so cloning a `PageRef` is free and never copies page bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageRef(u32);

impl PageRef {
    /// Raw pool index (diagnostics; the pool may reuse it after free).
    pub fn index(&self) -> usize {
        self.0 as usize
    }
}

/// Where a live page's bytes currently are.
enum PageState {
    Resident(Arc<SealedPage>),
    Spilled(PathBuf),
    /// Entry is on the free list (refs == 0).
    Free,
}

struct PoolEntry {
    refs: u32,
    hash: u64,
    /// Packed bytes (identical resident or spilled).
    bytes: usize,
    state: Mutex<PageState>,
    /// LRU stamp from the pool's logical touch clock (not wall time, so
    /// spill order is deterministic for a deterministic access order).
    last_touch: AtomicU64,
}

/// Disk-spill policy for cold sealed pages (`serve cluster
/// --kv-spill-dir`). The pool creates a unique subdirectory under `dir`
/// per pool instance, so respawned shard incarnations and concurrent
/// tests never collide on file names.
#[derive(Clone, Debug)]
pub struct SpillConfig {
    pub dir: PathBuf,
    /// Resident packed-byte budget; [`PagePool::spill_to_budget`] spills
    /// LRU pages until resident bytes fit under it.
    pub budget_bytes: usize,
}

/// Monotonic pool counters (never decremented; occupancy queries live on
/// the pool itself).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Unique pages created (dedup misses).
    pub unique_pages: u64,
    /// Inserts satisfied by an existing byte-identical page.
    pub dedup_hits: u64,
    /// Packed bytes of unique pages created — the "KV bytes actually
    /// allocated" series the shared-prefix bench reports per sequence.
    pub fresh_bytes: u64,
    /// Pages written to the spill directory (re-spills count again).
    pub spilled_total: u64,
    /// Spilled pages transparently reloaded on an attend.
    pub reloaded: u64,
}

/// The pool (one per [`crate::kvcache::PagedKvCache`]). See module docs.
pub struct PagePool {
    entries: Vec<PoolEntry>,
    free: Vec<u32>,
    /// content hash → entry indices (live entries only).
    by_hash: BTreeMap<u64, Vec<u32>>,
    clock: AtomicU64,
    /// Content-addressed dedup on insert. Off reproduces pre-pool
    /// allocation behavior exactly (every seal is a fresh page).
    dedup: bool,
    spill: Option<SpillConfig>,
    unique_pages: u64,
    dedup_hits: u64,
    fresh_bytes: u64,
    spilled_total: u64,
    reloaded: AtomicU64,
}

/// Distinguishes spill subdirectories across pool instances in one
/// process (respawned shard incarnations share the CLI-level dir).
static POOL_DIR_SEQ: AtomicU64 = AtomicU64::new(0);

impl PagePool {
    pub fn new() -> PagePool {
        PagePool {
            entries: Vec::new(),
            free: Vec::new(),
            by_hash: BTreeMap::new(),
            clock: AtomicU64::new(0),
            dedup: true,
            spill: None,
            unique_pages: 0,
            dedup_hits: 0,
            fresh_bytes: 0,
            spilled_total: 0,
            reloaded: AtomicU64::new(0),
        }
    }

    /// Enable/disable content-addressed dedup (on by default). The
    /// unshared serving baseline turns it off so its memory accounting
    /// matches a pool-less cache bitwise.
    pub fn set_dedup(&mut self, on: bool) {
        self.dedup = on;
    }

    /// Configure (or clear) disk spill. A unique per-pool subdirectory
    /// is created under `cfg.dir`; it is cleaned up on drop.
    pub fn set_spill(&mut self, cfg: Option<SpillConfig>) {
        self.spill = cfg.map(|c| {
            let n = POOL_DIR_SEQ.fetch_add(1, Ordering::SeqCst);
            let dir = c.dir.join(format!("pool{n:04}"));
            let _ = std::fs::create_dir_all(&dir);
            SpillConfig { dir, budget_bytes: c.budget_bytes }
        });
    }

    pub fn spill_config(&self) -> Option<&SpillConfig> {
        self.spill.as_ref()
    }

    fn touch(&self, e: &PoolEntry) {
        let t = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        e.last_touch.store(t, Ordering::Relaxed);
    }

    /// Insert a freshly sealed page, returning a handle carrying one ref.
    /// With dedup on, a byte-identical live page absorbs the insert
    /// (`refs += 1`, nothing allocated).
    pub fn insert(&mut self, page: SealedPage) -> PageRef {
        let hash = page.content_hash();
        if self.dedup {
            if let Some(bucket) = self.by_hash.get(&hash).cloned() {
                for idx in bucket {
                    if self.entries[idx as usize].refs == 0 {
                        continue;
                    }
                    let Ok(existing) = self.page(PageRef(idx)) else { continue };
                    if existing.content_eq(&page) {
                        self.entries[idx as usize].refs += 1;
                        self.dedup_hits += 1;
                        return PageRef(idx);
                    }
                }
            }
        }
        let bytes = page.packed_bytes();
        let state = Mutex::new(PageState::Resident(Arc::new(page)));
        let idx = match self.free.pop() {
            Some(idx) => {
                let e = &mut self.entries[idx as usize];
                e.refs = 1;
                e.hash = hash;
                e.bytes = bytes;
                e.state = state;
                idx
            }
            None => {
                let idx = self.entries.len() as u32;
                self.entries.push(PoolEntry {
                    refs: 1,
                    hash,
                    bytes,
                    state,
                    last_touch: AtomicU64::new(0),
                });
                idx
            }
        };
        self.touch(&self.entries[idx as usize]);
        self.by_hash.entry(hash).or_default().push(idx);
        self.unique_pages += 1;
        self.fresh_bytes += bytes as u64;
        PageRef(idx)
    }

    fn live_entry(&self, r: PageRef) -> Result<&PoolEntry> {
        let e = self
            .entries
            .get(r.0 as usize)
            .ok_or_else(|| anyhow!("page ref {} out of range", r.0))?;
        if e.refs == 0 {
            bail!("dead page ref {} (refcount dropped to zero)", r.0);
        }
        Ok(e)
    }

    /// Take one more ref on a live page (COW attach).
    pub fn retain(&mut self, r: PageRef) {
        let e = &mut self.entries[r.0 as usize];
        assert!(e.refs > 0, "retain of dead page ref {}", r.0);
        e.refs += 1;
    }

    /// Drop one ref; the last release frees the entry (and deletes its
    /// spill file, if any).
    pub fn release(&mut self, r: PageRef) {
        let e = &mut self.entries[r.0 as usize];
        assert!(e.refs > 0, "release of dead page ref {}", r.0);
        e.refs -= 1;
        if e.refs > 0 {
            return;
        }
        let hash = e.hash;
        e.bytes = 0;
        if let Ok(mut st) = e.state.lock() {
            if let PageState::Spilled(path) = &*st {
                let _ = std::fs::remove_file(path);
            }
            *st = PageState::Free;
        }
        if let Some(bucket) = self.by_hash.get_mut(&hash) {
            bucket.retain(|&i| i != r.0);
            if bucket.is_empty() {
                self.by_hash.remove(&hash);
            }
        }
        self.free.push(r.0);
    }

    /// Current refcount of a live page (0 for a freed entry).
    pub fn refs(&self, r: PageRef) -> u32 {
        self.entries.get(r.0 as usize).map(|e| e.refs).unwrap_or(0)
    }

    /// Packed bytes of a live page.
    pub fn page_bytes(&self, r: PageRef) -> usize {
        self.entries.get(r.0 as usize).map(|e| e.bytes).unwrap_or(0)
    }

    /// The read path: touch the LRU clock and hand out the page,
    /// transparently reloading it from disk if it was spilled. `&self` —
    /// safe from the multi-threaded decode fan-out.
    pub fn page(&self, r: PageRef) -> Result<Arc<SealedPage>> {
        let e = self.live_entry(r)?;
        self.touch(e);
        let mut st = e.state.lock().map_err(|_| anyhow!("page {} lock poisoned", r.0))?;
        match &*st {
            PageState::Resident(p) => Ok(p.clone()),
            PageState::Spilled(path) => {
                let page = Arc::new(read_page(path)?);
                self.reloaded.fetch_add(1, Ordering::Relaxed);
                let _ = std::fs::remove_file(path);
                *st = PageState::Resident(page.clone());
                Ok(page)
            }
            PageState::Free => bail!("page ref {} points at a freed entry", r.0),
        }
    }

    /// Spill least-recently-touched resident pages until resident bytes
    /// fit the configured budget. No-op without a spill config. Returns
    /// the number of pages written.
    pub fn spill_to_budget(&mut self) -> Result<usize> {
        let Some(cfg) = self.spill.clone() else { return Ok(0) };
        let mut resident: Vec<(u64, u32, usize)> = Vec::new();
        let mut resident_bytes = 0usize;
        for (i, e) in self.entries.iter().enumerate() {
            if e.refs == 0 {
                continue;
            }
            let st = e.state.lock().map_err(|_| anyhow!("page {i} lock poisoned"))?;
            if matches!(&*st, PageState::Resident(_)) {
                resident.push((e.last_touch.load(Ordering::Relaxed), i as u32, e.bytes));
                resident_bytes += e.bytes;
            }
        }
        if resident_bytes <= cfg.budget_bytes {
            return Ok(0);
        }
        resident.sort_unstable();
        let mut spilled = 0usize;
        for (_, idx, bytes) in resident {
            if resident_bytes <= cfg.budget_bytes {
                break;
            }
            let e = &self.entries[idx as usize];
            let mut st = e.state.lock().map_err(|_| anyhow!("page {idx} lock poisoned"))?;
            let PageState::Resident(page) = &*st else { continue };
            let path = cfg.dir.join(format!("p{idx}.bin"));
            write_page(&path, page)
                .with_context(|| format!("spilling page {idx} to {}", path.display()))?;
            *st = PageState::Spilled(path);
            drop(st);
            resident_bytes -= bytes;
            spilled += 1;
            self.spilled_total += 1;
        }
        Ok(spilled)
    }

    /// Live pages (refs > 0).
    pub fn live_pages(&self) -> usize {
        self.entries.iter().filter(|e| e.refs > 0).count()
    }

    /// Live pages held by more than one ref.
    pub fn shared_pages(&self) -> usize {
        self.entries.iter().filter(|e| e.refs > 1).count()
    }

    /// Live pages currently on disk.
    pub fn spilled_pages(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| e.refs > 0)
            .filter(|e| matches!(e.state.lock().as_deref(), Ok(PageState::Spilled(_))))
            .count()
    }

    /// Packed bytes of live pages resident in RAM.
    pub fn resident_bytes(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| e.refs > 0)
            .filter(|e| matches!(e.state.lock().as_deref(), Ok(PageState::Resident(_))))
            .map(|e| e.bytes)
            .sum()
    }

    /// Packed bytes of all live pages (resident + spilled), each unique
    /// page counted once regardless of refcount.
    pub fn total_bytes(&self) -> usize {
        self.entries.iter().filter(|e| e.refs > 0).map(|e| e.bytes).sum()
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            unique_pages: self.unique_pages,
            dedup_hits: self.dedup_hits,
            fresh_bytes: self.fresh_bytes,
            spilled_total: self.spilled_total,
            reloaded: self.reloaded.load(Ordering::Relaxed),
        }
    }
}

impl Default for PagePool {
    fn default() -> PagePool {
        PagePool::new()
    }
}

impl Drop for PagePool {
    /// Best-effort cleanup of the pool's private spill subdirectory
    /// (files of pages still spilled at teardown, then the dir itself).
    fn drop(&mut self) {
        if let Some(cfg) = &self.spill {
            for e in &self.entries {
                if let Ok(st) = e.state.lock() {
                    if let PageState::Spilled(path) = &*st {
                        let _ = std::fs::remove_file(path);
                    }
                }
            }
            let _ = std::fs::remove_dir(&cfg.dir);
        }
    }
}

/// Spill file format: 8 little-endian u32s
/// `[k.rows, k.cols, k.codes.len, k.scales.len, vt.rows, vt.cols,
/// vt.codes.len, vt.scales.len]` followed by the four byte arrays.
fn write_page(path: &std::path::Path, page: &SealedPage) -> Result<()> {
    let k = &page.k;
    let vt = &page.vt;
    let mut buf = Vec::with_capacity(32 + page.packed_bytes());
    for n in [
        k.rows, k.cols, k.codes.len(), k.scales.len(),
        vt.rows, vt.cols, vt.codes.len(), vt.scales.len(),
    ] {
        buf.extend_from_slice(&(n as u32).to_le_bytes());
    }
    buf.extend_from_slice(&k.codes);
    buf.extend_from_slice(&k.scales);
    buf.extend_from_slice(&vt.codes);
    buf.extend_from_slice(&vt.scales);
    std::fs::write(path, buf)?;
    Ok(())
}

fn read_page(path: &std::path::Path) -> Result<SealedPage> {
    let buf = std::fs::read(path).with_context(|| format!("reloading {}", path.display()))?;
    if buf.len() < 32 {
        bail!("spill file {} truncated ({} bytes)", path.display(), buf.len());
    }
    let mut dims = [0usize; 8];
    for (i, d) in dims.iter_mut().enumerate() {
        *d = u32::from_le_bytes(buf[i * 4..i * 4 + 4].try_into().unwrap()) as usize;
    }
    let [kr, kc, kcl, ksl, vr, vc, vcl, vsl] = dims;
    if buf.len() != 32 + kcl + ksl + vcl + vsl {
        bail!("spill file {} has inconsistent lengths", path.display());
    }
    let mut off = 32usize;
    let mut take = |n: usize| {
        let s = buf[off..off + n].to_vec();
        off += n;
        s
    };
    let k = PackedNvfp4 { rows: kr, cols: kc, codes: take(kcl), scales: take(ksl) };
    let vt = PackedNvfp4 { rows: vr, cols: vc, codes: take(vcl), scales: take(vsl) };
    Ok(SealedPage { k, vt })
}
