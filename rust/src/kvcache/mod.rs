//! Paged NVFP4 KV cache with a shared sealed-page pool (the paper's §5
//! future-work item, grown into the serving tier's memory manager).
//!
//! Layout: a page holds [`PAGE_SIZE`] = 16 tokens for one (layer, seq,
//! head) — deliberately equal to the NVFP4 block size so that
//! - **K** rows quantize along the head dimension (one row = one token,
//!   d/16 blocks), and
//! - **V** quantizes along the token axis (16-token blocks == the page),
//! exactly matching the contraction-axis layout the FP4 attention engine
//! needs — a full page converts to packed form with zero re-blocking.
//!
//! ## Page lifecycle: hot → sealed → pooled → shared
//!
//! * A page is kept in f32 while it fills (**hot**) and is **sealed**
//!   (packed to 4-bit) when the 16th token lands. Sealed pages cost 4.5
//!   bits/element vs 32 for f32 — the ~7× KV-memory reduction the paper
//!   projects for low-precision decoding.
//! * Sealed pages are **immutable** and live in a refcounted,
//!   content-addressed [`pool::PagePool`]; the page list stores only
//!   [`pool::PageRef`] handles. Quantization is deterministic, so
//!   byte-identical token prefixes produce byte-identical sealed pages,
//!   and the pool deduplicates them on insert with **zero numeric
//!   effect** — the attend walk reads the exact same packed bytes either
//!   way.
//! * **Copy-on-write** is attach/detach of refs, never a byte copy: a
//!   sequence admitted against a shared prompt prefix attaches the
//!   matching sealed run ([`PagedKvCache::attach_prefix_at`]), and its
//!   first divergent token simply opens a private hot page after the
//!   shared run. Dropping the sequence releases its refs; a page is
//!   freed when the last holder lets go.
//! * Cold sealed pages can **spill to disk** behind the pool seam
//!   ([`PagedKvCache::spill_to_budget`], LRU by last touch) and reload
//!   transparently on the next attend.
//!
//! [`PagedKvCache::memory_stats`] counts a shared page's bytes **once**
//! no matter how many sequences hold it; [`PagedKvCache::memory_json`]
//! additionally breaks occupancy into hot/sealed/shared/spilled page
//! counts for dashboards.
//!
//! Reads: [`PagedKvCache::attend_decode`] (fused single-query decode) and
//! [`PagedKvCache::attend_prefill`] (batched multi-query causal prefill)
//! are the paged backends of `attention::AttnEngine`; [`PagedKvCache::gather`]
//! materialises f32 copies for the baseline path.
//!
//! Addressing: sequences live in **Vec-indexed slots**. [`PagedKvCache::add_seq`]
//! returns a [`SeqSlot`] handle, and the `*_at` variants of every operation
//! index the slot table directly — zero map lookups on the per-token serve
//! path (the old `BTreeMap<u64, …>` survives only as an id → slot directory
//! for admission/teardown and the u64-keyed convenience wrappers). Freed
//! slots go on a free list and their page lists are reused by later
//! sequences, so a serving worker's slot table stays as small as its peak
//! concurrency no matter how many sequences churn through it; generation
//! counters make a stale handle a hard error instead of silent cross-talk.

pub mod pool;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::attention::packed::QuantQueryCache;
use crate::formats::e4m3;
use crate::formats::lut;
use crate::formats::tensor4::PackedNvfp4;
use crate::json::Json;

pub use pool::{PagePool, PageRef, PoolStats, SealedPage, SpillConfig};

/// Tokens per page == NVFP4 block size.
pub const PAGE_SIZE: usize = 16;

/// One (layer, seq, head) page.
enum Page {
    /// Filling: f32 staging, `len` tokens of K and V ((len × d) each).
    Hot { k: Vec<f32>, v: Vec<f32>, len: usize },
    /// Sealed: a refcounted handle into the shared page pool (K packed
    /// 16 × d, blocks along d; V packed transposed d × 16, blocks along
    /// the token axis).
    Sealed(PageRef),
}

/// Per-(layer, head) list of pages for one sequence.
struct HeadCache {
    pages: Vec<Page>,
    len: usize,
}

/// Handle to a live sequence's slot in the cache: a plain Vec index, so
/// the per-token hot path does no map lookup at all. The generation
/// counter pins the handle to one occupancy — after [`PagedKvCache::drop_slot`]
/// the slot may be reused by another sequence, and the stale handle then
/// errors instead of reading someone else's pages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqSlot {
    idx: u32,
    gen: u32,
}

impl SeqSlot {
    /// The raw slot index (stable while the sequence is live) — handy as a
    /// dense per-sequence array key in serving workers.
    pub fn index(&self) -> usize {
        self.idx as usize
    }
}

/// One slot of the cache's sequence table.
struct SlotEntry {
    id: u64,
    gen: u32,
    live: bool,
    /// Layer-major `[layer * heads + head]` page lists. The outer Vecs are
    /// retained across sequence reuse (the slot's page list arena).
    heads: Vec<HeadCache>,
}

/// Resolve a slot handle against the table (free function so callers can
/// hold the entry borrow while mutating the disjoint `pool` field).
fn slot_entry(slots: &[SlotEntry], slot: SeqSlot) -> Result<&SlotEntry> {
    let e = slots
        .get(slot.idx as usize)
        .ok_or_else(|| anyhow!("slot {} out of range", slot.idx))?;
    if !e.live || e.gen != slot.gen {
        bail!("stale slot handle {} (sequence dropped)", slot.idx);
    }
    Ok(e)
}

fn slot_entry_mut(slots: &mut [SlotEntry], slot: SeqSlot) -> Result<&mut SlotEntry> {
    let e = slots
        .get_mut(slot.idx as usize)
        .ok_or_else(|| anyhow!("slot {} out of range", slot.idx))?;
    if !e.live || e.gen != slot.gen {
        bail!("stale slot handle {} (sequence dropped)", slot.idx);
    }
    Ok(e)
}

/// Reusable workspace for [`PagedKvCache::attend_decode`].
///
/// Holds the quantized query, one page worth of scores/probabilities, the
/// packed P̃ block, and the output accumulator. Buffers retain capacity
/// across calls, so the steady-state decode loop never allocates.
pub struct DecodeScratch {
    /// Quantized-query memo (1 × head_dim, blocks along d): repeated calls
    /// with an identical query — repeated heads sharing one query vector,
    /// re-scoring an unchanged query — skip the encode pass entirely.
    qcache: QuantQueryCache,
    /// Scores for one page's tokens.
    s: [f32; PAGE_SIZE],
    /// exp(S − m) for one sealed page.
    p: [f32; PAGE_SIZE],
    /// Packed E2M1 codes of the quantized P̃ page block (8 bytes).
    p_codes: Vec<u8>,
    /// E4M3 scale byte of the quantized P̃ page block.
    p_scales: Vec<u8>,
    /// Unnormalised output accumulator (head_dim).
    acc: Vec<f32>,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        DecodeScratch {
            qcache: QuantQueryCache::new(),
            s: [0.0; PAGE_SIZE],
            p: [0.0; PAGE_SIZE],
            p_codes: Vec::new(),
            p_scales: Vec::new(),
            acc: Vec::new(),
        }
    }

    /// (hits, misses) of the quantized-query memo.
    pub fn query_cache_stats(&self) -> (u64, u64) {
        (self.qcache.hits, self.qcache.misses)
    }
}

impl Default for DecodeScratch {
    fn default() -> DecodeScratch {
        DecodeScratch::new()
    }
}

/// Paged FP4 KV cache over `layers × heads`, multi-sequence, backed by a
/// shared sealed-page pool (see module docs for the lifecycle).
pub struct PagedKvCache {
    layers: usize,
    heads: usize,
    head_dim: usize,
    /// Vec-indexed sequence table; freed entries are recycled via `free`.
    slots: Vec<SlotEntry>,
    free: Vec<u32>,
    /// seq_id → slot index. Admission/teardown and the u64-keyed wrappers
    /// only — never consulted by the `*_at` hot path.
    ids: BTreeMap<u64, u32>,
    /// Refcounted owner of every sealed page.
    pool: PagePool,
}

impl PagedKvCache {
    pub fn new(layers: usize, heads: usize, head_dim: usize) -> PagedKvCache {
        assert_eq!(head_dim % 16, 0, "head_dim must be a multiple of 16");
        PagedKvCache {
            layers,
            heads,
            head_dim,
            slots: Vec::new(),
            free: Vec::new(),
            ids: BTreeMap::new(),
            pool: PagePool::new(),
        }
    }

    /// Per-head K/V vector width (the engine derives head counts from it).
    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Attention heads per layer.
    pub fn heads(&self) -> usize {
        self.heads
    }

    /// Transformer layers this cache spans.
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// The sealed-page pool (occupancy queries, per-page byte lookups).
    pub fn pool(&self) -> &PagePool {
        &self.pool
    }

    /// Mutable pool access (prefix-index ref management).
    pub fn pool_mut(&mut self) -> &mut PagePool {
        &mut self.pool
    }

    /// Toggle content-addressed dedup of sealed pages (on by default).
    pub fn set_dedup(&mut self, on: bool) {
        self.pool.set_dedup(on);
    }

    /// Configure disk spill for cold sealed pages (see [`SpillConfig`]).
    pub fn set_spill(&mut self, cfg: Option<SpillConfig>) {
        self.pool.set_spill(cfg);
    }

    /// Spill least-recently-touched sealed pages until the resident byte
    /// budget is met; returns pages written. No-op without a spill config.
    pub fn spill_to_budget(&mut self) -> Result<usize> {
        self.pool.spill_to_budget()
    }

    /// Admit `seq`, returning its slot handle. Re-admitting a live id
    /// returns the existing slot (the old `or_insert` semantics). Freed
    /// slots are reused before the table grows, so the table stays sized
    /// to peak concurrency under sequence churn.
    pub fn add_seq(&mut self, seq: u64) -> SeqSlot {
        if let Some(&idx) = self.ids.get(&seq) {
            return SeqSlot { idx, gen: self.slots[idx as usize].gen };
        }
        let n = self.layers * self.heads;
        let idx = match self.free.pop() {
            Some(idx) => {
                let e = &mut self.slots[idx as usize];
                e.id = seq;
                e.live = true;
                idx
            }
            None => {
                let idx = self.slots.len() as u32;
                self.slots.push(SlotEntry {
                    id: seq,
                    gen: 0,
                    live: true,
                    heads: (0..n).map(|_| HeadCache { pages: Vec::new(), len: 0 }).collect(),
                });
                idx
            }
        };
        self.ids.insert(seq, idx);
        SeqSlot { idx, gen: self.slots[idx as usize].gen }
    }

    /// Resolve a live sequence id to its slot handle (one map lookup —
    /// hoist this out of per-token loops).
    pub fn slot(&self, seq: u64) -> Result<SeqSlot> {
        let idx = *self.ids.get(&seq).ok_or_else(|| anyhow!("unknown seq {seq}"))?;
        Ok(SeqSlot { idx, gen: self.slots[idx as usize].gen })
    }

    /// Free a sequence by slot handle: hot pages are dropped and every
    /// sealed ref is released back to the pool immediately (so
    /// [`PagedKvCache::memory_stats`] drops with it — a page survives only
    /// while some other holder still refs it), the slot joins the free
    /// list, and the handle's generation is retired.
    pub fn drop_slot(&mut self, slot: SeqSlot) -> Result<()> {
        let e = slot_entry_mut(&mut self.slots, slot)?;
        let id = e.id;
        e.live = false;
        e.gen = e.gen.wrapping_add(1);
        for hc in e.heads.iter_mut() {
            for page in hc.pages.drain(..) {
                if let Page::Sealed(r) = page {
                    self.pool.release(r);
                }
            }
            hc.len = 0;
        }
        self.ids.remove(&id);
        self.free.push(slot.idx);
        Ok(())
    }

    /// Free a sequence by id. An unknown id is a hard error, matching
    /// [`PagedKvCache::drop_slot`] — a caller double-dropping (or dropping
    /// a sequence it never admitted) is a leak bug that must not hide.
    pub fn drop_seq(&mut self, seq: u64) -> Result<()> {
        let slot = self.slot(seq)?;
        self.drop_slot(slot)
    }

    /// Number of live sequences.
    pub fn live_seqs(&self) -> usize {
        self.ids.len()
    }

    /// Size of the slot table (live + reusable freed slots) — bounded by
    /// the peak live-sequence count, not by total sequences ever admitted.
    pub fn slot_capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn seq_len(&self, seq: u64) -> usize {
        self.slot(seq).and_then(|s| self.seq_len_at(s)).unwrap_or(0)
    }

    /// Cached token count of a live slot.
    pub fn seq_len_at(&self, slot: SeqSlot) -> Result<usize> {
        Ok(slot_entry(&self.slots, slot)?.heads[0].len)
    }

    /// Attach a run of already-sealed prefix pages to an **empty** slot
    /// (copy-on-write admission). `runs[p]` holds page `p`'s refs in
    /// layer-major `[layer * heads + head]` order; the cache takes one
    /// ref per attached page and the sequence's length advances by
    /// [`PAGE_SIZE`] per run entry. The next appended token opens a
    /// private hot page after the shared run — no bytes are copied.
    pub fn attach_prefix_at(&mut self, slot: SeqSlot, runs: &[Vec<PageRef>]) -> Result<()> {
        let n = self.layers * self.heads;
        let e = slot_entry_mut(&mut self.slots, slot)?;
        if e.heads.iter().any(|hc| hc.len != 0) {
            bail!("attach_prefix_at requires an empty sequence");
        }
        for run in runs {
            if run.len() != n {
                bail!("prefix run must cover {n} (layer, head) pages, got {}", run.len());
            }
            for (hidx, &r) in run.iter().enumerate() {
                self.pool.retain(r);
                let hc = &mut e.heads[hidx];
                hc.pages.push(Page::Sealed(r));
                hc.len += PAGE_SIZE;
            }
        }
        Ok(())
    }

    /// Collect the first `n_pages` sealed pages of a slot as layer-major
    /// runs (the shape [`PagedKvCache::attach_prefix_at`] consumes, and
    /// what a prefix index registers). Errors if any of those pages is
    /// still hot.
    pub fn sealed_prefix_refs_at(&self, slot: SeqSlot, n_pages: usize) -> Result<Vec<Vec<PageRef>>> {
        let e = slot_entry(&self.slots, slot)?;
        let n = self.layers * self.heads;
        let mut runs = Vec::with_capacity(n_pages);
        for p in 0..n_pages {
            let mut run = Vec::with_capacity(n);
            for (hidx, hc) in e.heads.iter().enumerate() {
                match hc.pages.get(p) {
                    Some(Page::Sealed(r)) => run.push(*r),
                    Some(Page::Hot { .. }) => bail!("page {p} of head {hidx} is not sealed yet"),
                    None => bail!("slot has no page {p} for head {hidx}"),
                }
            }
            runs.push(run);
        }
        Ok(runs)
    }

    /// Append one token's K and V vectors (`d` floats each).
    pub fn append(
        &mut self,
        seq: u64,
        layer: usize,
        head: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        let slot = self.slot(seq)?;
        self.append_at(slot, layer, head, k, v)
    }

    /// [`PagedKvCache::append`] by slot handle — no map lookup.
    pub fn append_at(
        &mut self,
        slot: SeqSlot,
        layer: usize,
        head: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        let d = self.head_dim;
        if k.len() != d || v.len() != d {
            bail!("k/v must be head_dim={d} long");
        }
        let idx = layer * self.heads + head;
        let hc = slot_entry_mut(&mut self.slots, slot)?
            .heads
            .get_mut(idx)
            .ok_or_else(|| anyhow!("bad layer/head {layer}/{head}"))?;
        let needs_new = match hc.pages.last() {
            Some(Page::Hot { len, .. }) => *len >= PAGE_SIZE,
            _ => true,
        };
        if needs_new {
            hc.pages.push(Page::Hot {
                k: Vec::with_capacity(PAGE_SIZE * d),
                v: Vec::with_capacity(PAGE_SIZE * d),
                len: 0,
            });
        }
        let mut sealed = None;
        if let Some(Page::Hot { k: pk, v: pv, len }) = hc.pages.last_mut() {
            pk.extend_from_slice(k);
            pv.extend_from_slice(v);
            *len += 1;
            if *len == PAGE_SIZE {
                // Seal: pack K along d, V along the token axis (transpose).
                let kq = PackedNvfp4::quantize(pk, PAGE_SIZE, d)?;
                let mut vt = vec![0.0f32; d * PAGE_SIZE];
                for t in 0..PAGE_SIZE {
                    for c in 0..d {
                        vt[c * PAGE_SIZE + t] = pv[t * d + c];
                    }
                }
                let vq = PackedNvfp4::quantize(&vt, d, PAGE_SIZE)?;
                sealed = Some(SealedPage { k: kq, vt: vq });
            }
        }
        if let Some(page) = sealed {
            // The pool owns the sealed bytes; with dedup on, a
            // byte-identical page already sealed by another sequence is
            // shared instead of stored twice.
            let r = self.pool.insert(page);
            *hc.pages.last_mut().unwrap() = Page::Sealed(r);
        }
        hc.len += 1;
        Ok(())
    }

    /// Gather the full K and V (each `len × d`, f32) for attention.
    ///
    /// Sealed pages dequantize from 4-bit pooled storage (the FP4 read
    /// path); the hot tail copies straight through.
    pub fn gather(&self, seq: u64, layer: usize, head: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        self.gather_at(self.slot(seq)?, layer, head)
    }

    /// [`PagedKvCache::gather`] by slot handle.
    pub fn gather_at(
        &self,
        slot: SeqSlot,
        layer: usize,
        head: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = self.head_dim;
        let idx = layer * self.heads + head;
        let hc = slot_entry(&self.slots, slot)?
            .heads
            .get(idx)
            .ok_or_else(|| anyhow!("bad layer/head"))?;
        let mut k = Vec::with_capacity(hc.len * d);
        let mut v = Vec::with_capacity(hc.len * d);
        for page in &hc.pages {
            match page {
                Page::Hot { k: pk, v: pv, .. } => {
                    k.extend_from_slice(pk);
                    v.extend_from_slice(pv);
                }
                Page::Sealed(r) => {
                    let page = self.pool.page(*r)?;
                    k.extend(page.k.dequantize());
                    let vtd = page.vt.dequantize(); // (d × 16)
                    let base = v.len();
                    v.resize(base + PAGE_SIZE * d, 0.0);
                    for c in 0..d {
                        for t in 0..PAGE_SIZE {
                            v[base + t * d + c] = vtd[c * PAGE_SIZE + t];
                        }
                    }
                }
            }
        }
        Ok((k, v))
    }

    /// Fused single-query decode attention over the paged FP4 cache.
    ///
    /// Streams pages with flash-style online-softmax rescaling instead of
    /// materialising K/V: sealed pages are consumed **in the packed
    /// domain** — QKᵀ via the byte-pair LUT against the page's packed K,
    /// P̃·V via the LUT against packed Vᵀ (the page is exactly one NVFP4
    /// block along the token axis, so only the page's `d` scale bytes and
    /// `d × 8` code bytes are touched) — while the hot (still-filling)
    /// tail falls back to plain f32. The query is quantized once per call
    /// for the packed dots; P̃ is quantized per page, matching the
    /// engine-side Alg. 1 semantics. Shared (pooled) pages walk the exact
    /// same packed bytes a private copy would, so sharing never changes a
    /// decode result.
    ///
    /// Replaces the `gather` + `attend_f32` decode pair: no O(seq_len·d)
    /// dequant + copy per token, and — with a reused [`DecodeScratch`] —
    /// no heap allocation in steady state.
    ///
    /// Writes the attention output into `out` (`head_dim` floats) and
    /// returns the logsumexp.
    pub fn attend_decode(
        &self,
        seq: u64,
        layer: usize,
        head: usize,
        q: &[f32],
        out: &mut [f32],
        scratch: &mut DecodeScratch,
    ) -> Result<f32> {
        self.attend_decode_at(self.slot(seq)?, layer, head, q, out, scratch)
    }

    /// [`PagedKvCache::attend_decode`] by slot handle — the serving
    /// hot path: Vec index, no map walk per token.
    pub fn attend_decode_at(
        &self,
        slot: SeqSlot,
        layer: usize,
        head: usize,
        q: &[f32],
        out: &mut [f32],
        scratch: &mut DecodeScratch,
    ) -> Result<f32> {
        let d = self.head_dim;
        if q.len() != d || out.len() != d {
            bail!("q/out must be head_dim={d} long");
        }
        let idx = layer * self.heads + head;
        let hc = slot_entry(&self.slots, slot)?
            .heads
            .get(idx)
            .ok_or_else(|| anyhow!("bad layer/head {layer}/{head}"))?;
        if hc.len == 0 {
            bail!("slot {} has no cached tokens", slot.idx);
        }
        attend_query_walk(hc, &self.pool, d, q, hc.len, out, scratch)
    }

    /// Batched multi-query prefill attention over the paged FP4 cache —
    /// the engine-side backend of `AttnEngine::prefill`.
    ///
    /// The `nq` query rows in `q` (`nq × head_dim`) belong to the **last
    /// `nq` cached tokens** (append the prompt first, then attend), with
    /// aligned-ends causality: query `i` sees keys `0 ..= len − nq + i`.
    /// One call walks the page list once per query with the same online
    /// softmax as [`PagedKvCache::attend_decode`] — sealed pages consumed
    /// in the packed domain, hot tail in f32 — so the per-token sequence
    /// lookup, query-cache probe, and accumulator setup of token-at-a-time
    /// decode amortise across the whole prompt. The final partial page of
    /// a query's causal window masks by zeroing P̃ beyond the limit before
    /// quantization, matching the engine-side padding semantics.
    ///
    /// Under prefix sharing the suffix queries attend attached shared
    /// pages exactly as if the slot had appended them itself — the walk
    /// only sees packed bytes behind `PageRef`s.
    ///
    /// Writes outputs into `out` (`nq × head_dim`) and per-row logsumexps
    /// into `lse` (`nq`). For a query whose window covers the whole cache
    /// the result is bitwise identical to [`PagedKvCache::attend_decode`].
    #[allow(clippy::too_many_arguments)]
    pub fn attend_prefill(
        &self,
        seq: u64,
        layer: usize,
        head: usize,
        q: &[f32],
        nq: usize,
        out: &mut [f32],
        lse: &mut [f32],
        scratch: &mut DecodeScratch,
    ) -> Result<()> {
        self.attend_prefill_at(self.slot(seq)?, layer, head, q, nq, out, lse, scratch)
    }

    /// [`PagedKvCache::attend_prefill`] by slot handle.
    #[allow(clippy::too_many_arguments)]
    pub fn attend_prefill_at(
        &self,
        slot: SeqSlot,
        layer: usize,
        head: usize,
        q: &[f32],
        nq: usize,
        out: &mut [f32],
        lse: &mut [f32],
        scratch: &mut DecodeScratch,
    ) -> Result<()> {
        let d = self.head_dim;
        if q.len() != nq * d || out.len() != nq * d || lse.len() != nq {
            bail!("q/out must be nq={nq} x head_dim={d}, lse nq={nq} long");
        }
        let idx = layer * self.heads + head;
        let hc = slot_entry(&self.slots, slot)?
            .heads
            .get(idx)
            .ok_or_else(|| anyhow!("bad layer/head {layer}/{head}"))?;
        let len = hc.len;
        if nq == 0 || nq > len {
            bail!("prefill needs 1..=len queries (nq={nq}, cached len={len})");
        }
        for i in 0..nq {
            // Aligned-ends causal window: this query's token position is
            // len - nq + i, so it attends limit = position + 1 keys.
            let limit = len - nq + i + 1;
            lse[i] = attend_query_walk(
                hc,
                &self.pool,
                d,
                &q[i * d..(i + 1) * d],
                limit,
                &mut out[i * d..(i + 1) * d],
                scratch,
            )?;
        }
        Ok(())
    }

    /// (bytes used, bytes an f32 cache would use) across all **live**
    /// sequences — freed slots release their refs in
    /// [`PagedKvCache::drop_slot`], so a drained cache reports (0, 0)
    /// no matter how many sequences churned through it. Sealed bytes come
    /// from the pool, so a page shared by N sequences is counted **once**;
    /// the f32-equivalent side counts every sequence's logical tokens (the
    /// memory an unshared f32 cache would need), which is exactly the
    /// sharing + quantization multiplier.
    pub fn memory_stats(&self) -> (usize, usize) {
        let d = self.head_dim;
        let mut used = self.pool.total_bytes();
        let mut f32_equiv = 0usize;
        for heads in self.slots.iter().filter(|s| s.live).map(|s| &s.heads) {
            for hc in heads {
                f32_equiv += hc.len * d * 4 * 2; // K and V
                for page in &hc.pages {
                    if let Page::Hot { k, v, .. } = page {
                        used += (k.len() + v.len()) * 4;
                    }
                }
            }
        }
        (used, f32_equiv)
    }

    /// Occupancy as one JSON object for the telemetry snapshot: live
    /// sequence count, packed bytes in use (shared pages once), the
    /// f32-equivalent bytes the same tokens would occupy (their ratio is
    /// the paper's ~7× KV-memory reduction, amplified by sharing), and
    /// per-kind page counts so dashboards can graph pool composition.
    pub fn memory_json(&self) -> Json {
        let (used, f32_equiv) = self.memory_stats();
        let mut hot = 0usize;
        for heads in self.slots.iter().filter(|s| s.live).map(|s| &s.heads) {
            for hc in heads {
                hot += hc.pages.iter().filter(|p| matches!(p, Page::Hot { .. })).count();
            }
        }
        Json::obj(vec![
            ("live_seqs", Json::Num(self.live_seqs() as f64)),
            ("kv_bytes", Json::Num(used as f64)),
            ("kv_bytes_f32_equiv", Json::Num(f32_equiv as f64)),
            (
                "pages",
                Json::obj(vec![
                    ("hot", Json::Num(hot as f64)),
                    ("sealed", Json::Num(self.pool.live_pages() as f64)),
                    ("shared", Json::Num(self.pool.shared_pages() as f64)),
                    ("spilled", Json::Num(self.pool.spilled_pages() as f64)),
                ]),
            ),
        ])
    }
}

/// Shared per-query online-softmax page walk behind
/// [`PagedKvCache::attend_decode`] and [`PagedKvCache::attend_prefill`]:
/// attends keys `0..limit` of one (seq, layer, head) page list — sealed
/// pages resolved through the pool and consumed in the packed domain
/// (query quantized once through the scratch's N-way memo, P̃ quantized
/// per page), the hot tail in f32 — writing the output row into `out`
/// and returning the logsumexp.
///
/// A `limit` ending inside a sealed page masks causally by zeroing P̃
/// beyond the window before quantizing the block, matching the
/// engine-side padding semantics; with `limit == hc.len` every page is
/// full and the walk is exactly the single-query decode. The only
/// fallible step is the pool lookup (stale ref / unreadable spill file).
fn attend_query_walk(
    hc: &HeadCache,
    pool: &PagePool,
    d: usize,
    q: &[f32],
    limit: usize,
    out: &mut [f32],
    scratch: &mut DecodeScratch,
) -> Result<f32> {
    let lut = lut::pair_dot();
    let scale = 1.0 / (d as f32).sqrt();
    // Quantize the query once (blocks along d, the QKᵀ contraction) —
    // every sealed-page dot below runs purely on packed bytes. The memo
    // makes repeated identical queries (shared across heads, or
    // re-scored) skip even that single encode pass.
    let q4 = scratch.qcache.get_or_quantize(q);
    scratch.acc.clear();
    scratch.acc.resize(d, 0.0);
    let mut m = f32::NEG_INFINITY;
    let mut l = 0.0f32;
    let mut pos = 0usize; // tokens before the current page
    for page in &hc.pages {
        if pos >= limit {
            break;
        }
        match page {
            Page::Sealed(r) => {
                let sealed = pool.page(*r)?;
                let (k, vt) = (&sealed.k, &sealed.vt);
                let n_in = PAGE_SIZE.min(limit - pos);
                let mut page_m = f32::NEG_INFINITY;
                for t in 0..n_in {
                    let s = lut::packed_row_dot(lut, q4, 0, k, t) * scale;
                    scratch.s[t] = s;
                    page_m = page_m.max(s);
                }
                let new_m = m.max(page_m);
                let alpha = (m - new_m).exp(); // 0 on the first page
                l *= alpha;
                for a in scratch.acc.iter_mut() {
                    *a *= alpha;
                }
                for t in 0..n_in {
                    let p = (scratch.s[t] - new_m).exp();
                    scratch.p[t] = p;
                    l += p;
                }
                // Causal mask inside the page: zero P̃ beyond the window
                // before quantizing the block (no-op for a full page).
                for p in scratch.p[n_in..].iter_mut() {
                    *p = 0.0;
                }
                m = new_m;
                // P̃ for this page is exactly one NVFP4 block along the
                // token axis: quantize it and dot against packed Vᵀ.
                lut::quantize_row_into(&scratch.p, &mut scratch.p_codes, &mut scratch.p_scales);
                let sp = e4m3::decode(scratch.p_scales[0]);
                for (c, a) in scratch.acc.iter_mut().enumerate() {
                    let sv = e4m3::decode(vt.scales[c]);
                    let base = c * lut::BLOCK_BYTES;
                    let dot = lut::bytes_dot(
                        lut,
                        &scratch.p_codes,
                        &vt.codes[base..base + lut::BLOCK_BYTES],
                    );
                    *a += dot * (sp * sv);
                }
                pos += PAGE_SIZE;
            }
            Page::Hot { k, v, len: hot_len } => {
                // f32 fallback for the still-filling tail.
                let n = (*hot_len).min(limit - pos);
                let mut page_m = f32::NEG_INFINITY;
                for t in 0..n {
                    let kt = &k[t * d..(t + 1) * d];
                    let mut acc = 0.0f32;
                    for c in 0..d {
                        acc += q[c] * kt[c];
                    }
                    let s = acc * scale;
                    scratch.s[t] = s;
                    page_m = page_m.max(s);
                }
                let new_m = m.max(page_m);
                let alpha = (m - new_m).exp();
                l *= alpha;
                for a in scratch.acc.iter_mut() {
                    *a *= alpha;
                }
                for t in 0..n {
                    let p = (scratch.s[t] - new_m).exp();
                    l += p;
                    let vt_row = &v[t * d..(t + 1) * d];
                    for (c, a) in scratch.acc.iter_mut().enumerate() {
                        *a += p * vt_row[c];
                    }
                }
                m = new_m;
                pos += *hot_len;
            }
        }
    }
    let inv = 1.0 / l;
    for (oc, a) in out.iter_mut().zip(&scratch.acc) {
        *oc = a * inv;
    }
    Ok(m + l.ln())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn fill(cache: &mut PagedKvCache, seq: u64, tokens: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut ks = Vec::new();
        cache.add_seq(seq);
        for _ in 0..tokens {
            let k = rng.normal_vec(d, 0.0, 1.0);
            let v = rng.normal_vec(d, 0.0, 1.0);
            cache.append(seq, 0, 0, &k, &v).unwrap();
            ks.extend(k);
        }
        ks
    }

    #[test]
    fn gather_returns_all_tokens() {
        let d = 32;
        let mut c = PagedKvCache::new(1, 1, d);
        fill(&mut c, 7, 37, d, 1); // crosses two sealed pages + hot tail
        let (k, v) = c.gather(7, 0, 0).unwrap();
        assert_eq!(k.len(), 37 * d);
        assert_eq!(v.len(), 37 * d);
        assert_eq!(c.seq_len(7), 37);
    }

    #[test]
    fn sealed_pages_quantize_hot_tail_exact() {
        let d = 16;
        let mut c = PagedKvCache::new(1, 1, d);
        let ks = fill(&mut c, 1, 20, d, 2);
        let (k, _) = c.gather(1, 0, 0).unwrap();
        // Tokens 16..20 are in the hot page: bit-exact.
        assert_eq!(&k[16 * d..], &ks[16 * d..]);
        // Tokens 0..16 went through FP4: close but generally not equal.
        let diff: f32 = k[..16 * d]
            .iter()
            .zip(&ks[..16 * d])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff > 0.0 && diff < 1.0, "diff {diff}");
    }

    #[test]
    fn memory_reduction_when_sealed() {
        let d = 64;
        let mut c = PagedKvCache::new(2, 2, d);
        c.add_seq(1);
        let mut rng = Rng::new(3);
        for _ in 0..64 {
            for l in 0..2 {
                for h in 0..2 {
                    let k = rng.normal_vec(d, 0.0, 1.0);
                    let v = rng.normal_vec(d, 0.0, 1.0);
                    c.append(1, l, h, &k, &v).unwrap();
                }
            }
        }
        let (used, f32_eq) = c.memory_stats();
        let ratio = f32_eq as f32 / used as f32;
        assert!(ratio > 6.5, "compression ratio {ratio}");
    }

    #[test]
    fn v_roundtrip_through_transpose() {
        let d = 16;
        let mut c = PagedKvCache::new(1, 1, d);
        c.add_seq(1);
        let mut rng = Rng::new(4);
        let mut vs = Vec::new();
        for _ in 0..16 {
            let k = rng.normal_vec(d, 0.0, 1.0);
            let v = rng.normal_vec(d, 0.0, 1.0);
            c.append(1, 0, 0, &k, &v).unwrap();
            vs.extend(v);
        }
        let (_, v) = c.gather(1, 0, 0).unwrap();
        // Quantized along the token axis; same ordering as input.
        for i in 0..16 * d {
            assert!((v[i] - vs[i]).abs() < 1.5, "elem {i}");
        }
    }

    #[test]
    fn errors_on_unknown_seq() {
        let mut c = PagedKvCache::new(1, 1, 16);
        assert!(c.append(9, 0, 0, &[0.0; 16], &[0.0; 16]).is_err());
        assert!(c.gather(9, 0, 0).is_err());
        assert!(c.drop_seq(42).is_err(), "unknown drop_seq must be a hard error");
        let mut scratch = DecodeScratch::new();
        let mut out = vec![0.0; 16];
        assert!(c.attend_decode(9, 0, 0, &[0.0; 16], &mut out, &mut scratch).is_err());
        // Known seq but no tokens yet: also an error, not NaN output.
        c.add_seq(1);
        assert!(c.attend_decode(1, 0, 0, &[0.0; 16], &mut out, &mut scratch).is_err());
        // Double drop: first succeeds, second errors.
        assert!(c.drop_seq(1).is_ok());
        assert!(c.drop_seq(1).is_err());
    }

    #[test]
    fn attend_decode_single_hot_token_copies_value() {
        // One cached token => softmax weight 1 => output == v (hot page,
        // f32 path: bit-exact).
        let d = 16;
        let mut c = PagedKvCache::new(1, 1, d);
        c.add_seq(1);
        let mut rng = Rng::new(5);
        let k = rng.normal_vec(d, 0.0, 1.0);
        let v = rng.normal_vec(d, 0.0, 1.0);
        c.append(1, 0, 0, &k, &v).unwrap();
        let q = rng.normal_vec(d, 0.0, 1.0);
        let mut out = vec![0.0; d];
        let mut scratch = DecodeScratch::new();
        let lse = c.attend_decode(1, 0, 0, &q, &mut out, &mut scratch).unwrap();
        assert_eq!(out, v);
        assert!(lse.is_finite());
    }

    #[test]
    fn attend_decode_matches_gather_attend_f32() {
        // Fused paged decode vs the materialising baseline across
        // page-aligned and hot-tail lengths. The fused path additionally
        // quantizes the query and P̃ for sealed pages (the paper's
        // inference-kernel semantics), so agreement is to FP4 tolerance,
        // not bit-exact.
        use crate::attention::flash::attend_f32_core;
        let d = 64;
        for &(tokens, seed) in &[(16usize, 10u64), (17, 11), (37, 12), (512, 13)] {
            let mut c = PagedKvCache::new(1, 1, d);
            c.add_seq(1);
            let mut rng = Rng::new(seed);
            for _ in 0..tokens {
                let k = rng.normal_vec(d, 0.0, 1.0);
                let v = rng.normal_vec(d, 0.0, 1.0);
                c.append(1, 0, 0, &k, &v).unwrap();
            }
            let q = rng.normal_vec(d, 0.0, 1.0);
            let (kc, vc) = c.gather(1, 0, 0).unwrap();
            let base = attend_f32_core(&q, &kc, &vc, 1, tokens, d, false);
            let mut out = vec![0.0; d];
            let mut scratch = DecodeScratch::new();
            let lse = c.attend_decode(1, 0, 0, &q, &mut out, &mut scratch).unwrap();
            let max_diff = out
                .iter()
                .zip(&base.o)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            // Python-simulated diffs peak at ~0.21 (tokens=17, where the
            // quantized query meets few keys); 0.5 leaves 2x margin while
            // still catching any structural bug.
            assert!(max_diff < 0.5, "tokens={tokens}: max_diff {max_diff}");
            assert!((lse - base.lse[0]).abs() < 0.5, "tokens={tokens}: lse");
            assert!(out.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn attend_decode_shares_quantized_query_across_heads() {
        // Two heads fed the *same* query vector through one scratch: the
        // second attend quantizes nothing (cache hit) yet both heads score
        // their own K/V pages correctly.
        let d = 32;
        let mut c = PagedKvCache::new(1, 2, d);
        c.add_seq(1);
        let mut rng = Rng::new(16);
        for _ in 0..20 {
            for h in 0..2 {
                let k = rng.normal_vec(d, 0.0, 1.0);
                let v = rng.normal_vec(d, 0.0, 1.0);
                c.append(1, 0, h, &k, &v).unwrap();
            }
        }
        let q = rng.normal_vec(d, 0.0, 1.0);
        let mut scratch = DecodeScratch::new();
        let mut o0 = vec![0.0; d];
        let mut o1 = vec![0.0; d];
        c.attend_decode(1, 0, 0, &q, &mut o0, &mut scratch).unwrap();
        c.attend_decode(1, 0, 1, &q, &mut o1, &mut scratch).unwrap();
        assert_eq!(scratch.query_cache_stats(), (1, 1), "second head must hit");
        assert_ne!(o0, o1, "different heads still attend different pages");
        // And the shared-query result is identical to a fresh scratch.
        let mut fresh = DecodeScratch::new();
        let mut o1b = vec![0.0; d];
        c.attend_decode(1, 0, 1, &q, &mut o1b, &mut fresh).unwrap();
        assert_eq!(o1, o1b);
    }

    #[test]
    fn attend_prefill_matches_f32_reference_causally() {
        // Batched prefill vs gather + causal f32 attention (aligned ends):
        // FP4 tolerance, every query row finite, lse in agreement.
        use crate::attention::flash::attend_f32_core;
        let d = 64;
        for &(tokens, nq, seed) in &[(16usize, 4usize, 20u64), (37, 8, 21), (64, 16, 22)] {
            let mut c = PagedKvCache::new(1, 1, d);
            c.add_seq(1);
            let mut rng = Rng::new(seed);
            for _ in 0..tokens {
                let k = rng.normal_vec(d, 0.0, 1.0);
                let v = rng.normal_vec(d, 0.0, 1.0);
                c.append(1, 0, 0, &k, &v).unwrap();
            }
            let q = rng.normal_vec(nq * d, 0.0, 1.0);
            let (kc, vc) = c.gather(1, 0, 0).unwrap();
            let base = attend_f32_core(&q, &kc, &vc, nq, tokens, d, true);
            let mut out = vec![0.0f32; nq * d];
            let mut lse = vec![0.0f32; nq];
            let mut scratch = DecodeScratch::new();
            c.attend_prefill(1, 0, 0, &q, nq, &mut out, &mut lse, &mut scratch).unwrap();
            let max_diff = out
                .iter()
                .zip(&base.o)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 0.5, "tokens={tokens} nq={nq}: max_diff {max_diff}");
            for i in 0..nq {
                assert!((lse[i] - base.lse[i]).abs() < 0.5, "tokens={tokens} row {i}");
            }
            assert!(out.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn attend_prefill_full_window_matches_attend_decode_bitwise() {
        // The last prefill query sees the whole cache — identical float
        // sequence to the fused single-query decode, so bitwise equal.
        // Covers both a fully-sealed cache and one with a hot tail.
        let d = 32;
        for &(tokens, seed) in &[(32usize, 23u64), (37, 24)] {
            let mut c = PagedKvCache::new(1, 1, d);
            c.add_seq(1);
            let mut rng = Rng::new(seed);
            for _ in 0..tokens {
                let k = rng.normal_vec(d, 0.0, 1.0);
                let v = rng.normal_vec(d, 0.0, 1.0);
                c.append(1, 0, 0, &k, &v).unwrap();
            }
            let nq = 4;
            let q = rng.normal_vec(nq * d, 0.0, 1.0);
            let mut out = vec![0.0f32; nq * d];
            let mut lse = vec![0.0f32; nq];
            let mut scratch = DecodeScratch::new();
            c.attend_prefill(1, 0, 0, &q, nq, &mut out, &mut lse, &mut scratch).unwrap();
            let mut dec = vec![0.0f32; d];
            let mut fresh = DecodeScratch::new();
            let dec_lse = c
                .attend_decode(1, 0, 0, &q[(nq - 1) * d..], &mut dec, &mut fresh)
                .unwrap();
            assert_eq!(&out[(nq - 1) * d..], &dec[..], "tokens={tokens}");
            assert_eq!(lse[nq - 1], dec_lse, "tokens={tokens}");
        }
    }

    #[test]
    fn attend_prefill_rejects_bad_query_counts() {
        let d = 16;
        let mut c = PagedKvCache::new(1, 1, d);
        fill(&mut c, 1, 8, d, 25);
        let mut scratch = DecodeScratch::new();
        let q = vec![0.0f32; 16 * d];
        let mut out = vec![0.0f32; 16 * d];
        let mut lse = vec![0.0f32; 16];
        // More queries than cached tokens.
        assert!(c.attend_prefill(1, 0, 0, &q, 16, &mut out, &mut lse, &mut scratch).is_err());
        // Zero queries.
        assert!(c.attend_prefill(1, 0, 0, &[], 0, &mut [], &mut [], &mut scratch).is_err());
        // Unknown sequence.
        assert!(c
            .attend_prefill(9, 0, 0, &q[..8 * d], 8, &mut out[..8 * d], &mut lse[..8], &mut scratch)
            .is_err());
    }

    #[test]
    fn slot_handle_paths_match_id_paths_bitwise() {
        // The *_at hot path and the u64-keyed wrappers are the same code;
        // pin that a resolved handle produces identical floats.
        let d = 32;
        let mut c = PagedKvCache::new(2, 2, d);
        let slot = c.add_seq(9);
        let mut rng = Rng::new(30);
        for _ in 0..21 {
            for l in 0..2 {
                for h in 0..2 {
                    let k = rng.normal_vec(d, 0.0, 1.0);
                    let v = rng.normal_vec(d, 0.0, 1.0);
                    c.append_at(slot, l, h, &k, &v).unwrap();
                }
            }
        }
        assert_eq!(c.seq_len(9), 21);
        assert_eq!(c.seq_len_at(slot).unwrap(), 21);
        assert_eq!(c.slot(9).unwrap(), slot);
        let q = rng.normal_vec(d, 0.0, 1.0);
        let (mut a, mut b) = (vec![0.0; d], vec![0.0; d]);
        let mut s1 = DecodeScratch::new();
        let mut s2 = DecodeScratch::new();
        let la = c.attend_decode(9, 1, 1, &q, &mut a, &mut s1).unwrap();
        let lb = c.attend_decode_at(slot, 1, 1, &q, &mut b, &mut s2).unwrap();
        assert_eq!(a, b);
        assert_eq!(la, lb);
        let (k1, v1) = c.gather(9, 0, 1).unwrap();
        let (k2, v2) = c.gather_at(slot, 0, 1).unwrap();
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn churn_reuses_slots_and_memory_stats_drain_to_zero() {
        // Thousands of sequences through a bounded live set: the slot
        // table must stay at the peak concurrency (no slot leak), freed
        // pages must leave memory_stats immediately, and a drained cache
        // reports exactly (0, 0).
        let d = 16;
        let live_cap = 8usize;
        let mut c = PagedKvCache::new(1, 1, d);
        let mut rng = Rng::new(31);
        let mut live: Vec<u64> = Vec::new();
        for i in 0..2000u64 {
            if live.len() == live_cap {
                c.drop_seq(live.remove(0)).unwrap();
            }
            let slot = c.add_seq(i);
            // Cross a page boundary so sealed pages churn too.
            for _ in 0..(PAGE_SIZE + 3) {
                let k = rng.normal_vec(d, 0.0, 1.0);
                let v = rng.normal_vec(d, 0.0, 1.0);
                c.append_at(slot, 0, 0, &k, &v).unwrap();
            }
            live.push(i);
            assert!(c.slot_capacity() <= live_cap, "slot leak: {}", c.slot_capacity());
            assert_eq!(c.live_seqs(), live.len());
        }
        let (used, equiv) = c.memory_stats();
        // Only the live set is accounted.
        assert!(used > 0 && equiv == live.len() * (PAGE_SIZE + 3) * d * 4 * 2);
        for id in live.drain(..) {
            c.drop_seq(id).unwrap();
        }
        assert_eq!(c.memory_stats(), (0, 0));
        assert_eq!(c.live_seqs(), 0);
        assert!(c.slot_capacity() <= live_cap);
        // The pool drained with the sequences: no live pages left behind.
        assert_eq!(c.pool().live_pages(), 0);
    }

    #[test]
    fn dedup_shares_identical_sealed_pages() {
        // Two sequences appending byte-identical tokens seal byte-identical
        // pages; with dedup on (the default) the pool stores one copy and
        // memory_stats counts it once.
        let d = 16;
        let mut c = PagedKvCache::new(1, 1, d);
        let mut rng = Rng::new(40);
        let toks: Vec<(Vec<f32>, Vec<f32>)> = (0..PAGE_SIZE)
            .map(|_| (rng.normal_vec(d, 0.0, 1.0), rng.normal_vec(d, 0.0, 1.0)))
            .collect();
        for seq in [1u64, 2] {
            let slot = c.add_seq(seq);
            for (k, v) in &toks {
                c.append_at(slot, 0, 0, k, v).unwrap();
            }
        }
        assert_eq!(c.pool().live_pages(), 1, "identical pages must dedup");
        assert_eq!(c.pool().shared_pages(), 1);
        assert_eq!(c.pool().stats().dedup_hits, 1);
        let (used_shared, equiv) = c.memory_stats();
        assert_eq!(equiv, 2 * PAGE_SIZE * d * 4 * 2);
        // Unshared baseline: dedup off stores both copies.
        let mut u = PagedKvCache::new(1, 1, d);
        u.set_dedup(false);
        for seq in [1u64, 2] {
            let slot = u.add_seq(seq);
            for (k, v) in &toks {
                u.append_at(slot, 0, 0, k, v).unwrap();
            }
        }
        assert_eq!(u.pool().live_pages(), 2);
        assert_eq!(u.pool().shared_pages(), 0);
        let (used_unshared, _) = u.memory_stats();
        assert_eq!(used_unshared, 2 * used_shared, "shared bytes counted once");
        // Dropping one holder keeps the page; dropping both frees it.
        c.drop_seq(1).unwrap();
        assert_eq!(c.pool().live_pages(), 1);
        assert_eq!(c.pool().shared_pages(), 0);
        c.drop_seq(2).unwrap();
        assert_eq!(c.pool().live_pages(), 0);
    }

    #[test]
    fn attach_prefix_matches_appended_sequence_bitwise() {
        // Seq A appends 37 tokens. Seq B attaches A's two sealed prefix
        // pages (32 tokens) and appends the same tail — gather and attend
        // must be bitwise identical: the walk reads the same packed bytes.
        let d = 32;
        let mut c = PagedKvCache::new(2, 2, d);
        let a = c.add_seq(1);
        let mut rng = Rng::new(41);
        let toks: Vec<Vec<(Vec<f32>, Vec<f32>)>> = (0..37)
            .map(|_| {
                (0..4)
                    .map(|_| (rng.normal_vec(d, 0.0, 1.0), rng.normal_vec(d, 0.0, 1.0)))
                    .collect()
            })
            .collect();
        for tok in &toks {
            for l in 0..2 {
                for h in 0..2 {
                    let (k, v) = &tok[l * 2 + h];
                    c.append_at(a, l, h, k, v).unwrap();
                }
            }
        }
        let runs = c.sealed_prefix_refs_at(a, 2).unwrap();
        assert_eq!(runs.len(), 2);
        assert!(runs.iter().all(|r| r.len() == 4));
        let b = c.add_seq(2);
        c.attach_prefix_at(b, &runs).unwrap();
        assert_eq!(c.seq_len_at(b).unwrap(), 32);
        for tok in &toks[32..] {
            for l in 0..2 {
                for h in 0..2 {
                    let (k, v) = &tok[l * 2 + h];
                    c.append_at(b, l, h, k, v).unwrap();
                }
            }
        }
        assert_eq!(c.pool().shared_pages(), 2 * 4, "prefix pages shared across A and B");
        let q = rng.normal_vec(d, 0.0, 1.0);
        let (mut oa, mut ob) = (vec![0.0; d], vec![0.0; d]);
        let mut s1 = DecodeScratch::new();
        let mut s2 = DecodeScratch::new();
        let la = c.attend_decode_at(a, 1, 1, &q, &mut oa, &mut s1).unwrap();
        let lb = c.attend_decode_at(b, 1, 1, &q, &mut ob, &mut s2).unwrap();
        assert_eq!(oa, ob);
        assert_eq!(la, lb);
        let (k1, v1) = c.gather_at(a, 0, 1).unwrap();
        let (k2, v2) = c.gather_at(b, 0, 1).unwrap();
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
        // Attaching to a non-empty slot is rejected.
        assert!(c.attach_prefix_at(b, &runs).is_err());
        // Dropping A leaves B's attached pages fully readable.
        c.drop_slot(a).unwrap();
        assert!(c.gather_at(b, 0, 1).is_ok());
        c.drop_slot(b).unwrap();
        assert_eq!(c.pool().live_pages(), 0);
    }

    #[test]
    fn memory_json_reports_page_kinds() {
        let d = 16;
        let mut c = PagedKvCache::new(1, 1, d);
        fill(&mut c, 1, PAGE_SIZE + 3, d, 43);
        let doc = c.memory_json();
        assert_eq!(doc.get("live_seqs").as_f64(), Some(1.0));
        assert_eq!(doc.get("pages").get("hot").as_f64(), Some(1.0));
        assert_eq!(doc.get("pages").get("sealed").as_f64(), Some(1.0));
        assert_eq!(doc.get("pages").get("shared").as_f64(), Some(0.0));
        assert_eq!(doc.get("pages").get("spilled").as_f64(), Some(0.0));
    }

    #[test]
    fn stale_slot_handles_error_instead_of_cross_talking() {
        let d = 16;
        let mut c = PagedKvCache::new(1, 1, d);
        let slot = c.add_seq(1);
        c.append_at(slot, 0, 0, &[1.0; 16], &[2.0; 16]).unwrap();
        c.drop_slot(slot).unwrap();
        // The freed slot is re-admitted by another sequence...
        let slot2 = c.add_seq(2);
        assert_eq!(slot.index(), slot2.index(), "slot must be reused");
        // ...and every old-handle operation is a hard error, not a read
        // of the new tenant's pages.
        let mut out = vec![0.0; d];
        let mut scratch = DecodeScratch::new();
        assert!(c.append_at(slot, 0, 0, &[0.0; 16], &[0.0; 16]).is_err());
        assert!(c.gather_at(slot, 0, 0).is_err());
        assert!(c.attend_decode_at(slot, 0, 0, &[0.0; 16], &mut out, &mut scratch).is_err());
        assert!(c.seq_len_at(slot).is_err());
        assert!(c.drop_slot(slot).is_err());
        // Re-admitting a live id hands back the same slot.
        assert_eq!(c.add_seq(2), slot2);
    }

    #[test]
    fn attend_decode_scratch_reuse_is_stable() {
        // Same query twice through one scratch: identical answers.
        let d = 32;
        let mut c = PagedKvCache::new(1, 1, d);
        fill(&mut c, 3, 40, d, 14);
        let mut rng = Rng::new(15);
        let q = rng.normal_vec(d, 0.0, 1.0);
        let mut scratch = DecodeScratch::new();
        let mut a = vec![0.0; d];
        let mut b = vec![0.0; d];
        c.attend_decode(3, 0, 0, &q, &mut a, &mut scratch).unwrap();
        c.attend_decode(3, 0, 0, &q, &mut b, &mut scratch).unwrap();
        assert_eq!(a, b);
    }
}
