//! Config system (substrate): a TOML-subset parser + typed experiment
//! configs with CLI `section.key=value` overrides.
//!
//! Supported TOML subset (all the repo needs): `[section]` headers, `key =
//! value` with string/int/float/bool/homogeneous-scalar-array values, `#`
//! comments. Files under `configs/` define experiment presets; every value
//! can be overridden from the CLI (`repro exp table2 -c configs/fast.toml
//! -s train.steps=50`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A scalar or array config value.
#[derive(Clone, Debug, PartialEq)]
pub enum CfgValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<CfgValue>),
}

impl CfgValue {
    fn parse(tok: &str) -> Result<CfgValue> {
        let t = tok.trim();
        if t.starts_with('"') && t.ends_with('"') && t.len() >= 2 {
            return Ok(CfgValue::Str(t[1..t.len() - 1].to_string()));
        }
        if t == "true" {
            return Ok(CfgValue::Bool(true));
        }
        if t == "false" {
            return Ok(CfgValue::Bool(false));
        }
        if t.starts_with('[') && t.ends_with(']') {
            let inner = &t[1..t.len() - 1];
            let items = split_top(inner)?;
            return Ok(CfgValue::Arr(
                items.iter().map(|s| CfgValue::parse(s)).collect::<Result<_>>()?,
            ));
        }
        if let Ok(i) = t.parse::<i64>() {
            return Ok(CfgValue::Int(i));
        }
        if let Ok(f) = t.parse::<f64>() {
            return Ok(CfgValue::Float(f));
        }
        // bare word = string (lenient; convenient for CLI overrides)
        if !t.is_empty() && t.chars().all(|c| c.is_alphanumeric() || "_-.".contains(c)) {
            return Ok(CfgValue::Str(t.to_string()));
        }
        bail!("cannot parse value: {t:?}")
    }
}

fn split_top(s: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    Ok(out)
}

/// Parsed config: `section.key -> value` (top-level keys have section "").
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, CfgValue>,
}

impl Config {
    pub fn parse(src: &str) -> Result<Config> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            map.insert(key, CfgValue::parse(v).with_context(|| format!("line {}", lineno + 1))?);
        }
        Ok(Config { map })
    }

    pub fn load(path: &Path) -> Result<Config> {
        let src = std::fs::read_to_string(path).with_context(|| format!("{path:?}"))?;
        Config::parse(&src)
    }

    /// Apply a `section.key=value` CLI override.
    pub fn set(&mut self, assignment: &str) -> Result<()> {
        let (k, v) = assignment
            .split_once('=')
            .ok_or_else(|| anyhow!("override must be key=value: {assignment:?}"))?;
        self.map.insert(k.trim().to_string(), CfgValue::parse(v)?);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&CfgValue> {
        self.map.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        match self.map.get(key) {
            Some(CfgValue::Str(s)) => s.clone(),
            _ => default.to_string(),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        match self.map.get(key) {
            Some(CfgValue::Int(i)) => *i as usize,
            Some(CfgValue::Float(f)) => *f as usize,
            _ => default,
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        match self.map.get(key) {
            Some(CfgValue::Float(f)) => *f as f32,
            Some(CfgValue::Int(i)) => *i as f32,
            _ => default,
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.map.get(key) {
            Some(CfgValue::Bool(b)) => *b,
            _ => default,
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        match self.map.get(key) {
            Some(CfgValue::Int(i)) => *i as u64,
            _ => default,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let src = r#"
            # experiment preset
            top = 1
            [train]
            steps = 200
            lr = 1e-4          # peak
            variant = "qat"
            ablate = true
            seqs = [64, 128]
        "#;
        let c = Config::parse(src).unwrap();
        assert_eq!(c.usize_or("top", 0), 1);
        assert_eq!(c.usize_or("train.steps", 0), 200);
        assert!((c.f32_or("train.lr", 0.0) - 1e-4).abs() < 1e-9);
        assert_eq!(c.str_or("train.variant", ""), "qat");
        assert!(c.bool_or("train.ablate", false));
        match c.get("train.seqs") {
            Some(CfgValue::Arr(a)) => assert_eq!(a.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn overrides() {
        let mut c = Config::parse("[a]\nx = 1\n").unwrap();
        c.set("a.x=5").unwrap();
        c.set("a.name=hello").unwrap();
        assert_eq!(c.usize_or("a.x", 0), 5);
        assert_eq!(c.str_or("a.name", ""), "hello");
        assert!(c.set("garbage").is_err());
    }

    #[test]
    fn defaults_when_missing() {
        let c = Config::default();
        assert_eq!(c.usize_or("nope", 7), 7);
        assert_eq!(c.str_or("nope", "d"), "d");
    }

    #[test]
    fn comments_inside_strings() {
        let c = Config::parse("k = \"a#b\"\n").unwrap();
        assert_eq!(c.str_or("k", ""), "a#b");
    }
}
