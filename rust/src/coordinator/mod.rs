//! Training orchestrator (L3): drives compiled train-step artifacts.
//!
//! The paper's contribution lives at L1/L2, so L3 is a *driver* — but a
//! real one: state threading across steps, LR schedules, metric logging
//! (loss + pre-clip grad-norm time series, the Figure-3 signals),
//! checkpointing, periodic eval hooks, and divergence detection (the
//! "exploding gradients" the paper reports for drop-in QAT must be
//! *observable*, not fatal).

pub mod checkpoint;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{Runtime, Value};
use crate::tensor::Tensor;

/// Learning-rate schedule (constant or linear-warmup cosine).
#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    Constant(f32),
    /// warmup steps, peak lr, total steps, final fraction
    Cosine { warmup: usize, peak: f32, total: usize, floor_frac: f32 },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f32 {
        match *self {
            LrSchedule::Constant(lr) => lr,
            LrSchedule::Cosine { warmup, peak, total, floor_frac } => {
                if step < warmup {
                    peak * (step + 1) as f32 / warmup as f32
                } else {
                    let t = (step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
                    let cos = 0.5 * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos());
                    peak * (floor_frac + (1.0 - floor_frac) * cos)
                }
            }
        }
    }
}

/// Per-step metrics (the Figure-3 time series).
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub step: usize,
    pub loss: f32,
    pub grad_norm: f32,
    pub lr: f32,
    pub wall_ms: f64,
    /// True if the training watchdog rolled this step back (the recorded
    /// loss/grad_norm keep the bad values; the params do not).
    pub rollback: bool,
}

/// Model + optimizer state as host tensors, threaded between executions.
pub struct TrainState {
    /// Parameter tensors, artifact input order.
    pub params: Vec<Tensor>,
    /// Optimizer tensors (m__*/v__*), artifact input order.
    pub opt: Vec<Tensor>,
    pub step: usize,
}

/// Orchestrates one training run over a `*_train_*` artifact.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    pub train_artifact: String,
    pub schedule: LrSchedule,
    pub state: TrainState,
    pub history: Vec<StepMetrics>,
    n_params: usize,
    n_opt: usize,
    n_batch_inputs: usize,
    /// Consider the run diverged when |loss| or grad_norm exceeds this (or
    /// goes non-finite). The run continues — divergence is data here.
    pub divergence_threshold: f32,
}

impl<'rt> Trainer<'rt> {
    /// Initialise from an `*_init_*` artifact (params) + zeroed optimizer.
    pub fn new(
        rt: &'rt Runtime,
        init_artifact: &str,
        train_artifact: &str,
        seed: i32,
        schedule: LrSchedule,
    ) -> Result<Trainer<'rt>> {
        let params = rt.run(init_artifact, &[Value::scalar_i32(seed)])?;
        let meta = rt.meta(train_artifact)?;
        let n_params = meta.param_names().len();
        let n_opt = meta.opt_names().len();
        if n_params == 0 || n_opt == 0 {
            bail!("{train_artifact} metadata missing param/opt names");
        }
        if params.len() != n_params {
            bail!(
                "init artifact produced {} params, train step wants {}",
                params.len(),
                n_params
            );
        }
        // step/lr + batch tensors follow params+opt in the input list.
        let n_batch_inputs = meta.inputs.len() - n_params - n_opt - 2;
        let opt = meta.inputs[n_params..n_params + n_opt]
            .iter()
            .map(|spec| Tensor::zeros(spec.shape.clone()))
            .collect();
        Ok(Trainer {
            rt,
            train_artifact: train_artifact.to_string(),
            schedule,
            state: TrainState { params, opt, step: 0 },
            history: Vec::new(),
            n_params,
            n_opt,
            n_batch_inputs,
            divergence_threshold: 1e6,
        })
    }

    /// Resume with existing parameters (e.g. SFT from a pretrained state).
    pub fn with_params(mut self, params: Vec<Tensor>) -> Result<Self> {
        if params.len() != self.n_params {
            bail!("expected {} params, got {}", self.n_params, params.len());
        }
        self.state.params = params;
        Ok(self)
    }

    /// One optimizer step on the supplied batch values (tokens+mask for
    /// LM, x0+noise+t for diffusion). Returns the step's metrics.
    pub fn step(&mut self, batch: &[Value]) -> Result<StepMetrics> {
        if batch.len() != self.n_batch_inputs {
            bail!(
                "train step wants {} batch inputs, got {}",
                self.n_batch_inputs,
                batch.len()
            );
        }
        let lr = self.schedule.at(self.state.step);
        let t0 = std::time::Instant::now();
        let mut inputs: Vec<Value> =
            Vec::with_capacity(self.n_params + self.n_opt + 2 + batch.len());
        for p in &self.state.params {
            inputs.push(Value::F32(p.clone()));
        }
        for o in &self.state.opt {
            inputs.push(Value::F32(o.clone()));
        }
        inputs.push(Value::scalar_f32((self.state.step + 1) as f32));
        inputs.push(Value::scalar_f32(lr));
        inputs.extend_from_slice(batch);

        let mut outputs = self.rt.run(&self.train_artifact, &inputs)?;
        let grad_norm = outputs
            .pop()
            .ok_or_else(|| anyhow!("missing grad_norm output"))?
            .item();
        let loss = outputs
            .pop()
            .ok_or_else(|| anyhow!("missing loss output"))?
            .item();
        let opt = outputs.split_off(self.n_params);
        self.state.params = outputs;
        self.state.opt = opt;
        self.state.step += 1;
        let m = StepMetrics {
            step: self.state.step,
            loss,
            grad_norm,
            lr,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            rollback: false,
        };
        self.history.push(m);
        Ok(m)
    }

    /// Run `steps` optimizer steps pulling batches from `next_batch`.
    /// Calls `on_log` every `log_every` steps (and on the last step).
    pub fn run(
        &mut self,
        steps: usize,
        log_every: usize,
        mut next_batch: impl FnMut(usize) -> Vec<Value>,
        mut on_log: impl FnMut(&StepMetrics),
    ) -> Result<()> {
        for i in 0..steps {
            let batch = next_batch(i);
            let m = self.step(&batch)?;
            if log_every > 0 && (i % log_every == 0 || i + 1 == steps) {
                on_log(&m);
            }
        }
        Ok(())
    }

    /// True if any recorded step exceeded the divergence threshold.
    pub fn diverged(&self) -> bool {
        self.history.iter().any(|m| {
            !m.loss.is_finite()
                || !m.grad_norm.is_finite()
                || m.loss.abs() > self.divergence_threshold
                || m.grad_norm > self.divergence_threshold
        })
    }

    /// Mean loss over the last `n` steps (NaN-safe; for result tables).
    pub fn tail_loss(&self, n: usize) -> f32 {
        let tail: Vec<f32> = self
            .history
            .iter()
            .rev()
            .take(n)
            .map(|m| m.loss)
            .filter(|l| l.is_finite())
            .collect();
        if tail.is_empty() {
            f32::NAN
        } else {
            tail.iter().sum::<f32>() / tail.len() as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_schedule_shape() {
        let s = LrSchedule::Cosine { warmup: 10, peak: 1.0, total: 110, floor_frac: 0.1 };
        assert!(s.at(0) < s.at(9));
        assert!((s.at(9) - 1.0).abs() < 0.11);
        assert!(s.at(60) < 1.0);
        assert!(s.at(109) >= 0.1 - 1e-6);
        assert!(s.at(500) >= 0.1 - 1e-6); // clamps past total
    }

    #[test]
    fn constant_schedule() {
        let s = LrSchedule::Constant(3e-4);
        assert_eq!(s.at(0), 3e-4);
        assert_eq!(s.at(1000), 3e-4);
    }
}
