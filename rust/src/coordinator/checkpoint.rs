//! Checkpoint format (substrate): a self-describing binary container for
//! named f32 tensors — magic, version, then per-tensor
//! `name_len|name|rank|dims|f32 data` records (little endian), sealed by
//! a `payload_len|fnv1a64|footer-magic` trailer.
//!
//! Used to persist trained parameters between experiment phases (continued
//! pretraining → SFT → serving) without re-running training. Because a
//! checkpoint may be the only surviving copy of hours of training, the
//! format is hardened against the two failure modes that actually eat
//! checkpoints in practice:
//!
//! - **Torn writes** (crash / disk-full mid-save): [`save`] writes to a
//!   `.tmp` sibling and atomically renames it into place, so `path` only
//!   ever holds a complete file.
//! - **Silent corruption** (truncation, bit rot): the trailer records the
//!   payload length and an FNV-1a 64 checksum; [`load`] verifies both
//!   before parsing and returns a descriptive error instead of garbage
//!   tensors.
//!
//! **Version 3** ([`save_train`] / [`load_train`]) appends an optimizer
//! section *after* the tensor records, inside the same checksummed
//! payload: Adam/LowPAdam step count, the per-tensor f32 state slots,
//! and raw byte slots (LowPAdam's E4M3 moment bytes, verbatim — so a
//! resumed finetune replays bitwise). Because the v2 parser reads exactly
//! `count` tensor records and ignores trailing payload, [`load`] opens a
//! v3 file as tensors-only; v2 files load through [`load_train`] with
//! `None` optimizer state. Nothing about v2 changed.

use std::fs::File;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::optim::OptimizerState;
use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"AQATCKPT";
const FOOTER_MAGIC: &[u8; 8] = b"AQATCKSM";
const VERSION: u32 = 2;
/// Version written by [`save_train`]: v2 tensor records followed by an
/// optimizer-state section, all inside the checksummed payload.
const TRAIN_VERSION: u32 = 3;
/// Trailer: payload_len u64 | fnv1a64 u64 | footer magic.
const FOOTER_LEN: usize = 8 + 8 + 8;
const HEADER_LEN: usize = 8 + 4;

/// FNV-1a 64-bit over `bytes` — tiny, dependency-free, and plenty to
/// catch truncation and bit flips (this is an integrity check, not a
/// cryptographic seal).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Write named tensors to `path` atomically: the bytes land in
/// `path.tmp` first and are renamed over `path` only once fully written
/// and synced, so a crash mid-save never leaves a torn checkpoint at
/// `path` (at worst a stale `.tmp` sibling, which the next save
/// overwrites).
pub fn save(path: &Path, named: &[(String, &Tensor)]) -> Result<()> {
    write_file(path, VERSION, tensor_payload(named))
}

/// Serialize the v2 tensor-record payload in memory, so the checksum
/// covers exactly the bytes that hit disk.
fn tensor_payload(named: &[(String, &Tensor)]) -> Vec<u8> {
    let mut payload = Vec::new();
    payload.extend_from_slice(&(named.len() as u32).to_le_bytes());
    for (name, t) in named {
        let nb = name.as_bytes();
        payload.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        payload.extend_from_slice(nb);
        payload.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            payload.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &x in &t.data {
            payload.extend_from_slice(&x.to_le_bytes());
        }
    }
    payload
}

/// Atomic tmp-write-sync-rename of `header | payload | trailer`.
fn write_file(path: &Path, version: u32, payload: Vec<u8>) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = File::create(&tmp).with_context(|| format!("{tmp:?}"))?;
        f.write_all(MAGIC)?;
        f.write_all(&version.to_le_bytes())?;
        f.write_all(&payload)?;
        f.write_all(&(payload.len() as u64).to_le_bytes())?;
        f.write_all(&fnv1a64(&payload).to_le_bytes())?;
        f.write_all(FOOTER_MAGIC)?;
        f.sync_all().with_context(|| format!("sync {tmp:?}"))?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Save a **training** checkpoint (version 3): the v2 tensor records plus
/// the optimizer's full mutable state, so a finetune resumed from the
/// file replays the exact byte-for-byte trajectory it would have taken
/// uninterrupted. `opt: None` writes an empty optimizer section (the
/// tensors still load everywhere, including plain [`load`]).
pub fn save_train(
    path: &Path,
    named: &[(String, &Tensor)],
    opt: Option<&OptimizerState>,
) -> Result<()> {
    let mut payload = tensor_payload(named);
    match opt {
        None => payload.push(0u8),
        Some(st) => {
            payload.push(1u8);
            payload.extend_from_slice(&st.step.to_le_bytes());
            payload.extend_from_slice(&(st.slots.len() as u32).to_le_bytes());
            for slot in &st.slots {
                payload.extend_from_slice(&(slot.len() as u32).to_le_bytes());
                for buf in slot {
                    payload.extend_from_slice(&(buf.len() as u32).to_le_bytes());
                    for &x in buf {
                        payload.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
            payload.extend_from_slice(&(st.byte_slots.len() as u32).to_le_bytes());
            for slot in &st.byte_slots {
                payload.extend_from_slice(&(slot.len() as u32).to_le_bytes());
                for buf in slot {
                    payload.extend_from_slice(&(buf.len() as u32).to_le_bytes());
                    payload.extend_from_slice(buf);
                }
            }
        }
    }
    write_file(path, TRAIN_VERSION, payload)
}

/// A bounds-checked cursor over the in-memory payload: every read is
/// validated against the (already checksummed) buffer, so a malformed
/// record errors instead of panicking.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(end) => {
                let s = &self.buf[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            None => bail!(
                "truncated checkpoint payload: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ),
        }
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Verify magic, version, footer length, and checksum; return the
/// version and the checksummed payload bytes. Shared by [`load`] and
/// [`load_train`] so both reject the same corruptions identically.
fn read_verified(path: &Path) -> Result<(u32, Vec<u8>)> {
    let bytes = std::fs::read(path).with_context(|| format!("{path:?}"))?;
    if bytes.len() < HEADER_LEN || &bytes[..8] != MAGIC {
        bail!("not a checkpoint file: {path:?}");
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != VERSION && version != TRAIN_VERSION {
        bail!(
            "unsupported checkpoint version {version} (expected {VERSION} or {TRAIN_VERSION}): \
             {path:?}"
        );
    }
    if bytes.len() < HEADER_LEN + FOOTER_LEN {
        bail!("truncated checkpoint (no integrity footer): {path:?}");
    }
    let (body, footer) = bytes.split_at(bytes.len() - FOOTER_LEN);
    if &footer[16..24] != FOOTER_MAGIC {
        bail!("truncated checkpoint (integrity footer missing or cut short): {path:?}");
    }
    let payload = &body[HEADER_LEN..];
    let stored_len = u64::from_le_bytes(footer[..8].try_into().unwrap());
    if stored_len != payload.len() as u64 {
        bail!(
            "truncated checkpoint: footer says {stored_len} payload bytes, found {}: {path:?}",
            payload.len()
        );
    }
    let stored_sum = u64::from_le_bytes(footer[8..16].try_into().unwrap());
    let actual_sum = fnv1a64(payload);
    if stored_sum != actual_sum {
        bail!(
            "checkpoint checksum mismatch (stored {stored_sum:#018x}, computed \
             {actual_sum:#018x}) — file is corrupt: {path:?}"
        );
    }
    Ok((version, payload.to_vec()))
}

/// Parse the tensor-record section at the cursor (exactly `count`
/// records; trailing payload — a v3 optimizer section — is left unread).
fn parse_tensors(c: &mut Cursor) -> Result<Vec<(String, Tensor)>> {
    let count = c.u32()? as usize;
    let mut out = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        let name_len = c.u32()? as usize;
        let name = String::from_utf8(c.take(name_len)?.to_vec())?;
        let rank = c.u32()? as usize;
        let mut shape = Vec::with_capacity(rank.min(64));
        for _ in 0..rank {
            shape.push(c.u64()? as usize);
        }
        let n: usize = shape.iter().product();
        let raw = c.take(n.checked_mul(4).context("tensor element count overflows")?)?;
        let data = raw.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())).collect();
        out.push((name, Tensor::new(shape, data)?));
    }
    Ok(out)
}

/// Read all tensors back, in file order. Fails with a descriptive error
/// (rather than returning corrupt tensors) if the file is truncated,
/// bit-flipped, or not a checkpoint at all. Accepts both v2 and v3 files
/// (a v3 optimizer section is simply skipped).
pub fn load(path: &Path) -> Result<Vec<(String, Tensor)>> {
    let (_version, payload) = read_verified(path)?;
    let mut c = Cursor { buf: &payload, pos: 0 };
    parse_tensors(&mut c)
}

/// Read a training checkpoint: tensors plus the optimizer state, when
/// the file carries one. A v2 file (or a v3 file saved with `opt: None`)
/// returns `None` state — callers fall back to fresh moments, exactly
/// the behaviour before v3 existed.
pub fn load_train(path: &Path) -> Result<(Vec<(String, Tensor)>, Option<OptimizerState>)> {
    let (version, payload) = read_verified(path)?;
    let mut c = Cursor { buf: &payload, pos: 0 };
    let tensors = parse_tensors(&mut c)?;
    if version != TRAIN_VERSION {
        return Ok((tensors, None));
    }
    let present = c.take(1)?[0];
    if present == 0 {
        return Ok((tensors, None));
    }
    let step = i32::from_le_bytes(c.take(4)?.try_into().unwrap());
    let n_slots = c.u32()? as usize;
    let mut slots = Vec::with_capacity(n_slots.min(64));
    for _ in 0..n_slots {
        let n_tensors = c.u32()? as usize;
        let mut slot = Vec::with_capacity(n_tensors.min(4096));
        for _ in 0..n_tensors {
            let len = c.u32()? as usize;
            let raw = c.take(len.checked_mul(4).context("state buffer length overflows")?)?;
            slot.push(
                raw.chunks_exact(4)
                    .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                    .collect::<Vec<f32>>(),
            );
        }
        slots.push(slot);
    }
    let n_byte_slots = c.u32()? as usize;
    let mut byte_slots = Vec::with_capacity(n_byte_slots.min(64));
    for _ in 0..n_byte_slots {
        let n_tensors = c.u32()? as usize;
        let mut slot = Vec::with_capacity(n_tensors.min(4096));
        for _ in 0..n_tensors {
            let len = c.u32()? as usize;
            slot.push(c.take(len)?.to_vec());
        }
        byte_slots.push(slot);
    }
    Ok((tensors, Some(OptimizerState { step, slots, byte_slots })))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (Tensor, Tensor) {
        let t1 = Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]).unwrap();
        let t2 = Tensor::scalar(42.0);
        (t1, t2)
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test");
        let path = dir.join("a.ckpt");
        let (t1, t2) = sample();
        save(&path, &[("w".into(), &t1), ("b".into(), &t2)]).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, "w");
        assert_eq!(back[0].1, t1);
        assert_eq!(back[1].1, t2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_is_atomic_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test_atomic");
        let path = dir.join("a.ckpt");
        let (t1, _) = sample();
        save(&path, &[("w".into(), &t1)]).unwrap();
        assert!(path.exists());
        assert!(!path.with_extension("tmp").exists(), "tmp must be renamed away");
        // Overwriting an existing checkpoint also goes through the tmp.
        save(&path, &[("w".into(), &t1)]).unwrap();
        assert!(!path.with_extension("tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxx").unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("not a checkpoint"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_truncation() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test_trunc");
        let path = dir.join("a.ckpt");
        let (t1, t2) = sample();
        save(&path, &[("w".into(), &t1), ("b".into(), &t2)]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Chop bytes off the end at several depths: all must error, none
        // may return a partial tensor list.
        for cut in [1, FOOTER_LEN, FOOTER_LEN + 5, bytes.len() - HEADER_LEN - 1] {
            let short = &bytes[..bytes.len() - cut];
            std::fs::write(&path, short).unwrap();
            let err = load(&path).unwrap_err().to_string();
            assert!(err.contains("truncated") || err.contains("not a checkpoint"), "{err}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_bit_flips() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test_flip");
        let path = dir.join("a.ckpt");
        let (t1, t2) = sample();
        save(&path, &[("w".into(), &t1), ("b".into(), &t2)]).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // Flip one bit in every payload byte position in turn — the
        // checksum must catch each one.
        for pos in HEADER_LEN..clean.len() - FOOTER_LEN {
            let mut bytes = clean.clone();
            bytes[pos] ^= 0x10;
            std::fs::write(&path, &bytes).unwrap();
            let err = load(&path).unwrap_err().to_string();
            assert!(err.contains("checksum mismatch"), "pos {pos}: {err}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn train_roundtrip_preserves_optimizer_state_bytes() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test_train");
        let path = dir.join("t.ckpt");
        let (t1, t2) = sample();
        let st = OptimizerState {
            step: 7,
            slots: vec![vec![vec![1.5, -2.25], vec![]], vec![vec![0.03125]]],
            byte_slots: vec![vec![vec![0x00, 0x7E, 0x80, 0xFE], vec![]]],
        };
        save_train(&path, &[("w".into(), &t1), ("b".into(), &t2)], Some(&st)).unwrap();
        let (tensors, opt) = load_train(&path).unwrap();
        assert_eq!(tensors.len(), 2);
        assert_eq!(tensors[0].1, t1);
        let opt = opt.expect("optimizer state present");
        assert_eq!(opt.step, st.step);
        assert_eq!(opt.slots, st.slots);
        // The raw moment bytes must come back verbatim — bitwise resume
        // depends on it.
        assert_eq!(opt.byte_slots, st.byte_slots);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v3_file_loads_as_plain_tensors_and_v2_loads_as_train() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test_compat");
        let (t1, _) = sample();
        // v3 → plain `load` sees the tensors, ignores the opt section.
        let p3 = dir.join("v3.ckpt");
        let st = OptimizerState { step: 2, slots: vec![vec![vec![1.0]]], byte_slots: vec![] };
        save_train(&p3, &[("w".into(), &t1)], Some(&st)).unwrap();
        let back = load(&p3).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].1, t1);
        // v3 with no state → Some tensors, None state.
        let p3n = dir.join("v3none.ckpt");
        save_train(&p3n, &[("w".into(), &t1)], None).unwrap();
        assert!(load_train(&p3n).unwrap().1.is_none());
        // v2 → `load_train` sees tensors, None state.
        let p2 = dir.join("v2.ckpt");
        save(&p2, &[("w".into(), &t1)]).unwrap();
        let (tensors, opt) = load_train(&p2).unwrap();
        assert_eq!(tensors[0].1, t1);
        assert!(opt.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn train_checkpoint_detects_bit_flips_in_opt_section() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test_train_flip");
        let path = dir.join("t.ckpt");
        let (t1, _) = sample();
        let st = OptimizerState {
            step: 1,
            slots: vec![vec![vec![4.0]]],
            byte_slots: vec![vec![vec![0x3Au8; 8]]],
        };
        save_train(&path, &[("w".into(), &t1)], Some(&st)).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // Flip a byte near the end of the payload (inside the opt
        // section): the shared checksum must catch it.
        let mut bytes = clean.clone();
        let pos = bytes.len() - FOOTER_LEN - 3;
        bytes[pos] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_train(&path).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_old_versions() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test_ver");
        let path = dir.join("a.ckpt");
        let (t1, _) = sample();
        save(&path, &[("w".into(), &t1)]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..12].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("unsupported checkpoint version 1"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
