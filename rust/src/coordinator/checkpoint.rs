//! Checkpoint format (substrate): a self-describing binary container for
//! named f32 tensors — magic, version, then per-tensor
//! `name_len|name|rank|dims|f32 data` records (little endian), sealed by
//! a `payload_len|fnv1a64|footer-magic` trailer.
//!
//! Used to persist trained parameters between experiment phases (continued
//! pretraining → SFT → serving) without re-running training. Because a
//! checkpoint may be the only surviving copy of hours of training, the
//! format is hardened against the two failure modes that actually eat
//! checkpoints in practice:
//!
//! - **Torn writes** (crash / disk-full mid-save): [`save`] writes to a
//!   `.tmp` sibling and atomically renames it into place, so `path` only
//!   ever holds a complete file.
//! - **Silent corruption** (truncation, bit rot): the trailer records the
//!   payload length and an FNV-1a 64 checksum; [`load`] verifies both
//!   before parsing and returns a descriptive error instead of garbage
//!   tensors.

use std::fs::File;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"AQATCKPT";
const FOOTER_MAGIC: &[u8; 8] = b"AQATCKSM";
const VERSION: u32 = 2;
/// Trailer: payload_len u64 | fnv1a64 u64 | footer magic.
const FOOTER_LEN: usize = 8 + 8 + 8;
const HEADER_LEN: usize = 8 + 4;

/// FNV-1a 64-bit over `bytes` — tiny, dependency-free, and plenty to
/// catch truncation and bit flips (this is an integrity check, not a
/// cryptographic seal).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Write named tensors to `path` atomically: the bytes land in
/// `path.tmp` first and are renamed over `path` only once fully written
/// and synced, so a crash mid-save never leaves a torn checkpoint at
/// `path` (at worst a stale `.tmp` sibling, which the next save
/// overwrites).
pub fn save(path: &Path, named: &[(String, &Tensor)]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }

    // Serialize the payload in memory so the checksum covers exactly the
    // bytes that hit disk.
    let mut payload = Vec::new();
    payload.extend_from_slice(&(named.len() as u32).to_le_bytes());
    for (name, t) in named {
        let nb = name.as_bytes();
        payload.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        payload.extend_from_slice(nb);
        payload.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            payload.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &x in &t.data {
            payload.extend_from_slice(&x.to_le_bytes());
        }
    }

    let tmp = path.with_extension("tmp");
    {
        let mut f = File::create(&tmp).with_context(|| format!("{tmp:?}"))?;
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&payload)?;
        f.write_all(&(payload.len() as u64).to_le_bytes())?;
        f.write_all(&fnv1a64(&payload).to_le_bytes())?;
        f.write_all(FOOTER_MAGIC)?;
        f.sync_all().with_context(|| format!("sync {tmp:?}"))?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// A bounds-checked cursor over the in-memory payload: every read is
/// validated against the (already checksummed) buffer, so a malformed
/// record errors instead of panicking.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(end) => {
                let s = &self.buf[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            None => bail!(
                "truncated checkpoint payload: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ),
        }
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Read all tensors back, in file order. Fails with a descriptive error
/// (rather than returning corrupt tensors) if the file is truncated,
/// bit-flipped, or not a checkpoint at all.
pub fn load(path: &Path) -> Result<Vec<(String, Tensor)>> {
    let bytes = std::fs::read(path).with_context(|| format!("{path:?}"))?;
    if bytes.len() < HEADER_LEN || &bytes[..8] != MAGIC {
        bail!("not a checkpoint file: {path:?}");
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != VERSION {
        bail!("unsupported checkpoint version {version} (expected {VERSION}): {path:?}");
    }
    if bytes.len() < HEADER_LEN + FOOTER_LEN {
        bail!("truncated checkpoint (no integrity footer): {path:?}");
    }
    let (body, footer) = bytes.split_at(bytes.len() - FOOTER_LEN);
    if &footer[16..24] != FOOTER_MAGIC {
        bail!("truncated checkpoint (integrity footer missing or cut short): {path:?}");
    }
    let payload = &body[HEADER_LEN..];
    let stored_len = u64::from_le_bytes(footer[..8].try_into().unwrap());
    if stored_len != payload.len() as u64 {
        bail!(
            "truncated checkpoint: footer says {stored_len} payload bytes, found {}: {path:?}",
            payload.len()
        );
    }
    let stored_sum = u64::from_le_bytes(footer[8..16].try_into().unwrap());
    let actual_sum = fnv1a64(payload);
    if stored_sum != actual_sum {
        bail!(
            "checkpoint checksum mismatch (stored {stored_sum:#018x}, computed \
             {actual_sum:#018x}) — file is corrupt: {path:?}"
        );
    }

    let mut c = Cursor { buf: payload, pos: 0 };
    let count = c.u32()? as usize;
    let mut out = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        let name_len = c.u32()? as usize;
        let name = String::from_utf8(c.take(name_len)?.to_vec())?;
        let rank = c.u32()? as usize;
        let mut shape = Vec::with_capacity(rank.min(64));
        for _ in 0..rank {
            shape.push(c.u64()? as usize);
        }
        let n: usize = shape.iter().product();
        let raw = c.take(n.checked_mul(4).context("tensor element count overflows")?)?;
        let data = raw.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())).collect();
        out.push((name, Tensor::new(shape, data)?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (Tensor, Tensor) {
        let t1 = Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]).unwrap();
        let t2 = Tensor::scalar(42.0);
        (t1, t2)
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test");
        let path = dir.join("a.ckpt");
        let (t1, t2) = sample();
        save(&path, &[("w".into(), &t1), ("b".into(), &t2)]).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, "w");
        assert_eq!(back[0].1, t1);
        assert_eq!(back[1].1, t2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_is_atomic_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test_atomic");
        let path = dir.join("a.ckpt");
        let (t1, _) = sample();
        save(&path, &[("w".into(), &t1)]).unwrap();
        assert!(path.exists());
        assert!(!path.with_extension("tmp").exists(), "tmp must be renamed away");
        // Overwriting an existing checkpoint also goes through the tmp.
        save(&path, &[("w".into(), &t1)]).unwrap();
        assert!(!path.with_extension("tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxx").unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("not a checkpoint"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_truncation() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test_trunc");
        let path = dir.join("a.ckpt");
        let (t1, t2) = sample();
        save(&path, &[("w".into(), &t1), ("b".into(), &t2)]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Chop bytes off the end at several depths: all must error, none
        // may return a partial tensor list.
        for cut in [1, FOOTER_LEN, FOOTER_LEN + 5, bytes.len() - HEADER_LEN - 1] {
            let short = &bytes[..bytes.len() - cut];
            std::fs::write(&path, short).unwrap();
            let err = load(&path).unwrap_err().to_string();
            assert!(err.contains("truncated") || err.contains("not a checkpoint"), "{err}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_bit_flips() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test_flip");
        let path = dir.join("a.ckpt");
        let (t1, t2) = sample();
        save(&path, &[("w".into(), &t1), ("b".into(), &t2)]).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // Flip one bit in every payload byte position in turn — the
        // checksum must catch each one.
        for pos in HEADER_LEN..clean.len() - FOOTER_LEN {
            let mut bytes = clean.clone();
            bytes[pos] ^= 0x10;
            std::fs::write(&path, &bytes).unwrap();
            let err = load(&path).unwrap_err().to_string();
            assert!(err.contains("checksum mismatch"), "pos {pos}: {err}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_old_versions() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test_ver");
        let path = dir.join("a.ckpt");
        let (t1, _) = sample();
        save(&path, &[("w".into(), &t1)]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..12].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("unsupported checkpoint version 1"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
