//! Checkpoint format (substrate): a simple self-describing binary container
//! for named f32 tensors — magic, version, then per-tensor
//! `name_len|name|rank|dims|f32 data` records (little endian).
//!
//! Used to persist trained parameters between experiment phases (continued
//! pretraining → SFT → serving) without re-running training.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"AQATCKPT";
const VERSION: u32 = 1;

/// Write named tensors to `path`.
pub fn save(path: &Path, named: &[(String, &Tensor)]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("{path:?}"))?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(named.len() as u32).to_le_bytes())?;
    for (name, t) in named {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u32).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        for &x in &t.data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read all tensors back, in file order.
pub fn load(path: &Path) -> Result<Vec<(String, Tensor)>> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("{path:?}"))?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a checkpoint file: {path:?}");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let rank = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = shape.iter().product();
        let mut data = vec![0.0f32; n];
        let mut buf = [0u8; 4];
        for x in data.iter_mut() {
            r.read_exact(&mut buf)?;
            *x = f32::from_le_bytes(buf);
        }
        out.push((String::from_utf8(name)?, Tensor::new(shape, data)?));
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test");
        let path = dir.join("a.ckpt");
        let t1 = Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]).unwrap();
        let t2 = Tensor::scalar(42.0);
        save(&path, &[("w".into(), &t1), ("b".into(), &t2)]).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, "w");
        assert_eq!(back[0].1, t1);
        assert_eq!(back[1].1, t2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("attn_qat_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxx").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
