//! Automated pairwise judge — the Figure-2 "blind human evaluation" proxy.
//!
//! The paper compares Attn-QAT vs BF16 on 99 VBench prompts with human
//! win/tie/lose votes. Here each "prompt" is a generation seed; the judge
//! compares per-clip overall-quality scores with a tie band.

use super::video::{video_metrics, VideoRefStats};

/// Aggregated pairwise outcome (from A's perspective).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JudgeOutcome {
    pub wins: usize,
    pub ties: usize,
    pub losses: usize,
}

impl JudgeOutcome {
    pub fn total(&self) -> usize {
        self.wins + self.ties + self.losses
    }
}

/// Judge per-clip: score clip i of A vs clip i of B with tie band `eps`.
///
/// `a`/`b` are (n_clips × frames × d) sample tensors from the two systems
/// under identical seeds (the "same prompt" condition).
pub fn judge_pairwise(
    a: &[f32],
    b: &[f32],
    n_clips: usize,
    frames: usize,
    d: usize,
    r: &VideoRefStats,
    eps: f32,
) -> JudgeOutcome {
    let clip = frames * d;
    let mut out = JudgeOutcome::default();
    for i in 0..n_clips {
        let ma = video_metrics(&a[i * clip..(i + 1) * clip], 1, frames, d, r);
        let mb = video_metrics(&b[i * clip..(i + 1) * clip], 1, frames, d, r);
        let delta = ma.overall - mb.overall;
        if delta > eps {
            out.wins += 1;
        } else if delta < -eps {
            out.losses += 1;
        } else {
            out.ties += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::latents::LatentGen;
    use crate::eval::video::reference_stats;
    use crate::rng::Rng;

    #[test]
    fn identical_inputs_all_tie() {
        let (n, t, d) = (9, 16, 8);
        let mut g = LatentGen::new(1, t, d);
        let mut a = Vec::new();
        for _ in 0..n {
            a.extend(g.sample());
        }
        let r = reference_stats(&a, n, t, d);
        let o = judge_pairwise(&a, &a, n, t, d, &r, 0.01);
        assert_eq!(o, JudgeOutcome { wins: 0, ties: n, losses: 0 });
    }

    #[test]
    fn clean_beats_noise() {
        let (n, t, d) = (12, 16, 8);
        let mut g = LatentGen::new(2, t, d);
        let mut a = Vec::new();
        for _ in 0..n {
            a.extend(g.sample());
        }
        let r = reference_stats(&a, n, t, d);
        let mut rng = Rng::new(3);
        let b = rng.normal_vec(n * t * d, 0.0, 1.0);
        let o = judge_pairwise(&a, &b, n, t, d, &r, 0.01);
        assert!(o.wins > o.losses, "{o:?}");
        assert_eq!(o.total(), n);
    }
}
