//! LM evaluation over compiled `lm_eval_*` artifacts: perplexity and
//! multiple-choice accuracy by likelihood ranking — the same mechanism
//! lm-eval-harness uses for the paper's Table 3/4 benchmarks.

use anyhow::{anyhow, Result};

use crate::data::corpus::Corpus;
use crate::data::tasks::{gen_mc, mc_row, McItem};
use crate::data::LmBatch;
use crate::rng::Rng;
use crate::runtime::{Runtime, Value};
use crate::tensor::Tensor;

/// Run the eval artifact on one batch; returns (sum_nll, n_tok) per row.
fn eval_batch(
    rt: &Runtime,
    artifact: &str,
    params: &[Tensor],
    batch: &LmBatch,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let mut inputs: Vec<Value> = params.iter().cloned().map(Value::F32).collect();
    inputs.push(batch.token_value());
    inputs.push(batch.mask_value());
    let out = rt.run(artifact, &inputs)?;
    Ok((out[0].data.clone(), out[1].data.clone()))
}

/// Held-out perplexity over `n_batches` fresh corpus batches.
///
/// The corpus seed should differ from the training seed — the generator is
/// the "dataset", so a different stream seed is the held-out split.
pub fn perplexity(
    rt: &Runtime,
    artifact: &str,
    params: &[Tensor],
    corpus: &mut Corpus,
    n_batches: usize,
) -> Result<f64> {
    let meta = rt.meta(artifact)?;
    let batch = meta.usize_field("batch").ok_or_else(|| anyhow!("no batch in meta"))?;
    let seq = meta
        .raw
        .get("model")
        .get("seq_len")
        .as_usize()
        .ok_or_else(|| anyhow!("no seq_len in meta"))?;
    let mut total_nll = 0.0f64;
    let mut total_tok = 0.0f64;
    for _ in 0..n_batches {
        let b = corpus.next_batch(batch, seq);
        let (nll, tok) = eval_batch(rt, artifact, params, &b)?;
        total_nll += nll.iter().map(|&x| x as f64).sum::<f64>();
        total_tok += tok.iter().map(|&x| x as f64).sum::<f64>();
    }
    Ok((total_nll / total_tok.max(1.0)).exp())
}

/// Multiple-choice accuracy on `n_items` generated items of `suite`.
///
/// Each item contributes 4 rows (one per choice); rows are packed into the
/// artifact's batch size, padded with repeats, and the choice with the
/// lowest summed continuation NLL wins.
pub fn mc_accuracy(
    rt: &Runtime,
    artifact: &str,
    params: &[Tensor],
    suite: &str,
    n_items: usize,
    seed: u64,
) -> Result<f64> {
    let meta = rt.meta(artifact)?;
    let batch = meta.usize_field("batch").ok_or_else(|| anyhow!("no batch in meta"))?;
    let seq = meta
        .raw
        .get("model")
        .get("seq_len")
        .as_usize()
        .ok_or_else(|| anyhow!("no seq_len in meta"))?;

    let mut rng = Rng::new(seed).split(suite);
    let mut corpus = Corpus::new(seed ^ 0x5eed);
    let items: Vec<McItem> = (0..n_items).map(|_| gen_mc(&mut rng, suite, &mut corpus)).collect();

    // Flatten to rows.
    let mut rows: Vec<(Vec<i32>, Vec<f32>)> = Vec::with_capacity(items.len() * 4);
    for item in &items {
        for c in 0..4 {
            rows.push(mc_row(item, c, seq));
        }
    }
    // Score in batches.
    let mut scores = Vec::with_capacity(rows.len());
    for chunk in rows.chunks(batch) {
        let mut tokens = Vec::with_capacity(batch * (seq + 1));
        let mut mask = Vec::with_capacity(batch * seq);
        for r in 0..batch {
            let (t, m) = &chunk[r.min(chunk.len() - 1)]; // pad w/ repeats
            tokens.extend_from_slice(t);
            mask.extend_from_slice(m);
        }
        let b = LmBatch { batch, seq, tokens, mask };
        let (nll, _) = eval_batch(rt, artifact, params, &b)?;
        scores.extend_from_slice(&nll[..chunk.len()]);
    }
    // Rank.
    let mut correct = 0usize;
    for (i, item) in items.iter().enumerate() {
        let s = &scores[i * 4..(i + 1) * 4];
        let best = (0..4)
            .min_by(|&a, &b| s[a].partial_cmp(&s[b]).unwrap())
            .unwrap();
        if best == item.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len() as f64)
}

/// Exact-match accuracy on SFT tasks via greedy argmax decoding with the
/// logits... scored through the eval artifact by likelihood instead:
/// a generated answer is "correct" when the true answer is the argmin-NLL
/// continuation against 3 corrupted alternatives (a strictly harder check
/// than teacher-forced loss, cheaper than autoregressive decode).
pub fn sft_task_accuracy(
    rt: &Runtime,
    artifact: &str,
    params: &[Tensor],
    op: u8,
    n_items: usize,
    seed: u64,
) -> Result<f64> {
    // Reuse the MC machinery with per-op suites.
    let suite = match op {
        b'C' => "copy",
        b'S' => "sort",
        b'Q' => "lookup",
        _ => "copy",
    };
    mc_accuracy(rt, artifact, params, suite, n_items, seed)
}
