//! VBench-proxy video-quality metrics (Tables 1 & 2).
//!
//! The synthetic latent generator (`data::latents`) has known structure, so
//! each VBench axis maps to a measurable quantity on generated samples
//! `(B × frames × d)`:
//!
//! | VBench axis            | proxy measurement                                    |
//! |------------------------|------------------------------------------------------|
//! | Imaging Quality        | per-frame norm distribution matches the reference    |
//! | Aesthetic Quality      | per-dimension variance spectrum matches reference    |
//! | Subject Consistency    | cosine similarity of adjacent frames                 |
//! | Background Consistency | cosine of each frame to the clip's temporal mean     |
//! | Temporal Flickering    | inverse high-frequency (2nd-difference) energy       |
//! | Motion Smoothness      | 2nd difference small relative to 1st difference      |
//! | Dynamic Degree         | fraction of clips with motion energy above threshold |
//! | Overall                | VBench-style weighted mean                           |
//!
//! All metrics are in [0, 1] with higher = better except Dynamic Degree,
//! which (as in VBench) measures "is there motion at all" — quantization
//! collapse shows up as *low* dynamic degree, exactly as in the paper's
//! Tables 1–2 (0.52 BF16 → 0.30 FP4).

/// Reference statistics estimated from ground-truth generator samples.
#[derive(Clone, Debug)]
pub struct VideoRefStats {
    pub mean_frame_norm: f32,
    /// Sorted per-dimension variances (the "spectrum").
    pub var_spectrum: Vec<f32>,
    /// Median per-clip motion energy; the dynamic-degree threshold.
    pub motion_threshold: f32,
}

/// The eight VBench-proxy scores.
#[derive(Clone, Copy, Debug, Default)]
pub struct VideoMetrics {
    pub imaging_quality: f32,
    pub aesthetic_quality: f32,
    pub subject_consistency: f32,
    pub background_consistency: f32,
    pub temporal_flickering: f32,
    pub motion_smoothness: f32,
    pub dynamic_degree: f32,
    pub overall: f32,
}

impl VideoMetrics {
    pub fn row(&self) -> [f32; 8] {
        [
            self.imaging_quality,
            self.aesthetic_quality,
            self.subject_consistency,
            self.background_consistency,
            self.temporal_flickering,
            self.motion_smoothness,
            self.dynamic_degree,
            self.overall,
        ]
    }
}

fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    if na < 1e-9 || nb < 1e-9 {
        return 0.0;
    }
    (dot / (na * nb)).clamp(-1.0, 1.0)
}

/// Estimate reference stats from ground-truth samples `(b, t, d)`.
pub fn reference_stats(samples: &[f32], b: usize, t: usize, d: usize) -> VideoRefStats {
    let mut norms = Vec::with_capacity(b * t);
    for clip in 0..b {
        for fr in 0..t {
            let f = &samples[(clip * t + fr) * d..(clip * t + fr + 1) * d];
            norms.push(f.iter().map(|x| x * x).sum::<f32>().sqrt());
        }
    }
    let mean_frame_norm = norms.iter().sum::<f32>() / norms.len() as f32;

    let mut var_spectrum = per_dim_variances(samples, b * t, d);
    var_spectrum.sort_by(|a, bb| a.partial_cmp(bb).unwrap());

    let mut energies: Vec<f32> = (0..b).map(|c| motion_energy(samples, c, t, d)).collect();
    energies.sort_by(|a, bb| a.partial_cmp(bb).unwrap());
    let motion_threshold = energies[energies.len() / 2];

    VideoRefStats { mean_frame_norm, var_spectrum, motion_threshold }
}

fn per_dim_variances(samples: &[f32], rows: usize, d: usize) -> Vec<f32> {
    let mut mean = vec![0.0f32; d];
    for r in 0..rows {
        for c in 0..d {
            mean[c] += samples[r * d + c];
        }
    }
    for m in mean.iter_mut() {
        *m /= rows as f32;
    }
    let mut var = vec![0.0f32; d];
    for r in 0..rows {
        for c in 0..d {
            let e = samples[r * d + c] - mean[c];
            var[c] += e * e;
        }
    }
    for v in var.iter_mut() {
        *v /= rows as f32;
    }
    var
}

/// Mean per-step first-difference norm of clip `c` ("how much motion").
fn motion_energy(samples: &[f32], c: usize, t: usize, d: usize) -> f32 {
    let clip = &samples[c * t * d..(c + 1) * t * d];
    let mut acc = 0.0f32;
    for fr in 0..t - 1 {
        let mut step = 0.0f32;
        for j in 0..d {
            let diff = clip[(fr + 1) * d + j] - clip[fr * d + j];
            step += diff * diff;
        }
        acc += step.sqrt();
    }
    acc / (t - 1) as f32
}

/// Compute the eight metrics for generated samples `(b, t, d)`.
pub fn video_metrics(samples: &[f32], b: usize, t: usize, d: usize, r: &VideoRefStats) -> VideoMetrics {
    let mut subject = 0.0f32;
    let mut background = 0.0f32;
    let mut flicker = 0.0f32;
    let mut smooth = 0.0f32;
    let mut dynamic = 0usize;
    let mut norm_err = 0.0f32;

    for c in 0..b {
        let clip = &samples[c * t * d..(c + 1) * t * d];
        // temporal mean frame
        let mut mean = vec![0.0f32; d];
        for fr in 0..t {
            for j in 0..d {
                mean[j] += clip[fr * d + j];
            }
        }
        for m in mean.iter_mut() {
            *m /= t as f32;
        }
        let mut subj_c = 0.0f32;
        let mut bg_c = 0.0f32;
        for fr in 0..t {
            let f = &clip[fr * d..(fr + 1) * d];
            bg_c += cosine(f, &mean);
            if fr + 1 < t {
                subj_c += cosine(f, &clip[(fr + 1) * d..(fr + 2) * d]);
            }
            let n = f.iter().map(|x| x * x).sum::<f32>().sqrt();
            norm_err += (n - r.mean_frame_norm).abs() / r.mean_frame_norm.max(1e-6);
        }
        subject += subj_c / (t - 1) as f32;
        background += bg_c / t as f32;

        // flicker: 2nd-difference energy relative to frame magnitude
        let mut d2 = 0.0f32;
        let mut d1 = 0.0f32;
        for fr in 1..t - 1 {
            let mut acc2 = 0.0f32;
            for j in 0..d {
                let v = clip[(fr + 1) * d + j] - 2.0 * clip[fr * d + j] + clip[(fr - 1) * d + j];
                acc2 += v * v;
            }
            d2 += acc2.sqrt();
        }
        for fr in 0..t - 1 {
            let mut acc1 = 0.0f32;
            for j in 0..d {
                let v = clip[(fr + 1) * d + j] - clip[fr * d + j];
                acc1 += v * v;
            }
            d1 += acc1.sqrt();
        }
        d2 /= (t - 2) as f32;
        d1 /= (t - 1) as f32;
        let frame_scale = r.mean_frame_norm.max(1e-6);
        flicker += 1.0 / (1.0 + d2 / frame_scale);
        smooth += 1.0 / (1.0 + d2 / (d1 + 1e-6));

        // Dynamic degree: motion must be present AND in-distribution.
        // (Pure sampler noise has *huge* first-difference energy; VBench's
        // optical-flow test likewise rejects incoherent flicker.)
        let me = motion_energy(samples, c, t, d);
        if me > r.motion_threshold && me < 3.0 * r.motion_threshold {
            dynamic += 1;
        }
    }

    let bf = b as f32;
    let imaging_quality = (-(norm_err / (bf * t as f32))).exp();
    // spectrum distance
    let mut spec = per_dim_variances(samples, b * t, d);
    spec.sort_by(|a, bb| a.partial_cmp(bb).unwrap());
    let mut sdist = 0.0f32;
    let mut sref = 0.0f32;
    for (a, rr) in spec.iter().zip(&r.var_spectrum) {
        sdist += (a - rr).abs();
        sref += rr.abs();
    }
    let aesthetic_quality = (-(sdist / sref.max(1e-6))).exp();

    let m = VideoMetrics {
        imaging_quality,
        aesthetic_quality,
        subject_consistency: (subject / bf).clamp(0.0, 1.0),
        background_consistency: (background / bf).clamp(0.0, 1.0),
        temporal_flickering: flicker / bf,
        motion_smoothness: smooth / bf,
        dynamic_degree: dynamic as f32 / bf,
        overall: 0.0,
    };
    VideoMetrics {
        overall: 0.15 * m.imaging_quality
            + 0.15 * m.aesthetic_quality
            + 0.2 * m.subject_consistency
            + 0.2 * m.background_consistency
            + 0.1 * m.temporal_flickering
            + 0.1 * m.motion_smoothness
            + 0.1 * m.dynamic_degree,
        ..m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::latents::LatentGen;
    use crate::rng::Rng;

    fn gen_samples(seed: u64, b: usize, t: usize, d: usize) -> Vec<f32> {
        let mut g = LatentGen::new(seed, t, d);
        let mut out = Vec::new();
        for _ in 0..b {
            out.extend(g.sample());
        }
        out
    }

    #[test]
    fn ground_truth_scores_high() {
        let (b, t, d) = (16, 32, 16);
        let r = reference_stats(&gen_samples(1, b, t, d), b, t, d);
        let m = video_metrics(&gen_samples(2, b, t, d), b, t, d, &r);
        assert!(m.imaging_quality > 0.8, "imaging {}", m.imaging_quality);
        assert!(m.subject_consistency > 0.8, "subject {}", m.subject_consistency);
        assert!(m.background_consistency > 0.8, "bg {}", m.background_consistency);
        assert!(m.dynamic_degree > 0.25, "dyn {}", m.dynamic_degree);
        assert!(m.overall > 0.7, "overall {}", m.overall);
    }

    #[test]
    fn noise_scores_low() {
        let (b, t, d) = (16, 32, 16);
        let r = reference_stats(&gen_samples(1, b, t, d), b, t, d);
        let mut rng = Rng::new(3);
        let noise = rng.normal_vec(b * t * d, 0.0, 1.0);
        let m_ref = video_metrics(&gen_samples(2, b, t, d), b, t, d, &r);
        let m_noise = video_metrics(&noise, b, t, d, &r);
        assert!(m_noise.overall < m_ref.overall - 0.1,
            "noise {} vs real {}", m_noise.overall, m_ref.overall);
        assert!(m_noise.subject_consistency < m_ref.subject_consistency);
        assert!(m_noise.temporal_flickering < m_ref.temporal_flickering);
    }

    #[test]
    fn frozen_video_has_zero_dynamics() {
        let (b, t, d) = (8, 32, 16);
        let r = reference_stats(&gen_samples(1, b, t, d), b, t, d);
        // Repeat a single frame per clip: perfect consistency, no motion.
        let mut frozen = Vec::with_capacity(b * t * d);
        let mut rng = Rng::new(4);
        for _ in 0..b {
            let f = rng.normal_vec(d, 0.0, 1.0);
            for _ in 0..t {
                frozen.extend_from_slice(&f);
            }
        }
        let m = video_metrics(&frozen, b, t, d, &r);
        assert_eq!(m.dynamic_degree, 0.0);
        assert!(m.subject_consistency > 0.99);
    }
}
