//! Evaluation harness: LM metrics, VBench-proxy video metrics, judge.

pub mod judge;
pub mod lm;
pub mod video;

pub use judge::{judge_pairwise, JudgeOutcome};
pub use lm::{mc_accuracy, perplexity};
pub use video::{video_metrics, VideoMetrics, VideoRefStats};
