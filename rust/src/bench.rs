//! In-tree micro-benchmark harness (substrate; criterion is unavailable in
//! the offline build).
//!
//! Measures wall time per iteration with warmup, reports median / p10 /
//! p90, and appends JSON lines to `results/bench/<group>.jsonl` so bench
//! runs accumulate a comparable history (the §Perf before/after log).

use std::io::Write;
use std::time::Instant;

use crate::json::Json;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    /// Optional work units per iteration (flops, tokens, elements...)
    pub units_per_iter: f64,
    pub unit: &'static str,
}

impl BenchResult {
    /// Units per second at the median time.
    pub fn throughput(&self) -> f64 {
        if self.units_per_iter > 0.0 {
            self.units_per_iter / (self.median_ns * 1e-9)
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("median_ns", Json::Num(self.median_ns)),
            ("p10_ns", Json::Num(self.p10_ns)),
            ("p90_ns", Json::Num(self.p90_ns)),
            ("units_per_iter", Json::Num(self.units_per_iter)),
            ("unit", Json::Str(self.unit.to_string())),
            ("throughput", Json::Num(self.throughput())),
        ])
    }
}

/// Time `f` with `warmup` throwaway and `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> BenchResult {
    bench_units(name, warmup, iters, 0.0, "", f)
}

/// Like [`bench`] but records `units_per_iter` for throughput reporting.
pub fn bench_units<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    units_per_iter: f64,
    unit: &'static str,
    mut f: F,
) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    BenchResult {
        name: name.to_string(),
        iters,
        median_ns: pct(0.5),
        p10_ns: pct(0.1),
        p90_ns: pct(0.9),
        units_per_iter,
        unit,
    }
}

/// Collects results, prints a table, persists JSONL under `results/bench/`.
pub struct Reporter {
    group: String,
    /// Free-form run-configuration string stamped into the provenance
    /// header ([`Reporter::set_config`]); empty by default.
    config: String,
    results: Vec<BenchResult>,
}

impl Reporter {
    pub fn new(group: &str) -> Reporter {
        println!("== bench group: {group} ==");
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>16}",
            "name", "median", "p10", "p90", "throughput"
        );
        Reporter { group: group.to_string(), config: String::new(), results: Vec::new() }
    }

    /// Describe the run's configuration (shape, iteration counts, ...):
    /// recorded verbatim in the `runmeta` provenance line [`save`] writes.
    ///
    /// [`save`]: Reporter::save
    pub fn set_config(&mut self, config: &str) {
        self.config = config.to_string();
    }

    pub fn push(&mut self, r: BenchResult) {
        let tput = if r.units_per_iter > 0.0 {
            format!("{:.3e} {}/s", r.throughput(), r.unit)
        } else {
            "-".to_string()
        };
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>16}",
            r.name,
            fmt_ns(r.median_ns),
            fmt_ns(r.p10_ns),
            fmt_ns(r.p90_ns),
            tput
        );
        self.results.push(r);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Append all results to `results/bench/<group>.jsonl`, preceded by a
    /// `{"kind":"runmeta",...}` provenance header (git rev, bench name,
    /// config string, wall-clock stamp) so accumulated rows stay
    /// attributable to the commit and configuration that produced them.
    pub fn save(&self) -> std::io::Result<()> {
        let dir = std::path::Path::new("results/bench");
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(dir.join(format!("{}.jsonl", self.group)))?;
        writeln!(f, "{}", crate::telemetry::runmeta(&self.group, &self.config))?;
        for r in &self.results {
            writeln!(f, "{}", r.to_json())?;
        }
        Ok(())
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let r = bench("spin", 1, 5, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i);
            }
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
        assert!(acc > 0);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            median_ns: 1e9,
            p10_ns: 1e9,
            p90_ns: 1e9,
            units_per_iter: 100.0,
            unit: "tok",
        };
        assert!((r.throughput() - 100.0).abs() < 1e-9);
    }
}
