//! Analytical performance model (Figure 5's speedup shape + TPU estimates).
//!
//! The CPU testbed cannot exhibit FP4 tensor-core speedups, so — per the
//! substitution rule (DESIGN.md §2) — we model kernel time on the paper's
//! hardware (RTX 5090) from first principles: matmul time at the format's
//! tensor-core rate, plus elementwise preprocessing at memory bandwidth,
//! plus HBM traffic. What the model must reproduce is the *shape* of
//! Figure 5: FP4 variants ≫ BF16 FlashAttention, and Attn-QAT 1.1–1.5×
//! over SageAttention3 because it skips Smooth-QK and two-level-P work.
//!
//! The same module provides the TPU-side VMEM/MXU estimates quoted in
//! DESIGN.md §3 for the Pallas kernel.

/// Hardware profile (defaults ≈ RTX 5090).
#[derive(Clone, Copy, Debug)]
pub struct Hw {
    /// Dense BF16 tensor-core throughput, FLOP/s.
    pub bf16_flops: f64,
    /// Dense FP4 (NVFP4) tensor-core throughput, FLOP/s.
    pub fp4_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Effective elementwise (CUDA-core) throughput, elements/s.
    pub elementwise_eps: f64,
}

impl Default for Hw {
    fn default() -> Hw {
        Hw {
            bf16_flops: 210e12,
            fp4_flops: 840e12, // 4× bf16 dense (Blackwell NVFP4, no sparsity)
            hbm_bw: 1.79e12,
            elementwise_eps: 5.0e12,
        }
    }
}

/// Attention kernel variants of Figure 5.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// FlashAttention-2, BF16 matmuls, no quantization.
    Fa2Bf16,
    /// SageAttention3: FP4 matmuls + Smooth-QK + two-level P.
    Sage3,
    /// Attn-QAT inference: FP4 matmuls, plain φ quantization only.
    AttnQat,
}

/// Modeled kernel execution estimate.
#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    pub matmul_s: f64,
    pub elementwise_s: f64,
    pub memory_s: f64,
    pub total_s: f64,
    /// Achieved fraction of the format's tensor-core roofline.
    pub mxu_utilization: f64,
}

/// Model one attention forward: batch `b`, heads `h`, seq `n`, head dim `d`.
pub fn estimate(k: Kernel, hw: &Hw, b: usize, h: usize, n: usize, d: usize) -> Estimate {
    let bh = (b * h) as f64;
    let nf = n as f64;
    let df = d as f64;
    // Two matmuls: S = QKᵀ and O = P·V, each 2·n²·d FLOPs per head.
    let mm_flops = 2.0 * 2.0 * bh * nf * nf * df;
    let mm_rate = match k {
        Kernel::Fa2Bf16 => hw.bf16_flops,
        _ => hw.fp4_flops,
    };
    let matmul_s = mm_flops / mm_rate;

    // Elementwise work (element-visits), per variant:
    //   softmax machinery (exp, max, rescale): ~4 visits of the n² scores.
    let mut ew = 4.0 * bh * nf * nf;
    match k {
        Kernel::Fa2Bf16 => {}
        Kernel::Sage3 => {
            // quantize Q,K,V (2 visits each: amax + round), smooth Q,K
            // (mean + subtract: 2 visits each), P quantize with two-level
            // (rowmax + rescale + amax + round + unscale: 5 visits of n²),
            // ΔS correction accumulation (1 visit of n²).
            ew += 2.0 * 3.0 * bh * nf * df; // quantize QKV
            ew += 2.0 * 2.0 * bh * nf * df; // smooth Q and K
            ew += 5.0 * bh * nf * nf; // two-level P + ΔS add-back
        }
        Kernel::AttnQat => {
            ew += 2.0 * 3.0 * bh * nf * df; // quantize QKV
            ew += 2.0 * bh * nf * nf; // plain P quantize (amax + round)
        }
    }
    let elementwise_s = ew / hw.elementwise_eps;

    // HBM traffic: all variants read BF16 Q/K/V once (FP4 kernels quantize
    // on the fly in-register) and write O in BF16; traffic is ~equal, the
    // win is matmul rate + elementwise work.
    let bytes = bh * nf * df * (3.0 * 2.0 + 2.0);
    let memory_s = bytes / hw.hbm_bw;

    // Matmul overlaps poorly with elementwise in these kernels (the paper's
    // speedup comes precisely from removing elementwise work): serialize
    // matmul+elementwise, overlap memory.
    let total_s = (matmul_s + elementwise_s).max(memory_s);
    Estimate {
        matmul_s,
        elementwise_s,
        memory_s,
        total_s,
        mxu_utilization: matmul_s / total_s,
    }
}

/// Modeled speedup of `a` over `b` on identical shapes.
pub fn speedup(a: Kernel, b: Kernel, hw: &Hw, bs: usize, h: usize, n: usize, d: usize) -> f64 {
    estimate(b, hw, bs, h, n, d).total_s / estimate(a, hw, bs, h, n, d).total_s
}

// ---------------------------------------------------------------------------
// TPU-side estimates for the Pallas kernel (DESIGN.md §3)
// ---------------------------------------------------------------------------

/// VMEM bytes one grid step of the Alg. 2 forward needs (f32 tiles).
pub fn pallas_vmem_bytes(bq: usize, bk: usize, d: usize) -> usize {
    // Q, O, O' tiles (bq×d), K, V tiles (bk×d, double-buffered ×2),
    // S/P tiles (bq×bk), m/l/alpha rows (3×bq).
    4 * (3 * bq * d + 2 * 2 * bk * d + 2 * bq * bk + 3 * bq)
}

/// True when the tile configuration fits a TPU core's VMEM (~16 MiB).
pub fn pallas_fits_vmem(bq: usize, bk: usize, d: usize) -> bool {
    pallas_vmem_bytes(bq, bk, d) < 16 * 1024 * 1024
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_shape_holds() {
        let hw = Hw::default();
        for &n in &[1024usize, 2048, 4096, 8192] {
            for &d in &[64usize, 128] {
                let s_qat_sage = speedup(Kernel::AttnQat, Kernel::Sage3, &hw, 16, 16, n, d);
                assert!(
                    (1.05..1.8).contains(&s_qat_sage),
                    "attn-qat/sage3 at n={n} d={d}: {s_qat_sage}"
                );
                let s_qat_fa2 = speedup(Kernel::AttnQat, Kernel::Fa2Bf16, &hw, 16, 16, n, d);
                assert!(s_qat_fa2 > 1.2, "attn-qat/fa2 at n={n} d={d}: {s_qat_fa2}");
            }
        }
    }

    #[test]
    fn utilization_increases_with_head_dim() {
        let hw = Hw::default();
        let lo = estimate(Kernel::AttnQat, &hw, 16, 16, 4096, 64).mxu_utilization;
        let hi = estimate(Kernel::AttnQat, &hw, 16, 16, 4096, 128).mxu_utilization;
        assert!(hi > lo, "{hi} vs {lo}");
    }

    #[test]
    fn design_md_vmem_figures() {
        // The DESIGN.md §3 numbers: 128×128 tiles, d=128 fit comfortably.
        assert!(pallas_fits_vmem(128, 128, 128));
        assert!(!pallas_fits_vmem(2048, 2048, 512));
    }
}
